"""Architecture registry: --arch <id> resolution + per-shape config adaptation."""
from __future__ import annotations

import dataclasses

from repro.configs import (
    deepseek_7b, deepseek_coder_33b, equiformer_v2, gin_tu, granite_moe_1b,
    graphcast, grok_1_314b, meshgraphnet, rgl_paper, starcoder2_3b, wide_deep,
)
from repro.configs.common import (
    ArchSpec, ShapeSpec, gnn_inputs, lm_inputs, recsys_inputs,
)

REGISTRY = {
    spec.arch_id: spec
    for spec in [
        starcoder2_3b.CONFIG, deepseek_7b.CONFIG, deepseek_coder_33b.CONFIG,
        grok_1_314b.CONFIG, granite_moe_1b.CONFIG,
        graphcast.CONFIG, meshgraphnet.CONFIG, gin_tu.CONFIG,
        equiformer_v2.CONFIG, wide_deep.CONFIG,
    ]
}

RGL_PAPER = rgl_paper.CONFIG

ARCH_IDS = sorted(REGISTRY)


def get_config(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return REGISTRY[arch_id]


def effective_model_cfg(spec: ArchSpec, shape: ShapeSpec):
    """Adapt the published config to the assigned input shape.

    * GNN: d_in/d_out track the shape's feature/target widths (padded to a
      multiple of 16 so the "model" mesh axis divides the feature dim); the
      arch's depth/width/equivariance stay fixed — those are what the config
      pins.
    * LM: vocab padded to a multiple of 256 (MaxText-style logical vocab
      padding) so the vocab-sharded embed/head divide over "model".
    """
    from repro.configs.common import ceil_to

    cfg = spec.model_cfg
    if spec.family == "lm":
        vp = ceil_to(cfg.vocab, 256)
        if vp != cfg.vocab:
            cfg = dataclasses.replace(cfg, vocab=vp)
    elif spec.family == "gnn":
        p = shape.params
        d_in = ceil_to(p["d_feat"], 16)
        repl = dict(d_in=d_in, d_out=p["d_out"])
        if cfg.arch == "graphcast":
            repl["n_vars"] = d_in
            repl["d_out"] = d_in  # graphcast predicts its input stack
        if shape.name == "molecule":
            repl["graph_readout"] = cfg.arch != "graphcast"
        cfg = dataclasses.replace(cfg, **repl)
    return cfg


def input_specs(arch_id: str, shape_name: str, *, abstract: bool = True):
    """ShapeDtypeStruct stand-ins for every model input of the given cell."""
    spec = get_config(arch_id)
    shape = spec.shapes[shape_name]
    if shape.kind == "skip":
        raise ValueError(
            f"{arch_id} x {shape_name} is a documented skip: {shape.params['reason']}"
        )
    cfg = effective_model_cfg(spec, shape)
    builder = {"lm": lm_inputs, "gnn": gnn_inputs, "recsys": recsys_inputs}[spec.family]
    return builder(shape, cfg, abstract=abstract)


__all__ = [
    "REGISTRY", "ARCH_IDS", "RGL_PAPER", "get_config", "effective_model_cfg",
    "input_specs",
]
