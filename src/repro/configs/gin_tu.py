"""GIN [arXiv:1810.00826; paper] — 5 layers, d=64, sum agg, learnable eps."""
from repro.configs.common import ArchSpec, GNN_SHAPES
from repro.models.gnn.config import GNNConfig

CONFIG = ArchSpec(
    arch_id="gin-tu",
    family="gnn",
    model_cfg=GNNConfig(
        name="gin-tu", arch="gin", n_layers=5, d_hidden=64,
        d_in=64, d_out=16, aggregator="sum", mlp_layers=2,
    ),
    shapes=GNN_SHAPES,
    reduced_cfg=GNNConfig(
        name="gin-smoke", arch="gin", n_layers=2, d_hidden=16,
        d_in=16, d_out=4, aggregator="sum",
    ),
    source="arXiv:1810.00826; paper",
)
