"""EquiformerV2 [arXiv:2306.12059; unverified] — 12 layers, d=128, l_max=6,
m_max=2, 8 heads, SO(2)-eSCN equivariant graph attention.

TPU adaptation (DESIGN.md §2): per-edge Wigner rotations are served from a
quantized direction LUT (32x64 bins); equivariance error is first-order in
bin width and measured in tests.  Non-geometric assigned shapes (citation /
products graphs) get synthetic 3D positions via input_specs.
"""
from repro.configs.common import ArchSpec, GNN_SHAPES
from repro.models.gnn.config import GNNConfig

CONFIG = ArchSpec(
    arch_id="equiformer-v2",
    family="gnn",
    model_cfg=GNNConfig(
        name="equiformer-v2", arch="equiformer_v2", n_layers=12, d_hidden=128,
        d_in=128, d_out=1, l_max=6, m_max=2, n_heads=8, n_wigner_bins=2048,
    ),
    shapes=GNN_SHAPES,
    reduced_cfg=GNNConfig(
        name="equiformer-smoke", arch="equiformer_v2", n_layers=2, d_hidden=16,
        d_in=16, d_out=4, l_max=2, m_max=1, n_heads=4, n_wigner_bins=128,
    ),
    source="arXiv:2306.12059; unverified",
)
