"""DeepSeek-Coder-33B [arXiv:2401.14196; hf] — llama-arch, GQA kv=8."""
from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer.config import TransformerConfig

CONFIG = ArchSpec(
    arch_id="deepseek-coder-33b",
    family="lm",
    model_cfg=TransformerConfig(
        name="deepseek-coder-33b",
        n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
        d_ff=19200, vocab=32256,
    ),
    shapes=lm_shapes(sliding_window=None),
    reduced_cfg=TransformerConfig(
        name="deepseek-coder-33b-smoke",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
        d_ff=192, vocab=128, dtype="float32",
    ),
    source="arXiv:2401.14196; hf",
)
