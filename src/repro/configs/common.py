"""Config framework: ArchSpec + per-family input builders.

Every assigned architecture registers an ArchSpec carrying its exact
published configuration, its shape set, a `reduced()` smoke config, and
builders that yield either ShapeDtypeStructs (dry-run: no allocation) or
real arrays (smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | long_decode | infer | retrieval
    params: dict


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys
    model_cfg: object
    shapes: dict
    reduced_cfg: object  # tiny same-family config for CPU smoke tests
    source: str  # citation tag from the assignment
    notes: str = ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def ceil_to(x: int, m: int) -> int:
    return -(-int(x) // m) * m


EDGE_CHUNK = 16384  # equiformer edge-scan chunk; edge padding unit for big E


def padded_edges(shape: "ShapeSpec") -> int:
    """Edge-array length after sharding/chunk-friendly padding (mask-safe)."""
    p = shape.params
    if shape.name == "minibatch_lg":
        e = p["block_edges"]
    elif shape.name == "molecule":
        e = p["n_edges"] * p["batch"] * 2
    else:
        e = p["n_edges"]
    return ceil_to(e, EDGE_CHUNK if e > EDGE_CHUNK else 512)


def lm_shapes(*, sliding_window: Optional[int] = None) -> dict:
    """The 4 assigned LM shapes.  long_500k only for sub-quadratic archs."""
    shapes = {
        "train_4k": ShapeSpec("train_4k", "train",
                              dict(seq_len=4096, global_batch=256)),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                                 dict(seq_len=32768, global_batch=32)),
        "decode_32k": ShapeSpec("decode_32k", "decode",
                                dict(seq_len=32768, global_batch=128)),
    }
    if sliding_window is not None:
        shapes["long_500k"] = ShapeSpec(
            "long_500k", "long_decode",
            dict(seq_len=524288, global_batch=1, cache_len=sliding_window),
        )
    else:
        shapes["long_500k"] = ShapeSpec(
            "long_500k", "skip",
            dict(reason="pure full-attention arch; sub-quadratic attention "
                        "required at 524k context (DESIGN.md §4)"),
        )
    return shapes


GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        dict(n_nodes=2708, n_edges=10556, d_feat=1433, d_out=40),
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train",
        dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
             fanout=(15, 10), d_feat=602, d_out=41,
             # sampled-block static shapes:
             block_nodes=1024 + 1024 * 15 + 1024 * 150,
             block_edges=1024 * 15 + 1024 * 150),
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "train",
        dict(n_nodes=2449029, n_edges=61859140, d_feat=100, d_out=47),
    ),
    "molecule": ShapeSpec(
        "molecule", "train",
        dict(n_nodes=30, n_edges=64, batch=128, d_feat=16, d_out=4),
    ),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeSpec("serve_p99", "infer", dict(batch=512)),
    "serve_bulk": ShapeSpec("serve_bulk", "infer", dict(batch=262144)),
    "retrieval_cand": ShapeSpec(
        "retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000, k=100)
    ),
}


# ---------------------------------------------------------------------------
# input builders (abstract=True -> ShapeDtypeStruct, no allocation)
# ---------------------------------------------------------------------------
def lm_inputs(shape: ShapeSpec, cfg, *, abstract: bool = True):
    p = shape.params
    if shape.kind == "train":
        b, s = p["global_batch"], p["seq_len"]
        out = {
            "tokens": sds((b, s), jnp.int32),
            "loss_mask": sds((b, s), jnp.bool_),
        }
    elif shape.kind == "prefill":
        b, s = p["global_batch"], p["seq_len"]
        out = {
            "tokens": sds((b, s), jnp.int32),
            "true_len": sds((b,), jnp.int32),
        }
    elif shape.kind in ("decode", "long_decode"):
        b = p["global_batch"]
        sc = p.get("cache_len", p["seq_len"])
        L, kv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
        cache_dt = jnp.int8 if cfg.kv_quant else jnp.dtype(cfg.dtype)
        out = {
            "token": sds((b,), jnp.int32),
            "cache_k": sds((L, b, sc, kv, dh), cache_dt),
            "cache_v": sds((L, b, sc, kv, dh), cache_dt),
            "cache_pos": sds((b, sc), jnp.int32),
            "cursor": sds((b,), jnp.int32),
        }
        if cfg.kv_quant:
            out["k_scale"] = sds((L, b, sc, kv), jnp.bfloat16)
            out["v_scale"] = sds((L, b, sc, kv), jnp.bfloat16)
    else:
        raise ValueError(shape.kind)
    if abstract:
        return out
    rng = np.random.default_rng(0)
    return concretize(out, rng, vocab=cfg.vocab)


def gnn_inputs(shape: ShapeSpec, cfg, *, abstract: bool = True):
    p = shape.params
    if shape.name == "minibatch_lg":
        n = p["block_nodes"]
    elif shape.name == "molecule":
        n = p["n_nodes"] * p["batch"]
    else:
        n = p["n_nodes"]
    e = padded_edges(shape)
    d_in = cfg.d_in
    out = {
        "node_feat": sds((n, d_in), jnp.float32),
        "edge_src": sds((e,), jnp.int32),
        "edge_dst": sds((e,), jnp.int32),
        "edge_mask": sds((e,), jnp.bool_),
    }
    if cfg.arch == "equiformer_v2":
        out["pos"] = sds((n, 3), jnp.float32)
        out["wigner_lut"] = sds(
            (cfg.n_wigner_bins, cfg.sphere_k, cfg.sphere_k), jnp.float32
        )
    if shape.name == "molecule" and cfg.graph_readout:
        out["targets"] = sds((p["batch"], cfg.d_out), jnp.float32)
        out["graph_ids"] = sds((n,), jnp.int32)
    else:
        out["targets"] = sds((n, cfg.d_out), jnp.float32)
        out["node_mask"] = sds((n,), jnp.float32)
    if abstract:
        return out
    return concretize(out, np.random.default_rng(0), n_nodes=n)


def recsys_inputs(shape: ShapeSpec, cfg, *, abstract: bool = True):
    p = shape.params
    if shape.kind == "retrieval":
        out = {
            "query": sds((p["batch"], cfg.mlp[-1]), jnp.float32),
            "cand_emb": sds((p["n_candidates"], cfg.mlp[-1]), jnp.float32),
        }
    else:
        b = p["batch"]
        out = {
            "dense": sds((b, cfg.n_dense), jnp.float32),
            "sparse_ids": sds((b, cfg.n_sparse, cfg.bag_size), jnp.int32),
        }
        if shape.kind == "train":
            out["labels"] = sds((b,), jnp.float32)
    if abstract:
        return out
    return concretize(out, np.random.default_rng(0), vocab=cfg.rows_per_field)


def concretize(tree, rng, *, vocab: int = 64, n_nodes: int = 8):
    """Fill a ShapeDtypeStruct tree with small random arrays (smoke tests)."""

    def fill(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if x.dtype == jnp.bool_:
            return jnp.ones(x.shape, bool)
        if jnp.issubdtype(x.dtype, jnp.integer):
            if "edge" in name or name in ("graph_ids",):
                hi = max(n_nodes, 2)
            elif name in ("token", "tokens"):
                hi = vocab
            elif name == "sparse_ids":
                hi = vocab
            elif name in ("cache_pos",):
                return jnp.full(x.shape, -1, jnp.int32)
            elif name in ("cursor", "true_len"):
                return jnp.full(x.shape, 1, jnp.int32)
            else:
                hi = 2
            return jnp.asarray(rng.integers(0, hi, x.shape), x.dtype)
        return jnp.asarray(rng.standard_normal(x.shape) * 0.1, x.dtype)

    return jax.tree_util.tree_map_with_path(fill, tree)
