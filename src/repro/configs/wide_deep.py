"""Wide & Deep [arXiv:1606.07792; paper] — 40 sparse fields, embed 32,
MLP 1024-512-256, concat interaction.  Tables: 40 x 1M rows (row-sharded)."""
from repro.configs.common import ArchSpec, RECSYS_SHAPES
from repro.models.recsys.wide_deep import WideDeepConfig

CONFIG = ArchSpec(
    arch_id="wide-deep",
    family="recsys",
    model_cfg=WideDeepConfig(
        name="wide-deep", n_sparse=40, rows_per_field=1_000_000, embed_dim=32,
        n_dense=13, mlp=(1024, 512, 256), bag_size=4,
    ),
    shapes=RECSYS_SHAPES,
    reduced_cfg=WideDeepConfig(
        name="wide-deep-smoke", n_sparse=6, rows_per_field=128, embed_dim=8,
        n_dense=5, mlp=(32, 16), bag_size=3,
    ),
    source="arXiv:1606.07792; paper",
)
