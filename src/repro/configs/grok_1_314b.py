"""Grok-1 314B [hf:xai-org/grok-1; unverified] — MoE 8 experts top-2.

8 experts < 16 model-mesh devices => "tp" MoE sharding (d_ff split over
"model", experts over "data" via the 2D weight sharding).  bf16 optimizer
moments keep the per-chip HBM budget under 16 GB (EXPERIMENTS.md §Dry-run).
"""
from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer.config import MoEConfig, TransformerConfig

CONFIG = ArchSpec(
    arch_id="grok-1-314b",
    family="lm",
    model_cfg=TransformerConfig(
        name="grok-1-314b",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=0, vocab=131072,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32768, shard_mode="tp"),
    ),
    shapes=lm_shapes(sliding_window=None),
    reduced_cfg=TransformerConfig(
        name="grok-1-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=0, vocab=128, dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=128, shard_mode="tp"),
    ),
    source="hf:xai-org/grok-1; unverified",
)
