"""The paper's own experiment configuration (RGL pipeline defaults).

Dataset scales mirror the paper: OGBN-Arxiv-like citation graph (169,343
nodes / 1.15M edges) for abstract generation + retrieval-scaling, and the
Baby/Sports bipartite graphs for modality completion.  Benchmarks use
`scale` to run reduced-size versions on this CPU-only container; ratios,
not absolute times, reproduce Fig. 2/4.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RGLPaperConfig:
    # retrieval pipeline (paper §2)
    strategies: tuple = ("bfs", "dense", "steiner")
    k_seeds: int = 4
    max_hops: int = 3
    max_nodes: int = 64
    filter_budget: int = 32
    # datasets (paper §3)
    arxiv_nodes: int = 169_343
    arxiv_edges: int = 1_157_799
    arxiv_feat: int = 128
    baby_users: int = 19_445
    baby_items: int = 7_050
    baby_inter: int = 160_792
    sports_users: int = 35_598
    sports_items: int = 18_357
    sports_inter: int = 296_337
    missing_rate: float = 0.4  # paper Table 1 masking
    query_counts: tuple = (10, 100, 1000, 10_000)  # paper Fig. 4 x-axis


CONFIG = RGLPaperConfig()
