"""Granite-3.0-1B-A400M [hf:ibm-granite; hf] — MoE 32 experts top-8.

32 experts >= 16 model-mesh devices => true expert parallelism ("expert"
shard mode; dispatch lowers to all-to-all over "model")."""
from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer.config import MoEConfig, TransformerConfig

CONFIG = ArchSpec(
    arch_id="granite-moe-1b-a400m",
    family="lm",
    model_cfg=TransformerConfig(
        name="granite-moe-1b-a400m",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_head=64,
        d_ff=0, vocab=49155,
        moe=MoEConfig(n_experts=32, top_k=8, d_ff=512, shard_mode="expert"),
    ),
    shapes=lm_shapes(sliding_window=None),
    reduced_cfg=TransformerConfig(
        name="granite-moe-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=0, vocab=128, dtype="float32",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=64, shard_mode="expert"),
    ),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
