"""GraphCast [arXiv:2212.12794; unverified] — encoder-processor-decoder mesh
GNN, 16 layers, d_hidden=512, sum aggregation, n_vars=227.

Adaptation note (DESIGN.md §4): assigned input shapes supply one generic
graph, so the grid<->mesh bipartite encoder/decoder degenerate to per-node
MLPs and n_vars tracks the shape's d_feat; the 16-layer processor — the
compute hot spot — is exercised unchanged.  mesh_refinement=6 is recorded
for provenance (it fixes the mesh size in the weather deployment).
"""
from repro.configs.common import ArchSpec, GNN_SHAPES
from repro.models.gnn.config import GNNConfig

CONFIG = ArchSpec(
    arch_id="graphcast",
    family="gnn",
    model_cfg=GNNConfig(
        name="graphcast", arch="graphcast", n_layers=16, d_hidden=512,
        d_in=227, d_out=227, n_vars=227, mesh_refinement=6, aggregator="sum",
    ),
    shapes=GNN_SHAPES,
    reduced_cfg=GNNConfig(
        name="graphcast-smoke", arch="graphcast", n_layers=2, d_hidden=32,
        d_in=16, d_out=16, n_vars=16, aggregator="sum",
    ),
    source="arXiv:2212.12794; unverified",
)
