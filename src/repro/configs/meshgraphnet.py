"""MeshGraphNet [arXiv:2010.03409; unverified] — 15 layers, d=128, sum agg,
2-hidden-layer LayerNorm'd MLPs for edge and node updates."""
from repro.configs.common import ArchSpec, GNN_SHAPES
from repro.models.gnn.config import GNNConfig

CONFIG = ArchSpec(
    arch_id="meshgraphnet",
    family="gnn",
    model_cfg=GNNConfig(
        name="meshgraphnet", arch="meshgraphnet", n_layers=15, d_hidden=128,
        d_in=128, d_out=128, aggregator="sum", mlp_layers=2,
    ),
    shapes=GNN_SHAPES,
    reduced_cfg=GNNConfig(
        name="meshgraphnet-smoke", arch="meshgraphnet", n_layers=2,
        d_hidden=32, d_in=16, d_out=8, aggregator="sum", mlp_layers=2,
    ),
    source="arXiv:2010.03409; unverified",
)
