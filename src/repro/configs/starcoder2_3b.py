"""StarCoder2-3B [arXiv:2402.19173; hf] — GQA kv=2, RoPE, sliding-window 4096.

The sliding window makes it the one assigned LM that runs `long_500k`
(O(window) per decoded token via the ring-buffer KV cache).
"""
from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer.config import TransformerConfig

CONFIG = ArchSpec(
    arch_id="starcoder2-3b",
    family="lm",
    model_cfg=TransformerConfig(
        name="starcoder2-3b",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
        d_ff=12288, vocab=49152, sliding_window=4096, rope_theta=1e5,
    ),
    shapes=lm_shapes(sliding_window=4096),
    reduced_cfg=TransformerConfig(
        name="starcoder2-3b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=256, vocab=128, sliding_window=16, dtype="float32",
    ),
    source="arXiv:2402.19173; hf",
)
