"""DeepSeek-7B [arXiv:2401.02954; hf] — llama-arch, GQA kv=32 (== MHA)."""
from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer.config import TransformerConfig

CONFIG = ArchSpec(
    arch_id="deepseek-7b",
    family="lm",
    model_cfg=TransformerConfig(
        name="deepseek-7b",
        n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_head=128,
        d_ff=11008, vocab=102400,
    ),
    shapes=lm_shapes(sliding_window=None),
    reduced_cfg=TransformerConfig(
        name="deepseek-7b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=224, vocab=128, dtype="float32",
    ),
    source="arXiv:2401.02954; hf",
)
