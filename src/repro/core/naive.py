"""NetworkX-class pure-Python baseline for graph retrieval.

Same complexity class as the paper's NetworkX baseline (adjacency-dict
traversal, one query at a time, interpreted).  Used both as the correctness
oracle for the batched JAX implementations and as the slow side of the
Fig. 2/4 speedup benchmark.
"""
from __future__ import annotations

import heapq
from collections import deque


def bfs_distances(adj: dict, seeds, max_hops: int) -> dict:
    dist = {s: 0 for s in seeds}
    dq = deque(seeds)
    while dq:
        u = dq.popleft()
        if dist[u] >= max_hops:
            continue
        for v in adj[u]:
            if v not in dist:
                dist[v] = dist[u] + 1
                dq.append(v)
    return dist


def bfs_subgraph(adj: dict, seeds, max_hops: int, max_nodes: int) -> list:
    """Closest-first ball; ties by node id (matches the batched kernel)."""
    dist = bfs_distances(adj, seeds, max_hops)
    order = sorted(dist.items(), key=lambda kv: (kv[1], kv[0]))
    return [u for u, _ in order[:max_nodes]]


def dense_subgraph(
    adj: dict, seeds, max_hops: int, max_nodes: int, n_rounds: int = 3
) -> list:
    """Greedy internal-degree peeling (mirror of the batched heuristic)."""
    cand = set(bfs_distances(adj, seeds, max_hops))
    dist = bfs_distances(adj, seeds, max_hops)
    seeds = set(seeds)
    for _ in range(n_rounds):
        deg = {u: sum(1 for v in adj[u] if v in cand) for u in cand}
        if len(cand) <= max_nodes:
            break
        kth = sorted(deg.values(), reverse=True)[min(max_nodes, len(deg)) - 1]
        cand = {u for u in cand if deg[u] >= kth} | seeds
    deg = {u: sum(1 for v in adj[u] if v in cand) for u in cand}
    order = sorted(cand, key=lambda u: (-deg[u], dist.get(u, 1 << 30), u))
    return order[:max_nodes]


def steiner_subgraph(adj: dict, terminals, max_hops: int, max_nodes: int) -> list:
    """KMB 2-approximation with BFS metric (unweighted graphs)."""
    terminals = [t for t in terminals if t >= 0]
    if not terminals:
        return []
    # Voronoi: nearest terminal (lowest slot wins ties), dist field
    dist, label = {}, {}
    dq = deque()
    for slot, s in enumerate(terminals):
        if s not in dist:
            dist[s], label[s] = 0, slot
            dq.append(s)
    frontier = list(dq)
    d = 0
    while frontier and d < max_hops:
        nxt = {}
        for u in frontier:
            for v in adj[u]:
                if v not in dist:
                    cand = label[u]
                    if v not in nxt or cand < nxt[v]:
                        nxt[v] = cand
        for v, lb in nxt.items():
            dist[v] = d + 1
            label[v] = lb
        frontier = list(nxt)
        d += 1
    # bridge edges -> terminal-pair metric
    t = len(terminals)
    w = {}
    bridge = {}
    for u in dist:
        for v in adj[u]:
            if v in dist and label[u] != label[v]:
                key = (min(label[u], label[v]), max(label[u], label[v]))
                plen = dist[u] + 1 + dist[v]
                eid = (u, v) if label[u] <= label[v] else (v, u)
                if key not in w or (plen, eid) < (w[key], bridge[key]):
                    w[key], bridge[key] = plen, eid
    # Prim MST over terminals
    in_tree = {0}
    mst = []
    while len(in_tree) < t:
        best = None
        for (a, b), pw in w.items():
            if (a in in_tree) != (b in in_tree):
                if best is None or pw < best[0]:
                    best = (pw, a, b)
        if best is None:
            break
        _, a, b = best
        in_tree.add(a if b in in_tree else b)
        mst.append((a, b))
    # mark terminals + backtraced paths
    marked = set(terminals)

    def descend(u):
        while dist[u] > 0:
            marked.add(u)
            nxts = [v for v in adj[u] if v in dist and dist[v] == dist[u] - 1]
            if not nxts:
                break
            u = min(nxts)
        marked.add(u)

    for a, b in mst:
        u, v = bridge[(min(a, b), max(a, b))]
        descend(u)
        descend(v)
    order = sorted(marked, key=lambda u: (dist.get(u, 1 << 30), u))
    return order[:max_nodes]


def knn_nodes(emb, query, k: int) -> list:
    """Per-query python kNN (the paper's kNN baseline, naive form)."""
    scores = []
    for i in range(len(emb)):
        s = sum(float(a) * float(b) for a, b in zip(emb[i], query))
        heapq.heappush(scores, (-s, i))
    return [heapq.heappop(scores)[1] for _ in range(k)]


def ppr_scores(adj: dict, seeds, alpha: float = 0.85, n_iter: int = 10) -> dict:
    """Per-query personalized PageRank, dict-based power iteration."""
    s0 = 1.0 / max(len(seeds), 1)
    p = {u: s0 for u in seeds}
    for _ in range(n_iter):
        nxt = {u: (1 - alpha) * s0 for u in seeds}
        for u, pu in p.items():
            if not adj[u]:
                continue
            share = alpha * pu / len(adj[u])
            for v in adj[u]:
                nxt[v] = nxt.get(v, 0.0) + share
        p = nxt
    return p


def ppr_subgraph(adj: dict, seeds, max_nodes: int, alpha: float = 0.85,
                 n_iter: int = 10) -> list:
    p = ppr_scores(adj, seeds, alpha, n_iter)
    order = sorted(p, key=lambda u: (-p[u], u))
    return order[:max_nodes]
