"""ROUGE-1 / ROUGE-2 / ROUGE-L (Lin, 2004) — paper Table 2's metrics."""
from __future__ import annotations

from collections import Counter


def _ngram_f1(hyp: list, ref: list, n: int) -> float:
    if len(hyp) < n or len(ref) < n:
        return 0.0
    hc = Counter(tuple(hyp[i : i + n]) for i in range(len(hyp) - n + 1))
    rc = Counter(tuple(ref[i : i + n]) for i in range(len(ref) - n + 1))
    overlap = sum((hc & rc).values())
    if overlap == 0:
        return 0.0
    p = overlap / max(sum(hc.values()), 1)
    r = overlap / max(sum(rc.values()), 1)
    return 2 * p * r / (p + r)


def _lcs(a: list, b: list) -> int:
    if not a or not b:
        return 0
    prev = [0] * (len(b) + 1)
    for x in a:
        cur = [0] * (len(b) + 1)
        for j, y in enumerate(b, 1):
            cur[j] = prev[j - 1] + 1 if x == y else max(prev[j], cur[j - 1])
        prev = cur
    return prev[-1]


def rouge(hyp: str, ref: str) -> dict:
    h, r = hyp.lower().split(), ref.lower().split()
    out = {
        "rouge1": _ngram_f1(h, r, 1),
        "rouge2": _ngram_f1(h, r, 2),
    }
    l = _lcs(h, r)
    if l == 0 or not h or not r:
        out["rougeL"] = 0.0
    else:
        p, rc = l / len(h), l / len(r)
        out["rougeL"] = 2 * p * rc / (p + rc)
    return out


def rouge_corpus(hyps: list, refs: list) -> dict:
    scores = [rouge(h, r) for h, r in zip(hyps, refs)]
    keys = scores[0].keys() if scores else []
    return {k: sum(s[k] for s in scores) / max(len(scores), 1) for k in keys}
