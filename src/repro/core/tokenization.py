"""Stage 4 of the RGL pipeline: tokenization (paper §2.1.4).

A word-level tokenizer (vocab built from the corpus, hashed OOV buckets) and
a graph linearizer that renders a retrieved subgraph into a budgeted prompt:

    [BOS] <query tokens> [CTX] <node_0 tokens> [SEP] <node_1 tokens> ... [GEN]

Node order = retrieval priority (closest/densest first), so truncation under
the token budget drops the least relevant context first — the mechanism the
paper's dynamic filtering feeds.  Output is fixed-shape (L,) int32 + mask.
"""
from __future__ import annotations

import dataclasses

import numpy as np

PAD, BOS, CTX, SEP, GEN, UNK = 0, 1, 2, 3, 4, 5
N_SPECIAL = 6


@dataclasses.dataclass
class Vocab:
    word_to_id: dict
    n_hash: int = 1024

    @property
    def size(self) -> int:
        return N_SPECIAL + len(self.word_to_id) + self.n_hash

    def encode_word(self, w: str) -> int:
        i = self.word_to_id.get(w)
        if i is not None:
            return N_SPECIAL + i
        return N_SPECIAL + len(self.word_to_id) + (hash(w) % self.n_hash)

    @staticmethod
    def build(corpus, max_words: int = 8192, n_hash: int = 1024) -> "Vocab":
        from collections import Counter

        c = Counter()
        for text in corpus:
            c.update(text.lower().split())
        keep = [w for w, _ in c.most_common(max_words)]
        return Vocab({w: i for i, w in enumerate(keep)}, n_hash=n_hash)


class GraphTokenizer:
    def __init__(self, vocab: Vocab, max_len: int = 512, node_budget: int = 48):
        self.vocab = vocab
        self.max_len = max_len
        self.node_budget = node_budget  # max tokens contributed per node

    def encode_text(self, text: str, budget: int) -> list:
        return [self.vocab.encode_word(w) for w in text.lower().split()[:budget]]

    def linearize(
        self,
        query_text: str,
        node_texts: list,  # ordered retrieved-node texts (already filtered)
    ) -> tuple[np.ndarray, np.ndarray]:
        ids = [BOS] + self.encode_text(query_text, self.node_budget) + [CTX]
        for t in node_texts:
            nt = self.encode_text(t, self.node_budget)
            if len(ids) + len(nt) + 2 > self.max_len:
                break
            ids.extend(nt)
            ids.append(SEP)
        ids.append(GEN)
        ids = ids[: self.max_len]
        out = np.full(self.max_len, PAD, dtype=np.int32)
        out[: len(ids)] = ids
        mask = np.zeros(self.max_len, dtype=bool)
        mask[: len(ids)] = True
        return out, mask

    def batch_linearize(self, query_texts, node_texts_per_query):
        ids, masks = zip(
            *(self.linearize(q, ns) for q, ns in zip(query_texts, node_texts_per_query))
        )
        return np.stack(ids), np.stack(masks)


def subgraph_texts(sub, node_text: list) -> list:
    """Materialize per-query ordered node texts from a Subgraph (host side)."""
    out = []
    nodes = np.asarray(sub.nodes)
    mask = np.asarray(sub.mask)
    for qi in range(nodes.shape[0]):
        out.append([node_text[int(v)] for v, m in zip(nodes[qi], mask[qi]) if m])
    return out
