"""Dynamic node filtering (paper §1/§2: cut token consumption pre-generation).

Filters operate on a retrieved :class:`Subgraph` and a per-node relevance
score, reducing the node budget while always preserving the seed terminals.
Fixed shapes: filtering = reordering + masking, never reshaping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.graph_retrieval import Subgraph, INF


@functools.partial(jax.jit, static_argnames=("budget",))
def dynamic_filter(
    sub: Subgraph,
    node_scores: jnp.ndarray,  # (N,) or (Q, N) relevance (higher = keep)
    seeds: jnp.ndarray,  # (Q, S)
    *,
    budget: int,
) -> Subgraph:
    """Keep the ``budget`` highest-scoring retrieved nodes (+ all seeds)."""
    q, m = sub.nodes.shape
    n = sub.num_nodes
    if node_scores.ndim == 1:
        node_scores = jnp.broadcast_to(node_scores[None], (q, n))
    safe = jnp.minimum(sub.nodes, n - 1)
    s = jnp.take_along_axis(node_scores, safe, axis=1)  # (Q, M)
    is_seed = (sub.nodes[:, :, None] == seeds[:, None, :]).any(-1) & sub.mask
    s = jnp.where(is_seed, jnp.inf, s)  # seeds always survive
    s = jnp.where(sub.mask, s, -jnp.inf)
    budget = min(budget, m)
    top_s, pos = jax.lax.top_k(s, budget)
    nodes = jnp.take_along_axis(sub.nodes, pos, axis=1)
    mask = top_s > -jnp.inf
    dist = jnp.take_along_axis(sub.dist, pos, axis=1)
    return Subgraph(
        nodes=jnp.where(mask, nodes, n),
        mask=mask,
        dist=jnp.where(mask, dist, INF),
        num_nodes=n,
        overflow=sub.overflow,  # preserve the compact backend's flags
    )


@functools.partial(jax.jit, static_argnames=())
def similarity_scores(node_emb: jnp.ndarray, query_emb: jnp.ndarray) -> jnp.ndarray:
    """(N, D) x (Q, D) -> (Q, N) cosine relevance for dynamic filtering."""
    ne = node_emb / (jnp.linalg.norm(node_emb, axis=-1, keepdims=True) + 1e-6)
    qe = query_emb / (jnp.linalg.norm(query_emb, axis=-1, keepdims=True) + 1e-6)
    return qe @ ne.T
