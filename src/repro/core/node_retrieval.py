"""Stage 2 of the RGL pipeline: semantic node retrieval (paper §2.1.2).

Embeds queries (optionally through a user-supplied encoder, e.g. one of the
GNN architectures) and returns the top-k seed nodes per query from a vector
index.  Batched end to end.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp


def retrieve_nodes(
    index,
    queries: jnp.ndarray,
    k: int,
    *,
    encoder: Optional[Callable] = None,
):
    """queries: (Q, D_in); returns (scores (Q,k), node_ids (Q,k))."""
    q = jnp.asarray(queries)
    if encoder is not None:
        q = encoder(q)
    return index.search(q, k)
