"""Stage 3 of the RGL pipeline: batched graph retrieval (paper §2.1.3).

TPU-native re-expression of RGL's C++ retrieval engine.  All three paper
strategies — RGL-BFS, RGL-Dense, RGL-Steiner — are implemented as
*fixed-shape frontier algebra* over the ELL adjacency:

* BFS          — pull-based frontier expansion: one (Q, N, K) gather per hop.
* Steiner      — Mehlhorn/KMB 2-approximation: one multi-source
                 label-propagating BFS builds Voronoi cells, bridge edges give
                 terminal-pair distances, a fixed-iteration Prim MST picks the
                 tree topology, and distance-descent backtracing marks path
                 nodes.  Unweighted graphs (all paper datasets) ⇒ BFS ≡ Dijkstra.
* Dense        — greedy peeling: the k-hop candidate ball is refined by
                 iterated internal-degree ranking (densest-subgraph heuristic).

Everything is batched over queries (the paper's core speedup mechanism:
amortize per-query overhead) and jit-compiled; graphs must be symmetric
(generators symmetrize; pull-BFS reads in-neighbors).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.graph.ell import ELLGraph

INF = jnp.int32(0x3FFFFFF)


@dataclasses.dataclass
class Subgraph:
    """Padded per-query subgraph: ``nodes`` ordered by retrieval priority."""

    nodes: jnp.ndarray  # (Q, M) int32, sentinel = num_nodes where ~mask
    mask: jnp.ndarray  # (Q, M) bool
    dist: jnp.ndarray  # (Q, M) int32 hop distance of each picked node
    num_nodes: int  # N of the parent graph


jax.tree_util.register_dataclass(
    Subgraph, data_fields=["nodes", "mask", "dist"], meta_fields=["num_nodes"]
)


def seeds_to_mask(seeds: jnp.ndarray, n: int) -> jnp.ndarray:
    """(Q, S) seed indices (pad with -1 or >=n) -> (Q, N) bool mask."""
    q, s = seeds.shape
    valid = (seeds >= 0) & (seeds < n)
    safe = jnp.where(valid, seeds, 0)
    base = jnp.zeros((q, n), bool)
    return base.at[jnp.arange(q)[:, None], safe].max(valid)


def _frontier_hop(nbr, nbr_mask, frontier):
    """One pull hop: reach[q, v] = OR_k frontier[q, nbr[v, k]]."""
    q = frontier.shape[0]
    fp = jnp.concatenate([frontier, jnp.zeros((q, 1), bool)], axis=1)  # (Q, N+1)
    gathered = fp[:, nbr]  # (Q, N, K)
    return jnp.any(gathered & nbr_mask[None], axis=-1)


@functools.partial(jax.jit, static_argnames=("max_hops",))
def bfs_distances(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds_mask: jnp.ndarray,
    max_hops: int,
) -> jnp.ndarray:
    """Batched BFS hop distances.  (Q, N) int32; INF where unreached."""
    dist0 = jnp.where(seeds_mask, 0, INF)

    def hop(carry, h):
        dist, frontier = carry
        reach = _frontier_hop(nbr, nbr_mask, frontier)
        new = reach & (dist == INF)
        dist = jnp.where(new, h + 1, dist)
        return (dist, new), None

    (dist, _), _ = jax.lax.scan(
        hop, (dist0, seeds_mask), jnp.arange(max_hops, dtype=jnp.int32)
    )
    return dist


@functools.partial(jax.jit, static_argnames=("max_hops",))
def voronoi_bfs(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, T) terminal node ids (may contain -1 padding)
    max_hops: int,
):
    """Multi-source BFS with source labels.

    Returns (dist (Q,N) int32, label (Q,N) int32 in [0,T) or T for none).
    Ties: lowest terminal slot wins (deterministic).
    """
    q, t = seeds.shape
    n = nbr.shape[0]
    valid = (seeds >= 0) & (seeds < n)
    safe = jnp.where(valid, seeds, 0)
    label0 = jnp.full((q, n), t, jnp.int32)
    # lower slot wins ties at init: scatter in reverse slot order via min
    slot = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (q, t))
    slot = jnp.where(valid, slot, t)
    label0 = label0.at[jnp.arange(q)[:, None], safe].min(slot)
    dist0 = jnp.where(label0 < t, 0, INF)

    def hop(carry, h):
        dist, label, frontier = carry
        qn = frontier.shape[0]
        fp = jnp.concatenate([frontier, jnp.zeros((qn, 1), bool)], 1)
        lp = jnp.concatenate([label, jnp.full((qn, 1), t, jnp.int32)], 1)
        g_f = fp[:, nbr]  # (Q, N, K) neighbor-in-frontier
        g_l = lp[:, nbr]  # (Q, N, K) neighbor labels
        active = g_f & nbr_mask[None]
        cand = jnp.where(active, g_l, t)
        best = jnp.min(cand, axis=-1)  # (Q, N) best label among frontier nbrs
        reach = jnp.any(active, axis=-1)
        new = reach & (dist == INF)
        dist = jnp.where(new, h + 1, dist)
        label = jnp.where(new, best, label)
        return (dist, label, new), None

    (dist, label, _), _ = jax.lax.scan(
        hop, (dist0, label0, dist0 == 0), jnp.arange(max_hops, dtype=jnp.int32)
    )
    return dist, label


def _select_by_key(key: jnp.ndarray, keep: jnp.ndarray, m: int, n: int):
    """Pick m nodes with the smallest ``key`` among ``keep``; pad w/ sentinel n.

    Returns (nodes (Q,m) int32, mask (Q,m) bool, order-aligned gather of key).
    """
    big = jnp.int32(0x7FFFFFF0)
    k = jnp.where(keep, key, big)
    neg = -(k.astype(jnp.int32))
    topv, topi = jax.lax.top_k(neg, m)  # largest of -key == smallest key
    mask = topv > -big
    nodes = jnp.where(mask, topi, n).astype(jnp.int32)
    return nodes, mask, jnp.where(mask, -topv, INF)


@functools.partial(jax.jit, static_argnames=("max_hops", "max_nodes"))
def bfs_subgraph(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, S)
    *,
    max_hops: int = 3,
    max_nodes: int = 64,
) -> Subgraph:
    """RGL-BFS: closest-first ball around the retrieved seed nodes."""
    n = nbr.shape[0]
    sm = seeds_to_mask(seeds, n)
    dist = bfs_distances(nbr, nbr_mask, sm, max_hops)
    keep = dist < INF
    d = jnp.minimum(dist, max_hops + 1)
    key = d * jnp.int32(n) + jnp.arange(n, dtype=jnp.int32)[None, :]
    nodes, mask, _ = _select_by_key(key, keep, max_nodes, n)
    dsel = jnp.where(mask, jnp.take_along_axis(d, jnp.minimum(nodes, n - 1), 1), INF)
    return Subgraph(nodes=nodes, mask=mask, dist=dsel, num_nodes=n)


@functools.partial(jax.jit, static_argnames=("max_hops", "max_nodes", "n_rounds"))
def dense_subgraph(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    max_hops: int = 2,
    max_nodes: int = 64,
    n_rounds: int = 3,
) -> Subgraph:
    """RGL-Dense: greedy internal-degree peeling of the k-hop candidate ball."""
    n, k = nbr.shape
    q = seeds.shape[0]
    sm = seeds_to_mask(seeds, n)
    dist = bfs_distances(nbr, nbr_mask, sm, max_hops)
    cand = dist < INF  # (Q, N) candidate ball

    def indeg(c):
        cp = jnp.concatenate([c, jnp.zeros((q, 1), bool)], 1)
        g = cp[:, nbr] & nbr_mask[None]  # (Q, N, K)
        return jnp.sum(g, axis=-1).astype(jnp.int32) * c

    def round_(c, _):
        deg = indeg(c)
        # threshold = max_nodes-th largest degree among candidates
        kth = jax.lax.top_k(jnp.where(c, deg, -1), min(max_nodes, n))[0][:, -1]
        keep = c & (deg >= kth[:, None])
        keep = keep | sm  # never peel seeds
        return keep, None

    cand, _ = jax.lax.scan(round_, cand, None, length=n_rounds)
    deg = indeg(cand)
    # final pick: highest internal degree first, then closer, then lower id;
    # seeds get the minimal key band (always < n) so they are never evicted
    d = jnp.minimum(dist, max_hops + 1)
    key = (jnp.int32(k + 1) - deg) * jnp.int32((max_hops + 2) * n) + d * jnp.int32(n) \
        + jnp.arange(n, dtype=jnp.int32)[None, :]
    key = jnp.where(sm, jnp.arange(n, dtype=jnp.int32)[None, :], key)
    nodes, mask, _ = _select_by_key(key, cand, max_nodes, n)
    dsel = jnp.where(mask, jnp.take_along_axis(d, jnp.minimum(nodes, n - 1), 1), INF)
    return Subgraph(nodes=nodes, mask=mask, dist=dsel, num_nodes=n)


@functools.partial(jax.jit, static_argnames=("max_hops", "max_nodes"))
def steiner_subgraph(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, T) terminals
    *,
    max_hops: int = 4,
    max_nodes: int = 64,
) -> Subgraph:
    """RGL-Steiner: KMB/Mehlhorn 2-approx Steiner tree over the terminals.

    1. Voronoi BFS: dist-to-nearest-terminal + owning terminal per node.
    2. Bridge edges (u,v), label(u) != label(v) give candidate terminal-pair
       path lengths dist(u)+1+dist(v); segment-min over label pairs.
    3. Prim MST over the (T, T) terminal metric (fixed T-1 iterations).
    4. Mark MST-edge bridge endpoints; distance-descent backtrace marks the
       connecting shortest paths.  Tree nodes ranked closest-first.
    """
    n, k = nbr.shape
    q, t = seeds.shape
    dist, label = voronoi_bfs(nbr, nbr_mask, seeds, max_hops)

    # ---- bridge edges between Voronoi cells -------------------------------
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
    dst = nbr  # (N, K)
    dp = jnp.concatenate([dist, jnp.full((q, 1), INF, jnp.int32)], 1)
    lp = jnp.concatenate([label, jnp.full((q, 1), t, jnp.int32)], 1)
    d_src = dist[:, src.reshape(-1)].reshape(q, n * k)
    d_dst = dp[:, dst.reshape(-1)].reshape(q, n * k)
    l_src = label[:, src.reshape(-1)].reshape(q, n * k)
    l_dst = lp[:, dst.reshape(-1)].reshape(q, n * k)
    e_ok = (
        nbr_mask.reshape(-1)[None, :]
        & (l_src < t) & (l_dst < t) & (l_src != l_dst)
        & (d_src < INF) & (d_dst < INF)
    )
    plen = jnp.where(e_ok, d_src + 1 + d_dst, INF)  # (Q, N*K)
    pair = l_src * t + l_dst  # (Q, N*K) in [0, T*T)
    pair = jnp.where(e_ok, pair, 0)

    def seg_min(vals, segs):
        return jax.vmap(
            lambda v, s: jax.ops.segment_min(v, s, num_segments=t * t)
        )(vals, segs)

    w = seg_min(plen, pair)  # (Q, T*T) pairwise path lengths
    # best bridge edge per pair: two-pass argmin (value then edge id)
    eid = jnp.broadcast_to(jnp.arange(n * k, dtype=jnp.int32)[None], (q, n * k))
    at_min = e_ok & (plen == jnp.take_along_axis(w, pair, axis=1))
    best_eid = seg_min(jnp.where(at_min, eid, jnp.int32(n * k)), pair)  # (Q,T*T)
    w = w.reshape(q, t, t)
    w = jnp.minimum(w, jnp.swapaxes(w, 1, 2))  # symmetrize
    w = jnp.where(jnp.eye(t, dtype=bool)[None], INF, w)
    best_eid = jnp.minimum(
        best_eid.reshape(q, t, t), jnp.swapaxes(best_eid.reshape(q, t, t), 1, 2)
    )

    # ---- Prim MST over terminals ------------------------------------------
    in_tree0 = jnp.zeros((q, t), bool).at[:, 0].set(True)

    def prim(carry, _):
        in_tree, edges, step = carry
        m = jnp.where(in_tree[:, :, None] & ~in_tree[:, None, :], w, INF)
        flat = m.reshape(q, t * t)
        best = jnp.argmin(flat, axis=1)
        a, b = best // t, best % t
        ok = jnp.take_along_axis(flat, best[:, None], 1)[:, 0] < INF
        in_tree = in_tree.at[jnp.arange(q), jnp.where(ok, b, 0)].max(ok)
        edges = edges.at[:, step, 0].set(jnp.where(ok, a, -1))
        edges = edges.at[:, step, 1].set(jnp.where(ok, b, -1))
        return (in_tree, edges, step + 1), None

    edges0 = jnp.full((q, max(t - 1, 1), 2), -1, jnp.int32)
    (in_tree, mst, _), _ = jax.lax.scan(
        prim, (in_tree0, edges0, 0), None, length=max(t - 1, 0)
    )

    # ---- mark tree nodes: terminals + bridge endpoints + backtraces --------
    marked = seeds_to_mask(seeds, n)

    def descend(marked, start, start_ok):
        """Walk from `start` toward its terminal by strict dist descent."""

        def body(carry, _):
            cur, ok, mk = carry
            mk = mk.at[jnp.arange(q), jnp.where(ok, cur, 0)].max(ok)
            dcur = jnp.take_along_axis(dist, cur[:, None], 1)[:, 0]
            nb = nbr[cur]  # (Q, K)
            nbm = nbr_mask[cur]
            dn = jnp.take_along_axis(dp, nb, 1)  # (Q, K)
            want = nbm & (dn == (dcur - 1)[:, None])
            pick = jnp.argmax(want, axis=1)
            nxt = jnp.take_along_axis(nb, pick[:, None], 1)[:, 0]
            ok = ok & jnp.any(want, axis=1) & (dcur > 0)
            cur = jnp.where(ok, nxt, cur)
            return (cur, ok, mk), None

        (_, _, marked), _ = jax.lax.scan(
            body, (start, start_ok, marked), None, length=max_hops + 1
        )
        return marked

    n_mst = mst.shape[1]
    for e in range(n_mst):  # T is small (≤16); unrolled loop over MST edges
        a, b = mst[:, e, 0], mst[:, e, 1]
        ok = a >= 0
        be = best_eid[jnp.arange(q), jnp.maximum(a, 0), jnp.maximum(b, 0)]
        ok = ok & (be < n * k)
        be = jnp.where(ok, be, 0)
        u, slot = be // k, be % k
        v = nbr[u, slot]
        marked = descend(marked, u, ok)
        marked = descend(marked, jnp.minimum(v, n - 1), ok & (v < n))

    d = jnp.minimum(dist, max_hops + 1)
    key = d * jnp.int32(n) + jnp.arange(n, dtype=jnp.int32)[None, :]
    nodes, mask, _ = _select_by_key(key, marked, max_nodes, n)
    dsel = jnp.where(mask, jnp.take_along_axis(d, jnp.minimum(nodes, n - 1), 1), INF)
    return Subgraph(nodes=nodes, mask=mask, dist=dsel, num_nodes=n)


@functools.partial(jax.jit, static_argnames=("n_iter", "max_nodes", "max_hops"))
def ppr_subgraph(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, S)
    *,
    alpha: float = 0.85,
    n_iter: int = 10,
    max_nodes: int = 64,
    max_hops: int = None,  # accepted for strategy-API parity; PPR's reach is
    # governed by (alpha, n_iter), not a hop radius
) -> Subgraph:
    """Personalized-PageRank retrieval (paper's PPR baseline, batched).

    Fixed-iteration power method in pull form over the ELL adjacency:
      p <- (1-a)·s + a · sum_k p[nbr[v,k]] / deg[nbr[v,k]]
    Nodes ranked by PPR mass; `dist` carries the score rank (0 = seed-like).
    """
    n, k = nbr.shape
    q = seeds.shape[0]
    sm = seeds_to_mask(seeds, n)
    s = sm.astype(jnp.float32)
    s = s / jnp.maximum(s.sum(axis=1, keepdims=True), 1.0)
    deg = jnp.maximum(nbr_mask.sum(axis=1).astype(jnp.float32), 1.0)  # (N,)

    def step(p, _):
        contrib = p / deg[None, :]  # (Q, N) mass each node pushes per edge
        cp = jnp.concatenate([contrib, jnp.zeros((q, 1))], axis=1)
        gathered = cp[:, nbr]  # (Q, N, K)
        pulled = jnp.sum(jnp.where(nbr_mask[None], gathered, 0.0), axis=-1)
        return (1 - alpha) * s + alpha * pulled, None

    p, _ = jax.lax.scan(step, s, None, length=n_iter)
    keep = (p > 0) | sm
    # rank by score descending; quantize score into an integer key
    order = jnp.argsort(-p, axis=1)
    rank = jnp.zeros_like(order).at[
        jnp.arange(q)[:, None], order
    ].set(jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (q, n)))
    nodes, mask, _ = _select_by_key(rank, keep, max_nodes, n)
    rsel = jnp.where(mask, jnp.take_along_axis(rank, jnp.minimum(nodes, n - 1), 1), INF)
    return Subgraph(nodes=nodes, mask=mask, dist=rsel, num_nodes=n)


STRATEGIES = {
    "bfs": bfs_subgraph,
    "dense": dense_subgraph,
    "steiner": steiner_subgraph,
    "ppr": ppr_subgraph,
}


def retrieve_subgraph(
    g: ELLGraph, seeds: jnp.ndarray, strategy: str = "bfs", **kw
) -> Subgraph:
    """Strategy dispatch over an :class:`ELLGraph` (public entry point)."""
    fn = STRATEGIES[strategy]
    return fn(g.nbr, g.nbr_mask, jnp.asarray(seeds, jnp.int32), **kw)


@functools.partial(jax.jit, static_argnames=())
def induced_adjacency(nbr: jnp.ndarray, nbr_mask: jnp.ndarray, sub: Subgraph):
    """Relabel the parent adjacency onto subgraph positions.

    Returns (sub_nbr (Q, M, K) positions into sub.nodes with sentinel M,
    sub_mask (Q, M, K)) — ready for downstream GNN encoding of the retrieved
    context, batched over queries.
    """
    q, m = sub.nodes.shape
    n, k = nbr.shape
    lut = jnp.full((q, n + 1), m, jnp.int32)
    safe = jnp.where(sub.mask, sub.nodes, n)
    lut = lut.at[jnp.arange(q)[:, None], safe].min(
        jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None], (q, m))
    )
    lut = lut.at[:, n].set(m)
    gn = nbr[jnp.minimum(safe, n - 1)]  # (Q, M, K) original neighbor ids
    gm = nbr_mask[jnp.minimum(safe, n - 1)] & sub.mask[:, :, None]
    pos = jnp.take_along_axis(lut, gn.reshape(q, -1), 1).reshape(q, m, k)
    ok = gm & (pos < m)
    return jnp.where(ok, pos, m).astype(jnp.int32), ok
