"""Stage 3 of the RGL pipeline: batched graph retrieval (paper §2.1.3).

TPU-native re-expression of RGL's C++ retrieval engine.  All three paper
strategies — RGL-BFS, RGL-Dense, RGL-Steiner — are implemented as
*fixed-shape frontier algebra* over the ELL adjacency:

* BFS          — pull-based frontier expansion: one (Q, N, K) gather per hop.
* Steiner      — Mehlhorn/KMB 2-approximation: one multi-source
                 label-propagating BFS builds Voronoi cells, bridge edges give
                 terminal-pair distances, a fixed-iteration Prim MST picks the
                 tree topology, and distance-descent backtracing marks path
                 nodes.  Unweighted graphs (all paper datasets) ⇒ BFS ≡ Dijkstra.
* Dense        — greedy peeling: the k-hop candidate ball is refined by
                 iterated internal-degree ranking (densest-subgraph heuristic).

Every strategy exists in two backends sharing one output contract:

* **dense**   — per-hop work is O(N): full-graph gathers, full-graph ranking.
                Exact, simple, and fine while N is small.
* **compact** — per-hop work is O(C): seeds are expanded into a fixed-capacity
                sorted *workset* of C candidate ids (:mod:`repro.core.workset`,
                backed by the ``kernels.frontier_expand`` mark kernel), and the
                strategy runs over the workset-local induced adjacency.  When
                no query overflows the capacity, the output — nodes, mask,
                dist, including tie order — is bitwise identical to the dense
                backend; overflow is reported per query so callers can fall
                back (``mode="auto"`` does so automatically).

Everything is batched over queries (the paper's core speedup mechanism:
amortize per-query overhead) and jit-compiled; graphs must be symmetric
(generators symmetrize; pull-BFS reads in-neighbors).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workset import Workset, build_workset, localize, workset_adjacency
from repro.graph.ell import ELLGraph
from repro.kernels.bfs_frontier import ops as bfs_frontier_ops

INF = jnp.int32(0x3FFFFFF)

# graphs at least this large route to the compact backend under mode="auto";
# set from the measured dense/compact crossover (BENCH_retrieval_scaling.json:
# compact loses below ~100k nodes on CPU, wins 3-15x above 200k)
AUTO_COMPACT_MIN_NODES = 100_000


@dataclasses.dataclass
class Subgraph:
    """Padded per-query subgraph: ``nodes`` ordered by retrieval priority.

    ``overflow`` is only populated by the compact backend: True for queries
    whose candidate ball exceeded the workset capacity (output truncated
    deterministically, no longer dense-parity).  ``None`` means the dense
    backend ran (never truncates).
    """

    nodes: jnp.ndarray  # (Q, M) int32, sentinel = num_nodes where ~mask
    mask: jnp.ndarray  # (Q, M) bool
    dist: jnp.ndarray  # (Q, M) int32 hop distance of each picked node
    num_nodes: int  # N of the parent graph
    overflow: Optional[jnp.ndarray] = None  # (Q,) bool, compact backend only


jax.tree_util.register_dataclass(
    Subgraph,
    data_fields=["nodes", "mask", "dist", "overflow"],
    meta_fields=["num_nodes"],
)


def seeds_to_mask(seeds: jnp.ndarray, n: int) -> jnp.ndarray:
    """(Q, S) seed indices (pad with -1 or >=n) -> (Q, N) bool mask."""
    q, s = seeds.shape
    valid = (seeds >= 0) & (seeds < n)
    safe = jnp.where(valid, seeds, 0)
    base = jnp.zeros((q, n), bool)
    return base.at[jnp.arange(q)[:, None], safe].max(valid)


@functools.partial(jax.jit, static_argnames=("max_hops",))
def bfs_distances(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds_mask: jnp.ndarray,
    max_hops: int,
) -> jnp.ndarray:
    """Batched BFS hop distances.  (Q, N) int32; INF where unreached."""
    dist0 = jnp.where(seeds_mask, 0, INF)

    def hop(carry, h):
        dist, frontier = carry
        # one pull hop through the kernels.bfs_frontier op: Pallas-tiled on
        # TPU, the pure-jnp gather elsewhere (size-gated inside the op)
        reach = bfs_frontier_ops.frontier_hop(frontier, nbr, nbr_mask)
        new = reach & (dist == INF)
        dist = jnp.where(new, h + 1, dist)
        return (dist, new), None

    (dist, _), _ = jax.lax.scan(
        hop, (dist0, seeds_mask), jnp.arange(max_hops, dtype=jnp.int32)
    )
    return dist


@functools.partial(jax.jit, static_argnames=("max_hops",))
def voronoi_bfs(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, T) terminal node ids (may contain -1 padding)
    max_hops: int,
):
    """Multi-source BFS with source labels.

    Returns (dist (Q,N) int32, label (Q,N) int32 in [0,T) or T for none).
    Ties: lowest terminal slot wins (deterministic).
    """
    q, t = seeds.shape
    n = nbr.shape[0]
    valid = (seeds >= 0) & (seeds < n)
    safe = jnp.where(valid, seeds, 0)
    label0 = jnp.full((q, n), t, jnp.int32)
    # lower slot wins ties at init: scatter in reverse slot order via min
    slot = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (q, t))
    slot = jnp.where(valid, slot, t)
    label0 = label0.at[jnp.arange(q)[:, None], safe].min(slot)
    dist0 = jnp.where(label0 < t, 0, INF)

    def hop(carry, h):
        dist, label, frontier = carry
        qn = frontier.shape[0]
        fp = jnp.concatenate([frontier, jnp.zeros((qn, 1), bool)], 1)
        lp = jnp.concatenate([label, jnp.full((qn, 1), t, jnp.int32)], 1)
        g_f = fp[:, nbr]  # (Q, N, K) neighbor-in-frontier
        g_l = lp[:, nbr]  # (Q, N, K) neighbor labels
        active = g_f & nbr_mask[None]
        cand = jnp.where(active, g_l, t)
        best = jnp.min(cand, axis=-1)  # (Q, N) best label among frontier nbrs
        reach = jnp.any(active, axis=-1)
        new = reach & (dist == INF)
        dist = jnp.where(new, h + 1, dist)
        label = jnp.where(new, best, label)
        return (dist, label, new), None

    (dist, label, _), _ = jax.lax.scan(
        hop, (dist0, label0, dist0 == 0), jnp.arange(max_hops, dtype=jnp.int32)
    )
    return dist, label


def _select_by_key(key: jnp.ndarray, keep: jnp.ndarray, m: int, n: int):
    """Pick m nodes with the smallest ``key`` among ``keep``; pad w/ sentinel n.

    Returns (nodes (Q,m) int32, mask (Q,m) bool, order-aligned gather of key).
    """
    big = jnp.int32(0x7FFFFFF0)
    k = jnp.where(keep, key, big)
    neg = -(k.astype(jnp.int32))
    topv, topi = jax.lax.top_k(neg, m)  # largest of -key == smallest key
    mask = topv > -big
    nodes = jnp.where(mask, topi, n).astype(jnp.int32)
    return nodes, mask, jnp.where(mask, -topv, INF)


def _select_ws(key: jnp.ndarray, keep: jnp.ndarray, ws: Workset, m: int):
    """Workset-local ``_select_by_key``: same keys, positions mapped back to
    global ids.  Keys embed the global node id, so with identical (key, keep)
    sets the selection — values, order, padding — matches the dense path.

    Returns (nodes (Q,m) int32 global, mask (Q,m) bool, topi (Q,m) positions).
    """
    n = ws.num_nodes
    big = jnp.int32(0x7FFFFFF0)
    k = jnp.where(keep & (ws.ids < n), key, big)
    topv, topi = jax.lax.top_k(-k, m)
    mask = topv > -big
    nodes = jnp.where(mask, jnp.take_along_axis(ws.ids, topi, 1), n)
    return nodes.astype(jnp.int32), mask, topi


def _gather_local(rowvals: jnp.ndarray, wnbr: jnp.ndarray, fill):
    """Gather per-slot values over the local adjacency with a slack column.

    rowvals (Q, C); wnbr (Q, C, K) positions with sentinel C; ``fill`` is the
    value served for sentinel slots.  Returns (Q, C, K).
    """
    q, c, k = wnbr.shape
    padded = jnp.concatenate(
        [rowvals, jnp.full((q, 1), fill, rowvals.dtype)], axis=1
    )
    return jnp.take_along_axis(padded, wnbr.reshape(q, c * k), 1).reshape(q, c, k)


# ---------------------------------------------------------------- BFS --------


@functools.partial(jax.jit, static_argnames=("max_hops", "max_nodes"))
def bfs_subgraph(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, S)
    *,
    max_hops: int = 3,
    max_nodes: int = 64,
) -> Subgraph:
    """RGL-BFS: closest-first ball around the retrieved seed nodes."""
    n = nbr.shape[0]
    sm = seeds_to_mask(seeds, n)
    dist = bfs_distances(nbr, nbr_mask, sm, max_hops)
    keep = dist < INF
    d = jnp.minimum(dist, max_hops + 1)
    key = d * jnp.int32(n) + jnp.arange(n, dtype=jnp.int32)[None, :]
    nodes, mask, _ = _select_by_key(key, keep, max_nodes, n)
    dsel = jnp.where(mask, jnp.take_along_axis(d, jnp.minimum(nodes, n - 1), 1), INF)
    return Subgraph(nodes=nodes, mask=mask, dist=dsel, num_nodes=n)


@functools.partial(
    jax.jit, static_argnames=("max_hops", "max_nodes", "workset_cap")
)
def bfs_subgraph_compact(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, S)
    *,
    max_hops: int = 3,
    max_nodes: int = 64,
    workset_cap: int = 2048,
) -> Subgraph:
    """RGL-BFS over the workset: O(C) per hop instead of O(N)."""
    n = nbr.shape[0]
    ws = build_workset(
        nbr, nbr_mask, seeds, max_hops=max_hops, cap=workset_cap
    )
    key = ws.dist * jnp.int32(n) + jnp.where(ws.ids < n, ws.ids, 0)
    nodes, mask, topi = _select_ws(key, ws.ids < n, ws, max_nodes)
    dsel = jnp.where(mask, jnp.take_along_axis(ws.dist, topi, 1), INF)
    return Subgraph(
        nodes=nodes, mask=mask, dist=dsel, num_nodes=n, overflow=ws.overflow
    )


# ---------------------------------------------------------------- Dense ------


@functools.partial(jax.jit, static_argnames=("max_hops", "max_nodes", "n_rounds"))
def dense_subgraph(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    max_hops: int = 2,
    max_nodes: int = 64,
    n_rounds: int = 3,
) -> Subgraph:
    """RGL-Dense: greedy internal-degree peeling of the k-hop candidate ball."""
    n, k = nbr.shape
    q = seeds.shape[0]
    sm = seeds_to_mask(seeds, n)
    dist = bfs_distances(nbr, nbr_mask, sm, max_hops)
    cand = dist < INF  # (Q, N) candidate ball

    def indeg(c):
        cp = jnp.concatenate([c, jnp.zeros((q, 1), bool)], 1)
        g = cp[:, nbr] & nbr_mask[None]  # (Q, N, K)
        return jnp.sum(g, axis=-1).astype(jnp.int32) * c

    def round_(c, _):
        deg = indeg(c)
        # threshold = max_nodes-th largest degree among candidates
        kth = jax.lax.top_k(jnp.where(c, deg, -1), min(max_nodes, n))[0][:, -1]
        keep = c & (deg >= kth[:, None])
        keep = keep | sm  # never peel seeds
        return keep, None

    cand, _ = jax.lax.scan(round_, cand, None, length=n_rounds)
    deg = indeg(cand)
    # final pick: highest internal degree first, then closer, then lower id;
    # seeds get the minimal key band (always < n) so they are never evicted
    d = jnp.minimum(dist, max_hops + 1)
    key = (jnp.int32(k + 1) - deg) * jnp.int32((max_hops + 2) * n) + d * jnp.int32(n) \
        + jnp.arange(n, dtype=jnp.int32)[None, :]
    key = jnp.where(sm, jnp.arange(n, dtype=jnp.int32)[None, :], key)
    nodes, mask, _ = _select_by_key(key, cand, max_nodes, n)
    dsel = jnp.where(mask, jnp.take_along_axis(d, jnp.minimum(nodes, n - 1), 1), INF)
    return Subgraph(nodes=nodes, mask=mask, dist=dsel, num_nodes=n)


@functools.partial(
    jax.jit,
    static_argnames=("max_hops", "max_nodes", "n_rounds", "workset_cap"),
)
def dense_subgraph_compact(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    max_hops: int = 2,
    max_nodes: int = 64,
    n_rounds: int = 3,
    workset_cap: int = 2048,
) -> Subgraph:
    """RGL-Dense over the workset: peeling scores C nodes per round, not N."""
    n, k = nbr.shape
    ws = build_workset(
        nbr, nbr_mask, seeds, max_hops=max_hops, cap=workset_cap
    )
    wnbr, wmask = workset_adjacency(nbr, nbr_mask, ws.ids)
    valid = ws.ids < n
    sm = valid & (ws.dist == 0)  # seed slots: the distinct valid seeds
    cand0 = valid  # every workset entry is inside the max_hops ball

    def indeg(c):
        g = _gather_local(c, wnbr, False) & wmask
        return jnp.sum(g, axis=-1).astype(jnp.int32) * c

    def round_(c, _):
        deg = indeg(c)
        kth = jax.lax.top_k(
            jnp.where(c, deg, -1), min(max_nodes, workset_cap)
        )[0][:, -1]
        keep = c & (deg >= kth[:, None])
        keep = keep | sm
        return keep, None

    cand, _ = jax.lax.scan(round_, cand0, None, length=n_rounds)
    deg = indeg(cand)
    d = jnp.minimum(ws.dist, max_hops + 1)
    gid = jnp.where(valid, ws.ids, 0)
    key = (jnp.int32(k + 1) - deg) * jnp.int32((max_hops + 2) * n) \
        + d * jnp.int32(n) + gid
    key = jnp.where(sm, gid, key)
    nodes, mask, topi = _select_ws(key, cand, ws, max_nodes)
    dsel = jnp.where(mask, jnp.take_along_axis(d, topi, 1), INF)
    return Subgraph(
        nodes=nodes, mask=mask, dist=dsel, num_nodes=n, overflow=ws.overflow
    )


# ---------------------------------------------------------------- Steiner ----


def _seg_min(vals, segs, t):
    return jax.vmap(
        lambda v, s: jax.ops.segment_min(v, s, num_segments=t * t)
    )(vals, segs)


def _terminal_metric(d_src, d_dst, l_src, l_dst, e_mask, eid, t, eid_sentinel):
    """Terminal-pair shortest-path metric from bridge edges.

    All inputs are flattened edge tables (Q, E) — the dense path passes the
    full N*K edge set, the compact path the C*K workset edge set; ``eid``
    carries *global* edge ids in both, so the per-pair argmin tie-break is
    backend independent.  Returns (w (Q,T,T) symmetric pair lengths with INF
    diagonal, best_eid (Q,T,T) global edge id realizing each pair).
    """
    q = d_src.shape[0]
    e_ok = (
        e_mask
        & (l_src < t) & (l_dst < t) & (l_src != l_dst)
        & (d_src < INF) & (d_dst < INF)
    )
    plen = jnp.where(e_ok, d_src + 1 + d_dst, INF)  # (Q, E)
    pair = jnp.where(e_ok, l_src * t + l_dst, 0)  # (Q, E) in [0, T*T)
    w = _seg_min(plen, pair, t)  # (Q, T*T) pairwise path lengths
    # best bridge edge per pair: two-pass argmin (value then edge id)
    at_min = e_ok & (plen == jnp.take_along_axis(w, pair, axis=1))
    best_eid = _seg_min(
        jnp.where(at_min, eid, jnp.int32(eid_sentinel)), pair, t
    )
    w = w.reshape(q, t, t)
    w = jnp.minimum(w, jnp.swapaxes(w, 1, 2))  # symmetrize
    w = jnp.where(jnp.eye(t, dtype=bool)[None], INF, w)
    best_eid = jnp.minimum(
        best_eid.reshape(q, t, t), jnp.swapaxes(best_eid.reshape(q, t, t), 1, 2)
    )
    return w, best_eid


def _prim_mst(w, t):
    """Fixed-iteration Prim MST over the (Q, T, T) terminal metric."""
    q = w.shape[0]
    in_tree0 = jnp.zeros((q, t), bool).at[:, 0].set(True)

    def prim(carry, _):
        in_tree, edges, step = carry
        m = jnp.where(in_tree[:, :, None] & ~in_tree[:, None, :], w, INF)
        flat = m.reshape(q, t * t)
        best = jnp.argmin(flat, axis=1)
        a, b = best // t, best % t
        ok = jnp.take_along_axis(flat, best[:, None], 1)[:, 0] < INF
        in_tree = in_tree.at[jnp.arange(q), jnp.where(ok, b, 0)].max(ok)
        edges = edges.at[:, step, 0].set(jnp.where(ok, a, -1))
        edges = edges.at[:, step, 1].set(jnp.where(ok, b, -1))
        return (in_tree, edges, step + 1), None

    edges0 = jnp.full((q, max(t - 1, 1), 2), -1, jnp.int32)
    (_, mst, _), _ = jax.lax.scan(
        prim, (in_tree0, edges0, 0), None, length=max(t - 1, 0)
    )
    return mst


def _descend_paths(marked, start, start_ok, dist, dp, row_fn, length):
    """Walk from ``start`` toward its terminal by strict dist descent,
    marking every visited position.  ``row_fn(cur)`` returns the (Q, K)
    neighbor positions + mask of each query's current node — global
    adjacency for the dense path, workset-local for the compact path."""
    q = start.shape[0]

    def body(carry, _):
        cur, ok, mk = carry
        mk = mk.at[jnp.arange(q), jnp.where(ok, cur, 0)].max(ok)
        dcur = jnp.take_along_axis(dist, cur[:, None], 1)[:, 0]
        nb, nbm = row_fn(cur)  # (Q, K) each
        dn = jnp.take_along_axis(dp, nb, 1)  # (Q, K)
        want = nbm & (dn == (dcur - 1)[:, None])
        pick = jnp.argmax(want, axis=1)
        nxt = jnp.take_along_axis(nb, pick[:, None], 1)[:, 0]
        ok = ok & jnp.any(want, axis=1) & (dcur > 0)
        cur = jnp.where(ok, nxt, cur)
        return (cur, ok, mk), None

    (_, _, marked), _ = jax.lax.scan(
        body, (start, start_ok, marked), None, length=length
    )
    return marked


@functools.partial(jax.jit, static_argnames=("max_hops", "max_nodes"))
def steiner_subgraph(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, T) terminals
    *,
    max_hops: int = 4,
    max_nodes: int = 64,
) -> Subgraph:
    """RGL-Steiner: KMB/Mehlhorn 2-approx Steiner tree over the terminals.

    1. Voronoi BFS: dist-to-nearest-terminal + owning terminal per node.
    2. Bridge edges (u,v), label(u) != label(v) give candidate terminal-pair
       path lengths dist(u)+1+dist(v); segment-min over label pairs.
    3. Prim MST over the (T, T) terminal metric (fixed T-1 iterations).
    4. Mark MST-edge bridge endpoints; distance-descent backtrace marks the
       connecting shortest paths.  Tree nodes ranked closest-first.
    """
    n, k = nbr.shape
    q, t = seeds.shape
    dist, label = voronoi_bfs(nbr, nbr_mask, seeds, max_hops)

    # ---- bridge edges between Voronoi cells -------------------------------
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
    dst = nbr  # (N, K)
    dp = jnp.concatenate([dist, jnp.full((q, 1), INF, jnp.int32)], 1)
    lp = jnp.concatenate([label, jnp.full((q, 1), t, jnp.int32)], 1)
    d_src = dist[:, src.reshape(-1)].reshape(q, n * k)
    d_dst = dp[:, dst.reshape(-1)].reshape(q, n * k)
    l_src = label[:, src.reshape(-1)].reshape(q, n * k)
    l_dst = lp[:, dst.reshape(-1)].reshape(q, n * k)
    eid = jnp.broadcast_to(jnp.arange(n * k, dtype=jnp.int32)[None], (q, n * k))
    w, best_eid = _terminal_metric(
        d_src, d_dst, l_src, l_dst, nbr_mask.reshape(-1)[None, :],
        eid, t, n * k,
    )

    mst = _prim_mst(w, t)

    # ---- mark tree nodes: terminals + bridge endpoints + backtraces --------
    marked = seeds_to_mask(seeds, n)
    row_fn = lambda cur: (nbr[cur], nbr_mask[cur])  # noqa: E731

    n_mst = mst.shape[1]
    for e in range(n_mst):  # T is small (≤16); unrolled loop over MST edges
        a, b = mst[:, e, 0], mst[:, e, 1]
        ok = a >= 0
        be = best_eid[jnp.arange(q), jnp.maximum(a, 0), jnp.maximum(b, 0)]
        ok = ok & (be < n * k)
        be = jnp.where(ok, be, 0)
        u, slot = be // k, be % k
        v = nbr[u, slot]
        marked = _descend_paths(marked, u, ok, dist, dp, row_fn, max_hops + 1)
        marked = _descend_paths(
            marked, jnp.minimum(v, n - 1), ok & (v < n), dist, dp, row_fn,
            max_hops + 1,
        )

    d = jnp.minimum(dist, max_hops + 1)
    key = d * jnp.int32(n) + jnp.arange(n, dtype=jnp.int32)[None, :]
    nodes, mask, _ = _select_by_key(key, marked, max_nodes, n)
    dsel = jnp.where(mask, jnp.take_along_axis(d, jnp.minimum(nodes, n - 1), 1), INF)
    return Subgraph(nodes=nodes, mask=mask, dist=dsel, num_nodes=n)


def _workset_voronoi_labels(ws: Workset, wnbr, wmask, seeds, max_hops: int):
    """Voronoi owner labels over the workset.  ``ws.dist`` *is* the
    multi-source BFS distance from the terminal set, so only the label
    propagation re-runs: nodes at distance h inherit the minimum label among
    neighbors at distance h-1 — the dense path's tie-break exactly."""
    q, t = seeds.shape
    n = ws.num_nodes
    c = ws.ids.shape[1]
    valid_s = (seeds >= 0) & (seeds < n)
    pos, found = localize(ws.ids, jnp.where(valid_s, seeds, n))
    ok = valid_s & found
    slot = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (q, t))
    qi = jnp.arange(q)[:, None]
    tgt = jnp.where(ok, pos, c)  # slack column
    label0 = jnp.full((q, c + 1), t, jnp.int32).at[qi, tgt].min(
        jnp.where(ok, slot, t)
    )[:, :c]

    def prop(label, h):
        g_l = _gather_local(label, wnbr, t)
        g_d = _gather_local(ws.dist, wnbr, INF)
        active = wmask & (g_d == h - 1)
        best = jnp.min(jnp.where(active, g_l, t), axis=-1)
        label = jnp.where(ws.dist == h, best, label)
        return label, None

    label, _ = jax.lax.scan(
        prop, label0, jnp.arange(1, max_hops + 1, dtype=jnp.int32)
    )
    return label


@functools.partial(
    jax.jit, static_argnames=("max_hops", "max_nodes", "workset_cap")
)
def steiner_subgraph_compact(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, T) terminals
    *,
    max_hops: int = 4,
    max_nodes: int = 64,
    workset_cap: int = 2048,
) -> Subgraph:
    """RGL-Steiner over the workset: the bridge scan walks C*K workset edges
    instead of N*K, Voronoi labels propagate over the local adjacency, and
    backtracing descends in workset coordinates."""
    n, k = nbr.shape
    q, t = seeds.shape
    ws = build_workset(
        nbr, nbr_mask, seeds, max_hops=max_hops, cap=workset_cap
    )
    c = ws.ids.shape[1]
    wnbr, wmask = workset_adjacency(nbr, nbr_mask, ws.ids)
    label = _workset_voronoi_labels(ws, wnbr, wmask, seeds, max_hops)

    # ---- bridge edges over the C*K workset edge table ---------------------
    dp = jnp.concatenate([ws.dist, jnp.full((q, 1), INF, jnp.int32)], 1)
    lp = jnp.concatenate([label, jnp.full((q, 1), t, jnp.int32)], 1)
    d_src = jnp.broadcast_to(ws.dist[:, :, None], (q, c, k)).reshape(q, c * k)
    l_src = jnp.broadcast_to(label[:, :, None], (q, c, k)).reshape(q, c * k)
    flat_nbr = wnbr.reshape(q, c * k)
    d_dst = jnp.take_along_axis(dp, flat_nbr, 1)
    l_dst = jnp.take_along_axis(lp, flat_nbr, 1)
    gid = jnp.where(ws.ids < n, ws.ids, 0)
    eid = (
        gid[:, :, None] * jnp.int32(k)
        + jnp.arange(k, dtype=jnp.int32)[None, None, :]
    ).reshape(q, c * k)  # *global* edge ids: tie-break parity with dense
    w, best_eid = _terminal_metric(
        d_src, d_dst, l_src, l_dst, wmask.reshape(q, c * k), eid, t, n * k
    )

    mst = _prim_mst(w, t)

    marked = (ws.ids < n) & (ws.dist == 0)  # terminals

    def row_fn(cur):
        nb = jnp.take_along_axis(wnbr, cur[:, None, None], 1)[:, 0]  # (Q, K)
        nbm = jnp.take_along_axis(wmask, cur[:, None, None], 1)[:, 0]
        return nb, nbm

    n_mst = mst.shape[1]
    for e in range(n_mst):
        a, b = mst[:, e, 0], mst[:, e, 1]
        ok = a >= 0
        be = best_eid[jnp.arange(q), jnp.maximum(a, 0), jnp.maximum(b, 0)]
        ok = ok & (be < n * k)
        be = jnp.where(ok, be, 0)
        u_g, slot = be // k, be % k
        u_l, found_u = localize(ws.ids, u_g[:, None])
        u_l, found_u = u_l[:, 0], found_u[:, 0]
        ok = ok & found_u
        u_l = jnp.minimum(u_l, c - 1)
        # v is u's slot-th neighbor, already in workset coordinates
        v_l = wnbr[jnp.arange(q), u_l, slot]
        marked = _descend_paths(
            marked, u_l, ok, ws.dist, dp, row_fn, max_hops + 1
        )
        marked = _descend_paths(
            marked, jnp.minimum(v_l, c - 1), ok & (v_l < c), ws.dist, dp,
            row_fn, max_hops + 1,
        )

    d = jnp.minimum(ws.dist, max_hops + 1)
    key = d * jnp.int32(n) + gid
    nodes, mask, topi = _select_ws(key, marked, ws, max_nodes)
    dsel = jnp.where(mask, jnp.take_along_axis(d, topi, 1), INF)
    return Subgraph(
        nodes=nodes, mask=mask, dist=dsel, num_nodes=n, overflow=ws.overflow
    )


# ---------------------------------------------------------------- PPR --------


@functools.partial(jax.jit, static_argnames=("n_iter", "max_nodes", "max_hops"))
def ppr_subgraph(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, S)
    *,
    alpha: float = 0.85,
    n_iter: int = 10,
    max_nodes: int = 64,
    max_hops: int = None,  # accepted for strategy-API parity; PPR's reach is
    # governed by (alpha, n_iter), not a hop radius
) -> Subgraph:
    """Personalized-PageRank retrieval (paper's PPR baseline, batched).

    Fixed-iteration power method in pull form over the ELL adjacency:
      p <- (1-a)·s + a · sum_k p[nbr[v,k]] / deg[nbr[v,k]]
    Nodes ranked by PPR mass; `dist` carries the score rank (0 = seed-like).
    """
    n, k = nbr.shape
    q = seeds.shape[0]
    sm = seeds_to_mask(seeds, n)
    s = sm.astype(jnp.float32)
    s = s / jnp.maximum(s.sum(axis=1, keepdims=True), 1.0)
    deg = jnp.maximum(nbr_mask.sum(axis=1).astype(jnp.float32), 1.0)  # (N,)

    def step(p, _):
        contrib = p / deg[None, :]  # (Q, N) mass each node pushes per edge
        cp = jnp.concatenate([contrib, jnp.zeros((q, 1))], axis=1)
        gathered = cp[:, nbr]  # (Q, N, K)
        pulled = jnp.sum(jnp.where(nbr_mask[None], gathered, 0.0), axis=-1)
        return (1 - alpha) * s + alpha * pulled, None

    p, _ = jax.lax.scan(step, s, None, length=n_iter)
    keep = (p > 0) | sm
    # rank by score descending; quantize score into an integer key
    order = jnp.argsort(-p, axis=1)
    rank = jnp.zeros_like(order).at[
        jnp.arange(q)[:, None], order
    ].set(jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (q, n)))
    nodes, mask, _ = _select_by_key(rank, keep, max_nodes, n)
    rsel = jnp.where(mask, jnp.take_along_axis(rank, jnp.minimum(nodes, n - 1), 1), INF)
    return Subgraph(nodes=nodes, mask=mask, dist=rsel, num_nodes=n)


@functools.partial(
    jax.jit, static_argnames=("n_iter", "max_nodes", "max_hops", "workset_cap")
)
def ppr_subgraph_compact(
    nbr: jnp.ndarray,
    nbr_mask: jnp.ndarray,
    seeds: jnp.ndarray,  # (Q, S)
    *,
    alpha: float = 0.85,
    n_iter: int = 10,
    max_nodes: int = 64,
    max_hops: int = None,  # API parity; expansion radius is n_iter
    workset_cap: int = 2048,
) -> Subgraph:
    """PPR over the workset.  After ``n_iter`` pull iterations mass reaches at
    most ``n_iter`` hops from the seeds, so the n_iter-hop workset carries the
    full support of p: the power method over the local adjacency is bitwise
    the dense computation (identical per-slot summation order), and ranks of
    all positive-mass nodes coincide."""
    n, k = nbr.shape
    q = seeds.shape[0]
    ws = build_workset(nbr, nbr_mask, seeds, max_hops=n_iter, cap=workset_cap)
    c = ws.ids.shape[1]
    wnbr, wmask = workset_adjacency(nbr, nbr_mask, ws.ids)
    valid = ws.ids < n
    sm = valid & (ws.dist == 0)
    s = sm.astype(jnp.float32)
    s = s / jnp.maximum(s.sum(axis=1, keepdims=True), 1.0)
    safe = jnp.minimum(ws.ids, n - 1)
    deg = jnp.maximum(nbr_mask[safe].sum(axis=-1).astype(jnp.float32), 1.0)

    def step(p, _):
        contrib = p / deg
        g = _gather_local(contrib, wnbr, jnp.float32(0.0))
        pulled = jnp.sum(jnp.where(wmask, g, 0.0), axis=-1)
        return (1 - alpha) * s + alpha * pulled, None

    p, _ = jax.lax.scan(step, s, None, length=n_iter)
    keep = ((p > 0) | sm) & valid
    order = jnp.argsort(-p, axis=1)  # stable: ties by position = global id
    rank = jnp.zeros_like(order).at[
        jnp.arange(q)[:, None], order
    ].set(jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[None], (q, c)))
    nodes, mask, topi = _select_ws(rank, keep, ws, max_nodes)
    rsel = jnp.where(mask, jnp.take_along_axis(rank, topi, 1), INF)
    return Subgraph(
        nodes=nodes, mask=mask, dist=rsel, num_nodes=n, overflow=ws.overflow
    )


# ---------------------------------------------------------------- dispatch ---

STRATEGIES = {
    "bfs": bfs_subgraph,
    "dense": dense_subgraph,
    "steiner": steiner_subgraph,
    "ppr": ppr_subgraph,
}

COMPACT_STRATEGIES = {
    "bfs": bfs_subgraph_compact,
    "dense": dense_subgraph_compact,
    "steiner": steiner_subgraph_compact,
    "ppr": ppr_subgraph_compact,
}


def retrieve_subgraph(
    g: ELLGraph,
    seeds: jnp.ndarray,
    strategy: str = "bfs",
    *,
    mode: str = "auto",
    workset_cap: int = 2048,
    **kw,
) -> Subgraph:
    """Strategy dispatch over an :class:`ELLGraph` (public entry point).

    ``mode`` selects the backend: ``"dense"`` (O(N) per hop, never
    truncates), ``"compact"`` (O(workset_cap) per hop, per-query
    ``overflow`` flags), or ``"auto"`` — compact for graphs with at least
    ``AUTO_COMPACT_MIN_NODES`` nodes (except ``ppr``, whose ``n_iter``-hop
    expansion radius overflows any practical cap on large connected graphs
    — it stays dense under auto), with a transparent dense re-run when any
    query overflows.  The overflow check is host-side (one device sync);
    inside an outer ``jax.jit`` trace the flags are tracers, so the check
    is skipped and the compact result is returned flags-and-all.
    """
    if mode not in ("dense", "compact", "auto"):
        raise ValueError(f"unknown retrieval mode: {mode!r}")
    seeds = jnp.asarray(seeds, jnp.int32)
    use_compact = mode == "compact" or (
        mode == "auto"
        and strategy != "ppr"
        and g.num_nodes >= AUTO_COMPACT_MIN_NODES
        and workset_cap < g.num_nodes
    )
    if use_compact:
        cap = max(workset_cap, kw.get("max_nodes", 64), seeds.shape[1])
        sub = COMPACT_STRATEGIES[strategy](
            g.nbr, g.nbr_mask, seeds, workset_cap=cap, **kw
        )
        if (
            mode == "auto"
            and not isinstance(sub.overflow, jax.core.Tracer)
            and bool(np.asarray(sub.overflow).any())
        ):
            return STRATEGIES[strategy](g.nbr, g.nbr_mask, seeds, **kw)
        return sub
    return STRATEGIES[strategy](g.nbr, g.nbr_mask, seeds, **kw)


@functools.partial(jax.jit, static_argnames=())
def induced_adjacency(nbr: jnp.ndarray, nbr_mask: jnp.ndarray, sub: Subgraph):
    """Relabel the parent adjacency onto subgraph positions.

    Returns (sub_nbr (Q, M, K) positions into sub.nodes with sentinel M,
    sub_mask (Q, M, K)) — ready for downstream GNN encoding of the retrieved
    context, batched over queries.
    """
    q, m = sub.nodes.shape
    n, k = nbr.shape
    lut = jnp.full((q, n + 1), m, jnp.int32)
    safe = jnp.where(sub.mask, sub.nodes, n)
    lut = lut.at[jnp.arange(q)[:, None], safe].min(
        jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None], (q, m))
    )
    lut = lut.at[:, n].set(m)
    gn = nbr[jnp.minimum(safe, n - 1)]  # (Q, M, K) original neighbor ids
    gm = nbr_mask[jnp.minimum(safe, n - 1)] & sub.mask[:, :, None]
    pos = jnp.take_along_axis(lut, gn.reshape(q, -1), 1).reshape(q, m, k)
    ok = gm & (pos < m)
    return jnp.where(ok, pos, m).astype(jnp.int32), ok
