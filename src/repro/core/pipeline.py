"""The end-to-end RGL pipeline (paper Fig. 1): index -> node retrieval ->
graph retrieval -> dynamic filtering -> tokenization -> generation.

``RGLPipeline`` is the OOP API; every stage is also exposed as a composable
function (the paper's Functional API) in its own module, so applications can
re-wire stages (e.g. modality completion stops after ``retrieve``)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import filters, graph_retrieval, node_retrieval, tokenization
from repro.core.graph_retrieval import Subgraph
from repro.graph.ell import ELLGraph


@dataclasses.dataclass
class PipelineConfig:
    strategy: str = "bfs"  # bfs | dense | steiner | ppr
    k_seeds: int = 4
    max_hops: int = 3
    max_nodes: int = 64
    filter_budget: int = 32  # dynamic node filter budget (<= max_nodes)
    max_prompt_len: int = 512
    node_token_budget: int = 48
    # stage-1 vector index: brute | ivf | sharded | sharded_ivf
    index_kind: str = "brute"
    index_shards: Optional[int] = None  # sharded kinds; None = one per device
    # stage-3 subgraph construction backend: dense | compact | auto
    retrieval_mode: str = "auto"
    workset_cap: int = 2048  # compact backend candidate capacity per query


@dataclasses.dataclass(frozen=True)
class RetrievalResult:
    """Typed result of :meth:`RGLPipeline.retrieve` / ``retrieve_many``.

    Replaces the positional ``(sub, seeds, n_valid)`` tuples so the graph
    mutation ``epoch`` has a principled home: the serving cache compares an
    entry's retrieval epoch against the store's current epoch to decide
    whether a collected result may still be cached (see
    :meth:`repro.serving.cache.RetrievalCache.put`).

    ``sub`` keeps the same non-blocking contract as before: it may hold
    in-flight device arrays (or lazy simulation proxies); accessors here
    never force a host sync.
    """

    sub: object  # Subgraph (or a lazy duck-typed stand-in, see simulate.py)
    seeds: object  # (Q, k_seeds) node ids
    n_valid: int = 1  # leading rows of sub/seeds that are meaningful
    epoch: int = 0  # graph mutation epoch the retrieval ran against

    # passthrough views so callers don't reach two levels deep
    @property
    def nodes(self):
        return self.sub.nodes

    @property
    def mask(self):
        return self.sub.mask

    @property
    def dist(self):
        return self.sub.dist

    @property
    def overflow(self):
        return getattr(self.sub, "overflow", None)


def index_from_config(emb, config: PipelineConfig, **kw):
    """Build the stage-1 index named by ``config.index_kind``.

    Serving entry points (``repro.launch.serve``, benchmarks) route through
    this so the index backend and shard count are plain config, not code.
    """
    from repro.core.indexing import build_index

    if config.index_kind in ("sharded", "sharded_ivf"):
        kw.setdefault("n_shards", config.index_shards)
    return build_index(emb, kind=config.index_kind, **kw)


@dataclasses.dataclass
class RGLPipeline:
    graph: ELLGraph
    index: object  # BruteIndex | IVFIndex
    node_emb: jnp.ndarray  # (N, D) embeddings used for filtering scores
    tokenizer: Optional[tokenization.GraphTokenizer] = None
    generator: Optional[object] = None
    node_text: Optional[list] = None
    config: PipelineConfig = dataclasses.field(default_factory=PipelineConfig)
    # Attached by repro.core.mutation.MutableGraphStore.make_pipeline(); a
    # frozen-corpus pipeline leaves it None (epoch stays 0 forever).
    mutation_store: Optional[object] = None

    @property
    def epoch(self) -> int:
        """Monotonic graph mutation epoch this pipeline currently serves."""
        store = self.mutation_store
        return 0 if store is None else int(store.epoch)

    @property
    def n_valid_nodes(self) -> int:
        """Upper bound (exclusive) on node ids a retrieval may return.

        With a mutation store attached the arrays are capacity-padded, so
        the logical node count — not the array length — bounds valid ids.
        """
        store = self.mutation_store
        if store is not None:
            return int(store.n_nodes)
        return int(self.node_emb.shape[0])

    # ---- functional stages --------------------------------------------------
    def retrieve_seeds(self, query_emb, encoder=None):
        return node_retrieval.retrieve_nodes(
            self.index, query_emb, self.config.k_seeds, encoder=encoder
        )

    def retrieve_subgraph(self, seeds) -> Subgraph:
        return graph_retrieval.retrieve_subgraph(
            self.graph,
            seeds,
            self.config.strategy,
            mode=self.config.retrieval_mode,
            workset_cap=self.config.workset_cap,
            max_hops=self.config.max_hops,
            max_nodes=self.config.max_nodes,
        )

    def filter(self, sub: Subgraph, query_emb, seeds) -> Subgraph:
        scores = filters.similarity_scores(self.node_emb, jnp.asarray(query_emb))
        return filters.dynamic_filter(
            sub, scores, jnp.asarray(seeds), budget=self.config.filter_budget
        )

    def retrieve(self, query_emb, encoder=None) -> RetrievalResult:
        """Stages 2+3+filter — the sub-pipeline completion tasks use."""
        _, seeds = self.retrieve_seeds(query_emb, encoder=encoder)
        sub = self.retrieve_subgraph(seeds)
        sub = self.filter(sub, query_emb, seeds)
        q = jnp.asarray(query_emb)
        n_valid = 1 if q.ndim == 1 else int(q.shape[0])
        return RetrievalResult(sub=sub, seeds=seeds, n_valid=n_valid,
                               epoch=self.epoch)

    def retrieve_many(
        self, query_embs, *, batch_size: Optional[int] = None, encoder=None
    ) -> RetrievalResult:
        """Fixed-shape batched retrieval for serving admission.

        Pads the query batch up to ``batch_size`` rows (zeros) so every
        serving-step admission reuses one jitted retrieval trace regardless of
        how many requests arrived — the paper's amortization mechanism applied
        at serve time.  All retrieval stages are row-independent, so padding
        rows never perturb real results.

        Returns a :class:`RetrievalResult` whose ``sub``/``seeds`` have
        leading dim ``batch_size``; only the first ``n_valid`` rows are
        meaningful.  ``epoch`` records the graph mutation epoch the
        retrieval was dispatched against.

        **Non-blocking contract:** the returned arrays are device arrays whose
        computation may still be in flight (JAX async dispatch) — this method
        never forces a host sync itself.  Callers that need host data must
        ``np.asarray`` the results, which blocks until retrieval finishes; the
        serving prefetch path (:mod:`repro.serving.prefetch`) relies on this
        laziness to overlap wave *i+1*'s retrieval with wave *i*'s decode.
        One caveat: ``retrieval_mode="auto"``'s host-side overflow check in
        :func:`repro.core.graph_retrieval.retrieve_subgraph` forces an early
        sync on the compact backend — prefer ``dense`` or ``compact``
        explicitly when overlap matters.
        """
        q = np.asarray(query_embs, np.float32)
        if q.ndim == 1:
            q = q[None]
        n_valid = q.shape[0]
        bs = batch_size or n_valid
        if n_valid > bs:
            raise ValueError(f"{n_valid} queries > batch_size {bs}")
        if n_valid < bs:
            q = np.concatenate(
                [q, np.zeros((bs - n_valid, q.shape[1]), np.float32)], axis=0
            )
        res = self.retrieve(jnp.asarray(q), encoder=encoder)
        return dataclasses.replace(res, n_valid=n_valid)

    def tokenize(self, query_texts, sub: Subgraph):
        assert self.tokenizer is not None and self.node_text is not None
        texts = tokenization.subgraph_texts(sub, self.node_text)
        return self.tokenizer.batch_linearize(query_texts, texts)

    # ---- OOP API ------------------------------------------------------------
    def run(self, query_emb, query_texts, max_new_tokens: int = 0) -> dict:
        res = self.retrieve(query_emb)
        sub, seeds = res.sub, res.seeds
        ids, mask = self.tokenize(query_texts, sub)
        outputs = None
        if self.generator is not None:
            outputs = self.generator.generate(ids, mask, max_new_tokens)
        return {
            "seeds": np.asarray(seeds),
            "subgraph": sub,
            "prompt_ids": ids,
            "prompt_mask": mask,
            "outputs": outputs,
        }
