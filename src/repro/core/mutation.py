"""Online corpus mutation: one store owning graph + index + embeddings.

``MutableGraphStore`` is the write path of the serving stack.  It composes

* a :class:`~repro.graph.delta.DeltaGraph` (frozen base ELL + append
  slack + kill/tombstone bitmaps, folded into a merged device view),
* a mutable vector index (:class:`~repro.core.indexing.MutableBruteIndex`
  or :class:`~repro.core.indexing.MutableIVFIndex` with a frozen coarse
  quantizer and per-list append slack),
* capacity-padded node embeddings/text with an ``alive`` bitmap,

and keeps every attached :class:`~repro.core.pipeline.RGLPipeline`
pointed at the current merged snapshot.  Three invariants carry the
correctness story:

**Zero-mutation parity.**  A freshly built store is *pristine*: it hands
out the exact frozen objects (``ELLGraph``, ``BruteIndex``/``IVFIndex``,
the original embedding array) a mutation-free setup would build, so a
serving run that never mutates is bitwise identical to one without the
store.  The first ``apply()`` activates the delta tier (one-time
capacity-padded rebuild + retrace).

**Snapshot functionality.**  ``apply()`` builds *new* device arrays and
re-points attached pipelines between engine steps; arrays handed to an
already-dispatched retrieval are never written, so in-flight async work
completes against the epoch it was launched on (no torn reads — the
``apply_mutations``-vs-``step`` interleaving contract).

**Rebuild parity.**  ``compact()`` derives the merged logical corpus
(surviving edges, alive bitmap, zeroed dead rows) from the host mirrors
and feeds it through the *same* canonical builder
(edge canonicalization -> ``CSRGraph.from_edges`` -> ``csr_to_ell`` ->
``assign_to_centroids`` list layout) that ``build(..., alive=...)`` uses
for a from-scratch construction — so post-compaction state, search
results and subgraphs are bitwise identical to a rebuild on the same
corpus (``tests/test_mutation.py`` asserts array-level equality).  For
IVF the comparator shares the frozen quantizer (FAISS semantics: a
"rebuild" re-assigns against the same centroids).

Node ids are stable forever: tombstoned ids keep their (empty) rows and
are never reused, so cached retrievals, tokenized prompts and region
keys stay coherent across any mutation sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import indexing
from repro.core.pipeline import PipelineConfig, RGLPipeline
from repro.graph.csr import CSRGraph
from repro.graph.delta import CapacityOverflow, DeltaGraph, SlackOverflow
from repro.graph.ell import csr_to_ell

_norm = jax.jit(indexing.l2_normalize)


@dataclasses.dataclass
class MutationBatch:
    """One atomic corpus change set.

    ``add_edges`` may reference nodes added in the same batch: new ids are
    assigned in order starting at the store's current ``n_nodes``.
    ``symmetric=True`` (default) inserts/deletes both arc directions —
    the retrieval tier's BFS is pull-based over a symmetrized graph.
    """

    add_node_feat: Optional[np.ndarray] = None  # (A, D) float32
    add_node_text: Optional[list] = None  # len A (defaults to "")
    add_edges: tuple = ()  # iterable of (u, v)
    del_edges: tuple = ()
    del_nodes: tuple = ()
    symmetric: bool = True

    @property
    def n_added_nodes(self) -> int:
        if self.add_node_feat is None:
            return 0
        return int(np.asarray(self.add_node_feat).shape[0])

    @property
    def is_empty(self) -> bool:
        # len(), not truthiness: edge fields are commonly numpy arrays,
        # whose bool() raises for more than one element.
        return (self.n_added_nodes == 0 and len(self.add_edges) == 0
                and len(self.del_edges) == 0 and len(self.del_nodes) == 0)


@dataclasses.dataclass
class MutationReport:
    """What one ``apply()`` did — consumed by cache invalidation."""

    epoch: int
    touched: np.ndarray  # node ids whose adjacency/liveness changed
    added_nodes: tuple = ()
    compactions: int = 0  # overflow-triggered compactions during the apply
    edges_added: int = 0
    edges_deleted: int = 0
    nodes_deleted: int = 0


class MutableGraphStore:
    """Corpus that changes while the engine serves (see module docstring)."""

    MUTABLE_INDEX_KINDS = ("brute", "ivf")

    def __init__(self, *, csr: CSRGraph, node_emb: np.ndarray,
                 node_text: Optional[list], index_kind: str,
                 index_kw: dict, headroom: int, extra_deg: int,
                 ivf_slack: int, max_deg: Optional[int],
                 pad_to_multiple: int):
        if index_kind not in self.MUTABLE_INDEX_KINDS:
            raise ValueError(
                f"mutable store supports index kinds "
                f"{self.MUTABLE_INDEX_KINDS}, got {index_kind!r}"
            )
        self.index_kind = index_kind
        self.index_kw = dict(index_kw)
        self.headroom = int(headroom)
        self.extra_deg = int(extra_deg)
        self.ivf_slack = int(ivf_slack)
        self.max_deg = max_deg
        self.pad_to_multiple = int(pad_to_multiple)

        self.epoch = 0
        self.compactions = 0
        self.mutations_since_compact = 0
        self.batches_applied = 0
        self._pipelines: list = []

        # pristine tier: the exact objects a frozen-corpus setup builds
        self._pristine_csr = csr
        self._pristine_ell = csr_to_ell(
            csr, max_deg=max_deg, pad_to_multiple=pad_to_multiple
        )
        self._pristine_emb = jnp.asarray(node_emb, dtype=jnp.float32)
        self._pristine_index = indexing.build_index(
            node_emb, kind=index_kind, **index_kw
        )
        self._h_feat0 = np.asarray(node_emb, dtype=np.float32)
        self.node_text = list(node_text) if node_text is not None else None
        self._active = False
        # active-tier state, populated by _activate()
        self.delta: Optional[DeltaGraph] = None
        self.h_feat: Optional[np.ndarray] = None
        self._emb_dev = None
        self._index = None

    # ---- construction ---------------------------------------------------
    @classmethod
    def build(cls, csr: CSRGraph, *, node_emb=None, node_text=None,
              index_kind: str = "brute", index_kw: Optional[dict] = None,
              headroom: int = 64, extra_deg: int = 16, ivf_slack: int = 8,
              max_deg: Optional[int] = None, pad_to_multiple: int = 8,
              alive: Optional[np.ndarray] = None,
              active: bool = False) -> "MutableGraphStore":
        """Build a store over ``csr``.

        Default is the pristine (zero-cost, bitwise-frozen) tier.  Pass
        ``active=True`` — optionally with an ``alive`` bitmap and, for IVF,
        ``index_kw['centroids']`` — to construct the capacity-padded active
        tier directly; this is the from-scratch comparator the rebuild
        parity tests use.
        """
        if node_emb is None:
            node_emb = csr.node_feat
        if node_text is None:
            node_text = csr.node_text
        kw = dict(index_kw or {})
        centroids = kw.pop("centroids", None)
        store = cls(
            csr=csr, node_emb=node_emb, node_text=node_text,
            index_kind=index_kind, index_kw=kw, headroom=headroom,
            extra_deg=extra_deg, ivf_slack=ivf_slack, max_deg=max_deg,
            pad_to_multiple=pad_to_multiple,
        )
        if active or alive is not None:
            n = csr.num_nodes
            a = (np.ones(n, bool) if alive is None
                 else np.asarray(alive, bool).copy())
            src, dst = csr.edge_list()
            feat = store._h_feat0 * a[:, None]
            text = (list(store.node_text) if store.node_text is not None
                    else None)
            store._build_active(
                n, a, src.astype(np.int64), dst.astype(np.int64),
                feat, text, centroids=centroids,
            )
        return store

    # ---- views (what pipelines consume) ---------------------------------
    @property
    def active(self) -> bool:
        return self._active

    @property
    def n_nodes(self) -> int:
        return self.delta.n_nodes if self._active else self._pristine_csr.num_nodes

    @property
    def capacity(self) -> int:
        return self.delta.capacity if self._active else self._pristine_csr.num_nodes

    @property
    def alive(self) -> np.ndarray:
        """Host bitmap over logical ids [0, n_nodes)."""
        if not self._active:
            return np.ones(self.n_nodes, bool)
        return ~self.delta.tomb[: self.n_nodes]

    @property
    def graph(self):
        return self.delta.merged() if self._active else self._pristine_ell

    @property
    def index(self):
        return self._index if self._active else self._pristine_index

    @property
    def node_emb(self):
        return self._emb_dev if self._active else self._pristine_emb

    def make_pipeline(self, *, tokenizer=None, generator=None,
                      config: Optional[PipelineConfig] = None) -> RGLPipeline:
        p = RGLPipeline(
            graph=self.graph, index=self.index, node_emb=self.node_emb,
            tokenizer=tokenizer, generator=generator,
            node_text=self.node_text,
            config=config or PipelineConfig(), mutation_store=self,
        )
        self._pipelines.append(p)
        return p

    def attach(self, pipeline: RGLPipeline) -> None:
        """Adopt an externally built pipeline (re-pointed on every apply)."""
        pipeline.mutation_store = self
        self._pipelines.append(pipeline)
        self._sync_pipelines()

    def _sync_pipelines(self) -> None:
        for p in self._pipelines:
            p.graph = self.graph
            p.index = self.index
            p.node_emb = self.node_emb
            p.node_text = self.node_text

    # ---- canonical active-tier builder (apply/compact/from-scratch) -----
    def _build_active(self, n: int, alive: np.ndarray, src: np.ndarray,
                      dst: np.ndarray, feat: np.ndarray,
                      text: Optional[list], *, centroids=None,
                      min_capacity: int = 0) -> None:
        """Rebuild the capacity-padded tier from a logical corpus.

        Every path into the active tier — first activation, periodic
        compaction, and the from-scratch comparator — funnels through this
        one function, which is what makes rebuild parity bitwise: same
        corpus in, same canonicalization, same arrays out.
        """
        keep = alive[src] & alive[dst]
        src, dst = src[keep], dst[keep]
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        if src.size:
            dup = np.concatenate(
                [[False], (src[1:] == src[:-1]) & (dst[1:] == dst[:-1])]
            )
            src, dst = src[~dup], dst[~dup]
        csr = CSRGraph.from_edges(src, dst, n)
        ell = csr_to_ell(
            csr, max_deg=self.max_deg, pad_to_multiple=self.pad_to_multiple
        )
        capacity = max(n + self.headroom, min_capacity)
        self.delta = DeltaGraph(
            np.asarray(ell.nbr), np.asarray(ell.nbr_mask), n, capacity,
            extra_deg=self.extra_deg,
        )
        self.delta.tomb[:n] = ~alive

        self.h_feat = np.zeros((capacity, feat.shape[1]), np.float32)
        self.h_feat[:n] = feat * alive[:, None]
        self.node_text = None if text is None else [
            t if a else "" for t, a in zip(text, alive)
        ]
        self._emb_dev = jnp.asarray(self.h_feat)
        self._rebuild_index(centroids=centroids)
        self._active = True

    def _alive_cap(self) -> np.ndarray:
        a = np.zeros(self.delta.capacity, bool)
        a[: self.delta.n_nodes] = ~self.delta.tomb[: self.delta.n_nodes]
        return a

    def _rebuild_index(self, *, centroids=None) -> None:
        embn = _norm(self._emb_dev)
        alive_cap = self._alive_cap()
        valid = jnp.asarray(alive_cap)
        if self.index_kind == "brute":
            self._index = indexing.MutableBruteIndex(
                emb=embn * valid[:, None], valid=valid
            )
            return
        if centroids is None:
            if self._index is not None:
                centroids = self._index.centroids  # frozen quantizer
            else:
                centroids = self._pristine_index.centroids
        centroids = jnp.asarray(centroids)
        ids = np.flatnonzero(alive_cap).astype(np.int32)
        assign = np.asarray(indexing.assign_to_centroids(embn[ids], centroids))
        lists, counts = indexing.build_inverted_lists_slack(
            assign, ids, self.delta.capacity, int(centroids.shape[0]),
            self.ivf_slack,
        )
        nprobe = self.index_kw.get(
            "nprobe", getattr(self._pristine_index, "nprobe", 4)
        )
        self._index = indexing.MutableIVFIndex(
            emb=embn * valid[:, None], centroids=centroids,
            h_lists=lists, h_counts=counts, valid=valid,
            nprobe=nprobe, slack=self.ivf_slack,
        )

    def _activate(self) -> None:
        csr = self._pristine_csr
        n = csr.num_nodes
        src, dst = csr.edge_list()
        text = list(self.node_text) if self.node_text is not None else None
        self._build_active(
            n, np.ones(n, bool), src.astype(np.int64), dst.astype(np.int64),
            self._h_feat0.copy(), text,
        )

    # ---- the write path -------------------------------------------------
    def apply(self, batch: MutationBatch) -> MutationReport:
        """Apply one mutation batch; bumps the epoch, re-points pipelines.

        Must be called between engine steps (never concurrently with a
        dispatch); snapshots already handed out stay readable.  Slack or
        capacity overflow triggers an inline compaction and the apply
        proceeds — mutations never fail for layout reasons.
        """
        if not self._active:
            self._activate()
        report_compactions = self.compactions
        touched: set = set()
        added: list = []

        n_add = batch.n_added_nodes
        if self.delta.n_nodes + n_add > self.delta.capacity:
            self._compact(min_capacity=self.delta.n_nodes + n_add
                          + self.headroom)
        if n_add:
            feats = np.asarray(batch.add_node_feat, np.float32)
            texts = batch.add_node_text or [""] * n_add
            for i in range(n_add):
                u = self.delta.add_node()
                self.h_feat[u] = feats[i]
                if self.node_text is not None:
                    self.node_text.append(texts[i])
                added.append(u)
                touched.add(u)
        # Any compaction from here on rebuilds the index over all alive
        # ids, the just-added nodes included — _refresh_device must then
        # skip the incremental add or the IVF lists hold them twice.
        compactions_after_adds = self.compactions

        edges_added = edges_deleted = 0
        for u, v in batch.add_edges:
            for a, b in ((u, v), (v, u)) if batch.symmetric else ((u, v),):
                try:
                    done = self.delta.add_edge(int(a), int(b))
                except (SlackOverflow, CapacityOverflow):
                    self._compact()
                    done = self.delta.add_edge(int(a), int(b))
                if done:
                    edges_added += 1
                    touched.update((int(a), int(b)))
        for u, v in batch.del_edges:
            for a, b in ((u, v), (v, u)) if batch.symmetric else ((u, v),):
                if self.delta.del_edge(int(a), int(b)):
                    edges_deleted += 1
                    touched.update((int(a), int(b)))
        for u in batch.del_nodes:
            u = int(u)
            touched.add(u)
            touched.update(int(v) for v in self.delta.neighbors_live(u))
            self.delta.del_node(u)
            self.h_feat[u] = 0.0
            if self.node_text is not None:
                self.node_text[u] = ""

        self.epoch += 1
        self.batches_applied += 1
        self.mutations_since_compact += 1
        already_indexed = self.compactions != compactions_after_adds
        self._refresh_device([] if already_indexed else added)
        self._sync_pipelines()
        return MutationReport(
            epoch=self.epoch,
            touched=np.array(sorted(touched), dtype=np.int64),
            added_nodes=tuple(added),
            compactions=self.compactions - report_compactions,
            edges_added=edges_added, edges_deleted=edges_deleted,
            nodes_deleted=len(batch.del_nodes),
        )

    def _refresh_device(self, added_ids: list) -> None:
        self._emb_dev = jnp.asarray(self.h_feat)
        embn = _norm(self._emb_dev)
        valid = jnp.asarray(self._alive_cap())
        if self.index_kind == "brute":
            self._index = indexing.MutableBruteIndex(
                emb=embn * valid[:, None], valid=valid
            )
            return
        idx = self._index
        idx.emb = embn * valid[:, None]
        idx.valid = valid
        idx._dev = None
        if added_ids:
            try:
                idx.add(np.asarray(added_ids, np.int32))
            except SlackOverflow:
                self._compact()

    # ---- compaction -----------------------------------------------------
    def compact(self) -> None:
        """Fold the delta into a fresh canonical base (see module doc)."""
        if not self._active:
            return
        self._compact()
        self._sync_pipelines()

    def _compact(self, min_capacity: int = 0) -> None:
        n = self.delta.n_nodes
        alive = ~self.delta.tomb[:n]
        src, dst = self.delta.live_edge_list()
        text = list(self.node_text) if self.node_text is not None else None
        centroids = (self._index.centroids
                     if self.index_kind == "ivf" else None)
        self._build_active(
            n, alive, src, dst, self.h_feat[:n].copy(), text,
            centroids=centroids, min_capacity=min_capacity,
        )
        self.compactions += 1
        self.mutations_since_compact = 0

    # ---- introspection --------------------------------------------------
    def stats(self) -> dict:
        return {
            "epoch": self.epoch,
            "active": self._active,
            "n_nodes": self.n_nodes,
            "capacity": self.capacity,
            "alive_nodes": int(self.alive.sum()),
            "batches_applied": self.batches_applied,
            "compactions": self.compactions,
            "mutations_since_compact": self.mutations_since_compact,
        }
