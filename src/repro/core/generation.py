"""Stage 5 of the RGL pipeline: generation interface (paper §2.1.4).

The paper calls hosted LLMs (GPT-4o-mini / DeepSeek-V3); offline here, the
interface targets the in-repo LM stack instead.  Two backends:

* :class:`ExtractiveGenerator` — LM-free summarizer (budgeted extraction from
  the retrieved context, retrieval-priority order).  Deterministic; used as
  the cheap default in benchmarks.
* :class:`LMGenerator` — any of the 5 assigned LM architectures, greedy or
  temperature sampling through the serving path (prefill + KV-cache decode).
  Constructed in ``repro.models.transformer.generate`` to avoid circular
  imports; registered here via :func:`register_lm_generator`.
"""
from __future__ import annotations

from typing import Protocol

import numpy as np


class Generator(Protocol):
    def generate(self, prompt_ids: np.ndarray, prompt_mask: np.ndarray,
                 max_new_tokens: int) -> list:  # -> list[str]
        ...


class ExtractiveGenerator:
    """Budgeted extraction: emit context tokens in retrieval-priority order.

    A strong cheap baseline for abstract generation — ROUGE against the true
    abstract rewards overlapping content words, which retrieved neighborhood
    text supplies (the same effect the paper gets from prompting an LLM with
    the retrieved context)."""

    def __init__(self, vocab, max_words: int = 48):
        self.vocab = vocab
        self.max_words = max_words
        self.id_to_word = {v + 6: k for k, v in vocab.word_to_id.items()}

    def generate(self, prompt_ids, prompt_mask, max_new_tokens: int = 0) -> list:
        out = []
        budget = self.max_words if max_new_tokens == 0 else max_new_tokens
        for ids, m in zip(np.asarray(prompt_ids), np.asarray(prompt_mask)):
            words = [self.id_to_word[int(t)] for t in ids[m] if int(t) in self.id_to_word]
            seen, uniq = set(), []
            for w in words:
                if w not in seen:
                    seen.add(w)
                    uniq.append(w)
            out.append(" ".join(uniq[:budget]))
        return out


_LM_GENERATOR_FACTORY = None


def register_lm_generator(factory) -> None:
    global _LM_GENERATOR_FACTORY
    _LM_GENERATOR_FACTORY = factory


def make_lm_generator(*args, **kw):
    if _LM_GENERATOR_FACTORY is None:
        from repro.models.transformer import generate as _g  # lazy wiring

        register_lm_generator(_g.LMGenerator)
    return _LM_GENERATOR_FACTORY(*args, **kw)
