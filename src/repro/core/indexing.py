"""Stage 1 of the RGL pipeline: indexing.

Vector indexes over node embeddings (paper §2.1.2):

* :class:`BruteIndex` — exact MXU-friendly scoring.  The hot loop is the
  fused similarity→top-k Pallas kernel (``repro.kernels.topk_sim``).
* :class:`IVFIndex` — k-means coarse quantizer (Lloyd in jnp) with padded
  inverted lists; probes ``nprobe`` lists per query.  Sub-linear scan cost,
  fixed shapes throughout (lists padded to the longest list).  Candidate
  scoring streams through the tiled ``repro.kernels.ivf_scan`` path instead
  of materializing the dense (Q, nprobe*L, D) gather.
* ``ShardedIndex`` (``repro.core.sharding``) — row-partitions either scan
  across a device mesh and merges per-shard top-k hierarchically.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ivf_scan import ops as ivf_ops
from repro.kernels.topk_sim import ops as topk_ops


def l2_normalize(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + eps)


@dataclasses.dataclass
class BruteIndex:
    emb: jnp.ndarray  # (N, D) float32, rows may be L2-normalized
    normalized: bool = True

    @staticmethod
    def build(emb, normalize: bool = True) -> "BruteIndex":
        emb = jnp.asarray(emb, dtype=jnp.float32)
        if normalize:
            emb = l2_normalize(emb)
        return BruteIndex(emb=emb, normalized=normalize)

    def search(self, queries: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Return (scores, indices) of the top-k most similar nodes, (Q, k)."""
        q = jnp.asarray(queries, dtype=jnp.float32)
        if self.normalized:
            q = l2_normalize(q)
        return topk_ops.topk_similarity(q, self.emb, k)


def kmeans(
    x: jnp.ndarray, n_clusters: int, n_iter: int = 10, seed: int = 0
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Lloyd's algorithm.  Returns (centroids (C, D), assignment (N,)).

    When ``n_clusters > n`` the init falls back to sampling with
    replacement (duplicate centroids yield empty clusters, which the
    update step already keeps frozen) instead of crashing
    ``jax.random.choice(replace=False)``.
    """
    n = x.shape[0]
    key = jax.random.PRNGKey(seed)
    init = jax.random.choice(
        key, n, shape=(n_clusters,), replace=n_clusters > n
    )
    cent = x[init]

    def step(cent, _):
        d = (
            jnp.sum(x * x, axis=1)[:, None]
            - 2.0 * x @ cent.T
            + jnp.sum(cent * cent, axis=1)[None, :]
        )
        assign = jnp.argmin(d, axis=1)
        sums = jax.ops.segment_sum(x, assign, num_segments=n_clusters)
        counts = jax.ops.segment_sum(
            jnp.ones((n,), x.dtype), assign, num_segments=n_clusters
        )
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1), cent)
        return new, assign

    cent, assigns = jax.lax.scan(step, cent, None, length=n_iter)
    return cent, assigns[-1]


def build_inverted_lists(
    assign: np.ndarray, n: int, n_clusters: int, min_pad: int = 8
) -> tuple[np.ndarray, np.ndarray]:
    """Padded inverted lists from a cluster assignment — vectorized scatter.

    Returns (lists (C, L) int32 with sentinel n, mask (C, L) bool).  A
    member's rank within its cluster is its position in the stable argsort
    minus the cluster's start offset (cumcount), so the whole fill is three
    NumPy ops instead of an O(N) Python loop.
    """
    assign = np.asarray(assign)
    counts = np.bincount(assign, minlength=n_clusters)
    pad = max(min_pad, int(counts.max()) if n else min_pad)
    lists = np.full((n_clusters, pad), n, dtype=np.int32)
    order = np.argsort(assign, kind="stable")
    sorted_assign = assign[order]
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    ranks = np.arange(n) - starts[sorted_assign]
    lists[sorted_assign, ranks] = order
    return lists, lists < n


@dataclasses.dataclass
class IVFIndex:
    """Inverted-file index: coarse centroids + padded member lists."""

    emb: jnp.ndarray  # (N, D)
    centroids: jnp.ndarray  # (C, D)
    lists: jnp.ndarray  # (C, L) int32 member ids, sentinel = N
    list_mask: jnp.ndarray  # (C, L) bool
    nprobe: int = 4

    @staticmethod
    def build(
        emb, n_clusters: int = 64, nprobe: int = 4, n_iter: int = 10,
        normalize: bool = True, seed: int = 0,
    ) -> "IVFIndex":
        emb = jnp.asarray(emb, dtype=jnp.float32)
        if normalize:
            emb = l2_normalize(emb)
        n = emb.shape[0]
        n_clusters = max(1, min(n_clusters, n))
        cent, assign = kmeans(emb, n_clusters, n_iter=n_iter, seed=seed)
        lists, mask = build_inverted_lists(np.asarray(assign), n, n_clusters)
        return IVFIndex(
            emb=emb,
            centroids=jnp.asarray(cent),
            lists=jnp.asarray(lists),
            list_mask=jnp.asarray(mask),
            nprobe=min(nprobe, n_clusters),
        )

    def search(self, queries: jnp.ndarray, k: int):
        q = l2_normalize(jnp.asarray(queries, dtype=jnp.float32))
        return _ivf_search(
            self.emb, self.centroids, self.lists, self.list_mask, q,
            min(self.nprobe, self.centroids.shape[0]), k,
        )


def ivf_probe_scan(
    emb, centroids, lists, list_mask, q, nprobe: int, k: int,
    tiled: Optional[bool] = None,
):
    """Trace-time core of the IVF search (also reused per shard).

    1) score centroids, pick nprobe lists per query;
    2) gather candidate ids (Q, nprobe*L) with sentinel padding;
    3) tiled candidate scan (repro.kernels.ivf_scan) — fixed-shape chunks
       instead of a dense (Q, nprobe*L, D) embedding gather.
    """
    cs = q @ centroids.T  # (Q, C)
    _, probe = jax.lax.top_k(cs, nprobe)  # (Q, P)
    cand = lists[probe].reshape(q.shape[0], -1)  # (Q, P*L) int32 ids
    cmask = list_mask[probe].reshape(q.shape[0], -1)
    return ivf_ops.ivf_candidate_scan(q, emb, cand, cmask, k, tiled=tiled)


_ivf_search = jax.jit(ivf_probe_scan, static_argnames=("nprobe", "k", "tiled"))


# ---- mutable tier (online insert/delete; see repro.core.mutation) --------
#
# The frozen indexes above assume the corpus is complete before build.  The
# mutable variants serve a corpus that changes while the engine runs:
# capacity-padded embedding rows with a ``valid`` bitmap (deletes are masked
# at scan time, FAISS-style), and — for IVF — a **frozen coarse quantizer**:
# centroids are trained once, new embeddings are assigned to the nearest
# existing centroid into per-list append slack, and compaction rebuilds only
# the list layout (never the centroids).  Freezing the quantizer is what
# makes "rebuild from scratch on the merged corpus" a deterministic
# comparator: both the incremental path and the rebuild assign with
# :func:`assign_to_centroids`, so post-compaction state is bitwise equal.


@jax.jit
def assign_to_centroids(embn: jnp.ndarray, centroids: jnp.ndarray):
    """Nearest-centroid assignment (same distance form as :func:`kmeans`).

    The single canonical assignment used by activation, incremental adds
    and compaction — internal consistency is what the bitwise rebuild
    parity rests on.
    """
    d = (
        jnp.sum(embn * embn, axis=1)[:, None]
        - 2.0 * embn @ centroids.T
        + jnp.sum(centroids * centroids, axis=1)[None, :]
    )
    return jnp.argmin(d, axis=1)


def build_inverted_lists_slack(
    assign: np.ndarray, ids: np.ndarray, capacity: int, n_clusters: int,
    slack: int, min_pad: int = 8,
) -> tuple[np.ndarray, np.ndarray]:
    """Padded inverted lists over ``ids`` only, with ``slack`` spare slots
    per list for future appends.  Returns (lists (C, L) int32 with sentinel
    ``capacity``, counts (C,)).  Members are stored in ascending-id order
    (``ids`` must be sorted), the canonical layout compaction re-creates."""
    assign = np.asarray(assign)
    ids = np.asarray(ids, dtype=np.int32)
    counts = np.bincount(assign, minlength=n_clusters).astype(np.int32)
    width = int(counts.max()) + slack if ids.size else slack
    width = max(min_pad, -(-width // min_pad) * min_pad)
    lists = np.full((n_clusters, width), capacity, dtype=np.int32)
    order = np.argsort(assign, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    ranks = np.arange(ids.size) - starts[assign[order]]
    lists[assign[order], ranks] = ids[order]
    return lists, counts


@partial(jax.jit, static_argnames=("k",))
def _masked_topk(q, emb, valid, k: int):
    scores = q @ emb.T
    scores = jnp.where(valid[None, :], scores, -jnp.inf)
    return jax.lax.top_k(scores, k)


@dataclasses.dataclass
class MutableBruteIndex:
    """Exact scan over capacity-padded rows; deletes masked to ``-inf``."""

    emb: jnp.ndarray  # (capacity, D) L2-normalized; dead rows are zero
    valid: jnp.ndarray  # (capacity,) bool

    def search(self, queries, k: int):
        q = l2_normalize(jnp.asarray(queries, dtype=jnp.float32))
        return _masked_topk(q, self.emb, self.valid, k)


@partial(jax.jit, static_argnames=("nprobe", "k"))
def _mutable_ivf_search(emb, centroids, lists, list_mask, valid, q,
                        nprobe: int, k: int):
    cs = q @ centroids.T
    _, probe = jax.lax.top_k(cs, nprobe)
    cand = lists[probe].reshape(q.shape[0], -1)
    cmask = list_mask[probe].reshape(q.shape[0], -1)
    safe = jnp.minimum(cand, emb.shape[0] - 1)
    cmask = cmask & valid[safe]  # scan-time delete masking
    return ivf_ops.ivf_candidate_scan(q, emb, cand, cmask, k)


class MutableIVFIndex:
    """IVF with a frozen coarse quantizer and per-list append slack.

    ``h_lists``/``h_counts`` are host mirrors (mutation-rate structures);
    the device copies are re-uploaded lazily after a mutation.  Appends
    that would overflow a list raise
    :class:`repro.graph.delta.SlackOverflow`, which the owning store
    answers with a compaction (list layout rebuilt, centroids untouched).
    """

    def __init__(self, emb, centroids, h_lists, h_counts, valid,
                 nprobe: int = 4, slack: int = 8):
        self.emb = emb  # (capacity, D) normalized device
        self.centroids = centroids  # (C, D) device, frozen
        self.h_lists = h_lists  # (C, L) int32, sentinel = capacity
        self.h_counts = h_counts  # (C,) int32
        self.valid = valid  # (capacity,) bool device
        self.nprobe = int(nprobe)
        self.slack = int(slack)
        self._dev = None  # cached (lists, mask) device pair

    @property
    def n_clusters(self) -> int:
        return int(self.h_lists.shape[0])

    def add(self, ids: np.ndarray) -> np.ndarray:
        """Append ``ids`` (already written into ``emb``) to their nearest
        list.  Returns the cluster assignment; raises on slack overflow."""
        from repro.graph.delta import SlackOverflow  # local: avoid cycle

        ids = np.asarray(ids, dtype=np.int32)
        if ids.size == 0:
            return ids
        assign = np.asarray(assign_to_centroids(self.emb[ids], self.centroids))
        width = self.h_lists.shape[1]
        for i, c in zip(ids, assign):
            cnt = int(self.h_counts[c])
            if i in self.h_lists[c, :cnt]:
                continue  # already indexed (e.g. by a compaction rebuild)
            if cnt >= width:
                raise SlackOverflow(
                    f"IVF list {int(c)}: {width} slots full; compact"
                )
            self.h_lists[c, cnt] = i
            self.h_counts[c] = cnt + 1
        self._dev = None
        return assign

    def _device_lists(self):
        if self._dev is None:
            mask = (
                np.arange(self.h_lists.shape[1])[None, :]
                < self.h_counts[:, None]
            )
            self._dev = (jnp.asarray(self.h_lists), jnp.asarray(mask))
        return self._dev

    def search(self, queries, k: int):
        q = l2_normalize(jnp.asarray(queries, dtype=jnp.float32))
        lists, mask = self._device_lists()
        return _mutable_ivf_search(
            self.emb, self.centroids, lists, mask, self.valid, q,
            min(self.nprobe, self.n_clusters), k,
        )


def build_index(emb, kind: str = "brute", **kw):
    if kind == "brute":
        return BruteIndex.build(emb, **kw)
    if kind == "ivf":
        return IVFIndex.build(emb, **kw)
    if kind in ("sharded", "sharded_ivf"):
        from repro.core.sharding import ShardedIndex  # local: avoid cycle

        inner = "ivf" if kind == "sharded_ivf" else "brute"
        return ShardedIndex.build(emb, inner=inner, **kw)
    raise ValueError(f"unknown index kind: {kind}")
