"""Stage 1 of the RGL pipeline: indexing.

Vector indexes over node embeddings (paper §2.1.2):

* :class:`BruteIndex` — exact MXU-friendly scoring.  The hot loop is the
  fused similarity→top-k Pallas kernel (``repro.kernels.topk_sim``).
* :class:`IVFIndex` — k-means coarse quantizer (Lloyd in jnp) with padded
  inverted lists; probes ``nprobe`` lists per query.  Sub-linear scan cost,
  fixed shapes throughout (lists padded to the longest list).  Candidate
  scoring streams through the tiled ``repro.kernels.ivf_scan`` path instead
  of materializing the dense (Q, nprobe*L, D) gather.
* ``ShardedIndex`` (``repro.core.sharding``) — row-partitions either scan
  across a device mesh and merges per-shard top-k hierarchically.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ivf_scan import ops as ivf_ops
from repro.kernels.topk_sim import ops as topk_ops


def l2_normalize(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + eps)


@dataclasses.dataclass
class BruteIndex:
    emb: jnp.ndarray  # (N, D) float32, rows may be L2-normalized
    normalized: bool = True

    @staticmethod
    def build(emb, normalize: bool = True) -> "BruteIndex":
        emb = jnp.asarray(emb, dtype=jnp.float32)
        if normalize:
            emb = l2_normalize(emb)
        return BruteIndex(emb=emb, normalized=normalize)

    def search(self, queries: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Return (scores, indices) of the top-k most similar nodes, (Q, k)."""
        q = jnp.asarray(queries, dtype=jnp.float32)
        if self.normalized:
            q = l2_normalize(q)
        return topk_ops.topk_similarity(q, self.emb, k)


def kmeans(
    x: jnp.ndarray, n_clusters: int, n_iter: int = 10, seed: int = 0
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Lloyd's algorithm.  Returns (centroids (C, D), assignment (N,)).

    When ``n_clusters > n`` the init falls back to sampling with
    replacement (duplicate centroids yield empty clusters, which the
    update step already keeps frozen) instead of crashing
    ``jax.random.choice(replace=False)``.
    """
    n = x.shape[0]
    key = jax.random.PRNGKey(seed)
    init = jax.random.choice(
        key, n, shape=(n_clusters,), replace=n_clusters > n
    )
    cent = x[init]

    def step(cent, _):
        d = (
            jnp.sum(x * x, axis=1)[:, None]
            - 2.0 * x @ cent.T
            + jnp.sum(cent * cent, axis=1)[None, :]
        )
        assign = jnp.argmin(d, axis=1)
        sums = jax.ops.segment_sum(x, assign, num_segments=n_clusters)
        counts = jax.ops.segment_sum(
            jnp.ones((n,), x.dtype), assign, num_segments=n_clusters
        )
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1), cent)
        return new, assign

    cent, assigns = jax.lax.scan(step, cent, None, length=n_iter)
    return cent, assigns[-1]


def build_inverted_lists(
    assign: np.ndarray, n: int, n_clusters: int, min_pad: int = 8
) -> tuple[np.ndarray, np.ndarray]:
    """Padded inverted lists from a cluster assignment — vectorized scatter.

    Returns (lists (C, L) int32 with sentinel n, mask (C, L) bool).  A
    member's rank within its cluster is its position in the stable argsort
    minus the cluster's start offset (cumcount), so the whole fill is three
    NumPy ops instead of an O(N) Python loop.
    """
    assign = np.asarray(assign)
    counts = np.bincount(assign, minlength=n_clusters)
    pad = max(min_pad, int(counts.max()) if n else min_pad)
    lists = np.full((n_clusters, pad), n, dtype=np.int32)
    order = np.argsort(assign, kind="stable")
    sorted_assign = assign[order]
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    ranks = np.arange(n) - starts[sorted_assign]
    lists[sorted_assign, ranks] = order
    return lists, lists < n


@dataclasses.dataclass
class IVFIndex:
    """Inverted-file index: coarse centroids + padded member lists."""

    emb: jnp.ndarray  # (N, D)
    centroids: jnp.ndarray  # (C, D)
    lists: jnp.ndarray  # (C, L) int32 member ids, sentinel = N
    list_mask: jnp.ndarray  # (C, L) bool
    nprobe: int = 4

    @staticmethod
    def build(
        emb, n_clusters: int = 64, nprobe: int = 4, n_iter: int = 10,
        normalize: bool = True, seed: int = 0,
    ) -> "IVFIndex":
        emb = jnp.asarray(emb, dtype=jnp.float32)
        if normalize:
            emb = l2_normalize(emb)
        n = emb.shape[0]
        n_clusters = max(1, min(n_clusters, n))
        cent, assign = kmeans(emb, n_clusters, n_iter=n_iter, seed=seed)
        lists, mask = build_inverted_lists(np.asarray(assign), n, n_clusters)
        return IVFIndex(
            emb=emb,
            centroids=jnp.asarray(cent),
            lists=jnp.asarray(lists),
            list_mask=jnp.asarray(mask),
            nprobe=min(nprobe, n_clusters),
        )

    def search(self, queries: jnp.ndarray, k: int):
        q = l2_normalize(jnp.asarray(queries, dtype=jnp.float32))
        return _ivf_search(
            self.emb, self.centroids, self.lists, self.list_mask, q,
            min(self.nprobe, self.centroids.shape[0]), k,
        )


def ivf_probe_scan(
    emb, centroids, lists, list_mask, q, nprobe: int, k: int,
    tiled: Optional[bool] = None,
):
    """Trace-time core of the IVF search (also reused per shard).

    1) score centroids, pick nprobe lists per query;
    2) gather candidate ids (Q, nprobe*L) with sentinel padding;
    3) tiled candidate scan (repro.kernels.ivf_scan) — fixed-shape chunks
       instead of a dense (Q, nprobe*L, D) embedding gather.
    """
    cs = q @ centroids.T  # (Q, C)
    _, probe = jax.lax.top_k(cs, nprobe)  # (Q, P)
    cand = lists[probe].reshape(q.shape[0], -1)  # (Q, P*L) int32 ids
    cmask = list_mask[probe].reshape(q.shape[0], -1)
    return ivf_ops.ivf_candidate_scan(q, emb, cand, cmask, k, tiled=tiled)


_ivf_search = jax.jit(ivf_probe_scan, static_argnames=("nprobe", "k", "tiled"))


def build_index(emb, kind: str = "brute", **kw):
    if kind == "brute":
        return BruteIndex.build(emb, **kw)
    if kind == "ivf":
        return IVFIndex.build(emb, **kw)
    if kind in ("sharded", "sharded_ivf"):
        from repro.core.sharding import ShardedIndex  # local: avoid cycle

        inner = "ivf" if kind == "sharded_ivf" else "brute"
        return ShardedIndex.build(emb, inner=inner, **kw)
    raise ValueError(f"unknown index kind: {kind}")
