"""Stage 1 of the RGL pipeline: indexing.

Two vector indexes over node embeddings (paper §2.1.2):

* :class:`BruteIndex` — exact MXU-friendly scoring.  The hot loop is the
  fused similarity→top-k Pallas kernel (``repro.kernels.topk_sim``).
* :class:`IVFIndex` — k-means coarse quantizer (Lloyd in jnp) with padded
  inverted lists; probes ``nprobe`` lists per query.  Sub-linear scan cost,
  fixed shapes throughout (lists padded to the longest list).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.topk_sim import ops as topk_ops


def l2_normalize(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + eps)


@dataclasses.dataclass
class BruteIndex:
    emb: jnp.ndarray  # (N, D) float32, rows may be L2-normalized
    normalized: bool = True

    @staticmethod
    def build(emb, normalize: bool = True) -> "BruteIndex":
        emb = jnp.asarray(emb, dtype=jnp.float32)
        if normalize:
            emb = l2_normalize(emb)
        return BruteIndex(emb=emb, normalized=normalize)

    def search(self, queries: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Return (scores, indices) of the top-k most similar nodes, (Q, k)."""
        q = jnp.asarray(queries, dtype=jnp.float32)
        if self.normalized:
            q = l2_normalize(q)
        return topk_ops.topk_similarity(q, self.emb, k)


def kmeans(
    x: jnp.ndarray, n_clusters: int, n_iter: int = 10, seed: int = 0
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Lloyd's algorithm.  Returns (centroids (C, D), assignment (N,))."""
    n = x.shape[0]
    key = jax.random.PRNGKey(seed)
    init = jax.random.choice(key, n, shape=(n_clusters,), replace=False)
    cent = x[init]

    def step(cent, _):
        d = (
            jnp.sum(x * x, axis=1)[:, None]
            - 2.0 * x @ cent.T
            + jnp.sum(cent * cent, axis=1)[None, :]
        )
        assign = jnp.argmin(d, axis=1)
        sums = jax.ops.segment_sum(x, assign, num_segments=n_clusters)
        counts = jax.ops.segment_sum(
            jnp.ones((n,), x.dtype), assign, num_segments=n_clusters
        )
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1), cent)
        return new, assign

    cent, assigns = jax.lax.scan(step, cent, None, length=n_iter)
    return cent, assigns[-1]


@dataclasses.dataclass
class IVFIndex:
    """Inverted-file index: coarse centroids + padded member lists."""

    emb: jnp.ndarray  # (N, D)
    centroids: jnp.ndarray  # (C, D)
    lists: jnp.ndarray  # (C, L) int32 member ids, sentinel = N
    list_mask: jnp.ndarray  # (C, L) bool
    nprobe: int = 4

    @staticmethod
    def build(
        emb, n_clusters: int = 64, nprobe: int = 4, n_iter: int = 10,
        normalize: bool = True, seed: int = 0,
    ) -> "IVFIndex":
        emb = jnp.asarray(emb, dtype=jnp.float32)
        if normalize:
            emb = l2_normalize(emb)
        cent, assign = kmeans(emb, n_clusters, n_iter=n_iter, seed=seed)
        assign_np = np.asarray(assign)
        n = emb.shape[0]
        counts = np.bincount(assign_np, minlength=n_clusters)
        pad = max(8, int(counts.max()))
        lists = np.full((n_clusters, pad), n, dtype=np.int32)
        fill = np.zeros(n_clusters, dtype=np.int64)
        order = np.argsort(assign_np, kind="stable")
        for i in order:  # host-side build; O(N)
            c = assign_np[i]
            lists[c, fill[c]] = i
            fill[c] += 1
        mask = lists < n
        return IVFIndex(
            emb=emb,
            centroids=jnp.asarray(cent),
            lists=jnp.asarray(lists),
            list_mask=jnp.asarray(mask),
            nprobe=nprobe,
        )

    def search(self, queries: jnp.ndarray, k: int):
        q = l2_normalize(jnp.asarray(queries, dtype=jnp.float32))
        return _ivf_search(
            self.emb, self.centroids, self.lists, self.list_mask, q,
            self.nprobe, k,
        )


@partial(jax.jit, static_argnames=("nprobe", "k"))
def _ivf_search(emb, centroids, lists, list_mask, q, nprobe: int, k: int):
    n, d = emb.shape
    # 1) score centroids, pick nprobe lists per query
    cs = q @ centroids.T  # (Q, C)
    _, probe = jax.lax.top_k(cs, nprobe)  # (Q, P)
    # 2) gather candidate ids (Q, P*L) with sentinel padding
    cand = lists[probe].reshape(q.shape[0], -1)  # (Q, P*L)
    cmask = list_mask[probe].reshape(q.shape[0], -1)
    emb_pad = jnp.concatenate([emb, jnp.zeros((1, d), emb.dtype)], 0)
    ce = emb_pad[cand]  # (Q, P*L, D)
    scores = jnp.einsum("qd,qld->ql", q, ce)
    scores = jnp.where(cmask, scores, -jnp.inf)
    top_s, top_i = jax.lax.top_k(scores, k)
    return top_s, jnp.take_along_axis(cand, top_i, axis=1)


def build_index(emb, kind: str = "brute", **kw):
    if kind == "brute":
        return BruteIndex.build(emb, **kw)
    if kind == "ivf":
        return IVFIndex.build(emb, **kw)
    raise ValueError(f"unknown index kind: {kind}")
