"""RGL core: the paper's contribution — the 5-stage RAG-on-Graphs pipeline."""
from repro.core.pipeline import (
    RGLPipeline, PipelineConfig, RetrievalResult, index_from_config,
)
from repro.core.mutation import MutableGraphStore, MutationBatch, MutationReport
from repro.core.graph_retrieval import (
    Subgraph,
    bfs_subgraph,
    dense_subgraph,
    steiner_subgraph,
    retrieve_subgraph,
    bfs_distances,
    induced_adjacency,
)
from repro.core.workset import Workset, build_workset, workset_adjacency
from repro.core.indexing import (
    BruteIndex, IVFIndex, MutableBruteIndex, MutableIVFIndex, build_index,
)
from repro.core.sharding import ShardedIndex, hierarchical_topk_merge
from repro.core.filters import dynamic_filter, similarity_scores
from repro.core.tokenization import Vocab, GraphTokenizer
from repro.core.generation import ExtractiveGenerator, make_lm_generator

__all__ = [
    "RGLPipeline", "PipelineConfig", "RetrievalResult", "index_from_config",
    "MutableGraphStore", "MutationBatch", "MutationReport", "Subgraph",
    "bfs_subgraph", "dense_subgraph", "steiner_subgraph", "retrieve_subgraph",
    "bfs_distances", "induced_adjacency",
    "Workset", "build_workset", "workset_adjacency",
    "BruteIndex", "IVFIndex", "MutableBruteIndex", "MutableIVFIndex",
    "ShardedIndex", "build_index",
    "hierarchical_topk_merge",
    "dynamic_filter", "similarity_scores",
    "Vocab", "GraphTokenizer",
    "ExtractiveGenerator", "make_lm_generator",
]
