"""Sharded vector index: row-partitioned scan + hierarchical top-k merge.

``ShardedIndex`` splits the node-embedding matrix into ``n_shards`` row
blocks laid out across a 1-D ``"shards"`` device mesh via ``shard_map``.
Each device scans only its block(s) with the existing per-shard machinery
(the ``topk_sim`` Pallas kernel for brute scans, the tiled ``ivf_scan``
path for IVF), translates local row ids to global ids by shard offset, and
emits a per-shard ``(Q, kk)`` candidate list.  A jitted hierarchical
(binary-tree) top-k reduction then merges the ``(S, Q, kk)`` candidates
down to the exact ``(Q, k)`` contract of ``BruteIndex.search``.

Design notes:

* **Logical shards vs devices.**  ``n_shards`` is a layout property; the
  mesh uses the largest divisor of ``n_shards`` that fits the available
  devices, and each device sweeps its local shards with ``lax.map``.  The
  same index therefore runs unchanged on 1 host device (pure logical
  sharding) or on a real mesh, and results are bit-identical either way.
* **Exactness under padding.**  N rarely divides ``n_shards``; the tail of
  the last shard is zero-padded (< n_shards rows).  Zero rows score 0.0 and
  could displace negative-scoring real rows from a shard's local top-k, so
  each shard returns ``kk = k + n_pad`` candidates — the k best *real* rows
  of a shard always survive — and padded ids are masked to (-inf, INT32_MAX)
  before the merge.
* **Tie-breaking.**  The pairwise merge sorts lexicographically by
  (score desc, global id asc) via a 2-key ``lax.sort``, the same total
  order ``jax.lax.top_k`` applies over the unsharded score matrix, so
  sharded brute results are bit-identical to ``BruteIndex.search`` —
  including duplicate-score ties — not merely allclose.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import indexing as _ix
from repro.kernels.topk_sim import ops as topk_ops

_I32_MAX = jnp.iinfo(jnp.int32).max


def _mesh_size(n_shards: int, n_devices: int) -> int:
    """Largest divisor of n_shards that is <= n_devices (each device must
    own a whole number of logical shards).  Warns when that collapses the
    mesh well below the available devices — e.g. 7 shards on 8 devices run
    on a single device; pick a shard count that shares a factor."""
    best = 1
    for m in range(min(n_shards, n_devices), 0, -1):
        if n_shards % m == 0:
            best = m
            break
    if best < min(n_shards, n_devices):
        import warnings

        warnings.warn(
            f"n_shards={n_shards} is coprime-ish to the {n_devices} available "
            f"devices; using a {best}-device mesh. Choose n_shards as a "
            f"multiple of the device count for full parallelism.",
            stacklevel=3,
        )
    return best


# --------------------------------------------------------------------------
# hierarchical top-k merge
# --------------------------------------------------------------------------
def _merge_pair(sa, ia, sb, ib, k: int):
    """Merge two sorted candidate lists along the last axis, keep top-k."""
    s = jnp.concatenate([sa, sb], axis=-1)
    i = jnp.concatenate([ia, ib], axis=-1)
    neg, ids = jax.lax.sort((-s, i), num_keys=2)
    return -neg[..., :k], ids[..., :k]


def hierarchical_topk_merge(scores: jnp.ndarray, ids: jnp.ndarray, k: int):
    """(S, Q, w) per-shard candidates -> exact (Q, k) via a binary tree.

    log2(S) rounds of pairwise merges; each round halves the shard axis.
    Selection of the k least elements under the total order
    (-score, id) is associative, so truncating to k at every node is exact.
    """
    if scores.shape[0] == 1:  # degenerate tree: sort + truncate directly
        kk = min(k, scores.shape[-1])
        neg, out_i = jax.lax.sort((-scores[0], ids[0]), num_keys=2)
        return -neg[..., :kk], out_i[..., :kk]
    while scores.shape[0] > 1:
        s = scores.shape[0]
        if s % 2:
            scores = jnp.concatenate(
                [scores, jnp.full_like(scores[:1], -jnp.inf)], axis=0
            )
            ids = jnp.concatenate(
                [ids, jnp.full_like(ids[:1], _I32_MAX)], axis=0
            )
        kk = min(k, 2 * scores.shape[-1])
        scores, ids = _merge_pair(
            scores[0::2], ids[0::2], scores[1::2], ids[1::2], kk
        )
    return scores[0], ids[0]


# --------------------------------------------------------------------------
# per-shard scan bodies (run inside shard_map; one device, s_local shards)
# --------------------------------------------------------------------------
def _brute_shard_fn(
    emb_block, q, *, kk: int, n_total: int, rows_per_shard: int,
    use_kernel: Optional[bool],
):
    p = jax.lax.axis_index("shards")
    s_local = emb_block.shape[0]

    def one(li):
        s, lid = topk_ops.topk_similarity(
            q, emb_block[li], kk, use_kernel=use_kernel
        )
        gid = lid + (p * s_local + li) * rows_per_shard
        ok = gid < n_total
        return (
            jnp.where(ok, s, -jnp.inf),
            jnp.where(ok, gid, _I32_MAX).astype(jnp.int32),
        )

    return jax.lax.map(one, jnp.arange(s_local))


def _ivf_shard_fn(
    emb_block, cent_block, lists_block, mask_block, q, *, k: int,
    n_total: int, rows_per_shard: int, nprobe: int,
):
    p = jax.lax.axis_index("shards")
    s_local = emb_block.shape[0]

    def one(li):
        s, lid = _ix.ivf_probe_scan(
            emb_block[li], cent_block[li], lists_block[li], mask_block[li],
            q, nprobe, k,
        )
        gid = lid + (p * s_local + li) * rows_per_shard
        # lid == rows_per_shard is the local sentinel (unfilled list slot)
        ok = (lid < rows_per_shard) & (gid < n_total)
        return (
            jnp.where(ok, s, -jnp.inf),
            jnp.where(ok, gid, _I32_MAX).astype(jnp.int32),
        )

    return jax.lax.map(one, jnp.arange(s_local))


# --------------------------------------------------------------------------
# jitted search entry points (module-level so index construction never
# recompiles; mesh is hashable and rides as a static arg)
# --------------------------------------------------------------------------
@partial(
    jax.jit,
    static_argnames=("mesh", "k", "n_total", "rows_per_shard", "use_kernel"),
)
def _sharded_brute_search(
    emb_shards, q, *, mesh: Mesh, k: int, n_total: int, rows_per_shard: int,
    use_kernel: Optional[bool],
):
    s, np_, _ = emb_shards.shape
    pad = s * np_ - n_total
    kk = min(k + pad, np_)
    fn = partial(
        _brute_shard_fn, kk=kk, n_total=n_total,
        rows_per_shard=rows_per_shard, use_kernel=use_kernel,
    )
    ss, ii = shard_map(
        fn, mesh=mesh, in_specs=(P("shards"), P()),
        out_specs=(P("shards"), P("shards")), check_rep=False,
    )(emb_shards, q)
    return hierarchical_topk_merge(ss, ii, k)


@partial(
    jax.jit,
    static_argnames=("mesh", "k", "n_total", "rows_per_shard", "nprobe"),
)
def _sharded_ivf_search(
    emb_shards, centroids, lists, list_mask, q, *, mesh: Mesh, k: int,
    n_total: int, rows_per_shard: int, nprobe: int,
):
    fn = partial(
        _ivf_shard_fn, k=k, n_total=n_total,
        rows_per_shard=rows_per_shard, nprobe=nprobe,
    )
    ss, ii = shard_map(
        fn, mesh=mesh,
        in_specs=(P("shards"), P("shards"), P("shards"), P("shards"), P()),
        out_specs=(P("shards"), P("shards")), check_rep=False,
    )(emb_shards, centroids, lists, list_mask, q)
    return hierarchical_topk_merge(ss, ii, k)


@dataclasses.dataclass
class ShardedIndex:
    """Row-partitioned vector index over a 1-D device mesh.

    ``inner="brute"`` is exact (bit-identical to ``BruteIndex``);
    ``inner="ivf"`` builds an independent IVF structure per shard and is
    approximate in the same way single-device IVF is.
    """

    emb_shards: jnp.ndarray  # (S, Np, D); last shard zero-padded at the tail
    n_total: int
    rows_per_shard: int
    mesh: Mesh
    normalized: bool = True
    inner: str = "brute"  # brute | ivf
    use_kernel: Optional[bool] = None  # passthrough to topk_sim ops
    # per-shard IVF state, stacked over shards (inner == "ivf" only)
    centroids: Optional[jnp.ndarray] = None  # (S, C, D)
    lists: Optional[jnp.ndarray] = None  # (S, C, L) local ids, sentinel = Np
    list_mask: Optional[jnp.ndarray] = None  # (S, C, L)
    nprobe: int = 4

    @property
    def n_shards(self) -> int:
        return self.emb_shards.shape[0]

    @staticmethod
    def build(
        emb,
        n_shards: Optional[int] = None,
        inner: str = "brute",
        normalize: bool = True,
        use_kernel: Optional[bool] = None,
        devices=None,
        n_clusters: int = 64,
        nprobe: int = 4,
        n_iter: int = 10,
        seed: int = 0,
    ) -> "ShardedIndex":
        emb = jnp.asarray(emb, dtype=jnp.float32)
        if normalize:
            emb = _ix.l2_normalize(emb)  # full-matrix, before partitioning
        n, d = emb.shape
        devices = list(devices) if devices is not None else jax.devices()
        if n_shards is None:
            n_shards = len(devices)
        n_shards = max(1, min(int(n_shards), n))
        rows = -(-n // n_shards)
        pad = n_shards * rows - n
        shards = jnp.pad(emb, ((0, pad), (0, 0))).reshape(n_shards, rows, d)
        m = _mesh_size(n_shards, len(devices))
        mesh = Mesh(np.asarray(devices[:m]), ("shards",))
        idx = ShardedIndex(
            emb_shards=shards, n_total=n, rows_per_shard=rows, mesh=mesh,
            normalized=normalize, inner=inner, use_kernel=use_kernel,
        )
        if inner == "ivf":
            idx._build_shard_ivf(n_clusters, nprobe, n_iter, seed)
        elif inner != "brute":
            raise ValueError(f"unknown inner scan: {inner}")
        return idx

    def _build_shard_ivf(
        self, n_clusters: int, nprobe: int, n_iter: int, seed: int
    ) -> None:
        """Per-shard k-means + inverted lists over each shard's real rows."""
        s, rows, _ = self.emb_shards.shape
        per_cent, per_lists, per_mask = [], [], []
        c_eff = max(1, min(n_clusters, rows))
        for si in range(s):
            # ceil-partitioning can leave trailing shards with no real rows
            n_local = max(0, min(rows, self.n_total - si * rows))
            if n_local == 0:
                cent = jnp.zeros((c_eff, self.emb_shards.shape[2]))
                lists = np.full((c_eff, 8), rows, np.int32)
                mask = np.zeros((c_eff, 8), bool)
                per_cent.append(cent)
                per_lists.append(lists)
                per_mask.append(mask)
                continue
            local = self.emb_shards[si, :n_local]
            c_s = max(1, min(c_eff, n_local))
            cent, assign = _ix.kmeans(local, c_s, n_iter=n_iter, seed=seed + si)
            lists, mask = _ix.build_inverted_lists(
                np.asarray(assign), n_local, c_s
            )
            # remap local sentinel n_local -> rows (uniform across shards)
            lists = np.where(mask, lists, rows)
            if c_s < c_eff:  # pad cluster axis; extra lists are all-sentinel
                cpad = c_eff - c_s
                cent = jnp.pad(cent, ((0, cpad), (0, 0)))
                lists = np.pad(lists, ((0, cpad), (0, 0)), constant_values=rows)
                mask = np.pad(mask, ((0, cpad), (0, 0)), constant_values=False)
            per_cent.append(cent)
            per_lists.append(lists)
            per_mask.append(mask)
        pad_l = max(a.shape[1] for a in per_lists)
        per_lists = [
            np.pad(a, ((0, 0), (0, pad_l - a.shape[1])), constant_values=rows)
            for a in per_lists
        ]
        per_mask = [
            np.pad(a, ((0, 0), (0, pad_l - a.shape[1])), constant_values=False)
            for a in per_mask
        ]
        self.centroids = jnp.stack(per_cent)
        self.lists = jnp.asarray(np.stack(per_lists), jnp.int32)
        self.list_mask = jnp.asarray(np.stack(per_mask))
        self.nprobe = min(nprobe, c_eff)

    def search(self, queries: jnp.ndarray, k: int):
        """(Q, D) queries -> exact-contract (scores (Q, k), ids (Q, k))."""
        q = jnp.asarray(queries, dtype=jnp.float32)
        if q.ndim == 1:
            q = q[None]
        if self.normalized:
            q = _ix.l2_normalize(q)
        k = min(k, self.n_total)
        if self.inner == "brute":
            return _sharded_brute_search(
                self.emb_shards, q, mesh=self.mesh, k=k,
                n_total=self.n_total, rows_per_shard=self.rows_per_shard,
                use_kernel=self.use_kernel,
            )
        return _sharded_ivf_search(
            self.emb_shards, self.centroids, self.lists, self.list_mask, q,
            mesh=self.mesh, k=k, n_total=self.n_total,
            rows_per_shard=self.rows_per_shard,
            nprobe=min(self.nprobe, self.centroids.shape[1]),
        )
