"""RGL Functional API (paper §2.3.2).

Every pipeline stage as a composable, injectable function — "for advanced
scenarios, such as meta-learning or dynamic parameterization, where
developers may need to inject custom logic at various stages".  Stages share
a plain-dict context so custom stages can be spliced anywhere:

    run = compose(
        stage_embed(index),
        stage_seeds(k=4),
        stage_subgraph(graph, "steiner", max_hops=3, max_nodes=48),
        my_custom_rerank_stage,           # any ctx -> ctx callable
        stage_filter(node_emb, budget=16),
        stage_tokenize(tokenizer, node_text),
    )
    ctx = run({"query_emb": qe, "query_texts": titles})
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.core import filters, graph_retrieval, tokenization

Stage = Callable[[dict], dict]


def compose(*stages: Stage) -> Stage:
    def run(ctx: dict) -> dict:
        for s in stages:
            ctx = s(ctx)
        return ctx

    return run


def stage_embed(index, encoder=None) -> Stage:
    def fn(ctx):
        q = jnp.asarray(ctx["query_emb"])
        ctx["query_emb"] = encoder(q) if encoder is not None else q
        ctx["index"] = index
        return ctx

    return fn


def stage_seeds(k: int = 4) -> Stage:
    def fn(ctx):
        scores, seeds = ctx["index"].search(ctx["query_emb"], k)
        ctx["seed_scores"], ctx["seeds"] = scores, seeds
        return ctx

    return fn


def stage_subgraph(graph, strategy: str = "bfs", **kw) -> Stage:
    def fn(ctx):
        ctx["subgraph"] = graph_retrieval.retrieve_subgraph(
            graph, ctx["seeds"], strategy, **kw
        )
        return ctx

    return fn


def stage_filter(node_emb, budget: int) -> Stage:
    def fn(ctx):
        scores = filters.similarity_scores(node_emb, ctx["query_emb"])
        ctx["subgraph"] = filters.dynamic_filter(
            ctx["subgraph"], scores, jnp.asarray(ctx["seeds"]), budget=budget
        )
        return ctx

    return fn


def stage_tokenize(tokenizer, node_text) -> Stage:
    def fn(ctx):
        texts = tokenization.subgraph_texts(ctx["subgraph"], node_text)
        ids, mask = tokenizer.batch_linearize(ctx["query_texts"], texts)
        ctx["prompt_ids"], ctx["prompt_mask"] = ids, mask
        return ctx

    return fn


def stage_generate(generator, max_new_tokens: int = 0) -> Stage:
    def fn(ctx):
        ctx["outputs"] = generator.generate(
            ctx["prompt_ids"], ctx["prompt_mask"], max_new_tokens
        )
        return ctx

    return fn
