"""Workset-compacted candidate expansion for subgraph construction.

The dense stage-3 path does O(N) work per query — every BFS hop gathers
the full ``(Q, N, K)`` adjacency — for an O(max_nodes) result.  A
*workset* bounds that cost by the retrieved neighborhood instead: seeds
are expanded hop by hop into a fixed-capacity, per-query candidate set of
``C`` global node ids (C ≪ N), kept **sorted ascending** so that
membership tests (``kernels.frontier_expand``) and global→local id
translation are log-time searches over device arrays.

With no overflow the workset after ``max_hops`` hops is exactly the BFS
ball around the seeds, and ``dist`` holds exact hop distances (every
shortest path to a ball node stays inside the ball).  On overflow the
per-query flag is set and truncation is deterministic: entries are never
evicted, so complete hops survive whole and the overflowing hop keeps its
lowest fresh ids.

All retrieval strategies then run over the *workset-local induced
adjacency* (``workset_adjacency``): ``(Q, C, K)`` neighbor slots holding
positions into the workset, sentinel ``C`` where the neighbor is absent —
the same fixed-shape frontier algebra as the dense path, shrunk from N
rows to C.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels.frontier_expand import ops as fe_ops

INF = jnp.int32(0x3FFFFFF)


@dataclasses.dataclass
class Workset:
    """Per-query candidate set: ``ids`` sorted ascending, sentinel = n."""

    ids: jnp.ndarray  # (Q, C) int32 global node ids, sentinel n where unused
    dist: jnp.ndarray  # (Q, C) int32 hop distance from the seed set, INF pad
    overflow: jnp.ndarray  # (Q,) bool — ball exceeded capacity, truncated
    num_nodes: int  # N of the parent graph

    @property
    def cap(self) -> int:
        return int(self.ids.shape[1])


jax.tree_util.register_dataclass(
    Workset, data_fields=["ids", "dist", "overflow"], meta_fields=["num_nodes"]
)


@functools.partial(jax.jit, static_argnames=("cap",))
def _seed_workset(seeds: jnp.ndarray, n: int, cap: int):
    """(Q, S) seed ids (pad with -1 or >= n) -> initial sorted workset."""
    q = seeds.shape[0]
    ids0 = jnp.where((seeds >= 0) & (seeds < n), seeds, n).astype(jnp.int32)
    ids0 = jnp.sort(ids0, axis=1)
    first = (ids0 < n) & jnp.concatenate(
        [jnp.ones((q, 1), bool), ids0[:, 1:] != ids0[:, :-1]], axis=1
    )
    rank = jnp.cumsum(first, axis=1, dtype=jnp.int32) - 1
    ok = first & (rank < cap)
    tgt = jnp.where(ok, rank, cap)
    qi = jnp.arange(q)[:, None]
    ws_ids = jnp.full((q, cap + 1), n, jnp.int32).at[qi, tgt].set(
        jnp.where(ok, ids0, n)
    )[:, :cap]
    ws_dist = jnp.full((q, cap + 1), INF, jnp.int32).at[qi, tgt].set(
        jnp.where(ok, 0, INF)
    )[:, :cap]
    overflow = jnp.any(first & (rank >= cap), axis=1)
    return ws_ids, ws_dist, overflow


@functools.partial(jax.jit, static_argnames=("max_hops", "cap", "use_kernel"))
def build_workset(
    nbr: jnp.ndarray,  # (N, K) int32 ELL adjacency, sentinel N
    nbr_mask: jnp.ndarray,  # (N, K) bool
    seeds: jnp.ndarray,  # (Q, S) int32 (pad with -1 or >= N)
    *,
    max_hops: int,
    cap: int,
    use_kernel: bool | None = None,
) -> Workset:
    """Expand seeds into the capacity-``cap`` workset of the max_hops ball."""
    n = nbr.shape[0]
    ws_ids, ws_dist, overflow = _seed_workset(seeds, n, cap)

    def hop(carry, h):
        wi, wd, ov = carry
        wi, wd, _, dropped = fe_ops.expand_hop(
            wi, wd, nbr, nbr_mask, h + 1, band=max_hops + 2,
            use_kernel=use_kernel,
        )
        return (wi, wd, ov | dropped), None

    (ws_ids, ws_dist, overflow), _ = jax.lax.scan(
        hop, (ws_ids, ws_dist, overflow),
        jnp.arange(max_hops, dtype=jnp.int32),
    )
    return Workset(ids=ws_ids, dist=ws_dist, overflow=overflow, num_nodes=n)


@jax.jit
def localize(ws_ids: jnp.ndarray, ids: jnp.ndarray):
    """Translate global node ids to workset positions.

    ws_ids (Q, C) sorted ascending; ids (Q, S) global.  Returns
    (pos (Q, S) int32 with sentinel C where absent, found (Q, S) bool).
    """
    c = ws_ids.shape[1]
    pos = jax.vmap(jnp.searchsorted)(ws_ids, ids).astype(jnp.int32)
    hit = jnp.take_along_axis(ws_ids, jnp.minimum(pos, c - 1), axis=1)
    found = (pos < c) & (hit == ids)
    return jnp.where(found, pos, c), found


@jax.jit
def workset_adjacency(
    nbr: jnp.ndarray, nbr_mask: jnp.ndarray, ws_ids: jnp.ndarray
):
    """Induce the parent adjacency onto workset positions.

    Returns (wnbr (Q, C, K) int32 positions into ws_ids with sentinel C,
    wmask (Q, C, K) bool — True iff the edge is real AND its endpoint is a
    workset member).  ELL row/slot order is preserved, so edge (c, k) here
    is edge (ws_ids[c], k) of the parent graph — tie-break parity with the
    dense path falls out of that.
    """
    q, c = ws_ids.shape
    n, k = nbr.shape
    valid = ws_ids < n
    safe = jnp.minimum(ws_ids, n - 1)
    gn = nbr[safe]  # (Q, C, K) global neighbor ids
    gm = valid[:, :, None] & nbr_mask[safe]
    pos, found = localize(ws_ids, gn.reshape(q, c * k))
    pos = pos.reshape(q, c, k)
    ok = gm & found.reshape(q, c, k)
    return jnp.where(ok, pos, c), ok
