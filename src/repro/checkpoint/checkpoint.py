"""Sharded, atomic, async checkpointing with reshard-on-restore.

Layout per step:  <dir>/step_<N>/
    manifest.json          — step, leaf paths/shapes/dtypes, shard layout
    shard_<i>.npz          — leaf arrays, chunked so no single file > ~1 GB

Writes go to step_<N>.tmp then os.rename (atomic on POSIX) so a crash never
leaves a half checkpoint visible.  AsyncCheckpointer runs saves on a worker
thread (device_get on caller, IO off the critical path) — the standard
overlap trick.  Restore takes an optional `sharding_tree`: arrays are
device_put onto the *target* sharding, so a checkpoint written on one mesh
restores onto another (elastic resize / failure recovery).
"""
from __future__ import annotations

import json
import os
import queue
import threading
from typing import Optional

import jax
import numpy as np

_MAX_SHARD_BYTES = 1 << 30


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = []
    arrs = []
    for kp, leaf in leaves:
        parts = []
        for k in kp:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        paths.append("/".join(parts))
        arrs.append(leaf)
    return paths, arrs, jax.tree_util.tree_structure(tree)


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    paths, arrs, _ = _flatten(tree)
    host_arrs = [np.asarray(jax.device_get(a)) for a in arrs]
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    # chunk leaves into shard files
    shards, cur, cur_bytes = [], {}, 0
    for p, a in zip(paths, host_arrs):
        if cur_bytes + a.nbytes > _MAX_SHARD_BYTES and cur:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        cur[p] = a
        cur_bytes += a.nbytes
    if cur:
        shards.append(cur)
    manifest = {"step": step, "n_shards": len(shards), "leaves": {}}
    for i, shard in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{i}.npz"), **{
            p.replace("/", "__"): a for p, a in shard.items()
        })
        for p, a in shard.items():
            manifest["leaves"][p] = {
                "shard": i, "shape": list(a.shape), "dtype": str(a.dtype),
            }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        import shutil

        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like, step: Optional[int] = None,
                       sharding_tree=None):
    """Restore into the structure of `like`.  With `sharding_tree`, each leaf
    is device_put onto its target sharding (reshard-on-restore)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    cache = {}

    def load_leaf(path):
        info = manifest["leaves"][path]
        i = info["shard"]
        if i not in cache:
            cache[i] = np.load(os.path.join(d, f"shard_{i}.npz"))
        return cache[i][path.replace("/", "__")]

    paths, _, treedef = _flatten(like)
    arrs = [load_leaf(p) for p in paths]
    if sharding_tree is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            sharding_tree, is_leaf=lambda x: hasattr(x, "spec") or x is None
        )
        arrs = [
            jax.device_put(a, s) if s is not None else a
            for a, s in zip(arrs, sh_leaves)
        ]
    return jax.tree_util.tree_unflatten(treedef, arrs), manifest["step"]


class AsyncCheckpointer:
    """Background-thread saver; blocks only on a full queue (depth 2)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q = queue.Queue(maxsize=2)
        self._err = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save_checkpoint(self.ckpt_dir, step, tree)
                self._gc()
            except Exception as e:  # surfaced on next save/close
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        import shutil

        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"), True)

    def save(self, step: int, tree) -> None:
        if self._err:
            raise self._err
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        self._q.put((step, host_tree))

    def close(self) -> None:
        self._q.join()
        self._q.put(None)
        self._t.join()
        if self._err:
            raise self._err
