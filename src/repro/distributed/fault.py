"""Fault tolerance & elasticity primitives (1000+-node posture).

Single-controller JAX gives us SPMD steps; what a production fleet needs on
top — and what this module provides, with in-process simulation hooks so the
logic is *tested*, not aspirational:

* StragglerMonitor  — EMA step-time tracker; flags hosts whose step time
  exceeds `threshold x` the fleet median (mitigation: re-shard input files
  away from the slow host, or evict it and trigger elastic resize).
* Heartbeat         — liveness registry; a host missing `max_missed` beats is
  declared dead, which triggers checkpoint-restore on the surviving mesh.
* ElasticPlan       — deterministic re-assignment of data shards when the
  healthy-host set changes (consistent hashing over file shards), so a
  resize never re-reads more than the departed hosts' share.
* run_with_restart  — crash-restart driver: wraps a step function, restores
  from the newest checkpoint after a (simulated) failure, verified by tests.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0
    window: int = 16
    _times: dict = dataclasses.field(default_factory=lambda: defaultdict(deque))

    def record(self, host: int, step_time: float) -> None:
        dq = self._times[host]
        dq.append(step_time)
        if len(dq) > self.window:
            dq.popleft()

    def median_time(self) -> Optional[float]:
        means = [sum(d) / len(d) for d in self._times.values() if d]
        if not means:
            return None
        means.sort()
        return means[len(means) // 2]

    def stragglers(self) -> list:
        med = self.median_time()
        if med is None:
            return []
        return [
            h for h, d in self._times.items()
            if d and (sum(d) / len(d)) > self.threshold * med
        ]


@dataclasses.dataclass
class Heartbeat:
    max_missed: int = 3
    interval_s: float = 10.0
    _last: dict = dataclasses.field(default_factory=dict)

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self._last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> list:
        now = time.monotonic() if now is None else now
        return [
            h for h, t in self._last.items()
            if now - t > self.max_missed * self.interval_s
        ]


def elastic_shard_assignment(n_shards: int, hosts: list) -> dict:
    """Deterministic shard->host map, stable under host-set changes
    (rendezvous hashing): only shards owned by departed hosts move."""
    assign = {}
    for s in range(n_shards):
        best, best_h = None, None
        for h in hosts:
            w = hash((s, h)) & 0xFFFFFFFF
            if best is None or w > best:
                best, best_h = w, h
        assign[s] = best_h
    return assign


def run_with_restart(
    step_fn: Callable,  # (state, step) -> state ; may raise
    save_fn: Callable,  # (state, step) -> None
    restore_fn: Callable,  # () -> (state, step)
    state,
    n_steps: int,
    checkpoint_every: int = 10,
    max_restarts: int = 3,
):
    """Crash-restart training driver.  On any exception: restore from the
    newest checkpoint and continue; give up after max_restarts."""
    step = 0
    restarts = 0
    while step < n_steps:
        try:
            state = step_fn(state, step)
            step += 1
            if step % checkpoint_every == 0:
                save_fn(state, step)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            state, step = restore_fn()
    return state, restarts
