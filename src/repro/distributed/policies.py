"""Sharding policies per model family (DESIGN.md §5).

Maps parameter-pytree paths and step inputs to PartitionSpecs for the
production meshes: (16, 16) ("data", "model") single-pod and (2, 16, 16)
("pod", "data", "model") multi-pod.

LM policy (dense): 2D weight sharding — FSDP over "data" on the contracting
dim + Megatron TP over "model" on heads/d_ff; activations sharded batch x
("pod","data") and model-dim where contracted.  Weights are replicated
across pods (hierarchical DP: reduce-scatter in-pod, all-reduce cross-pod —
GSPMD derives this from the specs).

LM policy (MoE): "expert" mode shards the E axis over "model" (EP;
dispatch lowers to all-to-all) for E >= 16 (granite 32e); "tp" mode shards
each expert's d_ff over "model" (grok 8e < 16 devices).

GNN policy: edge-parallel — edge arrays over DP axes, node feature dim over
"model" (row gathers stay shard-local; feature-contracting MLPs psum).

RecSys policy: embedding tables row-sharded over "model" (lookup lowers to
all-to-all), MLP replicated, batch over DP axes.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    """Data-parallel mesh axes: ("pod","data") when a pod axis exists."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    return P(dp_axes(mesh), *([None] * extra_dims))


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def batch_axes_or_none(mesh: Mesh, batch: int):
    """DP axes if they divide the global batch, else replicate (b=1 decode)."""
    return dp_axes(mesh) if batch % dp_size(mesh) == 0 else None


# --------------------------------------------------------------------------
# LM transformer
# --------------------------------------------------------------------------
def lm_param_spec(path: str, shape, moe_mode: str = "expert") -> P:
    """path: '/'-joined param path, e.g. 'layers/wq'."""
    leaf = path.split("/")[-1]
    if leaf in ("ln1", "ln2", "ln_f"):
        return P()  # tiny
    if leaf == "embed":
        return P(None, "model")
    if leaf == "head":
        return P(None, "model")
    # MoE expert weights (L, E, D, F) / (L, E, F, D) — match BEFORE the
    # generic w1/w2/w3 rules (same leaf names, different ranks).
    if "moe" in path.split("/"):
        if leaf in ("w1", "w3"):
            return P(None, "model", "data", None) if moe_mode == "expert" else P(
                None, None, "data", "model"
            )
        if leaf == "w2":
            return P(None, "model", None, "data") if moe_mode == "expert" else P(
                None, None, "model", "data"
            )
        return P()
    if leaf in ("wq", "wk", "wv", "w1", "w3"):
        return P(None, "data", "model")  # (L, D, out)
    if leaf in ("wo", "w2"):
        return P(None, "model", "data")  # (L, in, D)
    if leaf == "router":
        return P()
    return P()


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def lm_param_specs(param_shapes, moe_mode: str = "expert"):
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: lm_param_spec(_path_str(kp), x, moe_mode), param_shapes
    )


def lm_input_specs(mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    return {
        "tokens": P(dp, None),
        "loss_mask": P(dp, None),
    }


def lm_cache_specs(mesh: Mesh, batch: int, kv_heads: int,
                   kv_shard: str = "seq") -> dict:
    """KV-cache sharding.  Baseline "seq": shard the cache length over
    "model" (flash-decoding style — works for every arch since cache_len is
    always a multiple of 16; softmax stats psum over shards).  "heads" mode
    shards KV heads instead (only when kv_heads % model_size == 0) — a
    hillclimb option for deepseek-7b (kv=32).  Batch dims replicate when the
    global batch doesn't divide the DP axes (long_500k: batch=1)."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b = dp if batch % dp_size == 0 else None
    if kv_shard == "heads":
        return {
            "k": P(None, b, None, "model", None),
            "v": P(None, b, None, "model", None),
            "pos": P(b, None),
            "cursor": P(b),
        }
    return {
        "k": P(None, b, "model", None, None),
        "v": P(None, b, "model", None, None),
        "pos": P(b, "model"),
        "cursor": P(b),
    }


# --------------------------------------------------------------------------
# GNN
# --------------------------------------------------------------------------
def gnn_param_specs(param_shapes):
    """GNN params are small (<= 50M); feature-dim shard the big MLP mats,
    replicate the rest."""

    def spec(kp, x):
        if len(x.shape) == 2 and x.shape[0] * x.shape[1] >= 1 << 20:
            return P(None, "model")
        return P()

    return jax.tree_util.tree_map_with_path(spec, param_shapes)


def gnn_input_specs(mesh: Mesh, keys) -> dict:
    dp = dp_axes(mesh)
    table = {
        "node_feat": P(None, "model"),
        "pos": P(),
        "edge_src": P(dp),
        "edge_dst": P(dp),
        "edge_mask": P(dp),
        "edge_feat": P(dp, None),
        "targets": P(),
        "node_mask": P(),
        "graph_ids": P(),
        "wigner_lut": P(),
    }
    return {k: table[k] for k in keys}


# --------------------------------------------------------------------------
# RecSys
# --------------------------------------------------------------------------
def recsys_param_specs(param_shapes):
    def spec(kp, x):
        path = _path_str(kp)
        if path.endswith("table"):
            return P("model", None)
        if path.endswith("wide"):
            return P("model")
        return P()

    return jax.tree_util.tree_map_with_path(spec, param_shapes)


def recsys_input_specs(mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    return {
        "dense": P(dp, None),
        "sparse_ids": P(dp, None, None),
        "labels": P(dp),
        "query": P(),
        "cand_emb": P("model", None),
    }


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
