"""Error-feedback gradient compression for the DP all-reduce.

Two codecs (both with residual error feedback, Karimireddy et al. 2019):

* int8 — per-tensor scale quantization: 4x all-reduce bytes reduction; the
  all-reduce itself still runs in int-summed fp (decompress-reduce), matching
  how XLA would lower a quantized psum on ICI.
* topk — keep the largest-|g| fraction per tensor (sparse sync); indices are
  dense-masked (TPU-friendly: no ragged collectives), so the win is in
  collective *bytes on the wire* when combined with sparsity-aware reduction.

Used by training.loop as an optional wrapper around the gradient tree before
the (pjit-implicit) data-parallel reduction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"  # none | int8 | topk
    topk_frac: float = 0.01


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def _int8_codec(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_codec(g, frac):
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress(grads, residuals, cfg: CompressionConfig):
    """Returns (compressed_grads, new_residuals).  Error feedback: the codec
    quantization error is carried into the next step instead of dropped."""
    if cfg.kind == "none":
        return grads, residuals

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        if cfg.kind == "int8":
            c = _int8_codec(acc)
        elif cfg.kind == "topk":
            c = _topk_codec(acc, cfg.topk_frac)
        else:
            raise ValueError(cfg.kind)
        return c.astype(g.dtype), acc - c

    out = jax.tree.map(one, grads, residuals)
    comp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return comp, res
