"""Sharding hints usable from model code without threading a mesh through.

`shard_hint(x, *axes)` applies `with_sharding_constraint` when a mesh
context is active (the dry-run / launcher path) and is a no-op otherwise
(CPU tests, single device).  Axis entries: None, a mesh axis name, or the
logical "dp" which resolves to ("pod", "data") as available.

These hints are the §Perf memory-term fixes: without them GSPMD replicated
the big per-graph / per-cache intermediates (measured: equiformer x
ogb_products at 50 TiB/device; EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _active_mesh():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def shard_hint(x, *axes):
    m = _active_mesh()
    if m is None:
        return x
    resolved = []
    for a in axes:
        if a == "dp":
            dp = tuple(ax for ax in ("pod", "data") if ax in m.axis_names)
            resolved.append(dp if dp else None)
        elif a is None or a in m.axis_names:
            resolved.append(a)
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*resolved))
    )
