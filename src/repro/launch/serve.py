"""Serving launcher: --arch <id> spins up the serving engine with the arch's
reduced config on CPU (full configs serve via the dry-run sharding on real
hardware).

Two modes:

* token mode (default) — random already-tokenized prompts through the
  slot-based ``ServeEngine`` (generation stage only).
* ``--rag`` — the fused end-to-end path: a synthetic citation graph + vector
  index feed raw (query embedding, query text) requests through
  ``RAGServeEngine`` (batched retrieval admission + retrieval cache + decode).

``--rag --replicas N`` (N > 1) serves the same stream through an N-replica
fleet behind ``ReplicaRouter``: a shared retrieval cache (fleet-wide
single-flight), health-scored circuit breakers per replica, and —
with ``--crash-replica STEP`` — a live failover demo where one replica
crashes mid-run and its in-flight requests are re-dispatched onto the
survivors.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --rag
    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --rag \
        --index sharded --shards 4
    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --rag \
        --replicas 3 --crash-replica 3
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.models.transformer import model as tm
from repro.serving import (
    FaultyReplica, FaultyRetrieval, RAGRequest, RAGServeEngine, ReplicaRouter,
    Request, RetrievalCache, ServeEngine, ServingConfig,
)


def _print_decode_stats(ds: dict) -> None:
    if ds["spec_decode"]:
        print(f"  spec decode: window={ds['draft_window']}, "
              f"{ds['tokens_per_step']:.2f} accepted tokens/step, "
              f"accept rate {ds['draft_accept_rate']:.2f} "
              f"({ds['decode_steps']} verify dispatches)")
    if ds.get("paged_kv"):
        print(f"  paged KV: block={ds['block_size']} tokens, "
              f"pool={ds['pool_blocks']} blocks, "
              f"high water {ds['pool_high_water_blocks']} blocks")
    if ds.get("prefix_share"):
        print(f"  prefix share: {ds['kv_shared_admits']} shared admits / "
              f"{ds['kv_reused_tokens']} prompt tokens reused, "
              f"{ds['kv_cow_copies']} COW tail copies, "
              f"{ds['kv_pins']} pins ({ds['kv_pinned_blocks']} blocks held, "
              f"{ds['kv_releases']} released)")
    if ds.get("truncations"):
        print(f"  truncations: {ds['truncations']} request(s) retired by KV "
              f"exhaustion before reaching max_new_tokens")


def _serve_tokens(cfg, args) -> None:
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    cache_len = cfg.sliding_window or 128
    eng = ServeEngine(params, cfg, slots=args.slots, cache_len=cache_len,
                      spec_decode=args.spec_decode,
                      draft_window=args.draft_window,
                      paged_kv=args.paged_kv, block_size=args.kv_block,
                      pool_blocks=args.pool_blocks)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for u in range(args.requests):
        eng.submit(Request(
            uid=u,
            prompt_ids=rng.integers(1, cfg.vocab, size=int(rng.integers(4, 16))
                                    ).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    done = eng.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[{args.arch}] served {len(done)} requests / {toks} tokens "
          f"in {dt:.1f}s ({toks / dt:.1f} tok/s)")
    _print_decode_stats(eng.decode_stats())


def _serve_rag(cfg, args) -> None:
    from repro.core import (
        GraphTokenizer, PipelineConfig, RGLPipeline, Vocab, index_from_config,
    )
    from repro.graph import csr_to_ell, generators

    g = generators.citation_graph(args.nodes, avg_deg=8, seed=0)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    # the arch LM decodes the graph tokenizer's vocabulary
    cfg = dataclasses.replace(cfg, vocab=vocab.size)
    tok = GraphTokenizer(vocab, max_len=96, node_budget=8)
    pcfg = PipelineConfig(strategy="bfs", k_seeds=3, max_nodes=16,
                          filter_budget=6, index_kind=args.index,
                          index_shards=args.shards,
                          retrieval_mode=args.retrieval,
                          workset_cap=args.workset_cap)
    store = None
    if args.mutate_rate > 0:
        from repro.core import MutableGraphStore
        if args.index not in MutableGraphStore.MUTABLE_INDEX_KINDS:
            raise SystemExit(
                f"--mutate-rate needs --index in "
                f"{MutableGraphStore.MUTABLE_INDEX_KINDS}, got {args.index!r}"
            )
        store = MutableGraphStore.build(g, index_kind=args.index)
        pipe = store.make_pipeline(tokenizer=tok, config=pcfg)
    else:
        index = index_from_config(emb, pcfg)
        pipe = RGLPipeline(
            graph=ell, index=index, node_emb=emb, tokenizer=tok,
            node_text=g.node_text, config=pcfg,
        )
    if args.fault_rate > 0:
        # fault-injection demo mode: a seeded fraction of retrieval rows
        # raise / stall / corrupt, exercising the retry + degradation path
        pipe = FaultyRetrieval(pipe, seed=args.fault_seed,
                               fault_rate=args.fault_rate)
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    # the linearized graph prompt (<= tokenizer max_len) plus generated
    # tokens must fit the arena; sliding_window only bounds attention reach
    cache_len = max(cfg.sliding_window or 0, 96 + args.max_new + 1)
    # one ServingConfig carries every CLI knob (CLI flag > RGL_* env >
    # default — the same precedence rule as the engine's kwargs)
    serve_cfg = ServingConfig.resolve(
        None,
        slots=args.slots, cache_len=cache_len,
        cache_policy=args.cache_policy,
        cache_ttl=args.cache_ttl,
        prefetch=args.prefetch,
        prefetch_depth=args.prefetch_depth,
        admission=args.admission,
        spec_decode=args.spec_decode,
        draft_window=args.draft_window,
        paged_kv=args.paged_kv,
        kv_block_size=args.kv_block,
        kv_pool_blocks=args.pool_blocks,
        prefix_share=args.prefix_share,
        retrieval_timeout_s=args.retrieval_timeout,
        max_retries=args.retries,
        retry_backoff_s=args.retry_backoff,
        degraded_mode=args.degraded,
        compact_every=args.compact_every,
    )
    if args.replicas > 1:
        return _serve_rag_fleet(pipe, g, emb, params, cfg, serve_cfg, args)
    eng = RAGServeEngine(pipe, params, cfg,
                         config=serve_cfg,
                         max_pending=args.max_pending,
                         shed_policy=args.shed_policy,
                         default_deadline_s=args.deadline)
    rng = np.random.default_rng(0)
    q_ids = rng.choice(args.nodes, size=args.requests, replace=True)
    emb_np = np.asarray(emb)
    t0 = time.time()
    for u, qi in enumerate(q_ids):
        eng.submit(RAGRequest(
            uid=u, query_emb=emb_np[qi],
            query_text=" ".join(g.node_text[qi].split()[:4]),
            max_new_tokens=args.max_new,
        ))
    if store is not None:
        done = _drain_with_mutations(eng, store, args)
    else:
        # drain() never raises: under fault injection (or tight deadlines)
        # the stragglers are aborted and reported instead of crashing the
        # launcher
        done = eng.drain()
    dt = time.time() - t0
    ok = [r for r in done if r.done and not r.failed]
    toks = sum(len(r.out_tokens) for r in ok)
    s = eng.stats()
    print(f"[{args.arch}] RAG-served {len(ok)}/{len(done)} requests / "
          f"{toks} tokens in {dt:.1f}s ({toks / dt:.1f} tok/s); "
          f"{s['retrieval_batches']} retrieval batches, "
          f"cache {s['hits']}/{s['hits'] + s['misses']} hits")
    ft = (s["retries"], s["timeouts"], s["failed"], s["shed"],
          s["degraded"], s["stale_served"])
    if any(ft) or args.fault_rate > 0:
        print(f"  fault tolerance: {s['retries']} retries, "
              f"{s['timeouts']} timeouts, {s['failed']} failed, "
              f"{s['shed']} shed, {s['degraded']} degraded-served, "
              f"{s['stale_served']} stale-served")
    if s["prefetch"]:
        print(f"  prefetch: {s['prefetch_waves']} waves, "
              f"{s['overlap_seconds'] * 1e3:.1f}ms overlapped "
              f"({s['overlap_steps']} decode steps / "
              f"{s['overlap_tokens']} accepted tokens), "
              f"hidden_frac={s['hidden_frac']:.2f}")
    if s.get("mutation_batches"):
        print(f"  mutation: {s['mutation_batches']} batches "
              f"(epoch {s['mutation_epoch']}, "
              f"{s['mutation_compactions']} compactions, "
              f"{s['mutation_invalidated']} cache entries invalidated, "
              f"{s['stale_rejects']} stale puts rejected)")
    _print_decode_stats(s)


def _drain_with_mutations(eng, store, args, max_steps: int = 10_000) -> list:
    """Serve to completion while a seeded writer mutates the live corpus:
    each engine step, with probability ``--mutate-rate``, one mutation batch
    (an edge insert, an edge delete, or a node add) lands between steps via
    ``apply_mutations`` — the read/write-mix the online-mutation tier
    exists for."""
    from repro.core import MutationBatch

    rng = np.random.default_rng(args.fault_seed + 1)
    done = []
    for _ in range(max_steps):
        done.extend(eng.step())
        if eng._drained():
            return done
        if rng.random() >= args.mutate_rate:
            continue
        kind = rng.random()
        n = store.n_nodes
        if kind < 0.45:  # insert an edge
            batch = MutationBatch(add_edges=np.array(
                [[rng.integers(0, n), rng.integers(0, n)]]))
        elif kind < 0.9:  # delete an edge (no-op if it does not exist)
            batch = MutationBatch(del_edges=np.array(
                [[rng.integers(0, n), rng.integers(0, n)]]))
        else:  # add a node wired to two random anchors
            feat = rng.normal(size=(1, store.h_feat.shape[1] if store.active
                                    else store.node_emb.shape[1]))
            batch = MutationBatch(
                add_node_feat=feat.astype(np.float32),
                add_node_text=[f"live node {n}"],
                add_edges=np.array([[n, rng.integers(0, n)],
                                    [n, rng.integers(0, n)]]),
            )
        eng.apply_mutations(batch)
    done.extend(eng.abort(reason=f"drain gave up after {max_steps} steps"))
    return done


def _serve_rag_fleet(pipe, g, emb, params, cfg, serve_cfg, args) -> None:
    # shed/deadline knobs move to the router's front door: the router pins
    # the absolute deadline at submit and sheds on queue overflow, so the
    # per-replica engines run unbounded underneath it
    cache = RetrievalCache(capacity=256 * args.replicas,
                           policy=args.cache_policy, ttl=args.cache_ttl)
    engines = [
        RAGServeEngine(pipe, params, cfg, retrieval_cache=cache,
                       config=serve_cfg)
        for _ in range(args.replicas)
    ]
    if args.crash_replica is not None:
        engines[-1] = FaultyReplica(engines[-1], mode="crash",
                                    crash_step=args.crash_replica)
    router = ReplicaRouter(engines,
                           failover=args.failover,
                           max_pending=args.max_pending or 0,
                           shed_policy=args.shed_policy or "reject",
                           replica_depth=args.router_depth,
                           health_window=args.router_window,
                           trip_threshold=args.router_trip,
                           cooldown_steps=args.router_cooldown,
                           default_deadline_s=args.deadline)
    rng = np.random.default_rng(0)
    q_ids = rng.choice(args.nodes, size=args.requests, replace=True)
    emb_np = np.asarray(emb)
    t0 = time.time()
    for u, qi in enumerate(q_ids):
        router.submit(RAGRequest(
            uid=u, query_emb=emb_np[qi],
            query_text=" ".join(g.node_text[qi].split()[:4]),
            max_new_tokens=args.max_new,
        ))
    done = router.drain()
    dt = time.time() - t0
    ok = [r for r in done if r.done and not r.failed]
    toks = sum(len(r.out_tokens) for r in ok)
    s = router.stats()
    cs = cache.stats()
    print(f"[{args.arch}] fleet of {args.replicas} replicas RAG-served "
          f"{len(ok)}/{len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s)")
    print(f"  router: {s['submitted']} submitted, "
          f"{s['front_door_shed']} shed, "
          f"{s['failovers']} failover(s), {s['redispatched']} re-dispatched, "
          f"{s['stranded']} stranded")
    print(f"  shared cache: {cs['hits']}/{cs['hits'] + cs['misses']} hits, "
          f"{cs['stale_hits']} stale hits, {cs['size']} entries")
    for pr in s["per_replica"]:
        line = (f"  {pr['name']}: circuit={pr['circuit']}, "
                f"dispatched={pr['dispatched']}, "
                f"delivered={pr['delivered']}, "
                f"crashes={pr['crashes']}, trips={pr['trips']}")
        h = pr["health"]  # None for a crashed replica (health unreadable)
        if h is not None:
            line += (f"; retries={h['retries']}, timeouts={h['timeouts']}, "
                     f"failed={h['failed']}, degraded={h['degraded']}")
        print(line)


def main():
    ap = argparse.ArgumentParser()
    lm_archs = [a for a in C.ARCH_IDS if C.get_config(a).family == "lm"]
    ap.add_argument("--arch", required=True, choices=lm_archs)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max_new", type=int, default=12)
    ap.add_argument("--rag", action="store_true",
                    help="serve end-to-end through the fused RAG engine")
    ap.add_argument("--nodes", type=int, default=1000,
                    help="synthetic graph size for --rag")
    ap.add_argument("--index", default="brute",
                    choices=["brute", "ivf", "sharded", "sharded_ivf"],
                    help="stage-1 vector index backend for --rag")
    ap.add_argument("--shards", type=int, default=None,
                    help="shard count for sharded index kinds "
                         "(default: one per device)")
    ap.add_argument("--retrieval", default="auto",
                    choices=["dense", "compact", "auto"],
                    help="stage-3 subgraph construction backend for --rag")
    ap.add_argument("--workset-cap", type=int, default=2048,
                    help="compact backend candidate capacity per query")
    ap.add_argument("--cache-policy", default="lru",
                    choices=["lru", "lfu", "ttl"],
                    help="retrieval-cache eviction policy for --rag")
    ap.add_argument("--cache-ttl", type=float, default=None,
                    help="retrieval-cache entry expiry in seconds")
    ap.add_argument("--prefetch", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="double-buffered async admission: overlap the next "
                         "wave's retrieval with the current decode steps "
                         "(--no-prefetch forces sync; default honors "
                         "RGL_PREFETCH)")
    ap.add_argument("--prefetch-depth", type=int, default=None,
                    help="max launched-but-uncollected admission waves "
                         "(default: slots when --admission continuous, "
                         "else 1)")
    ap.add_argument("--admission", default=None,
                    choices=["wave", "continuous"],
                    help="admission granularity for --rag: whole waves, or "
                         "per-request launch/collect so a slow retrieval "
                         "row only delays its own request (default honors "
                         "RGL_ADMISSION, 'wave')")
    ap.add_argument("--paged-kv", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="paged KV pool: block-table indirection over "
                         "fixed-size blocks; slots return blocks the step "
                         "they retire (--no-paged-kv forces the contiguous "
                         "arena; default honors RGL_PAGED_KV)")
    ap.add_argument("--prefix-share", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="prefix-shared paged KV: pin hot retrieval-cache "
                         "entries' prefilled prompt blocks and alias them "
                         "into later identical prompts (refcounted "
                         "copy-on-write; needs --paged-kv; default honors "
                         "RGL_PREFIX_SHARE)")
    ap.add_argument("--kv-block", type=int, default=None,
                    help="tokens per KV block (must divide cache_len; "
                         "default: largest divisor <= 16, or RGL_KV_BLOCK)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="total blocks in the shared KV pool (default "
                         "slots*cache_len/block — full capacity; smaller "
                         "values save memory and may truncate long "
                         "generations under pressure)")
    ap.add_argument("--spec-decode", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="self-speculative multi-token decode: verify a "
                         "window of prompt-lookup drafts per jitted step "
                         "(--no-spec-decode forces one-token decode; "
                         "default honors RGL_SPEC_DECODE)")
    ap.add_argument("--draft-window", type=int, default=None,
                    help="fed tokens per speculative step (1 committed + "
                         "W-1 drafts; default honors RGL_DRAFT_WINDOW, 4)")
    ap.add_argument("--retrieval-timeout", type=float, default=None,
                    help="seconds before an unready retrieval wave is "
                         "declared timed out (default honors "
                         "RGL_RETRIEVAL_TIMEOUT; unset = wait forever)")
    ap.add_argument("--retries", type=int, default=None,
                    help="retry budget for a failed retrieval miss-group "
                         "(size-1 isolated relaunches; default honors "
                         "RGL_RETRIES, 0)")
    ap.add_argument("--retry-backoff", type=float, default=None,
                    help="base seconds for exponential retry backoff "
                         "(default honors RGL_RETRY_BACKOFF, 0)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds from submit; "
                         "expired requests are shed, never dispatched "
                         "(default honors RGL_DEADLINE; unset = none)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="pending-queue bound; overflow triggers "
                         "--shed-policy (default honors RGL_MAX_PENDING, "
                         "0 = unbounded)")
    ap.add_argument("--shed-policy", default=None,
                    choices=["reject", "evict-oldest"],
                    help="overflow victim: reject the new request or evict "
                         "the oldest pending one (default honors "
                         "RGL_SHED_POLICY, 'reject')")
    ap.add_argument("--degraded", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="retrieval-free (query-only) decode when retries "
                         "and the stale cache are exhausted (--no-degraded "
                         "fails such requests; default honors RGL_DEGRADED, "
                         "on)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve --rag through N engine replicas behind the "
                         "health-aware ReplicaRouter with a shared "
                         "retrieval cache (1 = single engine, no router)")
    ap.add_argument("--failover", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="re-dispatch a crashed replica's in-flight "
                         "requests onto survivors (--no-failover strands "
                         "them failed — the naive baseline)")
    ap.add_argument("--crash-replica", type=int, default=None,
                    metavar="STEP",
                    help="failover demo: the last replica crashes after "
                         "STEP engine steps")
    ap.add_argument("--router-depth", type=int, default=None,
                    help="max assigned requests per replica before the "
                         "router stops routing to it (default 2x slots)")
    ap.add_argument("--router-window", type=int, default=8,
                    help="router health window: fault-counter deltas from "
                         "the last N delivery rounds feed the circuit "
                         "breaker")
    ap.add_argument("--router-trip", type=int, default=3,
                    help="fault-delta sum over the window that trips a "
                         "replica's circuit open")
    ap.add_argument("--router-cooldown", type=int, default=8,
                    help="router steps an open circuit waits before "
                         "half-open probing (also the crashed-replica "
                         "revival probe interval)")
    ap.add_argument("--mutate-rate", type=float, default=0.0,
                    help="online mutation demo: per-step probability that "
                         "one mutation batch (edge insert/delete or node "
                         "add) lands between decode steps while serving "
                         "(needs --rag and a mutable --index; 0 = frozen "
                         "corpus)")
    ap.add_argument("--compact-every", type=int, default=None,
                    help="fold the mutation delta into a fresh base every "
                         "N applied batches (default honors "
                         "RGL_COMPACT_EVERY, 0 = manual)")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="inject seeded retrieval faults on this fraction "
                         "of query rows (demo/bench mode; 0 = off)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the per-row fault schedule")
    args = ap.parse_args()

    cfg = C.get_config(args.arch).reduced_cfg
    if args.rag:
        _serve_rag(cfg, args)
    else:
        _serve_tokens(cfg, args)


if __name__ == "__main__":
    main()
