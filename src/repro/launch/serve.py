"""Serving launcher: --arch <id> spins up the slot-based engine with the
arch's reduced config on CPU (full configs serve via the dry-run sharding
on real hardware).

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs as C
from repro.models.transformer import model as tm
from repro.serving import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    lm_archs = [a for a in C.ARCH_IDS if C.get_config(a).family == "lm"]
    ap.add_argument("--arch", required=True, choices=lm_archs)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max_new", type=int, default=12)
    args = ap.parse_args()

    cfg = C.get_config(args.arch).reduced_cfg
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    cache_len = cfg.sliding_window or 128
    eng = ServeEngine(params, cfg, slots=args.slots, cache_len=cache_len)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for u in range(args.requests):
        eng.submit(Request(
            uid=u,
            prompt_ids=rng.integers(1, cfg.vocab, size=int(rng.integers(4, 16))
                                    ).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    done = eng.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[{args.arch}] served {len(done)} requests / {toks} tokens "
          f"in {dt:.1f}s ({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
