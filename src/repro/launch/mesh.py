"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
while smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
