"""Training launcher: --arch <id> resolves a registry config and trains.

On real TPU fleets this runs under the production mesh with the family
sharding policy; on this container it runs the REDUCED config on CPU
(full configs are exercised via dryrun.py).  Includes the XLA flags a
v5e deployment would set for collective/compute overlap.

    PYTHONPATH=src python -m repro.launch.train --arch gin-tu --steps 20
"""
from __future__ import annotations

import os

# Latency-hiding scheduler: overlap collectives with compute on TPU.
_TPU_XLA_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
)
if os.environ.get("REPRO_TPU") == "1":  # pragma: no cover - hardware only
    os.environ["XLA_FLAGS"] = _TPU_XLA_FLAGS + os.environ.get("XLA_FLAGS", "")

import argparse  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs as C  # noqa: E402
from repro.training import AdamWConfig, TrainLoop, make_train_step  # noqa: E402


def _lm_data(cfg, batch, seq, seed=0):
    rng = np.random.default_rng(seed)
    while True:
        toks = rng.integers(0, cfg.vocab, size=(batch, seq), dtype=np.int32)
        yield {"tokens": jnp.asarray(toks),
               "loss_mask": jnp.ones((batch, seq), bool)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=C.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    spec = C.get_config(args.arch)
    cfg = spec.reduced_cfg
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=args.steps)

    if spec.family == "lm":
        from repro.models.transformer import model as tm

        params = tm.init_params(jax.random.PRNGKey(0), cfg)

        def loss_fn(p, b):
            return tm.lm_loss(p, b["tokens"], b["loss_mask"], cfg)

        data = _lm_data(cfg, args.batch, args.seq)
    elif spec.family == "gnn":
        from repro.graph import generators
        from repro.models.gnn import gnn_loss, init_gnn
        from repro.models.gnn.wigner import build_wigner_lut

        g = generators.citation_graph(200, avg_deg=5, d_feat=cfg.d_in, seed=0)
        src, dst = g.edge_list()
        inputs = {
            "node_feat": jnp.asarray(g.node_feat),
            "edge_src": jnp.asarray(src), "edge_dst": jnp.asarray(dst),
            "edge_mask": jnp.ones(len(src), bool),
            "targets": jnp.zeros((200, cfg.d_out)),
        }
        if cfg.arch == "equiformer_v2":
            inputs["pos"] = jnp.asarray(
                np.random.default_rng(0).standard_normal((200, 3)), jnp.float32
            )
            inputs["wigner_lut"] = jnp.asarray(
                build_wigner_lut(cfg.l_max, n_theta=8, n_phi=16, n_samples=128)
            )
        params = init_gnn(jax.random.PRNGKey(0), cfg)

        def loss_fn(p, b):
            return gnn_loss(p, cfg, b), {}

        def _gen():
            while True:
                yield inputs

        data = _gen()
    else:  # recsys
        from repro.models.recsys import wide_deep as wdm

        params = wdm.init_wide_deep(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)

        def _gen():
            while True:
                b = args.batch * 8
                ids = rng.integers(0, cfg.rows_per_field,
                                   (b, cfg.n_sparse, cfg.bag_size))
                ids += np.arange(cfg.n_sparse)[None, :, None] * cfg.rows_per_field
                yield {
                    "dense": jnp.asarray(
                        rng.standard_normal((b, cfg.n_dense)), jnp.float32),
                    "sparse_ids": jnp.asarray(ids, jnp.int32),
                    "labels": jnp.asarray(rng.integers(0, 2, b), jnp.float32),
                }

        def loss_fn(p, b):
            return wdm.wide_deep_loss(
                p, cfg, b["dense"], b["sparse_ids"], b["labels"]), {}

        data = _gen()

    init_state, step = make_train_step(loss_fn, opt)
    loop = TrainLoop(step_fn=jax.jit(step), data_iter=data, log_every=5)
    state, history = loop.run(init_state(params), args.steps)
    print(f"[{args.arch}] done: " + (
        f"loss {history[0][1]:.4f} -> {history[-1][1]:.4f}" if history else "ok"))


if __name__ == "__main__":
    main()
