"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the real step
function (train / prefill / decode / infer / retrieval) with the family
sharding policy, compiles, and records memory_analysis + cost_analysis +
collective bytes parsed from the post-SPMD HLO.

FLOPs/collective accounting: XLA's HloCostAnalysis visits while-loop bodies
ONCE (verified on this container), and our layers run under lax.scan.  So
each cell is compiled three times: the full config (true per-device memory)
plus L=1 and L=2 analysis variants with single-tile attention/loss/edge
chunking, from which per-layer FLOPs/bytes/collective increments are fit
linearly and extrapolated to the real depth:  X(L) = a + b*L.

Usage:
  python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out reports/dryrun]
"""
# The VERY FIRST lines, before ANY other import (jax locks device count on
# first init):
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs as C  # noqa: E402
from repro.distributed import policies as pol  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.gnn import gnn_loss, init_gnn  # noqa: E402
from repro.models.recsys import wide_deep  # noqa: E402
from repro.models.transformer import model as tm  # noqa: E402
from repro.training.loop import make_train_step  # noqa: E402
from repro.training.optimizer import AdamWConfig  # noqa: E402

_COLL_RE = re.compile(
    r"=\s*(\w+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective kind (per-device program => local
    shapes; all-reduce counted 2x for its reduce-scatter+all-gather phases)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = _DTYPE_BYTES[dtype]
        for d in dims.split(","):
            if d:
                n *= int(d)
        mult = 2 if kind == "all-reduce" else 1
        out[kind] = out.get(kind, 0) + n * mult
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def _opt_cfg(spec) -> AdamWConfig:
    big = spec.family == "lm" and spec.model_cfg.param_count()[0] > 50e9
    return AdamWConfig(state_dtype="bfloat16" if big else "float32")


# ---------------------------------------------------------------------------
# per-family step builders: return (fn, example_args, in_shardings)
# ---------------------------------------------------------------------------
def build_lm(spec, shape, mesh, cfg, *, n_micro: int | None = None):
    from repro.configs.common import lm_inputs

    inputs = lm_inputs(shape, cfg)
    pspecs_fn = lambda tree: pol.lm_param_specs(
        tree, moe_mode=cfg.moe.shard_mode if cfg.moe else "expert"
    )
    dp = pol.dp_axes(mesh)
    params_shape = jax.eval_shape(lambda k: tm.init_params(k, cfg), jax.random.PRNGKey(0))

    def shard(tree, specs):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P),
        )

    if shape.kind == "train":
        opt_cfg = _opt_cfg(spec)

        def loss_fn(params, batch):
            return tm.lm_loss(params, batch["tokens"], batch["loss_mask"], cfg)

        # 8 microbatches: per-(device, microbatch) = 2 sequences — the knob
        # that brought train_4k from 59 GiB to <16 GiB/chip (§Perf iter 2).
        # Analysis variants pass n_micro=1 (FLOPs are microbatch-invariant,
        # and the micro-scan body would be counted once by HloCostAnalysis).
        if n_micro is None:
            n_micro = int(os.environ.get("REPRO_N_MICRO", "8"))
        init_state, step = make_train_step(loss_fn, opt_cfg, n_microbatches=n_micro)
        state_shape = jax.eval_shape(init_state, params_shape)
        psp = pspecs_fn(params_shape)
        state_specs = {
            "params": psp,
            "opt": {"m": psp, "v": psp, "step": P()},
        }
        batch_specs = {"tokens": P(dp, None), "loss_mask": P(dp, None)}
        args = (state_shape, inputs)
        shardings = (shard(None, state_specs), shard(None, batch_specs))
        return step, args, shardings, 0  # donate state

    if shape.kind == "prefill":
        cache_len = shape.params["seq_len"]
        b = pol.batch_axes_or_none(mesh, shape.params["global_batch"])

        def fn(params, tokens, true_len):
            return tm.prefill(params, tokens, true_len, cfg, cache_len)

        psp = pspecs_fn(params_shape)
        args = (params_shape, inputs["tokens"], inputs["true_len"])
        shardings = (
            shard(None, psp),
            NamedSharding(mesh, P(b, None)),
            NamedSharding(mesh, P(b)),
        )
        return fn, args, shardings, None

    # decode / long_decode
    quant = cfg.kv_quant

    def fn(params, ck, cv, cpos, cursor, token, ks=None, vs=None):
        cache = tm.KVCache(k=ck, v=cv, pos=cpos, cursor=cursor,
                           k_scale=ks, v_scale=vs)
        nxt, new_cache = tm.decode_step(params, cache, token, cfg)
        return jnp.argmax(nxt, -1).astype(jnp.int32), new_cache

    psp = pspecs_fn(params_shape)
    batch = shape.params["global_batch"]
    cs = pol.lm_cache_specs(
        mesh, batch, cfg.n_kv_heads,
        kv_shard=os.environ.get("REPRO_KV_SHARD", "seq"),
    )
    b = pol.batch_axes_or_none(mesh, batch)
    args = [
        params_shape, inputs["cache_k"], inputs["cache_v"],
        inputs["cache_pos"], inputs["cursor"], inputs["token"],
    ]
    scale_spec = NamedSharding(mesh, P(*cs["k"][:-1]))
    shardings = [
        shard(None, psp),
        NamedSharding(mesh, cs["k"]), NamedSharding(mesh, cs["v"]),
        NamedSharding(mesh, cs["pos"]), NamedSharding(mesh, cs["cursor"]),
        NamedSharding(mesh, P(b)),
    ]
    donate = (1, 2)
    if quant:
        args += [inputs["k_scale"], inputs["v_scale"]]
        shardings += [scale_spec, scale_spec]
        donate = (1, 2, 6, 7)
    return fn, tuple(args), tuple(shardings), donate


def build_gnn(spec, shape, mesh, cfg, *, edge_chunk=16384):
    from repro.configs.common import gnn_inputs

    inputs = gnn_inputs(shape, cfg)
    params_shape = jax.eval_shape(lambda k: init_gnn(k, cfg), jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig()

    def loss_fn(params, batch):
        if cfg.arch == "equiformer_v2":
            from repro.models.gnn.equiformer import apply_equiformer

            out = apply_equiformer(params, cfg, batch, edge_chunk=edge_chunk)
            tgt = batch["targets"]
            if cfg.graph_readout and "graph_ids" in batch:
                out = jax.ops.segment_sum(
                    out, batch["graph_ids"], num_segments=tgt.shape[0]
                )
            loss = jnp.mean((out - tgt) ** 2)
        else:
            loss = gnn_loss(params, cfg, batch)
        return loss, {}

    init_state, step = make_train_step(loss_fn, opt_cfg)
    state_shape = jax.eval_shape(init_state, params_shape)
    psp = pol.gnn_param_specs(params_shape)
    state_specs = {"params": psp, "opt": {"m": psp, "v": psp, "step": P()}}
    in_specs = pol.gnn_input_specs(mesh, inputs.keys())
    mk = lambda specs: jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
    return step, (state_shape, inputs), (mk(state_specs), mk(in_specs)), 0


def build_recsys(spec, shape, mesh, cfg):
    from repro.configs.common import recsys_inputs

    inputs = recsys_inputs(shape, cfg)
    mk = lambda specs: jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
    rs = pol.recsys_input_specs(mesh)
    if shape.kind == "retrieval":
        def fn(query, cand):
            from repro.kernels.topk_sim import ref as topk_ref

            return topk_ref.topk_similarity(query, cand, shape.params["k"])

        args = (inputs["query"], inputs["cand_emb"])
        shardings = (
            NamedSharding(mesh, rs["query"]), NamedSharding(mesh, rs["cand_emb"]),
        )
        return fn, args, shardings, None

    params_shape = jax.eval_shape(
        lambda k: wide_deep.init_wide_deep(k, cfg), jax.random.PRNGKey(0)
    )
    psp = pol.recsys_param_specs(params_shape)
    if shape.kind == "train":
        def loss_fn(params, batch):
            return (
                wide_deep.wide_deep_loss(
                    params, cfg, batch["dense"], batch["sparse_ids"], batch["labels"]
                ),
                {},
            )

        init_state, step = make_train_step(loss_fn, AdamWConfig())
        state_shape = jax.eval_shape(init_state, params_shape)
        state_specs = {"params": psp, "opt": {"m": psp, "v": psp, "step": P()}}
        b_specs = {k: rs[k] for k in ("dense", "sparse_ids", "labels")}
        return (
            step, (state_shape, inputs), (mk(state_specs), mk(b_specs)), 0,
        )

    def fn(params, dense, sparse_ids):
        return wide_deep.wide_deep_logits(params, cfg, dense, sparse_ids)

    args = (params_shape, inputs["dense"], inputs["sparse_ids"])
    shardings = (
        mk(psp), NamedSharding(mesh, rs["dense"]), NamedSharding(mesh, rs["sparse_ids"]),
    )
    return fn, args, shardings, None


# ---------------------------------------------------------------------------
def _analysis_cfg(spec, shape, n_layers):
    """Config variant for the linear-in-L FLOPs fit: layers UNROLLED (a
    scanned body is counted once by HloCostAnalysis) and single-tile
    attention/loss chunking so inner scan trip counts don't hide work."""
    cfg = C.effective_model_cfg(spec, shape)
    if spec.family == "lm":
        s = shape.params.get("seq_len", 4096)
        return dataclasses.replace(
            cfg, n_layers=n_layers, q_chunk=max(s, 256), kv_chunk=max(s, 256),
            loss_chunk=max(s - 1, 1), remat=False, scan_layers=False,
        )
    if spec.family == "gnn":
        return dataclasses.replace(cfg, n_layers=n_layers)
    return cfg


def _cost_dict(compiled) -> dict:
    """Normalize Compiled.cost_analysis() across jax versions: newer releases
    return a list of per-computation dicts, older ones a single dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _compile_cell(spec, shape, mesh, cfg, *, edge_chunk=16384, n_micro=None):
    builder = {"lm": build_lm, "gnn": build_gnn, "recsys": build_recsys}[spec.family]
    kw = {}
    if spec.family == "gnn":
        kw["edge_chunk"] = edge_chunk
    if spec.family == "lm":
        kw["n_micro"] = n_micro
    fn, args, shardings, donate = builder(spec, shape, mesh, cfg, **kw)
    jit_kw = {"in_shardings": shardings}
    if donate == 0:
        jit_kw["donate_argnums"] = (0,)
    elif isinstance(donate, tuple):
        jit_kw["donate_argnums"] = donate
    with mesh:
        lowered = jax.jit(fn, **jit_kw).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             skip_analysis: bool = False, edge_chunk: int = 16384) -> dict:
    spec = C.get_config(arch_id)
    shape = spec.shapes[shape_name]
    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "kind": shape.kind,
    }
    if shape.kind == "skip":
        rec["status"] = "skip"
        rec["reason"] = shape.params["reason"]
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 512 if multi_pod else 256
    t0 = time.time()

    # --- full-config compile: true memory + collective schedule -------------
    cfg_full = C.effective_model_cfg(spec, shape)
    if os.environ.get("REPRO_KV_QUANT") == "1" and spec.family == "lm":
        cfg_full = dataclasses.replace(cfg_full, kv_quant=True)
    lowered, compiled = _compile_cell(spec, shape, mesh, cfg_full,
                                      edge_chunk=edge_chunk)
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "per_device_total": int(
            ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes
        ),
    }
    ca = _cost_dict(compiled)
    rec["cost_full_program"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }
    rec["collectives_full_program"] = collective_bytes(compiled.as_text())
    rec["compile_s_full"] = round(time.time() - t0, 1)

    # --- 2-point depth fit for scan-hidden work ------------------------------
    if not skip_analysis and spec.family in ("lm", "gnn"):
        pts = {}
        from repro.configs.common import padded_edges

        for L in (1, 2):
            cfg_l = _analysis_cfg(spec, shape, L)
            _, comp_l = _compile_cell(
                spec, shape, mesh, cfg_l,
                edge_chunk=padded_edges(shape) if spec.family == "gnn" else 16384,
                n_micro=1,
            )
            ca_l = _cost_dict(comp_l)
            pts[L] = {
                "flops": float(ca_l.get("flops", 0.0)),
                "bytes": float(ca_l.get("bytes accessed", 0.0)),
                "coll": collective_bytes(comp_l.as_text())["total"],
            }
        L_full = cfg_full.n_layers
        fit = {}
        for key in ("flops", "bytes", "coll"):
            b = pts[2][key] - pts[1][key]
            a = pts[1][key] - b
            fit[key] = a + b * L_full
        rec["fit_per_device"] = {
            "flops": fit["flops"], "hbm_bytes": fit["bytes"],
            "collective_bytes": fit["coll"],
            "points": pts, "n_layers": L_full,
        }
    elif spec.family == "recsys":
        rec["fit_per_device"] = {
            "flops": rec["cost_full_program"]["flops"],
            "hbm_bytes": rec["cost_full_program"]["bytes"],
            "collective_bytes": rec["collectives_full_program"]["total"],
        }
    rec["n_devices"] = n_dev
    rec["status"] = "ok"
    rec["compile_s_total"] = round(time.time() - t0, 1)
    return rec


def all_cells():
    for arch_id in C.ARCH_IDS:
        spec = C.get_config(arch_id)
        for shape_name in spec.shapes:
            yield arch_id, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-analysis", action="store_true",
                    help="full-config compile only (no 2-point FLOPs fit)")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    for arch_id, shape_name in cells:
        for mp in meshes:
            tag = f"{arch_id}__{shape_name}__{'mp' if mp else 'sp'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[cached] {tag}")
                continue
            print(f"[run] {tag}", flush=True)
            try:
                rec = run_cell(
                    arch_id, shape_name, multi_pod=mp,
                    skip_analysis=args.skip_analysis or mp,
                )
            except Exception as e:  # record failures: they are bugs to fix
                rec = {
                    "arch": arch_id, "shape": shape_name,
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                }
                print(f"  ERROR {rec['error'][:300]}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec.get("status") == "ok":
                mem = rec["memory"]["per_device_total"] / 2**30
                print(f"  ok mem/dev={mem:.2f} GiB "
                      f"compile={rec['compile_s_total']}s", flush=True)


if __name__ == "__main__":
    main()
