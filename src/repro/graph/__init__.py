"""Graph substrate: static-shape graph containers and host-side tooling.

Device-side code (retrieval, GNNs) consumes :class:`ELLGraph` — a padded
neighbor-list format with a sentinel row so gathers stay in-bounds.  Host-side
code (samplers, generators, converters) goes through :class:`CSRGraph`.
"""
from repro.graph.csr import CSRGraph
from repro.graph.ell import ELLGraph, csr_to_ell
from repro.graph.delta import CapacityOverflow, DeltaGraph, SlackOverflow
from repro.graph.batch import batch_graphs
from repro.graph.sampler import NeighborSampler
from repro.graph import generators

__all__ = [
    "CSRGraph",
    "ELLGraph",
    "csr_to_ell",
    "DeltaGraph",
    "SlackOverflow",
    "CapacityOverflow",
    "batch_graphs",
    "NeighborSampler",
    "generators",
]
