"""Delta tier over a frozen ELL graph: streaming edge/node mutation.

The frozen formats (:class:`~repro.graph.csr.CSRGraph` on host,
:class:`~repro.graph.ell.ELLGraph` on device) are compact-for-scan but
immutable.  :class:`DeltaGraph` adds the mutable-for-ingest half of the
graph_accel split (SNIPPETS.md): a **base** ELL block frozen at the last
compaction, plus

* per-node **append slack** — ``extra_deg`` spare neighbor slots per row
  for edges added since the last compaction,
* a **kill bitmap** over base slots — deleting a base edge masks its slot
  instead of restructuring the row,
* a **tombstone bitmap** over nodes — deleting a node masks the node and
  every edge into it at fold time (its storage is reclaimed at
  compaction; node ids are never reused, so caches/tokenized prompts
  referencing old ids stay coherent).

All mirrors are host numpy (mutation is a host-side, serving-loop-rate
event); :meth:`merged` folds them into one device ``ELLGraph`` through a
single jitted concat+mask (shapes fixed at ``(capacity, K + extra_deg)``,
so every mutation epoch reuses the same trace).  Readers —
``workset.build_workset``, dense BFS, every subgraph strategy — consume
the merged view unchanged: it is just an ``ELLGraph`` whose ``num_nodes``
is the capacity and whose sentinel is ``capacity``.

Mutations are *functional* at the device level: a fold builds **new**
arrays and never writes into ones a dispatched retrieval may still be
reading, so an in-flight async retrieval always completes against the
snapshot it was launched on (the race-freedom contract
``RAGServeEngine.apply_mutations`` relies on).

Compaction is not done here — :class:`repro.core.mutation.MutableGraphStore`
rebuilds a canonical base from :meth:`live_edge_list` so the result is
bitwise identical to a from-scratch build on the merged corpus.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.ell import ELLGraph


class SlackOverflow(RuntimeError):
    """A per-row append buffer is full — compact to fold slack into base."""


class CapacityOverflow(RuntimeError):
    """No free node rows left — compact with a larger capacity."""


@partial(jax.jit, static_argnames=("capacity",))
def _fold_merged(base_nbr, base_live, extra_nbr, extra_mask, tomb,
                 *, capacity: int):
    """Concat base + slack slots and mask kills/tombstones (one dispatch)."""
    nbr = jnp.concatenate([base_nbr, extra_nbr], axis=1)
    mask = jnp.concatenate([base_live, extra_mask], axis=1)
    # sentinel id == capacity: give the tombstone gather a neutral last row
    tomb_ext = jnp.concatenate([tomb, jnp.zeros((1,), bool)])
    mask = mask & ~tomb_ext[jnp.minimum(nbr, capacity)]  # edges INTO dead
    mask = mask & ~tomb[:, None]  # rows OF dead
    nbr = jnp.where(mask, nbr, capacity)
    return nbr, mask


class DeltaGraph:
    """Mutable graph = frozen base ELL + slack/kill/tombstone overlays.

    ``capacity`` rows are pre-allocated; logical node ids are
    ``0 .. n_nodes-1`` and grow by :meth:`add_node` (never reused).  The
    device-facing sentinel is ``capacity`` throughout.
    """

    def __init__(self, base_nbr: np.ndarray, base_mask: np.ndarray,
                 n_nodes: int, capacity: int, extra_deg: int = 16):
        n, k = base_mask.shape
        if n > capacity:
            raise ValueError(f"base has {n} rows > capacity {capacity}")
        if n_nodes < n:
            raise ValueError("n_nodes must cover every base row")
        self.capacity = int(capacity)
        self.extra_deg = int(extra_deg)
        self.n_nodes = int(n_nodes)
        self.base_deg = int(k)
        # base slots, remapped to the capacity sentinel and capacity rows
        self.h_base_nbr = np.full((capacity, k), capacity, dtype=np.int32)
        self.h_base_nbr[:n][base_mask] = base_nbr[base_mask]
        self.h_base_mask = np.zeros((capacity, k), dtype=bool)
        self.h_base_mask[:n] = base_mask
        self.h_kill = np.zeros((capacity, k), dtype=bool)
        self.h_extra = np.full((capacity, extra_deg), capacity, dtype=np.int32)
        self.h_extra_cnt = np.zeros(capacity, dtype=np.int32)
        self.tomb = np.zeros(capacity, dtype=bool)
        self._merged = None  # cached device fold

    # ---- mutation ops (host mirrors; device fold is rebuilt lazily) -----
    def _check_id(self, u: int) -> None:
        if not (0 <= u < self.n_nodes):
            raise ValueError(f"node id {u} out of range [0, {self.n_nodes})")
        if self.tomb[u]:
            raise ValueError(f"node id {u} is tombstoned")

    def add_node(self) -> int:
        if self.n_nodes >= self.capacity:
            raise CapacityOverflow(
                f"capacity {self.capacity} exhausted; compact with headroom"
            )
        u = self.n_nodes
        self.n_nodes += 1
        self._merged = None
        return u

    def add_edge(self, u: int, v: int) -> bool:
        """Add directed edge u->v.  Returns False if it already exists."""
        self._check_id(u)
        self._check_id(v)
        row_live = self.h_base_mask[u] & ~self.h_kill[u]
        if np.any(row_live & (self.h_base_nbr[u] == v)):
            return False
        # resurrect a killed base slot before consuming slack
        killed = self.h_base_mask[u] & self.h_kill[u] & (self.h_base_nbr[u] == v)
        if np.any(killed):
            self.h_kill[u, int(np.argmax(killed))] = False
            self._merged = None
            return True
        c = int(self.h_extra_cnt[u])
        if np.any(self.h_extra[u, :c] == v):
            return False
        if c >= self.extra_deg:
            raise SlackOverflow(
                f"node {u}: {self.extra_deg} slack slots full; compact"
            )
        self.h_extra[u, c] = v
        self.h_extra_cnt[u] = c + 1
        self._merged = None
        return True

    def del_edge(self, u: int, v: int) -> bool:
        """Delete directed edge u->v.  Returns False if absent."""
        self._check_id(u)
        base = self.h_base_mask[u] & ~self.h_kill[u] & (self.h_base_nbr[u] == v)
        if np.any(base):
            self.h_kill[u, int(np.argmax(base))] = True
            self._merged = None
            return True
        c = int(self.h_extra_cnt[u])
        hit = np.flatnonzero(self.h_extra[u, :c] == v)
        if hit.size:
            i = int(hit[0])  # shift left: keeps insertion order deterministic
            self.h_extra[u, i:c - 1] = self.h_extra[u, i + 1:c]
            self.h_extra[u, c - 1] = self.capacity
            self.h_extra_cnt[u] = c - 1
            self._merged = None
            return True
        return False

    def del_node(self, u: int) -> None:
        self._check_id(u)
        self.tomb[u] = True
        self._merged = None

    # ---- host views -----------------------------------------------------
    def neighbors_live(self, u: int) -> np.ndarray:
        """Live out-neighbors of ``u`` (tombstoned targets excluded)."""
        row_live = self.h_base_mask[u] & ~self.h_kill[u]
        c = int(self.h_extra_cnt[u])
        nbrs = np.concatenate(
            [self.h_base_nbr[u][row_live], self.h_extra[u, :c]]
        )
        return nbrs[~self.tomb[nbrs]]

    def live_edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        """All surviving directed edges among non-tombstoned nodes."""
        live = self.h_base_mask & ~self.h_kill  # (cap, K)
        extra_mask = (
            np.arange(self.extra_deg)[None, :] < self.h_extra_cnt[:, None]
        )
        nbr = np.concatenate([self.h_base_nbr, self.h_extra], axis=1)
        mask = np.concatenate([live, extra_mask], axis=1)
        mask &= ~self.tomb[:, None]
        safe = np.minimum(nbr, self.capacity - 1)
        mask &= ~self.tomb[safe]
        src, slot = np.nonzero(mask)
        return src.astype(np.int64), nbr[src, slot].astype(np.int64)

    def merged_host(self) -> tuple[np.ndarray, np.ndarray]:
        """Numpy oracle of the merged view (tests compare vs. device fold)."""
        live = self.h_base_mask & ~self.h_kill
        extra_mask = (
            np.arange(self.extra_deg)[None, :] < self.h_extra_cnt[:, None]
        )
        nbr = np.concatenate([self.h_base_nbr, self.h_extra], axis=1)
        mask = np.concatenate([live, extra_mask], axis=1)
        tomb_ext = np.concatenate([self.tomb, [False]])
        mask = mask & ~tomb_ext[np.minimum(nbr, self.capacity)]
        mask = mask & ~self.tomb[:, None]
        nbr = np.where(mask, nbr, self.capacity).astype(np.int32)
        return nbr, mask

    # ---- device view ----------------------------------------------------
    def merged(self) -> ELLGraph:
        """Device merged view; cached until the next mutation.

        The fold allocates fresh device arrays, so ELLGraph snapshots
        handed out earlier stay valid for still-running dispatches.
        """
        if self._merged is None:
            live = self.h_base_mask & ~self.h_kill
            extra_mask = (
                np.arange(self.extra_deg)[None, :] < self.h_extra_cnt[:, None]
            )
            nbr, mask = _fold_merged(
                jnp.asarray(self.h_base_nbr), jnp.asarray(live),
                jnp.asarray(self.h_extra), jnp.asarray(extra_mask),
                jnp.asarray(self.tomb), capacity=self.capacity,
            )
            self._merged = ELLGraph(
                nbr=nbr, nbr_mask=mask, num_nodes=self.capacity,
                node_feat=None,
            )
        return self._merged
