"""ELL (padded neighbor list) graph format — the device-side layout.

TPU-native adaptation of RGL's C++ adjacency access: every node stores exactly
``max_deg`` neighbor slots; unused slots hold the sentinel ``num_nodes``.  All
gathers index arrays of length ``num_nodes + 1`` whose last row is a neutral
element, so frontier expansion / message passing are single fixed-shape
gathers with no bounds checks.  High-degree tails beyond ``max_deg`` are
truncated (documented; choose ``max_deg >= max degree`` for exactness).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class ELLGraph:
    """``nbr[i, k]`` = k-th neighbor of node i, or ``num_nodes`` (sentinel)."""

    nbr: jnp.ndarray  # (N, max_deg) int32
    nbr_mask: jnp.ndarray  # (N, max_deg) bool — True where a real edge exists
    num_nodes: int
    node_feat: Optional[jnp.ndarray] = None  # (N, F)

    @property
    def max_deg(self) -> int:
        return int(self.nbr.shape[1])

    @property
    def sentinel(self) -> int:
        return self.num_nodes

    def degrees(self) -> jnp.ndarray:
        return jnp.sum(self.nbr_mask, axis=1).astype(jnp.int32)


def csr_to_ell(
    g: CSRGraph, max_deg: Optional[int] = None, *, pad_to_multiple: int = 8
) -> ELLGraph:
    """Convert CSR → ELL, truncating rows above ``max_deg`` (host-side)."""
    deg = g.degrees()
    if max_deg is None:
        max_deg = int(deg.max()) if g.num_nodes else 1
    max_deg = max(1, max_deg)
    if pad_to_multiple > 1:
        max_deg = -(-max_deg // pad_to_multiple) * pad_to_multiple
    n = g.num_nodes
    nbr = np.full((n, max_deg), n, dtype=np.int32)
    take = np.minimum(deg, max_deg)
    # Vectorized row fill: flat positions for each (node, slot) pair.
    rows = np.repeat(np.arange(n), take)
    slots = _ranges(take)
    src_pos = np.repeat(g.indptr[:-1], take) + slots
    nbr[rows, slots] = g.indices[src_pos]
    mask = np.arange(max_deg)[None, :] < take[:, None]
    feat = jnp.asarray(g.node_feat) if g.node_feat is not None else None
    return ELLGraph(
        nbr=jnp.asarray(nbr), nbr_mask=jnp.asarray(mask), num_nodes=n, node_feat=feat
    )


def _ranges(counts: np.ndarray) -> np.ndarray:
    """[0..c0-1, 0..c1-1, ...] without a Python loop."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    idx = np.arange(total, dtype=np.int64)
    starts = np.repeat(np.cumsum(counts) - counts, counts)
    return idx - starts
