"""Synthetic graph generators mirroring the paper's dataset shapes.

No internet in this environment — these stand in for OGBN-Arxiv (citation),
Amazon Baby/Sports (bipartite multimodal recsys) and the GNN-shape graphs.
Scales are parameterized so tests use tiny versions and benchmarks mid-size.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph

_WORDS = (
    "graph retrieval neural network attention model learning deep node edge "
    "embedding transformer language token subgraph query index semantic sparse "
    "dense steiner bfs traversal augmented generation context citation paper "
    "abstract method result dataset feature structure efficient scalable"
).split()


def _texts(rng: np.random.Generator, n: int, length: int = 24) -> list:
    ids = rng.integers(0, len(_WORDS), size=(n, length))
    return [" ".join(_WORDS[w] for w in row) for row in ids]


def _topic_texts(
    rng: np.random.Generator, comm: np.ndarray, length: int = 24, k: int = 8,
) -> list:
    """Community-biased texts: each community favors its own word subset, so
    graph/feature neighborhoods share vocabulary (the structure the paper's
    abstract-generation task exploits)."""
    n_words = len(_WORDS)
    probs = np.full((k, n_words), 1.0)
    for c in range(k):
        topic = rng.choice(n_words, size=n_words // k, replace=False)
        probs[c, topic] = 12.0
    probs /= probs.sum(axis=1, keepdims=True)
    out = []
    for c in comm:
        ids = rng.choice(n_words, size=length, p=probs[int(c)])
        out.append(" ".join(_WORDS[w] for w in ids))
    return out


def citation_graph(
    n: int = 2000, avg_deg: int = 8, d_feat: int = 128, seed: int = 0,
    with_text: bool = True,
) -> CSRGraph:
    """Preferential-attachment citation network (OGBN-Arxiv stand-in)."""
    rng = np.random.default_rng(seed)
    m = max(1, avg_deg // 2)
    src, dst = [], []
    targets = list(range(min(m, n)))
    for v in range(m, n):
        # preferential attachment: sample from current endpoint pool
        choice = rng.choice(len(targets), size=m, replace=True)
        for c in choice:
            src.append(v)
            dst.append(targets[c])
        targets.extend([v] * m)
        targets.extend([targets[c] for c in choice])
    feat = rng.standard_normal((n, d_feat)).astype(np.float32)
    # community structure in BOTH features and texts so retrieval is
    # meaningful (semantic index and textual context agree)
    k = 8
    centers = rng.standard_normal((k, d_feat)).astype(np.float32) * 2.0
    comm = rng.integers(0, k, size=n)
    feat += centers[comm]
    text = _topic_texts(rng, comm, k=k) if with_text else None
    return CSRGraph.from_edges(
        np.array(src), np.array(dst), n, symmetrize=True,
        node_feat=feat, node_text=text,
    )


def bipartite_recsys_graph(
    n_users: int = 1000, n_items: int = 400, n_inter: int = 8000,
    d_modal: int = 64, seed: int = 0,
) -> tuple[CSRGraph, np.ndarray, np.ndarray]:
    """User-item interaction graph (Baby/Sports stand-in).

    Returns (graph, item_modal_feat, is_item_mask).  Nodes 0..n_users-1 are
    users; n_users..n_users+n_items-1 are items.  Items carry modality
    features with latent-factor structure (so completion is learnable).
    """
    rng = np.random.default_rng(seed)
    n = n_users + n_items
    d_lat = 16
    u_lat = rng.standard_normal((n_users, d_lat)).astype(np.float32)
    i_lat = rng.standard_normal((n_items, d_lat)).astype(np.float32)
    logits = u_lat @ i_lat.T  # (U, I)
    # sample interactions proportional to affinity
    flat_p = np.exp(logits / 2.0).ravel()
    flat_p /= flat_p.sum()
    picks = rng.choice(n_users * n_items, size=min(n_inter, n_users * n_items),
                       replace=False, p=flat_p)
    u, i = np.divmod(picks, n_items)
    proj = rng.standard_normal((d_lat, d_modal)).astype(np.float32)
    modal = i_lat @ proj + 0.1 * rng.standard_normal((n_items, d_modal)).astype(np.float32)
    feat = np.zeros((n, d_modal), dtype=np.float32)
    feat[n_users:] = modal
    g = CSRGraph.from_edges(u, i + n_users, n, symmetrize=True, node_feat=feat)
    is_item = np.zeros(n, dtype=bool)
    is_item[n_users:] = True
    return g, modal, is_item


def random_regular_graph(n: int, deg: int, d_feat: int = 64, seed: int = 0) -> CSRGraph:
    """Near-regular random graph (full_graph / ogb_products stand-in shapes)."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, n, size=(n, deg))
    src = np.repeat(np.arange(n), deg)
    feat = rng.standard_normal((n, d_feat)).astype(np.float32)
    return CSRGraph.from_edges(src, dst.ravel(), n, symmetrize=True, node_feat=feat)


def molecule_graphs(
    n_graphs: int = 128, n_nodes: int = 30, n_edges: int = 64,
    d_feat: int = 16, seed: int = 0,
) -> list:
    """Batch of small molecule-like graphs with 3D positions in node_feat[:, :3]."""
    rng = np.random.default_rng(seed)
    out = []
    for k in range(n_graphs):
        pos = rng.standard_normal((n_nodes, 3)).astype(np.float32)
        # connect nearest neighbors until ~n_edges arcs
        d2 = ((pos[:, None] - pos[None]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        kn = max(1, n_edges // n_nodes)
        nbrs = np.argsort(d2, axis=1)[:, :kn]
        src = np.repeat(np.arange(n_nodes), kn)
        feat = np.concatenate(
            [pos, rng.standard_normal((n_nodes, d_feat - 3)).astype(np.float32)], axis=1
        )
        out.append(
            CSRGraph.from_edges(src, nbrs.ravel(), n_nodes, symmetrize=True, node_feat=feat)
        )
    return out
