"""Compressed-sparse-row graph container (host side, numpy).

This is the canonical exchange format of the library: generators emit it,
samplers consume it, and :func:`repro.graph.ell.csr_to_ell` converts it into
the device-side padded format.  Mirrors the role of RGL's C++ graph index.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    """Directed graph in CSR form.  ``indices[indptr[u]:indptr[u+1]]`` are the
    out-neighbors of ``u``.  Undirected graphs store both arc directions."""

    indptr: np.ndarray  # (N+1,) int64
    indices: np.ndarray  # (E,) int32
    num_nodes: int
    node_feat: Optional[np.ndarray] = None  # (N, F) float32
    edge_feat: Optional[np.ndarray] = None  # (E, Fe) float32
    node_text: Optional[list] = None  # list[str] textual payloads (RAG corpus)

    def __post_init__(self):
        assert self.indptr.shape == (self.num_nodes + 1,)
        self.indptr = np.asarray(self.indptr, dtype=np.int64)
        self.indices = np.asarray(self.indices, dtype=np.int32)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u] : self.indptr[u + 1]]

    @staticmethod
    def from_edges(
        src: np.ndarray,
        dst: np.ndarray,
        num_nodes: int,
        *,
        symmetrize: bool = False,
        node_feat: Optional[np.ndarray] = None,
        edge_feat: Optional[np.ndarray] = None,
        node_text: Optional[list] = None,
    ) -> "CSRGraph":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if symmetrize:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
            if edge_feat is not None:
                edge_feat = np.concatenate([edge_feat, edge_feat], axis=0)
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        if edge_feat is not None:
            edge_feat = edge_feat[order]
        counts = np.bincount(src, minlength=num_nodes)
        indptr = np.zeros(num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(
            indptr=indptr,
            indices=dst.astype(np.int32),
            num_nodes=num_nodes,
            node_feat=node_feat,
            edge_feat=edge_feat,
            node_text=node_text,
        )

    def edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        """Return (src, dst) int32 arrays — the scatter format for GNNs."""
        src = np.repeat(np.arange(self.num_nodes, dtype=np.int32), self.degrees())
        return src, self.indices.copy()

    def to_adj_dict(self) -> dict:
        """Adjacency-dict view for the pure-Python (NetworkX-class) baseline."""
        return {u: self.neighbors(u).tolist() for u in range(self.num_nodes)}

    def subgraph(self, nodes: np.ndarray) -> "CSRGraph":
        """Induced subgraph over ``nodes`` (host-side; exact, dynamic shape)."""
        nodes = np.asarray(nodes)
        relabel = -np.ones(self.num_nodes, dtype=np.int64)
        relabel[nodes] = np.arange(len(nodes))
        src, dst = [], []
        for new_u, u in enumerate(nodes):
            nbrs = self.neighbors(u)
            keep = relabel[nbrs] >= 0
            dst.extend(relabel[nbrs[keep]].tolist())
            src.extend([new_u] * int(keep.sum()))
        nf = self.node_feat[nodes] if self.node_feat is not None else None
        nt = [self.node_text[i] for i in nodes] if self.node_text is not None else None
        return CSRGraph.from_edges(
            np.asarray(src, dtype=np.int64),
            np.asarray(dst, dtype=np.int64),
            len(nodes),
            node_feat=nf,
            node_text=nt,
        )
