"""Batch many small graphs into one block-diagonal graph (molecule shape)."""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def batch_graphs(graphs: list[CSRGraph]) -> tuple[CSRGraph, np.ndarray]:
    """Disjoint union.  Returns (big_graph, graph_ids) where ``graph_ids[i]``
    maps node i of the union back to its source graph (for graph-level
    readout via segment_sum)."""
    offsets = np.cumsum([0] + [g.num_nodes for g in graphs])
    src_all, dst_all, feats, gids = [], [], [], []
    for k, g in enumerate(graphs):
        s, d = g.edge_list()
        src_all.append(s.astype(np.int64) + offsets[k])
        dst_all.append(d.astype(np.int64) + offsets[k])
        if g.node_feat is not None:
            feats.append(g.node_feat)
        gids.append(np.full(g.num_nodes, k, dtype=np.int32))
    nf = np.concatenate(feats, axis=0) if feats else None
    big = CSRGraph.from_edges(
        np.concatenate(src_all),
        np.concatenate(dst_all),
        int(offsets[-1]),
        node_feat=nf,
    )
    return big, np.concatenate(gids)
