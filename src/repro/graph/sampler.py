"""Fan-out neighbor sampler (GraphSAGE-style) — real, host-side, vectorized.

Produces fixed-shape sampled blocks for the ``minibatch_lg`` regime
(batch_nodes=1024, fanout 15-10): seed nodes, per-hop padded neighbor tables
and the union node set, ready to feed the GNN ``train_step``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class SampledBlock:
    """Fixed-shape minibatch: ``nodes`` is the union (padded with -1 → relabeled
    to the sentinel row); ``hops[k]`` is an (n_k, fanout_k) int32 table of
    *positions into* ``nodes`` (sentinel = len(nodes))."""

    nodes: np.ndarray  # (cap,) original node ids, -1 padded
    n_valid: int
    hops: list  # list[(n_k, fanout_k) int32] position tables
    hop_masks: list  # list[(n_k, fanout_k) bool]
    seeds_pos: np.ndarray  # (batch,) positions of the seed nodes in `nodes`


class NeighborSampler:
    def __init__(self, g: CSRGraph, fanouts: tuple[int, ...], seed: int = 0):
        self.g = g
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)
        # capacity: batch * prod(1 + fanouts) upper bound, computed per batch.

    def capacity(self, batch: int) -> int:
        cap = batch
        layer = batch
        for f in self.fanouts:
            layer *= f
            cap += layer
        return cap

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        g, rng = self.g, self.rng
        seeds = np.asarray(seeds, dtype=np.int64)
        frontier = seeds
        all_nodes = [seeds]
        raw_hops = []  # neighbor node-ids per hop, sentinel -1
        deg = g.degrees()
        for f in self.fanouts:
            n_f = len(frontier)
            d = deg[frontier]  # (n_f,)
            # sample f slots per frontier node: random offsets modulo degree
            offs = rng.integers(0, 1 << 30, size=(n_f, f))
            has = d > 0
            safe_d = np.maximum(d, 1)
            slot = offs % safe_d[:, None]
            nbrs = g.indices[g.indptr[frontier][:, None] + slot]  # (n_f, f)
            nbrs = np.where(has[:, None], nbrs, -1).astype(np.int64)
            raw_hops.append(nbrs)
            frontier = nbrs[nbrs >= 0].ravel()
            all_nodes.append(np.unique(frontier))
        uniq = np.unique(np.concatenate(all_nodes))
        uniq = uniq[uniq >= 0]
        cap = self.capacity(len(seeds))
        n_valid = len(uniq)
        assert n_valid <= cap, (n_valid, cap)
        nodes = np.full(cap, -1, dtype=np.int64)
        nodes[:n_valid] = uniq
        # position lookup (original id -> position in `nodes`, sentinel=cap)
        lut = np.full(g.num_nodes + 1, cap, dtype=np.int64)
        lut[uniq] = np.arange(n_valid)
        hops, hop_masks = [], []
        for nbrs in raw_hops:
            m = nbrs >= 0
            pos = lut[np.where(m, nbrs, 0)]
            hops.append(np.where(m, pos, cap).astype(np.int32))
            hop_masks.append(m)
        seeds_pos = lut[seeds].astype(np.int32)
        return SampledBlock(
            nodes=nodes, n_valid=n_valid, hops=hops, hop_masks=hop_masks,
            seeds_pos=seeds_pos,
        )
