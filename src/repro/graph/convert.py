"""Interop converters (paper §2.1.1: "seamless conversions to and from
popular frameworks such as DGL and PyG").

Neither library is installable offline, so we implement the conversions
against their *data layouts* (the stable exchange contracts):

* PyG style:  dict(edge_index=(2, E) int array, x=(N, F), num_nodes=N)
* DGL style:  dict(edges=(src, dst) tuple, ndata={"feat": (N, F)})

If the real libraries are importable, `to_pyg`/`to_dgl` return actual
`torch_geometric.data.Data` / `dgl.DGLGraph` objects; otherwise the layout
dicts (tested path in this container).
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def to_pyg(g: CSRGraph):
    src, dst = g.edge_list()
    payload = {
        "edge_index": np.stack([src, dst]).astype(np.int64),
        "x": g.node_feat,
        "num_nodes": g.num_nodes,
    }
    try:  # pragma: no cover - library not available offline
        from torch_geometric.data import Data
        import torch

        return Data(
            edge_index=torch.as_tensor(payload["edge_index"]),
            x=None if g.node_feat is None else torch.as_tensor(g.node_feat),
            num_nodes=g.num_nodes,
        )
    except ImportError:
        return payload


def from_pyg(data) -> CSRGraph:
    if isinstance(data, dict):
        ei, x, n = data["edge_index"], data.get("x"), data["num_nodes"]
    else:  # pragma: no cover
        ei = data.edge_index.numpy()
        x = None if data.x is None else data.x.numpy()
        n = data.num_nodes
    ei = np.asarray(ei)
    return CSRGraph.from_edges(ei[0], ei[1], int(n), node_feat=x)


def to_dgl(g: CSRGraph):
    src, dst = g.edge_list()
    payload = {
        "edges": (src.astype(np.int64), dst.astype(np.int64)),
        "num_nodes": g.num_nodes,
        "ndata": {} if g.node_feat is None else {"feat": g.node_feat},
    }
    try:  # pragma: no cover - library not available offline
        import dgl
        import torch

        gg = dgl.graph(
            (torch.as_tensor(payload["edges"][0]),
             torch.as_tensor(payload["edges"][1])),
            num_nodes=g.num_nodes,
        )
        if g.node_feat is not None:
            gg.ndata["feat"] = torch.as_tensor(g.node_feat)
        return gg
    except ImportError:
        return payload


def from_dgl(data) -> CSRGraph:
    if isinstance(data, dict):
        src, dst = data["edges"]
        n = data["num_nodes"]
        x = data.get("ndata", {}).get("feat")
    else:  # pragma: no cover
        src, dst = (t.numpy() for t in data.edges())
        n = data.num_nodes()
        x = data.ndata.get("feat")
        x = None if x is None else x.numpy()
    return CSRGraph.from_edges(np.asarray(src), np.asarray(dst), int(n),
                               node_feat=x)
