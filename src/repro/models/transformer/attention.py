"""Attention: RoPE, chunked (flash-style) causal/sliding-window attention for
train/prefill, and KV-cache decode attention.

The chunked path is the memory-critical piece: a double `lax.scan` over
(q-chunk, kv-chunk) tiles with online-softmax accumulators keeps the largest
intermediate at (B, KV, rep, Cq, Ck) instead of (B, H, S, S) — the same
blocking the Pallas flash kernel (repro.kernels.flash_attn) uses on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _tile_mask(qi, kj, cq, ck, window):
    """(Cq, Ck) causal/windowed mask for tile at q-offset qi, kv-offset kj."""
    iq = qi + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
    jk = kj + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
    m = jk <= iq
    if window is not None:
        m &= (iq - jk) < window
    return m


@functools.partial(
    jax.jit, static_argnames=("window", "q_chunk", "kv_chunk", "use_kernel")
)
def chunked_attention(
    q: jnp.ndarray,  # (B, S, H, dh)
    k: jnp.ndarray,  # (B, S, KV, dh)
    v: jnp.ndarray,  # (B, S, KV, dh)
    *,
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Differentiable flash attention (custom VJP).

    Naive autodiff through the (q-block, kv-block) double scan stashes every
    softmax tile — equivalent to materializing the full (B, H, S, S) score
    matrix (measured: 227 GiB/device for starcoder2 train_4k; EXPERIMENTS.md
    §Perf iteration 0).  The custom backward recomputes tiles from the saved
    (q, k, v, o, logsumexp) instead — the FlashAttention-2 bwd schedule."""
    if use_kernel:
        from repro.kernels.flash_attn import ops as fa_ops

        return fa_ops.flash_attention(
            q, k, v, window=window, q_blk=q_chunk, kv_blk=kv_chunk
        )
    s = q.shape[1]
    cq = min(q_chunk, s)
    ck = min(kv_chunk, s)
    assert s % cq == 0 and s % ck == 0, (s, cq, ck)
    return _flash(window, cq, ck)(q, k, v)


@functools.lru_cache(maxsize=None)
def _flash(window, cq, ck):
    """custom_vjp flash attention specialized to (window, q_chunk, kv_chunk)."""

    @jax.custom_vjp
    def fn(q, k, v):
        return _flash_fwd(q, k, v, window, cq, ck)[0]

    def fwd(q, k, v):
        o, lse = _flash_fwd(q, k, v, window, cq, ck)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        return _flash_bwd(res, do, window, cq, ck)

    fn.defvjp(fwd, bwd)
    return fn


def _flash_fwd(q, k, v, window, cq, ck):
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    nq, nk = s // cq, s // ck
    scale = dh**-0.5
    qg = q.reshape(b, nq, cq, kvh, rep, dh)
    kg = k.reshape(b, nk, ck, kvh, dh)
    vg = v.reshape(b, nk, ck, kvh, dh)

    def q_block(carry, qi):
        qb = qg[:, qi]  # (B, Cq, KV, rep, dh)

        def kv_block(acc, kj):
            m, l, o = acc
            kb, vb = kg[:, kj], vg[:, kj]  # (B, Ck, KV, dh)
            s_ = jnp.einsum(
                "bqkrd,bckd->bkrqc", qb, kb, preferred_element_type=jnp.float32
            ) * scale  # (B, KV, rep, Cq, Ck)
            tm = _tile_mask(qi * cq, kj * ck, cq, ck, window)
            s_ = jnp.where(tm[None, None, None], s_, NEG)
            m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            o = o * corr[..., None] + jnp.einsum(
                "bkrqc,bckd->bkrqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l, o), None

        m0 = jnp.full((b, kvh, rep, cq), NEG, jnp.float32)
        l0 = jnp.zeros((b, kvh, rep, cq), jnp.float32)
        o0 = jnp.zeros((b, kvh, rep, cq, dh), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_block, (m0, l0, o0), jnp.arange(nk, dtype=jnp.int32)
        )
        out = (o / jnp.maximum(l[..., None], 1e-20)).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-20))  # (B, KV, rep, Cq)
        return carry, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, jnp.arange(nq, dtype=jnp.int32))
    # outs: (nq, B, KV, rep, Cq, dh) -> (B, S, H, dh)
    out = jnp.moveaxis(outs, 0, 1)  # (B, nq, KV, rep, Cq, dh)
    out = jnp.transpose(out, (0, 1, 4, 2, 3, 5)).reshape(b, s, h, dh)
    lse = jnp.moveaxis(lses, 0, 1)  # (B, nq, KV, rep, Cq)
    return out, lse


def _flash_bwd(res, do, window, cq, ck):
    """FlashAttention-2 backward: recompute score tiles from (q,k,v,lse);
    pass 1 accumulates dq over kv blocks, pass 2 accumulates (dk, dv) over
    q blocks.  Live memory = one tile + the output grads."""
    q, k, v, o, lse = res
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    nq, nk = s // cq, s // ck
    scale = dh**-0.5
    qg = q.reshape(b, nq, cq, kvh, rep, dh)
    kg = k.reshape(b, nk, ck, kvh, dh)
    vg = v.reshape(b, nk, ck, kvh, dh)
    og = o.reshape(b, nq, cq, kvh, rep, dh)
    dog = do.reshape(b, nq, cq, kvh, rep, dh)
    # delta[iq] = rowsum(do * o): (B, nq, KV, rep, Cq)
    delta = jnp.einsum("bnqkrd,bnqkrd->bnkrq", dog.astype(jnp.float32),
                       og.astype(jnp.float32))

    def tile_p(qb, kb, lse_q, qi, kj):
        s_ = jnp.einsum(
            "bqkrd,bckd->bkrqc", qb, kb, preferred_element_type=jnp.float32
        ) * scale
        tm = _tile_mask(qi * cq, kj * ck, cq, ck, window)
        s_ = jnp.where(tm[None, None, None], s_, NEG)
        return jnp.exp(s_ - lse_q[..., None])  # (B, KV, rep, Cq, Ck)

    # ---- pass 1: dq per q block (scan over kv blocks inside) ---------------
    def dq_block(_, qi):
        qb = qg[:, qi]
        lse_q, dob, dlt = lse[:, qi], dog[:, qi], delta[:, qi]

        def inner(acc, kj):
            p = tile_p(qb, kg[:, kj], lse_q, qi, kj)
            dp = jnp.einsum("bqkrd,bckd->bkrqc", dob.astype(jnp.float32),
                            vg[:, kj].astype(jnp.float32))
            ds = p * (dp - dlt[..., None]) * scale
            acc = acc + jnp.einsum("bkrqc,bckd->bqkrd", ds,
                                   kg[:, kj].astype(jnp.float32))
            return acc, None

        dq0 = jnp.zeros((b, cq, kvh, rep, dh), jnp.float32)
        dqb, _ = jax.lax.scan(inner, dq0, jnp.arange(nk, dtype=jnp.int32))
        return None, dqb

    _, dqs = jax.lax.scan(dq_block, None, jnp.arange(nq, dtype=jnp.int32))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, s, h, dh).astype(q.dtype)

    # ---- pass 2: dk, dv per kv block (scan over q blocks inside) -----------
    def dkv_block(_, kj):
        kb, vb = kg[:, kj], vg[:, kj]

        def inner(acc, qi):
            dk_acc, dv_acc = acc
            qb = qg[:, qi]
            p = tile_p(qb, kb, lse[:, qi], qi, kj)
            dob = dog[:, qi].astype(jnp.float32)
            dv_acc = dv_acc + jnp.einsum("bkrqc,bqkrd->bckd", p, dob)
            dp = jnp.einsum("bqkrd,bckd->bkrqc", dob, vb.astype(jnp.float32))
            ds = p * (dp - delta[:, qi][..., None]) * scale
            dk_acc = dk_acc + jnp.einsum("bkrqc,bqkrd->bckd", ds,
                                         qb.astype(jnp.float32))
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, ck, kvh, dh), jnp.float32)
        (dkb, dvb), _ = jax.lax.scan(
            inner, (z, z), jnp.arange(nq, dtype=jnp.int32)
        )
        return None, (dkb, dvb)

    _, (dks, dvs) = jax.lax.scan(dkv_block, None, jnp.arange(nk, dtype=jnp.int32))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, s, kvh, dh).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, s, kvh, dh).astype(v.dtype)
    return dq, dk, dv


def dense_attention(q, k, v, *, window=None):
    """Reference O(S^2)-memory attention (tests / tiny shapes)."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, s, kvh, rep, dh)
    s_ = jnp.einsum("bqkrd,bckd->bkrqc", qg, k, preferred_element_type=jnp.float32)
    s_ = s_ * (dh**-0.5)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window is not None:
        m &= (i - j) < window
    s_ = jnp.where(m[None, None, None], s_, NEG)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bkrqc,bckd->bqkrd", p.astype(v.dtype), v)
    return o.reshape(b, s, h, dh)


def verify_attention(
    q: jnp.ndarray,  # (B, W, H, dh) — RoPE'd queries for W fed tokens
    k_cache: jnp.ndarray,  # (B, Sc, KV, dh) — incl. the W freshly written rows
    v_cache: jnp.ndarray,  # (B, Sc, KV, dh)
    kv_pos: jnp.ndarray,  # (B, Sc) absolute positions, -1 = empty slot
    q_pos: jnp.ndarray,  # (B, W) absolute position of each fed token
    window: Optional[int] = None,
    k_scale: Optional[jnp.ndarray] = None,  # (B, Sc, KV) int8-mode absmax
    v_scale: Optional[jnp.ndarray] = None,  # scales (dequant fused into dots)
) -> jnp.ndarray:
    """Multi-query decode attention for speculative draft verification.

    Scores W query positions against the KV arena in one pass.  Query *i* is
    masked to ``kv_pos <= q_pos[:, i]`` — the exact visibility rule
    :func:`decode_attention` applies to its single query — so each verified
    position attends over precisely the cache a sequential decode step at
    that position would see (fed tokens at later positions are written into
    the arena but masked out; they only become visible once the query walks
    past them).
    """
    b, w, h, dh = q.shape
    kvh = k_cache.shape[2]
    rep = h // kvh
    qg = q.reshape(b, w, kvh, rep, dh)
    s_ = jnp.einsum(
        "bwkrd,bckd->bkrwc", qg, k_cache.astype(qg.dtype),
        preferred_element_type=jnp.float32,
    ) * (dh**-0.5)  # (B, KV, rep, W, Sc)
    if k_scale is not None:  # int8 cache: fold dequant scale into the scores
        s_ = s_ * jnp.transpose(k_scale, (0, 2, 1)).astype(
            jnp.float32)[:, :, None, None]
    ok = (kv_pos[:, None, :] >= 0) & (kv_pos[:, None, :] <= q_pos[..., None])
    if window is not None:
        ok &= (q_pos[..., None] - kv_pos[:, None, :]) < window
    s_ = jnp.where(ok[:, None, None], s_, NEG)  # (B, KV, rep, W, Sc)
    p = jax.nn.softmax(s_, axis=-1)
    if v_scale is not None:  # fold dequant into the probabilities
        p = p * jnp.transpose(v_scale, (0, 2, 1)).astype(
            p.dtype)[:, :, None, None]
        o = jnp.einsum(
            "bkrwc,bckd->bkrwd", p, v_cache.astype(p.dtype),
            preferred_element_type=jnp.float32,
        ).astype(qg.dtype)
    else:
        o = jnp.einsum("bkrwc,bckd->bkrwd", p.astype(v_cache.dtype), v_cache)
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(b, w, h, dh)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, dh) — current-step query (already RoPE'd)
    k_cache: jnp.ndarray,  # (B, Sc, KV, dh) — rotated keys at absolute pos
    v_cache: jnp.ndarray,  # (B, Sc, KV, dh)
    kv_pos: jnp.ndarray,  # (B, Sc) absolute positions, -1 = empty slot
    cur_pos: jnp.ndarray,  # (B,) position of the current token
    window: Optional[int] = None,
    k_new: Optional[jnp.ndarray] = None,  # (B, 1, KV, dh) — current token's
    v_new: Optional[jnp.ndarray] = None,  # k/v, appended WITHOUT writing the
    k_scale: Optional[jnp.ndarray] = None,  # (B, Sc, KV) int8-mode absmax
    v_scale: Optional[jnp.ndarray] = None,  # scales (dequant fused into dots)
) -> jnp.ndarray:  # cache (avoids per-layer full-cache copies; §Perf decode)
    b, _, h, dh = q.shape
    kvh = k_cache.shape[2]
    rep = h // kvh
    qg = q.reshape(b, kvh, rep, dh)
    s_ = jnp.einsum(
        "bkrd,bckd->bkrc", qg, k_cache.astype(qg.dtype),
        preferred_element_type=jnp.float32,
    ) * (dh**-0.5)  # (B, KV, rep, Sc)
    if k_scale is not None:  # int8 cache: fold dequant scale into the scores
        s_ = s_ * jnp.transpose(k_scale, (0, 2, 1)).astype(jnp.float32)[:, :, None]
    # strict `<` : if k_new is given the current position is handled by the
    # appended self term, and the ring slot being overwritten is stale.
    lim_ok = kv_pos < cur_pos[:, None] if k_new is not None else (
        kv_pos <= cur_pos[:, None]
    )
    ok = (kv_pos >= 0) & lim_ok
    if window is not None:
        ok &= (cur_pos[:, None] - kv_pos) < window
    s_ = jnp.where(ok[:, None, None], s_, NEG)
    if k_new is None:
        p = jax.nn.softmax(s_, axis=-1)
        if v_scale is not None:  # fold dequant into the probabilities
            p = p * jnp.transpose(v_scale, (0, 2, 1)).astype(p.dtype)[:, :, None]
            o = jnp.einsum(
                "bkrc,bckd->bkrd", p, v_cache.astype(p.dtype),
                preferred_element_type=jnp.float32,
            )
            return o.astype(qg.dtype).reshape(b, 1, h, dh)
        o = jnp.einsum("bkrc,bckd->bkrd", p.astype(v_cache.dtype), v_cache)
        return o.reshape(b, 1, h, dh)
    s_self = jnp.einsum(
        "bkrd,bkd->bkr", qg, k_new[:, 0], preferred_element_type=jnp.float32
    )[..., None] * (dh**-0.5)  # (B, KV, rep, 1)
    m = jnp.maximum(jnp.max(s_, axis=-1, keepdims=True), s_self)
    e_c = jnp.exp(s_ - m)
    e_s = jnp.exp(s_self - m)
    den = jnp.sum(e_c, axis=-1, keepdims=True) + e_s
    o = jnp.einsum("bkrc,bckd->bkrd", (e_c / den).astype(v_cache.dtype), v_cache)
    o = o + (e_s / den).astype(v_new.dtype) * v_new[:, 0][:, :, None, :]
    return o.reshape(b, 1, h, dh)


# --------------------------------------------------------------------------
# paged KV: block-table indirection in front of the decode/verify kernels
# --------------------------------------------------------------------------
def paged_gather(pool: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Materialize a per-slot contiguous view out of a shared paged pool.

    ``pool`` (P, ...) holds the physical rows of every slot's KV blocks;
    ``rows`` (B, Sc) maps each slot's logical arena row to its pool row
    (pre-clamped to 0 under unallocated blocks — see
    ``repro.models.transformer.model.block_rows``).  One advanced-indexing
    gather -> (B, Sc, ...), the exact layout the contiguous kernels take.
    """
    return pool[rows]


def paged_decode_attention(
    q: jnp.ndarray,  # (B, 1, H, dh) — current-step query (already RoPE'd)
    k_pool: jnp.ndarray,  # (P, KV, dh) — this layer's shared block pool
    v_pool: jnp.ndarray,  # (P, KV, dh)
    rows: jnp.ndarray,  # (B, Sc) block-table row map (see paged_gather)
    kv_pos: jnp.ndarray,  # (B, Sc) absolute positions, -1 = empty/unallocated
    cur_pos: jnp.ndarray,  # (B,) position of the current token
    window: Optional[int] = None,
    k_scale: Optional[jnp.ndarray] = None,  # (P, KV) int8-mode absmax scales
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Block-table-indirected decode attention: gather the slot's logical
    view from the pool, then delegate to :func:`decode_attention` unchanged.

    Rows gathered from unallocated blocks (clamped to pool row 0) carry
    ``kv_pos == -1``; the NEG mask turns them into *exact* zeros after the
    softmax (exp underflows to 0.0, and 0.0 * finite == 0.0), so the output
    is bitwise identical to a contiguous arena holding the same live rows —
    the indirection cost is one gather per layer, not a different kernel.
    """
    kc = paged_gather(k_pool, rows)
    vc = paged_gather(v_pool, rows)
    ks = paged_gather(k_scale, rows) if k_scale is not None else None
    vs = paged_gather(v_scale, rows) if v_scale is not None else None
    return decode_attention(q, kc, vc, kv_pos, cur_pos, window,
                            k_scale=ks, v_scale=vs)


def paged_verify_attention(
    q: jnp.ndarray,  # (B, W, H, dh) — RoPE'd queries for W fed tokens
    k_pool: jnp.ndarray,  # (P, KV, dh) — incl. the W freshly written rows
    v_pool: jnp.ndarray,  # (P, KV, dh)
    rows: jnp.ndarray,  # (B, Sc) block-table row map
    kv_pos: jnp.ndarray,  # (B, Sc) absolute positions, -1 = empty
    q_pos: jnp.ndarray,  # (B, W) absolute position of each fed token
    window: Optional[int] = None,
    k_scale: Optional[jnp.ndarray] = None,  # (P, KV)
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Block-table-indirected :func:`verify_attention` — same gather-then-
    delegate construction (and the same bitwise-parity argument) as
    :func:`paged_decode_attention`, for the speculative verify pass."""
    kc = paged_gather(k_pool, rows)
    vc = paged_gather(v_pool, rows)
    ks = paged_gather(k_scale, rows) if k_scale is not None else None
    vs = paged_gather(v_scale, rows) if v_scale is not None else None
    return verify_attention(q, kc, vc, kv_pos, q_pos, window,
                            k_scale=ks, v_scale=vs)
