"""LM generation: prefill + KV-cache greedy/temperature decoding.

Implements the RGL generation interface (repro.core.generation.Generator)
on top of any TransformerConfig — the offline stand-in for the paper's
GPT-4o-mini / DeepSeek-V3 backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import model as tm
from repro.models.transformer.config import TransformerConfig


@functools.partial(
    jax.jit, static_argnames=("cfg", "max_new", "cache_len", "temperature")
)
def generate_tokens(
    params, prompt, true_len, key, cfg: TransformerConfig,
    max_new: int, cache_len: int, temperature: float = 0.0,
):
    """prompt (B, S) -> generated (B, max_new) int32."""
    logits, cache = tm.prefill(params, prompt, true_len, cfg, cache_len)

    def sample(lg, k):
        if temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        g = -jnp.log(-jnp.log(jax.random.uniform(k, lg.shape) + 1e-9) + 1e-9)
        return jnp.argmax(lg / temperature + g, axis=-1).astype(jnp.int32)

    k0, key = jax.random.split(key)
    tok0 = sample(logits, k0)

    def body(carry, k):
        tok, cache = carry
        logits, cache = tm.decode_step(params, cache, tok, cfg)
        nxt = sample(logits, k)
        return (nxt, cache), tok

    keys = jax.random.split(key, max_new)
    (_, _), toks = jax.lax.scan(body, (tok0, cache), keys)
    return jnp.swapaxes(toks, 0, 1)  # (B, max_new)


class LMGenerator:
    """core.generation.Generator backend over the in-repo LM stack."""

    def __init__(self, params, cfg: TransformerConfig, vocab, *,
                 cache_len: int = 1024, temperature: float = 0.0, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.vocab = vocab
        self.cache_len = cache_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.id_to_word = {v + 6: k for k, v in vocab.word_to_id.items()}

    def generate(self, prompt_ids, prompt_mask, max_new_tokens: int = 32) -> list:
        prompt = jnp.asarray(prompt_ids, jnp.int32)
        true_len = jnp.asarray(prompt_mask).sum(axis=1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        toks = generate_tokens(
            self.params, prompt, true_len, k, self.cfg,
            max_new=max(max_new_tokens, 1), cache_len=self.cache_len,
            temperature=self.temperature,
        )
        out = []
        for row in np.asarray(toks):
            words = [self.id_to_word.get(int(t), "") for t in row]
            out.append(" ".join(w for w in words if w))
        return out
