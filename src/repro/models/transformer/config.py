"""Transformer configuration (decoder-only LM family)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden width
    capacity_factor: float = 1.25
    shard_mode: str = "expert"  # "expert" (EP) or "tp" (TP within expert)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int  # dense FFN width (ignored when moe is set)
    vocab: int
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # None = full causal attention
    moe: Optional[MoEConfig] = None
    dtype: str = "bfloat16"  # parameter / activation dtype
    remat: bool = True
    scan_layers: bool = True
    q_chunk: int = 512  # chunked-attention block sizes (flash-style)
    kv_chunk: int = 512
    loss_chunk: int = 512  # seq chunk for streamed cross-entropy
    norm_eps: float = 1e-5
    kv_quant: bool = False  # int8 KV cache (per-row absmax scales)

    @property
    def n_rep(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> tuple[int, int]:
        """(total params N, active params N_active) excluding embeddings'
        contribution is included — standard 6ND accounting uses non-embedding
        + embedding; we report both terms folded in."""
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.moe is not None:
            ff_tot = 3 * d * self.moe.d_ff * self.moe.n_experts + d * self.moe.n_experts
            ff_act = 3 * d * self.moe.d_ff * self.moe.top_k + d * self.moe.n_experts
        else:
            ff_tot = ff_act = 3 * d * self.d_ff
        per_layer_t = attn + ff_tot + 2 * d
        per_layer_a = attn + ff_act + 2 * d
        emb = self.vocab * d * 2  # embed + head
        return (
            self.n_layers * per_layer_t + emb + d,
            self.n_layers * per_layer_a + emb + d,
        )
