"""Mixture-of-Experts FFN (GShard/Mixtral-style) with fixed shapes.

TPU-idiomatic dispatch: tokens are sorted by assigned expert (stable argsort),
truncated at per-expert capacity C = cf * T * k / E, batched through an
(E, C, D) x (E, D, F) grouped GEMM, and combined back with gate weights via
segment-sum.  All shapes static; overflow tokens are dropped (standard
capacity-factor semantics) and the auxiliary load-balance loss (Switch) keeps
the router near-uniform.

Sharding: "expert" mode shards the E axis (EP — dispatch becomes all-to-all
under GSPMD); "tp" mode shards the F axis (TP within expert, for E < mesh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.constraints import shard_hint
from repro.models.transformer.config import MoEConfig


def init_moe_params(key, d_model: int, cfg: MoEConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, f = cfg.n_experts, cfg.d_ff
    s_in = d_model**-0.5
    s_ff = f**-0.5
    return {
        "router": (jax.random.normal(k1, (d_model, e)) * s_in).astype(jnp.float32),
        "w1": (jax.random.normal(k2, (e, d_model, f)) * s_in).astype(dtype),
        "w3": (jax.random.normal(k3, (e, d_model, f)) * s_in).astype(dtype),
        "w2": (jax.random.normal(k4, (e, f, d_model)) * s_ff).astype(dtype),
    }


def moe_ffn(params, x: jnp.ndarray, cfg: MoEConfig):
    """x: (T, D) token-major. Returns (y (T, D), aux_loss scalar)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(cfg.capacity_factor * t * k / e)
    cap = max(8, -(-cap // 8) * 8)  # pad capacity to a multiple of 8

    logits = x.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, top_e = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = top_e.reshape(-1)  # (T*k,) expert of each (token, slot)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_gate = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)  # (T*k,)
    se = flat_e[order]
    st = flat_tok[order]
    sg = flat_gate[order]
    # rank within expert group = idx - start_of_group
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts  # (E,)
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)  # sentinel = E*C

    xg = shard_hint(x[st], "dp", None)  # (T*k, D) tokens in sorted order
    xpad = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].add(
        jnp.where(keep[:, None], xg, 0)
    )[: e * cap]
    xe = xpad.reshape(e, cap, d)
    # EP: experts over "model" (dispatch = all-to-all); TP: capacity over dp,
    # d_ff over "model" inside each expert.
    if cfg.shard_mode == "expert":
        xe = shard_hint(xe, "model", None, None)
    else:
        xe = shard_hint(xe, None, "dp", None)

    # ---- grouped GEMM (SwiGLU experts) -------------------------------------
    h = jnp.einsum("ecd,edf->ecf", xe, params["w1"], preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", xe, params["w3"], preferred_element_type=jnp.float32)
    if cfg.shard_mode == "expert":
        h = shard_hint(h, "model", None, None)
    else:
        h = shard_hint(h, None, "dp", "model")
    h = (jax.nn.silu(h) * g).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w2"], preferred_element_type=jnp.float32)
    ye = shard_hint(
        ye, *(("model", None, None) if cfg.shard_mode == "expert"
              else (None, "dp", None))
    )

    # ---- combine -------------------------------------------------------------
    yflat = ye.reshape(e * cap, d)
    yg = shard_hint(
        jnp.where(keep[:, None], yflat[jnp.minimum(slot, e * cap - 1)], 0.0),
        "dp", None,
    )
    y = jax.ops.segment_sum(yg * sg[:, None], st, num_segments=t)
    return y.astype(x.dtype), aux
