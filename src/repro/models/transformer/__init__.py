from repro.models.transformer.config import TransformerConfig, MoEConfig
from repro.models.transformer import model, attention, moe, generate

__all__ = ["TransformerConfig", "MoEConfig", "model", "attention", "moe", "generate"]
