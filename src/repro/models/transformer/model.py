"""Decoder-only LM: init / train forward / prefill / decode, scan-over-layers.

Covers all 5 assigned LM architectures: GQA + RoPE, dense-SwiGLU or MoE FFN,
optional sliding-window attention (starcoder2), streamed cross-entropy (vocab
up to 131k), ring-buffer KV cache for long-context decode.

Layers are stacked on a leading L axis and driven by `lax.scan` (+ optional
`jax.checkpoint`), so HLO size and compile time are depth-independent — a
hard requirement for the 62-layer/33B dry-run on this container.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.constraints import shard_hint
from repro.models.transformer import attention as attn
from repro.models.transformer.config import TransformerConfig
from repro.models.transformer.moe import init_moe_params, moe_ffn


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    s = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * s * w.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_params(key, cfg: TransformerConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    keys = jax.random.split(key, 8)
    s_d = d**-0.5
    L = cfg.n_layers

    def nrm(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(dtype)

    layers = {
        "ln1": jnp.ones((L, d), jnp.float32),
        "ln2": jnp.ones((L, d), jnp.float32),
        "wq": nrm(keys[0], (L, d, h * dh), s_d),
        "wk": nrm(keys[1], (L, d, kv * dh), s_d),
        "wv": nrm(keys[2], (L, d, kv * dh), s_d),
        "wo": nrm(keys[3], (L, h * dh, d), (h * dh) ** -0.5),
    }
    if cfg.moe is None:
        layers.update(
            w1=nrm(keys[4], (L, d, cfg.d_ff), s_d),
            w3=nrm(keys[5], (L, d, cfg.d_ff), s_d),
            w2=nrm(keys[6], (L, cfg.d_ff, d), cfg.d_ff**-0.5),
        )
    else:
        moe_keys = jax.random.split(keys[4], L)
        per_layer = [init_moe_params(k, d, cfg.moe, dtype) for k in moe_keys]
        layers["moe"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    k_e, k_h = jax.random.split(keys[7])
    return {
        "embed": nrm(k_e, (cfg.vocab, d), 1.0),
        "layers": layers,
        "ln_f": jnp.ones((d,), jnp.float32),
        "head": nrm(k_h, (d, cfg.vocab), s_d),
    }


# --------------------------------------------------------------------------
# shared layer body
# --------------------------------------------------------------------------
def _attn_proj(p, xn, cfg: TransformerConfig):
    b, s, _ = xn.shape
    q = (xn @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (xn @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = (xn @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    return q, k, v


def _layer_train(x, p, cfg: TransformerConfig, positions):
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _attn_proj(p, xn, cfg)
    q = attn.rope(q, positions, cfg.rope_theta)
    k = attn.rope(k, positions, cfg.rope_theta)
    s = x.shape[1]
    if s <= max(cfg.q_chunk, 256):
        o = attn.dense_attention(q, k, v, window=cfg.sliding_window)
    else:
        o = attn.chunked_attention(
            q, k, v, window=cfg.sliding_window,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        )
    b, s_, h, dh = o.shape
    x = x + (o.reshape(b, s_, h * dh) @ p["wo"]).astype(x.dtype)

    xn = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is None:
        hidden = jax.nn.silu(xn @ p["w1"]) * (xn @ p["w3"])
        y = (hidden @ p["w2"]).astype(x.dtype)
        aux = jnp.zeros((), jnp.float32)
    else:
        t = b * s_
        y, aux = moe_ffn(p["moe"], xn.reshape(t, -1), cfg.moe)
        y = y.reshape(b, s_, -1)
    return x + y, aux


# --------------------------------------------------------------------------
# train-time forward + streamed loss
# --------------------------------------------------------------------------
def backbone(params, tokens: jnp.ndarray, cfg: TransformerConfig) -> tuple:
    """tokens (B, S) -> (hidden (B, S, D), aux_loss)."""
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]

    def body(carry, p):
        x, aux = carry
        x, a = _layer_train(x, p, cfg, positions)
        return (x, aux + a), None

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(
            fn, (x, jnp.zeros((), jnp.float32)), params["layers"]
        )
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            (x, aux), _ = fn((x, aux), p)
    return rms_norm(x, params["ln_f"], cfg.norm_eps), aux


def lm_logits(params, tokens, cfg: TransformerConfig):
    """Materialized logits — tests/small shapes only (V can be 131k)."""
    x, _ = backbone(params, tokens, cfg)
    return x.astype(jnp.float32) @ params["head"].astype(jnp.float32)


def lm_loss(params, tokens, loss_mask, cfg: TransformerConfig, aux_weight=0.01):
    """Next-token cross-entropy, streamed over sequence chunks.

    tokens (B, S) int32; loss_mask (B, S) — mask[t] gates prediction of
    token[t+1].  Returns (loss, metrics dict).
    """
    x, aux = backbone(params, tokens, cfg)
    b, s, d = x.shape
    c = min(cfg.loss_chunk, s - 1)
    n_pred = s - 1
    nc = n_pred // c
    rem = n_pred - nc * c
    head = params["head"]

    def chunk_nll(xc, yc, mc):
        lg = xc.astype(jnp.float32) @ head.astype(jnp.float32)  # (B, c, V)
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(lg, yc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - tgt) * mc), jnp.sum(mc)

    def body(acc, i):
        st = i * c
        xc = jax.lax.dynamic_slice_in_dim(x, st, c, axis=1)
        yc = jax.lax.dynamic_slice_in_dim(tokens, st + 1, c, axis=1)
        mc = jax.lax.dynamic_slice_in_dim(loss_mask, st, c, axis=1).astype(jnp.float32)
        nll, cnt = chunk_nll(xc, yc, mc)
        return (acc[0] + nll, acc[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), jnp.arange(nc, dtype=jnp.int32)
    )
    if rem:
        nll_r, cnt_r = chunk_nll(
            x[:, nc * c : s - 1],
            tokens[:, nc * c + 1 :],
            loss_mask[:, nc * c : s - 1].astype(jnp.float32),
        )
        nll, cnt = nll + nll_r, cnt + cnt_r
    loss = nll / jnp.maximum(cnt, 1.0)
    total = loss + aux_weight * aux
    return total, {"nll": loss, "aux": aux, "tokens": cnt}


# --------------------------------------------------------------------------
# serving: KV cache, prefill, decode
# --------------------------------------------------------------------------
@dataclasses.dataclass
class KVCache:
    k: jnp.ndarray  # (L, B, Sc, KV, dh) — int8 when quantized
    v: jnp.ndarray  # (L, B, Sc, KV, dh)
    pos: jnp.ndarray  # (B, Sc) absolute position per slot, -1 empty
    cursor: jnp.ndarray  # (B,) next absolute position to write
    k_scale: object = None  # (L, B, Sc, KV) bf16 absmax scales (int8 mode)
    v_scale: object = None


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "pos", "cursor", "k_scale", "v_scale"],
    meta_fields=[],
)


def _quant_rows(x: jnp.ndarray):
    """Per-(.., KV)-row absmax int8 quantization over d_head."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def init_cache(cfg: TransformerConfig, batch: int, cache_len: int) -> KVCache:
    dtype = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.d_head)
    if cfg.kv_quant:
        return KVCache(
            k=jnp.zeros(shape, jnp.int8),
            v=jnp.zeros(shape, jnp.int8),
            pos=jnp.full((batch, cache_len), -1, jnp.int32),
            cursor=jnp.zeros((batch,), jnp.int32),
            k_scale=jnp.zeros(shape[:-1], jnp.bfloat16),
            v_scale=jnp.zeros(shape[:-1], jnp.bfloat16),
        )
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        pos=jnp.full((batch, cache_len), -1, jnp.int32),
        cursor=jnp.zeros((batch,), jnp.int32),
    )


def prefill(params, tokens, true_len, cfg: TransformerConfig, cache_len: int):
    """Run the prompt, fill the cache, return (next_token_logits, cache).

    tokens (B, S) left-aligned, padded; true_len (B,).  Requires S <= cache_len.
    """
    b, s = tokens.shape
    assert s <= cache_len
    x = params["embed"][tokens]
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    def body(x, p):
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _attn_proj(p, xn, cfg)
        q = attn.rope(q, positions, cfg.rope_theta)
        k = attn.rope(k, positions, cfg.rope_theta)
        if s <= max(cfg.q_chunk, 256):
            o = attn.dense_attention(q, k, v, window=cfg.sliding_window)
        else:
            o = attn.chunked_attention(
                q, k, v, window=cfg.sliding_window,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            )
        x = x + (o.reshape(b, s, -1) @ p["wo"]).astype(x.dtype)
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is None:
            y = (jax.nn.silu(xn @ p["w1"]) * (xn @ p["w3"])) @ p["w2"]
        else:
            y, _ = moe_ffn(p["moe"], xn.reshape(b * s, -1), cfg.moe)
            y = y.reshape(b, s, -1)
        # cache rows: batch over dp, sequence over "model" (decode layout) —
        # unhinted, GSPMD replicated the 257 GB cache (§Perf).
        k = shard_hint(k, "dp", "model", None, None)
        v = shard_hint(v, "dp", "model", None, None)
        return x + y.astype(x.dtype), (k, v)

    fn = jax.checkpoint(body, static_argnums=()) if cfg.remat else body
    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(fn, x, params["layers"])
    else:  # unrolled (cost-analysis variants)
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            x, (k_i, v_i) = fn(x, p)
            ks_l.append(k_i)
            vs_l.append(v_i)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
    # cache layout
    pad = cache_len - s
    kc = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    kc = shard_hint(kc, None, "dp", "model", None, None)
    vc = shard_hint(vc, None, "dp", "model", None, None)
    slot_pos = jnp.arange(cache_len, dtype=jnp.int32)[None, :]
    pos = jnp.where(slot_pos < true_len[:, None], slot_pos, -1)
    if cfg.kv_quant:
        kq, ksc = _quant_rows(kc)
        vq, vsc = _quant_rows(vc)
        cache = KVCache(k=kq, v=vq, pos=pos, cursor=true_len.astype(jnp.int32),
                        k_scale=ksc, v_scale=vsc)
    else:
        cache = KVCache(k=kc, v=vc, pos=pos, cursor=true_len.astype(jnp.int32))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    last = jnp.take_along_axis(
        x, jnp.maximum(true_len - 1, 0)[:, None, None].astype(jnp.int32), axis=1
    )  # (B, 1, D)
    logits = last.astype(jnp.float32) @ params["head"].astype(jnp.float32)
    return logits[:, 0], cache


def decode_step(params, cache: KVCache, token, cfg: TransformerConfig):
    """One decode step.  token (B,) int32 -> (logits (B, V), new cache)."""
    b = token.shape[0]
    sc = cache.k.shape[2]
    cur = cache.cursor  # (B,) position of the token being processed
    slot = cur % sc
    x = params["embed"][token][:, None]  # (B, 1, D)
    bidx = jnp.arange(b)
    # Masked-broadcast cache update (elementwise => shards cleanly; a scatter
    # into the sequence-sharded cache made GSPMD gather the whole cache).
    # An append-attention variant with a single top-level scatter was tried
    # and REFUTED on memory (§Perf decode iterations: 28.8 -> 37.9 GiB —
    # scan xs double-buffering dominates); decode_attention(k_new=...) is
    # kept for serving-engine use.
    slot_mask = jnp.arange(sc, dtype=jnp.int32)[None, :] == slot[:, None]  # (B, Sc)
    quant = cfg.kv_quant

    def body(x, inputs):
        p, kc, vc, ks, vs = inputs
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _attn_proj(p, xn, cfg)
        q = attn.rope(q, cur[:, None], cfg.rope_theta)
        k = attn.rope(k, cur[:, None], cfg.rope_theta)
        if quant:
            kq, ksc = _quant_rows(k)
            vq, vsc = _quant_rows(v)
            kc = jnp.where(slot_mask[:, :, None, None], kq[:, 0][:, None], kc)
            vc = jnp.where(slot_mask[:, :, None, None], vq[:, 0][:, None], vc)
            ks = jnp.where(slot_mask[:, :, None], ksc[:, 0][:, None], ks)
            vs = jnp.where(slot_mask[:, :, None], vsc[:, 0][:, None], vs)
        else:
            kc = jnp.where(slot_mask[:, :, None, None], k[:, 0][:, None], kc)
            vc = jnp.where(slot_mask[:, :, None, None], v[:, 0][:, None], vc)
        pos = jnp.where(slot_mask, cur[:, None], cache.pos)
        o = attn.decode_attention(
            q, kc, vc, pos, cur, cfg.sliding_window, k_scale=ks, v_scale=vs
        )
        x = x + (o.reshape(b, 1, -1) @ p["wo"]).astype(x.dtype)
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is None:
            y = (jax.nn.silu(xn @ p["w1"]) * (xn @ p["w3"])) @ p["w2"]
        else:
            y, _ = moe_ffn(p["moe"], xn.reshape(b, -1), cfg.moe)
            y = y[:, None]
        return x + y.astype(x.dtype), (kc, vc, ks, vs)

    xs = (params["layers"], cache.k, cache.v, cache.k_scale, cache.v_scale)
    if cfg.scan_layers:
        x, (kc, vc, ks, vs) = jax.lax.scan(body, x, xs)
    else:  # unrolled (cost-analysis variants)
        outs = []
        for i in range(cfg.n_layers):
            sl = jax.tree.map(lambda a: a[i], xs)
            x, o_i = body(x, sl)
            outs.append(o_i)
        cols = list(zip(*outs))
        kc, vc = jnp.stack(cols[0]), jnp.stack(cols[1])
        ks = jnp.stack(cols[2]) if quant else None
        vs = jnp.stack(cols[3]) if quant else None
    new_pos = jnp.where(slot_mask, cur[:, None], cache.pos)
    new_cache = KVCache(k=kc, v=vc, pos=new_pos, cursor=cur + 1,
                        k_scale=ks, v_scale=vs)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x[:, 0].astype(jnp.float32) @ params["head"].astype(jnp.float32)
    return logits, new_cache


@functools.partial(jax.jit, static_argnames=("cfg",))
def serve_step(params, cache: KVCache, token, cfg: TransformerConfig):
    """Greedy decode step — the unit the decode/long dry-run shapes lower."""
    logits, cache = decode_step(params, cache, token, cfg)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache


# --------------------------------------------------------------------------
# self-speculative verification: score W fed tokens in one dispatch
# --------------------------------------------------------------------------
def verify_window(params, cache: KVCache, tokens, cfg: TransformerConfig):
    """Score a window of fed tokens against the KV arena in ONE dispatch.

    tokens (B, W): column 0 is the last committed token, columns 1..W-1 are
    draft continuations.  Token *i* is processed at absolute position
    ``cursor + i``: all W tokens' K/V rows are written first (masked to
    positions < cache_len so a window near the arena end never wraps onto a
    live row), then every query attends under the per-position visibility
    mask of :func:`repro.models.transformer.attention.verify_attention` —
    each position sees exactly the cache a sequential :func:`decode_step`
    at that position would see, which is what makes greedy acceptance
    token-exact against one-token decode.

    Returns (greedy (B, W), cache).  The cache holds all W written rows and
    an UNCHANGED cursor; :func:`verify_step` rewinds to the first rejection
    by advancing the cursor only past the accepted prefix.  Rows written for
    rejected positions are left in place: their ``pos`` values exceed every
    later query position until the cursor catches up, so the `<=` mask hides
    them, and the next window overwrites them before any attention runs.
    """
    b, w = tokens.shape
    sc = cache.k.shape[2]
    cur = cache.cursor  # (B,)
    positions = cur[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    writable = positions < sc  # never ring-wrap onto live rows
    slot = positions % sc
    slot_mask = (jnp.arange(sc, dtype=jnp.int32)[None, None, :]
                 == slot[..., None]) & writable[..., None]  # (B, W, Sc)
    x = params["embed"][tokens]  # (B, W, D)
    new_pos = cache.pos
    for i in range(w):
        new_pos = jnp.where(slot_mask[:, i], positions[:, i:i + 1], new_pos)
    quant = cfg.kv_quant

    def body(x, inputs):
        p, kc, vc, ks, vs = inputs
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _attn_proj(p, xn, cfg)
        q = attn.rope(q, positions, cfg.rope_theta)
        k = attn.rope(k, positions, cfg.rope_theta)
        if quant:
            kq, ksc = _quant_rows(k)
            vq, vsc = _quant_rows(v)
            for i in range(w):
                m = slot_mask[:, i][:, :, None, None]
                kc = jnp.where(m, kq[:, i][:, None], kc)
                vc = jnp.where(m, vq[:, i][:, None], vc)
                ks = jnp.where(slot_mask[:, i][:, :, None],
                               ksc[:, i][:, None], ks)
                vs = jnp.where(slot_mask[:, i][:, :, None],
                               vsc[:, i][:, None], vs)
        else:
            # one fused masked merge instead of W sequential full-array
            # passes: ring slots within a window are distinct, so the
            # one-hot contraction selects exactly one (w) row per written
            # slot — multiply-by-one/add-zero keeps the merge bitwise
            # identical to the sequential wheres
            onehot = slot_mask.astype(k.dtype)  # (B, W, Sc)
            wrote = slot_mask.any(axis=1)[:, :, None, None]  # (B, Sc, 1, 1)
            kc = jnp.where(wrote, jnp.einsum("bws,bwkd->bskd", onehot, k), kc)
            vc = jnp.where(wrote, jnp.einsum("bws,bwkd->bskd", onehot, v), vc)
        o = attn.verify_attention(
            q, kc, vc, new_pos, positions, cfg.sliding_window,
            k_scale=ks, v_scale=vs,
        )
        x = x + (o.reshape(b, w, -1) @ p["wo"]).astype(x.dtype)
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is None:
            y = (jax.nn.silu(xn @ p["w1"]) * (xn @ p["w3"])) @ p["w2"]
        else:
            y, _ = moe_ffn(p["moe"], xn.reshape(b * w, -1), cfg.moe)
            y = y.reshape(b, w, -1)
        return x + y.astype(x.dtype), (kc, vc, ks, vs)

    xs = (params["layers"], cache.k, cache.v, cache.k_scale, cache.v_scale)
    if cfg.scan_layers:
        x, (kc, vc, ks, vs) = jax.lax.scan(body, x, xs)
    else:  # unrolled (cost-analysis variants)
        outs = []
        for i in range(cfg.n_layers):
            sl = jax.tree.map(lambda a: a[i], xs)
            x, o_i = body(x, sl)
            outs.append(o_i)
        cols = list(zip(*outs))
        kc, vc = jnp.stack(cols[0]), jnp.stack(cols[1])
        ks = jnp.stack(cols[2]) if quant else None
        vs = jnp.stack(cols[3]) if quant else None
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x.astype(jnp.float32) @ params["head"].astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, W)
    new_cache = KVCache(k=kc, v=vc, pos=new_pos, cursor=cache.cursor,
                        k_scale=ks, v_scale=vs)
    return greedy, new_cache


def _accept_prefix(greedy, tokens, room, w: int, eos_id):
    """Greedy-exact acceptance shared by the contiguous and paged verify
    steps: position 0 always accepts; draft *i* accepts iff it equals the
    accepted output at *i-1*; ``room`` caps the prefix and ``eos_id``
    truncates it just past the first EOS.  Returns (accepted, cur_tok)."""
    match = (tokens[:, 1:] == greedy[:, :-1]).astype(jnp.int32)  # (B, W-1)
    raw = 1 + jnp.cumprod(match, axis=1).sum(axis=1)  # (B,) in [1, W]
    accepted = jnp.minimum(raw, jnp.maximum(room, 1))
    if eos_id is not None:
        idx = jnp.arange(w, dtype=jnp.int32)[None, :]
        is_eos = (greedy == eos_id) & (idx < accepted[:, None])
        first_eos = jnp.min(jnp.where(is_eos, idx, w), axis=1)
        accepted = jnp.minimum(accepted, first_eos + 1)
    cur_tok = jnp.take_along_axis(greedy, (accepted - 1)[:, None], axis=1)[:, 0]
    return accepted, cur_tok


@functools.partial(jax.jit, static_argnames=("cfg", "eos_id"))
def verify_step(params, cache: KVCache, tokens, room,
                cfg: TransformerConfig, eos_id=None):
    """One speculative engine step: verify W fed tokens, accept the greedy-
    matching prefix, rewind the cache cursor to the first rejection.

    tokens (B, W): [committed last token, draft_1 .. draft_{W-1}].
    room (B,): per-slot cap on accepted tokens this step
    (``min(max_new_tokens remaining, cache_len - cursor)``; clamped to
    >= 1 here, so a dead slot's cursor still drifts — by 1 to W per step
    depending on its stale room — until admission re-pins it).

    Acceptance is greedy-exact: position 0's output is always accepted (it
    is what one-token decode would emit); draft *i* is accepted iff it equals
    the accepted output at position *i-1*, so the accepted prefix is bitwise
    identical to step-by-step decode.  ``eos_id`` truncates the accepted
    prefix just past the first EOS, mirroring the sequential stop check.

    Returns (greedy (B, W), accepted (B,) in [1, W], next committed token
    (B,), cache with ``cursor += accepted``).
    """
    b, w = tokens.shape
    greedy, cache = verify_window(params, cache, tokens, cfg)
    accepted, cur_tok = _accept_prefix(greedy, tokens, room, w, eos_id)
    cache = KVCache(k=cache.k, v=cache.v, pos=cache.pos,
                    cursor=cache.cursor + accepted,
                    k_scale=cache.k_scale, v_scale=cache.v_scale)
    return greedy, accepted, cur_tok, cache


# --------------------------------------------------------------------------
# paged KV pool: block-table indirection over a shared block arena
# --------------------------------------------------------------------------
@dataclasses.dataclass
class PagedKVCache:
    """KV arena as a pool of fixed-size blocks shared by every decode slot.

    Same logical semantics as :class:`KVCache` — ``pos`` / ``cursor`` keep
    the per-slot absolute-position view over a virtual (B, Sc) arena — but
    the physical rows live in a (L, P, KV, dh) pool of ``pool_blocks``
    blocks of ``block_size`` tokens each (P = pool_blocks * block_size).
    ``table[b, j]`` names the pool block backing logical positions
    [j*bs, (j+1)*bs) of slot b; -1 = unallocated.  Allocated entries always
    form a prefix of the row because positions only grow until the slot
    retires and frees everything at once.

    ``free`` is a device free-list stack whose valid entries are
    ``free[:n_free]``: the jitted step pops blocks from the top as cursors
    cross block boundaries, :func:`free_slot_blocks` pushes a retired
    slot's blocks back in one small dispatch.  Neither direction syncs the
    host; the serving engine replays the same arithmetic on host mirrors
    (cursor → blocks needed → stack depth), so pool-exhaustion checks are
    host-only and deterministic.

    ``ref`` is the per-pool-block refcount that makes prefix sharing safe:
    a block's count is the number of holders — table entries across slots
    plus retrieval-cache pins (:func:`acquire_blocks`).  Allocation pops a
    block at count 0 and sets it to 1; :func:`free_slot_blocks` /
    :func:`release_blocks` decrement and push a block back onto the stack
    only when its count hits zero, so an aliased prompt prefix outlives
    any single holder.
    """

    k: jnp.ndarray  # (L, P, KV, dh) — int8 when quantized
    v: jnp.ndarray  # (L, P, KV, dh)
    pos: jnp.ndarray  # (B, Sc) absolute position per logical row, -1 empty
    cursor: jnp.ndarray  # (B,) next absolute position to write
    table: jnp.ndarray  # (B, max_blocks) pool block per logical block, -1 none
    free: jnp.ndarray  # (pool_blocks,) free-list stack storage
    n_free: jnp.ndarray  # () int32 valid stack depth
    ref: jnp.ndarray  # (pool_blocks,) int32 holders per block (0 = free)
    k_scale: object = None  # (L, P, KV) bf16 absmax scales (int8 mode)
    v_scale: object = None


jax.tree_util.register_dataclass(
    PagedKVCache,
    data_fields=["k", "v", "pos", "cursor", "table", "free", "n_free",
                 "ref", "k_scale", "v_scale"],
    meta_fields=[],
)


def init_paged_cache(cfg: TransformerConfig, batch: int, cache_len: int,
                     block_size: int, pool_blocks: int) -> PagedKVCache:
    if cache_len % block_size != 0:
        raise ValueError(
            f"block_size={block_size} must divide cache_len={cache_len}"
        )
    dtype = jnp.dtype(cfg.dtype)
    p = pool_blocks * block_size
    m = cache_len // block_size
    shape = (cfg.n_layers, p, cfg.n_kv_heads, cfg.d_head)
    kv_dtype = jnp.int8 if cfg.kv_quant else dtype
    scales = (jnp.zeros(shape[:-1], jnp.bfloat16) if cfg.kv_quant else None)
    return PagedKVCache(
        k=jnp.zeros(shape, kv_dtype),
        v=jnp.zeros(shape, kv_dtype),
        pos=jnp.full((batch, cache_len), -1, jnp.int32),
        cursor=jnp.zeros((batch,), jnp.int32),
        table=jnp.full((batch, m), -1, jnp.int32),
        free=jnp.arange(pool_blocks, dtype=jnp.int32),
        n_free=jnp.asarray(pool_blocks, jnp.int32),
        ref=jnp.zeros((pool_blocks,), jnp.int32),
        k_scale=scales,
        v_scale=(None if scales is None else scales),
    )


def block_rows(table: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """(B, M) block table -> (B, M*bs) pool-row gather map.  Rows under
    unallocated blocks map to pool row 0 — callers mask those logical rows
    via ``pos == -1``, so the gathered garbage is exact zero-weight."""
    b, m = table.shape
    off = jnp.arange(block_size, dtype=jnp.int32)
    rows = table[:, :, None] * block_size + off[None, None, :]
    return jnp.where(rows >= 0, rows, 0).reshape(b, m * block_size)


def alloc_blocks(table, free, n_free, ref, target, live, max_new: int):
    """Grow each live slot's allocated-block prefix to ``target[b]`` blocks
    by popping from the free stack — at most ``max_new`` new blocks per slot
    (a static bound, so the pop unrolls to ``max_new`` masked writes).
    Every popped block's refcount is set to 1 (its sole holder is the slot
    whose table entry now names it).

    The caller guarantees ``sum(need) <= n_free``: the serving engine
    retires slots host-side (``truncated=True``) before dispatch whenever
    the pool cannot cover the step, so no in-jit exhaustion handling — and
    no host sync — is ever needed (the engine's ``RGL_KV_DEBUG`` guard
    raises host-side if the invariant is ever violated; in-jit the
    violation would silently alias stale stack entries).  Dead slots
    (``~live``) never allocate, even though their cursors drift between
    admissions.
    """
    b, m = table.shape
    p = free.shape[0]
    n_tab = jnp.sum(table >= 0, axis=1).astype(jnp.int32)
    need = jnp.where(live, jnp.clip(target - n_tab, 0, max_new), 0)
    offs = (jnp.cumsum(need) - need).astype(jnp.int32)  # exclusive prefix sum
    cols = jnp.arange(m, dtype=jnp.int32)[None, :]
    for j in range(max_new):
        take = j < need  # (B,)
        src = jnp.clip(n_free - 1 - offs - j, 0, p - 1)
        blk = free[src]  # (B,) popped block ids (garbage where ~take)
        write = take[:, None] & (cols == (n_tab + j)[:, None])
        table = jnp.where(write, blk[:, None], table)
        ref = ref.at[jnp.where(take, blk, p)].set(1, mode="drop")
    return table, (n_free - jnp.sum(need)).astype(jnp.int32), ref


def _release_refs(free, n_free, ref, drops):
    """Decrement per-block refcounts by ``drops`` (a (P,) count of holds
    being dropped per pool block) and push every block whose count hits
    zero back onto the free stack, in ascending block-id order.  The
    per-POOL-BLOCK accounting (rather than per-table-entry) makes the push
    set duplicate-free by construction even when several retiring holders
    reference the same shared block."""
    p = free.shape[0]
    ref = ref - drops
    push = (drops > 0) & (ref <= 0)
    npush = jnp.cumsum(push.astype(jnp.int32))
    dst = jnp.where(push, n_free + npush - 1, p)
    free = free.at[dst].set(jnp.arange(p, dtype=jnp.int32), mode="drop")
    return free, (n_free + npush[-1]).astype(jnp.int32), jnp.maximum(ref, 0)


@jax.jit
def free_slot_blocks(cache: PagedKVCache, mask) -> PagedKVCache:
    """Drop every masked slot's hold on its blocks and clear its
    table/pos/cursor — ONE small dispatch per retirement step, batched over
    however many slots finished together.  A block returns to the free
    stack only when its refcount hits zero, so prompt-prefix blocks shared
    with other slots (or pinned by the retrieval cache) survive the
    retirement."""
    table = cache.table
    p = cache.free.shape[0]
    valid = (mask[:, None] & (table >= 0)).reshape(-1)
    ids = jnp.where(valid, table.reshape(-1), p)
    drops = jnp.zeros((p,), jnp.int32).at[ids].add(1, mode="drop")
    free, n_free, ref = _release_refs(
        cache.free, cache.n_free, cache.ref, drops
    )
    return dataclasses.replace(
        cache,
        free=free,
        n_free=n_free,
        ref=ref,
        table=jnp.where(mask[:, None], -1, table),
        pos=jnp.where(mask[:, None], -1, cache.pos),
        cursor=jnp.where(mask, 0, cache.cursor),
    )


@jax.jit
def acquire_blocks(cache: PagedKVCache, ids) -> PagedKVCache:
    """Add one hold per listed pool block (``ids`` int32, -1 entries
    ignored) — the retrieval-cache pin / pending-share side of the
    refcount protocol."""
    p = cache.free.shape[0]
    ref = cache.ref.at[jnp.where(ids >= 0, ids, p)].add(1, mode="drop")
    return dataclasses.replace(cache, ref=ref)


@jax.jit
def release_blocks(cache: PagedKVCache, ids) -> PagedKVCache:
    """Drop one hold per listed pool block (``ids`` int32, -1 entries
    ignored), pushing blocks that hit refcount zero back onto the free
    stack — the eviction side of :func:`acquire_blocks`."""
    p = cache.free.shape[0]
    drops = jnp.zeros((p,), jnp.int32).at[
        jnp.where(ids >= 0, ids, p)
    ].add(1, mode="drop")
    free, n_free, ref = _release_refs(
        cache.free, cache.n_free, cache.ref, drops
    )
    return dataclasses.replace(cache, free=free, n_free=n_free, ref=ref)


@functools.partial(jax.jit, static_argnames=("block_size",))
def adopt_prefix_blocks(cache: PagedKVCache, cur_tok, mask, src_table,
                        length, tail_src, first, block_size: int):
    """Map an already-prefilled prompt's pool blocks into each masked
    slot's table instead of re-running prefill.

    For slot b with ``mask[b]``: alias the ``length[b] // bs`` full leading
    blocks from ``src_table[b]`` (the holders' refcounts were bumped by the
    engine before this dispatch — the slot takes those holds over), and
    when the prompt ends mid-block (``tail_src[b] >= 0`` names the donor's
    partial tail block) pop a fresh block, copy the tail block's K/V rows
    into it, and point the table at the copy — copy-on-write at the first
    divergent write position, done eagerly because the very next decode
    write for this slot lands inside that block.  Rows past the prompt ride
    along in the copy but carry ``pos == -1`` until overwritten, so the
    masked attention never sees them.  The one-dispatch hold the engine
    took on each copied source block is dropped here (pushing it back if
    the donor entry was released mid-flight).

    ``pos``/``cursor`` pin to the prompt length and ``cur_tok`` takes
    ``first`` (the donor prefill's recorded argmax), so decode proceeds
    exactly as if this slot had been admitted through the prefill path —
    greedy decode only reads KV, and the aliased rows are bitwise the
    donor's, so outputs are bitwise identical to unshared admission.
    """
    bs = block_size
    b, sc = cache.pos.shape
    p_rows = cache.k.shape[1]
    p = cache.free.shape[0]
    m = cache.table.shape[1]
    nfull = jnp.where(mask, length // bs, 0)
    has_tail = mask & (tail_src >= 0)
    need = has_tail.astype(jnp.int32)
    offs = (jnp.cumsum(need) - need).astype(jnp.int32)
    src_i = jnp.clip(cache.n_free - 1 - offs, 0, p - 1)
    fresh = cache.free[src_i]  # (B,) popped tail copies (garbage where ~take)
    n_free = (cache.n_free - jnp.sum(need)).astype(jnp.int32)
    ref = cache.ref.at[jnp.where(has_tail, fresh, p)].set(1, mode="drop")
    # drop the engine's one-dispatch hold on each copied source block
    drops = jnp.zeros((p,), jnp.int32).at[
        jnp.where(has_tail, tail_src, p)
    ].add(1, mode="drop")
    free, n_free, ref = _release_refs(cache.free, n_free, ref, drops)
    cols = jnp.arange(m, dtype=jnp.int32)[None, :]
    t = jnp.where(cols < nfull[:, None], src_table, -1)
    t = jnp.where((cols == nfull[:, None]) & has_tail[:, None],
                  fresh[:, None], t)
    table = jnp.where(mask[:, None], t, cache.table)
    # COW row copy: all bs rows of each tail block, batched over slots
    off = jnp.arange(bs, dtype=jnp.int32)[None, :]
    srows = (jnp.clip(tail_src, 0, p - 1) * bs)[:, None] + off  # (B, bs)
    drows = jnp.where(has_tail[:, None], fresh[:, None] * bs + off,
                      p_rows).reshape(-1)

    def cpy(pool):
        if pool is None:
            return None
        return pool.at[:, drows].set(pool[:, srows.reshape(-1)], mode="drop")

    spos = jnp.arange(sc, dtype=jnp.int32)[None, :]
    pos_new = jnp.where(spos < length[:, None], spos, -1)
    new_cache = PagedKVCache(
        k=cpy(cache.k),
        v=cpy(cache.v),
        pos=jnp.where(mask[:, None], pos_new, cache.pos),
        cursor=jnp.where(mask, length.astype(jnp.int32), cache.cursor),
        table=table,
        free=free,
        n_free=n_free,
        ref=ref,
        k_scale=cpy(cache.k_scale),
        v_scale=cpy(cache.v_scale),
    )
    return new_cache, jnp.where(mask, first, cur_tok)


def paged_decode_step(params, cache: PagedKVCache, token, live,
                      cfg: TransformerConfig, block_size: int):
    """One decode step over the paged pool — same logical semantics (and
    bitwise-identical outputs for live slots) as :func:`decode_step` on a
    contiguous arena.

    The (B, Sc) per-slot view that the attention consumes is gathered from
    the pool through the block table
    (:func:`repro.models.transformer.attention.paged_decode_attention`);
    rows under unallocated blocks carry ``pos == -1`` and the masked
    softmax zeroes them exactly, so the attention math cannot tell the two
    layouts apart.  ``live`` (B,) gates allocation and writes: a dead
    slot's cursor drifts between admissions exactly as it does on the
    contiguous arena, but it never pops a free block or scatters a row.
    """
    b = token.shape[0]
    sc = cache.pos.shape[1]
    p_rows = cache.k.shape[1]
    bs = block_size
    m = cache.table.shape[1]
    cur = cache.cursor  # (B,) position of the token being processed
    # allocate the block holding position `cur` (at most 1 new per step)
    target = jnp.where(live, cur // bs + 1, 0)
    table, n_free, ref = alloc_blocks(
        cache.table, cache.free, cache.n_free, cache.ref, target, live, 1
    )
    rows = block_rows(table, bs)  # (B, Sc)
    ent = jnp.take_along_axis(
        table, jnp.clip(cur // bs, 0, m - 1)[:, None], axis=1
    )[:, 0]
    ok_w = live & (ent >= 0) & (cur < sc)
    # out-of-range destination == dropped write: dead/over-arena slots
    # scatter nowhere, deterministically
    wrow = jnp.where(ok_w, ent * bs + cur % bs, p_rows)
    slot_mask = (jnp.arange(sc, dtype=jnp.int32)[None, :] == cur[:, None]) \
        & live[:, None]  # live slots never wrap: cur < sc by retirement
    x = params["embed"][token][:, None]  # (B, 1, D)
    quant = cfg.kv_quant

    def body(x, inputs):
        p, kc, vc, ks, vs = inputs  # kc/vc (P, KV, dh) — this layer's pool
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _attn_proj(p, xn, cfg)
        q = attn.rope(q, cur[:, None], cfg.rope_theta)
        k = attn.rope(k, cur[:, None], cfg.rope_theta)
        if quant:
            kq, ksc = _quant_rows(k)
            vq, vsc = _quant_rows(v)
            kc = kc.at[wrow].set(kq[:, 0], mode="drop")
            vc = vc.at[wrow].set(vq[:, 0], mode="drop")
            ks = ks.at[wrow].set(ksc[:, 0], mode="drop")
            vs = vs.at[wrow].set(vsc[:, 0], mode="drop")
        else:
            kc = kc.at[wrow].set(k[:, 0], mode="drop")
            vc = vc.at[wrow].set(v[:, 0], mode="drop")
        pos = jnp.where(slot_mask, cur[:, None], cache.pos)
        o = attn.paged_decode_attention(
            q, kc, vc, rows, pos, cur, cfg.sliding_window,
            k_scale=ks, v_scale=vs,
        )
        x = x + (o.reshape(b, 1, -1) @ p["wo"]).astype(x.dtype)
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is None:
            y = (jax.nn.silu(xn @ p["w1"]) * (xn @ p["w3"])) @ p["w2"]
        else:
            y, _ = moe_ffn(p["moe"], xn.reshape(b, -1), cfg.moe)
            y = y[:, None]
        return x + y.astype(x.dtype), (kc, vc, ks, vs)

    xs = (params["layers"], cache.k, cache.v, cache.k_scale, cache.v_scale)
    if cfg.scan_layers:
        x, (kc, vc, ks, vs) = jax.lax.scan(body, x, xs)
    else:  # unrolled (cost-analysis variants)
        outs = []
        for i in range(cfg.n_layers):
            sl = jax.tree.map(lambda a: a[i], xs)
            x, o_i = body(x, sl)
            outs.append(o_i)
        cols = list(zip(*outs))
        kc, vc = jnp.stack(cols[0]), jnp.stack(cols[1])
        ks = jnp.stack(cols[2]) if quant else None
        vs = jnp.stack(cols[3]) if quant else None
    new_pos = jnp.where(slot_mask, cur[:, None], cache.pos)
    new_cache = PagedKVCache(k=kc, v=vc, pos=new_pos, cursor=cur + 1,
                             table=table, free=cache.free, n_free=n_free,
                             ref=ref, k_scale=ks, v_scale=vs)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x[:, 0].astype(jnp.float32) @ params["head"].astype(jnp.float32)
    return logits, new_cache


@functools.partial(jax.jit, static_argnames=("cfg", "block_size"))
def paged_serve_step(params, cache: PagedKVCache, token, live,
                     cfg: TransformerConfig, block_size: int):
    """Greedy paged decode step — :func:`serve_step` over the block pool."""
    logits, cache = paged_decode_step(params, cache, token, live, cfg,
                                      block_size)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache


def paged_verify_window(params, cache: PagedKVCache, tokens, live,
                        cfg: TransformerConfig, block_size: int):
    """:func:`verify_window` over the paged pool: allocate the blocks the
    W-token window crosses, scatter all W rows, score every position under
    the same per-position visibility mask.  Values written and the gathered
    per-slot view are identical to the contiguous merge, so greedy outputs
    are bitwise identical.  Returns (greedy (B, W), cache) with an
    UNCHANGED cursor — :func:`paged_verify_step` advances it by the
    accepted count, leaving rejected rows in place exactly like the
    contiguous arena (their ``pos`` exceeds later query positions until
    overwritten)."""
    b, w = tokens.shape
    sc = cache.pos.shape[1]
    p_rows = cache.k.shape[1]
    bs = block_size
    m = cache.table.shape[1]
    cur = cache.cursor  # (B,)
    positions = cur[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    writable = (positions < sc) & live[:, None]
    # a W-window starting anywhere inside a block spans at most
    # ceil(W/bs) + 1 blocks, so the allocator's static bound stays tiny
    hi = jnp.minimum(cur + w, sc)
    target = jnp.where(live, (hi + bs - 1) // bs, 0)
    max_new = min(m, (w + bs - 1) // bs + 1)
    table, n_free, ref = alloc_blocks(
        cache.table, cache.free, cache.n_free, cache.ref, target, live,
        max_new
    )
    rows = block_rows(table, bs)  # (B, Sc)
    ent = jnp.take_along_axis(
        table, jnp.clip(positions // bs, 0, m - 1), axis=1
    )  # (B, W)
    wrows = jnp.where(writable & (ent >= 0),
                      ent * bs + positions % bs, p_rows).reshape(-1)  # (B*W,)
    slot_mask = (jnp.arange(sc, dtype=jnp.int32)[None, None, :]
                 == jnp.clip(positions, 0, sc - 1)[..., None]) \
        & writable[..., None]  # (B, W, Sc)
    x = params["embed"][tokens]  # (B, W, D)
    new_pos = cache.pos
    for i in range(w):
        new_pos = jnp.where(slot_mask[:, i], positions[:, i:i + 1], new_pos)
    quant = cfg.kv_quant

    def body(x, inputs):
        p, kc, vc, ks, vs = inputs  # kc/vc (P, KV, dh)
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _attn_proj(p, xn, cfg)
        q = attn.rope(q, positions, cfg.rope_theta)
        k = attn.rope(k, positions, cfg.rope_theta)
        if quant:
            kq, ksc = _quant_rows(k)
            vq, vsc = _quant_rows(v)
            kc = kc.at[wrows].set(kq.reshape(b * w, -1, kq.shape[-1]),
                                  mode="drop")
            vc = vc.at[wrows].set(vq.reshape(b * w, -1, vq.shape[-1]),
                                  mode="drop")
            ks = ks.at[wrows].set(ksc.reshape(b * w, -1), mode="drop")
            vs = vs.at[wrows].set(vsc.reshape(b * w, -1), mode="drop")
        else:
            kc = kc.at[wrows].set(k.reshape(b * w, -1, k.shape[-1]),
                                  mode="drop")
            vc = vc.at[wrows].set(v.reshape(b * w, -1, v.shape[-1]),
                                  mode="drop")
        o = attn.paged_verify_attention(
            q, kc, vc, rows, new_pos, positions, cfg.sliding_window,
            k_scale=ks, v_scale=vs,
        )
        x = x + (o.reshape(b, w, -1) @ p["wo"]).astype(x.dtype)
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is None:
            y = (jax.nn.silu(xn @ p["w1"]) * (xn @ p["w3"])) @ p["w2"]
        else:
            y, _ = moe_ffn(p["moe"], xn.reshape(b * w, -1), cfg.moe)
            y = y.reshape(b, w, -1)
        return x + y.astype(x.dtype), (kc, vc, ks, vs)

    xs = (params["layers"], cache.k, cache.v, cache.k_scale, cache.v_scale)
    if cfg.scan_layers:
        x, (kc, vc, ks, vs) = jax.lax.scan(body, x, xs)
    else:  # unrolled (cost-analysis variants)
        outs = []
        for i in range(cfg.n_layers):
            sl = jax.tree.map(lambda a: a[i], xs)
            x, o_i = body(x, sl)
            outs.append(o_i)
        cols = list(zip(*outs))
        kc, vc = jnp.stack(cols[0]), jnp.stack(cols[1])
        ks = jnp.stack(cols[2]) if quant else None
        vs = jnp.stack(cols[3]) if quant else None
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x.astype(jnp.float32) @ params["head"].astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, W)
    new_cache = PagedKVCache(k=kc, v=vc, pos=new_pos, cursor=cache.cursor,
                             table=table, free=cache.free, n_free=n_free,
                             ref=ref, k_scale=ks, v_scale=vs)
    return greedy, new_cache


@functools.partial(jax.jit, static_argnames=("cfg", "eos_id", "block_size"))
def paged_verify_step(params, cache: PagedKVCache, tokens, room, live,
                      cfg: TransformerConfig, eos_id=None, *,
                      block_size: int):
    """:func:`verify_step` over the paged pool: verify W fed tokens, accept
    the greedy-matching prefix (same :func:`_accept_prefix` arithmetic, so
    acceptance is bitwise identical), advance the cursor past it."""
    b, w = tokens.shape
    greedy, cache = paged_verify_window(params, cache, tokens, live, cfg,
                                        block_size)
    accepted, cur_tok = _accept_prefix(greedy, tokens, room, w, eos_id)
    cache = dataclasses.replace(cache, cursor=cache.cursor + accepted)
    return greedy, accepted, cur_tok, cache
