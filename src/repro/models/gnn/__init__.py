"""GNN zoo: GIN, MeshGraphNet, GraphCast, EquiformerV2 (eSCN)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.gnn.config import GNNConfig
from repro.models.gnn.simple import (
    init_gin, apply_gin, init_mgn, apply_mgn, init_graphcast, apply_graphcast,
)
from repro.models.gnn.equiformer import init_equiformer, apply_equiformer

_REGISTRY = {
    "gin": (init_gin, apply_gin),
    "meshgraphnet": (init_mgn, apply_mgn),
    "graphcast": (init_graphcast, apply_graphcast),
    "equiformer_v2": (init_equiformer, apply_equiformer),
}


def init_gnn(key, cfg: GNNConfig):
    return _REGISTRY[cfg.arch][0](key, cfg)


def apply_gnn(params, cfg: GNNConfig, inputs) -> jnp.ndarray:
    return _REGISTRY[cfg.arch][1](params, cfg, inputs)


def gnn_loss(params, cfg: GNNConfig, inputs):
    """Masked node-level (or graph-level readout) regression MSE."""
    out = apply_gnn(params, cfg, inputs)
    if cfg.graph_readout and "graph_ids" in inputs:
        import jax

        gid = inputs["graph_ids"]
        n_graphs = inputs["targets"].shape[0]
        out = jax.ops.segment_sum(out, gid, num_segments=n_graphs)
    tgt = inputs["targets"]
    err = (out - tgt) ** 2
    nm = inputs.get("node_mask")
    if nm is not None and not cfg.graph_readout:
        err = err * nm[:, None]
        return jnp.sum(err) / jnp.maximum(jnp.sum(nm) * tgt.shape[-1], 1.0)
    return jnp.mean(err)


__all__ = ["GNNConfig", "init_gnn", "apply_gnn", "gnn_loss"]
