"""GIN, MeshGraphNet and GraphCast over the shared edge-list interface.

* GIN (Xu et al., 2019): h' = MLP((1 + eps) h + sum_nbr h), learnable eps.
* MeshGraphNet (Pfaff et al., 2021): per-layer edge MLP + node MLP with
  residuals and LayerNorm'd 2-hidden-layer MLPs.
* GraphCast (Lam et al., 2023): encoder MLP -> 16 interaction-network
  processor layers (same family as MGN) -> decoder MLP to n_vars.  When the
  assigned input shape supplies a single generic graph, the grid<->mesh
  bipartite mapping degenerates to the identity (documented in DESIGN.md) —
  the processor (the compute hot spot) is exercised unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.constraints import shard_hint
from repro.models.gnn.common import (
    apply_mlp, gather_src_dst, init_mlp, scatter_mean, scatter_sum,
)
from repro.models.gnn.config import GNNConfig


def _agg(cfg: GNNConfig):
    return scatter_mean if cfg.aggregator == "mean" else scatter_sum


# ---------------------------------------------------------------- GIN ------
def init_gin(key, cfg: GNNConfig):
    ks = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_in if i == 0 else d
        layers.append(
            {"mlp": init_mlp(ks[i], [d_in] + [d] * cfg.mlp_layers),
             "eps": jnp.zeros(())}
        )
    return {"layers": layers, "out": init_mlp(ks[-1], [d, cfg.d_out])}


def apply_gin(params, cfg: GNNConfig, inputs):
    h = inputs["node_feat"]
    n = h.shape[0]
    src, dst = inputs["edge_src"], inputs["edge_dst"]
    em = inputs.get("edge_mask")
    def one_layer(h, lp):
        hs, _ = gather_src_dst(h, src, dst, n)
        hs = shard_hint(hs, "dp", "model")
        agg = _agg(cfg)(hs, dst, n, em)
        h = apply_mlp(lp["mlp"], (1.0 + lp["eps"]) * h + agg, layernorm=True)
        return shard_hint(h, None, "model")

    for lp in params["layers"]:
        h = jax.checkpoint(one_layer)(h, lp)
    return apply_mlp(params["out"], h)


# ------------------------------------------------------- MeshGraphNet ------
def init_mgn(key, cfg: GNNConfig, d_edge_in: int = 4):
    ks = jax.random.split(key, cfg.n_layers * 2 + 3)
    d = cfg.d_hidden
    mlp_dims = [d] * cfg.mlp_layers + [d]
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "edge": init_mlp(ks[2 * i], [3 * d] + mlp_dims),
            "node": init_mlp(ks[2 * i + 1], [2 * d] + mlp_dims),
        })
    return {
        "enc_node": init_mlp(ks[-3], [cfg.d_in] + mlp_dims),
        "enc_edge": init_mlp(ks[-2], [d_edge_in] + mlp_dims),
        "layers": layers,
        "dec": init_mlp(ks[-1], [d, d, cfg.d_out]),
    }


def _edge_geometry(inputs, n):
    """Default edge features: endpoint feature delta summary (4 dims)."""
    if "edge_feat" in inputs and inputs["edge_feat"] is not None:
        return inputs["edge_feat"]
    h = inputs["node_feat"]
    hs, hd = gather_src_dst(h, inputs["edge_src"], inputs["edge_dst"], n)
    diff = (hs - hd)[:, :3] if h.shape[1] >= 3 else jnp.zeros((hs.shape[0], 3))
    norm = jnp.linalg.norm(diff, axis=-1, keepdims=True)
    return jnp.concatenate([diff, norm], axis=-1)


def apply_mgn(params, cfg: GNNConfig, inputs):
    n = inputs["node_feat"].shape[0]
    src, dst = inputs["edge_src"], inputs["edge_dst"]
    em = inputs.get("edge_mask")
    h = apply_mlp(params["enc_node"], inputs["node_feat"], layernorm=True)
    e = apply_mlp(params["enc_edge"], _edge_geometry(inputs, n), layernorm=True)
    h = shard_hint(h, None, "model")
    # edge state 2D-sharded (edges x features): keeps the concat + edge-MLP
    # shard-local — feature-replicated e made GSPMD all-gather (E, d) per
    # layer (880 GiB/step on ogb_products; §Perf cell 3)
    e = shard_hint(e, "dp", "model")

    def one_layer(carry, lp):
        h, e = carry
        hs, hd = gather_src_dst(h, src, dst, n)
        e = e + apply_mlp(lp["edge"], jnp.concatenate([e, hs, hd], -1), layernorm=True)
        e = shard_hint(e, "dp", "model")
        agg = _agg(cfg)(e, dst, n, em)
        h = h + apply_mlp(lp["node"], jnp.concatenate([h, agg], -1), layernorm=True)
        h = shard_hint(h, None, "model")
        return h, e

    # block-checkpoint groups of 4 layers: backward saves (h, e) only at
    # block boundaries instead of every MLP intermediate per edge (401 GiB
    # -> block-boundary cost on ogb_products; EXPERIMENTS.md §Perf)
    layers = params["layers"]
    for i in range(0, len(layers), 4):
        blk = layers[i : i + 4]

        def block_fn(carry, blk=blk):
            for lp in blk:
                carry = one_layer(carry, lp)
            return carry

        h, e = jax.checkpoint(block_fn)((h, e))
    return apply_mlp(params["dec"], h)


# ----------------------------------------------------------- GraphCast ------
def init_graphcast(key, cfg: GNNConfig, d_edge_in: int = 4):
    """Encoder–processor–decoder; inputs are the n_vars atmospheric stack."""
    k1, k2 = jax.random.split(key)
    proc_cfg = GNNConfig(
        name="proc", arch="meshgraphnet", n_layers=cfg.n_layers,
        d_hidden=cfg.d_hidden, d_in=cfg.n_vars, d_out=cfg.n_vars,
        mlp_layers=cfg.mlp_layers, aggregator=cfg.aggregator,
    )
    return init_mgn(k1, proc_cfg, d_edge_in=d_edge_in)


def apply_graphcast(params, cfg: GNNConfig, inputs):
    proc_cfg = GNNConfig(
        name="proc", arch="meshgraphnet", n_layers=cfg.n_layers,
        d_hidden=cfg.d_hidden, d_in=cfg.n_vars, d_out=cfg.n_vars,
        mlp_layers=cfg.mlp_layers, aggregator=cfg.aggregator,
    )
    # GraphCast predicts the state *increment*
    return inputs["node_feat"] + apply_mgn(params, proc_cfg, inputs)
