"""Shared GNN substrate: MLPs, edge-list message passing via segment ops.

JAX has no native sparse message passing (BCOO only) — per the assignment,
aggregation is built from `jnp.take` gathers over an edge index plus
`jax.ops.segment_sum` / `segment_max` scatters.  Edge lists carry a validity
mask so every shape is static (padded edges scatter zeros to a sentinel row).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

# §Perf knob (collective term): cast edge-aggregation partial sums to bf16
# BEFORE the GSPMD-inserted cross-shard reduction — halves all-reduce bytes
# for edge-parallel message passing at a bounded accuracy cost.
MSG_BF16 = os.environ.get("REPRO_MSG_BF16") == "1"


def init_mlp(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": (jax.random.normal(ks[i], (dims[i], dims[i + 1])) * dims[i] ** -0.5
                  ).astype(dtype)
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), dtype) for i in range(len(dims) - 1)
    }


def apply_mlp(p, x, *, act=jax.nn.relu, final_act=False, layernorm=False):
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    if layernorm:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-5)
    return x


def gather_src_dst(h, src, dst, n):
    """Gather endpoint features; sentinel row n (zeros) absorbs padded edges."""
    hp = jnp.concatenate([h, jnp.zeros((1,) + h.shape[1:], h.dtype)], axis=0)
    return hp[jnp.minimum(src, n)], hp[jnp.minimum(dst, n)]


def scatter_sum(msg, dst, n, edge_mask=None):
    if edge_mask is not None:
        msg = jnp.where(edge_mask[(...,) + (None,) * (msg.ndim - 1)], msg, 0)
    if MSG_BF16:
        dtype = msg.dtype
        out = jax.ops.segment_sum(
            msg.astype(jnp.bfloat16), jnp.minimum(dst, n), num_segments=n + 1
        )
        return out[:n].astype(dtype)
    return jax.ops.segment_sum(msg, jnp.minimum(dst, n), num_segments=n + 1)[:n]


def scatter_mean(msg, dst, n, edge_mask=None):
    s = scatter_sum(msg, dst, n, edge_mask)
    ones = jnp.ones((msg.shape[0],), msg.dtype)
    if edge_mask is not None:
        ones = ones * edge_mask.astype(msg.dtype)
    cnt = jax.ops.segment_sum(ones, jnp.minimum(dst, n), num_segments=n + 1)[:n]
    return s / jnp.maximum(cnt[(...,) + (None,) * (msg.ndim - 1)], 1.0)


def segment_softmax(logits, dst, n, edge_mask=None):
    """Per-destination softmax over incoming edges.  logits (E, ...)."""
    seg = jnp.minimum(dst, n)
    if edge_mask is not None:
        logits = jnp.where(
            edge_mask[(...,) + (None,) * (logits.ndim - 1)], logits, -1e30
        )
    mx = jax.ops.segment_max(logits, seg, num_segments=n + 1)
    ex = jnp.exp(logits - mx[seg])
    if edge_mask is not None:
        ex = jnp.where(edge_mask[(...,) + (None,) * (ex.ndim - 1)], ex, 0)
    den = jax.ops.segment_sum(ex, seg, num_segments=n + 1)
    return ex / jnp.maximum(den[seg], 1e-20)
