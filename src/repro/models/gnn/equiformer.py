"""EquiformerV2-style equivariant graph attention via eSCN convolutions.

Structure per layer (arXiv:2306.12059, adapted per DESIGN.md §2/§6):

  1. per-edge: gather source irreps features X[src] (K, C), rotate into the
     edge frame with the quantized Wigner LUT (K = (l_max+1)^2);
  2. restrict to |m| <= m_max coefficients and apply the SO(2) linear map
     (the eSCN O(L^3) trick): per-m pair mixing with rotation-equivariant
     (W1, W2) structure, modulated by radial-basis edge scalars;
  3. multi-head attention: logits from the invariant (l=0) channels,
     segment-softmax over incoming edges;
  4. rotate messages back (D^T), scatter-sum to targets;
  5. node update: equivariant RMS norm per l-block, gated FFN (sigmoid gate
     from l=0 channels scales l>0 blocks).

Edges are processed in fixed-size chunks under `lax.scan` so the (E, K, K)
Wigner gather never materializes for huge graphs (ogb_products: 62M edges).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.constraints import shard_hint
from repro.models.gnn.common import init_mlp, apply_mlp
from repro.models.gnn.config import GNNConfig
from repro.models.gnn.wigner import m_index_sets

N_RBF = 16


def _pad_rows(x, rows, K):
    """Scatter (…, n_rows, C) back into zero-padded (…, K, C)."""
    out = jnp.zeros(x.shape[:-2] + (K,) + x.shape[-1:], x.dtype)
    return out.at[..., rows, :].set(x)


def init_equiformer(key, cfg: GNNConfig):
    C = cfg.d_hidden
    K = cfg.sphere_k
    msets = m_index_sets(cfg.l_max, cfg.m_max)
    ks = jax.random.split(key, cfg.n_layers * 8 + 3)
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[i], 2 * (cfg.m_max + 1) + 4)
        so2 = {}
        for m in range(cfg.m_max + 1):
            n_l = len(msets[m][0])
            dim = n_l * C
            so2[f"w1_{m}"] = (
                jax.random.normal(kk[2 * m], (dim, dim)) * dim**-0.5
            ).astype(jnp.float32)
            if m > 0:
                so2[f"w2_{m}"] = (
                    jax.random.normal(kk[2 * m + 1], (dim, dim)) * dim**-0.5
                ).astype(jnp.float32)
        layers.append({
            "so2": so2,
            "radial": init_mlp(kk[-4], [N_RBF + C, C, (cfg.m_max + 1)]),
            "attn": init_mlp(kk[-3], [2 * C + N_RBF, C, cfg.n_heads]),
            "gate": init_mlp(kk[-2], [C, C, (cfg.l_max + 1) * C]),
            "ln_scale": jnp.ones((cfg.l_max + 1, C)),
        })
    return {
        "embed": init_mlp(ks[-3], [cfg.d_in, C]),
        "layers": layers,
        "out": init_mlp(ks[-2], [C, C, cfg.d_out]),
    }


def _so2_conv(lp, xm, msets, radial_mod, C):
    """Apply the SO(2) linear map in the rotated frame.

    xm: dict m -> (B, n_l, C) cos part [+ (B, n_l, C) sin part for m>0].
    radial_mod: (B, m_max+1) multiplicative radial modulation per m.
    """
    out = {}
    for m, (cos_rows, sin_rows) in msets.items():
        n_l = len(cos_rows)
        w1 = lp["so2"][f"w1_{m}"]
        mod = radial_mod[:, m][:, None, None]
        if m == 0:
            xc = xm[0][0]  # (B, n_l, C)
            yc = (xc.reshape(xc.shape[0], -1) @ w1).reshape(xc.shape)
            out[0] = (yc * mod,)
        else:
            xc, xs = xm[m]
            w2 = lp["so2"][f"w2_{m}"]
            fc, fs = xc.reshape(xc.shape[0], -1), xs.reshape(xs.shape[0], -1)
            yc = (fc @ w1 - fs @ w2).reshape(xc.shape)
            ys = (fc @ w2 + fs @ w1).reshape(xs.shape)
            out[m] = (yc * mod, ys * mod)
    return out


def _equi_rmsnorm(x, scale, l_max):
    """Per-l-block RMS norm of irreps features x (N, K, C)."""
    outs = []
    for l in range(l_max + 1):
        s, e = l * l, (l + 1) * (l + 1)
        blk = x[:, s:e]
        rms = jnp.sqrt(jnp.mean(blk * blk, axis=(1, 2), keepdims=True) + 1e-6)
        outs.append(blk / rms * scale[l][None, None, :])
    return jnp.concatenate(outs, axis=1)


def apply_equiformer(params, cfg: GNNConfig, inputs, *, edge_chunk: int = 16384):
    """inputs: node_feat (N,F), pos (N,3), edge_src/dst (E,), edge_mask (E,),
    wigner_lut (n_bins, K, K).  Returns (N, d_out)."""
    C, K, H = cfg.d_hidden, cfg.sphere_k, cfg.n_heads
    msets = m_index_sets(cfg.l_max, cfg.m_max)
    n = inputs["node_feat"].shape[0]
    src, dst = inputs["edge_src"], inputs["edge_dst"]
    emask = inputs.get("edge_mask", jnp.ones(src.shape, bool))
    pos = inputs["pos"]
    lut = inputs["wigner_lut"]
    n_theta = int(np.sqrt(lut.shape[0] // 2))
    n_phi = 2 * n_theta

    e_total = src.shape[0]
    chunk = min(edge_chunk, e_total)
    n_chunks = max(e_total // chunk, 1)
    assert n_chunks * chunk == e_total, (e_total, chunk)

    # edge geometry: direction bins + RBF(dist)
    pp = jnp.concatenate([pos, jnp.zeros((1, 3), pos.dtype)], 0)
    d_vec = pp[jnp.minimum(dst, n)] - pp[jnp.minimum(src, n)]
    dist = jnp.linalg.norm(d_vec, axis=-1)
    u = d_vec / jnp.maximum(dist, 1e-6)[:, None]
    theta = jnp.arccos(jnp.clip(u[:, 2], -1, 1))
    phi = jnp.arctan2(u[:, 1], u[:, 0])
    it = jnp.clip((theta / np.pi * n_theta).astype(jnp.int32), 0, n_theta - 1)
    ip = jnp.clip(
        ((phi + np.pi) / (2 * np.pi) * n_phi).astype(jnp.int32), 0, n_phi - 1
    )
    ebin = it * n_phi + ip  # (E,)
    centers = jnp.linspace(0.0, 4.0, N_RBF)
    rbf = jnp.exp(-((dist[:, None] - centers[None]) ** 2) * 4.0)  # (E, N_RBF)

    # initial irreps: invariant embedding in l=0, zeros elsewhere.
    # Irreps features are the dominant state: (N, K, C) — channel-sharded
    # over "model" (61 GiB replicated for ogb_products otherwise).
    h0 = apply_mlp(params["embed"], inputs["node_feat"])  # (N, C)
    x = jnp.zeros((n, K, C), h0.dtype).at[:, 0, :].set(h0)
    x = shard_hint(x, None, None, "model")

    def layer(x, lp):
        inv = x[:, 0, :]  # (N, C) invariant channels
        xp = shard_hint(
            jnp.concatenate([x, jnp.zeros((1, K, C), x.dtype)], 0),
            None, None, "model",
        )
        invp = jnp.concatenate([inv, jnp.zeros((1, C), inv.dtype)], 0)

        # ---- pass A: attention logits (invariant-only, no rotation needed)
        def logits_chunk(_, ci):
            sl = ci * chunk
            s_ = jax.lax.dynamic_slice_in_dim(src, sl, chunk)
            d_ = jax.lax.dynamic_slice_in_dim(dst, sl, chunk)
            m_ = jax.lax.dynamic_slice_in_dim(emask, sl, chunk)
            r_ = jax.lax.dynamic_slice_in_dim(rbf, sl, chunk)
            zi = jnp.concatenate(
                [invp[jnp.minimum(s_, n)], invp[jnp.minimum(d_, n)], r_], -1
            )
            lg = apply_mlp(lp["attn"], zi)  # (chunk, H)
            return None, jnp.where(m_[:, None], lg, -1e30)

        _, all_lg = jax.lax.scan(logits_chunk, None, jnp.arange(n_chunks))

        # segment max is a softmax STATISTIC: stop-grad is exact (the max
        # shift cancels in the softmax gradient), which keeps the scatter-max
        # scan out of autodiff — its per-chunk (N, H) carry residuals were
        # 295 GiB/device on ogb_products (§Perf iter 2->3).
        lg_sg = jax.lax.stop_gradient(all_lg)

        def mx_chunk(mx, ci):
            sl = ci * chunk
            d_ = jax.lax.dynamic_slice_in_dim(dst, sl, chunk)
            return mx.at[jnp.minimum(d_, n)].max(lg_sg[ci]), None

        mx, _ = jax.lax.scan(
            mx_chunk, jnp.full((n + 1, H), -1e30), jnp.arange(n_chunks)
        )
        mx = jax.lax.stop_gradient(mx)

        # denominator: rematerialized additive accumulation (same pattern as
        # pass B) — backward recomputes each chunk's exp instead of stashing
        def sm_partial(ci):
            sl = ci * chunk
            d_ = jax.lax.dynamic_slice_in_dim(dst, sl, chunk)
            seg = jnp.minimum(d_, n)
            ex = jnp.exp(all_lg[ci] - mx[seg])
            return jnp.zeros((n + 1, H)).at[seg].add(ex)

        def sm_chunk(sm, ci):
            return sm + jax.checkpoint(sm_partial)(ci), None

        sm, _ = jax.lax.scan(
            sm_chunk, jnp.zeros((n + 1, H)), jnp.arange(n_chunks)
        )

        # ---- pass B: rotated SO(2) messages, weighted scatter ---------------
        # The chunk body is rematerialized and the accumulation kept additive
        # OUTSIDE the checkpoint: backward then recomputes each chunk instead
        # of stashing per-edge (E, K, C) intermediates (measured 1.6 TiB/dev
        # for ogb_products before this; EXPERIMENTS.md §Perf).
        def chunk_partial(ci):
            sl = ci * chunk
            s_ = jax.lax.dynamic_slice_in_dim(src, sl, chunk)
            d_ = jax.lax.dynamic_slice_in_dim(dst, sl, chunk)
            b_ = jax.lax.dynamic_slice_in_dim(ebin, sl, chunk)
            r_ = jax.lax.dynamic_slice_in_dim(rbf, sl, chunk)
            seg = jnp.minimum(d_, n)
            D = shard_hint(lut[b_], "dp", None, None)  # (chunk, K, K)
            xs = shard_hint(xp[jnp.minimum(s_, n)], "dp", None, None)
            xr = jnp.einsum("eij,ejc->eic", D, xs)
            xm = {
                m: tuple(
                    xr[:, rows, :] for rows in msets[m] if len(rows)
                )
                for m in msets
            }
            rad_in = jnp.concatenate([r_, invp[jnp.minimum(s_, n)]], -1)
            rmod = apply_mlp(lp["radial"], rad_in)  # (chunk, m_max+1)
            ym = _so2_conv(lp, xm, msets, rmod, C)
            y = jnp.zeros((chunk, K, C), x.dtype)
            for m, (cos_rows, sin_rows) in msets.items():
                y = y.at[:, cos_rows, :].set(ym[m][0])
                if m > 0:
                    y = y.at[:, sin_rows, :].set(ym[m][1])
            yb = jnp.einsum("eji,ejc->eic", D, y)  # rotate back (D^T)
            alpha = jnp.exp(all_lg[ci] - mx[seg]) / jnp.maximum(sm[seg], 1e-20)
            yh = yb.reshape(chunk, K, H, C // H) * alpha[:, None, :, None]
            part = jnp.zeros((n + 1, K, C)).at[seg].add(yh.reshape(chunk, K, C))
            return shard_hint(part, None, None, "model")

        def msg_chunk(acc, ci):
            return acc + jax.checkpoint(chunk_partial)(ci), None

        acc0 = shard_hint(jnp.zeros((n + 1, K, C)), None, None, "model")
        acc, _ = jax.lax.scan(msg_chunk, acc0, jnp.arange(n_chunks))
        x = x + acc[:n]
        x = _equi_rmsnorm(x, lp["ln_scale"], cfg.l_max)
        x = shard_hint(x, None, None, "model")

        # gated FFN: l=0 through MLP; l>0 scaled by sigmoid gates
        gates = apply_mlp(lp["gate"], x[:, 0, :]).reshape(n, cfg.l_max + 1, C)
        outs = [x[:, 0:1, :] + jax.nn.silu(gates[:, 0:1, :])]
        for l in range(1, cfg.l_max + 1):
            s, e = l * l, (l + 1) * (l + 1)
            outs.append(x[:, s:e, :] * jax.nn.sigmoid(gates[:, l : l + 1, :]))
        return jnp.concatenate(outs, axis=1), None

    for lp in params["layers"]:
        x, _ = jax.checkpoint(layer)(x, lp)
    return apply_mlp(params["out"], x[:, 0, :])
