"""Unified GNN configuration across the four assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str  # gin | meshgraphnet | graphcast | equiformer_v2
    n_layers: int
    d_hidden: int
    d_in: int
    d_out: int
    aggregator: str = "sum"
    mlp_layers: int = 2
    # graphcast
    mesh_refinement: int = 6
    n_vars: int = 227
    # equiformer_v2
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_wigner_bins: int = 2048
    # graph-level readout (molecule shape)
    graph_readout: bool = False

    @property
    def sphere_k(self) -> int:
        return (self.l_max + 1) ** 2
