"""Real spherical harmonics + Wigner rotation LUT (host-side, numpy).

eSCN (Passaro & Zitnick, 2023; EquiformerV2 arXiv:2306.12059) rotates each
edge's features into a frame where the edge direction is the z-axis; the
SO(3) convolution then reduces to a block-diagonal SO(2) mixing over
|m| <= m_max — the O(L^6) -> O(L^3) trick.

TPU adaptation (DESIGN.md §2): per-edge Wigner matrices are *quantized* —
edge directions are bucketed into an (n_theta x n_phi) grid and the rotation
block matrix for each bucket is precomputed here once (least-squares fit of
the real-SH basis change, numerically robust, no e3nn dependency).  The model
gathers LUT[bin(edge)] on device.  Quantization error falls with bin count
(default 32x64 = 2048 bins) and is measured in tests.
"""
from __future__ import annotations

import numpy as np


def real_sph_harm(l_max: int, dirs: np.ndarray) -> np.ndarray:
    """Orthonormal real spherical harmonics.  dirs (M, 3) unit -> (M, K)."""
    x, y, z = dirs[:, 0], dirs[:, 1], dirs[:, 2]
    ct = np.clip(z, -1.0, 1.0)
    st = np.sqrt(np.maximum(1.0 - ct * ct, 0.0))
    phi = np.arctan2(y, x)
    m_count = dirs.shape[0]
    K = (l_max + 1) ** 2
    # associated Legendre P_l^m (no Condon–Shortley phase)
    P = np.zeros((l_max + 1, l_max + 1, m_count))
    P[0, 0] = 1.0
    for m in range(1, l_max + 1):
        P[m, m] = (2 * m - 1) * st * P[m - 1, m - 1]
    for m in range(l_max):
        P[m + 1, m] = (2 * m + 1) * ct * P[m, m]
    for m in range(l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[l, m] = ((2 * l - 1) * ct * P[l - 1, m] - (l + m - 1) * P[l - 2, m]) / (
                l - m
            )
    out = np.zeros((m_count, K))
    from math import factorial, pi, sqrt

    for l in range(l_max + 1):
        for m in range(l + 1):
            N = sqrt((2 * l + 1) / (4 * pi) * factorial(l - m) / factorial(l + m))
            if m == 0:
                out[:, l * l + l] = N * P[l, 0]
            else:
                out[:, l * l + l + m] = sqrt(2) * N * P[l, m] * np.cos(m * phi)
                out[:, l * l + l - m] = sqrt(2) * N * P[l, m] * np.sin(m * phi)
    return out


def _rot_to_z(theta: float, phi: float) -> np.ndarray:
    """Rotation matrix sending direction (theta, phi) to the +z axis."""
    ct, st = np.cos(theta), np.sin(theta)
    cp, sp = np.cos(phi), np.sin(phi)
    rz = np.array([[cp, sp, 0], [-sp, cp, 0], [0, 0, 1.0]])
    ry = np.array([[ct, 0, -st], [0, 1, 0], [st, 0, ct]])
    return ry @ rz


def wigner_block(l_max: int, R: np.ndarray, samples: np.ndarray,
                 Y_pinv_blocks: list) -> np.ndarray:
    """(K, K) block-diag real-SH rotation matrix for rotation R (via LSQ)."""
    K = (l_max + 1) ** 2
    Yr = real_sph_harm(l_max, samples @ R)  # Y(R^-1 n) since R orthogonal
    D = np.zeros((K, K))
    for l in range(l_max + 1):
        s, e = l * l, (l + 1) * (l + 1)
        D[s:e, s:e] = Y_pinv_blocks[l] @ Yr[:, s:e]
    return D


def build_wigner_lut(
    l_max: int, n_theta: int = 32, n_phi: int = 64, n_samples: int = 512,
    seed: int = 0,
) -> np.ndarray:
    """LUT (n_theta*n_phi, K, K): rotation-to-z Wigner blocks per direction bin."""
    rng = np.random.default_rng(seed)
    s = rng.standard_normal((n_samples, 3))
    s /= np.linalg.norm(s, axis=1, keepdims=True)
    Y = real_sph_harm(l_max, s)
    Y_pinv_blocks = [
        np.linalg.pinv(Y[:, l * l : (l + 1) * (l + 1)]) for l in range(l_max + 1)
    ]
    K = (l_max + 1) ** 2
    lut = np.zeros((n_theta * n_phi, K, K), np.float32)
    for it in range(n_theta):
        theta = (it + 0.5) / n_theta * np.pi
        for ip in range(n_phi):
            phi = (ip + 0.5) / n_phi * 2 * np.pi - np.pi
            lut[it * n_phi + ip] = wigner_block(
                l_max, _rot_to_z(theta, phi), s, Y_pinv_blocks
            )
    return lut


def direction_bins(dirs: np.ndarray, n_theta: int, n_phi: int) -> np.ndarray:
    """Quantize unit directions into LUT bins (numpy mirror of the jnp version)."""
    theta = np.arccos(np.clip(dirs[:, 2], -1, 1))
    phi = np.arctan2(dirs[:, 1], dirs[:, 0])
    it = np.clip((theta / np.pi * n_theta).astype(np.int64), 0, n_theta - 1)
    ip = np.clip(((phi + np.pi) / (2 * np.pi) * n_phi).astype(np.int64), 0, n_phi - 1)
    return (it * n_phi + ip).astype(np.int32)


# static index sets for the m-restricted SO(2) convolution -------------------
def m_index_sets(l_max: int, m_max: int):
    """Row indices (into the K-dim SH axis) participating per |m|.

    Returns dict m -> (cos_rows, sin_rows) with sin_rows empty for m == 0.
    Row for (l, m) lives at l^2 + l + m.
    """
    out = {}
    for m in range(m_max + 1):
        cos_rows = [l * l + l + m for l in range(m, l_max + 1)]
        sin_rows = [l * l + l - m for l in range(m, l_max + 1)] if m > 0 else []
        out[m] = (np.asarray(cos_rows, np.int32), np.asarray(sin_rows, np.int32))
    return out
