"""Wide & Deep (Cheng et al., 2016) with a hand-built EmbeddingBag.

JAX has no nn.EmbeddingBag — per the assignment, the bag lookup is
`jnp.take` over a row-sharded table + `segment_sum` over bag slots (multi-hot
fields), which IS the system's hot path at batch 65k x 40 fields.

The deep tower concatenates 40 x 32-dim bag embeddings + 13 dense features
through a 1024-512-256 MLP; the wide tower is a linear model over the same
sparse ids (per-row scalar weights) + dense features.  `retrieval_scores`
reuses the fused topk_sim kernel to score one query against 10^6 candidates
(the ``retrieval_cand`` shape — and exactly RGL's node-retrieval op).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.topk_sim import ops as topk_ops


@dataclasses.dataclass(frozen=True)
class WideDeepConfig:
    name: str = "wide-deep"
    n_sparse: int = 40  # number of sparse fields
    rows_per_field: int = 1_000_000  # embedding-table rows per field
    embed_dim: int = 32
    n_dense: int = 13
    mlp: tuple = (1024, 512, 256)
    bag_size: int = 4  # multi-hot ids per field (padded with -1)
    dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        return self.n_sparse * self.rows_per_field


def init_wide_deep(key, cfg: WideDeepConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, len(cfg.mlp) + 4)
    d_cat = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    dims = (d_cat,) + tuple(cfg.mlp) + (1,)
    mlp = {}
    for i in range(len(dims) - 1):
        mlp[f"w{i}"] = (
            jax.random.normal(ks[i], (dims[i], dims[i + 1])) * dims[i] ** -0.5
        ).astype(dtype)
        mlp[f"b{i}"] = jnp.zeros((dims[i + 1],), dtype)
    return {
        "table": (
            jax.random.normal(ks[-4], (cfg.total_rows, cfg.embed_dim)) * 0.01
        ).astype(dtype),
        "wide": jnp.zeros((cfg.total_rows,), dtype),
        "wide_dense": jnp.zeros((cfg.n_dense,), dtype),
        "bias": jnp.zeros((), dtype),
        "mlp": mlp,
    }


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Manual EmbeddingBag(sum).  ids (B, F, bag) int32, -1 padded; rows of
    field f live at [f * rows_per_field, (f+1) * rows_per_field) — caller
    pre-offsets ids.  Returns (B, F, embed_dim)."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    emb = jnp.take(table, safe.reshape(-1), axis=0).reshape(*ids.shape, -1)
    emb = jnp.where(valid[..., None], emb, 0.0)
    return emb.sum(axis=2)  # sum over bag slots


def wide_deep_logits(params, cfg: WideDeepConfig, dense, sparse_ids):
    """dense (B, n_dense); sparse_ids (B, n_sparse, bag) pre-offset, -1 pad."""
    b = dense.shape[0]
    bags = embedding_bag(params["table"], sparse_ids)  # (B, F, E)
    deep_in = jnp.concatenate([bags.reshape(b, -1), dense], axis=-1)
    x = deep_in
    n = len([k for k in params["mlp"] if k.startswith("w")])
    for i in range(n):
        x = x @ params["mlp"][f"w{i}"] + params["mlp"][f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    deep_logit = x[:, 0]
    # wide: per-row scalar weights, manual bag-sum
    valid = sparse_ids >= 0
    safe = jnp.where(valid, sparse_ids, 0)
    ww = jnp.take(params["wide"], safe.reshape(-1)).reshape(sparse_ids.shape)
    wide_logit = jnp.sum(jnp.where(valid, ww, 0.0), axis=(1, 2))
    wide_logit = wide_logit + dense @ params["wide_dense"]
    return deep_logit + wide_logit + params["bias"]


def wide_deep_loss(params, cfg: WideDeepConfig, dense, sparse_ids, labels):
    lg = wide_deep_logits(params, cfg, dense, sparse_ids)
    l = jnp.maximum(lg, 0) - lg * labels + jnp.log1p(jnp.exp(-jnp.abs(lg)))
    return jnp.mean(l)


def retrieval_scores(query: jnp.ndarray, cand_emb: jnp.ndarray, k: int = 100):
    """Score 1 (or Q) query tower output against n_candidates item embeddings
    via the fused similarity+top-k kernel — batched dot, never a loop."""
    q = query if query.ndim == 2 else query[None]
    return topk_ops.topk_similarity(q, cand_emb, k)
