from repro.models.recsys.wide_deep import (
    WideDeepConfig, init_wide_deep, wide_deep_logits, wide_deep_loss,
    retrieval_scores,
)

__all__ = [
    "WideDeepConfig", "init_wide_deep", "wide_deep_logits", "wide_deep_loss",
    "retrieval_scores",
]
