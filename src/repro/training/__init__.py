from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.loop import make_train_step, TrainLoop

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "make_train_step", "TrainLoop",
]
