"""Training loop: microbatched grad accumulation, compression hook, fault
hooks, async checkpointing.

`make_train_step` builds a jit-able step closed over loss_fn:
  * microbatching via `lax.scan` over gradient-accumulation slices (the
    activation-memory lever for the big LM configs),
  * optional error-feedback gradient compression before the DP reduction,
  * AdamW update with sharded (ZeRO-style) states.

`TrainLoop` drives it with StragglerMonitor + Heartbeat + AsyncCheckpointer
wired in; `tests/test_fault.py` kills and restores it mid-run.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed.compression import CompressionConfig, compress, init_residuals
from repro.distributed.fault import Heartbeat, StragglerMonitor
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def make_train_step(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics)
    opt_cfg: AdamWConfig,
    comp_cfg: CompressionConfig = CompressionConfig(),
    n_microbatches: int = 1,
    donate: bool = True,
):
    """Returns (init_state_fn, step_fn). State = {params, opt, residuals}."""

    def init_state(params):
        state = {"params": params, "opt": adamw_init(params, opt_cfg)}
        if comp_cfg.kind != "none":
            state["residuals"] = init_residuals(params)
        return state

    def grads_of(params, batch):
        if n_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return loss, metrics, grads

        def micro(acc, mb):
            (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            g_acc, l_acc = acc
            return (
                jax.tree.map(lambda a, b: a + b, g_acc, g),
                l_acc + loss,
            ), metrics

        # split batch leaves on axis 0 into (n_micro, b/n_micro, ...)
        mbs = jax.tree.map(
            lambda x: x.reshape((n_microbatches, x.shape[0] // n_microbatches)
                                + x.shape[1:]),
            batch,
        )
        g0 = jax.tree.map(jnp.zeros_like, params)
        (g, loss), metrics = jax.lax.scan(micro, (g0, 0.0), mbs)
        g = jax.tree.map(lambda x: x / n_microbatches, g)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss / n_microbatches, metrics, g

    def step(state, batch):
        loss, metrics, grads = grads_of(state["params"], batch)
        if comp_cfg.kind != "none":
            grads, residuals = compress(grads, state["residuals"], comp_cfg)
        params, opt, opt_metrics = adamw_update(
            grads, state["opt"], state["params"], opt_cfg
        )
        new_state = {"params": params, "opt": opt}
        if comp_cfg.kind != "none":
            new_state["residuals"] = residuals
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return init_state, step


@dataclasses.dataclass
class TrainLoop:
    step_fn: Callable  # jitted (state, batch) -> (state, metrics)
    data_iter: object  # iterator of batches
    checkpointer: Optional[object] = None  # AsyncCheckpointer
    checkpoint_every: int = 100
    monitor: StragglerMonitor = dataclasses.field(default_factory=StragglerMonitor)
    heartbeat: Heartbeat = dataclasses.field(default_factory=Heartbeat)
    host_id: int = 0
    log_every: int = 10
    log_fn: Callable = print

    def run(self, state, n_steps: int, start_step: int = 0):
        history = []
        for step in range(start_step, n_steps):
            t0 = time.monotonic()
            batch = next(self.data_iter)
            state, metrics = self.step_fn(state, batch)
            dt = time.monotonic() - t0
            self.monitor.record(self.host_id, dt)
            self.heartbeat.beat(self.host_id)
            if (step + 1) % self.log_every == 0:
                loss = float(metrics["loss"])
                history.append((step + 1, loss, dt))
                self.log_fn(f"step {step + 1}: loss={loss:.4f} ({dt * 1e3:.0f} ms)")
            if self.checkpointer and (step + 1) % self.checkpoint_every == 0:
                self.checkpointer.save(step + 1, state)
        return state, history
