"""Optimizers (self-contained, optax-style init/update pairs).

AdamW with configurable state dtype — bf16 first/second moments cut optimizer
HBM 4x, which is what lets grok-1-314B fit 256 x 16 GB v5e chips under 2D
weight sharding (see EXPERIMENTS.md §Dry-run).  States inherit the parameter
sharding (same pytree structure => same PartitionSpecs => ZeRO-style
sharded optimizer for free under pjit).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    state_dtype: str = "float32"  # "bfloat16" to halve m/v memory
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    lr = _schedule(cfg, step.astype(jnp.float32))
    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    dt = jnp.dtype(cfg.state_dtype)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(dt), v_new.astype(dt)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "lr": lr, "grad_norm": gnorm,
    }
