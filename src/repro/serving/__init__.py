from repro.serving.engine import ServeEngine, Request
from repro.serving.cache import RetrievalCache, CachedRetrieval
from repro.serving.rag_engine import RAGServeEngine, RAGRequest

__all__ = [
    "ServeEngine", "Request",
    "RetrievalCache", "CachedRetrieval",
    "RAGServeEngine", "RAGRequest",
]
