from repro.serving.config import ServingConfig
from repro.serving.engine import ServeEngine, Request
from repro.serving.cache import RetrievalCache, CachedRetrieval
from repro.serving.prefetch import AdmissionPrefetcher, PrefetchWave
from repro.serving.rag_engine import RAGServeEngine, RAGRequest
from repro.serving.router import ReplicaRouter
from repro.serving.stats import flatten_stats
from repro.serving.simulate import (
    DelayedRetrieval,
    FaultyReplica,
    FaultyRetrieval,
    LazyHostArray,
    ReplicaFault,
    RetrievalFault,
)

__all__ = [
    "ServingConfig", "flatten_stats",
    "ServeEngine", "Request",
    "RetrievalCache", "CachedRetrieval",
    "AdmissionPrefetcher", "PrefetchWave",
    "RAGServeEngine", "RAGRequest",
    "ReplicaRouter",
    "DelayedRetrieval", "FaultyRetrieval", "LazyHostArray", "RetrievalFault",
    "FaultyReplica", "ReplicaFault",
]
