from repro.serving.engine import ServeEngine, Request
from repro.serving.cache import RetrievalCache, CachedRetrieval
from repro.serving.prefetch import AdmissionPrefetcher, PrefetchWave
from repro.serving.rag_engine import RAGServeEngine, RAGRequest
from repro.serving.simulate import (
    DelayedRetrieval,
    FaultyRetrieval,
    LazyHostArray,
    RetrievalFault,
)

__all__ = [
    "ServeEngine", "Request",
    "RetrievalCache", "CachedRetrieval",
    "AdmissionPrefetcher", "PrefetchWave",
    "RAGServeEngine", "RAGRequest",
    "DelayedRetrieval", "FaultyRetrieval", "LazyHostArray", "RetrievalFault",
]
