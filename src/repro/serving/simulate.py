"""Retrieval-latency simulation for benchmarking the async admission path.

JAX async dispatch makes a real ``retrieve_many`` overlap decode naturally,
but its latency on a tiny CPU test graph is microseconds — too small to
measure scheduling behavior against.  :class:`DelayedRetrieval` wraps a real
pipeline and emulates a configurable retrieval cost with the *same* blocking
semantics as async dispatch:

* the ``retrieve_many`` call returns immediately (dispatch is cheap),
* forcing a result to host (``np.asarray`` -> ``__array__``) blocks until
  ``cost_s`` seconds after dispatch — exactly like blocking on a device
  array whose computation is still running.

A sync admission schedule therefore pays the full ``cost_s`` at every wave
boundary, while the prefetch schedule hides whatever fraction of it decode
steps cover — which is the comparison ``benchmarks/async_serving.py`` and
the overlap-oracle tests need to make deterministically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np


class LazyHostArray:
    """A host array that pretends to still be computing until ``ready_at``.

    ``np.asarray`` (via ``__array__``) blocks until the deadline passes —
    the same contract as forcing an in-flight JAX device array.  ``events``
    (if given) receives ``(tag, payload)`` tuples at force time, so tests
    can prove *when* the collect-phase block happened relative to decode.
    """

    def __init__(self, data: np.ndarray, ready_at: float,
                 sleep: Callable[[float], None] = time.sleep,
                 now: Callable[[], float] = time.perf_counter,
                 events: Optional[list] = None, tag: str = "force"):
        self._data = np.asarray(data)
        self._ready_at = ready_at
        self._sleep = sleep
        self._now = now
        self._events = events
        self._tag = tag

    def __array__(self, dtype=None, copy=None):
        remaining = self._ready_at - self._now()
        if remaining > 0:
            self._sleep(remaining)
        if self._events is not None:
            self._events.append((self._tag, self._now()))
            self._events = None  # log the first force only
        a = self._data
        return a.astype(dtype) if dtype is not None else a

    def is_ready(self) -> bool:
        """Same contract as ``jax.Array.is_ready``: True once forcing would
        not block.  This is what lets ``AdmissionPrefetcher.ready_index``
        skip past a still-computing wave in continuous admission."""
        return self._now() >= self._ready_at


@dataclasses.dataclass
class _LazySubgraph:
    """Duck-typed stand-in for ``Subgraph`` whose fields force lazily."""

    nodes: LazyHostArray
    mask: LazyHostArray
    dist: LazyHostArray


class DelayedRetrieval:
    """Pipeline proxy: real retrieval results, simulated device latency.

    Forwards everything to ``inner`` but rewrites ``retrieve_many`` so the
    returned arrays only become forceable ``cost_s`` seconds after dispatch.
    ``events`` receives ``("launch", t)`` per dispatch and ``("force", t)``
    on the first field forced per wave.

    ``cost_fn`` (optional) prices each *row*: it maps one query embedding to
    that row's retrieval cost in seconds, and the wave's deadline is the max
    over its rows — a batched dispatch finishes when its slowest member
    does.  This is the knob that makes wave admission's head-of-line
    blocking measurable: one expensive row holds every wave-mate's
    admission, while continuous (per-request) admission pays it on that
    request alone.  When ``cost_fn`` is None every wave costs ``cost_s``.
    """

    def __init__(self, inner, cost_s: float,
                 events: Optional[list] = None,
                 cost_fn: Optional[Callable[[np.ndarray], float]] = None):
        self.inner = inner
        self.cost_s = cost_s
        self.events = events
        self.cost_fn = cost_fn
        self.dispatches = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def retrieve_many(self, query_embs, *, batch_size=None, encoder=None):
        sub, seeds, n_valid = self.inner.retrieve_many(
            query_embs, batch_size=batch_size, encoder=encoder
        )
        self.dispatches += 1
        now = time.perf_counter()
        if self.events is not None:
            self.events.append(("launch", now))
        if self.cost_fn is not None:
            qe = np.asarray(query_embs)
            cost = max((float(self.cost_fn(row)) for row in qe), default=0.0)
        else:
            cost = self.cost_s
        ready_at = now + cost
        # force the real device arrays NOW (the tiny graph's true cost is
        # negligible) and re-wrap as host arrays gated on the deadline
        lazy = _LazySubgraph(
            nodes=LazyHostArray(np.asarray(sub.nodes), ready_at,
                                events=self.events),
            mask=LazyHostArray(np.asarray(sub.mask), ready_at),
            dist=LazyHostArray(np.asarray(sub.dist), ready_at),
        )
        return lazy, LazyHostArray(np.asarray(seeds), ready_at), n_valid
