"""Retrieval-latency and retrieval-fault simulation for the serving stack.

JAX async dispatch makes a real ``retrieve_many`` overlap decode naturally,
but its latency on a tiny CPU test graph is microseconds — too small to
measure scheduling behavior against.  :class:`DelayedRetrieval` wraps a real
pipeline and emulates a configurable retrieval cost with the *same* blocking
semantics as async dispatch:

* the ``retrieve_many`` call returns immediately (dispatch is cheap),
* forcing a result to host (``np.asarray`` -> ``__array__``) blocks until
  ``cost_s`` seconds after dispatch — exactly like blocking on a device
  array whose computation is still running.

A sync admission schedule therefore pays the full ``cost_s`` at every wave
boundary, while the prefetch schedule hides whatever fraction of it decode
steps cover — which is the comparison ``benchmarks/async_serving.py`` and
the overlap-oracle tests need to make deterministically.

:class:`FaultyRetrieval` extends the same idea to *failure* injection: a
seeded per-row fault schedule (each exact query embedding deterministically
maps to one fault type or to "clean") makes every production failure mode
reproducibly testable on CPU:

* ``dispatch`` — ``retrieve_many`` raises before returning (the jitted call
  itself died: OOM, bad shard, poisoned input reaching the kernel),
* ``force``    — dispatch succeeds but blocking on the result raises (an
  async device error surfacing at the host sync),
* ``stuck``    — the result never becomes ready (``is_ready()`` stays
  False forever; a force before readiness raises instead of hanging so an
  unconfigured timeout fails loudly rather than deadlocking the test),
* ``corrupt``  — the result lands "successfully" but carries out-of-range
  node ids under the valid mask (a wrong-shard answer / memory stomp).

``fails_per_row`` bounds how many dispatches a faulty row poisons before it
heals (None = permanent), which is what makes bounded-retry success paths
and retry-exhaustion ladder paths separately testable.

:class:`FaultyReplica` lifts fault injection one level up, to the **replica
fault domain**: it wraps a whole ``RAGServeEngine`` and makes ``step()``
itself fail on a seeded step schedule —

* ``crash`` — ``step()`` raises :class:`ReplicaFault` from ``crash_step``
  on, forever (a dead process / lost host),
* ``flap``  — ``step()`` raises over ``[crash_step, heal_step)`` and then
  works again (a restarting process; the router's revival probe is what
  brings it back into rotation),
* ``grey``  — ``step()`` works but each call pays an injected ``slow_s``
  delay (a degraded-but-alive host; pair it with a
  :class:`FaultyRetrieval`-wrapped pipeline on that one replica so its
  fault counters climb and the router's health scoring can see it).

Everything else (submit/abort/stats/...) passes through to the wrapped
engine, so :class:`repro.serving.router.ReplicaRouter` drives a
``FaultyReplica`` exactly like a healthy replica until the schedule fires.
All clocks are injectable (``sleep_fn``/``now_fn``) so chaos tests never
wall-sleep.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable, Optional

import numpy as np


class RetrievalFault(RuntimeError):
    """An injected retrieval failure (see :class:`FaultyRetrieval`)."""


class ReplicaFault(RuntimeError):
    """An injected replica-level failure (see :class:`FaultyReplica`)."""


class LazyHostArray:
    """A host array that pretends to still be computing until ``ready_at``.

    ``np.asarray`` (via ``__array__``) blocks until the deadline passes —
    the same contract as forcing an in-flight JAX device array.  ``events``
    (if given) receives ``(tag, payload)`` tuples at force time, so tests
    can prove *when* the collect-phase block happened relative to decode.

    ``exc`` (if given) is raised at force time instead of returning data —
    the async-device-error-surfacing-at-host-sync failure mode.  An infinite
    ``ready_at`` models a stuck computation: ``is_ready()`` never flips, and
    forcing raises immediately (a real device array would block forever;
    raising keeps an unconfigured-timeout bug loud instead of hung).
    """

    def __init__(self, data: np.ndarray, ready_at: float,
                 sleep: Callable[[float], None] = time.sleep,
                 now: Callable[[], float] = time.perf_counter,
                 events: Optional[list] = None, tag: str = "force",
                 exc: Optional[Exception] = None):
        self._data = np.asarray(data)
        self._ready_at = ready_at
        self._sleep = sleep
        self._now = now
        self._events = events
        self._tag = tag
        self._exc = exc

    def __array__(self, dtype=None, copy=None):
        if np.isinf(self._ready_at):
            raise RetrievalFault(
                "stuck retrieval row forced before ready (configure "
                "retrieval_timeout_s to shed it instead)"
            )
        remaining = self._ready_at - self._now()
        if remaining > 0:
            self._sleep(remaining)
        if self._events is not None:
            self._events.append((self._tag, self._now()))
            self._events = None  # log the first force only
        if self._exc is not None:
            raise self._exc
        a = self._data
        return a.astype(dtype) if dtype is not None else a

    def is_ready(self) -> bool:
        """Same contract as ``jax.Array.is_ready``: True once forcing would
        not block.  This is what lets ``AdmissionPrefetcher.ready_index``
        skip past a still-computing wave in continuous admission."""
        return self._now() >= self._ready_at


@dataclasses.dataclass
class _LazySubgraph:
    """Duck-typed stand-in for ``Subgraph`` whose fields force lazily."""

    nodes: LazyHostArray
    mask: LazyHostArray
    dist: LazyHostArray


class DelayedRetrieval:
    """Pipeline proxy: real retrieval results, simulated device latency.

    Forwards everything to ``inner`` but rewrites ``retrieve_many`` so the
    returned arrays only become forceable ``cost_s`` seconds after dispatch.
    ``events`` receives ``("launch", t)`` per dispatch and ``("force", t)``
    on the first field forced per wave.

    ``cost_fn`` (optional) prices each *row*: it maps one query embedding to
    that row's retrieval cost in seconds, and the wave's deadline is the max
    over its rows — a batched dispatch finishes when its slowest member
    does.  This is the knob that makes wave admission's head-of-line
    blocking measurable: one expensive row holds every wave-mate's
    admission, while continuous (per-request) admission pays it on that
    request alone.  When ``cost_fn`` is None every wave costs ``cost_s``.
    """

    def __init__(self, inner, cost_s: float,
                 events: Optional[list] = None,
                 cost_fn: Optional[Callable[[np.ndarray], float]] = None,
                 now_fn: Callable[[], float] = time.perf_counter,
                 sleep_fn: Callable[[float], None] = time.sleep):
        self.inner = inner
        self.cost_s = cost_s
        self.events = events
        self.cost_fn = cost_fn
        self.now_fn = now_fn
        self.sleep_fn = sleep_fn
        self.dispatches = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def retrieve_many(self, query_embs, *, batch_size=None, encoder=None):
        res = self.inner.retrieve_many(
            query_embs, batch_size=batch_size, encoder=encoder
        )
        sub, seeds, n_valid = res.sub, res.seeds, res.n_valid
        self.dispatches += 1
        now = self.now_fn()
        if self.events is not None:
            self.events.append(("launch", now))
        if self.cost_fn is not None:
            qe = np.asarray(query_embs)
            cost = max((float(self.cost_fn(row)) for row in qe), default=0.0)
        else:
            cost = self.cost_s
        ready_at = now + cost
        # force the real device arrays NOW (the tiny graph's true cost is
        # negligible) and re-wrap as host arrays gated on the deadline
        kw = dict(sleep=self.sleep_fn, now=self.now_fn)
        lazy = _LazySubgraph(
            nodes=LazyHostArray(np.asarray(sub.nodes), ready_at,
                                events=self.events, **kw),
            mask=LazyHostArray(np.asarray(sub.mask), ready_at, **kw),
            dist=LazyHostArray(np.asarray(sub.dist), ready_at, **kw),
        )
        return dataclasses.replace(
            res, sub=lazy,
            seeds=LazyHostArray(np.asarray(seeds), ready_at, **kw),
            n_valid=n_valid,
        )


class FaultyRetrieval:
    """Pipeline proxy with a seeded, per-row, reproducible fault schedule.

    Each exact query embedding deterministically maps (via a keyed hash of
    its float32 bytes + ``seed``) to one of ``fault_types`` with probability
    ``fault_rate``, or to "clean".  The same embedding therefore faults the
    same way in every run, every wave composition, and every admission
    schedule — which is what lets the chaos tests compare a faulted run's
    fault-free subset bitwise against a no-fault run.

    Fault semantics per dispatch (a dispatch is doomed by ANY scheduled
    fault among its rows; per-request isolation is the *retry layer's* job —
    it re-dispatches failed miss-groups one by one, see
    :class:`repro.serving.prefetch.AdmissionPrefetcher`):

    * ``dispatch`` — ``retrieve_many`` raises :class:`RetrievalFault`.
    * ``force``    — arrays return, but forcing them raises.
    * ``stuck``    — arrays never become ready (``is_ready()`` False
      forever); forcing one raises instead of hanging.
    * ``corrupt``  — arrays force fine but the faulty row's node ids are
      rewritten out of range (``>= n_nodes``) under the valid mask.

    ``fails_per_row``: how many dispatches each faulty row poisons before it
    heals (None = permanent).  ``fails_per_row=1`` + retries makes transient
    recovery testable; permanent faults exercise the degradation ladder.
    ``cost_s`` adds the usual simulated latency on clean dispatches.
    """

    FAULT_TYPES = ("dispatch", "force", "stuck", "corrupt")

    def __init__(self, inner, *, seed: int = 0, fault_rate: float = 0.2,
                 cost_s: float = 0.0,
                 fault_types: tuple = FAULT_TYPES,
                 fails_per_row: Optional[int] = None,
                 events: Optional[list] = None,
                 now_fn: Callable[[], float] = time.perf_counter,
                 sleep_fn: Callable[[float], None] = time.sleep):
        if not 0.0 <= fault_rate <= 1.0:
            raise ValueError(f"fault_rate must be in [0, 1], got {fault_rate}")
        unknown = [t for t in fault_types if t not in self.FAULT_TYPES]
        if unknown:
            raise ValueError(
                f"unknown fault types {unknown}; expected from "
                f"{self.FAULT_TYPES}"
            )
        self.inner = inner
        self.seed = int(seed)
        self.fault_rate = float(fault_rate)
        self.cost_s = float(cost_s)
        self.fault_types = tuple(fault_types)
        self.fails_per_row = fails_per_row
        self.events = events
        self.now_fn = now_fn
        self.sleep_fn = sleep_fn
        self.dispatches = 0
        self.injected = {t: 0 for t in self.FAULT_TYPES}
        self._fail_left: dict = {}  # row key -> remaining faulty dispatches

    def __getattr__(self, name):
        return getattr(self.inner, name)

    @staticmethod
    def _row_key(row: np.ndarray) -> bytes:
        return np.ascontiguousarray(
            np.asarray(row, np.float32)
        ).ravel().tobytes()

    def fault_of(self, query_emb) -> Optional[str]:
        """The *scheduled* fault type for this exact embedding (ignoring
        ``fails_per_row`` healing), or None if the row is clean.  Tests use
        this to partition requests into faulty / fault-free subsets."""
        h = hashlib.blake2b(
            self._row_key(query_emb), digest_size=8,
            key=str(self.seed).encode(),
        ).digest()
        rng = np.random.default_rng(int.from_bytes(h, "little"))
        if not self.fault_types or rng.random() >= self.fault_rate:
            return None
        return self.fault_types[int(rng.integers(len(self.fault_types)))]

    def _active_fault(self, row: np.ndarray) -> Optional[str]:
        """Scheduled fault, unless the row has already spent its
        ``fails_per_row`` budget (healed)."""
        ft = self.fault_of(row)
        if ft is None or self.fails_per_row is None:
            return ft
        left = self._fail_left.get(self._row_key(row), self.fails_per_row)
        return ft if left > 0 else None

    def _consume(self, row: np.ndarray, ft: str) -> None:
        self.injected[ft] += 1
        if self.fails_per_row is not None:
            k = self._row_key(row)
            self._fail_left[k] = \
                self._fail_left.get(k, self.fails_per_row) - 1

    def retrieve_many(self, query_embs, *, batch_size=None, encoder=None):
        q = np.asarray(query_embs, np.float32)
        if q.ndim == 1:
            q = q[None]
        self.dispatches += 1
        faults = [(q[i], self._active_fault(q[i])) for i in range(q.shape[0])]
        now = self.now_fn()
        if self.events is not None:
            self.events.append(("launch", now))

        # a dispatch fault kills the call before the inner pipeline runs
        dispatch_rows = [r for r, ft in faults if ft == "dispatch"]
        if dispatch_rows:
            for r in dispatch_rows:
                self._consume(r, "dispatch")
            raise RetrievalFault(
                f"injected dispatch fault ({len(dispatch_rows)} row(s))"
            )

        res = self.inner.retrieve_many(
            q, batch_size=batch_size, encoder=encoder
        )
        sub, seeds, n_valid = res.sub, res.seeds, res.n_valid
        nodes = np.asarray(sub.nodes).copy()
        mask = np.asarray(sub.mask)
        dist = np.asarray(sub.dist)
        seeds_np = np.asarray(seeds)

        corrupt_rows = [i for i, (_, ft) in enumerate(faults)
                        if ft == "corrupt"]
        if corrupt_rows:
            n_nodes = int(self.inner.node_emb.shape[0])
            for i in corrupt_rows:
                # out-of-range ids under the valid mask: exactly what a
                # wrong-shard answer or a memory stomp would hand back
                nodes[i, mask[i]] = n_nodes + 1 + i
                self._consume(q[i], "corrupt")

        ready_at = now + self.cost_s
        exc = None
        stuck_rows = [r for r, ft in faults if ft == "stuck"]
        force_rows = [r for r, ft in faults if ft == "force"]
        if stuck_rows:
            for r in stuck_rows:
                self._consume(r, "stuck")
            ready_at = np.inf  # never ready; a batched result is one unit
        elif force_rows:
            for r in force_rows:
                self._consume(r, "force")
            exc = RetrievalFault(
                f"injected force fault ({len(force_rows)} row(s))"
            )

        kw = dict(sleep=self.sleep_fn, now=self.now_fn)
        lazy = _LazySubgraph(
            nodes=LazyHostArray(nodes, ready_at, events=self.events, exc=exc,
                                **kw),
            mask=LazyHostArray(mask, ready_at, exc=exc, **kw),
            dist=LazyHostArray(dist, ready_at, exc=exc, **kw),
        )
        return dataclasses.replace(
            res, sub=lazy,
            seeds=LazyHostArray(seeds_np, ready_at, exc=exc, **kw),
            n_valid=n_valid,
        )


class FaultyReplica:
    """Replica-level fault domain: a ``RAGServeEngine`` whose ``step()``
    fails on a seeded step schedule (see the module docstring for the three
    modes).  Everything but ``step()`` delegates to the wrapped engine, so a
    router drives this exactly like a healthy replica — and ``abort()`` on a
    crashed replica still works (abort is host-side reconciliation; the
    injected fault only poisons the step path, like a wedged event loop over
    an otherwise reachable process).

    ``steps`` counts every ``step()`` *attempt* (faulting calls included),
    so a ``flap`` replica heals after ``heal_step - crash_step`` failed
    attempts regardless of how often the router probes it.
    """

    MODES = ("crash", "flap", "grey")

    def __init__(self, engine, *, mode: str = "crash", crash_step: int = 0,
                 heal_step: Optional[int] = None, slow_s: float = 0.0,
                 sleep_fn: Callable[[float], None] = time.sleep):
        if mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, got {mode!r}")
        if mode == "flap":
            if heal_step is None or heal_step <= crash_step:
                raise ValueError(
                    f"flap needs heal_step > crash_step, got "
                    f"crash_step={crash_step} heal_step={heal_step}"
                )
        elif heal_step is not None:
            raise ValueError(f"heal_step only applies to flap, not {mode!r}")
        self.engine = engine
        self.mode = mode
        self.crash_step = int(crash_step)
        self.heal_step = None if heal_step is None else int(heal_step)
        self.slow_s = float(slow_s)
        self.sleep_fn = sleep_fn
        self.steps = 0  # step() attempts, faulting ones included
        self.faults_injected = 0

    def __getattr__(self, name):
        return getattr(self.engine, name)

    def _faulting(self, at: int) -> bool:
        if self.mode == "grey":
            return False
        if at < self.crash_step:
            return False
        return self.heal_step is None or at < self.heal_step

    def step(self) -> list:
        at = self.steps
        self.steps += 1
        if self._faulting(at):
            self.faults_injected += 1
            raise ReplicaFault(
                f"injected {self.mode} fault at replica step {at}"
            )
        if self.mode == "grey" and self.slow_s > 0 and at >= self.crash_step:
            self.sleep_fn(self.slow_s)  # degraded-but-alive host
        return self.engine.step()
