"""Double-buffered async admission retrieval for the fused RAG engine.

The sync serving path retrieves at wave boundaries: every admission wave
dispatches one jitted ``retrieve_many`` and immediately forces the result to
host (``np.asarray``), so the decode arena idles for the full retrieval
latency of every wave.  :class:`AdmissionPrefetcher` splits that into two
phases so wave *i+1*'s retrieval overlaps wave *i*'s decode steps:

* **launch** — cache lookup + intra-wave dedupe + ONE jitted
  ``RGLPipeline.retrieve_many`` dispatch.  Results are kept as *device
  arrays* (JAX async dispatch: the call returns before the computation
  finishes), so retrieval runs concurrently with whatever the engine does
  next — i.e. decode steps for the previous wave.
* **collect** — block on the device arrays (the only host sync), insert the
  finished entries into the :class:`~repro.serving.cache.RetrievalCache`,
  and hand ``(request, entry)`` pairs back for tokenization + admission.
  The engine runs collect only once decode slots free up.

Between launch and collect every miss key is marked *in-flight* on the
cache (``mark_inflight``), so a later launch never re-dispatches a query
that is retrieved-but-not-yet-collected: the request **defers** to the
owning wave and resolves — including its cache-hit accounting — at its own
wave's collect.  This keeps hit/miss totals identical to the sync schedule.

``depth`` bounds how many launched-but-uncollected waves may exist (the
backpressure window).  The serving default is 1 — classic double buffering:
one wave decoding, one wave retrieving.  ``depth >= 2`` pipelines multiple
retrieval waves and is where the in-flight set becomes load-bearing.

**Parity scope.**  At the default ``depth=1`` every launch happens after all
earlier collects, so cache state — contents, recency, per-entry hits — is
step-for-step identical to sync and parity is unconditional.  At
``depth >= 2`` wave *i+1*'s lookups intentionally run before wave *i*'s
puts (that is the pipelining); outputs stay bitwise identical, and hit/miss
totals still match except under capacity pressure, where the reordered
recency updates can pick different eviction victims than the sync schedule
would.  Serializing the lookups would restore that last corner but forfeit
the overlap, so the divergence is accepted and documented.

Telemetry (merged into ``RAGServeEngine.stats()``):

* ``waves`` / ``batches`` / ``queries`` — async-collected waves that
  dispatched a retrieval (miss-free waves are excluded — they have nothing
  in flight), retrieval dispatches, retrieved (deduped) queries.
* ``launch_seconds`` / ``block_seconds`` — host time in dispatch and in the
  collect-phase force; their sum is the *observable* retrieval cost.
* ``overlap_seconds`` — per-wave wall time between launch returning and
  collect starting: the window retrieval had to run behind decode.  This is
  an *upper bound* on hidden retrieval compute — if retrieval finished
  early, the tail of the window hid nothing.
* ``overlap_steps`` — engine steps executed between a wave's launch and its
  collect (the overlap-oracle signal).
* ``overlap_tokens`` — tokens *committed* by the engine between a wave's
  launch and its collect.  Under self-speculative decode one engine step
  commits up to ``draft_window`` tokens, so steps systematically undercount
  the decode work that hid the retrieval; accepted tokens are the
  schedule-invariant measure.
* ``hidden_frac`` — ``overlap / (overlap + block)``: the fraction of each
  wave's in-flight window not paid as blocking time.  Near 1.0 means
  retrieval was never the bottleneck (either genuinely hidden or simply
  cheap); judge the magnitude of the win from ``collect_block_seconds``
  against the sync schedule's ``retrieval_seconds``.

**Fault tolerance.**  Retrieval is a fallible, variable-latency stage, so
the collect phase carries a containment layer (all off by default):

* a wave whose arrays are not ready ``retrieval_timeout_s`` after its
  dispatch is declared timed out instead of blocked on forever;
* a failed wave — launch raise, force raise, timeout, or a row whose node
  ids fail validation (out of ``[0, n_nodes)`` under the mask) — relaunches
  **only its failed miss-groups**, each as its own size-1 dispatch, up to
  ``max_retries`` times with exponential ``retry_backoff_s`` backoff.
  Size-1 relaunches are the per-request isolation mechanism: one poison row
  can no longer doom its wave-mates' retries, and retrieval is row-
  independent so a size-1 result is bitwise identical to the row it would
  have occupied in the batch;
* a group that exhausts its retries *fails closed*: its requests come back
  with ``entry=None`` plus an error reason (the engine's degradation
  ladder takes it from there) — ``collect`` itself never raises for a
  retrieval fault, and the wave's in-flight cache keys are always released
  in a ``finally`` so no key is poisoned and no later wave defers to a
  dead owner.  A deferred request whose owner's group failed (or whose
  owner aborted) re-dispatches as its own size-1 group instead of waiting
  forever.

Counters: ``retries`` (relaunches), ``timeouts`` (timed-out waits),
``failures`` (groups that exhausted retries and went to the ladder).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.serving.cache import CachedRetrieval, RetrievalCache


@dataclasses.dataclass
class PrefetchWave:
    """One launched admission wave: requests + the uncollected device arrays."""

    reqs: list  # RAGRequest, arrival order
    entry_for: list  # per request: CachedRetrieval | None until resolved
    miss_groups: dict  # key -> [request indices], intra-wave dedupe
    deferred: list  # (request idx, key, owner wave's entries_by_key dict)
    sub: object = None  # Subgraph of device arrays (lazy) when misses exist
    seeds: object = None
    epoch: int = 0  # graph epoch the retrieval was launched against
    launched_at: float = 0.0  # clock at dispatch return
    launch_step: int = 0  # engine step counter at launch
    launch_tokens: int = 0  # engine emitted-token counter at launch
    entries_by_key: dict = dataclasses.field(default_factory=dict)
    launch_error: Optional[str] = None  # the batched dispatch itself raised
    error_for: list = dataclasses.field(default_factory=list)  # per request

    @property
    def has_misses(self) -> bool:
        return bool(self.miss_groups)


class AdmissionPrefetcher:
    """Launch/collect state machine over at most ``depth`` in-flight waves.

    The same launch/collect code drives both admission schedules: sync mode
    collects immediately after launch (blocking at the wave boundary, zero
    overlap by definition), prefetch mode leaves the wave in flight until
    the engine has free slots.
    """

    def __init__(
        self,
        pipeline,
        cache: RetrievalCache,
        *,
        wave_size: int,
        depth: int = 1,
        retrieval_timeout_s: Optional[float] = None,
        max_retries: int = 0,
        retry_backoff_s: float = 0.0,
        now_fn: Callable[[], float] = time.perf_counter,
        sleep_fn: Callable[[float], None] = time.sleep,
    ):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        if retrieval_timeout_s is not None and retrieval_timeout_s <= 0:
            raise ValueError(
                f"retrieval_timeout_s must be > 0, got {retrieval_timeout_s}"
            )
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.pipeline = pipeline
        self.cache = cache
        self.wave_size = wave_size
        self.depth = depth
        self.retrieval_timeout_s = retrieval_timeout_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self._now = now_fn
        self._sleep = sleep_fn
        self._waves: deque[PrefetchWave] = deque()
        # telemetry
        self.waves = 0  # async-collected waves (prefetch schedule only)
        self.batches = 0  # retrieval dispatches (both schedules)
        self.queries = 0  # deduped queries retrieved
        self.launch_seconds = 0.0
        self.block_seconds = 0.0
        self.overlap_seconds = 0.0
        self.overlap_steps = 0
        self.overlap_tokens = 0
        self.retries = 0  # size-1 relaunches of failed miss-groups
        self.timeouts = 0  # waits that hit retrieval_timeout_s
        self.failures = 0  # groups that exhausted retries (ladder-bound)

    @property
    def _n_nodes(self) -> Optional[int]:
        """Node-id validation bound for corrupt-result detection; ``None``
        skips the check.  Read per use (not cached at construction) so the
        bound tracks the live graph as online mutations add nodes."""
        n = getattr(self.pipeline, "n_valid_nodes", None)
        if n is not None:
            return int(n)
        emb = getattr(self.pipeline, "node_emb", None)
        return int(emb.shape[0]) if emb is not None else None

    @property
    def in_flight(self) -> int:
        return len(self._waves)

    @property
    def in_flight_requests(self) -> int:
        """Requests inside launched-but-uncollected waves (load signal for
        the replica router's health snapshot)."""
        return sum(len(w.reqs) for w in self._waves)

    def can_launch(self) -> bool:
        return len(self._waves) < self.depth

    def launched_before(self, step: int) -> bool:
        """Whether the oldest in-flight wave was launched before ``step`` —
        collecting a wave in the same step it launched forfeits its overlap
        window, so the engine only does that when the arena is idle."""
        return bool(self._waves) and self._waves[0].launch_step < step

    def _owner_entries(self, key: bytes) -> Optional[dict]:
        """The in-flight owner wave's (still-empty) entries_by_key dict —
        filled in place at that wave's collect, so holding the dict (not the
        wave) is enough for deferred fallback and retains nothing else."""
        for w in self._waves:
            if key in w.miss_groups:
                return w.entries_by_key
        return None

    # -- launch ---------------------------------------------------------------
    def launch(self, reqs: list, *, step: int = 0,
               tokens: int = 0) -> PrefetchWave:
        """Dispatch one admission wave without forcing any device array.

        Cache lookups and hit/miss accounting happen here, mirroring the
        sync schedule request-for-request: hits attach immediately, misses
        dedupe into one ``retrieve_many`` row per quantized key (every
        duplicate still counts its own miss, as in sync admission), and
        keys already in flight defer to the owning wave with no counter
        touched until that wave collects.
        """
        cache = self.cache
        t0 = self._now()
        wave = PrefetchWave(
            reqs=reqs, entry_for=[None] * len(reqs), miss_groups={},
            deferred=[], launch_step=step, launch_tokens=tokens,
        )
        for j, r in enumerate(reqs):
            k = cache.key(r.query_emb)
            if k in wave.miss_groups:  # intra-wave dup: miss, one dispatch row
                cache.get(r.query_emb)  # counts the duplicate's miss
                wave.miss_groups[k].append(j)
                continue
            if cache.is_inflight(k):  # owned by an earlier uncollected wave
                owner_entries = self._owner_entries(k)
                if owner_entries is None:
                    # not one of OUR waves — with a shared cache the owner
                    # may be another replica's prefetcher, which registered
                    # its entries_by_key dict at mark_inflight: defer to it
                    # exactly like an intra-engine owner (cross-replica
                    # single flight — one dispatch per unique query across
                    # the whole fleet)
                    owner_entries = cache.inflight_entries(k)
                if owner_entries is not None:
                    wave.deferred.append((j, k, owner_entries))
                    continue
                # in-flight marker with no registered owner anywhere: a
                # stale key from a dead engine that never collected — fall
                # through and treat as an ordinary miss so the query is
                # re-dispatched instead of deferring to a result that will
                # never arrive
            e = cache.get(r.query_emb)
            if e is not None:
                wave.entry_for[j] = e
                r.cache_hit = True
            else:
                wave.miss_groups[k] = [j]

        if wave.miss_groups:
            qe = np.stack(
                [reqs[idxs[0]].query_emb for idxs in wave.miss_groups.values()]
            ).astype(np.float32)
            # async dispatch: retrieve_many returns device arrays without a
            # host sync, so the scan/BFS/filter pipeline runs concurrently
            # with the decode steps the engine issues after this returns
            try:
                res = self.pipeline.retrieve_many(qe, batch_size=self.wave_size)
                wave.sub, wave.seeds = res.sub, res.seeds
                wave.epoch = res.epoch
                n_valid = res.n_valid
            except Exception as exc:  # data-plane fault: contained, retried
                # at collect (per-group, size-1) — never marked in-flight,
                # so a concurrent wave is free to dispatch the same key
                wave.launch_error = f"dispatch: {exc}"
            else:
                # mark only after a successful dispatch: a raise above must
                # not leave keys poisoned in the in-flight set forever.
                # Registering entries_by_key lets OTHER prefetchers sharing
                # this cache defer to this wave (cross-replica single flight)
                for k in wave.miss_groups:
                    cache.mark_inflight(k, wave.entries_by_key)
                self.batches += 1
                self.queries += n_valid
        wave.launched_at = self._now()
        self.launch_seconds += wave.launched_at - t0
        self._waves.append(wave)
        return wave

    # -- collect --------------------------------------------------------------
    @staticmethod
    def _arr_ready(a) -> bool:
        """True once a device array's computation has finished (so forcing
        it would not block).  Non-JAX arrays (numpy, simulator stand-ins
        without the method) are always ready."""
        is_ready = getattr(a, "is_ready", None)
        return bool(is_ready()) if callable(is_ready) else True

    def _wave_ready(self, wave: PrefetchWave) -> bool:
        """A wave is collectable without blocking when its retrieval arrays
        have landed AND every deferred request's owner has already collected
        (a deferred entry resolves from the owner's ``entries_by_key``,
        which is empty until then — collecting early would re-dispatch
        nothing but would mis-account the hit).  A wave whose dispatch
        raised, or whose wait has outlived ``retrieval_timeout_s``, is also
        "ready": collecting it runs the retry/failure path instead of
        stalling the scheduler behind a dead or stuck dispatch."""
        for _, k, owner_entries in wave.deferred:
            if owner_entries is not None and k not in owner_entries \
                    and self.cache.is_inflight(k):
                return False
        if not wave.has_misses or wave.launch_error is not None:
            return True
        if self.retrieval_timeout_s is not None and \
                self._now() >= wave.launched_at + self.retrieval_timeout_s:
            return True
        return all(
            self._arr_ready(a)
            for a in (wave.sub.nodes, wave.sub.mask, wave.sub.dist, wave.seeds)
        )

    def ready_index(self) -> Optional[int]:
        """Index of the oldest in-flight wave that can be collected without
        blocking (its device arrays are ready and its deferred owners have
        resolved), or ``None``.  This is the per-request admission hook: a
        continuous scheduler collects whichever wave is done instead of
        stalling on FIFO order behind one slow retrieval row."""
        for i, w in enumerate(self._waves):
            if self._wave_ready(w):
                return i
        return None

    def collect(self, *, step: int = 0, tokens: int = 0,
                sync: bool = False) -> list:
        """Block on the oldest wave and return ``(request, entry, error)``
        triples in arrival order (``entry`` is None exactly when ``error``
        is set — retries exhausted, the engine's degradation ladder takes
        over).  ``sync=True`` marks a launch-then-collect-immediately
        schedule: no overlap is accrued (there was no window to hide in)."""
        wave = self._waves.popleft()
        return self._collect(wave, step=step, tokens=tokens, sync=sync)

    def collect_at(self, index: int, *, step: int = 0,
                   tokens: int = 0) -> list:
        """Collect the wave at ``index`` (from :meth:`ready_index`) out of
        FIFO order.  Safe for any wave — a not-actually-ready wave simply
        blocks — but deferred consistency is only guaranteed for indices
        that :meth:`ready_index` returned (owner waves resolve first)."""
        wave = self._waves[index]
        del self._waves[index]
        return self._collect(wave, step=step, tokens=tokens, sync=False)

    # -- fault containment -----------------------------------------------------
    def _wait_ready(self, arrs, deadline: Optional[float]) -> bool:
        """Poll until every array is ready or ``deadline`` passes.  With no
        deadline, return immediately and let the force block (the original,
        timeout-free behavior)."""
        if deadline is None:
            return True
        while not all(self._arr_ready(a) for a in arrs):
            now = self._now()
            if now >= deadline:
                return False
            self._sleep(min(1e-3, max(deadline - now, 1e-6)))
        return True

    def _validate_row(self, nodes, mask) -> Optional[str]:
        """Corrupt-result check: every node id under the valid mask must be
        a real node.  Returns an error reason, or None when clean."""
        if self._n_nodes is None:
            return None
        ids = np.asarray(nodes)[np.asarray(mask, bool)]
        if ids.size and (int(ids.min()) < 0 or int(ids.max()) >= self._n_nodes):
            return (
                f"corrupt: node id out of range [0, {self._n_nodes}) "
                f"(min {int(ids.min())}, max {int(ids.max())})"
            )
        return None

    def _retrieve_once(self, emb) -> tuple:
        """One isolated size-1 dispatch + bounded wait + force + validate.
        Returns ``(entry, None)`` or ``(None, reason)`` — never raises for a
        data-plane fault."""
        t0 = self._now()
        try:
            res = self.pipeline.retrieve_many(
                np.asarray(emb, np.float32)[None], batch_size=1
            )
            sub, seeds, epoch = res.sub, res.seeds, res.epoch
        except Exception as exc:
            return None, f"dispatch: {exc}"
        self.batches += 1
        self.queries += 1
        arrs = (sub.nodes, sub.mask, sub.dist, seeds)
        deadline = None if self.retrieval_timeout_s is None else \
            t0 + self.retrieval_timeout_s
        if not self._wait_ready(arrs, deadline):
            self.timeouts += 1
            return None, f"timeout: not ready in {self.retrieval_timeout_s}s"
        try:
            nodes, mask, dist, seeds_np = (np.asarray(a) for a in arrs)
        except Exception as exc:
            return None, f"force: {exc}"
        err = self._validate_row(nodes[0], mask[0])
        if err is not None:
            return None, err
        return CachedRetrieval(
            nodes=nodes[0].copy(), mask=mask[0].copy(),
            dist=dist[0].copy(), seeds=seeds_np[0].copy(), epoch=epoch,
        ), None

    def _retry_group(self, emb, failed_attempts: int,
                     last_reason: str) -> tuple:
        """Relaunch one failed miss-group (size-1 dispatches) until it
        succeeds or the retry budget is spent.  ``failed_attempts`` counts
        dispatches already charged against this group (the batched launch
        counts as one; a deferred orphan adopting a dead owner's key starts
        at zero — its first dispatch is not a retry)."""
        reason = last_reason
        while failed_attempts <= self.max_retries:
            if failed_attempts > 0:
                if self.retry_backoff_s > 0:
                    self._sleep(
                        self.retry_backoff_s * (2 ** (failed_attempts - 1))
                    )
                self.retries += 1
            entry, reason = self._retrieve_once(emb)
            if entry is not None:
                return entry, None
            failed_attempts += 1
        self.failures += 1
        return None, reason

    def _resolve_misses(self, wave: PrefetchWave, entries: dict,
                        failures: dict) -> None:
        """Materialize every miss-group of ``wave`` into ``entries`` (key ->
        CachedRetrieval) or ``failures`` (key -> reason), via the batched
        arrays when they are healthy and the per-group retry path when not."""
        groups = list(wave.miss_groups.items())  # row order == launch order
        todo: dict = {}  # key -> last failure reason (needs retry)
        if wave.launch_error is not None:
            todo = {k: wave.launch_error for k, _ in groups}
        else:
            arrs = (wave.sub.nodes, wave.sub.mask, wave.sub.dist, wave.seeds)
            deadline = None if self.retrieval_timeout_s is None else \
                wave.launched_at + self.retrieval_timeout_s
            if not self._wait_ready(arrs, deadline):
                self.timeouts += 1
                reason = f"timeout: not ready in {self.retrieval_timeout_s}s"
                todo = {k: reason for k, _ in groups}
            else:
                try:
                    nodes, mask, dist, seeds_np = \
                        (np.asarray(a) for a in arrs)
                except Exception as exc:
                    todo = {k: f"force: {exc}" for k, _ in groups}
                else:
                    for row, (k, idxs) in enumerate(groups):
                        err = self._validate_row(nodes[row], mask[row])
                        if err is not None:
                            todo[k] = err
                            continue
                        entries[k] = CachedRetrieval(
                            nodes=nodes[row].copy(), mask=mask[row].copy(),
                            dist=dist[row].copy(), seeds=seeds_np[row].copy(),
                            epoch=wave.epoch,
                        )
        for k, idxs in groups:
            if k not in todo:
                continue
            entry, reason = self._retry_group(
                wave.reqs[idxs[0]].query_emb, 1, todo[k]
            )
            if entry is not None:
                entries[k] = entry
            else:
                failures[k] = reason

    def _collect(self, wave: PrefetchWave, *, step: int, tokens: int,
                 sync: bool) -> list:
        cache = self.cache
        t0 = self._now()
        wave.error_for = [None] * len(wave.reqs)
        if not sync and wave.has_misses:
            # overlap accrues only for waves that actually dispatched a
            # retrieval: a miss-free (all-hit / all-deferred) wave has
            # nothing in flight, so its launch-to-collect window hides
            # nothing and would only inflate the telemetry
            self.waves += 1
            self.overlap_seconds += max(0.0, t0 - wave.launched_at)
            self.overlap_steps += max(0, step - wave.launch_step)
            self.overlap_tokens += max(0, tokens - wave.launch_tokens)
        entries: dict = {}
        failures: dict = {}
        try:
            if wave.has_misses:
                self._resolve_misses(wave, entries, failures)
                self.block_seconds += self._now() - t0

            # deferred first (they are cache *hits* on earlier waves' keys —
            # resolve before this wave's own puts, matching sync get-then-put
            # order), then insert this wave's fresh entries
            for j, k, owner_entries in wave.deferred:
                r = wave.reqs[j]
                e = cache.get(r.query_emb)  # counts the hit, bumps recency
                if e is not None:
                    r.cache_hit = True
                elif owner_entries is not None:
                    # the owner's entry was evicted/expired between its
                    # collect and ours: the get above counted the miss (as
                    # sync would), and instead of re-dispatching we serve the
                    # owner's result — retrieval is deterministic, so the
                    # bits match what sync's re-retrieval would produce — and
                    # re-insert it as that re-retrieval's put would.  Only
                    # the dispatch count diverges from sync here (one fewer,
                    # by design).
                    e = owner_entries.get(k)
                    if e is not None:
                        cache.put(r.query_emb, e)
                if e is None:
                    # orphaned deferral: the owner's group failed (or the
                    # owner was aborted) and its entry never landed — adopt
                    # the key as our own size-1 miss instead of waiting on
                    # a dead wave.  attempts=0: this request never dispatched
                    e, reason = self._retry_group(r.query_emb, 0, "orphaned")
                    if e is not None:
                        cache.put(r.query_emb, e)
                    else:
                        wave.error_for[j] = reason
                wave.entry_for[j] = e
            for row, (k, idxs) in enumerate(wave.miss_groups.items()):
                entry = entries.get(k)
                if entry is None:
                    for j in idxs:
                        wave.error_for[j] = failures.get(k, "unknown fault")
                    continue
                cache.put(wave.reqs[idxs[0]].query_emb, entry)
                wave.entries_by_key[k] = entry
                for j in idxs:
                    wave.entry_for[j] = entry
        finally:
            # even if resolution failed, the keys must leave the in-flight
            # set so later launches re-dispatch instead of deferring to a
            # dead wave — no poisoned keys, ever
            for k in wave.miss_groups:
                cache.release_inflight(k)
            wave.sub = wave.seeds = None  # drop device arrays promptly
        return list(zip(wave.reqs, wave.entry_for, wave.error_for))

    def abort(self) -> list:
        """Discard every in-flight wave: release their in-flight cache keys
        and hand back the never-resolved requests so the engine can mark
        them terminal.  Part of the engine's ``abort()`` reconciliation."""
        orphans = []
        while self._waves:
            w = self._waves.popleft()
            for k in w.miss_groups:
                self.cache.release_inflight(k)
            w.sub = w.seeds = None
            orphans.extend(w.reqs)
        return orphans

    def stats(self) -> dict:
        denom = self.overlap_seconds + self.block_seconds
        return {
            "prefetch_waves": self.waves,
            "overlap_seconds": self.overlap_seconds,
            "overlap_steps": self.overlap_steps,
            "overlap_tokens": self.overlap_tokens,
            "launch_seconds": self.launch_seconds,
            "collect_block_seconds": self.block_seconds,
            "hidden_frac": self.overlap_seconds / denom if denom > 0 else 0.0,
            "retries": self.retries,
            "timeouts": self.timeouts,
            "retrieval_failures": self.failures,
        }

    def stats_ns(self) -> dict:
        """Namespaced stats (unified serving schema): the prefetcher's
        counters under ``prefetch.*`` — see :mod:`repro.serving.stats`."""
        return {"prefetch": self.stats()}
