"""Prompt-lookup drafter for self-speculative decode.

No second model: drafts for each slot come from the request's OWN token
history (prompt + everything emitted so far).  The drafter finds the most
recent *earlier* occurrence of the history's trailing bigram (falling back
to the trailing unigram) and proposes the tokens that followed it — the
prompt-lookup / n-gram scheme that pays off exactly when generation is
repetitive: copy-heavy RAG answers that quote retrieved node text, and the
short greedy cycles small LMs collapse into.

The lookup is a fixed-shape jitted device computation over the (slots,
hist_cap) history arena: no per-slot Python, fused into the engine's single
jitted speculative step, output shape (slots, n_draft) regardless of how
many slots are live.  Wrong drafts cost nothing in correctness — the verify
pass rejects them — so dead slots just propose garbage that gets rejected.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n_draft",))
def draft_tokens(hist, hist_len, n_draft: int):
    """Propose ``n_draft`` continuation tokens per slot from its history.

    hist (B, H) int32 token history per slot, left-aligned: prompt tokens
    followed by every emitted token (the last valid entry is the slot's
    current committed token).  hist_len (B,) valid counts (0 for dead
    slots).  Returns (B, n_draft) int32 drafts: the continuation after the
    most recent earlier match of the trailing bigram (unigram fallback),
    extrapolated *cyclically* — when the continuation runs off the end of
    history, it wraps back to the match point, so a locked period-p loop
    (the steady state greedy decode collapses into) is drafted exactly for
    ANY p, not just p = 1.  Where no match exists at all, the draft repeats
    the last committed token, catching period-1 onset one step before a
    lookup can; a wrong guess is simply rejected by verification.
    """
    b, h = hist.shape
    idx = jnp.arange(h, dtype=jnp.int32)[None, :]  # (1, H)
    ln = hist_len[:, None].astype(jnp.int32)  # (B, 1)
    last = jnp.take_along_axis(hist, jnp.maximum(ln - 1, 0), axis=1)  # (B, 1)
    prev = jnp.take_along_axis(hist, jnp.maximum(ln - 2, 0), axis=1)
    shifted = jnp.concatenate(
        [jnp.full((b, 1), -1, jnp.int32), hist[:, :-1]], axis=1
    )  # shifted[j] = hist[j-1]
    cont = idx <= ln - 2  # a continuation token exists at idx + 1
    bigram = (hist == last) & (shifted == prev) & cont & (idx >= 1) & (ln >= 2)
    unigram = (hist == last) & cont & (ln >= 1)
    j_big = jnp.max(jnp.where(bigram, idx, -1), axis=1)  # most recent match
    j_uni = jnp.max(jnp.where(unigram, idx, -1), axis=1)
    j = jnp.where(j_big >= 0, j_big, j_uni)  # (B,) -1 = no match
    # continuation positions j+1 .. , wrapped modulo the distance from the
    # match to the end of history (= the loop period when generation has
    # locked into a cycle), so every draft position stays inside history
    period = jnp.maximum(ln[:, 0] - 1 - j, 1)[:, None]  # (B, 1)
    off = jnp.arange(n_draft, dtype=jnp.int32)[None, :]
    pos = j[:, None] + 1 + off % period
    draft = jnp.take_along_axis(hist, jnp.clip(pos, 0, h - 1), axis=1)
    return jnp.where(j[:, None] >= 0, draft, last).astype(jnp.int32)
