"""Unified serving-stats schema: namespaced tree + flat compatibility view.

Every serving layer exposes its counters under one namespace of a nested
``stats_ns()`` dict:

* ``cache.*``    — :class:`repro.serving.cache.RetrievalCache`
* ``engine.*``   — RAGServeEngine-level admission/degradation counters
* ``prefetch.*`` — :class:`repro.serving.prefetch.AdmissionPrefetcher`
* ``decode.*``   — :meth:`repro.serving.engine.ServeEngine.decode_stats`
* ``router.*``   — :class:`repro.serving.router.ReplicaRouter`
* ``mutation.*`` — the online-mutation tier (:mod:`repro.core.mutation`)

:func:`flatten_stats` derives the historical flat dict from the tree.  The
namespaces that predate the schema (``LEGACY_FLAT``) flatten *unprefixed* —
their keys are the exact keys nine PRs of tests and dashboards already
read (``hits``, ``prefetch_waves``, ``decode_steps``, ...).  Namespaces
introduced with the schema (``mutation``, ``router``) flatten with a
``<ns>_`` prefix so they can never collide with a legacy key.
"""
from __future__ import annotations

# namespaces whose keys were already top-level flat keys before the schema
# existed; they stay unprefixed for compatibility.  Flat-merge order (and
# therefore collision-overwrite behavior) follows the tree's insertion
# order, which every stats_ns() builds as cache, engine, prefetch, decode —
# the same order the old flat stats() merged them in.
LEGACY_FLAT = ("cache", "engine", "prefetch", "decode")


def flatten_stats(ns: dict) -> dict:
    """Flat compatibility view of a namespaced ``stats_ns()`` tree."""
    flat: dict = {}
    for name, group in ns.items():
        if not isinstance(group, dict):
            flat[name] = group
            continue
        if name in LEGACY_FLAT:
            flat.update(group)
        else:
            for k, v in group.items():
                flat[f"{name}_{k}"] = v
    return flat
