"""Fused retrieval-to-generation serving: the RGL "unified system" front-end.

``RAGServeEngine`` closes the gap between the retrieval pipeline and the
decode server: a raw ``(query_emb, query_text)`` request goes through

    index -> seed retrieval -> subgraph construction -> dynamic filter
          -> tokenization -> batched prefill -> continuous-batching decode

inside one engine.  Two amortization mechanisms drive throughput:

* **Batched admission retrieval** — every engine step gathers all pending
  admissions and runs ONE jitted ``RGLPipeline.retrieve_many`` call over the
  whole admission batch (padded to a fixed shape), instead of per-request
  retrieval dispatches.  This is the paper's core batching speedup applied at
  serve time.
* **Retrieval caching** — a policy-driven (lru / lfu / ttl, optional expiry)
  :class:`~repro.serving.cache.RetrievalCache` keyed on quantized query
  embeddings lets repeated / near-duplicate queries skip index + BFS + filter
  entirely.  Hit/miss counters are exposed as ``engine.cache_hits`` /
  ``engine.cache_misses``; pick the policy via ``cache_policy`` /
  ``cache_ttl`` engine kwargs.

Generation itself rides the slot-based :class:`~repro.serving.engine.ServeEngine`
(one jitted decode step for all slots, masked batched prefill admission).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.core.pipeline import RGLPipeline
from repro.models.transformer.config import TransformerConfig
from repro.serving.cache import CachedRetrieval, RetrievalCache
from repro.serving.engine import Request, ServeEngine


@dataclasses.dataclass
class RAGRequest:
    """A raw serving request: query embedding + query text, no tokens yet."""

    uid: int
    query_emb: np.ndarray  # (D,) float32
    query_text: str
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    prompt_ids: Optional[np.ndarray] = None  # filled at admission
    retrieved_nodes: Optional[np.ndarray] = None  # filtered subgraph members
    cache_hit: bool = False
    done: bool = False


class RAGServeEngine:
    """End-to-end RAG server: retrieval-batched admission over a decode arena.

    Usage::

        eng = RAGServeEngine(pipe, params, cfg, slots=8, cache_len=256)
        eng.submit(RAGRequest(uid=0, query_emb=emb, query_text="..."))
        finished = eng.run_to_completion()   # .out_tokens per request

    ``pipe`` must carry a tokenizer and node_text (stages 4's inputs).
    """

    def __init__(
        self,
        pipeline: RGLPipeline,
        params,
        cfg: TransformerConfig,
        *,
        slots: int = 8,
        cache_len: int = 512,
        eos_id: Optional[int] = None,
        retrieval_cache: Optional[RetrievalCache] = None,
        cache_capacity: int = 256,
        quant_eps: float = 1e-3,
        cache_policy: str = "lru",
        cache_ttl: Optional[float] = None,
    ):
        assert pipeline.tokenizer is not None, "pipeline needs a tokenizer"
        assert pipeline.node_text is not None, "pipeline needs node_text"
        if pipeline.tokenizer.max_len >= cache_len:
            raise ValueError(
                f"tokenizer.max_len={pipeline.tokenizer.max_len} must be < "
                f"cache_len={cache_len} so every prompt fits the KV arena"
            )
        self.pipeline = pipeline
        self.slots = slots
        self.engine = ServeEngine(
            params, cfg, slots=slots, cache_len=cache_len, eos_id=eos_id
        )
        self.cache = retrieval_cache if retrieval_cache is not None else \
            RetrievalCache(capacity=cache_capacity, quant_eps=quant_eps,
                           policy=cache_policy, ttl=cache_ttl)
        self.pending: deque = deque()
        self._inflight: dict = {}  # inner uid -> RAGRequest
        # amortization telemetry
        self.retrieval_batches = 0
        self.retrieved_queries = 0
        self.retrieval_seconds = 0.0

    # -- cache counters -------------------------------------------------------
    @property
    def cache_hits(self) -> int:
        return self.cache.hits

    @property
    def cache_misses(self) -> int:
        return self.cache.misses

    # -- admission ------------------------------------------------------------
    def submit(self, req: RAGRequest) -> None:
        self.pending.append(req)

    def _admit_retrieval(self) -> None:
        """Move up to one admission batch of pending requests through
        retrieval (one jitted batched call for all cache misses) and hand the
        tokenized prompts to the decode engine."""
        take = min(len(self.pending), self.slots)
        if take == 0:
            return
        reqs = [self.pending.popleft() for _ in range(take)]

        # cache lookup; dedupe misses within the batch by quantized key
        entry_for: list = [None] * take
        miss_reqs: dict = {}  # key -> (first request index, emb)
        for j, r in enumerate(reqs):
            e = self.cache.get(r.query_emb)
            if e is not None:
                entry_for[j] = e
                r.cache_hit = True
            else:
                miss_reqs.setdefault(self.cache.key(r.query_emb),
                                     []).append(j)

        if miss_reqs:
            order = list(miss_reqs.items())
            qe = np.stack([reqs[idxs[0]].query_emb for _, idxs in order]) \
                .astype(np.float32)
            t0 = time.perf_counter()
            sub, seeds, n_valid = self.pipeline.retrieve_many(
                qe, batch_size=self.slots
            )
            nodes = np.asarray(sub.nodes)  # blocks; also ends the timed span
            mask = np.asarray(sub.mask)
            dist = np.asarray(sub.dist)
            seeds_np = np.asarray(seeds)
            self.retrieval_seconds += time.perf_counter() - t0
            self.retrieval_batches += 1
            self.retrieved_queries += n_valid
            for row, (_, idxs) in enumerate(order):
                entry = CachedRetrieval(
                    nodes=nodes[row].copy(), mask=mask[row].copy(),
                    dist=dist[row].copy(), seeds=seeds_np[row].copy(),
                )
                self.cache.put(reqs[idxs[0]].query_emb, entry)
                for j in idxs:
                    entry_for[j] = entry

        # tokenize and admit
        tok = self.pipeline.tokenizer
        node_text = self.pipeline.node_text
        for j, r in enumerate(reqs):
            e = entry_for[j]
            texts = [node_text[int(v)] for v, m in zip(e.nodes, e.mask) if m]
            ids, mask = tok.linearize(r.query_text, texts)
            r.prompt_ids = ids[mask]
            r.retrieved_nodes = e.nodes[e.mask].copy()
            inner = Request(
                uid=r.uid, prompt_ids=r.prompt_ids,
                max_new_tokens=r.max_new_tokens,
            )
            self._inflight[id(inner)] = r
            self.engine.submit(inner)

    # -- stepping -------------------------------------------------------------
    def step(self) -> list:
        """One engine step: batched retrieval admission + one decode step.
        Returns the RAG requests that finished this step."""
        self._admit_retrieval()
        finished_inner = self.engine.step()
        out = []
        for inner in finished_inner:
            r = self._inflight.pop(id(inner))
            r.out_tokens = inner.out_tokens
            r.done = True
            out.append(r)
        return out

    def run_to_completion(self, max_steps: int = 10_000) -> list:
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if (not self.pending and not self.engine.queue
                    and not self.engine.live.any()):
                break
        return done

    def stats(self) -> dict:
        s = self.cache.stats()
        s.update(
            retrieval_batches=self.retrieval_batches,
            retrieved_queries=self.retrieved_queries,
            retrieval_seconds=self.retrieval_seconds,
        )
        return s
