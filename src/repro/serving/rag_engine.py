"""Fused retrieval-to-generation serving: the RGL "unified system" front-end.

``RAGServeEngine`` closes the gap between the retrieval pipeline and the
decode server: a raw ``(query_emb, query_text)`` request goes through

    index -> seed retrieval -> subgraph construction -> dynamic filter
          -> tokenization -> batched prefill -> continuous-batching decode

inside one engine.  Three amortization mechanisms drive throughput:

* **Batched admission retrieval** — every admission wave runs ONE jitted
  ``RGLPipeline.retrieve_many`` call over the whole wave (padded to a fixed
  shape), instead of per-request retrieval dispatches.  This is the paper's
  core batching speedup applied at serve time.
* **Retrieval caching** — a policy-driven (lru / lfu / ttl, optional expiry)
  :class:`~repro.serving.cache.RetrievalCache` keyed on quantized query
  embeddings lets repeated / near-duplicate queries skip index + BFS + filter
  entirely.  Hit/miss counters are exposed as ``engine.cache_hits`` /
  ``engine.cache_misses``; pick the policy via ``cache_policy`` /
  ``cache_ttl`` engine kwargs.
* **Async admission prefetch** (``prefetch=True``, or ``RGL_PREFETCH=1``) —
  wave *i+1*'s retrieval is *launched* (dispatched, results left as device
  arrays) while wave *i*'s decode steps run, and *collected* (forced,
  tokenized, admitted) only once decode slots free up: double-buffered
  admission via :class:`~repro.serving.prefetch.AdmissionPrefetcher`.  Sync
  mode runs the identical launch/collect code back-to-back, so the two
  schedules produce bitwise-identical outputs (see
  ``tests/test_async_serving.py``).

Two admission *granularities* sit on top of either schedule
(``admission=`` / ``RGL_ADMISSION``): classic **wave** admission retrieves
and admits whole waves, while **continuous** admission launches one
retrieval per request and — under prefetch — collects whichever request's
retrieval is ready (``AdmissionPrefetcher.ready_index``), so a single slow
retrieval row no longer delays its wave-mates and a freed decode slot never
waits for a wave boundary.  Outputs are bitwise identical across all four
combinations (greedy decode is schedule-invariant per request).

Generation itself rides the slot-based :class:`~repro.serving.engine.ServeEngine`
(one jitted decode step for all slots, masked batched prefill admission).
``spec_decode`` / ``RGL_SPEC_DECODE=1`` switches the decode arena to
self-speculative multi-token decode (prompt-lookup drafts verified in one
dispatch; bitwise-identical outputs, up to ``draft_window`` tokens committed
per dispatch) — see :mod:`repro.serving.engine`.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.core.pipeline import RGLPipeline
from repro.models.transformer.config import TransformerConfig
from repro.serving.cache import RetrievalCache
from repro.serving.config import ServingConfig
from repro.serving.engine import Request, ServeEngine
from repro.serving.prefetch import AdmissionPrefetcher
from repro.serving.stats import flatten_stats


@dataclasses.dataclass
class RAGRequest:
    """A raw serving request: query embedding + query text, no tokens yet.

    Terminal states (exactly one holds when the engine hands the request
    back): ``done`` (served — possibly ``stale`` or ``degraded``),
    ``failed`` (retrieval faults exhausted the whole degradation ladder, or
    the engine was aborted; ``error`` says why), or ``shed`` (refused by
    overload control or expired past its deadline before admission).
    """

    uid: int
    query_emb: np.ndarray  # (D,) float32
    query_text: str
    max_new_tokens: int = 32
    # seconds of deadline budget from submit time; the engine sheds the
    # request at any launch/collect/admit boundary past it.  None falls back
    # to the engine's default_deadline_s (None = no deadline)
    deadline_s: Optional[float] = None
    out_tokens: list = dataclasses.field(default_factory=list)
    prompt_ids: Optional[np.ndarray] = None  # filled at admission
    retrieved_nodes: Optional[np.ndarray] = None  # filtered subgraph members
    cache_hit: bool = False
    done: bool = False
    # retired early by KV exhaustion (contiguous arena full / paged pool
    # empty): out_tokens is shorter than max_new_tokens with no EOS
    truncated: bool = False
    # --- fault-tolerance terminal/degraded markers (see class docstring) ---
    stale: bool = False  # served from a TTL-expired cache entry
    degraded: bool = False  # served retrieval-free (query-only prompt)
    failed: bool = False
    shed: bool = False
    error: Optional[str] = None  # reason for failed/shed
    deadline_at: Optional[float] = None  # absolute deadline, set at submit


class RAGServeEngine:
    """End-to-end RAG server: retrieval-batched admission over a decode arena.

    Usage::

        eng = RAGServeEngine(pipe, params, cfg, slots=8, cache_len=256)
        eng.submit(RAGRequest(uid=0, query_emb=emb, query_text="..."))
        finished = eng.run_to_completion()   # .out_tokens per request

    ``pipe`` must carry a tokenizer and node_text (stages 4's inputs).
    ``prefetch=None`` reads the ``RGL_PREFETCH`` env var (default off).

    **Fault tolerance.**  Retrieval faults are data-plane events, not
    engine crashes: ``step()`` never raises for one.  A failed miss-group
    (dispatch raise, force raise, timeout after ``retrieval_timeout_s``, or
    a corrupt result) is retried in isolation up to ``max_retries`` times
    (``retry_backoff_s`` exponential backoff); on exhaustion the request
    walks the degradation ladder:

    1. **stale** — a resident cache entry for the key, TTL-expired allowed
       (``stale_served`` counter, ``RAGRequest.stale``);
    2. **degraded** — retrieval-free decode over a query-only prompt
       (``degraded`` counter/flag; disable with ``degraded_mode=False`` /
       ``RGL_DEGRADED=0``);
    3. **failed** — that one request terminates with ``failed=True`` and an
       ``error`` reason; wave-mates are unaffected.

    **Overload control.**  ``max_pending`` bounds the pending queue
    (0 = unbounded); on overflow ``shed_policy`` picks the victim:
    ``"reject"`` refuses the new request, ``"evict-oldest"`` sheds the
    oldest pending one.  Per-request deadlines (``deadline_s``, or the
    engine-wide ``default_deadline_s``) are checked at every
    launch/collect/admit boundary — an expired request is shed, never
    dispatched.  Shed requests surface through ``step()`` like finished
    ones, with ``shed=True``.

    ``abort()`` fails all outstanding work and reconciles every layer
    (pending queue, in-flight prefetch waves + cache keys, decode slots +
    paged KV blocks, admission tickets); ``drain()`` is run_to_completion
    that aborts the stragglers instead of raising.  Env knobs:
    ``RGL_RETRIEVAL_TIMEOUT``, ``RGL_RETRIES``, ``RGL_RETRY_BACKOFF``,
    ``RGL_DEADLINE``, ``RGL_MAX_PENDING``, ``RGL_SHED_POLICY``,
    ``RGL_DEGRADED``.

    **Replica embedding.**  The engine is designed to run as one replica of
    a fleet behind :class:`repro.serving.router.ReplicaRouter`: pass the
    same ``retrieval_cache=`` instance to every replica to share the
    retrieval tier (the in-flight key registry gives the fleet single-flight
    semantics — see :mod:`repro.serving.cache`), and the router reads
    :meth:`health` each step to score replicas and route around trouble.
    """

    def __init__(
        self,
        pipeline: RGLPipeline,
        params,
        cfg: TransformerConfig,
        *,
        config: Optional[ServingConfig] = None,
        slots: Optional[int] = None,
        cache_len: Optional[int] = None,
        eos_id: Optional[int] = None,
        retrieval_cache: Optional[RetrievalCache] = None,
        cache_capacity: Optional[int] = None,
        quant_eps: Optional[float] = None,
        cache_policy: Optional[str] = None,
        cache_ttl: Optional[float] = None,
        prefetch: Optional[bool] = None,
        prefetch_depth: Optional[int] = None,
        admission: Optional[str] = None,
        spec_decode: Optional[bool] = None,
        draft_window: Optional[int] = None,
        paged_kv: Optional[bool] = None,
        kv_block_size: Optional[int] = None,
        kv_pool_blocks: Optional[int] = None,
        prefix_share: Optional[bool] = None,
        retrieval_timeout_s: Optional[float] = None,
        max_retries: Optional[int] = None,
        retry_backoff_s: Optional[float] = None,
        degraded_mode: Optional[bool] = None,
        max_pending: Optional[int] = None,
        shed_policy: Optional[str] = None,
        default_deadline_s: Optional[float] = None,
        compact_every: Optional[int] = None,
        now_fn=time.monotonic,
        sleep_fn=time.sleep,
    ):
        assert pipeline.tokenizer is not None, "pipeline needs a tokenizer"
        assert pipeline.node_text is not None, "pipeline needs node_text"
        # one resolution pass: explicit kwarg > config= > RGL_* env > default.
        # The historical kwargs above are the deprecation shim — each one,
        # when non-None, becomes an explicit override of the config.
        self.config = resolved = ServingConfig.resolve(
            config,
            slots=slots, cache_len=cache_len, eos_id=eos_id,
            cache_capacity=cache_capacity, quant_eps=quant_eps,
            cache_policy=cache_policy, cache_ttl=cache_ttl,
            prefetch=prefetch, prefetch_depth=prefetch_depth,
            admission=admission, spec_decode=spec_decode,
            draft_window=draft_window, paged_kv=paged_kv,
            kv_block_size=kv_block_size, kv_pool_blocks=kv_pool_blocks,
            prefix_share=prefix_share,
            retrieval_timeout_s=retrieval_timeout_s, max_retries=max_retries,
            retry_backoff_s=retry_backoff_s, degraded_mode=degraded_mode,
            max_pending=max_pending, shed_policy=shed_policy,
            default_deadline_s=default_deadline_s,
            compact_every=compact_every,
        )
        if pipeline.tokenizer.max_len >= resolved.cache_len:
            raise ValueError(
                f"tokenizer.max_len={pipeline.tokenizer.max_len} must be < "
                f"cache_len={resolved.cache_len} so every prompt fits the KV "
                f"arena"
            )
        self.pipeline = pipeline
        self.slots = resolved.slots
        self.engine = ServeEngine(
            params, cfg, slots=resolved.slots, cache_len=resolved.cache_len,
            eos_id=resolved.eos_id,
            spec_decode=resolved.spec_decode,
            draft_window=resolved.draft_window,
            paged_kv=resolved.paged_kv, block_size=resolved.kv_block_size,
            pool_blocks=resolved.kv_pool_blocks,
            prefix_share=resolved.prefix_share,
        )
        self.cache = retrieval_cache if retrieval_cache is not None else \
            RetrievalCache(capacity=resolved.cache_capacity,
                           quant_eps=resolved.quant_eps,
                           policy=resolved.cache_policy,
                           ttl=resolved.cache_ttl,
                           region_bucket=resolved.region_bucket,
                           mutation_flush=resolved.mutation_flush)
        if self.engine.prefix_share:
            # wire the engine's pin protocol to this cache: pins only attach
            # to entries still resident (a pin on an evicted entry would leak
            # pool blocks forever), and pool pressure releases cache pins
            # before the engine truncates any live request
            self.engine.kv_pin_gate = self.cache.is_resident
            self.engine.kv_pin_reclaim = (
                lambda n: self.cache.reclaim_kv(n, owner=self.engine)
            )
        self.prefetch = resolved.prefetch
        self.admission = resolved.admission
        prefetch_depth = resolved.prefetch_depth
        if prefetch_depth is None:
            # continuous admission launches size-1 waves, so the in-flight
            # window must hold one wave per slot to keep every free slot's
            # retrieval overlapping; wave admission double-buffers (depth 1)
            prefetch_depth = resolved.slots \
                if self.admission == "continuous" else 1
        # continuous launches always carry one request, so the retrieval
        # batch pads to 1 row instead of `slots` — per-row retrieval is
        # row-independent, so results stay bitwise identical while the
        # per-dispatch compute stops scaling with the unused padding
        self.degraded_mode = resolved.degraded_mode
        self.max_pending = resolved.max_pending  # 0 = unbounded
        self.shed_policy = resolved.shed_policy
        self.default_deadline_s = resolved.default_deadline_s
        self.compact_every = resolved.compact_every  # 0 = manual only
        self._now = now_fn
        # the prefetcher shares the engine's clock pair so retry backoff,
        # timeout deadlines, and readiness polling are fully clock-injectable
        # (chaos tests drive a virtual clock and never wall-sleep)
        self.prefetcher = AdmissionPrefetcher(
            pipeline, self.cache,
            wave_size=1 if self.admission == "continuous" else resolved.slots,
            depth=prefetch_depth,
            retrieval_timeout_s=resolved.retrieval_timeout_s,
            max_retries=resolved.max_retries,
            retry_backoff_s=resolved.retry_backoff_s,
            now_fn=now_fn,
            sleep_fn=sleep_fn,
        )
        self.pending: deque = deque()
        self._inflight: dict = {}  # admission ticket -> RAGRequest
        self._next_ticket = 0  # monotonic; never reused (unlike id())
        self._step_no = 0
        # requests that went terminal outside decode (shed / failed /
        # degradation-exhausted); step() hands them back exactly once
        self._terminal: list = []
        # fault-tolerance counters (every submitted request lands in exactly
        # one of: done, failed, shed — stale/degraded refine done)
        self.shed_count = 0
        self.failed_count = 0
        self.degraded_count = 0
        self.stale_served = 0
        # online-mutation counters (apply_mutations)
        self.mutation_batches = 0
        self.mutation_invalidated = 0

    # -- cache counters -------------------------------------------------------
    @property
    def cache_hits(self) -> int:
        return self.cache.hits

    @property
    def cache_misses(self) -> int:
        return self.cache.misses

    # -- amortization telemetry (delegated to the prefetcher, which runs the
    # launch/collect phases for both admission schedules) ----------------------
    @property
    def retrieval_batches(self) -> int:
        return self.prefetcher.batches

    @property
    def retrieved_queries(self) -> int:
        return self.prefetcher.queries

    @property
    def retrieval_seconds(self) -> float:
        p = self.prefetcher
        return p.launch_seconds + p.block_seconds

    # -- terminal bookkeeping -------------------------------------------------
    def _shed(self, req: RAGRequest, reason: str) -> None:
        req.shed = True
        req.error = reason
        self.shed_count += 1
        self._terminal.append(req)

    def _fail(self, req: RAGRequest, reason: str) -> None:
        req.failed = True
        req.error = reason
        self.failed_count += 1
        self._terminal.append(req)

    def _expired(self, req: RAGRequest) -> bool:
        return req.deadline_at is not None and self._now() > req.deadline_at

    # -- admission ------------------------------------------------------------
    def _validate(self, req: RAGRequest) -> None:
        """Reject malformed requests at the front door, before any queue or
        dispatch sees them — a NaN embedding must not poison a batched
        retrieval wave, and a bad field must name the offending uid."""
        q = np.asarray(req.query_emb, np.float32)
        if q.ndim != 1:
            raise ValueError(
                f"request {req.uid}: query_emb must be 1-D, got shape "
                f"{tuple(q.shape)}"
            )
        node_emb = getattr(self.pipeline, "node_emb", None)
        if node_emb is not None and q.shape[0] != node_emb.shape[1]:
            raise ValueError(
                f"request {req.uid}: query_emb dim {q.shape[0]} != node "
                f"embedding dim {node_emb.shape[1]}"
            )
        if not np.isfinite(q).all():
            raise ValueError(
                f"request {req.uid}: query_emb contains NaN/Inf"
            )
        if not str(req.query_text).strip():
            raise ValueError(f"request {req.uid}: empty query_text")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.uid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}"
            )
        if req.deadline_s is not None and req.deadline_s <= 0:
            raise ValueError(
                f"request {req.uid}: deadline_s must be > 0, got "
                f"{req.deadline_s}"
            )

    def submit(self, req: RAGRequest) -> bool:
        """Validate and enqueue.  Returns True if the request entered the
        pending queue, False if overload control shed it on arrival
        (``shed_policy="reject"`` with a full queue) — the shed request is
        still handed back by the next ``step()``.  Malformed requests raise
        ``ValueError`` and never enter the system."""
        self._validate(req)
        if req.deadline_at is None:
            # a request arriving with deadline_at already pinned (a router
            # failover re-dispatch) keeps it: re-submitting must never
            # restart the deadline budget
            deadline = req.deadline_s if req.deadline_s is not None \
                else self.default_deadline_s
            if deadline is not None:
                req.deadline_at = self._now() + float(deadline)
        if self.max_pending and len(self.pending) >= self.max_pending:
            if self.shed_policy == "reject":
                self._shed(req, "queue full (shed_policy=reject)")
                return False
            victim = self.pending.popleft()
            self._shed(victim, "queue full (shed_policy=evict-oldest)")
        self.pending.append(req)
        return True

    def _take_wave(self, limit: Optional[int] = None) -> list:
        cap = self.slots if limit is None else limit
        out: list = []
        while self.pending and len(out) < cap:
            r = self.pending.popleft()
            if self._expired(r):
                # deadline boundary 1: never dispatch retrieval for a
                # request that is already past its deadline
                self._shed(r, "deadline expired before retrieval dispatch")
                continue
            out.append(r)
        return out

    @property
    def _launch_unit(self) -> int:
        """Requests per retrieval launch: a full wave in wave admission, a
        single request in continuous admission (so one slow retrieval row
        never blocks the admission of its would-be wave-mates)."""
        return 1 if self.admission == "continuous" else self.slots

    def _tokenize_and_admit(self, resolved: list) -> None:
        """Stage 4+5 handoff: linearize each resolved ``(request, entry,
        error)`` triple and hand the prompt to the decode engine under a
        fresh admission ticket.

        This is where the graceful-degradation ladder runs: a request whose
        retrieval failed (``entry is None``, ``error`` says why) tries a
        stale cache entry first, then a retrieval-free (query-only) prompt,
        and only then fails — each rung per request, so one dead retrieval
        row never drags down its wave-mates.  A request past its deadline is
        shed here instead of admitted (deadline boundary 3)."""
        tok = self.pipeline.tokenizer
        node_text = self.pipeline.node_text
        for r, e, err in resolved:
            if self._expired(r):
                self._shed(r, "deadline expired before admission")
                continue
            if e is None:
                stale = self.cache.peek_stale(r.query_emb)
                if stale is not None:
                    # ladder rung 1: serve the resident (possibly
                    # TTL-expired) entry rather than nothing
                    e = stale
                    r.stale = True
                    self.stale_served += 1
                elif self.degraded_mode:
                    # ladder rung 2: retrieval-free decode (query-only
                    # prompt); e stays None
                    r.degraded = True
                    self.degraded_count += 1
                else:
                    # ladder rung 3: fail just this request
                    self._fail(r, err or "retrieval failed")
                    continue
            ticket = None
            try:
                if e is not None:
                    texts = [node_text[int(v)]
                             for v, m in zip(e.nodes, e.mask) if m]
                    r.retrieved_nodes = e.nodes[e.mask].copy()
                else:
                    texts = []
                    r.retrieved_nodes = np.empty(0, np.int32)
                ids, mask = tok.linearize(r.query_text, texts)
                r.prompt_ids = ids[mask]
                inner = Request(
                    uid=r.uid, prompt_ids=r.prompt_ids,
                    max_new_tokens=r.max_new_tokens, ticket=self._next_ticket,
                )
                if self.engine.prefix_share and e is not None:
                    # consumer side when the entry already pins this pool's
                    # prefilled prompt blocks (admission re-validates the
                    # exact prompt and falls back to fresh prefill on any
                    # mismatch); donor side otherwise — a fresh admission
                    # hands its prompt blocks to the entry as a pin
                    inner.pin_to = e
                    if getattr(e, "kv_blocks", None) is not None and \
                            getattr(e, "kv_owner", None) is self.engine:
                        inner.shared_prefix = e
                ticket = inner.ticket
                self._inflight[ticket] = r
                self._next_ticket += 1
                self.engine.submit(inner)
            except Exception as exc:  # per-request containment: a bad
                # entry (e.g. out-of-range node id slipping past
                # validation) fails its own request, not the engine
                if ticket is not None:
                    self._inflight.pop(ticket, None)
                self._fail(r, f"admission: {exc}")

    def _admit_sync(self) -> None:
        """Sync schedule: launch one wave and collect it immediately (the
        collect's ``np.asarray`` blocks for the full retrieval latency).
        Continuous admission runs the same blocking launch+collect per
        *request* instead — one admission unit per free slot."""
        if self.admission == "continuous":
            while self.engine.free_slots > 0 and self.pending:
                reqs = self._take_wave(1)
                if not reqs:  # everything left was past deadline (shed)
                    continue
                tok = self.engine.emitted_tokens
                self.prefetcher.launch(reqs, step=self._step_no, tokens=tok)
                self._tokenize_and_admit(self.prefetcher.collect(
                    step=self._step_no, tokens=tok, sync=True))
            return
        reqs = self._take_wave()
        if not reqs:
            return
        tok = self.engine.emitted_tokens
        self.prefetcher.launch(reqs, step=self._step_no, tokens=tok)
        self._tokenize_and_admit(
            self.prefetcher.collect(step=self._step_no, tokens=tok, sync=True)
        )

    def _launch_pending(self) -> None:
        while self.pending and self.prefetcher.can_launch():
            reqs = self._take_wave(self._launch_unit)
            if not reqs:  # everything left was past deadline (shed)
                continue
            self.prefetcher.launch(reqs, step=self._step_no,
                                   tokens=self.engine.emitted_tokens)

    def _admit_prefetch(self) -> None:
        """Prefetch schedule: collect waves as decode slots free up
        (backpressure: never tokenize/admit into a still-full arena) and
        launch the next wave(s) so their retrieval overlaps this step's
        decode.  The launch is sandwiched between a wave's collect (which
        inserts its cache entries — so the next lookup sees them) and its
        tokenize/admit, putting the admission overhead *inside* the next
        wave's overlap window too."""
        while (self.prefetcher.launched_before(self._step_no)
                and self.engine.free_slots > 0):
            # never collect a wave in the step it launched (that would
            # forfeit its whole overlap window, e.g. under trickle load
            # where wave size < free slots) — except via the idle-arena
            # fast path below, where there is nothing to overlap with
            resolved = self.prefetcher.collect(
                step=self._step_no, tokens=self.engine.emitted_tokens
            )
            self._launch_pending()
            self._tokenize_and_admit(resolved)
        self._launch_pending()
        if (not self.engine.live.any() and not self.engine.queue
                and self.prefetcher.in_flight):
            # idle arena: nothing to overlap with, don't stall a step
            self._tokenize_and_admit(
                self.prefetcher.collect(step=self._step_no,
                                        tokens=self.engine.emitted_tokens)
            )

    def _admit_continuous(self) -> None:
        """Continuous + prefetch: per-request launches, out-of-FIFO collect.
        Each free slot collects whichever in-flight single-request wave is
        *ready* (device arrays landed, deferred owners resolved) via
        ``ready_index``/``collect_at`` — so one slow retrieval row delays
        only its own request, never its would-be wave-mates.  Launches are
        sandwiched between collect and tokenize/admit exactly like the wave
        schedule, keeping the admission overhead inside the next request's
        overlap window."""
        self._launch_pending()
        while self.engine.free_slots > 0 and self.prefetcher.in_flight:
            idx = self.prefetcher.ready_index()
            if idx is None:
                break
            resolved = self.prefetcher.collect_at(
                idx, step=self._step_no, tokens=self.engine.emitted_tokens
            )
            self._launch_pending()
            self._tokenize_and_admit(resolved)
        if (not self.engine.live.any() and not self.engine.queue
                and self.prefetcher.in_flight):
            # idle arena with nothing ready: block on the oldest wave rather
            # than burn empty steps (oldest first keeps deferred owners
            # resolving before their dependents)
            self._tokenize_and_admit(
                self.prefetcher.collect(step=self._step_no,
                                        tokens=self.engine.emitted_tokens)
            )
            self._launch_pending()

    # -- stepping -------------------------------------------------------------
    def step(self) -> list:
        """One engine step: admission (sync or prefetched, wave or
        continuous) + one decode step.  Returns the RAG requests that
        finished this step."""
        if not self.prefetch:
            self._admit_sync()
        elif self.admission == "continuous":
            self._admit_continuous()
        else:
            self._admit_prefetch()
        finished_inner = self.engine.step()
        self._step_no += 1
        out = []
        for inner in finished_inner:
            r = self._inflight.pop(inner.ticket)
            r.out_tokens = inner.out_tokens
            r.truncated = inner.truncated
            r.done = True
            out.append(r)
        if self._terminal:
            # shed / failed requests surface through the same channel as
            # finished ones, exactly once
            out.extend(self._terminal)
            self._terminal.clear()
        return out

    def _drained(self) -> bool:
        return (not self.pending and not self.prefetcher.in_flight
                and not self.engine.queue and not self.engine.live.any()
                and not self._terminal)

    def run_to_completion(self, max_steps: int = 10_000) -> list:
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if self._drained():
                return done
        raise RuntimeError(
            f"run_to_completion: work still pending after {max_steps} steps "
            f"({len(self.pending)} pending, {self.prefetcher.in_flight} "
            f"in-flight waves, {len(self.engine.queue)} queued, "
            f"{int(self.engine.live.sum())} live slots)"
        )

    # -- teardown / recovery --------------------------------------------------
    def abort(self, reason: str = "aborted") -> list:
        """Terminate every outstanding request and reconcile every layer:
        the pending queue is shed, in-flight prefetch waves are dropped
        (their in-flight cache keys released, so later lookups never defer
        to a dead wave), live decode slots are retired (paged KV blocks
        returned to the pool), and stranded admission tickets are cleared.
        The engine is immediately reusable for a fresh workload.  Returns
        every request that went terminal, exactly once."""
        while self.pending:
            self._shed(self.pending.popleft(), f"shed: {reason}")
        for r in self.prefetcher.abort():
            self._fail(r, f"aborted before admission: {reason}")
        for inner in self.engine.abort(reason=reason):
            r = self._inflight.pop(inner.ticket, None)
            if r is None:
                continue
            r.out_tokens = inner.out_tokens
            r.truncated = inner.truncated
            self._fail(r, inner.error or reason)
        for ticket in list(self._inflight):
            # tickets whose inner request the decode engine lost track of
            # (should be impossible; reconciled defensively)
            self._fail(self._inflight.pop(ticket), f"stranded: {reason}")
        out = list(self._terminal)
        self._terminal.clear()
        return out

    def drain(self, max_steps: int = 10_000) -> list:
        """``run_to_completion`` that never raises: if work is still
        outstanding after ``max_steps``, the stragglers are aborted and
        returned (``failed``/``shed``) alongside the completed requests, and
        the engine is left reusable."""
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if self._drained():
                return done
        done.extend(self.abort(reason=f"drain gave up after {max_steps} steps"))
        return done

    # -- online mutation ------------------------------------------------------
    def apply_mutations(self, batch) -> "object":
        """Apply a :class:`repro.core.mutation.MutationBatch` to the live
        graph/index tier between decode steps, then invalidate every cache
        entry whose region the batch touched (releasing their prefix-share KV
        pins).  Returns the store's ``MutationReport``.

        Safe to interleave with :meth:`step`: the store builds *new* device
        arrays and re-points the pipeline (functional snapshot), so a
        retrieval wave already dispatched completes against its launch-time
        snapshot; the cache's epoch put-gate then refuses to insert those
        superseded results.  Call between steps, not from another thread.
        """
        store = getattr(self.pipeline, "mutation_store", None)
        if store is None:
            raise RuntimeError(
                "apply_mutations needs a pipeline built on a "
                "MutableGraphStore (see repro.core.mutation)"
            )
        report = store.apply(batch)
        self.mutation_batches += 1
        self.mutation_invalidated += self.cache.invalidate_regions(
            report.touched, report.epoch
        )
        if self.compact_every and \
                store.stats()["mutations_since_compact"] >= self.compact_every:
            store.compact()
        return report

    def health(self) -> dict:
        """Cheap health/load snapshot for a fronting router — raw counters
        only, no derived stats (``stats()`` is the full surface).  The fault
        counters are cumulative; the router scores health on their *deltas*
        between steps (a climbing counter, not a large one, is the signal).
        """
        p = self.prefetcher
        return {
            # fault signals (cumulative)
            "retries": p.retries,
            "timeouts": p.timeouts,
            "retrieval_failures": p.failures,
            "failed": self.failed_count,
            "degraded": self.degraded_count,
            "stale_served": self.stale_served,
            "shed": self.shed_count,
            # load signals (instantaneous)
            "pending": len(self.pending),
            "inflight_waves": p.in_flight,
            "inflight_requests": p.in_flight_requests,
            "admitted": len(self._inflight),
            "live_slots": int(self.engine.live.sum()),
            "free_slots": self.engine.free_slots,
            "queued": len(self.engine.queue),
        }

    def stats_ns(self) -> dict:
        """Namespaced stats: one sub-dict per serving layer (``cache``,
        ``engine``, ``prefetch``, ``decode``, ``mutation`` — plus ``router``
        when fronted by a :class:`~repro.serving.router.ReplicaRouter`).
        :meth:`stats` is the flat compatibility view of exactly this tree
        (see :func:`repro.serving.stats.flatten_stats`)."""
        ns = {
            "cache": self.cache.stats(),
            "engine": {
                "retrieval_batches": self.retrieval_batches,
                "retrieved_queries": self.retrieved_queries,
                "retrieval_seconds": self.retrieval_seconds,
                "prefetch": self.prefetch,
                "admission": self.admission,
                "shed": self.shed_count,
                "failed": self.failed_count,
                "degraded": self.degraded_count,
                "stale_served": self.stale_served,
                "degraded_mode": self.degraded_mode,
            },
            "prefetch": self.prefetcher.stats(),
            "decode": self.engine.decode_stats(),
        }
        store = getattr(self.pipeline, "mutation_store", None)
        mut = dict(store.stats()) if store is not None else {}
        mut["batches"] = self.mutation_batches
        mut["invalidated"] = self.mutation_invalidated
        ns["mutation"] = mut
        return ns

    def stats(self) -> dict:
        return flatten_stats(self.stats_ns())
