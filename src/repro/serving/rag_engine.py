"""Fused retrieval-to-generation serving: the RGL "unified system" front-end.

``RAGServeEngine`` closes the gap between the retrieval pipeline and the
decode server: a raw ``(query_emb, query_text)`` request goes through

    index -> seed retrieval -> subgraph construction -> dynamic filter
          -> tokenization -> batched prefill -> continuous-batching decode

inside one engine.  Three amortization mechanisms drive throughput:

* **Batched admission retrieval** — every admission wave runs ONE jitted
  ``RGLPipeline.retrieve_many`` call over the whole wave (padded to a fixed
  shape), instead of per-request retrieval dispatches.  This is the paper's
  core batching speedup applied at serve time.
* **Retrieval caching** — a policy-driven (lru / lfu / ttl, optional expiry)
  :class:`~repro.serving.cache.RetrievalCache` keyed on quantized query
  embeddings lets repeated / near-duplicate queries skip index + BFS + filter
  entirely.  Hit/miss counters are exposed as ``engine.cache_hits`` /
  ``engine.cache_misses``; pick the policy via ``cache_policy`` /
  ``cache_ttl`` engine kwargs.
* **Async admission prefetch** (``prefetch=True``, or ``RGL_PREFETCH=1``) —
  wave *i+1*'s retrieval is *launched* (dispatched, results left as device
  arrays) while wave *i*'s decode steps run, and *collected* (forced,
  tokenized, admitted) only once decode slots free up: double-buffered
  admission via :class:`~repro.serving.prefetch.AdmissionPrefetcher`.  Sync
  mode runs the identical launch/collect code back-to-back, so the two
  schedules produce bitwise-identical outputs (see
  ``tests/test_async_serving.py``).

Two admission *granularities* sit on top of either schedule
(``admission=`` / ``RGL_ADMISSION``): classic **wave** admission retrieves
and admits whole waves, while **continuous** admission launches one
retrieval per request and — under prefetch — collects whichever request's
retrieval is ready (``AdmissionPrefetcher.ready_index``), so a single slow
retrieval row no longer delays its wave-mates and a freed decode slot never
waits for a wave boundary.  Outputs are bitwise identical across all four
combinations (greedy decode is schedule-invariant per request).

Generation itself rides the slot-based :class:`~repro.serving.engine.ServeEngine`
(one jitted decode step for all slots, masked batched prefill admission).
``spec_decode`` / ``RGL_SPEC_DECODE=1`` switches the decode arena to
self-speculative multi-token decode (prompt-lookup drafts verified in one
dispatch; bitwise-identical outputs, up to ``draft_window`` tokens committed
per dispatch) — see :mod:`repro.serving.engine`.
"""
from __future__ import annotations

import dataclasses
import os
from collections import deque
from typing import Optional

import numpy as np

from repro.core.pipeline import RGLPipeline
from repro.models.transformer.config import TransformerConfig
from repro.serving.cache import RetrievalCache
from repro.serving.engine import Request, ServeEngine, env_flag
from repro.serving.prefetch import AdmissionPrefetcher


def _prefetch_default() -> bool:
    """``RGL_PREFETCH`` env toggle, so the whole test/CI matrix can flip the
    admission schedule without touching call sites.  Only explicit truthy
    values enable it — anything else (including "no"/"disabled") stays sync."""
    return env_flag("RGL_PREFETCH")


def _admission_default() -> str:
    """``RGL_ADMISSION`` env default ("wave").  Invalid values raise — the
    two schedules produce identical outputs, so a typo would otherwise run
    silently in the wrong mode."""
    raw = os.environ.get("RGL_ADMISSION", "wave").lower()
    if raw not in ("wave", "continuous"):
        raise ValueError(
            f"RGL_ADMISSION={raw!r}: expected 'wave' or 'continuous'"
        )
    return raw


@dataclasses.dataclass
class RAGRequest:
    """A raw serving request: query embedding + query text, no tokens yet."""

    uid: int
    query_emb: np.ndarray  # (D,) float32
    query_text: str
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    prompt_ids: Optional[np.ndarray] = None  # filled at admission
    retrieved_nodes: Optional[np.ndarray] = None  # filtered subgraph members
    cache_hit: bool = False
    done: bool = False
    # retired early by KV exhaustion (contiguous arena full / paged pool
    # empty): out_tokens is shorter than max_new_tokens with no EOS
    truncated: bool = False


class RAGServeEngine:
    """End-to-end RAG server: retrieval-batched admission over a decode arena.

    Usage::

        eng = RAGServeEngine(pipe, params, cfg, slots=8, cache_len=256)
        eng.submit(RAGRequest(uid=0, query_emb=emb, query_text="..."))
        finished = eng.run_to_completion()   # .out_tokens per request

    ``pipe`` must carry a tokenizer and node_text (stages 4's inputs).
    ``prefetch=None`` reads the ``RGL_PREFETCH`` env var (default off).
    """

    def __init__(
        self,
        pipeline: RGLPipeline,
        params,
        cfg: TransformerConfig,
        *,
        slots: int = 8,
        cache_len: int = 512,
        eos_id: Optional[int] = None,
        retrieval_cache: Optional[RetrievalCache] = None,
        cache_capacity: int = 256,
        quant_eps: float = 1e-3,
        cache_policy: str = "lru",
        cache_ttl: Optional[float] = None,
        prefetch: Optional[bool] = None,
        prefetch_depth: Optional[int] = None,
        admission: Optional[str] = None,
        spec_decode: Optional[bool] = None,
        draft_window: Optional[int] = None,
        paged_kv: Optional[bool] = None,
        kv_block_size: Optional[int] = None,
        kv_pool_blocks: Optional[int] = None,
    ):
        assert pipeline.tokenizer is not None, "pipeline needs a tokenizer"
        assert pipeline.node_text is not None, "pipeline needs node_text"
        if pipeline.tokenizer.max_len >= cache_len:
            raise ValueError(
                f"tokenizer.max_len={pipeline.tokenizer.max_len} must be < "
                f"cache_len={cache_len} so every prompt fits the KV arena"
            )
        self.pipeline = pipeline
        self.slots = slots
        self.engine = ServeEngine(
            params, cfg, slots=slots, cache_len=cache_len, eos_id=eos_id,
            spec_decode=spec_decode, draft_window=draft_window,
            paged_kv=paged_kv, block_size=kv_block_size,
            pool_blocks=kv_pool_blocks,
        )
        self.cache = retrieval_cache if retrieval_cache is not None else \
            RetrievalCache(capacity=cache_capacity, quant_eps=quant_eps,
                           policy=cache_policy, ttl=cache_ttl)
        self.prefetch = _prefetch_default() if prefetch is None else \
            bool(prefetch)
        self.admission = _admission_default() if admission is None else \
            str(admission).lower()
        if self.admission not in ("wave", "continuous"):
            raise ValueError(
                f"admission={self.admission!r}: expected 'wave' or "
                f"'continuous'"
            )
        if prefetch_depth is None:
            # continuous admission launches size-1 waves, so the in-flight
            # window must hold one wave per slot to keep every free slot's
            # retrieval overlapping; wave admission double-buffers (depth 1)
            prefetch_depth = slots if self.admission == "continuous" else 1
        # continuous launches always carry one request, so the retrieval
        # batch pads to 1 row instead of `slots` — per-row retrieval is
        # row-independent, so results stay bitwise identical while the
        # per-dispatch compute stops scaling with the unused padding
        self.prefetcher = AdmissionPrefetcher(
            pipeline, self.cache,
            wave_size=1 if self.admission == "continuous" else slots,
            depth=prefetch_depth,
        )
        self.pending: deque = deque()
        self._inflight: dict = {}  # admission ticket -> RAGRequest
        self._next_ticket = 0  # monotonic; never reused (unlike id())
        self._step_no = 0

    # -- cache counters -------------------------------------------------------
    @property
    def cache_hits(self) -> int:
        return self.cache.hits

    @property
    def cache_misses(self) -> int:
        return self.cache.misses

    # -- amortization telemetry (delegated to the prefetcher, which runs the
    # launch/collect phases for both admission schedules) ----------------------
    @property
    def retrieval_batches(self) -> int:
        return self.prefetcher.batches

    @property
    def retrieved_queries(self) -> int:
        return self.prefetcher.queries

    @property
    def retrieval_seconds(self) -> float:
        p = self.prefetcher
        return p.launch_seconds + p.block_seconds

    # -- admission ------------------------------------------------------------
    def submit(self, req: RAGRequest) -> None:
        self.pending.append(req)

    def _take_wave(self, limit: Optional[int] = None) -> list:
        cap = self.slots if limit is None else limit
        take = min(len(self.pending), cap)
        return [self.pending.popleft() for _ in range(take)]

    @property
    def _launch_unit(self) -> int:
        """Requests per retrieval launch: a full wave in wave admission, a
        single request in continuous admission (so one slow retrieval row
        never blocks the admission of its would-be wave-mates)."""
        return 1 if self.admission == "continuous" else self.slots

    def _tokenize_and_admit(self, resolved: list) -> None:
        """Stage 4+5 handoff: linearize each (request, entry) pair and hand
        the prompt to the decode engine under a fresh admission ticket."""
        tok = self.pipeline.tokenizer
        node_text = self.pipeline.node_text
        for r, e in resolved:
            texts = [node_text[int(v)] for v, m in zip(e.nodes, e.mask) if m]
            ids, mask = tok.linearize(r.query_text, texts)
            r.prompt_ids = ids[mask]
            r.retrieved_nodes = e.nodes[e.mask].copy()
            inner = Request(
                uid=r.uid, prompt_ids=r.prompt_ids,
                max_new_tokens=r.max_new_tokens, ticket=self._next_ticket,
            )
            self._inflight[inner.ticket] = r
            self._next_ticket += 1
            self.engine.submit(inner)

    def _admit_sync(self) -> None:
        """Sync schedule: launch one wave and collect it immediately (the
        collect's ``np.asarray`` blocks for the full retrieval latency).
        Continuous admission runs the same blocking launch+collect per
        *request* instead — one admission unit per free slot."""
        if self.admission == "continuous":
            while self.engine.free_slots > 0 and self.pending:
                reqs = self._take_wave(1)
                tok = self.engine.emitted_tokens
                self.prefetcher.launch(reqs, step=self._step_no, tokens=tok)
                self._tokenize_and_admit(self.prefetcher.collect(
                    step=self._step_no, tokens=tok, sync=True))
            return
        reqs = self._take_wave()
        if not reqs:
            return
        tok = self.engine.emitted_tokens
        self.prefetcher.launch(reqs, step=self._step_no, tokens=tok)
        self._tokenize_and_admit(
            self.prefetcher.collect(step=self._step_no, tokens=tok, sync=True)
        )

    def _launch_pending(self) -> None:
        while self.pending and self.prefetcher.can_launch():
            self.prefetcher.launch(self._take_wave(self._launch_unit),
                                   step=self._step_no,
                                   tokens=self.engine.emitted_tokens)

    def _admit_prefetch(self) -> None:
        """Prefetch schedule: collect waves as decode slots free up
        (backpressure: never tokenize/admit into a still-full arena) and
        launch the next wave(s) so their retrieval overlaps this step's
        decode.  The launch is sandwiched between a wave's collect (which
        inserts its cache entries — so the next lookup sees them) and its
        tokenize/admit, putting the admission overhead *inside* the next
        wave's overlap window too."""
        while (self.prefetcher.launched_before(self._step_no)
                and self.engine.free_slots > 0):
            # never collect a wave in the step it launched (that would
            # forfeit its whole overlap window, e.g. under trickle load
            # where wave size < free slots) — except via the idle-arena
            # fast path below, where there is nothing to overlap with
            resolved = self.prefetcher.collect(
                step=self._step_no, tokens=self.engine.emitted_tokens
            )
            self._launch_pending()
            self._tokenize_and_admit(resolved)
        self._launch_pending()
        if (not self.engine.live.any() and not self.engine.queue
                and self.prefetcher.in_flight):
            # idle arena: nothing to overlap with, don't stall a step
            self._tokenize_and_admit(
                self.prefetcher.collect(step=self._step_no,
                                        tokens=self.engine.emitted_tokens)
            )

    def _admit_continuous(self) -> None:
        """Continuous + prefetch: per-request launches, out-of-FIFO collect.
        Each free slot collects whichever in-flight single-request wave is
        *ready* (device arrays landed, deferred owners resolved) via
        ``ready_index``/``collect_at`` — so one slow retrieval row delays
        only its own request, never its would-be wave-mates.  Launches are
        sandwiched between collect and tokenize/admit exactly like the wave
        schedule, keeping the admission overhead inside the next request's
        overlap window."""
        self._launch_pending()
        while self.engine.free_slots > 0 and self.prefetcher.in_flight:
            idx = self.prefetcher.ready_index()
            if idx is None:
                break
            resolved = self.prefetcher.collect_at(
                idx, step=self._step_no, tokens=self.engine.emitted_tokens
            )
            self._launch_pending()
            self._tokenize_and_admit(resolved)
        if (not self.engine.live.any() and not self.engine.queue
                and self.prefetcher.in_flight):
            # idle arena with nothing ready: block on the oldest wave rather
            # than burn empty steps (oldest first keeps deferred owners
            # resolving before their dependents)
            self._tokenize_and_admit(
                self.prefetcher.collect(step=self._step_no,
                                        tokens=self.engine.emitted_tokens)
            )
            self._launch_pending()

    # -- stepping -------------------------------------------------------------
    def step(self) -> list:
        """One engine step: admission (sync or prefetched, wave or
        continuous) + one decode step.  Returns the RAG requests that
        finished this step."""
        if not self.prefetch:
            self._admit_sync()
        elif self.admission == "continuous":
            self._admit_continuous()
        else:
            self._admit_prefetch()
        finished_inner = self.engine.step()
        self._step_no += 1
        out = []
        for inner in finished_inner:
            r = self._inflight.pop(inner.ticket)
            r.out_tokens = inner.out_tokens
            r.truncated = inner.truncated
            r.done = True
            out.append(r)
        return out

    def _drained(self) -> bool:
        return (not self.pending and not self.prefetcher.in_flight
                and not self.engine.queue and not self.engine.live.any())

    def run_to_completion(self, max_steps: int = 10_000) -> list:
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if self._drained():
                return done
        raise RuntimeError(
            f"run_to_completion: work still pending after {max_steps} steps "
            f"({len(self.pending)} pending, {self.prefetcher.in_flight} "
            f"in-flight waves, {len(self.engine.queue)} queued, "
            f"{int(self.engine.live.sum())} live slots)"
        )

    def stats(self) -> dict:
        s = self.cache.stats()
        s.update(
            retrieval_batches=self.retrieval_batches,
            retrieved_queries=self.retrieved_queries,
            retrieval_seconds=self.retrieval_seconds,
            prefetch=self.prefetch,
            admission=self.admission,
            **self.prefetcher.stats(),
            **self.engine.decode_stats(),
        )
        return s
