"""One config surface for the serving stack: ``ServingConfig``.

Nine PRs of organic growth left serving configuration spread over ~15
``RGL_*`` env vars, per-engine kwargs, and ``launch.serve`` CLI flags with
ad-hoc precedence.  ``ServingConfig`` consolidates all of it into one
frozen dataclass — decode arena, retrieval cache, admission/prefetch,
paged-KV/prefix-share, speculative decode, fault tolerance, router, and
the online-mutation tier — with ONE documented precedence rule:

    explicit kwarg  >  RGL_* environment variable  >  built-in default

Resolution model: a field value of ``None`` means "not specified here".
:meth:`ServingConfig.resolve` overlays explicit (non-None) kwargs onto a
base config, then :meth:`ServingConfig.finalize` fills every remaining
env-backed ``None`` from its ``RGL_*`` variable (or the built-in default)
and validates.  :meth:`ServingConfig.from_env` is the no-kwargs resolver.
Fields whose default is *derived from other fields* (``kv_block_size``,
``kv_pool_blocks``, ``prefetch_depth``, ``draft_window``,
``replica_depth``) may legitimately stay ``None`` after finalize; the
consuming layer derives them exactly as before.

``RAGServeEngine(config=...)``, :class:`repro.serving.router.ReplicaRouter`
and ``repro.launch.serve`` are built on this; the engines' historical
kwargs keep working as a deprecation shim (they become the explicit-kwarg
layer of the same resolution).

Env var -> field map (see the README table):

========================  =======================  ====================
field                     env var                  default
========================  =======================  ====================
prefetch                  RGL_PREFETCH             False
admission                 RGL_ADMISSION            "wave"
spec_decode               RGL_SPEC_DECODE          False
draft_window              RGL_DRAFT_WINDOW         4 (engine-derived)
paged_kv                  RGL_PAGED_KV             False
kv_block_size             RGL_KV_BLOCK             auto (engine-derived)
prefix_share              RGL_PREFIX_SHARE         False
cache_ttl                 RGL_CACHE_TTL            None (no expiry)
retrieval_timeout_s       RGL_RETRIEVAL_TIMEOUT    None (no timeout)
max_retries               RGL_RETRIES              0
retry_backoff_s           RGL_RETRY_BACKOFF        0.0
degraded_mode             RGL_DEGRADED             True
max_pending               RGL_MAX_PENDING          0 (unbounded)
shed_policy               RGL_SHED_POLICY          "reject"
default_deadline_s        RGL_DEADLINE             None (no deadline)
mutation                  RGL_MUTATION             False
compact_every             RGL_COMPACT_EVERY        0 (manual compaction)
========================  =======================  ====================
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional


def env_flag(name: str) -> bool:
    """Truthy env toggle: only explicit affirmative values enable."""
    return os.environ.get(name, "").lower() in ("1", "true", "on", "yes")


def _env_float(name: str) -> Optional[float]:
    """Optional float env knob; empty/unset means None, junk raises (a typo
    must not silently disable a fault-tolerance deadline)."""
    raw = os.environ.get(name, "").strip()
    if not raw:
        return None
    try:
        return float(raw)
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not a number") from None


def _env_int(name: str, default: Optional[int]) -> Optional[int]:
    raw = os.environ.get(name, "").strip()
    if not raw:
        return default
    try:
        return int(raw)
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not an integer") from None


def _degraded_default() -> bool:
    """``RGL_DEGRADED`` env toggle, default ON: degraded-mode admission is
    part of the graceful ladder, so only an explicit falsy value disables
    it (the opposite polarity of ``env_flag``)."""
    return os.environ.get("RGL_DEGRADED", "").lower() not in (
        "0", "false", "off", "no"
    )


def _shed_policy_default() -> str:
    raw = os.environ.get("RGL_SHED_POLICY", "reject").lower()
    if raw not in ("reject", "evict-oldest"):
        raise ValueError(
            f"RGL_SHED_POLICY={raw!r}: expected 'reject' or 'evict-oldest'"
        )
    return raw


def _admission_default() -> str:
    """``RGL_ADMISSION`` env default ("wave").  Invalid values raise — the
    two schedules produce identical outputs, so a typo would otherwise run
    silently in the wrong mode."""
    raw = os.environ.get("RGL_ADMISSION", "wave").lower()
    if raw not in ("wave", "continuous"):
        raise ValueError(
            f"RGL_ADMISSION={raw!r}: expected 'wave' or 'continuous'"
        )
    return raw


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Every serving knob in one frozen value (see module docstring).

    ``None`` in an env-backed field means "resolve from the environment";
    after :meth:`finalize` those fields are concrete.  ``None`` in a
    derived field (``draft_window``, ``kv_block_size``, ``kv_pool_blocks``,
    ``prefetch_depth``, ``replica_depth``) means "let the consuming layer
    derive it" and may persist.
    """

    # -- decode arena -----------------------------------------------------
    slots: int = 8
    cache_len: int = 512
    eos_id: Optional[int] = None
    spec_decode: Optional[bool] = None
    draft_window: Optional[int] = None
    paged_kv: Optional[bool] = None
    kv_block_size: Optional[int] = None
    kv_pool_blocks: Optional[int] = None
    prefix_share: Optional[bool] = None
    # -- retrieval cache --------------------------------------------------
    cache_capacity: int = 256
    quant_eps: float = 1e-3
    cache_policy: str = "lru"
    cache_ttl: Optional[float] = None
    region_bucket: int = 32
    mutation_flush: str = "region"
    # -- admission / prefetch ---------------------------------------------
    prefetch: Optional[bool] = None
    prefetch_depth: Optional[int] = None
    admission: Optional[str] = None
    # -- fault tolerance / overload control -------------------------------
    retrieval_timeout_s: Optional[float] = None
    max_retries: Optional[int] = None
    retry_backoff_s: Optional[float] = None
    degraded_mode: Optional[bool] = None
    max_pending: Optional[int] = None
    shed_policy: Optional[str] = None
    default_deadline_s: Optional[float] = None
    # -- replica router ---------------------------------------------------
    replicas: int = 1
    failover: bool = True
    replica_depth: Optional[int] = None
    health_window: int = 8
    trip_threshold: int = 3
    cooldown_steps: int = 8
    # -- online mutation --------------------------------------------------
    mutation: Optional[bool] = None
    compact_every: Optional[int] = None

    _ENV_BOOL = (("spec_decode", "RGL_SPEC_DECODE"),
                 ("paged_kv", "RGL_PAGED_KV"),
                 ("prefix_share", "RGL_PREFIX_SHARE"),
                 ("prefetch", "RGL_PREFETCH"),
                 ("mutation", "RGL_MUTATION"))

    @classmethod
    def from_env(cls) -> "ServingConfig":
        """The single env resolver: built-in defaults overlaid with every
        set ``RGL_*`` variable."""
        return cls().finalize()

    @classmethod
    def resolve(cls, config: Optional["ServingConfig"] = None,
                **overrides) -> "ServingConfig":
        """Apply the precedence rule: explicit kwarg > env > default.

        ``overrides`` entries that are ``None`` count as "not specified"
        (they fall through to ``config``, then env, then default) —
        exactly the contract the engines' historical kwargs had.
        """
        base = config if config is not None else cls()
        explicit = {k: v for k, v in overrides.items() if v is not None}
        unknown = set(explicit) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise TypeError(
                f"unknown ServingConfig field(s): {sorted(unknown)}"
            )
        return dataclasses.replace(base, **explicit).finalize()

    def finalize(self) -> "ServingConfig":
        """Fill env-backed ``None`` fields from ``RGL_*`` and validate."""
        kw = {}
        for field, env in self._ENV_BOOL:
            if getattr(self, field) is None:
                kw[field] = env_flag(env)
        if self.admission is None:
            kw["admission"] = _admission_default()
        else:
            adm = str(self.admission).lower()
            if adm not in ("wave", "continuous"):
                raise ValueError(
                    f"admission={adm!r}: expected 'wave' or 'continuous'"
                )
            kw["admission"] = adm
        if self.shed_policy is None:
            kw["shed_policy"] = _shed_policy_default()
        else:
            shed = str(self.shed_policy).lower()
            if shed not in ("reject", "evict-oldest"):
                raise ValueError(
                    f"shed_policy={shed!r}: expected 'reject' or "
                    f"'evict-oldest'"
                )
            kw["shed_policy"] = shed
        if self.draft_window is None and os.environ.get("RGL_DRAFT_WINDOW"):
            kw["draft_window"] = _env_int("RGL_DRAFT_WINDOW", None)
        if self.kv_block_size is None and os.environ.get("RGL_KV_BLOCK"):
            kw["kv_block_size"] = _env_int("RGL_KV_BLOCK", None)
        if self.cache_ttl is None:
            kw["cache_ttl"] = _env_float("RGL_CACHE_TTL")
        if self.retrieval_timeout_s is None:
            kw["retrieval_timeout_s"] = _env_float("RGL_RETRIEVAL_TIMEOUT")
        if self.max_retries is None:
            kw["max_retries"] = _env_int("RGL_RETRIES", 0)
        if self.retry_backoff_s is None:
            kw["retry_backoff_s"] = _env_float("RGL_RETRY_BACKOFF") or 0.0
        if self.degraded_mode is None:
            kw["degraded_mode"] = _degraded_default()
        if self.max_pending is None:
            kw["max_pending"] = _env_int("RGL_MAX_PENDING", 0)
        max_pending = kw.get("max_pending", self.max_pending)
        if max_pending < 0:
            raise ValueError(f"max_pending must be >= 0, got {max_pending}")
        if self.default_deadline_s is None:
            kw["default_deadline_s"] = _env_float("RGL_DEADLINE")
        if self.compact_every is None:
            kw["compact_every"] = _env_int("RGL_COMPACT_EVERY", 0)
        if self.mutation_flush not in ("region", "all"):
            raise ValueError(
                f"mutation_flush must be 'region' or 'all', got "
                f"{self.mutation_flush!r}"
            )
        return dataclasses.replace(self, **kw) if kw else self
