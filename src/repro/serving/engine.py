"""Batched serving engine: continuous batching over fixed decode slots.

vLLM-style scheduling adapted to TPU constraints (static shapes): a fixed
(B, cache_len) KV arena; each of the B slots holds one in-flight request.
Every engine step runs ONE jitted dispatch for all slots.  Admission is
batched too: all free slots are refilled together by a single masked batched
prefill — prompts are padded to a shared length bucket, run through one
``tm.prefill`` call, and the resulting cache rows are merged into the arena
with one jitted masked update (never reshaping, never per-slot dispatch).

Length bucketing keeps recompilation bounded: the prefill trace is specialized
on (slots, bucket) only, so at most O(log cache_len) prefill programs exist
over the lifetime of the engine.

Two decode modes share the arena:

* **one-token** (default) — each step is one ``tm.serve_step``: one jitted
  dispatch per output token, so tok/s is bounded by per-step dispatch
  overhead.
* **self-speculative** (``spec_decode=True`` or ``RGL_SPEC_DECODE=1``) —
  each step drafts a window of ``draft_window`` tokens per slot from the
  request's own prompt+output history (:mod:`repro.serving.drafter`, no
  second model) and verifies all of them in ONE jitted ``tm.verify_step``
  dispatch.  Greedy argmax verification accepts the longest draft prefix
  that matches what one-token decode would have emitted, so outputs are
  bitwise identical to the one-token schedule while each dispatch can
  commit up to ``draft_window`` tokens (see ``tests/test_spec_decode.py``).

This engine serves already-tokenized prompts.  For the fused
retrieval-to-generation front-end (the RGL "unified system" claim), see
:class:`repro.serving.rag_engine.RAGServeEngine`, which batches graph
retrieval across admissions and feeds this engine.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import model as tm
from repro.models.transformer.config import TransformerConfig
from repro.serving.drafter import draft_tokens


def env_flag(name: str) -> bool:
    """Truthy env toggle: only explicit affirmative values enable — anything
    else (including "no"/"disabled"/unset) stays off."""
    return os.environ.get(name, "").lower() in ("1", "true", "on", "yes")


def _draft_window_default() -> int:
    """``RGL_DRAFT_WINDOW`` env default.  The raw value is returned
    unclamped — the constructor applies the same ``>= 2`` validation to the
    env path as to an explicit ``draft_window=`` argument, so an invalid
    setting fails loudly instead of being silently rewritten."""
    raw = os.environ.get("RGL_DRAFT_WINDOW", "4")
    try:
        return int(raw)
    except ValueError:
        raise ValueError(
            f"RGL_DRAFT_WINDOW={raw!r} is not an integer"
        ) from None


def _auto_block_size(cache_len: int, preferred: int = 16) -> int:
    """Largest block size <= ``preferred`` dividing ``cache_len``, so the
    RGL_PAGED_KV env toggle works for any arena length without per-caller
    block-size plumbing."""
    for b in range(min(preferred, cache_len), 0, -1):
        if cache_len % b == 0:
            return b
    return 1


@dataclasses.dataclass
class Request:
    uid: int
    prompt_ids: np.ndarray  # (L,) int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # retired early by KV exhaustion (arena full, or paged pool empty):
    # out_tokens is shorter than max_new_tokens and did not end at EOS
    truncated: bool = False
    # retired by ServeEngine.abort(): whatever tokens were emitted so far
    # are kept, ``error`` carries the abort reason
    failed: bool = False
    error: Optional[str] = None
    # monotonic admission ticket assigned by the submitting front-end; a
    # stable identity that, unlike id(self), is never reused after GC
    ticket: int = -1
    # prefix sharing (paged arena + RGL_PREFIX_SHARE, set by the RAG layer):
    # ``shared_prefix`` names a CachedRetrieval whose pinned prefilled KV
    # blocks cover this request's exact prompt — admission re-validates and
    # aliases them instead of running prefill; ``pin_to`` names an entry
    # that should receive this request's freshly prefilled prompt blocks as
    # its pin (the donor side).  Both are best-effort: a released pin or a
    # prompt mismatch falls back to the ordinary prefill path.
    shared_prefix: object = None
    pin_to: object = None


@dataclasses.dataclass
class _SharePlan:
    """Admission-time snapshot of a validated prefix share.  Snapshotting
    (plus the refcount holds the engine takes when the plan is made)
    decouples the admission dispatch from the donor entry: a cache eviction
    or pin reclaim between planning and dispatch cannot invalidate the
    blocks mid-wave."""

    blocks: np.ndarray  # all ceil(L/bs) donor prompt blocks, table order
    nfull: int  # full leading blocks to alias
    tail: int  # donor's partial tail block to COW-copy, -1 if none
    length: int  # prompt tokens covered
    first_tok: int  # the donor prefill's recorded argmax


def _bucket_len(n: int, cache_len: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor), capped at cache_len."""
    b = floor
    while b < n:
        b <<= 1
    return min(b, cache_len)


@functools.partial(jax.jit, static_argnames=("cfg", "cache_len"))
def _prefill_batch(params, toks, tl, cfg: TransformerConfig, cache_len: int):
    """Module-level jit so traces are shared across engine instances —
    constructing a fresh engine must not recompile the serving programs."""
    return tm.prefill(params, toks, tl, cfg, cache_len)


@functools.partial(jax.jit, static_argnames=("cfg", "n_draft", "eos_id"))
def _spec_step(params, cache, cur_tok, hist, hist_len, max_new, out_len,
               cfg: TransformerConfig, n_draft: int, eos_id):
    """ONE fused dispatch per speculative engine step: prompt-lookup draft,
    per-slot acceptance room, windowed verify, acceptance + cursor rewind,
    and the history append of the accepted tokens.  Keeping the drafter,
    the room computation, and the history update inside the same jit
    matters on dispatch-bound hosts: at small model sizes each extra jitted
    call or host->device transfer costs about as much as the verify compute
    itself, so the host only downloads (greedy, accepted) per step and only
    uploads state at admission waves.

    max_new / out_len (B,) int32 are device mirrors of each slot's token
    budget and emitted count (pinned at admission, advanced here), so
    ``room = min(max_new - out_len, cache_len - cursor)`` — the clamp that
    keeps a window from overshooting ``max_new_tokens`` or the arena —
    never syncs the host.
    """
    drafts = draft_tokens(hist, hist_len, n_draft)
    fed = jnp.concatenate([cur_tok[:, None], drafts], axis=1)
    sc = cache.k.shape[2]
    room = jnp.minimum(max_new - out_len, sc - cache.cursor).astype(jnp.int32)
    greedy, accepted, nxt, cache = tm.verify_step(
        params, cache, fed, room, cfg, eos_id=eos_id
    )
    # append the accepted tokens to each slot's history (device-resident:
    # the host never re-uploads the arena between admissions)
    h = hist.shape[1]
    cols = jnp.arange(h, dtype=jnp.int32)[None, :]
    for i in range(n_draft + 1):
        write = (i < accepted)[:, None] & (cols == (hist_len + i)[:, None])
        hist = jnp.where(write, greedy[:, i:i + 1], hist)
    hist_len = jnp.minimum(hist_len + accepted, h)
    # pack (greedy, accepted) into ONE host-bound buffer: the engine's per-
    # step sync is a single device->host transfer, like one-token decode's
    packed = jnp.concatenate([greedy, accepted[:, None]], axis=1)
    return packed, nxt, cache, hist, hist_len, out_len + accepted


@jax.jit
def _merge_admitted(arena: tm.KVCache, new: tm.KVCache, cur_tok, first,
                    rows, newly):
    """Masked merge of freshly prefilled rows into the slot arena.

    ``rows[i]`` names the prefill-batch row feeding slot i; ``newly[i]`` masks
    which slots actually admit.  Elementwise select => shards cleanly.
    """

    def mix_b1(a, b):  # (L, B, ...) — batch on axis 1 (k/v/scales)
        if a is None:
            return None
        m = newly.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, b[:, rows], a)

    def mix_b0(a, b):  # (B, ...) — batch on axis 0 (pos/cursor)
        if a is None:
            return None
        m = newly.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, b[rows], a)

    cache = tm.KVCache(
        k=mix_b1(arena.k, new.k),
        v=mix_b1(arena.v, new.v),
        pos=mix_b0(arena.pos, new.pos),
        cursor=mix_b0(arena.cursor, new.cursor),
        k_scale=mix_b1(arena.k_scale, new.k_scale),
        v_scale=mix_b1(arena.v_scale, new.v_scale),
    )
    return cache, jnp.where(newly, first[rows], cur_tok)


@functools.partial(jax.jit, static_argnames=("block_size",))
def _paged_merge_admitted(arena: "tm.PagedKVCache", new: tm.KVCache, cur_tok,
                          first, rows, newly, tl, block_size: int):
    """Paged-arena admission merge: allocate each admitted slot's prompt
    blocks (ceil(L/bs)) from the free stack and scatter its freshly
    prefilled rows into the pool.  ``tl`` (B,) is the per-SLOT prompt
    length (0 where not admitting); pos/cursor/cur_tok merge with the same
    semantics as :func:`_merge_admitted`."""
    bs = block_size
    b, sc = arena.pos.shape
    p_rows = arena.k.shape[1]
    m = arena.table.shape[1]
    target = jnp.where(newly, (tl + bs - 1) // bs, 0)
    table, n_free, ref = tm.alloc_blocks(
        arena.table, arena.free, arena.n_free, arena.ref, target, newly, m
    )
    rowmap = tm.block_rows(table, bs)  # (B, Sc)
    spos = jnp.arange(sc, dtype=jnp.int32)[None, :]
    # scatter every row of the allocated blocks (zero-padding past the
    # prompt included — pos == -1 masks it, same as the contiguous merge);
    # rows past the allocation go out of range and drop
    valid = newly[:, None] & (spos < target[:, None] * bs)
    dst = jnp.where(valid, rowmap, p_rows).reshape(-1)  # (B*Sc,)

    def scat(pool, fresh):  # fresh (L, B, Sc, ...) -> pool (L, P, ...)
        if pool is None:
            return None
        vals = fresh[:, rows].reshape(
            (fresh.shape[0], b * sc) + fresh.shape[3:]
        )
        return pool.at[:, dst].set(vals, mode="drop")

    pos_new = jnp.where(spos < tl[:, None], spos, -1)
    cache = tm.PagedKVCache(
        k=scat(arena.k, new.k),
        v=scat(arena.v, new.v),
        pos=jnp.where(newly[:, None], pos_new, arena.pos),
        cursor=jnp.where(newly, tl.astype(jnp.int32), arena.cursor),
        table=table,
        free=arena.free,
        n_free=n_free,
        ref=ref,
        k_scale=scat(arena.k_scale, new.k_scale),
        v_scale=scat(arena.v_scale, new.v_scale),
    )
    return cache, jnp.where(newly, first[rows], cur_tok)


@functools.partial(
    jax.jit, static_argnames=("cfg", "n_draft", "eos_id", "block_size")
)
def _paged_spec_step(params, cache, cur_tok, hist, hist_len, max_new,
                     out_len, live, cfg: TransformerConfig, n_draft: int,
                     eos_id, block_size: int):
    """:func:`_spec_step` over the paged pool: identical draft / room /
    acceptance / history arithmetic (so outputs stay bitwise identical to
    the contiguous arena), with ``live`` gating the pool allocator and the
    block scatters inside :func:`tm.paged_verify_step`."""
    drafts = draft_tokens(hist, hist_len, n_draft)
    fed = jnp.concatenate([cur_tok[:, None], drafts], axis=1)
    sc = cache.pos.shape[1]
    room = jnp.minimum(max_new - out_len, sc - cache.cursor).astype(jnp.int32)
    greedy, accepted, nxt, cache = tm.paged_verify_step(
        params, cache, fed, room, live, cfg, eos_id=eos_id,
        block_size=block_size,
    )
    h = hist.shape[1]
    cols = jnp.arange(h, dtype=jnp.int32)[None, :]
    for i in range(n_draft + 1):
        write = (i < accepted)[:, None] & (cols == (hist_len + i)[:, None])
        hist = jnp.where(write, greedy[:, i:i + 1], hist)
    hist_len = jnp.minimum(hist_len + accepted, h)
    packed = jnp.concatenate([greedy, accepted[:, None]], axis=1)
    return packed, nxt, cache, hist, hist_len, out_len + accepted


class ServeEngine:
    """Continuous-batching decode server over a fixed KV arena.

    Usage::

        eng = ServeEngine(params, cfg, slots=8, cache_len=512)
        eng.submit(Request(uid=0, prompt_ids=ids, max_new_tokens=32))
        finished = eng.run_to_completion()

    ``spec_decode=None`` reads the ``RGL_SPEC_DECODE`` env var (default
    off); ``draft_window`` defaults to ``RGL_DRAFT_WINDOW`` (4).

    ``paged_kv=None`` reads ``RGL_PAGED_KV`` (default off: contiguous
    arena).  When paged, the KV arena is a shared pool of
    ``pool_blocks`` blocks of ``block_size`` tokens
    (:class:`repro.models.transformer.model.PagedKVCache`): a slot only
    holds blocks its cursor has actually crossed, and returns them the
    step its request retires, so total KV memory tracks *live tokens*
    instead of ``slots * cache_len``.  Outputs are bitwise identical to
    the contiguous arena in both decode modes.  ``block_size=None`` picks
    the largest divisor of ``cache_len`` <= 16 (override via arg or
    ``RGL_KV_BLOCK``); ``pool_blocks=None`` sizes the pool to full
    capacity (``slots * cache_len / block_size`` — never truncates).  An
    undersized pool is the memory-saving mode: admission gates on block
    availability (FIFO — an oversized head-of-line request blocks the
    queue rather than being skipped), and when live slots outgrow the
    pool mid-decode the engine retires the highest-indexed needy slot
    with ``truncated=True`` *before* the dispatch, so the in-jit
    allocator never over-pops and never needs a host sync.
    """

    def __init__(
        self, params, cfg: TransformerConfig, *, slots: int = 8,
        cache_len: int = 512, eos_id: Optional[int] = None,
        spec_decode: Optional[bool] = None, draft_window: Optional[int] = None,
        paged_kv: Optional[bool] = None, block_size: Optional[int] = None,
        pool_blocks: Optional[int] = None, prefix_share: Optional[bool] = None,
    ):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.spec_decode = env_flag("RGL_SPEC_DECODE") if spec_decode is None \
            else bool(spec_decode)
        self.draft_window = _draft_window_default() if draft_window is None \
            else int(draft_window)
        if self.spec_decode and self.draft_window < 2:
            raise ValueError(
                f"draft_window must be >= 2 (1 committed token + >= 1 draft),"
                f" got {self.draft_window}"
            )
        self.queue: deque = deque()
        self.active: list = [None] * slots
        self.live = np.zeros(slots, bool)
        self.paged_kv = env_flag("RGL_PAGED_KV") if paged_kv is None \
            else bool(paged_kv)
        # prefix sharing is a paged-arena feature: on a contiguous arena the
        # flag is inert (admission behaves exactly as before), so the
        # contiguous cells of the CI matrix double as the fallback parity leg
        self.prefix_share = (
            env_flag("RGL_PREFIX_SHARE") if prefix_share is None
            else bool(prefix_share)
        ) and self.paged_kv
        self.truncations = 0  # requests retired by KV exhaustion (both modes)
        if block_size is None:
            env_bs = os.environ.get("RGL_KV_BLOCK", "")
            block_size = int(env_bs) if env_bs else None
        if self.paged_kv:
            bs = _auto_block_size(cache_len) if block_size is None \
                else int(block_size)
            if bs < 1 or cache_len % bs != 0:
                raise ValueError(
                    f"block_size={bs} must divide cache_len={cache_len}"
                )
            self.block_size = bs
            self.max_blocks = cache_len // bs
            self.pool_blocks = slots * self.max_blocks if pool_blocks is None \
                else int(pool_blocks)
            if self.pool_blocks < self.max_blocks:
                raise ValueError(
                    f"pool_blocks={self.pool_blocks} cannot hold even one "
                    f"full-length request ({self.max_blocks} blocks)"
                )
            self.cache = tm.init_paged_cache(
                cfg, slots, cache_len, bs, self.pool_blocks
            )
            # host mirrors of the device allocator state: admission and
            # every dispatch replay the same block arithmetic the jitted
            # allocator runs, so exhaustion checks never sync the device.
            # The mirror is now content-exact, not just depth-exact — the
            # stack's block ids and per-block refcounts are replayed so the
            # host always knows WHICH blocks a slot holds (the retrieval
            # cache pins concrete block ids, and refcounted frees return a
            # data-dependent subset of a retiring slot's blocks)
            self._free_stack: list = list(range(self.pool_blocks))
            self._ref_host = np.zeros(self.pool_blocks, np.int32)
            self._slot_blocks: list = [[] for _ in range(slots)]
            self.pool_high_water = 0  # max blocks ever simultaneously held
            self._live_dev = jnp.asarray(self.live)
            self._live_dirty = False
        else:
            self.cache = tm.init_cache(cfg, slots, cache_len)
        # pre-dispatch invariant guard (satellite of the alloc_blocks
        # sum(need) <= n_free contract): raises host-side with slot/pool
        # counters instead of letting the jitted allocator silently alias
        # stale stack entries.  Env-gated; tests/conftest.py turns it on.
        self._kv_debug = env_flag("RGL_KV_DEBUG")
        # prefix-sharing hooks + telemetry.  kv_pin_gate: entry -> bool,
        # consulted before pinning prompt blocks to a retrieval-cache entry
        # (the RAG layer wires a residency check so blocks are never pinned
        # to an entry that was already evicted).  kv_pin_reclaim:
        # want_blocks -> freed, consulted under pool pressure so cache pins
        # are released before any live request is truncated.
        self.kv_pin_gate = None
        self.kv_pin_reclaim = None
        self.kv_pins = 0  # entries that received a prompt-block pin
        self.kv_releases = 0  # pins released (eviction / reclaim)
        self.kv_pinned_blocks = 0  # blocks currently held by pins
        self.kv_shared_admits = 0  # admissions served by aliased blocks
        self.kv_reused_tokens = 0  # prompt tokens whose prefill was skipped
        self.kv_cow_copies = 0  # partial tail blocks copied at adoption
        self.prefill_batches = 0  # prefill dispatches issued by _admit
        self.prefill_rows = 0  # prompts actually prefilled
        self.admit_seconds = 0.0  # wall time inside _admit
        self.cur_tok = jnp.zeros((slots,), jnp.int32)
        # per-slot token history arena for the prompt-lookup drafter:
        # prompt + every emitted token, left-aligned.  hist_cap bounds the
        # total (prompt < cache_len, decode stops at cursor == cache_len).
        # The host mirror is written at admission and uploaded once per
        # admission wave; between admissions the device copy evolves inside
        # _spec_step and the mirror tracks it via _hist_append.
        self._hist_cap = cache_len + 1
        self.hist = np.zeros((slots, self._hist_cap), np.int32)
        self.hist_len = np.zeros((slots,), np.int32)
        self._hist_dev = jnp.asarray(self.hist)
        self._hist_len_dev = jnp.asarray(self.hist_len)
        # host-tracked cursor mirror: admission pins it to the prompt length,
        # every decode dispatch advances it by the committed token count, so
        # finish checks and speculative room never sync on the device cursor
        self._cursor = np.zeros((slots,), np.int64)
        # device mirrors of each slot's token budget / emitted count for the
        # in-jit acceptance-room clamp (uploaded only at admission waves)
        self._max_new = np.ones((slots,), np.int32)
        self._out_len = np.zeros((slots,), np.int32)
        self._max_new_dev = jnp.asarray(self._max_new)
        self._out_len_dev = jnp.asarray(self._out_len)
        # decode telemetry (both modes): dispatches vs tokens committed
        self.decode_steps = 0  # jitted decode/verify dispatches
        self.slot_steps = 0  # live-slot decode opportunities (slots x steps)
        self.emitted_tokens = 0  # all tokens committed (incl. prefill firsts)
        self.decode_tokens = 0  # tokens committed by decode dispatches
        self.draft_proposed = 0  # draft tokens fed to verification
        self.draft_accepted = 0  # drafts accepted (excludes the free token)

    @property
    def free_slots(self) -> int:
        """Decode slots that remain free once the admission queue drains —
        the backpressure signal for async retrieval prefetch (collect a
        prefetched wave only when it can actually be admitted)."""
        return max(0, int(self.slots - self.live.sum()) - len(self.queue))

    # -- paged-pool host bookkeeping ------------------------------------------
    @property
    def _free_host(self) -> int:
        """Free-stack depth (host mirror) — kept as the historical name so
        existing telemetry and tests read it unchanged."""
        return len(self._free_stack)

    @property
    def _ntab(self) -> np.ndarray:
        """Per-slot allocated-block counts, derived from the content-exact
        block-id mirror (historical name, see ``_slot_blocks``)."""
        return np.array([len(b) for b in self._slot_blocks], np.int64)

    def _blocks_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.block_size)  # ceil division

    def _live_mask(self):
        """Device live mask for the paged dispatches, re-uploaded only when
        liveness changed (H2D upload, never a D2H sync)."""
        if self._live_dirty:
            self._live_dev = jnp.asarray(self.live)
            self._live_dirty = False
        return self._live_dev

    def _guard_alloc(self, need_total: int, where: str) -> None:
        """RGL_KV_DEBUG tripwire for the ``sum(need) <= n_free`` contract of
        ``tm.alloc_blocks``: a violation on device silently aliases stale
        free-stack entries (two slots end up writing the same pool block);
        here it raises with the counters needed to debug the accounting."""
        if self._kv_debug and need_total > len(self._free_stack):
            raise RuntimeError(
                f"paged-KV alloc invariant violated at {where}: dispatch "
                f"would pop {need_total} blocks but the free stack holds "
                f"{len(self._free_stack)} (pool_blocks={self.pool_blocks}, "
                f"pinned={self.kv_pinned_blocks}, "
                f"live={int(self.live.sum())}, "
                f"per-slot blocks={[len(b) for b in self._slot_blocks]})"
            )

    def _pop_host(self, slot: int, n: int) -> list:
        """Replay ``n`` free-stack pops for ``slot`` on the host mirrors —
        exactly the device allocator's order (sequential from the top)."""
        out = []
        for _ in range(n):
            blk = self._free_stack.pop()
            self._ref_host[blk] = 1
            self._slot_blocks[slot].append(blk)
            out.append(blk)
        return out

    def _host_release(self, drops: dict) -> int:
        """Replay refcount drops on the host mirrors: decrement each block's
        count, push blocks hitting zero back in ascending-id order (the
        device's cumsum-compaction order).  Returns blocks pushed."""
        pushed = []
        for blk in sorted(drops):
            r = int(self._ref_host[blk]) - drops[blk]
            if r < 0 and self._kv_debug:
                raise RuntimeError(
                    f"double-free of pool block {blk}: dropping "
                    f"{drops[blk]} holds but refcount is "
                    f"{int(self._ref_host[blk])} (pool_blocks="
                    f"{self.pool_blocks}, pinned={self.kv_pinned_blocks})"
                )
            self._ref_host[blk] = max(r, 0)
            if drops[blk] > 0 and r <= 0:
                pushed.append(blk)
        self._free_stack.extend(pushed)
        return len(pushed)

    def _free_slots_paged(self, slot_ids) -> None:
        """Drop the named slots' holds on their blocks: one jitted dispatch,
        mirrored on host.  Blocks shared with other slots or pinned by the
        retrieval cache stay out of the free stack until their last holder
        lets go."""
        mask = np.zeros(self.slots, bool)
        mask[list(slot_ids)] = True
        self.cache = tm.free_slot_blocks(self.cache, jnp.asarray(mask))
        drops: dict = {}
        for i in slot_ids:
            for blk in self._slot_blocks[i]:
                drops[blk] = drops.get(blk, 0) + 1
            self._slot_blocks[i] = []
        self._host_release(drops)
        self._live_dirty = True

    def _release_retired(self, live_before: np.ndarray) -> None:
        """Free the blocks of every slot that retired during this step's
        finish checks (batched into one dispatch)."""
        retired = np.where(live_before & ~self.live)[0]
        if retired.size:
            self._free_slots_paged(retired.tolist())

    def _paged_step_need(self) -> np.ndarray:
        """Per-slot blocks the next dispatch's in-jit allocator will pop —
        the identical arithmetic replayed on the host mirrors (cursor and
        table-prefix counts advance deterministically, so the two never
        diverge)."""
        w = self.draft_window if self.spec_decode else 1
        need = np.zeros(self.slots, np.int64)
        for i in range(self.slots):
            if not self.live[i]:
                continue
            hi = min(int(self._cursor[i]) + w, self.cache_len)
            need[i] = max(self._blocks_for(hi) - len(self._slot_blocks[i]), 0)
        return need

    def _reclaim_pins(self, deficit: int) -> int:
        """Ask the cache tier (via the RAG layer's hook) to release pinned
        prefilled-KV blocks under pool pressure — cache pins must never cost
        a live request tokens, so this runs before any truncation."""
        if self.kv_pin_reclaim is None or deficit <= 0:
            return 0
        return int(self.kv_pin_reclaim(int(deficit)))

    def _retire_pool_exhausted(self) -> list:
        """Host-side pre-dispatch exhaustion check: while the pool cannot
        cover every live slot's next-step allocation, first release cache
        pins, then retire the highest-indexed slot that needs a block
        (``truncated=True``) and reclaim its blocks.  Deterministic, and it
        guarantees the jitted allocator never over-pops — the device needs
        no exhaustion path."""
        finished = []
        need = self._paged_step_need()
        self._reclaim_pins(int(need.sum()) - self._free_host)
        while need.sum() > self._free_host:
            needy = np.where(need > 0)[0]
            i = int(needy[-1])
            req = self.active[i]
            req.done = True
            req.truncated = True
            self.truncations += 1
            finished.append(req)
            self.active[i] = None
            self.live[i] = False
            self._free_slots_paged([i])
            need[i] = 0
        return finished

    def _apply_paged_alloc(self) -> None:
        """Advance the host allocator mirrors by exactly what the dispatch
        being issued will pop on device."""
        need = self._paged_step_need()
        tot = int(need.sum())
        if tot:
            self._guard_alloc(tot, "decode step")
            for i in range(self.slots):
                if need[i]:
                    self._pop_host(i, int(need[i]))
        self.pool_high_water = max(
            self.pool_high_water, self.pool_blocks - self._free_host
        )

    # -- prefix sharing: pins, plans, adoption --------------------------------
    def _acquire_host(self, ids) -> None:
        self.cache = tm.acquire_blocks(
            self.cache, jnp.asarray(np.asarray(ids, np.int32))
        )
        for blk in ids:
            self._ref_host[int(blk)] += 1

    def _release_ids(self, ids) -> int:
        """Drop one hold per listed block (device + host mirrors); returns
        how many blocks actually returned to the free stack."""
        self.cache = tm.release_blocks(
            self.cache, jnp.asarray(np.asarray(ids, np.int32))
        )
        drops: dict = {}
        for blk in ids:
            drops[int(blk)] = drops.get(int(blk), 0) + 1
        return self._host_release(drops)

    def _pin_entry(self, entry, slot: int, req: "Request", tok0: int) -> None:
        """Attach the freshly prefilled prompt blocks of ``slot`` to the
        retrieval-cache entry that produced the prompt: the pin takes one
        refcount hold per block, records the exact prompt and first token,
        and registers a release hook the cache calls on eviction."""
        if getattr(entry, "kv_blocks", None) is not None:
            return  # already pinned (by this request's wave-mate or earlier)
        if self.kv_pin_gate is not None and not self.kv_pin_gate(entry):
            return  # entry no longer resident — pinning would leak blocks
        L = len(req.prompt_ids)
        blocks = np.asarray(
            self._slot_blocks[slot][:self._blocks_for(L)], np.int32
        )
        if blocks.size == 0:
            return
        self._acquire_host(blocks)
        entry.kv_blocks = blocks
        entry.kv_len = L
        entry.kv_first_tok = int(tok0)
        entry.kv_prompt = np.asarray(req.prompt_ids, np.int32).copy()
        entry.kv_owner = self
        entry.kv_release = self._release_kv_pin
        self.kv_pins += 1
        self.kv_pinned_blocks += int(blocks.size)

    def _release_kv_pin(self, entry) -> int:
        """Release an entry's prompt-block pin (cache eviction hook and the
        pool-pressure reclaim path).  Idempotent; returns how many blocks
        actually came back to the free stack (blocks still aliased by live
        slots stay out until those slots retire)."""
        blocks = getattr(entry, "kv_blocks", None)
        if blocks is None:
            return 0
        entry.kv_blocks = None
        entry.kv_prompt = None
        entry.kv_owner = None
        entry.kv_release = None
        self.kv_releases += 1
        self.kv_pinned_blocks -= int(np.asarray(blocks).size)
        return self._release_ids(list(np.asarray(blocks)))

    def _plan_share(self, req: "Request"):
        """Validate a request's ``shared_prefix`` against the entry's pin at
        admission time and snapshot it into a :class:`_SharePlan`, taking
        one refcount hold per donor block so nothing the plan references
        can be recycled before the adoption dispatch.  Returns None (and
        takes no holds) when the pin is gone, owned by another engine's
        pool, or covers a different prompt — the request then just prefills
        fresh, which is always correct."""
        entry = req.shared_prefix
        if entry is None:
            return None
        blocks = getattr(entry, "kv_blocks", None)
        if blocks is None or getattr(entry, "kv_owner", None) is not self:
            return None
        kp = getattr(entry, "kv_prompt", None)
        pi = np.asarray(req.prompt_ids, np.int32)
        if kp is None or len(kp) != len(pi) or not np.array_equal(kp, pi):
            return None
        L = int(entry.kv_len)
        blocks = np.asarray(blocks, np.int32)
        nfull = L // self.block_size
        tail = int(blocks[-1]) if L % self.block_size else -1
        plan = _SharePlan(blocks=blocks, nfull=nfull, tail=tail, length=L,
                          first_tok=int(entry.kv_first_tok))
        self._acquire_host(blocks)
        return plan

    def _drop_plan(self, plan: "_SharePlan") -> None:
        """Release a plan's holds without admitting it (gate backout)."""
        self._release_ids(list(plan.blocks))

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt_ids) >= self.cache_len:
            raise ValueError(
                f"prompt of {len(req.prompt_ids)} tokens cannot fit "
                f"cache_len={self.cache_len} (need room for >=1 new token)"
            )
        self.queue.append(req)

    def abort(self, reason: str = "aborted") -> list:
        """Retire every queued and live request (``failed=True``, partial
        ``out_tokens`` kept) and reconcile the arena: slots freed, paged KV
        blocks returned to the pool, queue cleared.  The engine is reusable
        afterwards — a fresh workload admits into a clean arena.  Returns
        the aborted requests."""
        out = []
        live_idx = [i for i in range(self.slots) if self.live[i]]
        for i in live_idx:
            req = self.active[i]
            req.done = True
            req.failed = True
            req.error = reason
            self.active[i] = None
            self.live[i] = False
            out.append(req)
        if self.paged_kv and live_idx:
            self._free_slots_paged(live_idx)
        while self.queue:
            req = self.queue.popleft()
            req.done = True
            req.failed = True
            req.error = reason
            out.append(req)
        return out

    def _admit(self) -> list:
        t0 = time.perf_counter()
        try:
            return self._admit_inner()
        finally:
            self.admit_seconds += time.perf_counter() - t0

    def _admit_inner(self) -> list:
        """Refill free slots with one masked batched prefill.  Returns the
        requests that finish AT admission (first token hits EOS, or
        ``max_new_tokens == 1``) — they never occupy a live slot, so a
        request can never emit more than ``max_new_tokens`` tokens.

        Paged arena: admission additionally gates on free blocks —
        ceil((L+1)/bs) per request, prompt plus the first decode write, so
        an admit is never pool-truncated on its very first step.  FIFO is
        preserved: a head-of-line request that does not fit blocks the
        rest of the queue instead of being skipped (full-size pools never
        gate, keeping admission identical to the contiguous schedule).

        Prefix sharing (``prefix_share``): a request whose validated
        ``shared_prefix`` entry pins this pool's blocks skips the prefill
        batch entirely — its plan aliases the donor's full blocks and
        COW-copies the partial tail in one ``tm.adopt_prefix_blocks``
        dispatch, so it only needs the gate's usual one-extra-block
        reservation.  Under pool pressure the gate releases cache pins
        before refusing a head-of-line request, so sharing never admits
        *less* than the unshared schedule would."""
        free = [i for i in range(self.slots) if not self.live[i]]
        plans: dict = {}  # queue position taken -> _SharePlan
        if self.paged_kv:
            take = 0
            taken = 0  # blocks already committed to earlier takes
            for r in list(self.queue)[:len(free)]:
                full_need = self._blocks_for(
                    min(len(r.prompt_ids) + 1, self.cache_len)
                )
                plan = self._plan_share(r) if self.prefix_share else None
                need = full_need - plan.nfull if plan is not None \
                    else full_need
                if need > self._free_host - taken:
                    self._reclaim_pins(need - (self._free_host - taken))
                if need > self._free_host - taken:
                    if plan is not None:
                        self._drop_plan(plan)
                    break
                if plan is not None:
                    plans[take] = plan
                taken += need
                take += 1
        else:
            take = min(len(free), len(self.queue))
        if take == 0:
            return []
        reqs = [self.queue.popleft() for _ in range(take)]
        slot_ids = free[:take]
        first_by_slot = np.zeros(self.slots, np.int64)
        # -- fresh population: one masked batched prefill (batch padded to
        # `slots` rows, lengths padded to a shared power-of-two bucket)
        fresh_pairs = [(j, i) for j, i in enumerate(slot_ids)
                       if j not in plans]
        if fresh_pairs:
            bucket = _bucket_len(
                max(len(reqs[j].prompt_ids) for j, _ in fresh_pairs),
                self.cache_len,
            )
            toks = np.zeros((self.slots, bucket), np.int32)
            tl = np.zeros((self.slots,), np.int32)
            for f, (j, _) in enumerate(fresh_pairs):
                L = len(reqs[j].prompt_ids)  # submit() guarantees L < Sc
                toks[f, :L] = np.asarray(reqs[j].prompt_ids, np.int32)
                tl[f] = L
            logits, fresh = _prefill_batch(
                self.params, jnp.asarray(toks), jnp.asarray(tl),
                self.cfg, self.cache_len,
            )
            self.prefill_batches += 1
            self.prefill_rows += len(fresh_pairs)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (slots,)
            rows = np.zeros(self.slots, np.int32)
            newly = np.zeros(self.slots, bool)
            tl_slot = np.zeros(self.slots, np.int32)
            for f, (j, i) in enumerate(fresh_pairs):
                rows[i] = f
                newly[i] = True
                tl_slot[i] = tl[f]
            if self.paged_kv:
                self._guard_alloc(
                    sum(self._blocks_for(int(t)) for t in tl_slot),
                    "admission prefill merge",
                )
                self.cache, self.cur_tok = _paged_merge_admitted(
                    self.cache, fresh, self.cur_tok, first,
                    jnp.asarray(rows), jnp.asarray(newly),
                    jnp.asarray(tl_slot), self.block_size,
                )
                # replay the merge's pops: slot-index ascending, exactly the
                # device allocator's order
                for f, (j, i) in enumerate(fresh_pairs):
                    self._pop_host(i, self._blocks_for(int(tl[f])))
                self._live_dirty = True
            else:
                self.cache, self.cur_tok = _merge_admitted(
                    self.cache, fresh, self.cur_tok, first,
                    jnp.asarray(rows), jnp.asarray(newly),
                )
            first_np = np.asarray(first)
            for f, (j, i) in enumerate(fresh_pairs):
                first_by_slot[i] = int(first_np[f])
        # -- shared population: alias donor blocks, no prefill dispatch
        if plans:
            mask = np.zeros(self.slots, bool)
            src_table = np.full((self.slots, self.max_blocks), -1, np.int32)
            length = np.zeros(self.slots, np.int32)
            tail = np.full(self.slots, -1, np.int32)
            firsts = np.zeros(self.slots, np.int32)
            for j, i in enumerate(slot_ids):
                plan = plans.get(j)
                if plan is None:
                    continue
                mask[i] = True
                src_table[i, :plan.nfull] = plan.blocks[:plan.nfull]
                length[i] = plan.length
                tail[i] = plan.tail
                firsts[i] = plan.first_tok
                first_by_slot[i] = plan.first_tok
            self._guard_alloc(int((tail >= 0).sum()), "prefix-share adopt")
            self.cache, self.cur_tok = tm.adopt_prefix_blocks(
                self.cache, self.cur_tok, jnp.asarray(mask),
                jnp.asarray(src_table), jnp.asarray(length),
                jnp.asarray(tail), jnp.asarray(firsts), self.block_size,
            )
            # host replay, in the dispatch's order: tail pops (slot index
            # ascending), then the one-dispatch tail-source holds release
            tail_drops: dict = {}
            for j, i in enumerate(slot_ids):
                plan = plans.get(j)
                if plan is None:
                    continue
                self._slot_blocks[i] = [int(b)
                                        for b in plan.blocks[:plan.nfull]]
                if plan.tail >= 0:
                    self._pop_host(i, 1)
                    tail_drops[plan.tail] = tail_drops.get(plan.tail, 0) + 1
                    self.kv_cow_copies += 1
                self.kv_shared_admits += 1
                self.kv_reused_tokens += plan.length
            self._host_release(tail_drops)
            self._live_dirty = True
        if self.paged_kv:
            self.pool_high_water = max(
                self.pool_high_water, self.pool_blocks - self._free_host
            )
        finished = []
        dead_at_admission = []
        for j, i in enumerate(slot_ids):
            req = reqs[j]
            tok0 = int(first_by_slot[i])
            req.out_tokens.append(tok0)
            self.emitted_tokens += 1
            L = len(req.prompt_ids)
            self._cursor[i] = L  # merge/adopt pinned this slot's cursor
            if (self.prefix_share and j not in plans
                    and req.pin_to is not None):
                # donor side: hand this prompt's freshly prefilled blocks to
                # the retrieval-cache entry so the next identical prompt
                # skips prefill
                self._pin_entry(req.pin_to, i, req, tok0)
            hit_eos = self.eos_id is not None and tok0 == self.eos_id
            if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
                # done at admission: the arena row was written but the slot
                # never goes live, so the next wave simply reuses it
                req.done = True
                finished.append(req)
                dead_at_admission.append(i)
                continue
            self.active[i] = req
            self.live[i] = True
            self.hist[i, :L] = np.asarray(req.prompt_ids, np.int32)
            self.hist[i, L] = tok0
            self.hist_len[i] = L + 1
            self._max_new[i] = req.max_new_tokens
            self._out_len[i] = 1
        if self.paged_kv and dead_at_admission:
            # admission allocated these slots' prompt blocks, but the slot
            # never went live — give the blocks straight back (pinned or
            # still-shared blocks stay with their remaining holders)
            self._free_slots_paged(dead_at_admission)
        if self.spec_decode:
            self._hist_dev = jnp.asarray(self.hist)
            self._hist_len_dev = jnp.asarray(self.hist_len)
            self._max_new_dev = jnp.asarray(self._max_new)
            self._out_len_dev = jnp.asarray(self._out_len)
        return finished

    def _hist_append(self, i: int, toks: list) -> None:
        hl = int(self.hist_len[i])
        n = min(len(toks), self._hist_cap - hl)
        if n > 0:
            self.hist[i, hl:hl + n] = toks[:n]
            self.hist_len[i] = hl + n

    def _finish_check(self, i: int, req: Request, last_tok: int,
                      cursor_i: int, finished: list) -> None:
        hit_eos = self.eos_id is not None and last_tok == self.eos_id
        budget_full = len(req.out_tokens) >= req.max_new_tokens
        arena_full = cursor_i >= self.cache_len
        if hit_eos or budget_full or arena_full:
            req.done = True
            if arena_full and not (hit_eos or budget_full):
                # retired by KV exhaustion, not by its own budget or an
                # EOS: flag it so callers can tell a complete answer from
                # a clipped one instead of silently receiving fewer tokens
                req.truncated = True
                self.truncations += 1
            finished.append(req)
            self.active[i] = None
            self.live[i] = False

    # -- one decode step for every live slot ----------------------------------
    def step(self) -> list:
        finished = self._admit()
        if self.paged_kv and self.live.any():
            finished.extend(self._retire_pool_exhausted())
        if not self.live.any():
            return finished
        if self.spec_decode:
            finished.extend(self._step_spec())
        else:
            finished.extend(self._step_one())
        return finished

    def _step_one(self) -> list:
        """One-token decode: one jitted dispatch emits one token per slot."""
        if self.paged_kv:
            self._apply_paged_alloc()
            nxt, self.cache = tm.paged_serve_step(
                self.params, self.cache, self.cur_tok, self._live_mask(),
                self.cfg, self.block_size,
            )
        else:
            nxt, self.cache = tm.serve_step(
                self.params, self.cache, self.cur_tok, self.cfg
            )
        self.cur_tok = nxt
        self.decode_steps += 1
        self._cursor += 1  # decode_step advances every slot's cursor
        finished = []
        live_before = self.live.copy()
        toks = np.asarray(nxt)
        for i, req in enumerate(self.active):
            if req is None or not self.live[i]:
                continue
            t = int(toks[i])
            req.out_tokens.append(t)
            self.emitted_tokens += 1
            self.decode_tokens += 1
            self.slot_steps += 1
            self._hist_append(i, [t])
            self._finish_check(i, req, t, int(self._cursor[i]), finished)
        if self.paged_kv:
            self._release_retired(live_before)
        return finished

    def _step_spec(self) -> list:
        """Self-speculative decode: draft ``W-1`` tokens per slot from its
        own history, verify all of them, and commit the greedy-matching
        prefix (1..W tokens per slot) — all in ONE jitted dispatch."""
        w = self.draft_window
        # acceptance room is computed in-jit from the device mirrors; both
        # terms are >= 1 for a live slot (admission retires len >= max_new
        # immediately, decode retires cursor >= cache_len).  Dead slots run
        # with whatever stale room their mirrors imply (clamped >= 1, so up
        # to W of drift per step) — harmless: writes stay masked at the
        # arena edge and admission re-pins cursor/mirrors before reuse
        if self.paged_kv:
            self._apply_paged_alloc()
            (packed, self.cur_tok, self.cache, self._hist_dev,
             self._hist_len_dev, self._out_len_dev) = _paged_spec_step(
                self.params, self.cache, self.cur_tok, self._hist_dev,
                self._hist_len_dev, self._max_new_dev, self._out_len_dev,
                self._live_mask(), self.cfg, w - 1, self.eos_id,
                self.block_size,
            )
        else:
            (packed, self.cur_tok, self.cache, self._hist_dev,
             self._hist_len_dev, self._out_len_dev) = _spec_step(
                self.params, self.cache, self.cur_tok, self._hist_dev,
                self._hist_len_dev, self._max_new_dev, self._out_len_dev,
                self.cfg, w - 1, self.eos_id,
            )
        self.decode_steps += 1
        finished = []
        live_before = self.live.copy()
        packed_np = np.asarray(packed)  # the step's single host sync
        g_np, acc_np = packed_np[:, :w], packed_np[:, w]
        self._cursor += acc_np  # verify_step advanced every slot by accepted
        self._out_len += acc_np  # keep the host mirror bitwise in step
        for i, req in enumerate(self.active):
            if req is None or not self.live[i]:
                continue
            a = int(acc_np[i])
            emitted = g_np[i, :a].tolist()
            req.out_tokens.extend(emitted)
            self.emitted_tokens += a
            self.decode_tokens += a
            self.slot_steps += 1
            self.draft_proposed += w - 1
            self.draft_accepted += a - 1
            self._hist_append(i, emitted)
            self._finish_check(i, req, emitted[-1], int(self._cursor[i]),
                               finished)
        if self.paged_kv:
            self._release_retired(live_before)
        return finished

    def decode_stats(self) -> dict:
        """Dispatch-amortization telemetry.  ``tokens_per_step`` is the mean
        number of tokens a live slot commits per jitted decode dispatch —
        exactly 1.0 in one-token mode, up to ``draft_window`` under
        speculation — i.e. the accepted-tokens/step signal, normalized per
        slot so batch occupancy does not inflate it."""
        stats = {
            "spec_decode": self.spec_decode,
            "draft_window": self.draft_window if self.spec_decode else 1,
            "decode_steps": self.decode_steps,
            "emitted_tokens": self.emitted_tokens,
            "decode_tokens": self.decode_tokens,
            "draft_proposed": self.draft_proposed,
            "draft_accepted": self.draft_accepted,
            "tokens_per_step": self.decode_tokens / max(self.slot_steps, 1),
            "draft_accept_rate": (
                self.draft_accepted / self.draft_proposed
                if self.draft_proposed else 0.0
            ),
            "paged_kv": self.paged_kv,
            "truncations": self.truncations,
            "prefix_share": self.prefix_share,
            "prefill_batches": self.prefill_batches,
            "prefill_rows": self.prefill_rows,
            "admit_seconds": self.admit_seconds,
        }
        if self.paged_kv:
            stats.update(
                block_size=self.block_size,
                pool_blocks=self.pool_blocks,
                pool_high_water_blocks=self.pool_high_water,
                pool_free_blocks=self._free_host,
                kv_shared_admits=self.kv_shared_admits,
                kv_reused_tokens=self.kv_reused_tokens,
                kv_cow_copies=self.kv_cow_copies,
                kv_pins=self.kv_pins,
                kv_releases=self.kv_releases,
                kv_pinned_blocks=self.kv_pinned_blocks,
            )
        return stats

    def stats_ns(self) -> dict:
        """Namespaced stats (unified serving schema): the decode arena's
        counters under ``decode.*`` — see :mod:`repro.serving.stats`."""
        return {"decode": self.decode_stats()}

    def run_to_completion(self, max_steps: int = 10_000) -> list:
        """Step until every request drains.  Raises if ``max_steps`` elapse
        with work still queued or live, instead of silently returning a
        partial result set."""
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and not self.live.any():
                return done
        raise RuntimeError(
            f"run_to_completion: work still pending after {max_steps} steps "
            f"({len(self.queue)} queued, {int(self.live.sum())} live slots)"
        )
