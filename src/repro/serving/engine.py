"""Batched serving engine: continuous batching over fixed decode slots.

vLLM-style scheduling adapted to TPU constraints (static shapes): a fixed
(B, cache_len) KV arena; each of the B slots holds one in-flight request.
Every engine step runs ONE jitted decode step for all slots.  Admission is
batched too: all free slots are refilled together by a single masked batched
prefill — prompts are padded to a shared length bucket, run through one
``tm.prefill`` call, and the resulting cache rows are merged into the arena
with one jitted masked update (never reshaping, never per-slot dispatch).

Length bucketing keeps recompilation bounded: the prefill trace is specialized
on (slots, bucket) only, so at most O(log cache_len) prefill programs exist
over the lifetime of the engine.

This engine serves already-tokenized prompts.  For the fused
retrieval-to-generation front-end (the RGL "unified system" claim), see
:class:`repro.serving.rag_engine.RAGServeEngine`, which batches graph
retrieval across admissions and feeds this engine.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import model as tm
from repro.models.transformer.config import TransformerConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt_ids: np.ndarray  # (L,) int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # monotonic admission ticket assigned by the submitting front-end; a
    # stable identity that, unlike id(self), is never reused after GC
    ticket: int = -1


def _bucket_len(n: int, cache_len: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor), capped at cache_len."""
    b = floor
    while b < n:
        b <<= 1
    return min(b, cache_len)


@functools.partial(jax.jit, static_argnames=("cfg", "cache_len"))
def _prefill_batch(params, toks, tl, cfg: TransformerConfig, cache_len: int):
    """Module-level jit so traces are shared across engine instances —
    constructing a fresh engine must not recompile the serving programs."""
    return tm.prefill(params, toks, tl, cfg, cache_len)


@jax.jit
def _merge_admitted(arena: tm.KVCache, new: tm.KVCache, cur_tok, first,
                    rows, newly):
    """Masked merge of freshly prefilled rows into the slot arena.

    ``rows[i]`` names the prefill-batch row feeding slot i; ``newly[i]`` masks
    which slots actually admit.  Elementwise select => shards cleanly.
    """

    def mix_b1(a, b):  # (L, B, ...) — batch on axis 1 (k/v/scales)
        if a is None:
            return None
        m = newly.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, b[:, rows], a)

    def mix_b0(a, b):  # (B, ...) — batch on axis 0 (pos/cursor)
        if a is None:
            return None
        m = newly.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, b[rows], a)

    cache = tm.KVCache(
        k=mix_b1(arena.k, new.k),
        v=mix_b1(arena.v, new.v),
        pos=mix_b0(arena.pos, new.pos),
        cursor=mix_b0(arena.cursor, new.cursor),
        k_scale=mix_b1(arena.k_scale, new.k_scale),
        v_scale=mix_b1(arena.v_scale, new.v_scale),
    )
    return cache, jnp.where(newly, first[rows], cur_tok)


class ServeEngine:
    """Continuous-batching decode server over a fixed KV arena.

    Usage::

        eng = ServeEngine(params, cfg, slots=8, cache_len=512)
        eng.submit(Request(uid=0, prompt_ids=ids, max_new_tokens=32))
        finished = eng.run_to_completion()
    """

    def __init__(
        self, params, cfg: TransformerConfig, *, slots: int = 8,
        cache_len: int = 512, eos_id: Optional[int] = None,
    ):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.queue: deque = deque()
        self.active: list = [None] * slots
        self.cache = tm.init_cache(cfg, slots, cache_len)
        self.cur_tok = jnp.zeros((slots,), jnp.int32)
        self.live = np.zeros(slots, bool)

    @property
    def free_slots(self) -> int:
        """Decode slots that remain free once the admission queue drains —
        the backpressure signal for async retrieval prefetch (collect a
        prefetched wave only when it can actually be admitted)."""
        return max(0, int(self.slots - self.live.sum()) - len(self.queue))

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt_ids) >= self.cache_len:
            raise ValueError(
                f"prompt of {len(req.prompt_ids)} tokens cannot fit "
                f"cache_len={self.cache_len} (need room for >=1 new token)"
            )
        self.queue.append(req)

    def _admit(self) -> None:
        free = [i for i in range(self.slots) if not self.live[i]]
        take = min(len(free), len(self.queue))
        if take == 0:
            return
        reqs = [self.queue.popleft() for _ in range(take)]
        slot_ids = free[:take]
        # one masked batched prefill: batch padded to `slots` rows, lengths
        # padded to a shared power-of-two bucket
        bucket = _bucket_len(max(len(r.prompt_ids) for r in reqs),
                             self.cache_len)
        toks = np.zeros((self.slots, bucket), np.int32)
        tl = np.zeros((self.slots,), np.int32)
        for j, r in enumerate(reqs):
            L = len(r.prompt_ids)  # submit() guarantees L < cache_len
            toks[j, :L] = np.asarray(r.prompt_ids, np.int32)
            tl[j] = L
        logits, fresh = _prefill_batch(
            self.params, jnp.asarray(toks), jnp.asarray(tl),
            self.cfg, self.cache_len,
        )
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (slots,)
        rows = np.zeros(self.slots, np.int32)
        newly = np.zeros(self.slots, bool)
        for j, i in enumerate(slot_ids):
            rows[i] = j
            newly[i] = True
        self.cache, self.cur_tok = _merge_admitted(
            self.cache, fresh, self.cur_tok, first,
            jnp.asarray(rows), jnp.asarray(newly),
        )
        first_np = np.asarray(first)
        for j, i in enumerate(slot_ids):
            req = reqs[j]
            req.out_tokens.append(int(first_np[j]))
            self.active[i] = req
            self.live[i] = True

    # -- one decode step for every live slot ----------------------------------
    def step(self) -> list:
        self._admit()
        if not self.live.any():
            return []
        nxt, self.cache = tm.serve_step(
            self.params, self.cache, self.cur_tok, self.cfg
        )
        self.cur_tok = nxt
        finished = []
        toks = np.asarray(nxt)
        for i, req in enumerate(self.active):
            if req is None or not self.live[i]:
                continue
            req.out_tokens.append(int(toks[i]))
            hit_eos = self.eos_id is not None and int(toks[i]) == self.eos_id
            full = (
                len(req.out_tokens) >= req.max_new_tokens
                or int(self.cache.cursor[i]) >= self.cache_len
            )
            if hit_eos or full:
                req.done = True
                finished.append(req)
                self.active[i] = None
                self.live[i] = False
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> list:
        """Step until every request drains.  Raises if ``max_steps`` elapse
        with work still queued or live, instead of silently returning a
        partial result set."""
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and not self.live.any():
                return done
        raise RuntimeError(
            f"run_to_completion: work still pending after {max_steps} steps "
            f"({len(self.queue)} queued, {int(self.live.sum())} live slots)"
        )
