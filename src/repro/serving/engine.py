"""Batched serving engine: continuous batching over fixed decode slots.

vLLM-style scheduling adapted to TPU constraints (static shapes): a fixed
(B, cache_len) KV arena; each of the B slots holds one in-flight request.
Every engine step runs ONE jitted decode step for all slots; finished or
empty slots are refilled by (re-)prefilling the pending queue — prefill for
slot i writes its cache rows via a masked batched update, never reshaping.

This is the RGL generation stage's server: prompts arrive already tokenized
by the pipeline (retrieval happens upstream, possibly on other hosts).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import model as tm
from repro.models.transformer.config import TransformerConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt_ids: np.ndarray  # (L,) int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self, params, cfg: TransformerConfig, *, slots: int = 8,
        cache_len: int = 512, eos_id: Optional[int] = None,
    ):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.queue: deque = deque()
        self.active: list = [None] * slots
        self.cache = tm.init_cache(cfg, slots, cache_len)
        self.cur_tok = jnp.zeros((slots,), jnp.int32)
        self.live = np.zeros(slots, bool)
        self._decode = jax.jit(
            lambda p, c, t: tm.serve_step(p, c, t, cfg), static_argnums=()
        )
        self._prefill_one = jax.jit(
            lambda p, toks, tl: tm.prefill(p, toks, tl, cfg, cache_len)
        )

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.slots):
            if self.live[i] or not self.queue:
                continue
            req = self.queue.popleft()
            L = len(req.prompt_ids)
            toks = jnp.asarray(req.prompt_ids, jnp.int32)[None]
            tl = jnp.asarray([L], jnp.int32)
            logits, cache1 = self._prefill_one(self.params, toks, tl)
            first = int(jnp.argmax(logits[0]))
            # merge this request's rows into the shared arena
            self.cache = tm.KVCache(
                k=self.cache.k.at[:, i].set(cache1.k[:, 0]),
                v=self.cache.v.at[:, i].set(cache1.v[:, 0]),
                pos=self.cache.pos.at[i].set(cache1.pos[0]),
                cursor=self.cache.cursor.at[i].set(cache1.cursor[0]),
            )
            self.cur_tok = self.cur_tok.at[i].set(first)
            req.out_tokens.append(first)
            self.active[i] = req
            self.live[i] = True

    # -- one decode step for every live slot ----------------------------------
    def step(self) -> list:
        self._admit()
        if not self.live.any():
            return []
        nxt, self.cache = self._decode(self.params, self.cache, self.cur_tok)
        self.cur_tok = nxt
        finished = []
        toks = np.asarray(nxt)
        for i, req in enumerate(self.active):
            if req is None or not self.live[i]:
                continue
            req.out_tokens.append(int(toks[i]))
            hit_eos = self.eos_id is not None and int(toks[i]) == self.eos_id
            full = (
                len(req.out_tokens) >= req.max_new_tokens
                or int(self.cache.cursor[i]) >= self.cache_len
            )
            if hit_eos or full:
                req.done = True
                finished.append(req)
                self.active[i] = None
                self.live[i] = False
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> list:
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and not self.live.any():
                break
        return done
