"""Batched serving engine: continuous batching over fixed decode slots.

vLLM-style scheduling adapted to TPU constraints (static shapes): a fixed
(B, cache_len) KV arena; each of the B slots holds one in-flight request.
Every engine step runs ONE jitted dispatch for all slots.  Admission is
batched too: all free slots are refilled together by a single masked batched
prefill — prompts are padded to a shared length bucket, run through one
``tm.prefill`` call, and the resulting cache rows are merged into the arena
with one jitted masked update (never reshaping, never per-slot dispatch).

Length bucketing keeps recompilation bounded: the prefill trace is specialized
on (slots, bucket) only, so at most O(log cache_len) prefill programs exist
over the lifetime of the engine.

Two decode modes share the arena:

* **one-token** (default) — each step is one ``tm.serve_step``: one jitted
  dispatch per output token, so tok/s is bounded by per-step dispatch
  overhead.
* **self-speculative** (``spec_decode=True`` or ``RGL_SPEC_DECODE=1``) —
  each step drafts a window of ``draft_window`` tokens per slot from the
  request's own prompt+output history (:mod:`repro.serving.drafter`, no
  second model) and verifies all of them in ONE jitted ``tm.verify_step``
  dispatch.  Greedy argmax verification accepts the longest draft prefix
  that matches what one-token decode would have emitted, so outputs are
  bitwise identical to the one-token schedule while each dispatch can
  commit up to ``draft_window`` tokens (see ``tests/test_spec_decode.py``).

This engine serves already-tokenized prompts.  For the fused
retrieval-to-generation front-end (the RGL "unified system" claim), see
:class:`repro.serving.rag_engine.RAGServeEngine`, which batches graph
retrieval across admissions and feeds this engine.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import model as tm
from repro.models.transformer.config import TransformerConfig
from repro.serving.drafter import draft_tokens


def env_flag(name: str) -> bool:
    """Truthy env toggle: only explicit affirmative values enable — anything
    else (including "no"/"disabled"/unset) stays off."""
    return os.environ.get(name, "").lower() in ("1", "true", "on", "yes")


def _draft_window_default() -> int:
    """``RGL_DRAFT_WINDOW`` env default.  The raw value is returned
    unclamped — the constructor applies the same ``>= 2`` validation to the
    env path as to an explicit ``draft_window=`` argument, so an invalid
    setting fails loudly instead of being silently rewritten."""
    raw = os.environ.get("RGL_DRAFT_WINDOW", "4")
    try:
        return int(raw)
    except ValueError:
        raise ValueError(
            f"RGL_DRAFT_WINDOW={raw!r} is not an integer"
        ) from None


def _auto_block_size(cache_len: int, preferred: int = 16) -> int:
    """Largest block size <= ``preferred`` dividing ``cache_len``, so the
    RGL_PAGED_KV env toggle works for any arena length without per-caller
    block-size plumbing."""
    for b in range(min(preferred, cache_len), 0, -1):
        if cache_len % b == 0:
            return b
    return 1


@dataclasses.dataclass
class Request:
    uid: int
    prompt_ids: np.ndarray  # (L,) int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # retired early by KV exhaustion (arena full, or paged pool empty):
    # out_tokens is shorter than max_new_tokens and did not end at EOS
    truncated: bool = False
    # retired by ServeEngine.abort(): whatever tokens were emitted so far
    # are kept, ``error`` carries the abort reason
    failed: bool = False
    error: Optional[str] = None
    # monotonic admission ticket assigned by the submitting front-end; a
    # stable identity that, unlike id(self), is never reused after GC
    ticket: int = -1


def _bucket_len(n: int, cache_len: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor), capped at cache_len."""
    b = floor
    while b < n:
        b <<= 1
    return min(b, cache_len)


@functools.partial(jax.jit, static_argnames=("cfg", "cache_len"))
def _prefill_batch(params, toks, tl, cfg: TransformerConfig, cache_len: int):
    """Module-level jit so traces are shared across engine instances —
    constructing a fresh engine must not recompile the serving programs."""
    return tm.prefill(params, toks, tl, cfg, cache_len)


@functools.partial(jax.jit, static_argnames=("cfg", "n_draft", "eos_id"))
def _spec_step(params, cache, cur_tok, hist, hist_len, max_new, out_len,
               cfg: TransformerConfig, n_draft: int, eos_id):
    """ONE fused dispatch per speculative engine step: prompt-lookup draft,
    per-slot acceptance room, windowed verify, acceptance + cursor rewind,
    and the history append of the accepted tokens.  Keeping the drafter,
    the room computation, and the history update inside the same jit
    matters on dispatch-bound hosts: at small model sizes each extra jitted
    call or host->device transfer costs about as much as the verify compute
    itself, so the host only downloads (greedy, accepted) per step and only
    uploads state at admission waves.

    max_new / out_len (B,) int32 are device mirrors of each slot's token
    budget and emitted count (pinned at admission, advanced here), so
    ``room = min(max_new - out_len, cache_len - cursor)`` — the clamp that
    keeps a window from overshooting ``max_new_tokens`` or the arena —
    never syncs the host.
    """
    drafts = draft_tokens(hist, hist_len, n_draft)
    fed = jnp.concatenate([cur_tok[:, None], drafts], axis=1)
    sc = cache.k.shape[2]
    room = jnp.minimum(max_new - out_len, sc - cache.cursor).astype(jnp.int32)
    greedy, accepted, nxt, cache = tm.verify_step(
        params, cache, fed, room, cfg, eos_id=eos_id
    )
    # append the accepted tokens to each slot's history (device-resident:
    # the host never re-uploads the arena between admissions)
    h = hist.shape[1]
    cols = jnp.arange(h, dtype=jnp.int32)[None, :]
    for i in range(n_draft + 1):
        write = (i < accepted)[:, None] & (cols == (hist_len + i)[:, None])
        hist = jnp.where(write, greedy[:, i:i + 1], hist)
    hist_len = jnp.minimum(hist_len + accepted, h)
    # pack (greedy, accepted) into ONE host-bound buffer: the engine's per-
    # step sync is a single device->host transfer, like one-token decode's
    packed = jnp.concatenate([greedy, accepted[:, None]], axis=1)
    return packed, nxt, cache, hist, hist_len, out_len + accepted


@jax.jit
def _merge_admitted(arena: tm.KVCache, new: tm.KVCache, cur_tok, first,
                    rows, newly):
    """Masked merge of freshly prefilled rows into the slot arena.

    ``rows[i]`` names the prefill-batch row feeding slot i; ``newly[i]`` masks
    which slots actually admit.  Elementwise select => shards cleanly.
    """

    def mix_b1(a, b):  # (L, B, ...) — batch on axis 1 (k/v/scales)
        if a is None:
            return None
        m = newly.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, b[:, rows], a)

    def mix_b0(a, b):  # (B, ...) — batch on axis 0 (pos/cursor)
        if a is None:
            return None
        m = newly.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, b[rows], a)

    cache = tm.KVCache(
        k=mix_b1(arena.k, new.k),
        v=mix_b1(arena.v, new.v),
        pos=mix_b0(arena.pos, new.pos),
        cursor=mix_b0(arena.cursor, new.cursor),
        k_scale=mix_b1(arena.k_scale, new.k_scale),
        v_scale=mix_b1(arena.v_scale, new.v_scale),
    )
    return cache, jnp.where(newly, first[rows], cur_tok)


@functools.partial(jax.jit, static_argnames=("block_size",))
def _paged_merge_admitted(arena: "tm.PagedKVCache", new: tm.KVCache, cur_tok,
                          first, rows, newly, tl, block_size: int):
    """Paged-arena admission merge: allocate each admitted slot's prompt
    blocks (ceil(L/bs)) from the free stack and scatter its freshly
    prefilled rows into the pool.  ``tl`` (B,) is the per-SLOT prompt
    length (0 where not admitting); pos/cursor/cur_tok merge with the same
    semantics as :func:`_merge_admitted`."""
    bs = block_size
    b, sc = arena.pos.shape
    p_rows = arena.k.shape[1]
    m = arena.table.shape[1]
    target = jnp.where(newly, (tl + bs - 1) // bs, 0)
    table, n_free = tm.alloc_blocks(
        arena.table, arena.free, arena.n_free, target, newly, m
    )
    rowmap = tm.block_rows(table, bs)  # (B, Sc)
    spos = jnp.arange(sc, dtype=jnp.int32)[None, :]
    # scatter every row of the allocated blocks (zero-padding past the
    # prompt included — pos == -1 masks it, same as the contiguous merge);
    # rows past the allocation go out of range and drop
    valid = newly[:, None] & (spos < target[:, None] * bs)
    dst = jnp.where(valid, rowmap, p_rows).reshape(-1)  # (B*Sc,)

    def scat(pool, fresh):  # fresh (L, B, Sc, ...) -> pool (L, P, ...)
        if pool is None:
            return None
        vals = fresh[:, rows].reshape(
            (fresh.shape[0], b * sc) + fresh.shape[3:]
        )
        return pool.at[:, dst].set(vals, mode="drop")

    pos_new = jnp.where(spos < tl[:, None], spos, -1)
    cache = tm.PagedKVCache(
        k=scat(arena.k, new.k),
        v=scat(arena.v, new.v),
        pos=jnp.where(newly[:, None], pos_new, arena.pos),
        cursor=jnp.where(newly, tl.astype(jnp.int32), arena.cursor),
        table=table,
        free=arena.free,
        n_free=n_free,
        k_scale=scat(arena.k_scale, new.k_scale),
        v_scale=scat(arena.v_scale, new.v_scale),
    )
    return cache, jnp.where(newly, first[rows], cur_tok)


@functools.partial(
    jax.jit, static_argnames=("cfg", "n_draft", "eos_id", "block_size")
)
def _paged_spec_step(params, cache, cur_tok, hist, hist_len, max_new,
                     out_len, live, cfg: TransformerConfig, n_draft: int,
                     eos_id, block_size: int):
    """:func:`_spec_step` over the paged pool: identical draft / room /
    acceptance / history arithmetic (so outputs stay bitwise identical to
    the contiguous arena), with ``live`` gating the pool allocator and the
    block scatters inside :func:`tm.paged_verify_step`."""
    drafts = draft_tokens(hist, hist_len, n_draft)
    fed = jnp.concatenate([cur_tok[:, None], drafts], axis=1)
    sc = cache.pos.shape[1]
    room = jnp.minimum(max_new - out_len, sc - cache.cursor).astype(jnp.int32)
    greedy, accepted, nxt, cache = tm.paged_verify_step(
        params, cache, fed, room, live, cfg, eos_id=eos_id,
        block_size=block_size,
    )
    h = hist.shape[1]
    cols = jnp.arange(h, dtype=jnp.int32)[None, :]
    for i in range(n_draft + 1):
        write = (i < accepted)[:, None] & (cols == (hist_len + i)[:, None])
        hist = jnp.where(write, greedy[:, i:i + 1], hist)
    hist_len = jnp.minimum(hist_len + accepted, h)
    packed = jnp.concatenate([greedy, accepted[:, None]], axis=1)
    return packed, nxt, cache, hist, hist_len, out_len + accepted


class ServeEngine:
    """Continuous-batching decode server over a fixed KV arena.

    Usage::

        eng = ServeEngine(params, cfg, slots=8, cache_len=512)
        eng.submit(Request(uid=0, prompt_ids=ids, max_new_tokens=32))
        finished = eng.run_to_completion()

    ``spec_decode=None`` reads the ``RGL_SPEC_DECODE`` env var (default
    off); ``draft_window`` defaults to ``RGL_DRAFT_WINDOW`` (4).

    ``paged_kv=None`` reads ``RGL_PAGED_KV`` (default off: contiguous
    arena).  When paged, the KV arena is a shared pool of
    ``pool_blocks`` blocks of ``block_size`` tokens
    (:class:`repro.models.transformer.model.PagedKVCache`): a slot only
    holds blocks its cursor has actually crossed, and returns them the
    step its request retires, so total KV memory tracks *live tokens*
    instead of ``slots * cache_len``.  Outputs are bitwise identical to
    the contiguous arena in both decode modes.  ``block_size=None`` picks
    the largest divisor of ``cache_len`` <= 16 (override via arg or
    ``RGL_KV_BLOCK``); ``pool_blocks=None`` sizes the pool to full
    capacity (``slots * cache_len / block_size`` — never truncates).  An
    undersized pool is the memory-saving mode: admission gates on block
    availability (FIFO — an oversized head-of-line request blocks the
    queue rather than being skipped), and when live slots outgrow the
    pool mid-decode the engine retires the highest-indexed needy slot
    with ``truncated=True`` *before* the dispatch, so the in-jit
    allocator never over-pops and never needs a host sync.
    """

    def __init__(
        self, params, cfg: TransformerConfig, *, slots: int = 8,
        cache_len: int = 512, eos_id: Optional[int] = None,
        spec_decode: Optional[bool] = None, draft_window: Optional[int] = None,
        paged_kv: Optional[bool] = None, block_size: Optional[int] = None,
        pool_blocks: Optional[int] = None,
    ):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.spec_decode = env_flag("RGL_SPEC_DECODE") if spec_decode is None \
            else bool(spec_decode)
        self.draft_window = _draft_window_default() if draft_window is None \
            else int(draft_window)
        if self.spec_decode and self.draft_window < 2:
            raise ValueError(
                f"draft_window must be >= 2 (1 committed token + >= 1 draft),"
                f" got {self.draft_window}"
            )
        self.queue: deque = deque()
        self.active: list = [None] * slots
        self.live = np.zeros(slots, bool)
        self.paged_kv = env_flag("RGL_PAGED_KV") if paged_kv is None \
            else bool(paged_kv)
        self.truncations = 0  # requests retired by KV exhaustion (both modes)
        if block_size is None:
            env_bs = os.environ.get("RGL_KV_BLOCK", "")
            block_size = int(env_bs) if env_bs else None
        if self.paged_kv:
            bs = _auto_block_size(cache_len) if block_size is None \
                else int(block_size)
            if bs < 1 or cache_len % bs != 0:
                raise ValueError(
                    f"block_size={bs} must divide cache_len={cache_len}"
                )
            self.block_size = bs
            self.max_blocks = cache_len // bs
            self.pool_blocks = slots * self.max_blocks if pool_blocks is None \
                else int(pool_blocks)
            if self.pool_blocks < self.max_blocks:
                raise ValueError(
                    f"pool_blocks={self.pool_blocks} cannot hold even one "
                    f"full-length request ({self.max_blocks} blocks)"
                )
            self.cache = tm.init_paged_cache(
                cfg, slots, cache_len, bs, self.pool_blocks
            )
            # host mirrors of the device allocator state: admission and
            # every dispatch replay the same block arithmetic the jitted
            # allocator runs, so exhaustion checks never sync the device
            self._free_host = self.pool_blocks
            self._ntab = np.zeros(slots, np.int64)  # allocated blocks/slot
            self.pool_high_water = 0  # max blocks ever simultaneously held
            self._live_dev = jnp.asarray(self.live)
            self._live_dirty = False
        else:
            self.cache = tm.init_cache(cfg, slots, cache_len)
        self.cur_tok = jnp.zeros((slots,), jnp.int32)
        # per-slot token history arena for the prompt-lookup drafter:
        # prompt + every emitted token, left-aligned.  hist_cap bounds the
        # total (prompt < cache_len, decode stops at cursor == cache_len).
        # The host mirror is written at admission and uploaded once per
        # admission wave; between admissions the device copy evolves inside
        # _spec_step and the mirror tracks it via _hist_append.
        self._hist_cap = cache_len + 1
        self.hist = np.zeros((slots, self._hist_cap), np.int32)
        self.hist_len = np.zeros((slots,), np.int32)
        self._hist_dev = jnp.asarray(self.hist)
        self._hist_len_dev = jnp.asarray(self.hist_len)
        # host-tracked cursor mirror: admission pins it to the prompt length,
        # every decode dispatch advances it by the committed token count, so
        # finish checks and speculative room never sync on the device cursor
        self._cursor = np.zeros((slots,), np.int64)
        # device mirrors of each slot's token budget / emitted count for the
        # in-jit acceptance-room clamp (uploaded only at admission waves)
        self._max_new = np.ones((slots,), np.int32)
        self._out_len = np.zeros((slots,), np.int32)
        self._max_new_dev = jnp.asarray(self._max_new)
        self._out_len_dev = jnp.asarray(self._out_len)
        # decode telemetry (both modes): dispatches vs tokens committed
        self.decode_steps = 0  # jitted decode/verify dispatches
        self.slot_steps = 0  # live-slot decode opportunities (slots x steps)
        self.emitted_tokens = 0  # all tokens committed (incl. prefill firsts)
        self.decode_tokens = 0  # tokens committed by decode dispatches
        self.draft_proposed = 0  # draft tokens fed to verification
        self.draft_accepted = 0  # drafts accepted (excludes the free token)

    @property
    def free_slots(self) -> int:
        """Decode slots that remain free once the admission queue drains —
        the backpressure signal for async retrieval prefetch (collect a
        prefetched wave only when it can actually be admitted)."""
        return max(0, int(self.slots - self.live.sum()) - len(self.queue))

    # -- paged-pool host bookkeeping ------------------------------------------
    def _blocks_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.block_size)  # ceil division

    def _live_mask(self):
        """Device live mask for the paged dispatches, re-uploaded only when
        liveness changed (H2D upload, never a D2H sync)."""
        if self._live_dirty:
            self._live_dev = jnp.asarray(self.live)
            self._live_dirty = False
        return self._live_dev

    def _free_slots_paged(self, slot_ids) -> None:
        """Return the named slots' blocks to the pool: one jitted push onto
        the device free stack, mirrored on host."""
        mask = np.zeros(self.slots, bool)
        mask[list(slot_ids)] = True
        self.cache = tm.free_slot_blocks(self.cache, jnp.asarray(mask))
        self._free_host += int(self._ntab[mask].sum())
        self._ntab[mask] = 0
        self._live_dirty = True

    def _release_retired(self, live_before: np.ndarray) -> None:
        """Free the blocks of every slot that retired during this step's
        finish checks (batched into one dispatch)."""
        retired = np.where(live_before & ~self.live)[0]
        if retired.size:
            self._free_slots_paged(retired.tolist())

    def _paged_step_need(self) -> np.ndarray:
        """Per-slot blocks the next dispatch's in-jit allocator will pop —
        the identical arithmetic replayed on the host mirrors (cursor and
        table-prefix counts advance deterministically, so the two never
        diverge)."""
        w = self.draft_window if self.spec_decode else 1
        need = np.zeros(self.slots, np.int64)
        for i in range(self.slots):
            if not self.live[i]:
                continue
            hi = min(int(self._cursor[i]) + w, self.cache_len)
            need[i] = max(self._blocks_for(hi) - int(self._ntab[i]), 0)
        return need

    def _retire_pool_exhausted(self) -> list:
        """Host-side pre-dispatch exhaustion check: while the pool cannot
        cover every live slot's next-step allocation, retire the
        highest-indexed slot that needs a block (``truncated=True``) and
        reclaim its blocks.  Deterministic, and it guarantees the jitted
        allocator never over-pops — the device needs no exhaustion path."""
        finished = []
        need = self._paged_step_need()
        while need.sum() > self._free_host:
            needy = np.where(need > 0)[0]
            i = int(needy[-1])
            req = self.active[i]
            req.done = True
            req.truncated = True
            self.truncations += 1
            finished.append(req)
            self.active[i] = None
            self.live[i] = False
            self._free_slots_paged([i])
            need[i] = 0
        return finished

    def _apply_paged_alloc(self) -> None:
        """Advance the host allocator mirrors by exactly what the dispatch
        being issued will pop on device."""
        need = self._paged_step_need()
        tot = int(need.sum())
        if tot:
            self._ntab += need
            self._free_host -= tot
        self.pool_high_water = max(
            self.pool_high_water, self.pool_blocks - self._free_host
        )

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt_ids) >= self.cache_len:
            raise ValueError(
                f"prompt of {len(req.prompt_ids)} tokens cannot fit "
                f"cache_len={self.cache_len} (need room for >=1 new token)"
            )
        self.queue.append(req)

    def abort(self, reason: str = "aborted") -> list:
        """Retire every queued and live request (``failed=True``, partial
        ``out_tokens`` kept) and reconcile the arena: slots freed, paged KV
        blocks returned to the pool, queue cleared.  The engine is reusable
        afterwards — a fresh workload admits into a clean arena.  Returns
        the aborted requests."""
        out = []
        live_idx = [i for i in range(self.slots) if self.live[i]]
        for i in live_idx:
            req = self.active[i]
            req.done = True
            req.failed = True
            req.error = reason
            self.active[i] = None
            self.live[i] = False
            out.append(req)
        if self.paged_kv and live_idx:
            self._free_slots_paged(live_idx)
        while self.queue:
            req = self.queue.popleft()
            req.done = True
            req.failed = True
            req.error = reason
            out.append(req)
        return out

    def _admit(self) -> list:
        """Refill free slots with one masked batched prefill.  Returns the
        requests that finish AT admission (first token hits EOS, or
        ``max_new_tokens == 1``) — they never occupy a live slot, so a
        request can never emit more than ``max_new_tokens`` tokens.

        Paged arena: admission additionally gates on free blocks —
        ceil((L+1)/bs) per request, prompt plus the first decode write, so
        an admit is never pool-truncated on its very first step.  FIFO is
        preserved: a head-of-line request that does not fit blocks the
        rest of the queue instead of being skipped (full-size pools never
        gate, keeping admission identical to the contiguous schedule)."""
        free = [i for i in range(self.slots) if not self.live[i]]
        if self.paged_kv:
            take = 0
            budget = self._free_host
            for r in list(self.queue)[:len(free)]:
                need = self._blocks_for(
                    min(len(r.prompt_ids) + 1, self.cache_len)
                )
                if need > budget:
                    break
                budget -= need
                take += 1
        else:
            take = min(len(free), len(self.queue))
        if take == 0:
            return []
        reqs = [self.queue.popleft() for _ in range(take)]
        slot_ids = free[:take]
        # one masked batched prefill: batch padded to `slots` rows, lengths
        # padded to a shared power-of-two bucket
        bucket = _bucket_len(max(len(r.prompt_ids) for r in reqs),
                             self.cache_len)
        toks = np.zeros((self.slots, bucket), np.int32)
        tl = np.zeros((self.slots,), np.int32)
        for j, r in enumerate(reqs):
            L = len(r.prompt_ids)  # submit() guarantees L < cache_len
            toks[j, :L] = np.asarray(r.prompt_ids, np.int32)
            tl[j] = L
        logits, fresh = _prefill_batch(
            self.params, jnp.asarray(toks), jnp.asarray(tl),
            self.cfg, self.cache_len,
        )
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (slots,)
        rows = np.zeros(self.slots, np.int32)
        newly = np.zeros(self.slots, bool)
        tl_slot = np.zeros(self.slots, np.int32)
        for j, i in enumerate(slot_ids):
            rows[i] = j
            newly[i] = True
            tl_slot[i] = tl[j]
        if self.paged_kv:
            self.cache, self.cur_tok = _paged_merge_admitted(
                self.cache, fresh, self.cur_tok, first,
                jnp.asarray(rows), jnp.asarray(newly), jnp.asarray(tl_slot),
                self.block_size,
            )
            for j, i in enumerate(slot_ids):
                nb = self._blocks_for(tl[j])
                self._ntab[i] = nb
                self._free_host -= nb
            self.pool_high_water = max(
                self.pool_high_water, self.pool_blocks - self._free_host
            )
            self._live_dirty = True
        else:
            self.cache, self.cur_tok = _merge_admitted(
                self.cache, fresh, self.cur_tok, first,
                jnp.asarray(rows), jnp.asarray(newly),
            )
        first_np = np.asarray(first)
        finished = []
        dead_at_admission = []
        for j, i in enumerate(slot_ids):
            req = reqs[j]
            tok0 = int(first_np[j])
            req.out_tokens.append(tok0)
            self.emitted_tokens += 1
            self._cursor[i] = tl[j]  # merge pinned this slot's device cursor
            hit_eos = self.eos_id is not None and tok0 == self.eos_id
            if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
                # done at admission: the arena row was written but the slot
                # never goes live, so the next wave simply reuses it
                req.done = True
                finished.append(req)
                dead_at_admission.append(i)
                continue
            self.active[i] = req
            self.live[i] = True
            L = len(req.prompt_ids)
            self.hist[i, :L] = np.asarray(req.prompt_ids, np.int32)
            self.hist[i, L] = tok0
            self.hist_len[i] = L + 1
            self._max_new[i] = req.max_new_tokens
            self._out_len[i] = 1
        if self.paged_kv and dead_at_admission:
            # admission allocated these slots' prompt blocks, but the slot
            # never went live — give the blocks straight back
            self._free_slots_paged(dead_at_admission)
        if self.spec_decode:
            self._hist_dev = jnp.asarray(self.hist)
            self._hist_len_dev = jnp.asarray(self.hist_len)
            self._max_new_dev = jnp.asarray(self._max_new)
            self._out_len_dev = jnp.asarray(self._out_len)
        return finished

    def _hist_append(self, i: int, toks: list) -> None:
        hl = int(self.hist_len[i])
        n = min(len(toks), self._hist_cap - hl)
        if n > 0:
            self.hist[i, hl:hl + n] = toks[:n]
            self.hist_len[i] = hl + n

    def _finish_check(self, i: int, req: Request, last_tok: int,
                      cursor_i: int, finished: list) -> None:
        hit_eos = self.eos_id is not None and last_tok == self.eos_id
        budget_full = len(req.out_tokens) >= req.max_new_tokens
        arena_full = cursor_i >= self.cache_len
        if hit_eos or budget_full or arena_full:
            req.done = True
            if arena_full and not (hit_eos or budget_full):
                # retired by KV exhaustion, not by its own budget or an
                # EOS: flag it so callers can tell a complete answer from
                # a clipped one instead of silently receiving fewer tokens
                req.truncated = True
                self.truncations += 1
            finished.append(req)
            self.active[i] = None
            self.live[i] = False

    # -- one decode step for every live slot ----------------------------------
    def step(self) -> list:
        finished = self._admit()
        if self.paged_kv and self.live.any():
            finished.extend(self._retire_pool_exhausted())
        if not self.live.any():
            return finished
        if self.spec_decode:
            finished.extend(self._step_spec())
        else:
            finished.extend(self._step_one())
        return finished

    def _step_one(self) -> list:
        """One-token decode: one jitted dispatch emits one token per slot."""
        if self.paged_kv:
            self._apply_paged_alloc()
            nxt, self.cache = tm.paged_serve_step(
                self.params, self.cache, self.cur_tok, self._live_mask(),
                self.cfg, self.block_size,
            )
        else:
            nxt, self.cache = tm.serve_step(
                self.params, self.cache, self.cur_tok, self.cfg
            )
        self.cur_tok = nxt
        self.decode_steps += 1
        self._cursor += 1  # decode_step advances every slot's cursor
        finished = []
        live_before = self.live.copy()
        toks = np.asarray(nxt)
        for i, req in enumerate(self.active):
            if req is None or not self.live[i]:
                continue
            t = int(toks[i])
            req.out_tokens.append(t)
            self.emitted_tokens += 1
            self.decode_tokens += 1
            self.slot_steps += 1
            self._hist_append(i, [t])
            self._finish_check(i, req, t, int(self._cursor[i]), finished)
        if self.paged_kv:
            self._release_retired(live_before)
        return finished

    def _step_spec(self) -> list:
        """Self-speculative decode: draft ``W-1`` tokens per slot from its
        own history, verify all of them, and commit the greedy-matching
        prefix (1..W tokens per slot) — all in ONE jitted dispatch."""
        w = self.draft_window
        # acceptance room is computed in-jit from the device mirrors; both
        # terms are >= 1 for a live slot (admission retires len >= max_new
        # immediately, decode retires cursor >= cache_len).  Dead slots run
        # with whatever stale room their mirrors imply (clamped >= 1, so up
        # to W of drift per step) — harmless: writes stay masked at the
        # arena edge and admission re-pins cursor/mirrors before reuse
        if self.paged_kv:
            self._apply_paged_alloc()
            (packed, self.cur_tok, self.cache, self._hist_dev,
             self._hist_len_dev, self._out_len_dev) = _paged_spec_step(
                self.params, self.cache, self.cur_tok, self._hist_dev,
                self._hist_len_dev, self._max_new_dev, self._out_len_dev,
                self._live_mask(), self.cfg, w - 1, self.eos_id,
                self.block_size,
            )
        else:
            (packed, self.cur_tok, self.cache, self._hist_dev,
             self._hist_len_dev, self._out_len_dev) = _spec_step(
                self.params, self.cache, self.cur_tok, self._hist_dev,
                self._hist_len_dev, self._max_new_dev, self._out_len_dev,
                self.cfg, w - 1, self.eos_id,
            )
        self.decode_steps += 1
        finished = []
        live_before = self.live.copy()
        packed_np = np.asarray(packed)  # the step's single host sync
        g_np, acc_np = packed_np[:, :w], packed_np[:, w]
        self._cursor += acc_np  # verify_step advanced every slot by accepted
        self._out_len += acc_np  # keep the host mirror bitwise in step
        for i, req in enumerate(self.active):
            if req is None or not self.live[i]:
                continue
            a = int(acc_np[i])
            emitted = g_np[i, :a].tolist()
            req.out_tokens.extend(emitted)
            self.emitted_tokens += a
            self.decode_tokens += a
            self.slot_steps += 1
            self.draft_proposed += w - 1
            self.draft_accepted += a - 1
            self._hist_append(i, emitted)
            self._finish_check(i, req, emitted[-1], int(self._cursor[i]),
                               finished)
        if self.paged_kv:
            self._release_retired(live_before)
        return finished

    def decode_stats(self) -> dict:
        """Dispatch-amortization telemetry.  ``tokens_per_step`` is the mean
        number of tokens a live slot commits per jitted decode dispatch —
        exactly 1.0 in one-token mode, up to ``draft_window`` under
        speculation — i.e. the accepted-tokens/step signal, normalized per
        slot so batch occupancy does not inflate it."""
        stats = {
            "spec_decode": self.spec_decode,
            "draft_window": self.draft_window if self.spec_decode else 1,
            "decode_steps": self.decode_steps,
            "emitted_tokens": self.emitted_tokens,
            "decode_tokens": self.decode_tokens,
            "draft_proposed": self.draft_proposed,
            "draft_accepted": self.draft_accepted,
            "tokens_per_step": self.decode_tokens / max(self.slot_steps, 1),
            "draft_accept_rate": (
                self.draft_accepted / self.draft_proposed
                if self.draft_proposed else 0.0
            ),
            "paged_kv": self.paged_kv,
            "truncations": self.truncations,
        }
        if self.paged_kv:
            stats.update(
                block_size=self.block_size,
                pool_blocks=self.pool_blocks,
                pool_high_water_blocks=self.pool_high_water,
                pool_free_blocks=self._free_host,
            )
        return stats

    def run_to_completion(self, max_steps: int = 10_000) -> list:
        """Step until every request drains.  Raises if ``max_steps`` elapse
        with work still queued or live, instead of silently returning a
        partial result set."""
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and not self.live.any():
                return done
        raise RuntimeError(
            f"run_to_completion: work still pending after {max_steps} steps "
            f"({len(self.queue)} queued, {int(self.live.sum())} live slots)"
        )
