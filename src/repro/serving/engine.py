"""Batched serving engine: continuous batching over fixed decode slots.

vLLM-style scheduling adapted to TPU constraints (static shapes): a fixed
(B, cache_len) KV arena; each of the B slots holds one in-flight request.
Every engine step runs ONE jitted dispatch for all slots.  Admission is
batched too: all free slots are refilled together by a single masked batched
prefill — prompts are padded to a shared length bucket, run through one
``tm.prefill`` call, and the resulting cache rows are merged into the arena
with one jitted masked update (never reshaping, never per-slot dispatch).

Length bucketing keeps recompilation bounded: the prefill trace is specialized
on (slots, bucket) only, so at most O(log cache_len) prefill programs exist
over the lifetime of the engine.

Two decode modes share the arena:

* **one-token** (default) — each step is one ``tm.serve_step``: one jitted
  dispatch per output token, so tok/s is bounded by per-step dispatch
  overhead.
* **self-speculative** (``spec_decode=True`` or ``RGL_SPEC_DECODE=1``) —
  each step drafts a window of ``draft_window`` tokens per slot from the
  request's own prompt+output history (:mod:`repro.serving.drafter`, no
  second model) and verifies all of them in ONE jitted ``tm.verify_step``
  dispatch.  Greedy argmax verification accepts the longest draft prefix
  that matches what one-token decode would have emitted, so outputs are
  bitwise identical to the one-token schedule while each dispatch can
  commit up to ``draft_window`` tokens (see ``tests/test_spec_decode.py``).

This engine serves already-tokenized prompts.  For the fused
retrieval-to-generation front-end (the RGL "unified system" claim), see
:class:`repro.serving.rag_engine.RAGServeEngine`, which batches graph
retrieval across admissions and feeds this engine.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import model as tm
from repro.models.transformer.config import TransformerConfig
from repro.serving.drafter import draft_tokens


def env_flag(name: str) -> bool:
    """Truthy env toggle: only explicit affirmative values enable — anything
    else (including "no"/"disabled"/unset) stays off."""
    return os.environ.get(name, "").lower() in ("1", "true", "on", "yes")


def _draft_window_default() -> int:
    try:
        return max(2, int(os.environ.get("RGL_DRAFT_WINDOW", "4")))
    except ValueError:
        return 4


@dataclasses.dataclass
class Request:
    uid: int
    prompt_ids: np.ndarray  # (L,) int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # monotonic admission ticket assigned by the submitting front-end; a
    # stable identity that, unlike id(self), is never reused after GC
    ticket: int = -1


def _bucket_len(n: int, cache_len: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor), capped at cache_len."""
    b = floor
    while b < n:
        b <<= 1
    return min(b, cache_len)


@functools.partial(jax.jit, static_argnames=("cfg", "cache_len"))
def _prefill_batch(params, toks, tl, cfg: TransformerConfig, cache_len: int):
    """Module-level jit so traces are shared across engine instances —
    constructing a fresh engine must not recompile the serving programs."""
    return tm.prefill(params, toks, tl, cfg, cache_len)


@functools.partial(jax.jit, static_argnames=("cfg", "n_draft", "eos_id"))
def _spec_step(params, cache, cur_tok, hist, hist_len, max_new, out_len,
               cfg: TransformerConfig, n_draft: int, eos_id):
    """ONE fused dispatch per speculative engine step: prompt-lookup draft,
    per-slot acceptance room, windowed verify, acceptance + cursor rewind,
    and the history append of the accepted tokens.  Keeping the drafter,
    the room computation, and the history update inside the same jit
    matters on dispatch-bound hosts: at small model sizes each extra jitted
    call or host->device transfer costs about as much as the verify compute
    itself, so the host only downloads (greedy, accepted) per step and only
    uploads state at admission waves.

    max_new / out_len (B,) int32 are device mirrors of each slot's token
    budget and emitted count (pinned at admission, advanced here), so
    ``room = min(max_new - out_len, cache_len - cursor)`` — the clamp that
    keeps a window from overshooting ``max_new_tokens`` or the arena —
    never syncs the host.
    """
    drafts = draft_tokens(hist, hist_len, n_draft)
    fed = jnp.concatenate([cur_tok[:, None], drafts], axis=1)
    sc = cache.k.shape[2]
    room = jnp.minimum(max_new - out_len, sc - cache.cursor).astype(jnp.int32)
    greedy, accepted, nxt, cache = tm.verify_step(
        params, cache, fed, room, cfg, eos_id=eos_id
    )
    # append the accepted tokens to each slot's history (device-resident:
    # the host never re-uploads the arena between admissions)
    h = hist.shape[1]
    cols = jnp.arange(h, dtype=jnp.int32)[None, :]
    for i in range(n_draft + 1):
        write = (i < accepted)[:, None] & (cols == (hist_len + i)[:, None])
        hist = jnp.where(write, greedy[:, i:i + 1], hist)
    hist_len = jnp.minimum(hist_len + accepted, h)
    # pack (greedy, accepted) into ONE host-bound buffer: the engine's per-
    # step sync is a single device->host transfer, like one-token decode's
    packed = jnp.concatenate([greedy, accepted[:, None]], axis=1)
    return packed, nxt, cache, hist, hist_len, out_len + accepted


@jax.jit
def _merge_admitted(arena: tm.KVCache, new: tm.KVCache, cur_tok, first,
                    rows, newly):
    """Masked merge of freshly prefilled rows into the slot arena.

    ``rows[i]`` names the prefill-batch row feeding slot i; ``newly[i]`` masks
    which slots actually admit.  Elementwise select => shards cleanly.
    """

    def mix_b1(a, b):  # (L, B, ...) — batch on axis 1 (k/v/scales)
        if a is None:
            return None
        m = newly.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, b[:, rows], a)

    def mix_b0(a, b):  # (B, ...) — batch on axis 0 (pos/cursor)
        if a is None:
            return None
        m = newly.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, b[rows], a)

    cache = tm.KVCache(
        k=mix_b1(arena.k, new.k),
        v=mix_b1(arena.v, new.v),
        pos=mix_b0(arena.pos, new.pos),
        cursor=mix_b0(arena.cursor, new.cursor),
        k_scale=mix_b1(arena.k_scale, new.k_scale),
        v_scale=mix_b1(arena.v_scale, new.v_scale),
    )
    return cache, jnp.where(newly, first[rows], cur_tok)


class ServeEngine:
    """Continuous-batching decode server over a fixed KV arena.

    Usage::

        eng = ServeEngine(params, cfg, slots=8, cache_len=512)
        eng.submit(Request(uid=0, prompt_ids=ids, max_new_tokens=32))
        finished = eng.run_to_completion()

    ``spec_decode=None`` reads the ``RGL_SPEC_DECODE`` env var (default
    off); ``draft_window`` defaults to ``RGL_DRAFT_WINDOW`` (4).
    """

    def __init__(
        self, params, cfg: TransformerConfig, *, slots: int = 8,
        cache_len: int = 512, eos_id: Optional[int] = None,
        spec_decode: Optional[bool] = None, draft_window: Optional[int] = None,
    ):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.spec_decode = env_flag("RGL_SPEC_DECODE") if spec_decode is None \
            else bool(spec_decode)
        self.draft_window = _draft_window_default() if draft_window is None \
            else int(draft_window)
        if self.spec_decode and self.draft_window < 2:
            raise ValueError(
                f"draft_window must be >= 2 (1 committed token + >= 1 draft),"
                f" got {self.draft_window}"
            )
        self.queue: deque = deque()
        self.active: list = [None] * slots
        self.cache = tm.init_cache(cfg, slots, cache_len)
        self.cur_tok = jnp.zeros((slots,), jnp.int32)
        self.live = np.zeros(slots, bool)
        # per-slot token history arena for the prompt-lookup drafter:
        # prompt + every emitted token, left-aligned.  hist_cap bounds the
        # total (prompt < cache_len, decode stops at cursor == cache_len).
        # The host mirror is written at admission and uploaded once per
        # admission wave; between admissions the device copy evolves inside
        # _spec_step and the mirror tracks it via _hist_append.
        self._hist_cap = cache_len + 1
        self.hist = np.zeros((slots, self._hist_cap), np.int32)
        self.hist_len = np.zeros((slots,), np.int32)
        self._hist_dev = jnp.asarray(self.hist)
        self._hist_len_dev = jnp.asarray(self.hist_len)
        # host-tracked cursor mirror: admission pins it to the prompt length,
        # every decode dispatch advances it by the committed token count, so
        # finish checks and speculative room never sync on the device cursor
        self._cursor = np.zeros((slots,), np.int64)
        # device mirrors of each slot's token budget / emitted count for the
        # in-jit acceptance-room clamp (uploaded only at admission waves)
        self._max_new = np.ones((slots,), np.int32)
        self._out_len = np.zeros((slots,), np.int32)
        self._max_new_dev = jnp.asarray(self._max_new)
        self._out_len_dev = jnp.asarray(self._out_len)
        # decode telemetry (both modes): dispatches vs tokens committed
        self.decode_steps = 0  # jitted decode/verify dispatches
        self.slot_steps = 0  # live-slot decode opportunities (slots x steps)
        self.emitted_tokens = 0  # all tokens committed (incl. prefill firsts)
        self.decode_tokens = 0  # tokens committed by decode dispatches
        self.draft_proposed = 0  # draft tokens fed to verification
        self.draft_accepted = 0  # drafts accepted (excludes the free token)

    @property
    def free_slots(self) -> int:
        """Decode slots that remain free once the admission queue drains —
        the backpressure signal for async retrieval prefetch (collect a
        prefetched wave only when it can actually be admitted)."""
        return max(0, int(self.slots - self.live.sum()) - len(self.queue))

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt_ids) >= self.cache_len:
            raise ValueError(
                f"prompt of {len(req.prompt_ids)} tokens cannot fit "
                f"cache_len={self.cache_len} (need room for >=1 new token)"
            )
        self.queue.append(req)

    def _admit(self) -> list:
        """Refill free slots with one masked batched prefill.  Returns the
        requests that finish AT admission (first token hits EOS, or
        ``max_new_tokens == 1``) — they never occupy a live slot, so a
        request can never emit more than ``max_new_tokens`` tokens."""
        free = [i for i in range(self.slots) if not self.live[i]]
        take = min(len(free), len(self.queue))
        if take == 0:
            return []
        reqs = [self.queue.popleft() for _ in range(take)]
        slot_ids = free[:take]
        # one masked batched prefill: batch padded to `slots` rows, lengths
        # padded to a shared power-of-two bucket
        bucket = _bucket_len(max(len(r.prompt_ids) for r in reqs),
                             self.cache_len)
        toks = np.zeros((self.slots, bucket), np.int32)
        tl = np.zeros((self.slots,), np.int32)
        for j, r in enumerate(reqs):
            L = len(r.prompt_ids)  # submit() guarantees L < cache_len
            toks[j, :L] = np.asarray(r.prompt_ids, np.int32)
            tl[j] = L
        logits, fresh = _prefill_batch(
            self.params, jnp.asarray(toks), jnp.asarray(tl),
            self.cfg, self.cache_len,
        )
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (slots,)
        rows = np.zeros(self.slots, np.int32)
        newly = np.zeros(self.slots, bool)
        for j, i in enumerate(slot_ids):
            rows[i] = j
            newly[i] = True
        self.cache, self.cur_tok = _merge_admitted(
            self.cache, fresh, self.cur_tok, first,
            jnp.asarray(rows), jnp.asarray(newly),
        )
        first_np = np.asarray(first)
        finished = []
        for j, i in enumerate(slot_ids):
            req = reqs[j]
            tok0 = int(first_np[j])
            req.out_tokens.append(tok0)
            self.emitted_tokens += 1
            self._cursor[i] = tl[j]  # merge pinned this slot's device cursor
            hit_eos = self.eos_id is not None and tok0 == self.eos_id
            if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
                # done at admission: the arena row was written but the slot
                # never goes live, so the next wave simply reuses it
                req.done = True
                finished.append(req)
                continue
            self.active[i] = req
            self.live[i] = True
            L = len(req.prompt_ids)
            self.hist[i, :L] = np.asarray(req.prompt_ids, np.int32)
            self.hist[i, L] = tok0
            self.hist_len[i] = L + 1
            self._max_new[i] = req.max_new_tokens
            self._out_len[i] = 1
        if self.spec_decode:
            self._hist_dev = jnp.asarray(self.hist)
            self._hist_len_dev = jnp.asarray(self.hist_len)
            self._max_new_dev = jnp.asarray(self._max_new)
            self._out_len_dev = jnp.asarray(self._out_len)
        return finished

    def _hist_append(self, i: int, toks: list) -> None:
        hl = int(self.hist_len[i])
        n = min(len(toks), self._hist_cap - hl)
        if n > 0:
            self.hist[i, hl:hl + n] = toks[:n]
            self.hist_len[i] = hl + n

    def _finish_check(self, i: int, req: Request, last_tok: int,
                      cursor_i: int, finished: list) -> None:
        hit_eos = self.eos_id is not None and last_tok == self.eos_id
        full = (
            len(req.out_tokens) >= req.max_new_tokens
            or cursor_i >= self.cache_len
        )
        if hit_eos or full:
            req.done = True
            finished.append(req)
            self.active[i] = None
            self.live[i] = False

    # -- one decode step for every live slot ----------------------------------
    def step(self) -> list:
        finished = self._admit()
        if not self.live.any():
            return finished
        if self.spec_decode:
            finished.extend(self._step_spec())
        else:
            finished.extend(self._step_one())
        return finished

    def _step_one(self) -> list:
        """One-token decode: one jitted dispatch emits one token per slot."""
        nxt, self.cache = tm.serve_step(
            self.params, self.cache, self.cur_tok, self.cfg
        )
        self.cur_tok = nxt
        self.decode_steps += 1
        self._cursor += 1  # decode_step advances every slot's cursor
        finished = []
        toks = np.asarray(nxt)
        for i, req in enumerate(self.active):
            if req is None or not self.live[i]:
                continue
            t = int(toks[i])
            req.out_tokens.append(t)
            self.emitted_tokens += 1
            self.decode_tokens += 1
            self.slot_steps += 1
            self._hist_append(i, [t])
            self._finish_check(i, req, t, int(self._cursor[i]), finished)
        return finished

    def _step_spec(self) -> list:
        """Self-speculative decode: draft ``W-1`` tokens per slot from its
        own history, verify all of them, and commit the greedy-matching
        prefix (1..W tokens per slot) — all in ONE jitted dispatch."""
        w = self.draft_window
        # acceptance room is computed in-jit from the device mirrors; both
        # terms are >= 1 for a live slot (admission retires len >= max_new
        # immediately, decode retires cursor >= cache_len).  Dead slots run
        # with whatever stale room their mirrors imply (clamped >= 1, so up
        # to W of drift per step) — harmless: writes stay masked at the
        # arena edge and admission re-pins cursor/mirrors before reuse
        (packed, self.cur_tok, self.cache, self._hist_dev,
         self._hist_len_dev, self._out_len_dev) = _spec_step(
            self.params, self.cache, self.cur_tok, self._hist_dev,
            self._hist_len_dev, self._max_new_dev, self._out_len_dev,
            self.cfg, w - 1, self.eos_id,
        )
        self.decode_steps += 1
        finished = []
        packed_np = np.asarray(packed)  # the step's single host sync
        g_np, acc_np = packed_np[:, :w], packed_np[:, w]
        self._cursor += acc_np  # verify_step advanced every slot by accepted
        self._out_len += acc_np  # keep the host mirror bitwise in step
        for i, req in enumerate(self.active):
            if req is None or not self.live[i]:
                continue
            a = int(acc_np[i])
            emitted = g_np[i, :a].tolist()
            req.out_tokens.extend(emitted)
            self.emitted_tokens += a
            self.decode_tokens += a
            self.slot_steps += 1
            self.draft_proposed += w - 1
            self.draft_accepted += a - 1
            self._hist_append(i, emitted)
            self._finish_check(i, req, emitted[-1], int(self._cursor[i]),
                               finished)
        return finished

    def decode_stats(self) -> dict:
        """Dispatch-amortization telemetry.  ``tokens_per_step`` is the mean
        number of tokens a live slot commits per jitted decode dispatch —
        exactly 1.0 in one-token mode, up to ``draft_window`` under
        speculation — i.e. the accepted-tokens/step signal, normalized per
        slot so batch occupancy does not inflate it."""
        return {
            "spec_decode": self.spec_decode,
            "draft_window": self.draft_window if self.spec_decode else 1,
            "decode_steps": self.decode_steps,
            "emitted_tokens": self.emitted_tokens,
            "decode_tokens": self.decode_tokens,
            "draft_proposed": self.draft_proposed,
            "draft_accepted": self.draft_accepted,
            "tokens_per_step": self.decode_tokens / max(self.slot_steps, 1),
            "draft_accept_rate": (
                self.draft_accepted / self.draft_proposed
                if self.draft_proposed else 0.0
            ),
        }

    def run_to_completion(self, max_steps: int = 10_000) -> list:
        """Step until every request drains.  Raises if ``max_steps`` elapse
        with work still queued or live, instead of silently returning a
        partial result set."""
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and not self.live.any():
                return done
        raise RuntimeError(
            f"run_to_completion: work still pending after {max_steps} steps "
            f"({len(self.queue)} queued, {int(self.live.sum())} live slots)"
        )
