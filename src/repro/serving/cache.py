"""Retrieval cache for the fused RAG serving engine.

An LRU map from *quantized query embedding* to the finished retrieval result
(filtered subgraph membership + seed ids).  Quantization (``round(emb / eps)``)
makes near-duplicate queries — repeated questions, embedding jitter below
``eps`` — collapse onto one key, so a hit skips the entire index + BFS +
filter stack.  Entries are host-side numpy (small: O(budget) ints per query),
so the cache never holds device memory.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np


@dataclasses.dataclass
class CachedRetrieval:
    """One query's retrieval output, materialized on host."""

    nodes: np.ndarray  # (M,) int32 subgraph node ids (sentinel where ~mask)
    mask: np.ndarray  # (M,) bool
    dist: np.ndarray  # (M,) int32 hop distances
    seeds: np.ndarray  # (S,) int32 seed node ids


class RetrievalCache:
    """LRU cache keyed on quantized query embeddings, with hit/miss counters.

    ``get`` counts a hit or miss and refreshes recency; ``put`` inserts and
    evicts the least-recently-used entry beyond ``capacity``.  ``capacity <= 0``
    disables caching (every lookup is a miss, nothing is stored).
    """

    def __init__(self, capacity: int = 256, quant_eps: float = 1e-3):
        self.capacity = capacity
        self.quant_eps = quant_eps
        self._data: OrderedDict[bytes, CachedRetrieval] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def key(self, query_emb) -> bytes:
        q = np.asarray(query_emb, np.float32).ravel()
        return np.round(q / self.quant_eps).astype(np.int32).tobytes()

    def get(self, query_emb) -> CachedRetrieval | None:
        k = self.key(query_emb)
        entry = self._data.get(k)
        if entry is None:
            self.misses += 1
            return None
        self._data.move_to_end(k)
        self.hits += 1
        return entry

    def put(self, query_emb, entry: CachedRetrieval) -> None:
        if self.capacity <= 0:
            return
        k = self.key(query_emb)
        self._data[k] = entry
        self._data.move_to_end(k)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "size": len(self._data),
            "hit_rate": self.hits / total if total else 0.0,
        }
