"""Retrieval cache for the fused RAG serving engine.

A policy-driven map from *quantized query embedding* to the finished
retrieval result (filtered subgraph membership + seed ids).  Quantization
(``round(emb / eps)``) makes near-duplicate queries — repeated questions,
embedding jitter below ``eps`` — collapse onto one key, so a hit skips the
entire index + BFS + filter stack.  Entries are host-side numpy (small:
O(budget) ints per query), so the cache never holds device memory.

Eviction policies (capacity pressure):

* ``lru`` — evict the least-recently-used entry (hits refresh recency).
* ``lfu`` — evict the entry with the fewest per-entry hits; ties broken by
  least-recent, so a cold newcomer never outlives a warm regular.
* ``ttl`` — evict the oldest-inserted entry (insertion-order FIFO); pairs
  naturally with an expiry window.

Independently of the policy, an optional ``ttl`` (seconds) expires entries
``ttl`` after insertion: an expired entry is *invisible* to ``get`` (each
such lookup counts a miss + ``expired``) but stays resident until capacity
pressure reclaims it — ``put`` purges expired entries before falling back
to policy eviction.  Keeping the stale bytes around is deliberate: the
serving engine's graceful-degradation ladder (:mod:`repro.serving.rag_engine`)
falls back to a stale entry via :meth:`RetrievalCache.peek_stale` when live
retrieval fails and retries are exhausted — a TTL-expired answer beats no
answer.

For async admission prefetch the cache also tracks an **in-flight miss
registry**: keys whose retrieval has been dispatched but whose results have
not been collected yet.  A later admission launch consults it so a
retrieved-but-not-yet-collected query is never re-dispatched — the request
defers to the in-flight wave instead (see
:class:`repro.serving.prefetch.AdmissionPrefetcher`).  Each in-flight key
may carry the *owner wave's* ``entries_by_key`` dict (filled in place at
that wave's collect), which is what makes the protocol work **across
replicas sharing one cache**: a prefetcher that finds a key in flight but
owned by none of its own waves can still defer — single-flight semantics
for the whole replica fleet, one dispatch per unique query no matter which
replica's request arrives first (see
:class:`repro.serving.router.ReplicaRouter`).

When the corpus mutates under serving (:mod:`repro.core.mutation`), the
cache is **versioned**: every entry records the mutation ``epoch`` it was
retrieved against plus its ``region`` (the set of node-id buckets its
subgraph + seeds touch), and :meth:`RetrievalCache.invalidate_regions`
drops only the entries whose region a mutation touched — releasing any
prefix-sharing KV pins they hold — while entries over unrelated regions
survive the epoch bump.  ``put`` refuses results collected against a
superseded region (an in-flight wave that raced a mutation), so staleness
for touched regions is bounded by a single epoch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict

import numpy as np

POLICIES = ("lru", "lfu", "ttl")


@dataclasses.dataclass
class CachedRetrieval:
    """One query's retrieval output, materialized on host.

    When prefix sharing is on (``RGL_PREFIX_SHARE``), a hot entry may
    additionally *pin* the paged-KV pool blocks holding the prefilled
    prompt that this retrieval produced: ``kv_blocks`` names the pool
    block ids (the pin holds one refcount per block), ``kv_prompt`` the
    exact token ids those blocks cover (admission re-validates against
    it), ``kv_first_tok`` the prefill's recorded argmax, and
    ``kv_release`` the owning engine's release hook — called by the cache
    on eviction/overwrite and by ``reclaim_kv`` under pool pressure, so
    cache lifetime, not request lifetime, bounds how long prefilled KV
    stays resident."""

    nodes: np.ndarray  # (M,) int32 subgraph node ids (sentinel where ~mask)
    mask: np.ndarray  # (M,) bool
    dist: np.ndarray  # (M,) int32 hop distances
    seeds: np.ndarray  # (S,) int32 seed node ids
    # graph-mutation versioning (see RetrievalCache.invalidate_regions):
    # the mutation epoch this retrieval ran against, and the set of
    # node-id buckets its subgraph + seeds touch (computed by put()).
    epoch: int = 0
    region: frozenset | None = None
    # prefilled-KV pin (engine-owned; None/defaults when unpinned)
    kv_blocks: np.ndarray | None = None  # (nblk,) int32 pool block ids
    kv_len: int = 0  # prompt tokens the pinned blocks cover
    kv_first_tok: int = -1  # prefill argmax recorded at pin time
    kv_prompt: np.ndarray | None = None  # (L,) int32 exact pinned prompt
    kv_owner: object = None  # engine whose pool the block ids index
    kv_release: object = None  # hook: entry -> blocks returned to the pool
    cache_key: bytes | None = None  # set by put(); drives is_resident()


@dataclasses.dataclass
class _Slot:
    """Cache bookkeeping around one entry."""

    entry: CachedRetrieval
    hits: int = 0  # per-entry hit count (drives lfu)
    inserted_at: float = 0.0  # ttl expiry + FIFO eviction order
    # each entry's TTL expiry is counted in stats()["expired"] exactly
    # once (the first lookup or purge that observes it) — the counter
    # tracks distinct expiries, not lookups of an expired resident
    expired_counted: bool = False


class RetrievalCache:
    """Policy-driven cache keyed on quantized query embeddings.

    ``get`` counts a hit or miss (expired entries are dropped and count as
    misses) and refreshes recency; ``put`` inserts and evicts per the
    policy beyond ``capacity``.  ``capacity <= 0`` disables caching (every
    lookup is a miss, nothing is stored).  ``now_fn`` is injectable so TTL
    behavior is testable without sleeping.
    """

    def __init__(
        self,
        capacity: int = 256,
        quant_eps: float = 1e-3,
        *,
        policy: str = "lru",
        ttl: float | None = None,
        region_bucket: int = 32,
        mutation_flush: str = "region",
        now_fn=time.monotonic,
    ):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        if mutation_flush not in ("region", "all"):
            raise ValueError(
                f"mutation_flush must be 'region' or 'all', got "
                f"{mutation_flush!r}"
            )
        self.capacity = capacity
        self.quant_eps = quant_eps
        self.policy = policy
        self.ttl = ttl
        self.region_bucket = max(1, int(region_bucket))
        self.mutation_flush = mutation_flush
        self._now = now_fn
        self._data: OrderedDict[bytes, _Slot] = OrderedDict()  # recency order
        # dispatched-but-uncollected keys -> owner wave's entries_by_key dict
        # (None for owners that did not register one)
        self._inflight: dict[bytes, dict | None] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0  # capacity evictions by the active policy
        self.expired = 0  # ttl expiries
        self.stale_hits = 0  # peek_stale found a resident (possibly
        #                      TTL-expired) entry to degrade onto
        self.stale_misses = 0  # peek_stale found nothing resident
        # graph-mutation versioning: the newest epoch a mutation has
        # reached, and a bounded log of (epoch, touched buckets) so put()
        # can reject results computed against a superseded region.
        self.graph_epoch = 0
        self._touched_log: list[tuple[int, frozenset]] = []
        self._touched_log_max = 256
        self.invalidated = 0  # entries dropped by invalidate_regions
        self.stale_rejects = 0  # put() refused a superseded-region entry

    def __len__(self) -> int:
        return len(self._data)

    def key(self, query_emb) -> bytes:
        q = np.asarray(query_emb, np.float32).ravel()
        return np.round(q / self.quant_eps).astype(np.int32).tobytes()

    # -- in-flight miss registry ----------------------------------------------
    def mark_inflight(self, key: bytes, entries: dict | None = None) -> None:
        """Record that ``key``'s retrieval has been dispatched but not yet
        collected, so later admission launches defer instead of re-dispatch.

        ``entries`` (optional) is the owning wave's ``entries_by_key`` dict,
        filled in place at that wave's collect — registering it lets a
        *different* prefetcher sharing this cache defer to the owner too
        (cross-replica single flight)."""
        self._inflight[key] = entries

    def is_inflight(self, key: bytes) -> bool:
        return key in self._inflight

    def inflight_entries(self, key: bytes) -> dict | None:
        """The registered owner's ``entries_by_key`` dict for an in-flight
        ``key`` (None if the key is not in flight, or its owner registered
        no dict).  Cross-replica deferral resolves through this."""
        return self._inflight.get(key)

    def release_inflight(self, key: bytes) -> None:
        self._inflight.pop(key, None)

    @property
    def inflight_count(self) -> int:
        return len(self._inflight)

    # -- expiry ---------------------------------------------------------------
    def _is_expired(self, slot: _Slot, now: float) -> bool:
        return self.ttl is not None and now - slot.inserted_at > self.ttl

    def _count_expiry(self, slot: _Slot) -> None:
        if not slot.expired_counted:
            slot.expired_counted = True
            self.expired += 1

    def _purge_expired(self, now: float) -> None:
        dead = [k for k, s in self._data.items() if self._is_expired(s, now)]
        for k in dead:
            slot = self._data.pop(k)
            self._count_expiry(slot)
            self._release_kv(slot.entry)

    # -- lookup / insert ------------------------------------------------------
    def get(self, query_emb) -> CachedRetrieval | None:
        k = self.key(query_emb)
        slot = self._data.get(k)
        now = self._now()
        if slot is not None and self._is_expired(slot, now):
            # expired entries are invisible here but stay resident (until a
            # capacity-pressure purge) so peek_stale can serve them when
            # live retrieval fails — see the degradation ladder.  The expiry
            # is counted once per entry, however many lookups observe it.
            self._count_expiry(slot)
            self.misses += 1
            return None
        if slot is None:
            self.misses += 1
            return None
        self._data.move_to_end(k)
        slot.hits += 1
        self.hits += 1
        return slot.entry

    def peek_stale(self, query_emb) -> CachedRetrieval | None:
        """Degraded-mode lookup: return the resident entry for this key even
        if TTL-expired, without touching hit/miss counters or recency.  The
        serving engine falls back to this when live retrieval has failed and
        retries are exhausted (counted there as ``stale_served``).  Counted
        here as ``stale_hits`` / ``stale_misses`` so degraded serving is
        observable at the cache tier too — with several engines sharing one
        cache, the cache-level totals are the fleet-wide view."""
        slot = self._data.get(self.key(query_emb))
        if slot is None:
            self.stale_misses += 1
            return None
        self.stale_hits += 1
        return slot.entry

    def hit_count(self, query_emb) -> int:
        """Per-entry hit count (0 if absent) — the lfu eviction signal."""
        slot = self._data.get(self.key(query_emb))
        return slot.hits if slot is not None else 0

    @staticmethod
    def _release_kv(entry: CachedRetrieval) -> int:
        """Release an entry's prefilled-KV pin (if any) as it leaves the
        cache — eviction, TTL purge, or overwrite — so cache pressure frees
        pool blocks.  The hook is the owning engine's and idempotent."""
        rel = getattr(entry, "kv_release", None)
        return int(rel(entry)) if rel is not None else 0

    def _evict_one(self, protect: bytes) -> None:
        # the just-inserted key is never its own victim (else a 0-hit
        # newcomer would be evicted immediately under lfu)
        pool = [k for k in self._data if k != protect]
        if self.policy == "lru":
            victim = pool[0]  # OrderedDict order = least recent first
        elif self.policy == "lfu":
            # fewest hits; scan in recency order so ties evict least-recent
            victim = min(pool, key=lambda k: self._data[k].hits)
        else:  # ttl: oldest inserted first (insertion-order FIFO)
            victim = min(pool, key=lambda k: self._data[k].inserted_at)
        self._release_kv(self._data.pop(victim).entry)
        self.evictions += 1

    # -- graph-mutation versioning --------------------------------------------
    def _region_of(self, entry: CachedRetrieval) -> frozenset:
        """Node-id buckets an entry's subgraph + seeds touch."""
        nodes = np.asarray(entry.nodes)[np.asarray(entry.mask, bool)]
        ids = np.concatenate([nodes.ravel(), np.asarray(entry.seeds).ravel()])
        return frozenset((ids.astype(np.int64) // self.region_bucket).tolist())

    def _conflicts_since(self, epoch: int, region: frozenset | None) -> bool:
        """Did any mutation after ``epoch`` touch ``region``?  Conservative:
        an epoch older than the bounded log (or an unknown region) counts
        as a conflict."""
        if self._touched_log and epoch < self._touched_log[0][0] - 1:
            return True
        for e, touched in self._touched_log:
            if e <= epoch:
                continue
            if region is None or (region & touched):
                return True
        return False

    def invalidate_regions(self, touched_nodes, epoch: int) -> int:
        """A mutation reached ``epoch`` after touching ``touched_nodes``:
        drop every entry whose subgraph region intersects the touched
        buckets (releasing any prefilled-KV pin it holds) so no future
        lookup — including degraded-mode ``peek_stale`` — can serve a
        result the mutation superseded.  Entries in unrelated regions
        survive; ``mutation_flush="all"`` is the strict mode that drops
        everything.  Returns the number of entries invalidated.

        Mutations that only *add* nodes/edges near a cached subgraph also
        land in the touched set (endpoints count), so a cached result that
        *should* now include a new neighbor is invalidated too — staleness
        is bounded by one epoch for touched regions.
        """
        ids = np.asarray(touched_nodes, np.int64).ravel()
        buckets = frozenset((ids // self.region_bucket).tolist())
        self.graph_epoch = max(self.graph_epoch, int(epoch))
        self._touched_log.append((int(epoch), buckets))
        del self._touched_log[: -self._touched_log_max]
        victims = []
        for k, slot in self._data.items():
            region = slot.entry.region
            if self.mutation_flush == "all" or region is None \
                    or (region & buckets):
                victims.append(k)
        for k in victims:
            self._release_kv(self._data.pop(k).entry)
        self.invalidated += len(victims)
        return len(victims)

    def put(self, query_emb, entry: CachedRetrieval) -> None:
        if self.capacity <= 0:
            return
        if entry.region is None:
            entry.region = self._region_of(entry)
        if entry.epoch < self.graph_epoch and \
                self._conflicts_since(entry.epoch, entry.region):
            # collected after a mutation superseded its region (e.g. an
            # in-flight wave launched pre-mutation): still served to its
            # requester, never cached
            self.stale_rejects += 1
            return
        now = self._now()
        k = self.key(query_emb)
        prev = self._data.get(k)
        if prev is not None:
            # re-insert of a live key (e.g. prefetch.py re-publishing an
            # owner-computed entry the owner's own eviction raced away):
            # keep the accumulated ``hits`` so a warm lfu entry does not
            # become the next eviction victim.  ``inserted_at`` DOES
            # refresh — a re-insert carries fresh data, so its TTL window
            # restarts (and ttl-policy eviction treats it as newest).
            if prev.entry is not entry:
                self._release_kv(prev.entry)  # displaced entry's pin goes
            self._data[k] = _Slot(entry=entry, inserted_at=now,
                                  hits=prev.hits)
        else:
            self._data[k] = _Slot(entry=entry, inserted_at=now)
        entry.cache_key = k
        self._data.move_to_end(k)
        if len(self._data) > self.capacity:
            self._purge_expired(now)
        while len(self._data) > self.capacity:
            self._evict_one(protect=k)

    # -- prefilled-KV pins ----------------------------------------------------
    def is_resident(self, entry: CachedRetrieval) -> bool:
        """True while ``entry`` is the live occupant of its cache slot —
        the engine's pin gate, so prompt blocks are never pinned to an
        entry that eviction (or an overwrite) already displaced (such a pin
        would leak pool blocks: no future eviction would release it)."""
        k = getattr(entry, "cache_key", None)
        if k is None:
            return False
        slot = self._data.get(k)
        return slot is not None and slot.entry is entry

    def kv_pinned_entries(self) -> int:
        return sum(
            1 for s in self._data.values()
            if getattr(s.entry, "kv_blocks", None) is not None
        )

    def reclaim_kv(self, want_blocks: int, owner=None) -> int:
        """Release prefilled-KV pins until at least ``want_blocks`` pool
        blocks have returned to the free stack (or no pins remain) —
        the engine's pool-pressure hook, called *before* it truncates any
        live request, so pinned KV is strictly lower-priority than live
        decode.  Victim order: TTL-expired pins first, then the active
        policy's eviction order among the rest.  Entries keep their
        retrieval result — only the KV pin is dropped.  ``owner`` filters
        to pins held against one engine's pool (a shared cache may carry
        pins from several replicas)."""
        if want_blocks <= 0:
            return 0
        now = self._now()
        pinned = [
            k for k, s in self._data.items()
            if getattr(s.entry, "kv_blocks", None) is not None
            and (owner is None or s.entry.kv_owner is owner)
        ]
        expired = [k for k in pinned
                   if self._is_expired(self._data[k], now)]
        fresh = [k for k in pinned if k not in set(expired)]
        if self.policy == "lfu":
            fresh.sort(key=lambda k: self._data[k].hits)
        elif self.policy == "ttl":
            fresh.sort(key=lambda k: self._data[k].inserted_at)
        # lru: dict order is already least-recent-first
        freed = 0
        for k in expired + fresh:
            if freed >= want_blocks:
                break
            freed += self._release_kv(self._data[k].entry)
        return freed

    def stats(self) -> dict:
        total = self.hits + self.misses
        now = self._now()
        resident = len(self._data)
        live = sum(1 for s in self._data.values()
                   if not self._is_expired(s, now))
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "expired": self.expired,
            "stale_hits": self.stale_hits,
            "stale_misses": self.stale_misses,
            "policy": self.policy,
            # resident = entries occupying capacity (including TTL-expired
            # ones kept for degraded-mode peek_stale); live = entries a
            # get() could still hit.  "size" keeps its historical meaning
            # (resident) for existing dashboards.
            "size": resident,
            "resident": resident,
            "live": live,
            "kv_pinned_entries": self.kv_pinned_entries(),
            "inflight": len(self._inflight),
            "hit_rate": self.hits / total if total else 0.0,
            "graph_epoch": self.graph_epoch,
            "invalidated": self.invalidated,
            "stale_rejects": self.stale_rejects,
        }

    def stats_ns(self) -> dict:
        """Namespaced stats (unified serving schema): this cache's counters
        under ``cache.*`` — see :mod:`repro.serving.stats`."""
        return {"cache": self.stats()}
