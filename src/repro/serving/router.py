"""Multi-replica serving front end: health-aware routing + replica failover.

One :class:`~repro.serving.rag_engine.RAGServeEngine` — however
fault-tolerant — is one fault domain and one arena's worth of throughput.
:class:`ReplicaRouter` fans requests across N engine replicas and makes
**replica failure a first-class, survived event**:

* **Health-aware routing** — each step the router reads every replica's
  :meth:`~repro.serving.rag_engine.RAGServeEngine.health` snapshot and
  scores the *delta* of its fault counters (retries + timeouts +
  retrieval failures + failed requests) over a sliding window of steps.  A
  replica whose faults are climbing trips a per-replica circuit breaker:

  - ``closed``    — normal rotation; new requests routed by least load.
  - ``open``      — no new dispatches; in-flight work keeps draining.
    After ``cooldown_steps`` the breaker moves to half-open.
  - ``half_open`` — at most one outstanding *probe* request.  A probe that
    completes cleanly (done, not degraded/stale/failed) closes the
    breaker; any fresh fault while half-open re-opens it.

* **Crash containment + failover** — a replica whose ``step()`` raises is
  marked crashed.  The router calls ``abort()`` on it (host-side
  reconciliation still works on a wedged replica: slots retired, paged KV
  blocks freed, in-flight cache keys released so no survivor ever defers
  to a dead wave) and — with ``failover=True`` (default) — **re-dispatches
  the crashed replica's un-finished requests onto survivors**.  Retrieval
  is cached/deterministic and greedy decode is schedule-invariant, so a
  re-dispatched request produces bitwise-identical output to the run it
  lost (asserted in ``tests/test_router.py``).  ``failover=False`` is the
  naive baseline: the crashed replica's requests are delivered ``failed``
  (stranded), which is what ``benchmarks/multi_replica.py`` measures
  against.  A crashed replica is re-probed every ``cooldown_steps`` (one
  ``step()`` attempt); a flapping replica that heals rejoins through the
  half-open path.

* **Front-door shedding** — ``max_pending`` bounds the *router* queue with
  the same ``reject`` / ``evict-oldest`` policies as the per-engine
  admission control, and expired deadlines are shed before dispatch, so
  overload is refused at the fleet edge before it costs any replica work.

* **Shared retrieval tier** — every replica should be constructed with the
  same :class:`~repro.serving.cache.RetrievalCache` instance.  The cache's
  in-flight key registry then gives the fleet single-flight semantics: a
  query dispatched by one replica is never re-dispatched by another — the
  later request defers to the owner's wave across the replica boundary
  (see :mod:`repro.serving.cache` / :mod:`repro.serving.prefetch`).

Delivery contract: every submitted request reaches **exactly one** terminal
state through :meth:`step`'s return (done / failed / shed), no matter which
replicas crash when — the chaos soak asserts exactly-once accounting and
zero leaked slots / blocks / cache keys across the whole fleet.

The router is single-threaded and steps replicas round-robin; replicas are
"threads/devices today, hosts later" (ROADMAP) — the containment protocol
(health deltas, circuit states, abort + re-dispatch) is the part that
carries over to a multi-host router unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

from repro.serving.rag_engine import RAGRequest


@dataclasses.dataclass
class _ReplicaState:
    """Router-side bookkeeping around one replica engine."""

    engine: object  # RAGServeEngine (or a FaultyReplica wrapping one)
    name: str
    circuit: str = "closed"  # closed | open | half_open
    crashed: bool = False
    opened_at: int = 0  # router step the circuit opened / replica crashed
    window: deque = dataclasses.field(default_factory=deque)  # fault deltas
    last_faults: int = 0  # cumulative fault score at last health read
    assigned: dict = dataclasses.field(default_factory=dict)  # uid -> req
    probe_uid: Optional[int] = None  # outstanding half-open probe
    # counters
    dispatched: int = 0
    delivered: int = 0
    crashes: int = 0
    trips: int = 0  # closed -> open transitions

    @property
    def load(self) -> int:
        return len(self.assigned)

    def fault_delta_sum(self) -> int:
        return sum(self.window)


class ReplicaRouter:
    """Fan requests across N ``RAGServeEngine`` replicas; survive replica
    failure.

    Usage::

        cache = RetrievalCache(capacity=512)
        replicas = [RAGServeEngine(pipe, params, cfg, retrieval_cache=cache)
                    for _ in range(3)]
        router = ReplicaRouter(replicas)
        router.submit(RAGRequest(uid=0, query_emb=emb, query_text="..."))
        finished = router.run_to_completion()

    Knobs:

    * ``failover`` — re-dispatch a crashed replica's unfinished requests
      onto survivors (True, default) or deliver them ``failed`` (False,
      the naive baseline).
    * ``max_pending`` / ``shed_policy`` — front-door overload control on
      the router queue (0 = unbounded; ``reject`` refuses the newcomer,
      ``evict-oldest`` sheds the oldest queued request).
    * ``replica_depth`` — max requests outstanding on one replica before
      the router stops routing to it (default ``2 * slots``): bounds how
      much work a crash can strand and keeps the queue at the front door
      where shedding is cheap.
    * ``trip_threshold`` / ``health_window`` — circuit opens when a
      replica accrues >= ``trip_threshold`` fault-counter deltas within
      the last ``health_window`` router steps.
    * ``cooldown_steps`` — steps an open circuit waits before half-open,
      and between revival probes of a crashed replica.
    * ``default_deadline_s`` — deadline applied to requests that carry
      none.  The router pins the *absolute* deadline at submit, so a
      failover re-dispatch never restarts a request's deadline budget.
    """

    def __init__(
        self,
        replicas: list,
        *,
        failover: bool = True,
        max_pending: int = 0,
        shed_policy: str = "reject",
        replica_depth: Optional[int] = None,
        health_window: int = 8,
        trip_threshold: int = 3,
        cooldown_steps: int = 8,
        default_deadline_s: Optional[float] = None,
        now_fn=time.monotonic,
    ):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        if max_pending < 0:
            raise ValueError(f"max_pending must be >= 0, got {max_pending}")
        if shed_policy not in ("reject", "evict-oldest"):
            raise ValueError(
                f"shed_policy={shed_policy!r}: expected 'reject' or "
                f"'evict-oldest'"
            )
        if health_window < 1:
            raise ValueError(f"health_window must be >= 1, got {health_window}")
        if trip_threshold < 1:
            raise ValueError(
                f"trip_threshold must be >= 1, got {trip_threshold}"
            )
        if cooldown_steps < 1:
            raise ValueError(
                f"cooldown_steps must be >= 1, got {cooldown_steps}"
            )
        self.replicas = [
            _ReplicaState(engine=e, name=f"replica{i}")
            for i, e in enumerate(replicas)
        ]
        for st in self.replicas:
            st.window = deque(maxlen=health_window)
        self.failover = failover
        self.max_pending = max_pending
        self.shed_policy = shed_policy
        self.replica_depth = replica_depth
        self.trip_threshold = trip_threshold
        self.cooldown_steps = cooldown_steps
        self.default_deadline_s = default_deadline_s
        self._now = now_fn
        self.pending: deque = deque()
        self._terminal: list = []  # front-door terminal (shed) requests
        self._delivered_uids: set = set()
        self._step_no = 0
        self._rr = 0  # round-robin tiebreak cursor
        # fleet counters
        self.submitted = 0
        self.shed_count = 0  # front-door sheds (router queue/deadline)
        self.failovers = 0  # crash events that triggered re-dispatch
        self.redispatched = 0  # requests resurrected onto survivors
        self.stranded = 0  # crashed-replica requests delivered failed
        self.duplicate_deliveries = 0  # exactly-once violations (bug tripwire)

    # -- capacity -------------------------------------------------------------
    def _depth(self, st: _ReplicaState) -> int:
        if self.replica_depth is not None:
            return self.replica_depth
        return 2 * st.engine.slots

    def _routable(self, st: _ReplicaState) -> bool:
        """May NEW work be routed to this replica right now?"""
        if st.crashed or st.circuit == "open":
            return False
        if st.circuit == "half_open":
            # one probe at a time: the breaker closes on its clean finish
            return st.probe_uid is None
        return st.load < self._depth(st)

    # -- front door -----------------------------------------------------------
    def _shed(self, req: RAGRequest, reason: str) -> None:
        req.shed = True
        req.error = reason
        self.shed_count += 1
        self._terminal.append(req)

    def submit(self, req: RAGRequest) -> bool:
        """Validate and enqueue at the front door.  Returns False when
        overload control sheds the request on arrival (it is still handed
        back by the next :meth:`step`).  Malformed requests raise
        ``ValueError`` and never enter the system."""
        self.replicas[0].engine._validate(req)
        self.submitted += 1
        # pin the ABSOLUTE deadline here: replicas must not restart the
        # budget when a failover re-submits the request
        if req.deadline_at is None:
            deadline = req.deadline_s if req.deadline_s is not None \
                else self.default_deadline_s
            if deadline is not None:
                req.deadline_at = self._now() + float(deadline)
        req.deadline_s = None
        if self.max_pending and len(self.pending) >= self.max_pending:
            if self.shed_policy == "reject":
                self._shed(req, "router queue full (shed_policy=reject)")
                return False
            victim = self.pending.popleft()
            self._shed(victim, "router queue full (shed_policy=evict-oldest)")
        self.pending.append(req)
        return True

    def _expired(self, req: RAGRequest) -> bool:
        return req.deadline_at is not None and self._now() > req.deadline_at

    # -- health scoring / circuit breaker -------------------------------------
    @staticmethod
    def _fault_score(h: dict) -> int:
        """Cumulative badness from the replica's own counters: every retry,
        timeout, exhausted retrieval, and failed request counts one."""
        return (h["retries"] + h["timeouts"] + h["retrieval_failures"]
                + h["failed"])

    def _update_health(self, st: _ReplicaState) -> None:
        if st.crashed:
            return
        h = st.engine.health()
        score = self._fault_score(h)
        delta = score - st.last_faults
        st.last_faults = score
        st.window.append(delta)
        if st.circuit == "closed":
            if st.fault_delta_sum() >= self.trip_threshold:
                st.circuit = "open"
                st.opened_at = self._step_no
                st.trips += 1
        elif st.circuit == "open":
            if self._step_no - st.opened_at >= self.cooldown_steps:
                st.circuit = "half_open"
                st.probe_uid = None
        elif st.circuit == "half_open":
            if delta > 0:
                # the probe (or draining work) faulted: back to open
                st.circuit = "open"
                st.opened_at = self._step_no
                st.probe_uid = None

    def _on_probe_result(self, st: _ReplicaState, req: RAGRequest) -> None:
        if st.circuit != "half_open" or req.uid != st.probe_uid:
            return
        st.probe_uid = None
        if req.done and not (req.failed or req.degraded or req.stale):
            st.circuit = "closed"
            st.window.clear()
        else:
            st.circuit = "open"
            st.opened_at = self._step_no

    # -- crash handling / failover --------------------------------------------
    @staticmethod
    def _reset_for_redispatch(req: RAGRequest) -> None:
        """Strip every per-attempt field so a survivor replica serves the
        request from scratch.  ``deadline_at`` survives on purpose — a
        failover must not extend the request's deadline budget."""
        req.out_tokens = []
        req.prompt_ids = None
        req.retrieved_nodes = None
        req.cache_hit = False
        req.done = req.failed = req.shed = False
        req.stale = req.degraded = req.truncated = False
        req.error = None

    def _handle_crash(self, st: _ReplicaState, exc: Exception) -> None:
        st.crashed = True
        st.circuit = "open"
        st.opened_at = self._step_no
        st.crashes += 1
        st.window.clear()
        st.probe_uid = None
        # host-side reconciliation works even on a wedged replica: slots
        # retired, paged blocks freed, in-flight cache keys released (so no
        # survivor defers to a dead wave), every outstanding request handed
        # back exactly once
        orphans = st.engine.abort(reason=f"{st.name} crashed: {exc}")
        orphan_uids = {r.uid for r in orphans}
        # defensive: anything assigned but not reported by abort() is failed
        for uid, req in list(st.assigned.items()):
            if uid not in orphan_uids and uid not in self._delivered_uids:
                req.failed = True
                req.error = f"{st.name} crashed: lost by abort"
                orphans.append(req)
        st.assigned.clear()
        if self.failover:
            self.failovers += 1
            for req in orphans:
                if self._expired(req):
                    self._reset_for_redispatch(req)
                    self._shed(req, "deadline expired during failover")
                    continue
                self._reset_for_redispatch(req)
                self.pending.appendleft(req)  # oldest work restarts first
                self.redispatched += 1
        else:
            # naive baseline: the crashed replica's requests stay stranded
            self.stranded += len(orphans)
            self._terminal.extend(orphans)

    def _probe_crashed(self, st: _ReplicaState) -> None:
        """Periodic revival attempt: one bare ``step()`` on an (empty,
        aborted) crashed replica.  A flapping replica that healed comes
        back through half-open; a still-dead one just resets the clock."""
        if self._step_no - st.opened_at < self.cooldown_steps:
            return
        try:
            st.engine.step()
        except Exception:
            st.opened_at = self._step_no  # still dead, wait another cooldown
            return
        st.crashed = False
        st.circuit = "half_open"
        st.probe_uid = None
        st.last_faults = self._fault_score(st.engine.health())
        st.window.clear()

    # -- dispatch -------------------------------------------------------------
    def _pick_replica(self) -> Optional[_ReplicaState]:
        """Least-loaded routable replica; round-robin breaks ties so equal
        replicas share work instead of piling onto index 0."""
        n = len(self.replicas)
        best = None
        best_key = None
        for off in range(n):
            st = self.replicas[(self._rr + off) % n]
            if not self._routable(st):
                continue
            key = st.load
            if best is None or key < best_key:
                best, best_key = st, key
        return best

    def _dispatch(self) -> None:
        while self.pending:
            req = self.pending[0]
            if self._expired(req):
                self.pending.popleft()
                self._shed(req, "deadline expired before dispatch")
                continue
            st = self._pick_replica()
            if st is None:
                return  # no routable capacity this step; keep queued
            self.pending.popleft()
            st.assigned[req.uid] = req
            st.dispatched += 1
            if st.circuit == "half_open":
                st.probe_uid = req.uid
            self._rr = (self.replicas.index(st) + 1) % len(self.replicas)
            # the replica re-validates cheaply; deadline_s is None so the
            # absolute deadline_at pinned at the front door stands
            st.engine.submit(req)

    # -- stepping -------------------------------------------------------------
    def _deliver(self, st: _ReplicaState, finished: list, out: list) -> None:
        for req in finished:
            st.assigned.pop(req.uid, None)
            st.delivered += 1
            if req.uid in self._delivered_uids:
                # exactly-once tripwire: never hand the caller a duplicate
                self.duplicate_deliveries += 1
                continue
            self._delivered_uids.add(req.uid)
            self._on_probe_result(st, req)
            out.append(req)

    def step(self) -> list:
        """One fleet step: revive/score replicas, dispatch front-door work,
        step every live replica (containing crashes), and hand back every
        request that reached a terminal state.  Never raises for a replica
        fault."""
        out: list = []
        for st in self.replicas:
            if st.crashed:
                self._probe_crashed(st)
        self._dispatch()
        for st in self.replicas:
            if st.crashed:
                continue
            try:
                finished = st.engine.step()
            except Exception as exc:
                self._handle_crash(st, exc)
                continue
            self._deliver(st, finished, out)
            self._update_health(st)
        self._step_no += 1
        if self._terminal:
            for req in self._terminal:
                if req.uid in self._delivered_uids:
                    self.duplicate_deliveries += 1
                    continue
                self._delivered_uids.add(req.uid)
                out.append(req)
            self._terminal.clear()
        return out

    @property
    def outstanding(self) -> int:
        """Requests accepted but not yet delivered: queued at the front
        door, pending terminal hand-back, or assigned out to a replica."""
        return (len(self.pending) + len(self._terminal)
                + sum(st.load for st in self.replicas))

    def _drained(self) -> bool:
        return self.outstanding == 0

    def run_to_completion(self, max_steps: int = 10_000) -> list:
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if self._drained():
                return done
        raise RuntimeError(
            f"run_to_completion: work still pending after {max_steps} steps "
            f"({len(self.pending)} queued at the router, "
            f"{sum(st.load for st in self.replicas)} assigned to replicas)"
        )

    def abort(self, reason: str = "aborted") -> list:
        """Fail/shed everything outstanding across the whole fleet and
        reconcile every replica.  Exactly-once delivery still holds: only
        requests not yet handed back are returned."""
        while self.pending:
            self._shed(self.pending.popleft(), f"shed: {reason}")
        out: list = []
        for st in self.replicas:
            try:
                orphans = st.engine.abort(reason=reason)
            except Exception:
                orphans = list(st.assigned.values())
                for r in orphans:
                    r.failed = True
                    r.error = f"{st.name} abort failed: {reason}"
            st.assigned.clear()
            self._deliver(st, orphans, out)
        for req in self._terminal:
            if req.uid not in self._delivered_uids:
                self._delivered_uids.add(req.uid)
                out.append(req)
        self._terminal.clear()
        return out

    def drain(self, max_steps: int = 10_000) -> list:
        """``run_to_completion`` that never raises: leftovers are aborted
        and returned alongside the completed requests."""
        done = []
        for _ in range(max_steps):
            done.extend(self.step())
            if self._drained():
                return done
        done.extend(self.abort(reason=f"drain gave up after {max_steps} steps"))
        return done

    # -- telemetry ------------------------------------------------------------
    def stats(self) -> dict:
        per_replica = []
        for st in self.replicas:
            h = None if st.crashed else st.engine.health()
            per_replica.append({
                "name": st.name,
                "circuit": "crashed" if st.crashed else st.circuit,
                "crashes": st.crashes,
                "trips": st.trips,
                "dispatched": st.dispatched,
                "delivered": st.delivered,
                "assigned": st.load,
                "fault_score": st.last_faults,
                "health": h,
            })
        return {
            "replicas": len(self.replicas),
            "submitted": self.submitted,
            "delivered": len(self._delivered_uids),
            "router_pending": len(self.pending),
            "front_door_shed": self.shed_count,
            "failovers": self.failovers,
            "redispatched": self.redispatched,
            "stranded": self.stranded,
            "duplicate_deliveries": self.duplicate_deliveries,
            "failover": self.failover,
            "per_replica": per_replica,
        }

    def stats_ns(self) -> dict:
        """Namespaced stats (unified serving schema): the router's own
        counters under ``router.*`` — see :mod:`repro.serving.stats`."""
        return {"router": self.stats()}
