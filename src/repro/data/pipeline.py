"""Data pipeline: synthetic corpora, RAG-augmented token streams, host sharding.

The paper's abstract-generation task maps to: for each query node, retrieve
a subgraph, linearize (tokenization stage), and train the LM to produce the
node's own text given the retrieved context — `rag_token_stream` builds
exactly that stream, batched through the (jit) retrieval pipeline, so RAG
retrieval is *in the training data path* (the paper's Fig. 2 scenario where
retrieval time stacks on learning time).

`host_shard_iter` does deterministic host sharding + elastic re-assignment
(rendezvous hashing from distributed.fault) for the multi-host posture.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.distributed.fault import elastic_shard_assignment


def synthetic_corpus(n_docs: int = 1000, seed: int = 0, length: int = 32) -> list:
    from repro.graph.generators import _texts

    rng = np.random.default_rng(seed)
    return _texts(rng, n_docs, length)


@dataclasses.dataclass
class TokenDataset:
    """Fixed-length LM samples from a list of token id sequences."""

    ids: np.ndarray  # (n, L) int32
    mask: np.ndarray  # (n, L) bool

    @staticmethod
    def from_texts(texts, vocab, max_len: int = 128) -> "TokenDataset":
        ids = np.zeros((len(texts), max_len), np.int32)
        mask = np.zeros((len(texts), max_len), bool)
        for i, t in enumerate(texts):
            enc = [1] + [vocab.encode_word(w) for w in t.lower().split()][: max_len - 1]
            ids[i, : len(enc)] = enc
            mask[i, : len(enc)] = True
        return TokenDataset(ids=ids, mask=mask)

    def batches(self, batch: int, seed: int = 0, shard: tuple = (0, 1)) -> Iterator:
        """Infinite shuffled batches; (shard_id, n_shards) host sharding."""
        rng = np.random.default_rng(seed)
        sid, ns = shard
        idx = np.arange(len(self.ids))
        idx = idx[idx % ns == sid]
        while True:
            order = rng.permutation(idx)
            for s in range(0, len(order) - batch + 1, batch):
                sel = order[s : s + batch]
                yield {"tokens": self.ids[sel], "loss_mask": self.mask[sel]}


def rag_token_stream(
    pipeline, query_texts: list, query_emb, target_texts: list,
    batch: int = 8, max_len: int = 256, seed: int = 0,
) -> Iterator:
    """RAG-augmented LM batches: prompt = linearized retrieved subgraph,
    loss only on the target continuation (prompt tokens are context)."""
    rng = np.random.default_rng(seed)
    n = len(query_texts)
    tok = pipeline.tokenizer
    while True:
        sel = rng.integers(0, n, size=batch)
        qe = query_emb[sel]
        sub = pipeline.retrieve(qe).sub
        from repro.core.tokenization import subgraph_texts

        node_texts = subgraph_texts(sub, pipeline.node_text)
        ids = np.zeros((batch, max_len), np.int32)
        lmask = np.zeros((batch, max_len), bool)
        for i, qi in enumerate(sel):
            p_ids, p_mask = tok.linearize(query_texts[qi], node_texts[i])
            plen = int(p_mask.sum())
            tgt = [tok.vocab.encode_word(w) for w in target_texts[qi].lower().split()]
            room = max_len - plen
            tgt = tgt[:room]
            ids[i, :plen] = p_ids[:plen]
            ids[i, plen : plen + len(tgt)] = tgt
            lmask[i, max(plen - 1, 0) : plen + len(tgt) - 1] = True  # predict target
        yield {"tokens": ids, "loss_mask": lmask}


def host_shard_iter(files: list, host: int, hosts: list) -> list:
    """Files this host owns under the current elastic assignment."""
    assign = elastic_shard_assignment(len(files), hosts)
    return [f for i, f in enumerate(files) if assign[i] == host]
