from repro.data.pipeline import (
    TokenDataset, rag_token_stream, host_shard_iter, synthetic_corpus,
)

__all__ = [
    "TokenDataset", "rag_token_stream", "host_shard_iter", "synthetic_corpus",
]
