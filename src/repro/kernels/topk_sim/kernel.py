"""Pallas TPU kernel: fused similarity scoring + per-block top-k.

The node-retrieval hot path of the RGL pipeline (paper §2.1.2) and the
recsys ``retrieval_cand`` shape.  Instead of materializing the full (Q, N)
score matrix in HBM (N can be 10^6), each grid cell

  * streams one (C_BLK, D) candidate tile from HBM into VMEM,
  * runs the (Q_BLK, D) x (D, C_BLK) product on the MXU,
  * reduces the tile to its local top-k on-chip,

so HBM writeback shrinks from N to k * n_blocks floats per query
(a ~C_BLK/k compression).  A cheap jnp merge in ops.py finishes the job.

Block sizes: Q_BLK x D and C_BLK x D tiles must fit VMEM (~16 MB on v5e);
defaults (128, 1024) with D <= 4096 use <= (128+1024) * 4096 * 4B = 18 MB
worst case, so ops.py clamps D-tiles by splitting D is unnecessary — D is an
embedding dim (<= 1024 in practice; asserted in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_sim_kernel(q_ref, e_ref, s_ref, i_ref, *, k: int, c_blk: int, n_valid: int):
    j = pl.program_id(1)
    q = q_ref[...]  # (Q_BLK, D)
    e = e_ref[...]  # (C_BLK, D)
    scores = jax.lax.dot_general(
        q, e, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q_BLK, C_BLK)
    col = j * c_blk + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(col < n_valid, scores, -jnp.inf)
    # iterative top-k within the tile (k is small: <= 128)
    for t in range(k):
        m = jnp.max(scores, axis=1)  # (Q_BLK,)
        a = jnp.argmax(scores, axis=1).astype(jnp.int32)  # (Q_BLK,)
        s_ref[:, 0, t] = m
        i_ref[:, 0, t] = a + j * c_blk
        # mask the winner out for the next round
        hit = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) == a[:, None]
        scores = jnp.where(hit, -jnp.inf, scores)


@functools.partial(
    jax.jit, static_argnames=("k", "q_blk", "c_blk", "n_valid", "interpret")
)
def topk_sim_blocks(
    q: jnp.ndarray,
    emb: jnp.ndarray,
    *,
    k: int,
    q_blk: int = 128,
    c_blk: int = 1024,
    n_valid: int | None = None,
    interpret: bool = False,
):
    """q: (Q, D) fp32, emb: (N, D) fp32; Q % q_blk == 0, N % c_blk == 0.

    Returns (scores (Q, n_c_blocks, k), indices (Q, n_c_blocks, k)) of the
    per-tile top-k; caller merges.
    """
    Q, D = q.shape
    N, _ = emb.shape
    assert Q % q_blk == 0 and N % c_blk == 0, (Q, q_blk, N, c_blk)
    assert k <= c_blk
    if n_valid is None:
        n_valid = N
    grid = (Q // q_blk, N // c_blk)
    kern = functools.partial(
        _topk_sim_kernel, k=k, c_blk=c_blk, n_valid=n_valid
    )
    out_shape = (
        jax.ShapeDtypeStruct((Q, N // c_blk, k), jnp.float32),
        jax.ShapeDtypeStruct((Q, N // c_blk, k), jnp.int32),
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_blk, D), lambda i, j: (i, 0)),
            pl.BlockSpec((c_blk, D), lambda i, j: (j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((q_blk, 1, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((q_blk, 1, k), lambda i, j: (i, j, 0)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(q, emb)
