from repro.kernels.topk_sim import ops, ref  # noqa: F401
