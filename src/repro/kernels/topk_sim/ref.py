"""Pure-jnp oracle for fused similarity + top-k node retrieval."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_similarity(q: jnp.ndarray, emb: jnp.ndarray, k: int):
    """q: (Q, D), emb: (N, D) -> (scores (Q, k), indices (Q, k)).

    Exact dot-product retrieval; ties broken by lower index (jax.lax.top_k
    is stable in that sense).
    """
    scores = jnp.dot(q, emb.T, preferred_element_type=jnp.float32)
    return jax.lax.top_k(scores, k)
