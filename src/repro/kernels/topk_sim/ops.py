"""jit'd public wrapper around the topk_sim Pallas kernel.

Handles padding to block multiples, CPU interpret fallback, the final
cross-block merge, and a size heuristic (tiny problems go straight to the
jnp oracle — kernel dispatch isn't worth it below one tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topk_sim import ref
from repro.kernels.topk_sim.kernel import topk_sim_blocks


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("k", "q_blk", "c_blk", "use_kernel"))
def topk_similarity(
    q: jnp.ndarray,
    emb: jnp.ndarray,
    k: int,
    *,
    q_blk: int = 128,
    c_blk: int = 1024,
    use_kernel: bool | None = None,
):
    """Top-k similarity search: q (Q, D) x emb (N, D) -> ((Q,k) scores, (Q,k) idx)."""
    Q, D = q.shape
    N, De = emb.shape
    assert D == De, (D, De)
    k = min(k, N)
    if use_kernel is None:
        use_kernel = N >= 2 * c_blk  # heuristic: at least two candidate tiles
    if not use_kernel:
        return ref.topk_similarity(q, emb, k)

    Qp, Np, Dp = _ceil_to(Q, q_blk), _ceil_to(N, c_blk), _ceil_to(D, 128)
    qp = jnp.zeros((Qp, Dp), jnp.float32).at[:Q, :D].set(q.astype(jnp.float32))
    ep = jnp.zeros((Np, Dp), jnp.float32).at[:N, :D].set(emb.astype(jnp.float32))
    kk = min(k, c_blk)
    s_blk, i_blk = topk_sim_blocks(
        qp, ep, k=kk, q_blk=q_blk, c_blk=c_blk, n_valid=N,
        interpret=not _on_tpu(),
    )
    s_flat = s_blk.reshape(Qp, -1)
    i_flat = i_blk.reshape(Qp, -1)
    top_s, pos = jax.lax.top_k(s_flat, k)
    top_i = jnp.take_along_axis(i_flat, pos, axis=1)
    return top_s[:Q], top_i[:Q]
