from repro.kernels.frontier_expand import ops, ref  # noqa: F401
