"""Pure-jnp oracle for the workset membership mark: batched searchsorted.

One lower-bound binary search per candidate over the sorted workset row —
the exact computation the Pallas kernel tiles, expressed as
``jnp.searchsorted`` under ``vmap``.  Kept as the parity oracle and as the
dispatch path off-TPU (XLA lowers it to the same log-round gather loop).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ws_member(ws_ids: jnp.ndarray, cand: jnp.ndarray) -> jnp.ndarray:
    """ws_ids (Q, C) int32 sorted ascending per row; cand (Q, W) int32.

    Returns (Q, W) bool: True where the candidate id appears in its row's
    workset.  Sentinel-padded workset slots are ordinary values — a
    candidate equal to the pad value *will* match it; callers mask
    sentinels themselves (repro convention: sentinel == num_nodes).
    """
    pos = jax.vmap(jnp.searchsorted)(ws_ids, cand)  # (Q, W) lower bound
    c = ws_ids.shape[1]
    hit = jnp.take_along_axis(ws_ids, jnp.minimum(pos, c - 1), axis=1)
    return (pos < c) & (hit == cand)
