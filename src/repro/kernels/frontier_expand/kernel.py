"""Pallas TPU kernel: workset membership mark via tiled binary search.

The hot inner step of workset-compacted subgraph construction: each hop
proposes ``C * K`` candidate node ids (the neighbors of every workset
entry) and must decide, per candidate, whether it is already a member of
the sorted workset.  The workset row (``C`` int32 ids, ascending, sentinel
padded — C ≤ 8k ⇒ ≤ 32 KB) stays VMEM-resident per query while the
candidate axis streams through in ``blk_w``-wide tiles:

  grid = (Q, W / blk_w); per cell:
    workset row (1, C)      int32  — indexed by query only (stays resident)
    cand tile   (1, blk_w)  int32
    out tile    (1, blk_w)  int8   = 1 where cand ∈ workset row

Membership is a vectorized lower-bound binary search: ``ceil(log2 C)``
rounds of VMEM row-gathers (the same in-VMEM dynamic gather the
bfs_frontier kernel uses), all lanes advancing in lockstep — fixed trip
count, fixed shapes, no data-dependent control flow.  This is what lets
hop expansion cost scale with the workset (``C * K`` marks) instead of the
graph (the dense path's ``(Q, N, K)`` gather).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mark_kernel(ws_ref, cand_ref, o_ref, *, c: int, steps: int):
    ws = ws_ref[0]  # (C,) int32 ascending (sentinel-padded)
    cand = cand_ref[0]  # (blk_w,) int32
    lo = jnp.zeros(cand.shape, jnp.int32)
    hi = jnp.full(cand.shape, c, jnp.int32)
    # lower bound: first index with ws[idx] >= cand, lanes in lockstep
    for _ in range(steps):
        act = lo < hi
        mid = jnp.where(act, (lo + hi) // 2, lo)
        v = ws[jnp.minimum(mid, c - 1)]  # (blk_w,) in-VMEM row gather
        go_right = act & (v < cand)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(act & ~go_right, mid, hi)
    hit = ws[jnp.minimum(lo, c - 1)]
    o_ref[0] = ((lo < c) & (hit == cand)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("blk_w", "interpret"))
def ws_mark_kernel(
    ws_ids: jnp.ndarray,  # (Q, C) int32 sorted ascending per row
    cand: jnp.ndarray,  # (Q, W) int32 candidate ids, W % blk_w == 0
    *,
    blk_w: int = 1024,
    interpret: bool = False,
):
    q, c = ws_ids.shape
    qc, w = cand.shape
    assert qc == q and w % blk_w == 0, (q, qc, w, blk_w)
    steps = max(1, int(c).bit_length())  # ceil(log2 C) + slack, lanes guard
    kern = functools.partial(_mark_kernel, c=c, steps=steps)
    return pl.pallas_call(
        kern,
        grid=(q, w // blk_w),
        in_specs=[
            pl.BlockSpec((1, c), lambda b, i: (b, 0)),
            pl.BlockSpec((1, blk_w), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, blk_w), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((q, w), jnp.int8),
        interpret=interpret,
    )(ws_ids, cand)
