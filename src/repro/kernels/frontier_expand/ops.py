"""Public workset hop-expansion ops: membership mark dispatch + one hop.

``ws_member`` picks the Pallas mark kernel on TPU (interpret mode when
forced elsewhere, for parity tests) and the searchsorted ref otherwise.

``expand_hop`` is the full fixed-shape hop: a ``(Q, C, K)`` neighbor
gather over the workset followed by a sort/unique dedup-merge.  All heavy
steps are *single-operand int32 sorts* over packed keys — XLA's variadic
(multi-key) sort and large scatters are several times slower on CPU — so
(id, dist) rides in one integer: ``id * band + dist`` for the id-major
dedup sort, ``dist * (n+1) + id`` for the distance-major truncation sort,
where ``band = max_hops + 2`` (every live distance is ≤ max_hops; slot
``band-1`` is the sentinel clamp).  This caps the compact path at
``(max_hops + 2) * (n + 1) < 2**31`` — ~200M nodes at the default radius.

Two arms produce bit-identical results:

* ref arm   — workset and candidates concat into one id-major sort; the
  first entry of each id group carries the minimum distance (existing
  entries always win: their distance is ≤ h < h+1).
* kernel arm — the Pallas ``ws_mark_kernel`` first marks candidates
  already in the workset (tiled binary search in VMEM), so only fresh ids
  enter the dedup sort.

Truncation under overflow is deterministic and identical in both arms:
surviving entries are the capacity-C smallest by (distance, id) — since
every existing entry's distance is < the hop's, complete hops are kept
whole and the overflowing hop keeps its lowest fresh ids.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.frontier_expand import ref
from repro.kernels.frontier_expand.kernel import ws_mark_kernel

INF = jnp.int32(0x3FFFFFF)
_MAX32 = jnp.int32(jnp.iinfo(jnp.int32).max)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("blk_w", "use_kernel"))
def ws_member(
    ws_ids: jnp.ndarray,  # (Q, C) int32 sorted ascending per row
    cand: jnp.ndarray,  # (Q, W) int32
    *,
    blk_w: int = 1024,
    use_kernel: bool | None = None,
) -> jnp.ndarray:
    """(Q, W) bool membership of each candidate in its row's sorted workset."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.ws_member(ws_ids, cand)
    w = cand.shape[1]
    blk = min(blk_w, _ceil_to(w, 128))
    wp = _ceil_to(w, blk)
    if wp != w:  # pad with int32 max: never matches a real id
        cand = jnp.pad(cand, ((0, 0), (0, wp - w)),
                       constant_values=jnp.iinfo(jnp.int32).max)
    out = ws_mark_kernel(ws_ids, cand, blk_w=blk, interpret=not _on_tpu())
    return out[:, :w].astype(bool)


def _first_of_group(ids: jnp.ndarray, real: jnp.ndarray) -> jnp.ndarray:
    """First occurrence of each id along a sorted row."""
    q = ids.shape[0]
    prev = jnp.concatenate([jnp.full((q, 1), -1, ids.dtype), ids[:, :-1]], 1)
    return real & (ids != prev)


@functools.partial(jax.jit, static_argnames=("band", "use_kernel"))
def expand_hop(
    ws_ids: jnp.ndarray,  # (Q, C) int32 sorted ascending, sentinel n padded
    ws_dist: jnp.ndarray,  # (Q, C) int32 hop distance, INF at padding
    nbr: jnp.ndarray,  # (N, K) int32 ELL adjacency, sentinel n
    nbr_mask: jnp.ndarray,  # (N, K) bool
    hop_dist,  # scalar int32 in [1, band-2]: distance of nodes added now
    *,
    band: int,  # max_hops + 2: exclusive upper bound on packed distances
    use_kernel: bool | None = None,
):
    """One workset expansion hop (see module docstring for the algorithm).

    ``hop_dist`` must be strictly greater than every live distance in
    ``ws_dist`` (BFS expansion always satisfies this) — both the keep-min-
    distance dedup and the never-evict-existing truncation rely on it.

    Returns ``(ws_ids', ws_dist', fresh (Q,), dropped (Q,) bool)`` where
    ``fresh`` counts distinct new ids proposed (pre-truncation) and
    ``dropped`` flags rows whose merge exceeded capacity.
    """
    q, c = ws_ids.shape
    n, k = nbr.shape
    if band * (n + 1) >= 2 ** 31:
        raise ValueError(
            f"compact path needs (max_hops + 2) * (n + 1) < 2**31; got "
            f"band={band}, n={n}"
        )
    band_ = jnp.int32(band)
    n1 = jnp.int32(n + 1)
    thr = band_ * n1  # every real packed key (either packing) is < thr
    hd = jnp.asarray(hop_dist, jnp.int32)
    valid = ws_ids < n
    safe = jnp.minimum(ws_ids, n - 1)
    cand = jnp.where(valid[:, :, None] & nbr_mask[safe], nbr[safe], n)
    cand = cand.reshape(q, c * k)

    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        # mark members with the Pallas kernel; only fresh ids enter the sort
        present = ws_member(ws_ids, cand, use_kernel=True)
        k1 = jnp.sort(
            jnp.where(present | (cand >= n), _MAX32, cand * band_ + hd), 1
        )  # (Q, C*K) id-major
        id1 = jnp.where(k1 < thr, k1 // band_, n)
        first = _first_of_group(id1, id1 < n)
        k2 = jnp.sort(jnp.where(first, hd * n1 + id1, _MAX32), 1)
        over_fresh = k2[:, c] < thr if c * k > c else jnp.zeros((q,), bool)
        old = jnp.where(valid, ws_dist * n1 + ws_ids, _MAX32)
        k3 = jnp.sort(jnp.concatenate([old, k2[:, :c]], 1), 1)  # (Q, 2C)
        fresh_n = jnp.sum(first, 1, dtype=jnp.int32)
        dropped = over_fresh | (k3[:, c] < thr)
        keep = k3[:, :c]
    else:
        # pure-sort arm: one id-major sort over workset + candidates; the
        # first entry of each id group is the keeper (min distance)
        old = jnp.where(valid, ws_ids * band_ + ws_dist, _MAX32)
        new = jnp.where(cand < n, cand * band_ + hd, _MAX32)
        k1 = jnp.sort(jnp.concatenate([old, new], 1), 1)  # (Q, C + C*K)
        id1 = jnp.where(k1 < thr, k1 // band_, n)
        d1 = k1 % band_
        first = _first_of_group(id1, id1 < n)
        k2 = jnp.sort(jnp.where(first, d1 * n1 + id1, _MAX32), 1)
        fresh_n = jnp.sum(first & (d1 == hd), 1, dtype=jnp.int32)
        dropped = k2[:, c] < thr
        keep = k2[:, :c]

    # repack (dist, id) -> id-major, restore sentinels, final small sort
    kid = keep % n1
    kd = keep // n1
    key3 = jnp.where(keep < thr, kid * band_ + kd, n * band_ + (band_ - 1))
    k4 = jnp.sort(key3, 1)  # (Q, C)
    out_ids = k4 // band_
    out_dist = jnp.where(out_ids < n, k4 % band_, INF)
    return out_ids.astype(jnp.int32), out_dist.astype(jnp.int32), fresh_n, dropped
