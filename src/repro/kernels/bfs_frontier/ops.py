"""Public wrapper: bool<->int8 plumbing, padding, interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bfs_frontier import ref
from repro.kernels.bfs_frontier.kernel import frontier_hop_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("blk_n", "use_kernel"))
def frontier_hop(
    frontier: jnp.ndarray,  # (Q, N) bool
    nbr: jnp.ndarray,  # (N, K) sentinel N
    nbr_mask: jnp.ndarray,
    *,
    blk_n: int = 512,
    use_kernel: bool | None = None,
):
    q, n = frontier.shape
    if use_kernel is None:
        # the Pallas path only pays off where it compiles natively; off-TPU
        # the interpret-mode fallback is orders of magnitude slower than the
        # jnp ref, so default dispatch is TPU-and-large-enough
        use_kernel = _on_tpu() and n >= blk_n
    if not use_kernel:
        return ref.frontier_hop(frontier, nbr, nbr_mask)
    blk = min(blk_n, n)
    np_ = -(-n // blk) * blk
    f8 = jnp.zeros((q, np_ + 1), jnp.int8).at[:, :n].set(frontier.astype(jnp.int8))
    nb = jnp.full((np_, nbr.shape[1]), np_, jnp.int32)
    nb = nb.at[:n].set(jnp.where(nbr_mask, nbr, np_).astype(jnp.int32))
    nb = jnp.where(nb == n, np_, nb)
    mk = jnp.zeros((np_, nbr.shape[1]), bool).at[:n].set(nbr_mask)
    out = frontier_hop_kernel(f8, nb, mk, blk_n=blk, interpret=not _on_tpu())
    return out[:, :n].astype(bool)
