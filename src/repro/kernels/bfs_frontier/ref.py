"""Pure-jnp oracle: one batched pull-BFS frontier hop."""
from __future__ import annotations

import jax.numpy as jnp


def frontier_hop(frontier, nbr, nbr_mask):
    """frontier (Q, N) bool; nbr (N, K) sentinel N; -> reach (Q, N) bool:
    reach[q, v] = OR_k frontier[q, nbr[v, k]] & nbr_mask[v, k]."""
    q = frontier.shape[0]
    fp = jnp.concatenate([frontier, jnp.zeros((q, 1), bool)], axis=1)
    g = fp[:, nbr]  # (Q, N, K)
    return jnp.any(g & nbr_mask[None], axis=-1)
