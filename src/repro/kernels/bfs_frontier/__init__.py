from repro.kernels.bfs_frontier import ops, ref  # noqa: F401
