"""Pallas TPU kernel: one pull-BFS frontier expansion hop.

The inner loop of the paper's graph retrieval (Fig. 2's hot path).  The
frontier is a per-query bitmap row (N+1 int8, VMEM-resident: 256k nodes =
256 KB) and the adjacency streams through in (BLK_N, K) node tiles:

  grid = (Q, N / BLK_N); per cell:
    frontier row (1, N+1) int8     — indexed by query only (stays resident)
    nbr tile     (BLK_N, K) int32
    out tile     (1, BLK_N) int8   = OR_k frontier[nbr[:, k]]

The K-slot loop is unrolled row-gathers within VMEM, identical in shape to
the ell_spmm kernel but with boolean max-accumulate — the paper's "batch
the traversal" insight expressed as fixed-shape tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hop_kernel(f_ref, nbr_ref, msk_ref, o_ref, *, k_slots: int):
    f = f_ref[0]  # (N+1,) int8
    idx = nbr_ref[...]  # (BLK_N, K)
    msk = msk_ref[...]  # (BLK_N, K)
    acc = jnp.zeros((idx.shape[0],), jnp.int8)
    for kk in range(k_slots):
        hit = f[idx[:, kk]]  # (BLK_N,) int8 gather within VMEM
        acc = jnp.maximum(acc, jnp.where(msk[:, kk], hit, 0))
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret"))
def frontier_hop_kernel(
    frontier: jnp.ndarray,  # (Q, N+1) int8 (slot N = 0 sentinel)
    nbr: jnp.ndarray,  # (N, K) int32 sentinel N
    nbr_mask: jnp.ndarray,  # (N, K) bool
    *,
    blk_n: int = 512,
    interpret: bool = False,
):
    q, n1 = frontier.shape
    n, k = nbr.shape
    assert n1 == n + 1 and n % blk_n == 0, (n1, n, blk_n)
    kern = functools.partial(_hop_kernel, k_slots=k)
    return pl.pallas_call(
        kern,
        grid=(q, n // blk_n),
        in_specs=[
            pl.BlockSpec((1, n1), lambda b, i: (b, 0)),
            pl.BlockSpec((blk_n, k), lambda b, i: (i, 0)),
            pl.BlockSpec((blk_n, k), lambda b, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_n), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int8),
        interpret=interpret,
    )(frontier, nbr, nbr_mask)
