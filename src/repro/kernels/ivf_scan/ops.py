"""jit'd public wrapper for the IVF candidate scan.

Pads the candidate axis to a tile multiple and picks dense-gather (small
candidate sets — one gather is cheaper than the scan machinery) vs the
tiled path (large candidate sets — bounded peak memory) by candidate
width.  Sentinel ids are clamped at gather time and masked at score time;
no padded copy of the embedding table is ever made.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
from jax import jit

from repro.kernels.ivf_scan import ref
from repro.kernels.ivf_scan.kernel import ivf_scan_tiled


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jit, static_argnames=("k", "c_blk", "tiled"))
def ivf_candidate_scan(
    q: jnp.ndarray,      # (Q, D)
    emb: jnp.ndarray,    # (N, D)
    cand: jnp.ndarray,   # (Q, W) int32 ids in [0, N]; N = sentinel
    cmask: jnp.ndarray,  # (Q, W) bool
    k: int,
    *,
    c_blk: int = 1024,
    tiled: bool | None = None,
):
    """Score each query against its candidate ids; return top-k (scores, ids).

    The output shape is always (Q, k): invalid (masked / sentinel) slots
    score -inf, and when the candidate list itself is narrower than k the
    tail is padded with (-inf, sentinel) — fixed shapes for downstream
    stages, matching ``jax.lax.top_k`` over the masked dense score matrix
    for the leading min(k, W) columns.
    """
    n, d = emb.shape
    w = cand.shape[1]
    k_eff = min(k, w)
    if tiled is None:
        tiled = w >= 2 * c_blk  # heuristic: at least two candidate tiles
    if not tiled:
        s, i = ref.ivf_candidate_scan(q, emb, cand, cmask, k_eff)
    else:
        wp = _ceil_to(w, c_blk)
        if wp != w:
            cand = jnp.pad(cand, ((0, 0), (0, wp - w)), constant_values=n)
            cmask = jnp.pad(cmask, ((0, 0), (0, wp - w)),
                            constant_values=False)
        s, i = ivf_scan_tiled(q, emb, cand, cmask, k_eff, c_blk=c_blk)
    if k_eff < k:  # keep the (Q, k) contract even for narrow candidate sets
        s = jnp.pad(s, ((0, 0), (0, k - k_eff)), constant_values=-jnp.inf)
        i = jnp.pad(i, ((0, 0), (0, k - k_eff)), constant_values=n)
    return s, i
