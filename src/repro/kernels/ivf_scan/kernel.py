"""Tiled IVF candidate scan: fixed-shape blocked gather+score+merge.

The IVF probe step scores each query against the members of its ``nprobe``
inverted lists.  The dense path (ref.py) gathers all ``W = nprobe * L``
candidate embeddings at once — a ``(Q, W, D)`` HBM materialization that
dwarfs the useful output.  This kernel streams the candidate axis in
``c_blk``-wide tiles instead, exactly like ``topk_sim`` streams the node
axis:

  for each chunk j of c_blk candidate slots:
    * gather   (Q, c_blk, D)   — one tile, not the whole candidate set
    * score    (Q, c_blk)      — batched dot against the query tile
    * reduce   chunk top-k, then merge into the running (Q, k) via a
      lexicographic (score desc, position asc) sort

so peak memory is O(Q * c_blk * D) regardless of nprobe, and every shape
is static.  Written as a blocked ``lax.scan`` rather than a
``pl.pallas_call``: the gather is data-dependent over an HBM-resident
table, which on TPU wants the scalar-prefetch/DMA pattern — the blocked
loop gives the same tiling semantics, runs on every backend, and lets XLA
fuse gather+dot per tile.  Matches ref.py exactly in exact arithmetic,
including the tie-break order (position within the candidate list, the
``jax.lax.top_k`` convention); with float scores the two paths can differ
by 1 ULP because XLA CPU's dense einsum rounds position-dependently (the
dense path is not even self-consistent across duplicate candidates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_I32_MAX = jnp.iinfo(jnp.int32).max


@functools.partial(jax.jit, static_argnames=("k", "c_blk"))
def ivf_scan_tiled(q, emb, cand, cmask, k: int, *, c_blk: int = 1024):
    """q: (Q, D); emb: (N, D); cand: (Q, W) int32 ids in [0, N] (N =
    sentinel, clamped for the gather, always masked); cmask: (Q, W) bool;
    W % c_blk == 0, k <= W.

    Returns (scores (Q, k), ids (Q, k)) identical to ref.ivf_candidate_scan.
    """
    qn, w = cand.shape
    assert w % c_blk == 0 and k <= w, (w, c_blk, k)
    n_chunks = w // c_blk
    kt = min(k, c_blk)  # per-chunk survivors

    # chunk-major layout for the scan: (n_chunks, Q, c_blk)
    cand_c = cand.reshape(qn, n_chunks, c_blk).transpose(1, 0, 2)
    mask_c = cmask.reshape(qn, n_chunks, c_blk).transpose(1, 0, 2)
    bases = jnp.arange(n_chunks, dtype=jnp.int32) * c_blk
    n_max = emb.shape[0] - 1

    def step(carry, xs):
        run_s, run_p, run_i = carry  # (Q, k) each, sorted by (-score, pos)
        c_ids, c_m, base = xs
        ce = emb[jnp.minimum(c_ids, n_max)]  # (Q, c_blk, D) — one tile
        s = jnp.einsum("qd,qcd->qc", q, ce)
        s = jnp.where(c_m, s, -jnp.inf)
        cs, cloc = jax.lax.top_k(s, kt)  # ties -> earlier in-chunk position
        cp = base + cloc  # global candidate-list position (tie key)
        ci = jnp.take_along_axis(c_ids, cloc, axis=1)
        ms = jnp.concatenate([run_s, cs], axis=1)
        mp = jnp.concatenate([run_p, cp], axis=1)
        mi = jnp.concatenate([run_i, ci], axis=1)
        neg, pos, ids = jax.lax.sort((-ms, mp, mi), num_keys=2)
        return (-neg[:, :k], pos[:, :k], ids[:, :k]), None

    init = (
        jnp.full((qn, k), -jnp.inf, jnp.float32),
        jnp.full((qn, k), _I32_MAX, jnp.int32),
        jnp.full((qn, k), emb.shape[0], jnp.int32),  # sentinel id
    )
    (run_s, _, run_i), _ = jax.lax.scan(step, init, (cand_c, mask_c, bases))
    return run_s, run_i
