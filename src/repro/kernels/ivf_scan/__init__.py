from repro.kernels.ivf_scan import kernel, ops, ref  # noqa: F401
