"""Pure-jnp oracle for the IVF candidate scan: dense gather + einsum.

This is the path the tiled kernel replaces — it materializes the whole
``(Q, W, D)`` candidate-embedding gather in HBM before scoring, where
``W = nprobe * list_len`` can reach 10^4 per query at production scale.
Kept as the parity oracle and as the fast path for small candidate sets.

Sentinel ids (== N) are clamped for the gather rather than served from an
appended zero row: a full-table concat inside the caller's jit would be
re-materialized per scan iteration on the tiled path, so both paths share
the clamp-and-mask convention (sentinel slots are always mask=False, so the
garbage row they gather never scores).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ivf_candidate_scan(q, emb, cand, cmask, k: int):
    """q: (Q, D); emb: (N, D); cand: (Q, W) int32 ids in [0, N] where N is
    the sentinel; cmask: (Q, W) bool, False at sentinel slots.

    Returns (scores (Q, k), ids (Q, k)) sorted by score desc, ties broken by
    earlier candidate position (jax.lax.top_k semantics).  Returned ids are
    the raw cand values (sentinels included on -inf rows).
    """
    safe = jnp.minimum(cand, emb.shape[0] - 1)
    ce = emb[safe]  # (Q, W, D) — the dense gather
    scores = jnp.einsum("qd,qwd->qw", q, ce)
    scores = jnp.where(cmask, scores, -jnp.inf)
    top_s, pos = jax.lax.top_k(scores, k)
    return top_s, jnp.take_along_axis(cand, pos, axis=1)
