"""Pure-jnp oracle: causal (optionally sliding-window) attention, GQA-aware."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q, k, v, *, window=None):
    """q (B,S,H,dh), k/v (B,S,KV,dh) -> (B,S,H,dh); causal; optional window."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, s, kvh, rep, dh)
    scores = jnp.einsum(
        "bqkrd,bckd->bkrqc", qg, k, preferred_element_type=jnp.float32
    ) * (dh**-0.5)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window is not None:
        m &= (i - j) < window
    scores = jnp.where(m[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkrqc,bckd->bqkrd", p.astype(v.dtype), v)
    return o.reshape(b, s, h, dh)
