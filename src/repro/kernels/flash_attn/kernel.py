"""Pallas TPU flash attention (FlashAttention-2 schedule), causal + window.

Grid: (batch*kv_head*rep, n_q_blocks, n_kv_blocks) — the kv axis is the
innermost (sequential on TPU), so online-softmax accumulators live in VMEM
scratch across kv steps and the output tile is written once, at the last kv
block.  Blocks are (BLK_Q, dh) x (BLK_K, dh) with dh a lane multiple (128);
the MXU sees (BLK_Q, dh) @ (dh, BLK_K).

Sliding-window masking composes with causal masking per tile; fully-masked
tiles still run (correct, suboptimal) — grid pruning is a recorded §Perf
candidate rather than baked-in complexity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, blk_q: int, blk_k: int, n_k: int, scale: float, window,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (BLK_Q, dh)
    k = k_ref[0]  # (BLK_K, dh)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (BLK_Q, BLK_K)
    iq = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    jk = kj * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jk <= iq
    if window is not None:
        mask &= (iq - jk) < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("blk_q", "blk_k", "window", "interpret")
)
def flash_mha(
    q: jnp.ndarray,  # (BH, S, dh) query heads flattened
    k: jnp.ndarray,  # (BH, S, dh) kv repeated to query-head count
    v: jnp.ndarray,
    *,
    blk_q: int = 128,
    blk_k: int = 128,
    window=None,
    interpret: bool = False,
):
    bh, s, dh = q.shape
    assert s % blk_q == 0 and s % blk_k == 0, (s, blk_q, blk_k)
    n_q, n_k = s // blk_q, s // blk_k
    kern = functools.partial(
        _flash_kernel, blk_q=blk_q, blk_k=blk_k, n_k=n_k,
        scale=dh**-0.5, window=window,
    )
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
