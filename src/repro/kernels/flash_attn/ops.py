"""Public wrapper: GQA layout handling, padding, CPU interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_mha


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "q_blk", "kv_blk"))
def flash_attention(q, k, v, *, window=None, q_blk: int = 128, kv_blk: int = 128):
    """q (B,S,H,dh), k/v (B,S,KV,dh) — causal flash attention, GQA-aware.

    KV heads are logically repeated to the query-head count; XLA keeps the
    repeat as a broadcast (no HBM copy) because it feeds a reshape-transpose.
    """
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    qf = jnp.transpose(q, (0, 2, 1, 3)).reshape(b * h, s, dh)
    kf = jnp.transpose(kr, (0, 2, 1, 3)).reshape(b * h, s, dh)
    vf = jnp.transpose(vr, (0, 2, 1, 3)).reshape(b * h, s, dh)
    blk_q = min(q_blk, s)
    blk_k = min(kv_blk, s)
    o = flash_mha(
        qf, kf, vf, blk_q=blk_q, blk_k=blk_k, window=window,
        interpret=not _on_tpu(),
    )
    return jnp.transpose(o.reshape(b, h, s, dh), (0, 2, 1, 3))
