"""Public wrapper: padding + sentinel handling + interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ell_spmm import ref
from repro.kernels.ell_spmm.kernel import ell_aggregate_kernel

_VMEM_BUDGET = 4 * 1024 * 1024  # bytes for the resident feature tile


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("blk_m", "use_kernel"))
def ell_aggregate(
    feat: jnp.ndarray,  # (Q, M, D)
    nbr: jnp.ndarray,  # (Q, M, K), sentinel M
    nbr_mask: jnp.ndarray,
    *,
    blk_m: int = 128,
    use_kernel: bool | None = None,
):
    q, m, d = feat.shape
    if use_kernel is None:
        use_kernel = (m + 1) * d * 4 <= _VMEM_BUDGET
    if not use_kernel:
        return ref.ell_aggregate(feat, nbr, nbr_mask)
    blk = min(blk_m, m)
    mp = -(-m // blk) * blk
    fpad = jnp.zeros((q, mp + 1, d), feat.dtype).at[:, :m].set(feat)
    npad = jnp.full((q, mp, nbr.shape[2]), mp, jnp.int32)
    npad = npad.at[:, :m].set(jnp.where(nbr_mask, nbr, mp).astype(jnp.int32))
    # remap original sentinel M -> padded sentinel MP
    npad = jnp.where(npad == m, mp, npad)
    mpad = jnp.zeros((q, mp, nbr.shape[2]), bool).at[:, :m].set(nbr_mask)
    out = ell_aggregate_kernel(
        fpad, npad, mpad, blk_m=blk, interpret=not _on_tpu()
    )
    return out[:, :m]
