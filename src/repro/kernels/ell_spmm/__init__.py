from repro.kernels.ell_spmm import ops, ref  # noqa: F401
