"""Pallas TPU kernel: batched ELL neighbor aggregation.

The message-passing hot path when encoding *retrieved subgraphs* (the RGL
case: Q queries x M<=1k nodes each, K neighbor slots).  The per-query
feature tile (M+1, D) fits VMEM — exactly the regime where gathers stay
on-chip instead of bouncing to HBM per edge:

  grid = (Q, M / BLK_M); per cell:
    feat tile    (M+1, D)   VMEM-resident (indexed by query only)
    nbr tile     (BLK_M, K) int32
    out tile     (BLK_M, D) = sum_k mask * feat[nbr[:, k]]

The inner gather is a K-step unrolled loop of row-gathers (jnp.take along
the sublane axis), each feeding a masked accumulate on the VPU.  Big-graph
aggregation (full_graph/ogb regimes) instead uses edge-list segment_sum in
models/gnn — that path is XLA-native and sharded; this kernel owns the
small-M high-Q regime the paper's pipeline produces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_kernel(feat_ref, nbr_ref, msk_ref, o_ref, *, k_slots: int):
    f = feat_ref[0]  # (M+1, D)
    idx = nbr_ref[0]  # (BLK_M, K)
    msk = msk_ref[0]  # (BLK_M, K)
    acc = jnp.zeros((idx.shape[0], f.shape[1]), jnp.float32)
    for kk in range(k_slots):  # unrolled: K is small (8..64)
        rows = f[idx[:, kk]]  # (BLK_M, D) row gather within VMEM
        acc = acc + jnp.where(msk[:, kk][:, None], rows, 0.0)
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_m", "interpret"))
def ell_aggregate_kernel(
    feat: jnp.ndarray,  # (Q, M+1, D) — row M is the zero sentinel
    nbr: jnp.ndarray,  # (Q, M, K) int32 in [0, M]
    nbr_mask: jnp.ndarray,  # (Q, M, K) bool
    *,
    blk_m: int = 128,
    interpret: bool = False,
):
    q, m1, d = feat.shape
    m = m1 - 1
    k = nbr.shape[2]
    assert m % blk_m == 0, (m, blk_m)
    kern = functools.partial(_ell_kernel, k_slots=k)
    return pl.pallas_call(
        kern,
        grid=(q, m // blk_m),
        in_specs=[
            pl.BlockSpec((1, m1, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, blk_m, k), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, blk_m, k), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_m, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, m, d), feat.dtype),
        interpret=interpret,
    )(feat, nbr, nbr_mask)
