"""Pure-jnp oracle: batched ELL neighbor aggregation (subgraph encoding)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_aggregate(feat, nbr, nbr_mask):
    """feat (Q, M, D); nbr (Q, M, K) positions into [0, M] (sentinel M);
    nbr_mask (Q, M, K).  out[q, i] = sum_k mask[q,i,k] * feat[q, nbr[q,i,k]]."""
    q, m, d = feat.shape

    def per_query(f, idx, msk):
        fp = jnp.concatenate([f, jnp.zeros((1, d), f.dtype)], axis=0)  # (M+1, D)
        g = fp[jnp.minimum(idx, m)]  # (M, K, D)
        return jnp.sum(jnp.where(msk[..., None], g, 0.0), axis=1)

    return jax.vmap(per_query)(feat, nbr, nbr_mask)
