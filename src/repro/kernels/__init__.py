"""Pallas TPU kernels (validated on CPU via interpret=True).

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd public wrapper with padding + fallback) and ref.py (pure-jnp
oracle used by the allclose test sweeps).
"""
from repro.kernels import (  # noqa: F401
    topk_sim, ell_spmm, flash_attn, bfs_frontier, ivf_scan,
)
