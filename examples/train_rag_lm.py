"""End-to-end driver: train an LM on RAG-augmented citation data.

Retrieval (RGL pipeline) runs inside the data path — each batch's prompts
are retrieved subgraph linearizations, and the LM learns to generate the
node text given its retrieved context (the paper's abstract-generation
setup as a *training* task).  Full substrate stack: AdamW + microbatching +
async checkpointing + straggler monitor + crash-restart capability.

Defaults are CPU-sized (~2M params, 200 steps).  --model_scale 100m selects
a ~100M-parameter configuration for real hardware.

    PYTHONPATH=src python examples/train_rag_lm.py --steps 200
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core import (
    BruteIndex, GraphTokenizer, PipelineConfig, RGLPipeline, Vocab,
)
from repro.data import rag_token_stream
from repro.graph import csr_to_ell, generators
from repro.models.transformer import TransformerConfig, model as tm
from repro.training import AdamWConfig, TrainLoop, make_train_step


def model_config(scale: str, vocab: int) -> TransformerConfig:
    if scale == "100m":
        return TransformerConfig(
            name="rag-lm-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_head=64, d_ff=3072, vocab=vocab, dtype="bfloat16",
        )
    return TransformerConfig(  # ~2M params: CPU-friendly
        name="rag-lm-2m", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        d_head=32, d_ff=512, vocab=vocab, dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=192)
    ap.add_argument("--nodes", type=int, default=1500)
    ap.add_argument("--model_scale", default="2m", choices=["2m", "100m"])
    ap.add_argument("--ckpt_dir", default="/tmp/rag_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    # ---- RGL retrieval pipeline (stages 1-4) -------------------------------
    g = generators.citation_graph(args.nodes, avg_deg=8, seed=0)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb,
        tokenizer=GraphTokenizer(vocab, max_len=args.seq, node_budget=12),
        node_text=g.node_text,
        config=PipelineConfig(strategy="bfs", k_seeds=3, max_nodes=24,
                              filter_budget=8),
    )
    titles = [" ".join(t.split()[:4]) for t in g.node_text]
    data = rag_token_stream(
        pipe, titles, np.asarray(g.node_feat), g.node_text,
        batch=args.batch, max_len=args.seq,
    )

    # ---- LM + training substrate -------------------------------------------
    cfg = model_config(args.model_scale, vocab.size)
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  vocab={vocab.size}")

    def loss_fn(p, batch):
        return tm.lm_loss(
            p, jnp.asarray(batch["tokens"]), jnp.asarray(batch["loss_mask"]), cfg
        )

    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    init_state, step = make_train_step(loss_fn, opt_cfg, n_microbatches=2)
    state = init_state(params)
    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    os.makedirs(args.ckpt_dir, exist_ok=True)
    loop = TrainLoop(
        step_fn=jax.jit(step, donate_argnums=(0,)),
        data_iter=data,
        checkpointer=AsyncCheckpointer(args.ckpt_dir, keep=2),
        checkpoint_every=50,
        log_every=10,
    )
    t0 = time.time()
    state, history = loop.run(state, args.steps, start_step=start)
    loop.checkpointer.close()
    if history:
        print(f"loss: {history[0][1]:.3f} -> {history[-1][1]:.3f} "
              f"({args.steps} steps, {time.time() - t0:.0f}s)")
    if loop.monitor.stragglers():
        print("stragglers detected:", loop.monitor.stragglers())


if __name__ == "__main__":
    main()
