"""RGL quickstart: the 5-stage pipeline on a synthetic citation graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (
    BruteIndex, ExtractiveGenerator, GraphTokenizer, PipelineConfig,
    RGLPipeline, Vocab,
)
from repro.graph import csr_to_ell, generators


def main():
    # 1) data + index (stage 1: indexing)
    g = generators.citation_graph(2000, avg_deg=8, seed=0)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    index = BruteIndex.build(emb)

    # tokenizer + generator (stages 4-5)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=384, node_budget=24)
    gen = ExtractiveGenerator(vocab, max_words=32)

    pipe = RGLPipeline(
        graph=ell, index=index, node_emb=emb, tokenizer=tok, generator=gen,
        node_text=g.node_text,
        config=PipelineConfig(strategy="steiner", k_seeds=4, max_hops=3,
                              max_nodes=48, filter_budget=16),
    )

    # a batch of queries = noisy versions of some node embeddings
    q_ids = [10, 500, 1500]
    qe = emb[jnp.asarray(q_ids)] + 0.05
    out = pipe.run(qe, [" ".join(g.node_text[i].split()[:5]) for i in q_ids])

    for r, qi in enumerate(q_ids):
        print(f"query node {qi}")
        print(f"  seeds: {out['seeds'][r].tolist()}")
        kept = int(out['subgraph'].mask[r].sum())
        print(f"  retrieved subgraph: {kept} nodes (steiner, filtered)")
        print(f"  generated: {out['outputs'][r][:100]}...")
    print("\npipeline stages: index -> node retrieval -> graph retrieval "
          "-> dynamic filter -> tokenize -> generate  [OK]")


if __name__ == "__main__":
    main()
