"""Serve a small RAG-LM end to end with the fused engine.

Raw (query embedding, query text) requests go through the whole RGL stack —
index -> seed retrieval -> subgraph -> dynamic filter -> tokenization ->
batched prefill -> continuous-batching decode — inside one RAGServeEngine.
Retrieval is batched across each admission wave and cached (LRU on quantized
query embeddings), so repeated queries skip index + BFS entirely.

    PYTHONPATH=src python examples/serve_rag.py --requests 12
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BruteIndex, GraphTokenizer, PipelineConfig, RGLPipeline, Vocab,
)
from repro.models.transformer import TransformerConfig, model as tm
from repro.graph import csr_to_ell, generators
from repro.serving import RAGRequest, RAGServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max_new", type=int, default=16)
    ap.add_argument("--repeat", type=int, default=0,
                    help="extra duplicate requests (exercise the cache)")
    args = ap.parse_args()

    g = generators.citation_graph(1000, avg_deg=8, seed=0)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=160, node_budget=10)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb, tokenizer=tok,
        node_text=g.node_text,
        config=PipelineConfig(strategy="bfs", k_seeds=3, max_nodes=16,
                              filter_budget=6),
    )

    cfg = TransformerConfig(
        name="serve-lm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=256, vocab=vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    eng = RAGServeEngine(pipe, params, cfg, slots=args.slots, cache_len=224)

    rng = np.random.default_rng(0)
    q_ids = rng.choice(1000, size=args.requests, replace=False)
    emb_np = np.asarray(emb)
    t0 = time.time()
    for u, qi in enumerate(q_ids):
        eng.submit(RAGRequest(
            uid=int(qi), query_emb=emb_np[qi],
            query_text=" ".join(g.node_text[qi].split()[:4]),
            max_new_tokens=args.max_new,
        ))
    for _ in range(args.repeat):  # duplicates — served from the cache
        qi = q_ids[int(rng.integers(len(q_ids)))]
        eng.submit(RAGRequest(
            uid=10_000 + int(qi), query_emb=emb_np[qi],
            query_text=" ".join(g.node_text[qi].split()[:4]),
            max_new_tokens=args.max_new,
        ))
    done = eng.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    s = eng.stats()
    print(f"served {len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, {args.slots} slots)")
    print(f"retrieval: {s['retrieval_batches']} batched calls for "
          f"{s['retrieved_queries']} queries in {s['retrieval_seconds']:.2f}s; "
          f"cache {s['hits']} hits / {s['misses']} misses")
    id2w = {v + 6: k for k, v in vocab.word_to_id.items()}
    sample = done[0]
    words = " ".join(id2w.get(t, "?") for t in sample.out_tokens[:10])
    print(f"request {sample.uid} -> {words} ...")


if __name__ == "__main__":
    main()
