"""Serve a small RAG-LM with batched requests (continuous batching).

Queries hit the RGL retrieval pipeline, get linearized into prompts, and
stream through the slot-based ServeEngine — the deployment shape of the
paper's Graph Q&A application.

    PYTHONPATH=src python examples/serve_rag.py --requests 12
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BruteIndex, GraphTokenizer, PipelineConfig, RGLPipeline, Vocab,
)
from repro.models.transformer import TransformerConfig, model as tm
from repro.graph import csr_to_ell, generators
from repro.serving import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max_new", type=int, default=16)
    args = ap.parse_args()

    g = generators.citation_graph(1000, avg_deg=8, seed=0)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=160, node_budget=10)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb, tokenizer=tok,
        node_text=g.node_text,
        config=PipelineConfig(strategy="bfs", k_seeds=3, max_nodes=16,
                              filter_budget=6),
    )

    cfg = TransformerConfig(
        name="serve-lm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=256, vocab=vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, slots=args.slots, cache_len=224)

    # batch-retrieve contexts for all requests, then stream them in
    rng = np.random.default_rng(0)
    q_ids = rng.choice(1000, size=args.requests, replace=False)
    qe = emb[jnp.asarray(q_ids)]
    sub, _ = pipe.retrieve(qe)
    from repro.core.tokenization import subgraph_texts

    ctxs = subgraph_texts(sub, g.node_text)
    t0 = time.time()
    for r, qi in enumerate(q_ids):
        ids, mask = tok.linearize(" ".join(g.node_text[qi].split()[:4]), ctxs[r])
        eng.submit(Request(uid=int(qi), prompt_ids=ids[mask],
                           max_new_tokens=args.max_new))
    done = eng.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, {args.slots} slots)")
    id2w = {v + 6: k for k, v in vocab.word_to_id.items()}
    sample = done[0]
    words = " ".join(id2w.get(t, "?") for t in sample.out_tokens[:10])
    print(f"request {sample.uid} -> {words} ...")


if __name__ == "__main__":
    main()
