"""RGL application: modality completion on a bipartite recsys graph
(paper §3.2.1 / Table 1) — retrieval-augmented feature completion.

    PYTHONPATH=src python examples/modality_completion.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.modality_completion import run


def main():
    rows = run(n_users=400, n_items=200, n_inter=4000)
    print(f"{'method':14s} {'MSE':>8s} {'R@20':>8s} {'N@20':>8s}")
    for r in rows:
        print(f"{r['name']:14s} {r['mse']:8.3f} {r['r@20']:8.4f} {r['n@20']:8.4f}")
    best = max(rows, key=lambda r: r["r@20"])
    print(f"\nbest method: {best['name']} (retrieval-augmented completion)")


if __name__ == "__main__":
    main()
