"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
config of each assigned arch and run one forward/train step on CPU,
asserting output shapes + no NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models.gnn import apply_gnn, gnn_loss, init_gnn
from repro.models.gnn.wigner import build_wigner_lut
from repro.models.recsys import wide_deep as wd
from repro.models.transformer import model as tm

# whole-arch train/serve smokes are the long tail of the suite; tier-1 runs
# `-m "not slow"` (pytest.ini), `-m slow` covers these
pytestmark = pytest.mark.slow

LM_ARCHS = [a for a in C.ARCH_IDS if C.get_config(a).family == "lm"]
GNN_ARCHS = [a for a in C.ARCH_IDS if C.get_config(a).family == "gnn"]


def test_registry_complete():
    assert len(C.ARCH_IDS) == 10
    fams = [C.get_config(a).family for a in C.ARCH_IDS]
    assert fams.count("lm") == 5 and fams.count("gnn") == 4
    assert fams.count("recsys") == 1


def test_full_configs_match_assignment():
    c = C.get_config("starcoder2-3b").model_cfg
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        30, 3072, 24, 2, 12288, 49152,
    ) and c.sliding_window == 4096
    c = C.get_config("deepseek-7b").model_cfg
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        30, 4096, 32, 32, 11008, 102400,
    )
    c = C.get_config("deepseek-coder-33b").model_cfg
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        62, 7168, 56, 8, 19200, 32256,
    )
    c = C.get_config("grok-1-314b").model_cfg
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == (
        64, 6144, 48, 8, 131072,
    ) and (c.moe.n_experts, c.moe.top_k, c.moe.d_ff) == (8, 2, 32768)
    c = C.get_config("granite-moe-1b-a400m").model_cfg
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == (
        24, 1024, 16, 8, 49155,
    ) and (c.moe.n_experts, c.moe.top_k, c.moe.d_ff) == (32, 8, 512)
    c = C.get_config("graphcast").model_cfg
    assert (c.n_layers, c.d_hidden, c.mesh_refinement, c.n_vars) == (16, 512, 6, 227)
    c = C.get_config("meshgraphnet").model_cfg
    assert (c.n_layers, c.d_hidden, c.mlp_layers) == (15, 128, 2)
    c = C.get_config("gin-tu").model_cfg
    assert (c.n_layers, c.d_hidden) == (5, 64)
    c = C.get_config("equiformer-v2").model_cfg
    assert (c.n_layers, c.d_hidden, c.l_max, c.m_max, c.n_heads) == (12, 128, 6, 2, 8)
    c = C.get_config("wide-deep").model_cfg
    assert (c.n_sparse, c.embed_dim, tuple(c.mlp)) == (40, 32, (1024, 512, 256))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_serve(arch):
    cfg = C.get_config(arch).reduced_cfg
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    mask = jnp.ones((2, 32), bool)
    loss, metrics = tm.lm_loss(params, toks, mask, cfg)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: tm.lm_loss(p, toks, mask, cfg)[0])(params)
    assert all(np.isfinite(float(jnp.abs(x).sum())) for x in jax.tree.leaves(g))
    # serve path
    cache_len = cfg.sliding_window or 32
    logits, cache = tm.prefill(params, toks[:, :16], jnp.array([16, 16]), cfg, cache_len)
    assert logits.shape == (2, cfg.vocab) and not bool(jnp.isnan(logits).any())
    nxt, cache = tm.serve_step(params, cache, jnp.argmax(logits, -1).astype(jnp.int32), cfg)
    assert nxt.shape == (2,)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    spec = C.get_config(arch)
    cfg = spec.reduced_cfg
    from repro.graph import generators

    g = generators.citation_graph(60, avg_deg=4, d_feat=cfg.d_in, seed=0)
    src, dst = g.edge_list()
    inputs = {
        "node_feat": jnp.asarray(g.node_feat),
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
        "edge_mask": jnp.ones(len(src), bool),
        "targets": jnp.zeros((60, cfg.d_out)),
    }
    if cfg.arch == "equiformer_v2":
        inputs["pos"] = jnp.asarray(
            np.random.default_rng(0).standard_normal((60, 3)).astype(np.float32)
        )
        inputs["wigner_lut"] = jnp.asarray(
            build_wigner_lut(cfg.l_max, n_theta=8, n_phi=16, n_samples=128)
        )
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    out = apply_gnn(params, cfg, inputs)
    assert out.shape == (60, cfg.d_out) and not bool(jnp.isnan(out).any())
    loss, grads = jax.value_and_grad(lambda p: gnn_loss(p, cfg, inputs))(params)
    assert np.isfinite(float(loss))


def test_recsys_smoke_train_step():
    cfg = C.get_config("wide-deep").reduced_cfg
    params = wd.init_wide_deep(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b = 16
    dense = jnp.asarray(rng.standard_normal((b, cfg.n_dense)), jnp.float32)
    ids = rng.integers(0, cfg.rows_per_field, (b, cfg.n_sparse, cfg.bag_size))
    ids += np.arange(cfg.n_sparse)[None, :, None] * cfg.rows_per_field
    ids[rng.random(ids.shape) < 0.2] = -1
    labels = jnp.asarray(rng.integers(0, 2, b), jnp.float32)
    loss = wd.wide_deep_loss(params, cfg, dense, jnp.asarray(ids), labels)
    assert np.isfinite(float(loss))
    lg = wd.wide_deep_logits(params, cfg, dense, jnp.asarray(ids))
    assert lg.shape == (b,) and not bool(jnp.isnan(lg).any())
    s, i = wd.retrieval_scores(
        jnp.asarray(rng.standard_normal((1, cfg.mlp[-1])), jnp.float32),
        jnp.asarray(rng.standard_normal((4096, cfg.mlp[-1])), jnp.float32),
        k=10,
    )
    assert s.shape == (1, 10)


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_input_specs_abstract(arch):
    spec = C.get_config(arch)
    for shape_name, shape in spec.shapes.items():
        if shape.kind == "skip":
            assert spec.family == "lm"
            continue
        specs = C.input_specs(arch, shape_name, abstract=True)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_long_500k_skips_documented():
    skips = [
        a for a in C.ARCH_IDS
        if C.get_config(a).family == "lm"
        and C.get_config(a).shapes["long_500k"].kind == "skip"
    ]
    assert sorted(skips) == [
        "deepseek-7b", "deepseek-coder-33b", "granite-moe-1b-a400m", "grok-1-314b",
    ]
    assert C.get_config("starcoder2-3b").shapes["long_500k"].kind == "long_decode"
