import os

import numpy as np
import pytest

# the paged-KV invariant guard (host-side tripwire for the alloc_blocks
# sum(need) <= n_free contract and for refcount double-frees) is env-gated
# off in production; the whole test suite runs with it armed so any
# accounting drift fails loudly instead of silently aliasing pool blocks
os.environ.setdefault("RGL_KV_DEBUG", "1")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
