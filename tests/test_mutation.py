"""Online mutation tier: delta graph, incremental index, rebuild parity,
versioned cache invalidation, ServingConfig precedence, unified stats."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GraphTokenizer, MutableGraphStore, MutationBatch, PipelineConfig,
    RetrievalResult, Vocab,
)
from repro.graph import CSRGraph, DeltaGraph, SlackOverflow, generators
from repro.models.transformer import TransformerConfig, model as tm
from repro.serving import (
    CachedRetrieval, RAGRequest, RAGServeEngine, RetrievalCache,
    ServingConfig, flatten_stats,
)

N = 80
D = 16


def _graph(seed=0, n=N):
    return generators.citation_graph(n, avg_deg=5, d_feat=D, seed=seed)


def _store(g=None, **kw):
    return MutableGraphStore.build(g if g is not None else _graph(), **kw)


def _rand_batches(store, rng, rounds):
    """A deterministic mixed mutation workload over live endpoints."""
    reports = []
    for _ in range(rounds):
        n = store.n_nodes
        alive = np.flatnonzero(np.asarray(store.alive)[:n])
        u, v = int(rng.choice(alive)), int(rng.choice(alive))
        kind = rng.random()
        if kind < 0.35:
            b = MutationBatch(add_edges=np.array([[u, v]]))
        elif kind < 0.6:
            b = MutationBatch(del_edges=np.array([[u, v]]))
        elif kind < 0.85:
            b = MutationBatch(
                add_node_feat=rng.normal(size=(1, D)).astype(np.float32),
                add_node_text=[f"added {n}"],
                add_edges=np.array([[n, u]]),
            )
        else:
            b = MutationBatch(del_nodes=np.array([u]))
        reports.append(store.apply(b))
    return reports


# ------------------------------------------------------- delta vs oracle ----
def test_delta_merged_view_matches_host_oracle(rng):
    g = _graph(seed=3)
    from repro.graph import csr_to_ell
    ell = csr_to_ell(g)
    cap = g.num_nodes + 10
    d = DeltaGraph(np.asarray(ell.nbr), np.asarray(ell.nbr_mask),
                   g.num_nodes, cap, extra_deg=4)
    r = np.random.default_rng(7)
    for _ in range(60):
        op = r.random()
        n = d.n_nodes
        live = np.flatnonzero(~d.tomb[:n])
        u, v = int(r.choice(live)), int(r.choice(live))
        if op < 0.4:
            try:
                d.add_edge(u, v)
            except SlackOverflow:
                pass
        elif op < 0.7:
            d.del_edge(u, v)
        elif op < 0.9 and n < cap:
            d.add_node()
        elif live.size > 2:
            d.del_node(u)
        nbr_h, mask_h = d.merged_host()
        m = d.merged()
        np.testing.assert_array_equal(np.asarray(m.nbr), nbr_h)
        np.testing.assert_array_equal(np.asarray(m.nbr_mask), mask_h)
        assert m.num_nodes == cap


def test_delta_edge_semantics():
    base_nbr = np.zeros((2, 1), np.int32)
    base_mask = np.zeros((2, 1), bool)
    d = DeltaGraph(base_nbr, base_mask, 2, 4, extra_deg=2)
    assert d.add_edge(0, 1) and not d.add_edge(0, 1)  # dedup
    assert d.del_edge(0, 1) and not d.del_edge(0, 1)  # idempotent delete
    assert d.add_edge(0, 1)  # re-add after delete
    u = d.add_node()
    assert u == 2
    assert d.add_edge(0, u)
    with pytest.raises(SlackOverflow):
        d.add_edge(0, 3 if d.add_node() == 3 else 0)  # third slack slot
    d.del_node(1)
    assert 1 not in d.neighbors_live(0)
    with pytest.raises(ValueError):
        d.add_edge(0, 1)  # tombstoned endpoint


# ------------------------------------------------ rebuild/bitwise parity ----
@pytest.mark.parametrize("kind", ["brute", "ivf"])
def test_compaction_bitwise_equals_from_scratch_rebuild(kind):
    g = _graph(seed=5)
    kw = {"index_kw": {"n_clusters": 8}} if kind == "ivf" else {}
    store = _store(g, index_kind=kind, **kw)
    rng = np.random.default_rng(42)
    _rand_batches(store, rng, 25)
    store.compact()

    # from-scratch comparator: same merged corpus, same frozen quantizer
    src, dst = store.delta.live_edge_list()
    g2 = CSRGraph.from_edges(src, dst, store.n_nodes,
                             node_feat=store.h_feat[:store.n_nodes].copy(),
                             node_text=list(store.node_text[:store.n_nodes]))
    ikw = {}
    if kind == "ivf":
        ikw = {"index_kw": {"centroids": np.asarray(store.index.centroids),
                            "nprobe": store.index.nprobe}}
    ref = MutableGraphStore.build(g2, index_kind=kind, alive=store.alive,
                                  active=True, **ikw)

    np.testing.assert_array_equal(np.asarray(store.graph.nbr),
                                  np.asarray(ref.graph.nbr))
    np.testing.assert_array_equal(np.asarray(store.graph.nbr_mask),
                                  np.asarray(ref.graph.nbr_mask))
    np.testing.assert_array_equal(np.asarray(store.node_emb),
                                  np.asarray(ref.node_emb))
    if kind == "brute":
        np.testing.assert_array_equal(np.asarray(store.index.emb),
                                      np.asarray(ref.index.emb))
    else:
        np.testing.assert_array_equal(store.index.h_lists, ref.index.h_lists)
        np.testing.assert_array_equal(store.index.h_counts, ref.index.h_counts)
    # and search parity on live queries
    q = np.asarray(g.node_feat[:5], np.float32)
    s1, i1 = store.index.search(q, 5)
    s2, i2 = ref.index.search(q, 5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_incremental_ivf_add_and_delete_visibility():
    g = _graph(seed=9)
    store = _store(g, index_kind="ivf", index_kw={"n_clusters": 6})
    rng = np.random.default_rng(1)
    feat = rng.normal(size=(1, D)).astype(np.float32)
    rep = store.apply(MutationBatch(add_node_feat=feat,
                                    add_node_text=["fresh"],
                                    add_edges=np.array([[N, 0]])))
    new_id = rep.added_nodes[0]
    # the new embedding is findable immediately (no compaction needed)
    _, idx = store.index.search(feat, 1)
    assert int(np.asarray(idx)[0, 0]) == new_id
    # a deleted node disappears from results at scan time
    store.apply(MutationBatch(del_nodes=np.array([new_id])))
    _, idx = store.index.search(feat, 5)
    assert new_id not in np.asarray(idx)[0].tolist()


def test_mid_apply_compaction_no_duplicate_ivf_entries():
    # A batch that adds a node and then overflows edge slack forces an
    # inline compaction *after* the node add; the rebuilt index already
    # holds the new id, so the post-apply incremental add must not insert
    # it a second time (it used to, yielding topk like [32, 32, ...]).
    g = _graph(seed=6)
    store = _store(g, index_kind="ivf", index_kw={"n_clusters": 6},
                   extra_deg=1)
    rng = np.random.default_rng(3)
    feat = rng.normal(size=(1, D)).astype(np.float32)
    edges = np.array([[N, v] for v in range(10)])
    rep = store.apply(MutationBatch(add_node_feat=feat,
                                    add_node_text=["fresh"],
                                    add_edges=edges))
    assert rep.compactions > 0  # the mid-apply scenario actually fired
    new_id = rep.added_nodes[0]
    idx = store.index
    flat = np.concatenate([idx.h_lists[c, : idx.h_counts[c]]
                           for c in range(idx.n_clusters)])
    _, dup = np.unique(flat, return_counts=True)
    assert dup.max() == 1  # every alive id indexed exactly once
    _, top = idx.search(feat, 5)
    top = np.asarray(top)[0].tolist()
    assert top[0] == new_id
    assert len(set(top)) == len(top)  # no duplicate results


def test_incremental_ivf_add_is_idempotent():
    g = _graph(seed=8)
    store = _store(g, index_kind="ivf", index_kw={"n_clusters": 6})
    rep = store.apply(MutationBatch(
        add_node_feat=np.ones((1, D), np.float32), add_node_text=["x"]))
    new_id = rep.added_nodes[0]
    idx = store.index
    before = (idx.h_lists.copy(), idx.h_counts.copy())
    idx.add(np.array([new_id], np.int32))  # re-add of an indexed id
    np.testing.assert_array_equal(idx.h_lists, before[0])
    np.testing.assert_array_equal(idx.h_counts, before[1])


def test_is_empty_handles_numpy_edge_arrays():
    assert MutationBatch().is_empty
    assert not MutationBatch(add_edges=np.array([[0, 1]])).is_empty
    assert not MutationBatch(del_edges=np.array([[0, 1]])).is_empty
    assert not MutationBatch(del_nodes=np.array([3])).is_empty
    assert not MutationBatch(
        add_node_feat=np.zeros((1, D), np.float32)).is_empty


def test_slack_overflow_triggers_inline_compaction():
    g = _graph(seed=2)
    store = _store(g, extra_deg=2)
    targets = np.arange(1, 40)
    for v in targets:  # way past 2 slack slots on node 0
        store.apply(MutationBatch(add_edges=np.array([[0, int(v)]])))
    assert store.compactions > 0  # overflow handled inline, no raise
    nbrs = set(store.delta.neighbors_live(0).tolist())
    assert set(targets.tolist()) <= nbrs


# -------------------------------------------------- zero-mutation parity ----
def test_pristine_store_serves_frozen_objects():
    g = _graph(seed=4)
    from repro.graph import csr_to_ell
    from repro.core.indexing import BruteIndex
    store = _store(g)
    ell = csr_to_ell(g)
    # pristine passthrough: identical arrays, not just equal ones
    np.testing.assert_array_equal(np.asarray(store.graph.nbr),
                                  np.asarray(ell.nbr))
    np.testing.assert_array_equal(np.asarray(store.node_emb), g.node_feat)
    frozen = BruteIndex.build(jnp.asarray(g.node_feat))
    q = np.asarray(g.node_feat[:4], np.float32)
    s1, i1 = store.index.search(q, 4)
    s2, i2 = frozen.search(q, 4)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert not store.active and store.epoch == 0


def test_retrieval_result_surface():
    store = _store()
    pipe = store.make_pipeline(config=PipelineConfig(
        strategy="bfs", k_seeds=2, max_hops=2, max_nodes=12, filter_budget=6))
    q = np.asarray(store.node_emb)[:3]
    res = pipe.retrieve_many(q, batch_size=4)
    assert isinstance(res, RetrievalResult)
    assert res.n_valid == 3 and res.epoch == 0
    assert res.nodes is res.sub.nodes and res.mask is res.sub.mask
    store.apply(MutationBatch(add_edges=np.array([[0, 1]])))
    assert pipe.retrieve_many(q, batch_size=4).epoch == 1
    assert pipe.n_valid_nodes == store.n_nodes


# ------------------------------------------- versioned cache invalidation ----
def _entry(nodes, seeds=None, epoch=0):
    nodes = np.asarray(nodes, np.int32)
    if seeds is None:
        seeds = nodes[:1]  # seed inside the entry's own region
    return CachedRetrieval(
        nodes=nodes, mask=np.ones_like(nodes, bool),
        dist=np.zeros(nodes.shape, np.int32),
        seeds=np.asarray(seeds, np.int32), epoch=epoch,
    )


def test_cache_region_invalidation_is_selective():
    c = RetrievalCache(capacity=8, region_bucket=4)
    c.put(np.ones(D) * 1, _entry([0, 1, 2]))       # buckets {0}
    c.put(np.ones(D) * 2, _entry([16, 17]))        # buckets {4}
    assert c.invalidate_regions(np.array([1]), epoch=1) == 1
    assert c.get(np.ones(D) * 1) is None           # touched region dropped
    assert c.get(np.ones(D) * 2) is not None       # untouched survives
    assert c.graph_epoch == 1
    s = c.stats()
    assert s["invalidated"] == 1 and s["graph_epoch"] == 1


def test_cache_put_gate_rejects_superseded_inflight_results():
    c = RetrievalCache(capacity=8, region_bucket=4)
    # a mutation lands (epoch 1, touching node 2) while a wave launched at
    # epoch 0 is still in flight; its late put must be refused
    c.invalidate_regions(np.array([2]), epoch=1)
    c.put(np.ones(D), _entry([0, 1, 2], epoch=0))
    assert c.get(np.ones(D)) is None and c.stats()["stale_rejects"] == 1
    # a late put whose region the mutation did NOT touch is still accepted
    c.put(np.ones(D) * 3, _entry([32, 33], epoch=0))
    assert c.get(np.ones(D) * 3) is not None


def test_cache_mutation_flush_all_mode():
    c = RetrievalCache(capacity=8, mutation_flush="all")
    c.put(np.ones(D), _entry([0]))
    c.put(np.ones(D) * 2, _entry([64]))
    assert c.invalidate_regions(np.array([0]), epoch=1) == 2
    assert c.stats()["resident"] == 0


def test_invalidation_releases_kv_pins():
    c = RetrievalCache(capacity=8, region_bucket=4)
    released = []

    def release(entry):
        released.append(entry)
        entry.kv_blocks = None
        return 2  # blocks returned to the pool

    e = _entry([0, 1])
    c.put(np.ones(D), e)
    e.kv_blocks = np.array([3, 4], np.int32)
    e.kv_release = release
    assert c.invalidate_regions(np.array([0]), epoch=1) == 1
    assert len(released) == 1 and released[0] is e
    assert e.kv_blocks is None


# --------------------------------------------- ServingConfig precedence ----
def test_serving_config_precedence_kwarg_env_default(monkeypatch):
    # pin a clean environment even when a CI cell arms these engine-wide
    monkeypatch.delenv("RGL_RETRIES", raising=False)
    monkeypatch.delenv("RGL_MUTATION", raising=False)
    # default
    assert ServingConfig.from_env().max_retries == 0
    # env beats default
    monkeypatch.setenv("RGL_RETRIES", "3")
    assert ServingConfig.from_env().max_retries == 3
    # kwarg beats env
    assert ServingConfig.resolve(None, max_retries=5).max_retries == 5
    # config object beats env too (it IS the kwarg layer once constructed)
    cfg = ServingConfig(max_retries=7).finalize()
    assert cfg.max_retries == 7
    # bools: env only consulted when field unset
    monkeypatch.setenv("RGL_MUTATION", "1")
    assert ServingConfig.from_env().mutation is True
    assert ServingConfig.resolve(None, mutation=False).mutation is False


def test_serving_config_validation():
    with pytest.raises(ValueError, match="admission"):
        ServingConfig(admission="bogus").finalize()
    with pytest.raises(ValueError, match="shed_policy"):
        ServingConfig(shed_policy="drop-all").finalize()
    with pytest.raises(ValueError, match="max_pending"):
        ServingConfig(max_pending=-1).finalize()
    with pytest.raises(ValueError, match="mutation_flush"):
        ServingConfig(mutation_flush="sometimes").finalize()
    with pytest.raises(TypeError, match="unknown"):
        ServingConfig.resolve(None, not_a_field=1)


def test_flatten_stats_namespaces():
    ns = {"cache": {"hits": 1}, "engine": {"shed": 2},
          "prefetch": {"retries": 0}, "decode": {"decode_steps": 9},
          "mutation": {"epoch": 3}, "router": {"failovers": 1}}
    flat = flatten_stats(ns)
    assert flat["hits"] == 1 and flat["decode_steps"] == 9  # legacy unprefixed
    assert flat["mutation_epoch"] == 3 and flat["router_failovers"] == 1


# ---------------------------------------------- serving-level integration ----
@pytest.fixture(scope="module")
def serving_stack():
    g = _graph(seed=11, n=120)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=48, node_budget=6)
    cfg = TransformerConfig(
        name="mut-t", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    pcfg = PipelineConfig(strategy="bfs", k_seeds=2, max_hops=2,
                          max_nodes=12, filter_budget=6)
    return g, tok, cfg, params, pcfg


def _engine(serving_stack, **kw):
    g, tok, cfg, params, pcfg = serving_stack
    store = MutableGraphStore.build(g, index_kind="brute")
    pipe = store.make_pipeline(tokenizer=tok, config=pcfg)
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=96, **kw)
    return store, pipe, eng


def _req(g, qi, uid=0, tokens=4):
    return RAGRequest(uid=uid, query_emb=np.asarray(g.node_feat[qi]),
                      query_text=g.node_text[qi], max_new_tokens=tokens)


def test_zero_mutation_serving_bitwise_identical(serving_stack):
    g, tok, cfg, params, pcfg = serving_stack
    from repro.graph import csr_to_ell
    from repro.core import RGLPipeline
    from repro.core.indexing import BruteIndex
    frozen_pipe = RGLPipeline(
        graph=csr_to_ell(g), index=BruteIndex.build(jnp.asarray(g.node_feat)),
        node_emb=jnp.asarray(g.node_feat), tokenizer=tok,
        node_text=g.node_text, config=pcfg,
    )
    ref_eng = RAGServeEngine(frozen_pipe, params, cfg, slots=2, cache_len=96)
    store, _, eng = _engine(serving_stack)
    for u, qi in enumerate([3, 14, 15, 9, 2, 6]):
        ref_eng.submit(_req(g, qi, uid=u))
        eng.submit(_req(g, qi, uid=u))
    ref_done = {r.uid: r for r in ref_eng.run_to_completion()}
    mut_done = {r.uid: r for r in eng.run_to_completion()}
    assert store.epoch == 0  # never activated
    for uid, r in ref_done.items():
        assert mut_done[uid].out_tokens == r.out_tokens
        np.testing.assert_array_equal(mut_done[uid].retrieved_nodes,
                                      r.retrieved_nodes)


def test_apply_mutations_interleaves_with_serving(serving_stack):
    g, *_ = serving_stack
    store, pipe, eng = _engine(serving_stack)
    for u, qi in enumerate([1, 5, 8, 12]):
        eng.submit(_req(g, qi, uid=u))
    done = []
    rng = np.random.default_rng(0)
    steps = 0
    while not eng._drained() and steps < 200:
        done.extend(eng.step())
        steps += 1
        n = store.n_nodes
        eng.apply_mutations(MutationBatch(add_edges=np.array(
            [[rng.integers(0, n), rng.integers(0, n)]])))
    assert len(done) == 4 and all(r.done for r in done)
    assert store.epoch >= 1
    s = eng.stats()
    assert s["mutation_batches"] == store.batches_applied
    ns = eng.stats_ns()
    assert ns["mutation"]["epoch"] == store.epoch
    assert set(ns) >= {"cache", "engine", "prefetch", "decode", "mutation"}
    # flat view keeps every legacy key
    for k in ("hits", "decode_steps", "prefetch_waves", "shed"):
        assert k in s


def test_mutation_invalidates_cached_retrieval_and_serves_fresh(serving_stack):
    g, *_ = serving_stack
    store, pipe, eng = _engine(serving_stack)
    eng.submit(_req(g, 7, uid=0))
    first = eng.run_to_completion()[0]
    assert eng.cache_misses == 1
    # sever node 7's whole neighborhood: region-touching mutation
    victim = int(first.retrieved_nodes[-1])
    rep = eng.apply_mutations(MutationBatch(del_nodes=np.array([victim])))
    assert eng.mutation_invalidated >= 1  # the cached entry was dropped
    eng.submit(_req(g, 7, uid=1))
    second = eng.run_to_completion()[0]
    assert eng.cache_misses == 2  # re-retrieved, not served from cache
    assert victim not in second.retrieved_nodes.tolist()


def test_mutation_releases_prefix_share_kv_pin(serving_stack):
    g, *_ = serving_stack
    store, pipe, eng = _engine(serving_stack, paged_kv=True, prefix_share=True)
    eng.submit(_req(g, 4, uid=0))
    r0 = eng.run_to_completion()[0]
    assert eng.engine.kv_pins >= 1  # entry pinned its prompt blocks
    pinned_before = eng.engine.kv_pinned_blocks
    assert pinned_before > 0
    victim = int(r0.retrieved_nodes[-1])
    eng.apply_mutations(MutationBatch(del_nodes=np.array([victim])))
    # invalidation released the pin: no stale prefill can ever be aliased
    assert eng.engine.kv_pinned_blocks == 0
    assert eng.engine.kv_releases >= 1
    eng.submit(_req(g, 4, uid=1))
    r1 = eng.run_to_completion()[0]
    assert victim not in r1.retrieved_nodes.tolist()
    assert eng.engine.kv_shared_admits == 0  # nothing stale was reused


def test_mid_flight_epoch_bump_does_not_corrupt_wave(serving_stack):
    """A mutation landing between launch and collect: the in-flight wave
    completes against its launch-time snapshot, and its (superseded) result
    is refused by the cache's epoch put-gate."""
    g, *_ = serving_stack
    store, pipe, eng = _engine(serving_stack, prefetch=True)
    eng.submit(_req(g, 9, uid=0))
    # launch the admission wave but do not collect yet
    eng._launch_pending()
    assert eng.prefetcher.in_flight == 1
    # mutation lands mid-flight: delete the queried node itself, so the
    # in-flight wave's region is guaranteed superseded
    rep = eng.apply_mutations(MutationBatch(del_nodes=np.array([9])))
    assert eng.cache.graph_epoch == rep.epoch
    done = eng.run_to_completion()
    assert len(done) == 1 and done[0].done and not done[0].failed
    # the wave's entry was epoch-0 and touched node 9's region -> rejected
    assert eng.cache.stats()["stale_rejects"] >= 1


def test_rgl_mutation_env_cell_smoke(serving_stack, monkeypatch):
    """RGL_MUTATION=1 routes engine construction through the store-backed
    pipeline (see tests/test_rag_serving.py stack fixture); here we assert
    the env knob resolves into ServingConfig."""
    monkeypatch.setenv("RGL_MUTATION", "1")
    assert ServingConfig.from_env().mutation is True
    monkeypatch.setenv("RGL_COMPACT_EVERY", "7")
    assert ServingConfig.from_env().compact_every == 7


def test_compact_every_auto_compaction(serving_stack):
    g, *_ = serving_stack
    store, pipe, eng = _engine(serving_stack, compact_every=2)
    rng = np.random.default_rng(3)
    for _ in range(4):
        n = store.n_nodes
        eng.apply_mutations(MutationBatch(add_edges=np.array(
            [[rng.integers(0, n), rng.integers(0, n)]])))
    assert store.compactions >= 2
    assert store.mutations_since_compact == 0
