"""Workset-compacted subgraph construction: dense-parity, overflow
semantics, and workset invariants.

The compact backend's contract: whenever no query overflows the capacity,
its output — nodes, mask, dist, including tie order — is bitwise identical
to the dense backend for every strategy; on overflow the truncation is
deterministic (first-C of the ball ordered by (hop distance, node id)) and
the per-query flag is raised.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph_retrieval as gr
from repro.core import naive
from repro.core.workset import build_workset, workset_adjacency
from repro.graph import CSRGraph, csr_to_ell, generators

STRAT_KW = {
    "bfs": dict(max_hops=3, max_nodes=40),
    "dense": dict(max_hops=2, max_nodes=24),
    "steiner": dict(max_hops=4, max_nodes=64),
    "ppr": dict(max_nodes=40, n_iter=6),
}


@pytest.fixture(scope="module")
def graph():
    g = generators.citation_graph(300, avg_deg=6, seed=7, with_text=False)
    return g, csr_to_ell(g), g.to_adj_dict()


def _seeds(n, q=6, s=4, seed=0):
    return np.random.default_rng(seed).integers(0, n, size=(q, s)).astype(np.int32)


def _assert_bitwise_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.nodes), np.asarray(b.nodes))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
    np.testing.assert_array_equal(np.asarray(a.dist), np.asarray(b.dist))


# -------------------------------------------------------- dense parity ------
@pytest.mark.parametrize("strategy", sorted(gr.STRATEGIES))
def test_compact_matches_dense_generous_cap(graph, strategy):
    """cap >= n: overflow is impossible, outputs must be bitwise equal."""
    g, ell, _ = graph
    seeds = jnp.asarray(_seeds(g.num_nodes))
    dense = gr.STRATEGIES[strategy](ell.nbr, ell.nbr_mask, seeds,
                                    **STRAT_KW[strategy])
    comp = gr.COMPACT_STRATEGIES[strategy](
        ell.nbr, ell.nbr_mask, seeds, workset_cap=512, **STRAT_KW[strategy]
    )
    assert not np.asarray(comp.overflow).any()
    _assert_bitwise_equal(dense, comp)


@pytest.mark.parametrize("strategy", sorted(gr.STRATEGIES))
def test_compact_matches_dense_tight_nonoverflowing_cap(graph, strategy):
    """cap < n but >= every ball: parity must still be exact."""
    g, ell, _ = graph
    seeds = jnp.asarray(_seeds(g.num_nodes, q=4, seed=3))
    kw = dict(STRAT_KW[strategy])
    if strategy in ("bfs", "steiner"):
        kw["max_hops"] = 2  # keep the ball well under the cap
    if strategy == "ppr":
        kw["n_iter"] = 2
    comp = gr.COMPACT_STRATEGIES[strategy](
        ell.nbr, ell.nbr_mask, seeds, workset_cap=256, **kw
    )
    assert not np.asarray(comp.overflow).any(), "cap too tight for this test"
    dense = gr.STRATEGIES[strategy](ell.nbr, ell.nbr_mask, seeds, **kw)
    _assert_bitwise_equal(dense, comp)


def test_retrieve_subgraph_mode_dispatch(graph):
    g, ell, _ = graph
    seeds = _seeds(g.num_nodes, q=3)
    d = gr.retrieve_subgraph(ell, seeds, "bfs", mode="dense",
                             max_hops=2, max_nodes=16)
    c = gr.retrieve_subgraph(ell, seeds, "bfs", mode="compact",
                             workset_cap=512, max_hops=2, max_nodes=16)
    a = gr.retrieve_subgraph(ell, seeds, "bfs", mode="auto",
                             max_hops=2, max_nodes=16)
    assert d.overflow is None  # dense backend does not track overflow
    assert c.overflow is not None
    _assert_bitwise_equal(d, c)
    _assert_bitwise_equal(d, a)  # auto on a small graph = dense
    with pytest.raises(ValueError):
        gr.retrieve_subgraph(ell, seeds, "bfs", mode="nope")


@pytest.mark.parametrize("trial", range(3))
def test_compact_parity_random_graphs(trial):
    """Random (non-PA) graphs, all strategies, through the dispatcher."""
    rng = np.random.default_rng(100 + trial)
    n = int(rng.integers(60, 200))
    src = rng.integers(0, n, size=n * 3)
    dst = rng.integers(0, n, size=n * 3)
    g = CSRGraph.from_edges(src, dst, n, symmetrize=True)
    ell = csr_to_ell(g)
    seeds = rng.integers(0, n, size=(3, 3)).astype(np.int32)
    for strategy in sorted(gr.STRATEGIES):
        kw = dict(STRAT_KW[strategy], max_nodes=min(32, n))
        d = gr.retrieve_subgraph(ell, seeds, strategy, mode="dense", **kw)
        c = gr.retrieve_subgraph(ell, seeds, strategy, mode="compact",
                                 workset_cap=max(256, n), **kw)
        assert not np.asarray(c.overflow).any()
        _assert_bitwise_equal(d, c)


# ------------------------------------------------------ workset invariants --
def test_workset_is_exact_ball_without_overflow(graph):
    g, ell, adj = graph
    seeds = jnp.asarray(_seeds(g.num_nodes, q=4, seed=5))
    ws = build_workset(ell.nbr, ell.nbr_mask, seeds, max_hops=3, cap=512)
    assert not np.asarray(ws.overflow).any()
    ids = np.asarray(ws.ids)
    dist = np.asarray(ws.dist)
    for qi in range(4):
        ball = naive.bfs_distances(
            adj, sorted(set(np.asarray(seeds)[qi].tolist())), 3
        )
        real = ids[qi][ids[qi] < g.num_nodes]
        assert (np.diff(real) > 0).all()  # sorted, unique
        assert set(real.tolist()) == set(ball)
        for v, dv in zip(ids[qi], dist[qi]):
            if v < g.num_nodes:
                assert ball[int(v)] == int(dv)


def test_workset_overflow_truncation_is_deterministic(graph):
    """Truncated workset == first-cap of the ball by (dist, id), flag set."""
    g, ell, adj = graph
    seeds = jnp.asarray(_seeds(g.num_nodes, q=4, seed=9))
    cap = 48
    ws = build_workset(ell.nbr, ell.nbr_mask, seeds, max_hops=3, cap=cap)
    ws2 = build_workset(ell.nbr, ell.nbr_mask, seeds, max_hops=3, cap=cap)
    np.testing.assert_array_equal(np.asarray(ws.ids), np.asarray(ws2.ids))
    np.testing.assert_array_equal(np.asarray(ws.dist), np.asarray(ws2.dist))
    ids = np.asarray(ws.ids)
    dist = np.asarray(ws.dist)
    for qi in range(4):
        ball = naive.bfs_distances(
            adj, sorted(set(np.asarray(seeds)[qi].tolist())), 3
        )
        expect_overflow = len(ball) > cap
        assert bool(np.asarray(ws.overflow)[qi]) == expect_overflow
        want = sorted(ball.items(), key=lambda kv: (kv[1], kv[0]))[:cap]
        got = sorted(
            (int(v), int(dv)) for v, dv in zip(ids[qi], dist[qi])
            if v < g.num_nodes
        )
        assert got == sorted(want)


def test_overflowing_retrieval_is_deterministic_and_flagged(graph):
    g, ell, _ = graph
    seeds = _seeds(g.num_nodes, q=4, seed=2)
    a = gr.retrieve_subgraph(ell, seeds, "bfs", mode="compact",
                             workset_cap=48, max_hops=3, max_nodes=32)
    b = gr.retrieve_subgraph(ell, seeds, "bfs", mode="compact",
                             workset_cap=48, max_hops=3, max_nodes=32)
    assert np.asarray(a.overflow).any()
    _assert_bitwise_equal(a, b)


def test_auto_mode_falls_back_to_dense_on_overflow(graph, monkeypatch):
    """auto + overflow -> transparent dense re-run (flagless exact output)."""
    g, ell, _ = graph
    monkeypatch.setattr(gr, "AUTO_COMPACT_MIN_NODES", 1)
    seeds = _seeds(g.num_nodes, q=4, seed=2)
    sub = gr.retrieve_subgraph(ell, seeds, "bfs", mode="auto",
                               workset_cap=48, max_hops=3, max_nodes=32)
    dense = gr.retrieve_subgraph(ell, seeds, "bfs", mode="dense",
                                 max_hops=3, max_nodes=32)
    assert sub.overflow is None  # the dense re-run is what came back
    _assert_bitwise_equal(sub, dense)


def test_auto_mode_is_traceable_under_outer_jit(graph, monkeypatch):
    """Inside jax.jit the overflow flags are tracers: the host-side
    fallback check must be skipped, not crash with a ConcretizationError."""
    import jax

    g, ell, _ = graph
    monkeypatch.setattr(gr, "AUTO_COMPACT_MIN_NODES", 1)
    seeds = jnp.asarray(_seeds(g.num_nodes, q=3, seed=6))

    @jax.jit
    def traced(s):
        sub = gr.retrieve_subgraph(ell, s, "bfs", mode="auto",
                                   workset_cap=256, max_hops=1, max_nodes=16)
        return sub.nodes, sub.overflow

    nodes, ovf = traced(seeds)
    eager = gr.retrieve_subgraph(ell, seeds, "bfs", mode="compact",
                                 workset_cap=256, max_hops=1, max_nodes=16)
    np.testing.assert_array_equal(np.asarray(nodes), np.asarray(eager.nodes))
    np.testing.assert_array_equal(np.asarray(ovf), np.asarray(eager.overflow))


def test_auto_mode_keeps_ppr_dense(graph, monkeypatch):
    """ppr's n_iter-hop radius overflows practical caps: auto stays dense."""
    g, ell, _ = graph
    monkeypatch.setattr(gr, "AUTO_COMPACT_MIN_NODES", 1)
    seeds = _seeds(g.num_nodes, q=3, seed=6)
    sub = gr.retrieve_subgraph(ell, seeds, "ppr", mode="auto",
                               workset_cap=48, max_nodes=16)
    assert sub.overflow is None  # dense backend ran


def test_workset_adjacency_matches_graph(graph):
    g, ell, adj = graph
    seeds = jnp.asarray(_seeds(g.num_nodes, q=3, seed=4))
    ws = build_workset(ell.nbr, ell.nbr_mask, seeds, max_hops=2, cap=256)
    wnbr, wmask = workset_adjacency(ell.nbr, ell.nbr_mask, ws.ids)
    ids = np.asarray(ws.ids)
    wn, wm = np.asarray(wnbr), np.asarray(wmask)
    for qi in range(3):
        members = {int(v): i for i, v in enumerate(ids[qi]) if v < g.num_nodes}
        for v, i in members.items():
            got = {int(ids[qi][p]) for p, ok in zip(wn[qi, i], wm[qi, i]) if ok}
            expect = {w for w in adj[v] if w in members}
            assert got == expect, (qi, v)


def test_filter_preserves_overflow_flags(graph):
    from repro.core.filters import dynamic_filter, similarity_scores

    g, ell, _ = graph
    seeds = _seeds(g.num_nodes, q=4, seed=2)
    sub = gr.retrieve_subgraph(ell, seeds, "bfs", mode="compact",
                               workset_cap=48, max_hops=3, max_nodes=32)
    emb = jnp.asarray(g.node_feat)
    scores = similarity_scores(emb, emb[seeds[:, 0]])
    out = dynamic_filter(sub, scores, jnp.asarray(seeds), budget=8)
    np.testing.assert_array_equal(
        np.asarray(out.overflow), np.asarray(sub.overflow)
    )
