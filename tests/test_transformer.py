"""LM stack: attention equivalence, flash VJP, serve-path consistency, MoE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import MoEConfig, TransformerConfig, model as tm
from repro.models.transformer.attention import chunked_attention, dense_attention
from repro.models.transformer.moe import init_moe_params, moe_ffn

CFG = TransformerConfig(
    name="tiny", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=97, dtype="float32",
)


def _qkv(s=256, h=8, kv=2, dh=32, b=2, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (b, s, h, dh)),
        jax.random.normal(ks[1], (b, s, kv, dh)),
        jax.random.normal(ks[2], (b, s, kv, dh)),
    )


@pytest.mark.parametrize("window", [None, 64])
def test_chunked_equals_dense(window):
    q, k, v = _qkv()
    o1 = chunked_attention(q, k, v, window=window, q_chunk=64, kv_chunk=64)
    o2 = dense_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@pytest.mark.parametrize("window", [None, 48])
def test_flash_vjp_matches_autodiff(window):
    q, k, v = _qkv(s=128, h=4, kv=2, dh=16)
    f1 = lambda *a: chunked_attention(*a, window=window, q_chunk=32, kv_chunk=32).sum()
    f2 = lambda *a: dense_attention(*a, window=window).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_lm_loss_near_uniform_at_init():
    params = tm.init_params(jax.random.PRNGKey(0), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, CFG.vocab)
    loss, _ = tm.lm_loss(params, toks, jnp.ones((2, 32), bool), CFG)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.5


def test_loss_chunking_invariance():
    params = tm.init_params(jax.random.PRNGKey(0), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0, CFG.vocab)
    mask = jnp.ones((2, 33), bool)
    import dataclasses

    l1, _ = tm.lm_loss(params, toks, mask, dataclasses.replace(CFG, loss_chunk=8))
    l2, _ = tm.lm_loss(params, toks, mask, dataclasses.replace(CFG, loss_chunk=32))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_prefill_decode_match_teacher_forcing():
    params = tm.init_params(jax.random.PRNGKey(0), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 0, CFG.vocab)
    full = tm.lm_logits(params, toks, CFG)
    logits, cache = tm.prefill(params, toks[:, :16], jnp.array([16, 16]), CFG, 24)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full[:, 15]), rtol=3e-4, atol=3e-4
    )
    for t in range(16, 24):
        logits, cache = tm.decode_step(params, cache, toks[:, t], CFG)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]), rtol=5e-4, atol=5e-4
        )


def test_ring_buffer_sliding_window_decode():
    import dataclasses

    cfgw = dataclasses.replace(CFG, sliding_window=8, n_layers=2)
    params = tm.init_params(jax.random.PRNGKey(1), cfgw)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 0, cfgw.vocab)
    full = tm.lm_logits(params, toks, cfgw)
    lg, cache = tm.prefill(params, toks[:, :8], jnp.array([8, 8]), cfgw, 8)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 7]), atol=1e-3)
    for t in range(8, 24):  # decode far past the cache length
        lg, cache = tm.decode_step(params, cache, toks[:, t], cfgw)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3
        )


def test_variable_length_prefill():
    params = tm.init_params(jax.random.PRNGKey(0), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, CFG.vocab)
    # row 1 has true length 10: its prefill logits must match a 10-token run
    logits, _ = tm.prefill(params, toks, jnp.array([16, 10]), CFG, 16)
    short = tm.lm_logits(params, toks[1:2, :10], CFG)
    np.testing.assert_allclose(
        np.asarray(logits[1]), np.asarray(short[0, 9]), rtol=3e-4, atol=3e-4
    )


# ------------------------------------------------------------------- MoE ---
def test_moe_capacity_and_combine():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=8.0)
    p = init_moe_params(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 16))
    y, aux = moe_ffn(p, x, cfg)
    assert y.shape == x.shape and float(aux) > 0
    # with huge capacity nothing is dropped: compare to dense per-expert eval
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, top_e = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    expect = np.zeros_like(np.asarray(x))
    for t in range(24):
        for j in range(2):
            e = int(top_e[t, j])
            h = jax.nn.silu(x[t] @ p["w1"][e]) * (x[t] @ p["w3"][e])
            expect[t] += float(gate[t, j]) * np.asarray(h @ p["w2"][e])
    np.testing.assert_allclose(np.asarray(y), expect, rtol=2e-4, atol=2e-4)


def test_moe_drops_overflow_at_low_capacity():
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff=8, capacity_factor=0.25)
    p = init_moe_params(jax.random.PRNGKey(0), 8, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
    y, _ = moe_ffn(p, x, cfg)
    # some tokens must be zeroed (dropped)
    dropped = np.asarray(jnp.all(y == 0, axis=-1)).sum()
    assert dropped > 0


def test_moe_lm_trains():
    cfg = TransformerConfig(
        name="m", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
        d_ff=0, vocab=64, dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64),
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    data = np.tile(np.random.default_rng(0).integers(0, 64, (4, 8)), (1, 4))
    toks = jnp.asarray(data, jnp.int32)
    mask = jnp.ones_like(toks, bool)

    def loss(p):
        return tm.lm_loss(p, toks, mask, cfg)[0]

    g = jax.grad(loss)(params)
    l0 = float(loss(params))
    p2 = jax.tree.map(lambda a, b: a - 0.5 * b, params, g)
    assert float(loss(p2)) < l0


def test_int8_kv_cache_decode_accuracy():
    """int8 KV cache (kv_quant): decode logits match fp32 within quant noise."""
    import dataclasses

    cfgq = dataclasses.replace(CFG, kv_quant=True)
    params = tm.init_params(jax.random.PRNGKey(0), cfgq)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 0, CFG.vocab)
    full = tm.lm_logits(params, toks, CFG)
    logits, cache = tm.prefill(params, toks[:, :16], jnp.array([16, 16]), cfgq, 24)
    assert cache.k.dtype == jnp.int8 and cache.k_scale is not None
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, 15]),
                               atol=5e-3)
    errs = []
    for t in range(16, 24):
        logits, cache = tm.decode_step(params, cache, toks[:, t], cfgq)
        errs.append(float(jnp.max(jnp.abs(logits - full[:, t]))))
    assert max(errs) < 0.05, errs
