"""Graph substrate: CSR/ELL/batching/sampler (+ hypothesis invariants)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.graph import CSRGraph, NeighborSampler, batch_graphs, csr_to_ell, generators


def test_csr_roundtrip():
    g = generators.citation_graph(200, avg_deg=6, seed=0)
    src, dst = g.edge_list()
    g2 = CSRGraph.from_edges(src, dst, g.num_nodes)
    assert g2.num_edges == g.num_edges
    for u in (0, 5, 199):
        assert sorted(g2.neighbors(u)) == sorted(g.neighbors(u))


def test_ell_preserves_neighbors():
    g = generators.citation_graph(150, avg_deg=4, seed=1)
    ell = csr_to_ell(g)
    deg = g.degrees()
    nbr = np.asarray(ell.nbr)
    msk = np.asarray(ell.nbr_mask)
    for u in range(0, 150, 17):
        got = sorted(nbr[u][msk[u]].tolist())
        assert got == sorted(g.neighbors(u).tolist())
        assert msk[u].sum() == deg[u]
    # sentinel padding everywhere else
    assert (nbr[~msk] == g.num_nodes).all()


def test_ell_truncation():
    g = generators.citation_graph(150, avg_deg=8, seed=2)
    ell = csr_to_ell(g, max_deg=4, pad_to_multiple=1)
    assert ell.nbr.shape[1] == 4
    assert int(ell.degrees().max()) <= 4


def test_batch_graphs_block_diagonal():
    gs = generators.molecule_graphs(n_graphs=5, n_nodes=10, n_edges=20, seed=0)
    big, gids = batch_graphs(gs)
    assert big.num_nodes == 50
    assert len(gids) == 50 and gids.max() == 4
    src, dst = big.edge_list()
    # no cross-graph edges
    assert (gids[src] == gids[dst]).all()


def test_neighbor_sampler_shapes_and_validity():
    g = generators.citation_graph(500, avg_deg=8, seed=3)
    s = NeighborSampler(g, (5, 3), seed=0)
    seeds = np.arange(32)
    blk = s.sample(seeds)
    assert blk.hops[0].shape == (32, 5)
    assert blk.hops[1].shape == (160, 3)
    assert blk.n_valid <= len(blk.nodes)
    cap = len(blk.nodes)
    # every sampled position points to a real union node or the sentinel
    for h, m in zip(blk.hops, blk.hop_masks):
        assert (h[m] < blk.n_valid).all()
        assert (h[~m] == cap).all()
    # sampled neighbors really are graph neighbors
    nodes = blk.nodes
    for i in range(5):
        u = seeds[i]
        nbrs = set(g.neighbors(u).tolist())
        for pos, ok in zip(blk.hops[0][i], blk.hop_masks[0][i]):
            if ok:
                assert int(nodes[pos]) in nbrs


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(5, 60),
    deg=st.integers(1, 6),
    seed=st.integers(0, 10_000),
)
def test_ell_degree_invariant(n, deg, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=n * deg)
    dst = rng.integers(0, n, size=n * deg)
    g = CSRGraph.from_edges(src, dst, n)
    ell = csr_to_ell(g)
    assert int(np.asarray(ell.degrees()).sum()) == g.num_edges
    nbr = np.asarray(ell.nbr)
    msk = np.asarray(ell.nbr_mask)
    assert (nbr[msk] < n).all() and (nbr[~msk] == n).all()
