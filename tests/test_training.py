"""Training substrate: optimizer, microbatching equivalence, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.distributed.compression import (
    CompressionConfig, compress, init_residuals,
)
from repro.training import AdamWConfig, adamw_init, adamw_update, make_train_step


def _quad_loss(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _setup(seed=0, n=64, d=8):
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal((d, 1))
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = (x @ w_true).astype(np.float32)
    params = {"w": jnp.zeros((d, 1))}
    return params, {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def test_adamw_converges():
    params, batch = _setup()
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1, total_steps=500)
    init_s, step = make_train_step(_quad_loss, cfg)
    state = init_s(params)
    step = jax.jit(step)
    for _ in range(300):
        state, m = step(state, batch)
    assert float(m["loss"]) < 1e-2


def test_microbatch_grads_match_full_batch():
    params, batch = _setup(n=32)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    _, step1 = make_train_step(_quad_loss, cfg, n_microbatches=1)
    _, step4 = make_train_step(_quad_loss, cfg, n_microbatches=4)
    init_s, _ = make_train_step(_quad_loss, cfg)
    s1, _ = step1(init_s(params), batch)
    s4, _ = step4(init_s(params), batch)
    np.testing.assert_allclose(
        np.asarray(s1["params"]["w"]), np.asarray(s4["params"]["w"]), rtol=1e-5
    )


def test_grad_clip():
    params = {"w": jnp.zeros((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    cfg = AdamWConfig(grad_clip=1.0, lr=1.0, weight_decay=0.0)
    opt = adamw_init(params, cfg)
    _, _, metrics = adamw_update(grads, opt, params, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_bf16_state_dtype():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    cfg = AdamWConfig(state_dtype="bfloat16")
    opt = adamw_init(params, cfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16


# ------------------------------------------------------------ compression --
def test_int8_error_feedback_preserves_signal():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
    cfg = CompressionConfig(kind="int8")
    res = init_residuals({"g": g})
    total = jnp.zeros_like(g)
    for _ in range(20):
        comp, res = compress({"g": g}, res, cfg)
        total = total + comp["g"]
    # avg compressed grad ~= true grad (error feedback is unbiased long-run)
    np.testing.assert_allclose(np.asarray(total / 20), np.asarray(g), atol=0.05)


def test_topk_compression_sparsity():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((1000,)), jnp.float32)
    cfg = CompressionConfig(kind="topk", topk_frac=0.01)
    comp, res = compress({"g": g}, init_residuals({"g": g}), cfg)
    nz = int((comp["g"] != 0).sum())
    assert nz <= 20  # ~1% kept (ties allowed)
    # residual holds the dropped mass
    np.testing.assert_allclose(
        np.asarray(comp["g"] + res["g"]), np.asarray(g), atol=1e-6
    )


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), kind=st.sampled_from(["int8", "topk"]))
def test_compression_error_feedback_invariant(seed, kind):
    """compressed + residual_new == grad + residual_old (mass conservation)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((128,)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((128,)) * 0.1, jnp.float32)
    cfg = CompressionConfig(kind=kind, topk_frac=0.05)
    comp, res = compress({"g": g}, {"g": r}, cfg)
    np.testing.assert_allclose(
        np.asarray(comp["g"] + res["g"]), np.asarray(g + r), atol=1e-4
    )
