"""Checkpointing + fault tolerance: atomic saves, async, restore-reshard,
crash-restart, stragglers, heartbeats, elastic shard reassignment."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer, latest_step, restore_checkpoint, save_checkpoint,
)
from repro.distributed.fault import (
    Heartbeat, StragglerMonitor, elastic_shard_assignment, run_with_restart,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 5, (3,)), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    r, step = restore_checkpoint(str(tmp_path), t)
    assert step == 7
    np.testing.assert_allclose(np.asarray(r["a"]), np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(r["nested"]["b"]), np.asarray(t["nested"]["b"]))


def test_latest_step_and_overwrite(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 3, t)
    assert latest_step(str(tmp_path)) == 3
    save_checkpoint(str(tmp_path), 3, _tree(seed=1))  # overwrite is atomic
    r, _ = restore_checkpoint(str(tmp_path), t, step=3)
    np.testing.assert_allclose(np.asarray(r["a"]), np.asarray(_tree(seed=1)["a"]))


def test_no_tmp_dirs_left(tmp_path):
    save_checkpoint(str(tmp_path), 2, _tree())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_async_checkpointer_gc(tmp_path):
    ac = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        ac.save(s, _tree())
    ac.close()
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert steps == [30, 40]


def test_restore_onto_sharding(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    sh_tree = jax.tree.map(lambda _: sh, t)
    r, _ = restore_checkpoint(str(tmp_path), t, sharding_tree=sh_tree)
    assert r["a"].sharding == sh


def test_crash_restart_driver(tmp_path):
    """Simulated failure at step 17: training must resume from step 10."""
    calls = {"crashed": False}

    def step_fn(state, step):
        if step == 17 and not calls["crashed"]:
            calls["crashed"] = True
            raise RuntimeError("simulated node failure")
        return {"x": state["x"] + 1}

    def save_fn(state, step):
        save_checkpoint(str(tmp_path), step, state)

    def restore_fn():
        st = latest_step(str(tmp_path))
        state, _ = restore_checkpoint(str(tmp_path), {"x": jnp.zeros(())}, step=st)
        return state, st

    state, restarts = run_with_restart(
        step_fn, save_fn, restore_fn, {"x": jnp.zeros(())}, n_steps=25,
        checkpoint_every=10,
    )
    assert restarts == 1
    assert int(state["x"]) == 25  # every step effectively executed


def test_straggler_monitor():
    m = StragglerMonitor(threshold=2.0)
    for h in range(8):
        for _ in range(5):
            m.record(h, 1.0 if h != 3 else 3.5)
    assert m.stragglers() == [3]


def test_heartbeat_death_detection():
    hb = Heartbeat(max_missed=3, interval_s=1.0)
    hb.beat(0, now=100.0)
    hb.beat(1, now=100.0)
    hb.beat(1, now=105.0)
    assert hb.dead_hosts(now=105.0) == [0]


def test_elastic_reassignment_stability():
    """Rendezvous hashing: removing a host only moves that host's shards."""
    hosts = list(range(8))
    a1 = elastic_shard_assignment(64, hosts)
    a2 = elastic_shard_assignment(64, [h for h in hosts if h != 3])
    moved = [s for s in range(64) if a1[s] != a2[s]]
    assert all(a1[s] == 3 for s in moved)
    assert all(a2[s] != 3 for s in range(64))
