"""Serving engine: continuous batching, correctness vs offline generation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import TransformerConfig, model as tm
from repro.models.transformer.generate import generate_tokens
from repro.serving import Request, ServeEngine

CFG = TransformerConfig(
    name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
    d_ff=64, vocab=64, dtype="float32",
)


def test_engine_completes_all_requests():
    params = tm.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(params, CFG, slots=3, cache_len=48)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=u, prompt_ids=rng.integers(1, 64, size=int(rng.integers(3, 10))).astype(np.int32),
                max_new_tokens=5)
        for u in range(7)
    ]
    for r in reqs:
        eng.submit(r)
    done = eng.run_to_completion()
    assert len(done) == 7
    assert all(len(r.out_tokens) >= 5 for r in done)


def test_engine_matches_offline_greedy():
    """Tokens from the slot-based engine == offline greedy generation."""
    params = tm.init_params(jax.random.PRNGKey(0), CFG)
    prompt = np.asarray([5, 9, 3, 22, 41], np.int32)
    eng = ServeEngine(params, CFG, slots=2, cache_len=32)
    req = Request(uid=0, prompt_ids=prompt, max_new_tokens=8)
    eng.submit(req)
    done = eng.run_to_completion()
    offline = generate_tokens(
        params, jnp.asarray(prompt)[None], jnp.asarray([len(prompt)]),
        jax.random.PRNGKey(0), CFG, max_new=8, cache_len=32, temperature=0.0,
    )
    assert done[0].out_tokens[:8] == np.asarray(offline[0]).tolist()


def test_engine_interleaved_admission():
    """Requests submitted while others are in flight still complete."""
    params = tm.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(params, CFG, slots=2, cache_len=32)
    rng = np.random.default_rng(1)
    eng.submit(Request(uid=0, prompt_ids=rng.integers(1, 64, 4).astype(np.int32),
                       max_new_tokens=6))
    done = []
    for step in range(40):
        done.extend(eng.step())
        if step == 2:
            eng.submit(Request(uid=1, prompt_ids=rng.integers(1, 64, 5).astype(np.int32),
                               max_new_tokens=4))
        if len(done) == 2:
            break
    assert {r.uid for r in done} == {0, 1}
