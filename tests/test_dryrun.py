"""Dry-run machinery: mesh construction + one real cell compile (subprocess,
since the 512-device XLA flag must be set before jax initializes)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=420, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_production_mesh_shapes():
    out = _run(
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.mesh import make_production_mesh\n"
        "m = make_production_mesh()\n"
        "assert m.shape == {'data': 16, 'model': 16}, m.shape\n"
        "mp = make_production_mesh(multi_pod=True)\n"
        "assert mp.shape == {'pod': 2, 'data': 16, 'model': 16}, mp.shape\n"
        "print('MESH_OK')\n"
    )
    assert "MESH_OK" in out


@pytest.mark.parametrize("multi_pod", [False, True])
def test_dryrun_cheapest_cell_compiles(multi_pod):
    """wide-deep retrieval_cand: full lower+compile on both meshes."""
    out = _run(
        "from repro.launch.dryrun import run_cell\n"
        f"rec = run_cell('wide-deep', 'retrieval_cand', multi_pod={multi_pod},"
        " skip_analysis=True)\n"
        "import json; print('REC=' + json.dumps(rec['status']))\n"
    )
    assert 'REC="ok"' in out


def test_dryrun_skip_cells_raise():
    from repro import configs as C

    with pytest.raises(ValueError, match="documented skip"):
        C.input_specs("grok-1-314b", "long_500k")
