"""Async admission prefetch: sync/prefetch parity, overlap oracle, in-flight
dedup, admission tickets, and run_to_completion exhaustion semantics."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BruteIndex, GraphTokenizer, PipelineConfig, \
    RGLPipeline, Vocab
from repro.graph import csr_to_ell, generators
from repro.models.transformer import TransformerConfig, model as tm
from repro.serving import (
    DelayedRetrieval, RAGRequest, RAGServeEngine, Request, ServeEngine,
)

N_NODES = 120
MAX_LEN = 64
CACHE_LEN = 96
SLOTS = 3


@pytest.fixture(scope="module")
def stack():
    g = generators.citation_graph(N_NODES, avg_deg=6, seed=7)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=MAX_LEN, node_budget=6)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb, tokenizer=tok,
        node_text=g.node_text,
        config=PipelineConfig(strategy="bfs", k_seeds=3, max_hops=2,
                              max_nodes=16, filter_budget=8),
    )
    cfg = TransformerConfig(
        name="async-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    return g, pipe, cfg, params


def _stream(g):
    """Deterministic request stream: more requests than slots, exact repeats
    across waves (cache hits), duplicates inside one wave (dedup collisions),
    and mixed generation lengths (staggered slot turnover)."""
    q_ids = [0, 1, 2, 0, 3, 3, 4, 1, 5, 0]
    max_new = [4, 6, 4, 5, 4, 4, 6, 4, 4, 5]
    return [
        RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[qi]),
                   query_text=g.node_text[qi], max_new_tokens=mn)
        for u, (qi, mn) in enumerate(zip(q_ids, max_new))
    ]


def _run(g, pipe, cfg, params, **kw):
    eng = RAGServeEngine(pipe, params, cfg, slots=SLOTS, cache_len=CACHE_LEN,
                         **kw)
    for r in _stream(g):
        eng.submit(r)
    done = {r.uid: r for r in eng.run_to_completion()}
    assert len(done) == 10 and all(r.done for r in done.values())
    return eng, done


# ------------------------------------------------------------------ parity ----
@pytest.mark.parametrize("depth", [1, 2])
def test_sync_prefetch_parity(stack, depth):
    """The same request stream through sync and prefetched admission yields
    bitwise-identical per-request outputs and identical cache accounting.

    Output parity is unconditional.  Accounting parity is unconditional at
    depth=1; at depth>=2 it additionally requires no capacity pressure
    (ample cache here) — pipelined lookups reorder recency updates, so
    eviction victims may differ under pressure (see prefetch.py docstring).
    """
    g, pipe, cfg, params = stack
    sync_eng, sync_done = _run(g, pipe, cfg, params, prefetch=False)
    pf_eng, pf_done = _run(g, pipe, cfg, params, prefetch=True,
                           prefetch_depth=depth)

    for uid in sync_done:
        assert pf_done[uid].out_tokens == sync_done[uid].out_tokens
        np.testing.assert_array_equal(
            pf_done[uid].retrieved_nodes, sync_done[uid].retrieved_nodes
        )
        np.testing.assert_array_equal(
            pf_done[uid].prompt_ids, sync_done[uid].prompt_ids
        )
        assert pf_done[uid].cache_hit == sync_done[uid].cache_hit

    assert pf_eng.cache_hits == sync_eng.cache_hits
    assert pf_eng.cache_misses == sync_eng.cache_misses
    assert pf_eng.retrieval_batches == sync_eng.retrieval_batches
    assert pf_eng.retrieved_queries == sync_eng.retrieved_queries
    # the stream has 3 cross-wave repeats and 1 intra-wave duplicate
    assert (sync_eng.cache_hits, sync_eng.cache_misses) == (3, 7)
    assert sync_eng.retrieved_queries == 6  # dedup collapsed the dup pair

    s_sync, s_pf = sync_eng.stats(), pf_eng.stats()
    assert s_sync["prefetch_waves"] == 0 and s_sync["overlap_seconds"] == 0.0
    assert s_pf["prefetch_waves"] > 0
    assert s_pf["prefetch"] and not s_sync["prefetch"]


# ----------------------------------------------------------- overlap oracle ----
def test_overlap_oracle_decode_between_launch_and_collect(stack):
    """With an injected retrieval latency, decode steps demonstrably execute
    between a wave's launch and its collect, and the overlap telemetry sees
    the hidden window; the sync schedule reports exactly zero overlap."""
    g, pipe, cfg, params = stack
    cost = 0.05
    events = []
    delayed = DelayedRetrieval(pipe, cost_s=cost, events=events)
    eng = RAGServeEngine(delayed, params, cfg, slots=2, cache_len=CACHE_LEN,
                         prefetch=True)
    inner_step = eng.engine.step

    def step_logged():
        was_live = eng.engine.live.any()
        out = inner_step()
        if was_live:
            events.append(("decode", time.perf_counter()))
        return out

    eng.engine.step = step_logged
    for u in range(4):  # 2 waves of 2 distinct queries each
        eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[u]),
                              query_text=g.node_text[u], max_new_tokens=8))
    done = eng.run_to_completion()
    assert len(done) == 4

    s = eng.stats()
    assert s["prefetch_waves"] >= 1
    assert s["overlap_seconds"] > 0.0
    assert s["overlap_steps"] >= 1
    assert 0.0 < s["hidden_frac"] <= 1.0

    # event-order oracle: some decode step lies strictly between a wave's
    # launch (dispatch return) and its collect (first force)
    launches = [t for tag, t in events if tag == "launch"]
    forces = [t for tag, t in events if tag == "force"]
    decodes = [t for tag, t in events if tag == "decode"]
    assert len(launches) == len(forces) == 2
    assert any(
        any(lt < dt < ft for dt in decodes)
        for lt, ft in zip(launches, forces)
    )

    # sync schedule on the same delayed pipeline: zero overlap, and the full
    # injected latency shows up as blocking retrieval time
    sync = RAGServeEngine(delayed, params, cfg, slots=2, cache_len=CACHE_LEN,
                          prefetch=False)
    for u in range(4):
        sync.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[u]),
                               query_text=g.node_text[u], max_new_tokens=8))
    sync.run_to_completion()
    ss = sync.stats()
    assert ss["overlap_seconds"] == 0.0 and ss["prefetch_waves"] == 0
    assert ss["retrieval_seconds"] >= 2 * cost * 0.9


def test_inflight_key_not_redispatched(stack):
    """A query whose key is retrieved-but-not-yet-collected defers to the
    in-flight wave instead of dispatching a second retrieval (depth=2 keeps
    two waves in flight, so the launch of wave 1 sees wave 0's keys)."""
    g, pipe, cfg, params = stack
    delayed = DelayedRetrieval(pipe, cost_s=0.02)
    eng = RAGServeEngine(delayed, params, cfg, slots=2, cache_len=CACHE_LEN,
                         prefetch=True, prefetch_depth=2)
    qis = [0, 1, 0, 2]  # wave0 = {0, 1}; wave1 = {0 (in flight), 2}
    for u, qi in enumerate(qis):
        eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[qi]),
                              query_text=g.node_text[qi], max_new_tokens=4))
    done = {r.uid: r for r in eng.run_to_completion()}
    assert len(done) == 4
    assert delayed.dispatches == 2  # wave1 dispatched only query 2
    assert eng.retrieved_queries == 3
    assert (eng.cache_hits, eng.cache_misses) == (1, 3)
    assert done[2].cache_hit and not done[0].cache_hit
    assert done[2].out_tokens == done[0].out_tokens
    np.testing.assert_array_equal(done[2].retrieved_nodes,
                                  done[0].retrieved_nodes)
    assert eng.cache.inflight_count == 0  # all keys released at collect


def test_deferred_fallback_when_owner_entry_evicted(stack):
    """If the owner wave's cache entry is evicted between its collect and
    the deferring wave's collect (tiny capacity), the deferred request is
    still served the owner's result — counted as a miss, exactly as the
    sync schedule would count it — and the entry is re-inserted as sync's
    re-retrieval would have done.  Only the dispatch count differs (one
    fewer: retrieval is deterministic so re-dispatching is pure waste)."""
    g, pipe, cfg, params = stack
    # capacity=1: wave0 retrieves {A=0, B=1}; put(B) evicts A before the
    # deferring wave collects
    qis = [0, 1, 0, 2]  # wave0 = {A, B}; wave1 = {A (in flight), C}

    def run(prefetch):
        eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                             cache_capacity=1, prefetch=prefetch,
                             prefetch_depth=2)
        for u, qi in enumerate(qis):
            eng.submit(RAGRequest(uid=u,
                                  query_emb=np.asarray(g.node_feat[qi]),
                                  query_text=g.node_text[qi],
                                  max_new_tokens=4))
        return eng, {r.uid: r for r in eng.run_to_completion()}

    sync_eng, sync_done = run(False)
    pf_eng, pf_done = run(True)
    for uid in sync_done:
        assert pf_done[uid].out_tokens == sync_done[uid].out_tokens
        np.testing.assert_array_equal(pf_done[uid].retrieved_nodes,
                                      sync_done[uid].retrieved_nodes)
        assert pf_done[uid].cache_hit == sync_done[uid].cache_hit
    assert pf_eng.cache_hits == sync_eng.cache_hits == 0
    assert pf_eng.cache_misses == sync_eng.cache_misses == 4
    assert not pf_done[2].cache_hit  # served, but honestly not a cache hit
    # sync re-dispatched the evicted key; prefetch served the in-flight copy
    assert sync_eng.retrieved_queries == 4
    assert pf_eng.retrieved_queries == 3
    assert pf_eng.cache.inflight_count == 0


def test_stale_inflight_marker_from_shared_cache_redispatches(stack):
    """An in-flight marker with no owning wave in this engine (a shared
    cache carrying a dead engine's leftover, or another engine's wave) must
    fall through to a normal re-dispatch, not defer to a result that will
    never arrive."""
    from repro.serving import RetrievalCache

    g, pipe, cfg, params = stack
    cache = RetrievalCache(capacity=8)
    cache.mark_inflight(cache.key(np.asarray(g.node_feat[0])))
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                         retrieval_cache=cache, prefetch=True)
    eng.submit(RAGRequest(uid=0, query_emb=np.asarray(g.node_feat[0]),
                          query_text=g.node_text[0], max_new_tokens=4))
    done = eng.run_to_completion()
    assert len(done) == 1 and done[0].retrieved_nodes is not None
    assert eng.retrieved_queries == 1  # re-dispatched despite the marker


def test_inflight_keys_released_on_retrieval_failure(stack):
    """A retrieval that fails at force time is *contained*: the engine
    completes (degraded-mode admission decodes a query-only prompt instead
    of raising out of step()), and the failed wave's keys leave the cache's
    in-flight set so later launches re-dispatch, not defer to a dead wave."""
    g, pipe, cfg, params = stack

    class BoomArray:
        def __array__(self, dtype=None, copy=None):
            raise RuntimeError("device boom")

    class BoomSub:
        nodes, mask, dist = BoomArray(), BoomArray(), BoomArray()

    class BoomPipe:
        def __init__(self, inner):
            self.inner = inner

        def __getattr__(self, name):
            return getattr(self.inner, name)

        def retrieve_many(self, q, *, batch_size=None, encoder=None):
            from repro.core.pipeline import RetrievalResult
            return RetrievalResult(sub=BoomSub(), seeds=BoomArray(),
                                   n_valid=int(q.shape[0]))

    eng = RAGServeEngine(BoomPipe(pipe), params, cfg, slots=2,
                         cache_len=CACHE_LEN, prefetch=True)
    eng.submit(RAGRequest(uid=0, query_emb=np.asarray(g.node_feat[0]),
                          query_text=g.node_text[0], max_new_tokens=2))
    done = eng.run_to_completion()
    assert len(done) == 1 and done[0].done and done[0].degraded
    assert not done[0].failed and len(done[0].out_tokens) == 2
    assert eng.cache.inflight_count == 0  # released despite the failure
    assert eng.stats()["retrieval_failures"] == 1

    # a dispatch-time failure marks nothing in the first place; with the
    # degraded rung disabled the request fails closed — alone, not the engine
    class BoomDispatch(BoomPipe):
        def retrieve_many(self, q, **kw):
            raise RuntimeError("dispatch boom")

    eng2 = RAGServeEngine(BoomDispatch(pipe), params, cfg, slots=2,
                          cache_len=CACHE_LEN, prefetch=True,
                          degraded_mode=False)
    eng2.submit(RAGRequest(uid=1, query_emb=np.asarray(g.node_feat[1]),
                           query_text=g.node_text[1], max_new_tokens=2))
    done2 = eng2.run_to_completion()
    assert len(done2) == 1 and done2[0].failed and not done2[0].done
    assert "dispatch boom" in done2[0].error
    assert eng2.cache.inflight_count == 0


# -------------------------------------------------------- admission tickets ----
def test_admission_tickets_survive_request_churn(stack):
    """Many short-lived requests through few slots: every completion maps
    back to the right RAGRequest via its monotonic ticket (id()-keyed
    mapping could silently cross-wire recycled objects)."""
    g, pipe, cfg, params = stack
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN)
    n, seen = 30, {}
    for u in range(n):
        qi = u % 5
        eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[qi]),
                              query_text=g.node_text[qi], max_new_tokens=2))
        if u % 3 == 2:  # churn: drain between small submission bursts
            for r in eng.run_to_completion():
                seen[r.uid] = r
    for r in eng.run_to_completion():
        seen[r.uid] = r
    assert set(seen) == set(range(n))
    assert eng._next_ticket == n  # one fresh ticket per admission, no reuse
    assert not eng._inflight
    # identical queries must have produced identical outputs, churn or not
    for u in range(5, n):
        assert seen[u].out_tokens == seen[u % 5].out_tokens


def test_inner_requests_carry_distinct_tickets(stack):
    g, pipe, cfg, params = stack
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN)
    for u in range(4):
        eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[u]),
                              query_text=g.node_text[u], max_new_tokens=6))
    eng.step()  # admits the first wave; nothing finishes yet
    tickets = [q.ticket for q in list(eng.engine.queue)] + \
        [q.ticket for q in eng.engine.active if q is not None]
    assert len(tickets) == len(set(tickets)) == 2
    assert all(t >= 0 for t in tickets)
    eng.run_to_completion()


# ------------------------------------------------- run_to_completion limits ----
def test_serve_engine_run_to_completion_raises_on_exhaustion():
    cfg = TransformerConfig(
        name="tiny", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=64, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, slots=1, cache_len=48)
    eng.submit(Request(uid=0, prompt_ids=np.asarray([3, 5], np.int32),
                       max_new_tokens=30))
    with pytest.raises(RuntimeError, match="still pending"):
        eng.run_to_completion(max_steps=3)
    done = eng.run_to_completion()  # clean drain picks up where it stopped
    assert [r.uid for r in done] == [0]
    assert not eng.queue and not eng.live.any()
    assert eng.run_to_completion() == []  # empty engine drains immediately


def test_rag_engine_run_to_completion_raises_on_exhaustion(stack):
    g, pipe, cfg, params = stack
    for prefetch in (False, True):
        eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                             prefetch=prefetch)
        for u in range(3):
            eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[u]),
                                  query_text=g.node_text[u],
                                  max_new_tokens=20))
        with pytest.raises(RuntimeError, match="still pending"):
            eng.run_to_completion(max_steps=2)
        done = eng.run_to_completion()
        assert {r.uid for r in done} == {0, 1, 2}
        assert eng._drained()


# ----------------------------------------------------------- configuration ----
def test_prefetch_env_default_and_override(stack, monkeypatch):
    g, pipe, cfg, params = stack

    def make(**kw):
        return RAGServeEngine(pipe, params, cfg, slots=2,
                              cache_len=CACHE_LEN, **kw)

    monkeypatch.delenv("RGL_PREFETCH", raising=False)
    assert not make().prefetch
    monkeypatch.setenv("RGL_PREFETCH", "1")
    assert make().prefetch
    assert not make(prefetch=False).prefetch  # explicit beats env
    monkeypatch.setenv("RGL_PREFETCH", "0")
    assert not make().prefetch
    assert make(prefetch=True).prefetch
    with pytest.raises(ValueError, match="depth"):
        make(prefetch=True, prefetch_depth=0)


def test_free_slots_backpressure_signal():
    cfg = TransformerConfig(
        name="tiny", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=64, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, slots=2, cache_len=48)
    assert eng.free_slots == 2
    eng.submit(Request(uid=0, prompt_ids=np.asarray([3], np.int32),
                       max_new_tokens=4))
    assert eng.free_slots == 1  # queued work claims a future slot
    eng.step()
    assert eng.free_slots == 1  # admitted: one live slot, empty queue
    eng.run_to_completion()
    assert eng.free_slots == 2
