"""Tests for the benchmark envelope checker (benchmarks/check_envelopes.py)
plus the tier-1 guard that the committed envelopes actually pass against the
committed BENCH_*.json artifacts — so an envelope edit that would fail the
nightly job is caught on the PR that makes it."""
import json
import os

import pytest

from benchmarks.check_envelopes import check_all, check_report, resolve

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------- resolve

def test_resolve_dotted_and_indexed_paths():
    doc = {
        "a": {"b": 1.5},
        "results": [{"speedup": 2.0}, {"speedup": 3.0, "deep": {"x": 7}}],
    }
    assert resolve(doc, "a.b") == 1.5
    assert resolve(doc, "results[0].speedup") == 2.0
    assert resolve(doc, "results[1].deep.x") == 7


def test_resolve_failures_are_loud():
    doc = {"a": {"b": 1}, "xs": [1, 2]}
    with pytest.raises(KeyError):
        resolve(doc, "a.nope")
    with pytest.raises(IndexError):
        resolve(doc, "xs[5]")
    with pytest.raises(TypeError):
        resolve(doc, "a[0]")  # [i] into a dict


# ----------------------------------------------------------- check_report

def test_check_report_min_max_and_clean():
    report = {"ratio": 1.7, "counts": {"stranded": 0, "completed": 24}}
    rules = [
        {"path": "ratio", "min": 1.5},
        {"path": "counts.stranded", "max": 0},
        {"path": "counts.completed", "min": 24, "max": 24},
    ]
    assert check_report(report, rules) == []

    bad = check_report(report, [{"path": "ratio", "min": 2.0}])
    assert len(bad) == 1 and "< min 2" in bad[0]

    bad = check_report(report, [{"path": "counts.completed", "max": 20}])
    assert len(bad) == 1 and "> max 20" in bad[0]


def test_check_report_flags_bad_rules_not_silently_passes():
    report = {"ok": 1, "name": "hi", "flag": True}
    # unresolvable path, non-numeric value, rule with no bounds: all of
    # these are envelope-authoring mistakes and must FAIL, not skip
    bad = check_report(report, [
        {"path": "missing.key", "min": 0},
        {"path": "name", "min": 0},
        {"path": "flag", "min": 0},
        {"path": "ok"},
    ])
    assert len(bad) == 4
    assert any("unresolvable" in v for v in bad)
    assert any("not a number" in v for v in bad)
    assert any("neither min nor max" in v for v in bad)


# -------------------------------------------------------------- check_all

def test_check_all_missing_artifact(tmp_path):
    env = {"_comment": "ignored",
           "BENCH_gone.json": [{"path": "x", "min": 0}]}
    violations, checked, missing = check_all(env, str(tmp_path))
    assert missing == ["BENCH_gone.json"]
    assert checked == []
    assert len(violations) == 1 and "missing" in violations[0]

    violations, _, missing = check_all(env, str(tmp_path),
                                       allow_missing=True)
    assert violations == [] and missing == ["BENCH_gone.json"]


def test_check_all_reads_and_labels(tmp_path):
    (tmp_path / "BENCH_x.json").write_text(json.dumps({"v": 0.5}))
    env = {"BENCH_x.json": [{"path": "v", "min": 0.9}]}
    violations, checked, _ = check_all(env, str(tmp_path))
    assert checked == ["BENCH_x.json"]
    assert len(violations) == 1
    assert violations[0].startswith("BENCH_x.json: v = 0.5")


# ------------------------------------------- committed artifacts vs rules

def test_committed_envelopes_pass_against_committed_artifacts():
    """The repo's own full-run BENCH_*.json artifacts must satisfy the
    committed envelopes — the same check the nightly job runs on fresh
    artifacts.  Keeps envelopes.json honest: a bound nobody could meet,
    or a typo'd path, fails here on the PR that introduced it."""
    with open(os.path.join(REPO, "benchmarks", "envelopes.json")) as f:
        env = json.load(f)
    violations, checked, missing = check_all(env, REPO)
    assert not missing, f"envelope names missing artifacts: {missing}"
    assert checked, "no artifacts checked — envelopes.json empty?"
    assert not violations, "committed artifacts violate committed " \
        f"envelopes:\n" + "\n".join(violations)
