"""Pallas kernel paths vs their pure-jnp ref.py oracles (interpret, CPU-safe).

Randomized small-input parity for the three retrieval-path kernels the RGL
pipeline leans on: topk_sim (indexing), ell_spmm (subgraph aggregation), and
bfs_frontier (graph retrieval).  ``use_kernel=True`` forces the Pallas path,
which runs in interpret mode off-TPU, so these assert the kernel's logic —
padding, sentinels, masking, cross-block merges — not just the jnp fallback.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bfs_frontier import ops as bops, ref as bref
from repro.kernels.ell_spmm import ops as eops, ref as eref
from repro.kernels.frontier_expand import ops as fops, ref as fref
from repro.kernels.topk_sim import ops as tops, ref as tref


@pytest.mark.parametrize("trial", range(3))
def test_topk_sim_kernel_parity(rng, trial):
    q = int(rng.integers(1, 12))
    n = int(rng.integers(1500, 3500))
    d = int(rng.integers(16, 160))
    k = int(rng.integers(1, 24))
    qv = jnp.asarray(rng.standard_normal((q, d)), jnp.float32)
    ev = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    s_k, i_k = tops.topk_similarity(qv, ev, k, use_kernel=True)
    s_r, i_r = tref.topk_similarity(qv, ev, k)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))


@pytest.mark.parametrize("trial", range(3))
def test_ell_spmm_kernel_parity(rng, trial):
    q = int(rng.integers(1, 5))
    m = int(rng.integers(20, 200))
    k = int(rng.integers(2, 12))
    d = int(rng.integers(8, 96))
    feat = jnp.asarray(rng.standard_normal((q, m, d)), jnp.float32)
    nbr = jnp.asarray(rng.integers(0, m + 1, (q, m, k)), jnp.int32)  # m = sentinel
    msk = jnp.asarray(rng.random((q, m, k)) < 0.6)
    o_k = eops.ell_aggregate(feat, nbr, msk, use_kernel=True)
    o_r = eref.ell_aggregate(feat, nbr, msk)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("trial", range(3))
def test_bfs_frontier_kernel_parity(rng, trial):
    n = int(rng.integers(300, 1200))
    k = int(rng.integers(2, 14))
    q = int(rng.integers(1, 5))
    nbr = jnp.asarray(rng.integers(0, n + 1, (n, k)), jnp.int32)  # n = sentinel
    msk = jnp.asarray(rng.random((n, k)) < 0.7)
    fr = jnp.asarray(rng.random((q, n)) < 0.03)
    r_k = bops.frontier_hop(fr, nbr, msk, use_kernel=True)
    r_r = bref.frontier_hop(fr, nbr, msk)
    np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_r))


def _sorted_workset(rng, q, c, n):
    """Random sorted-ascending workset rows with sentinel padding."""
    ws = np.full((q, c), n, np.int32)
    for qi in range(q):
        fill = int(rng.integers(1, c + 1))
        ws[qi, :fill] = np.sort(
            rng.choice(n, size=min(fill, n), replace=False)
        )[:fill]
    return ws


@pytest.mark.parametrize("trial", range(3))
def test_ws_member_kernel_parity(rng, trial):
    """Pallas binary-search mark (interpret mode) vs the searchsorted ref."""
    q = int(rng.integers(1, 5))
    c = int(rng.integers(16, 300))
    n = int(rng.integers(c, 4000))
    w = int(rng.integers(10, 5000))
    ws = jnp.asarray(_sorted_workset(rng, q, c, n))
    cand = jnp.asarray(rng.integers(0, n + 1, (q, w)), jnp.int32)
    m_k = fops.ws_member(ws, cand, use_kernel=True)
    m_r = fref.ws_member(ws, cand)
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))


@pytest.mark.parametrize("trial", range(3))
def test_expand_hop_kernel_vs_ref_arm(rng, trial):
    """The kernel-marked and pure-sort hop expansions are bit-identical."""
    n = int(rng.integers(100, 800))
    k = int(rng.integers(2, 10))
    q = int(rng.integers(1, 4))
    c = int(rng.integers(8, 64))
    nbr = jnp.asarray(rng.integers(0, n + 1, (n, k)), jnp.int32)
    msk = jnp.asarray(rng.random((n, k)) < 0.7)
    ws = _sorted_workset(rng, q, c, n)
    dist = np.where(
        ws < n, rng.integers(0, 3, (q, c)), int(fops.INF)
    ).astype(np.int32)
    args = (jnp.asarray(ws), jnp.asarray(dist), nbr, msk, 3)
    out_r = fops.expand_hop(*args, band=6, use_kernel=False)
    out_k = fops.expand_hop(*args, band=6, use_kernel=True)
    for a, b, name in zip(out_r, out_k, ("ids", "dist", "fresh", "dropped")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_frontier_empty_and_full(rng):
    """Degenerate frontiers survive the kernel's padding/sentinel plumbing."""
    n, k = 512, 6
    nbr = jnp.asarray(rng.integers(0, n + 1, (n, k)), jnp.int32)
    msk = jnp.asarray(rng.random((n, k)) < 0.7)
    for fr in (jnp.zeros((2, n), bool), jnp.ones((2, n), bool)):
        r_k = bops.frontier_hop(fr, nbr, msk, use_kernel=True)
        r_r = bref.frontier_hop(fr, nbr, msk)
        np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_r))
