"""End-to-end behaviour tests for the RGL pipeline (paper Fig. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BruteIndex, ExtractiveGenerator, GraphTokenizer, PipelineConfig,
    RGLPipeline, Vocab,
)
from repro.core.rouge import rouge, rouge_corpus
from repro.graph import csr_to_ell, generators


@pytest.fixture(scope="module")
def pipeline():
    g = generators.citation_graph(300, seed=5)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=256, node_budget=16)
    gen = ExtractiveGenerator(vocab)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb, tokenizer=tok,
        generator=gen, node_text=g.node_text,
        config=PipelineConfig(strategy="bfs", k_seeds=4, max_nodes=32,
                              filter_budget=16),
    )
    return g, pipe


@pytest.mark.parametrize("strategy", ["bfs", "dense", "steiner"])
def test_pipeline_all_strategies(pipeline, strategy):
    import dataclasses

    g, pipe = pipeline
    pipe = dataclasses.replace(
        pipe, config=dataclasses.replace(pipe.config, strategy=strategy)
    )
    qe = jnp.asarray(g.node_feat[:4]) + 0.05
    out = pipe.run(qe, [g.node_text[i] for i in range(4)])
    assert out["prompt_ids"].shape == (4, 256)
    assert len(out["outputs"]) == 4
    assert all(isinstance(o, str) and o for o in out["outputs"])
    # retrieval must surface the query node itself (it's in the index)
    for qi in range(4):
        assert qi in out["seeds"][qi]


def test_pipeline_self_retrieval_rouge(pipeline):
    """Retrieval-augmented extraction of a node's own neighborhood should
    beat a random-context baseline on ROUGE (paper Table 2's mechanism)."""
    g, pipe = pipeline
    idx = list(range(8))
    qe = jnp.asarray(g.node_feat[idx])
    refs = [g.node_text[i] for i in idx]
    out = pipe.run(qe, refs)
    scores_rag = rouge_corpus(out["outputs"], refs)
    rng = np.random.default_rng(0)
    rand_ctx = [g.node_text[int(rng.integers(0, 300))] for _ in idx]
    scores_rand = rouge_corpus(rand_ctx, refs)
    assert scores_rag["rouge1"] > scores_rand["rouge1"]


def test_rouge_metric_sanity():
    r = rouge("the cat sat on the mat", "the cat sat on the mat")
    assert r["rouge1"] == pytest.approx(1.0) and r["rougeL"] == pytest.approx(1.0)
    r2 = rouge("completely different words here", "the cat sat on the mat")
    assert r2["rouge1"] == 0.0
    r3 = rouge("the cat sat", "the cat sat on the mat")
    assert 0 < r3["rouge1"] < 1 and 0 < r3["rougeL"] < 1


def test_lm_generator_in_pipeline(pipeline):
    """Full stage-5 with the in-repo LM backend (tiny model, greedy)."""
    import dataclasses

    from repro.core.generation import make_lm_generator
    from repro.models.transformer import TransformerConfig, model as tm

    g, pipe = pipeline
    cfg = TransformerConfig(
        name="gen", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
        d_ff=64, vocab=pipe.tokenizer.vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    gen = make_lm_generator(params, cfg, pipe.tokenizer.vocab, cache_len=300)
    pipe = dataclasses.replace(pipe, generator=gen)
    qe = jnp.asarray(g.node_feat[:2])
    out = pipe.run(qe, [g.node_text[0], g.node_text[1]], max_new_tokens=8)
    assert len(out["outputs"]) == 2


def test_rag_token_stream():
    from repro.data import rag_token_stream

    g = generators.citation_graph(200, seed=8)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb,
        tokenizer=GraphTokenizer(vocab, max_len=128, node_budget=8),
        node_text=g.node_text,
        config=PipelineConfig(k_seeds=2, max_nodes=16, filter_budget=8),
    )
    it = rag_token_stream(
        pipe, g.node_text, np.asarray(g.node_feat), g.node_text,
        batch=4, max_len=128,
    )
    b = next(it)
    assert b["tokens"].shape == (4, 128)
    assert b["loss_mask"].any()  # loss covers the target continuation
    assert (b["tokens"][~b["loss_mask"] & (b["tokens"] > 0)] >= 0).all()
