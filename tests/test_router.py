"""Multi-replica serving: health-aware routing, circuit breaking, crash
failover with bitwise parity, front-door shedding, the shared retrieval
tier's cross-replica single flight, and the replica-level chaos soak."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import BruteIndex, GraphTokenizer, PipelineConfig, \
    RGLPipeline, Vocab
from repro.graph import csr_to_ell, generators
from repro.models.transformer import TransformerConfig, model as tm
from repro.serving import (
    DelayedRetrieval, FaultyReplica, FaultyRetrieval, RAGRequest,
    RAGServeEngine, ReplicaFault, ReplicaRouter, RetrievalCache,
)

N_NODES = 120
CACHE_LEN = 96
SLOTS = 3


@pytest.fixture(scope="module")
def stack():
    g = generators.citation_graph(N_NODES, avg_deg=6, seed=7)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=64, node_budget=6)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb, tokenizer=tok,
        node_text=g.node_text,
        config=PipelineConfig(strategy="bfs", k_seeds=3, max_hops=2,
                              max_nodes=16, filter_budget=8),
    )
    cfg = TransformerConfig(
        name="fault-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    return g, pipe, cfg, params


def _req(g, qi, uid=0, max_new=4, **kw):
    return RAGRequest(uid=uid, query_emb=np.asarray(g.node_feat[qi]),
                      query_text=g.node_text[qi], max_new_tokens=max_new,
                      **kw)


def _engine(pipe, params, cfg, **kw):
    kw.setdefault("slots", SLOTS)
    kw.setdefault("cache_len", CACHE_LEN)
    kw.setdefault("max_pending", 0)
    kw.setdefault("max_retries", 1)
    kw.setdefault("retrieval_timeout_s", 1.0)
    return RAGServeEngine(pipe, params, cfg, **kw)


def _fleet(pipe, params, cfg, n, cache=None, **kw):
    cache = cache if cache is not None else RetrievalCache(capacity=256)
    return [_engine(pipe, params, cfg, retrieval_cache=cache, **kw)
            for _ in range(n)], cache


def _reference(pipe, params, cfg, reqs):
    """Single clean engine, own cache: the parity oracle."""
    eng = _engine(pipe, params, cfg)
    for r in reqs:
        eng.submit(r)
    return {r.uid: r for r in eng.run_to_completion()}


def _assert_fleet_clean(router, cache):
    """Zero leaked state in any layer of any replica after the fleet
    settles — including crashed (aborted) replicas."""
    assert cache.inflight_count == 0
    assert not router.pending and not router._terminal
    for st in router.replicas:
        eng = st.engine
        if isinstance(eng, FaultyReplica):
            eng = eng.engine  # unwrap to the RAGServeEngine
        assert not st.assigned
        assert eng.prefetcher.in_flight == 0
        assert not eng._inflight and not eng._terminal
        assert not eng.engine.queue and not eng.engine.live.any()
        inner = eng.engine
        if inner.paged_kv:
            # prefill pins (prefix sharing) may hold blocks by design
            assert inner._free_host == \
                inner.pool_blocks - inner.kv_pinned_blocks
            assert int(inner._ntab.sum()) == 0


# ------------------------------------------------------------- validation ----
def test_router_and_faulty_replica_validation(stack):
    g, pipe, cfg, params = stack
    eng = _engine(pipe, params, cfg)
    with pytest.raises(ValueError, match="at least one replica"):
        ReplicaRouter([])
    with pytest.raises(ValueError, match="shed_policy"):
        ReplicaRouter([eng], shed_policy="drop-newest")
    with pytest.raises(ValueError, match="max_pending"):
        ReplicaRouter([eng], max_pending=-1)
    with pytest.raises(ValueError, match="trip_threshold"):
        ReplicaRouter([eng], trip_threshold=0)
    with pytest.raises(ValueError, match="mode"):
        FaultyReplica(eng, mode="gremlin")
    with pytest.raises(ValueError, match="heal_step"):
        FaultyReplica(eng, mode="flap", crash_step=3, heal_step=2)
    with pytest.raises(ValueError, match="heal_step"):
        FaultyReplica(eng, mode="crash", heal_step=5)
    # malformed requests are refused at the router's front door
    router = ReplicaRouter([eng])
    bad = np.asarray(g.node_feat[0]).copy()
    bad[0] = np.nan
    with pytest.raises(ValueError, match="NaN"):
        router.submit(RAGRequest(uid=0, query_emb=bad, query_text="q"))
    assert not router.pending


def test_faulty_replica_modes(stack):
    g, pipe, cfg, params = stack
    eng = _engine(pipe, params, cfg)
    crash = FaultyReplica(eng, mode="crash", crash_step=1)
    assert crash.slots == SLOTS  # delegation
    crash.step()  # step 0: healthy
    with pytest.raises(ReplicaFault, match="crash fault at replica step 1"):
        crash.step()
    with pytest.raises(ReplicaFault):
        crash.step()  # crash is permanent
    assert crash.steps == 3 and crash.faults_injected == 2

    flap = FaultyReplica(eng, mode="flap", crash_step=0, heal_step=2)
    for _ in range(2):
        with pytest.raises(ReplicaFault):
            flap.step()
    flap.step()  # healed
    assert flap.faults_injected == 2

    clock = [0.0]
    grey = FaultyReplica(eng, mode="grey", slow_s=0.5,
                         sleep_fn=lambda s: clock.__setitem__(0, clock[0] + s))
    grey.step()
    assert clock[0] == 0.5 and grey.faults_injected == 0


# ------------------------------------------------------- routing & parity ----
def test_load_balanced_routing_matches_single_replica_bitwise(stack):
    """A healthy 3-replica fleet spreads load and produces outputs bitwise
    identical to one clean engine serving the same stream."""
    g, pipe, cfg, params = stack
    n = 9
    ref = _reference(pipe, params, cfg, [_req(g, u % 6, uid=u)
                                         for u in range(n)])
    replicas, cache = _fleet(pipe, params, cfg, 3)
    router = ReplicaRouter(replicas)
    for u in range(n):
        router.submit(_req(g, u % 6, uid=u))
    done = {r.uid: r for r in router.run_to_completion()}
    assert set(done) == set(range(n))
    for u in range(n):
        assert done[u].done and not done[u].failed
        assert done[u].out_tokens == ref[u].out_tokens
        np.testing.assert_array_equal(done[u].retrieved_nodes,
                                      ref[u].retrieved_nodes)
    s = router.stats()
    assert s["duplicate_deliveries"] == 0 and s["failovers"] == 0
    # least-loaded + round-robin: every replica served some of the stream
    assert all(r["dispatched"] > 0 for r in s["per_replica"])
    _assert_fleet_clean(router, cache)


def test_crash_failover_redispatches_bitwise(stack):
    """One replica crashes mid-run: its in-flight requests are re-dispatched
    onto survivors and complete bitwise identical to a clean single-replica
    run — replica failure is survived, not surfaced."""
    g, pipe, cfg, params = stack
    n = 9
    # max_new long enough that the crashed replica still holds in-flight
    # work at crash_step even when spec decode commits multiple tokens/step
    ref = _reference(pipe, params, cfg, [_req(g, u % 6, uid=u, max_new=12)
                                         for u in range(n)])
    replicas, cache = _fleet(pipe, params, cfg, 3)
    replicas[1] = FaultyReplica(replicas[1], mode="crash", crash_step=2)
    router = ReplicaRouter(replicas, cooldown_steps=50)
    for u in range(n):
        router.submit(_req(g, u % 6, uid=u, max_new=12))
    done = {r.uid: r for r in router.run_to_completion()}
    assert set(done) == set(range(n))  # exactly-once, fleet-wide
    for u in range(n):
        assert done[u].done and not done[u].failed, done[u].error
        assert done[u].out_tokens == ref[u].out_tokens
        np.testing.assert_array_equal(done[u].retrieved_nodes,
                                      ref[u].retrieved_nodes)
    s = router.stats()
    assert s["failovers"] == 1 and s["redispatched"] > 0
    assert s["stranded"] == 0 and s["duplicate_deliveries"] == 0
    assert s["per_replica"][1]["circuit"] == "crashed"
    assert s["per_replica"][1]["crashes"] == 1
    _assert_fleet_clean(router, cache)


def test_naive_router_strands_crashed_replicas_requests(stack):
    """failover=False is the baseline the tentpole beats: the crashed
    replica's requests are delivered failed instead of re-dispatched."""
    g, pipe, cfg, params = stack
    n = 9
    replicas, cache = _fleet(pipe, params, cfg, 3)
    replicas[1] = FaultyReplica(replicas[1], mode="crash", crash_step=2)
    router = ReplicaRouter(replicas, failover=False, cooldown_steps=50)
    for u in range(n):
        router.submit(_req(g, u % 6, uid=u, max_new=12))
    done = {r.uid: r for r in router.run_to_completion()}
    assert set(done) == set(range(n))  # still exactly-once
    stranded = [r for r in done.values() if r.failed]
    served = [r for r in done.values() if r.done]
    assert stranded and len(stranded) == router.stats()["stranded"]
    assert all("crashed" in r.error for r in stranded)
    assert len(served) + len(stranded) == n
    assert router.stats()["redispatched"] == 0
    _assert_fleet_clean(router, cache)


def test_flapping_replica_heals_and_rejoins_through_half_open(stack):
    """A flapping replica crashes, is probed back to life, serves a clean
    half-open probe, and re-closes its circuit into full rotation."""
    g, pipe, cfg, params = stack
    replicas, cache = _fleet(pipe, params, cfg, 2)
    replicas[1] = FaultyReplica(replicas[1], mode="flap", crash_step=1,
                                heal_step=4)
    router = ReplicaRouter(replicas, cooldown_steps=2)
    for u in range(6):
        router.submit(_req(g, u % 4, uid=u))
    done = {r.uid: r for r in router.run_to_completion()}
    assert set(done) == set(range(6))
    assert all(r.done for r in done.values())
    assert router.stats()["failovers"] == 1

    # second workload: the healed replica must be back in rotation
    for u in range(10, 18):
        router.submit(_req(g, u % 4, uid=u))
    done2 = {r.uid: r for r in router.run_to_completion()}
    assert set(done2) == set(range(10, 18))
    assert all(r.done for r in done2.values())
    s = router.stats()
    assert s["per_replica"][1]["circuit"] == "closed"  # healed + probe passed
    assert s["per_replica"][1]["delivered"] > 0
    assert s["duplicate_deliveries"] == 0
    _assert_fleet_clean(router, cache)


def test_grey_replica_trips_circuit_and_traffic_routes_around(stack):
    """A degraded-but-alive replica (fault counters climbing) trips its
    breaker; later traffic goes to healthy replicas only."""
    g, pipe, cfg, params = stack
    cache = RetrievalCache(capacity=256)
    healthy = _engine(pipe, params, cfg, retrieval_cache=cache)
    sick_pipe = FaultyRetrieval(pipe, seed=0, fault_rate=1.0,
                                fault_types=("dispatch",))
    sick = _engine(sick_pipe, params, cfg, retrieval_cache=cache,
                   max_retries=0, degraded_mode=True)
    grey = FaultyReplica(sick, mode="grey", slow_s=0.0)
    router = ReplicaRouter([healthy, grey], trip_threshold=2,
                           cooldown_steps=500)  # stays open once tripped
    for u in range(4):
        router.submit(_req(g, u, uid=u))
    done = {r.uid: r for r in router.run_to_completion()}
    assert len(done) == 4 and all(r.done for r in done.values())
    s = router.stats()
    assert s["per_replica"][1]["circuit"] == "open"
    assert s["per_replica"][1]["trips"] == 1
    first_wave_on_grey = s["per_replica"][1]["dispatched"]
    assert first_wave_on_grey > 0  # it did take traffic before tripping

    # post-trip traffic bypasses the grey replica entirely
    for u in range(10, 16):
        router.submit(_req(g, u % 6, uid=u))
    done2 = {r.uid: r for r in router.run_to_completion()}
    assert all(r.done and not r.degraded for r in done2.values())
    s2 = router.stats()
    assert s2["per_replica"][1]["dispatched"] == first_wave_on_grey
    assert s2["per_replica"][1]["circuit"] == "open"
    _assert_fleet_clean(router, cache)


# --------------------------------------------------------- front-door shed ----
def test_front_door_shed_reject_and_evict_oldest(stack):
    g, pipe, cfg, params = stack
    replicas, cache = _fleet(pipe, params, cfg, 1)
    router = ReplicaRouter(replicas, max_pending=2, shed_policy="reject")
    assert router.submit(_req(g, 0, uid=0))
    assert router.submit(_req(g, 1, uid=1))
    assert not router.submit(_req(g, 2, uid=2))  # full -> shed on arrival
    done = {r.uid: r for r in router.run_to_completion()}
    assert done[0].done and done[1].done
    assert done[2].shed and "reject" in done[2].error
    assert router.stats()["front_door_shed"] == 1
    _assert_fleet_clean(router, cache)

    replicas2, cache2 = _fleet(pipe, params, cfg, 1)
    router2 = ReplicaRouter(replicas2, max_pending=2,
                            shed_policy="evict-oldest")
    for u in range(3):
        router2.submit(_req(g, u, uid=u))
    done2 = {r.uid: r for r in router2.run_to_completion()}
    assert done2[0].shed and "evict-oldest" in done2[0].error
    assert done2[1].done and done2[2].done
    _assert_fleet_clean(router2, cache2)


def test_router_deadline_pinned_across_failover(stack):
    """A failover re-dispatch must not restart the request's deadline
    budget: the absolute deadline pinned at the front door stands, and an
    already-expired orphan is shed, not re-served."""
    g, pipe, cfg, params = stack
    clock = [0.0]
    replicas, cache = _fleet(pipe, params, cfg, 2,
                             now_fn=lambda: clock[0])
    replicas[1] = FaultyReplica(replicas[1], mode="crash", crash_step=1)
    router = ReplicaRouter(replicas, cooldown_steps=50,
                           now_fn=lambda: clock[0])
    router.submit(_req(g, 0, uid=0, max_new=12, deadline_s=5.0))
    router.submit(_req(g, 1, uid=1, max_new=12, deadline_s=5.0))
    assert all(r.deadline_at == 5.0 for r in router.pending)
    done = {}
    done.update({r.uid: r for r in router.step()})  # dispatch; replica1 dies
    clock[0] = 6.0  # past both deadlines; survivors must not extend them
    for r in router.drain():
        done[r.uid] = r
    assert set(done) == {0, 1}
    # whichever requests were still un-served at expiry went shed — none
    # were re-served on a restarted budget
    for r in done.values():
        assert r.done or (r.shed and "deadline" in r.error)
        if r.shed:
            assert r.deadline_at == 5.0  # budget was never restarted
    assert any(r.shed for r in done.values())
    _assert_fleet_clean(router, cache)


# ----------------------------------------------- shared retrieval tier -------
def test_shared_cache_single_flight_across_replicas(stack):
    """The same query submitted to two different replicas dispatches ONE
    retrieval fleet-wide: the second replica defers to the first's in-flight
    wave through the shared cache's registry and resolves as a hit."""
    g, pipe, cfg, params = stack
    clock = [0.0]
    delayed = DelayedRetrieval(
        pipe, cost_s=0.01,
        now_fn=lambda: clock[0],
        sleep_fn=lambda s: clock.__setitem__(0, clock[0] + s),
    )
    cache = RetrievalCache(capacity=256)
    replicas = [
        _engine(delayed, params, cfg, retrieval_cache=cache, prefetch=True,
                admission="wave", now_fn=lambda: clock[0],
                sleep_fn=lambda s: clock.__setitem__(0, clock[0] + s))
        for _ in range(2)
    ]
    router = ReplicaRouter(replicas, now_fn=lambda: clock[0])
    # warm-up: one unique request per replica so both arenas are busy and
    # neither takes the idle-arena blocking-collect shortcut
    router.submit(_req(g, 1, uid=10))
    router.submit(_req(g, 2, uid=11))
    router.step()
    assert delayed.dispatches == 2
    # the contended query, one copy to each replica
    router.submit(_req(g, 0, uid=0))
    router.submit(_req(g, 0, uid=1))
    done = {r.uid: r for r in router.run_to_completion()}
    assert set(done) == {0, 1, 10, 11}
    assert all(r.done for r in done.values())
    assert delayed.dispatches == 3  # qi=0 dispatched ONCE for the fleet
    assert done[0].out_tokens == done[1].out_tokens
    # exactly one copy was the dispatcher; the other resolved as a hit
    assert sorted([done[0].cache_hit, done[1].cache_hit]) == [False, True]
    assert cache.stats()["hits"] >= 1
    _assert_fleet_clean(router, cache)


# ------------------------------------------------------------- chaos soak ----
def test_replica_chaos_soak_small(stack):
    """Tier-1 replica chaos: crash + flap in one 3-replica fleet over a
    repeat-heavy stream.  Exactly one terminal per request fleet-wide, zero
    leaks anywhere, and — retrieval being clean and failover on — every
    request completes bitwise identical to a clean single-replica run."""
    g, pipe, cfg, params = stack
    n = 15
    ref = _reference(pipe, params, cfg, [_req(g, u % 5, uid=u)
                                         for u in range(n)])
    replicas, cache = _fleet(pipe, params, cfg, 3)
    replicas[1] = FaultyReplica(replicas[1], mode="crash", crash_step=3)
    replicas[2] = FaultyReplica(replicas[2], mode="flap", crash_step=2,
                                heal_step=6)
    router = ReplicaRouter(replicas, cooldown_steps=2)
    for u in range(n):
        router.submit(_req(g, u % 5, uid=u))
    done = {r.uid: r for r in router.drain()}
    assert set(done) == set(range(n))
    s = router.stats()
    assert s["duplicate_deliveries"] == 0
    assert s["failovers"] >= 2  # both faulty replicas crashed at least once
    for u in range(n):
        assert done[u].done and not done[u].failed, done[u].error
        assert done[u].out_tokens == ref[u].out_tokens
        np.testing.assert_array_equal(done[u].retrieved_nodes,
                                      ref[u].retrieved_nodes)
    _assert_fleet_clean(router, cache)


@pytest.mark.slow
@pytest.mark.parametrize("paged", [False, True])
def test_replica_chaos_soak_with_retrieval_faults(stack, paged):
    """Full-depth chaos: replica crashes + flaps ON TOP of a 25% seeded
    retrieval fault schedule, shared cache, failover on.  Invariants: the
    router never raises, every request reaches exactly one terminal state,
    accounting closes, nothing leaks, and the fault-free subset (clean
    query, served un-degraded) is bitwise identical to a no-fault run."""
    g, pipe, cfg, params = stack
    n = 24
    q_ids = [u % 8 for u in range(n)]
    ref = _reference(pipe, params, cfg,
                     [_req(g, qi, uid=u) for u, qi in enumerate(q_ids)])
    faulty = FaultyRetrieval(pipe, seed=23, fault_rate=0.25)
    bad_q = {qi for qi in set(q_ids)
             if faulty.fault_of(np.asarray(g.node_feat[qi])) is not None}
    assert bad_q and len(bad_q) < 8
    cache = RetrievalCache(capacity=256)
    replicas = [_engine(faulty, params, cfg, retrieval_cache=cache,
                        paged_kv=paged, retrieval_timeout_s=0.05)
                for _ in range(3)]
    replicas[1] = FaultyReplica(replicas[1], mode="crash", crash_step=4)
    replicas[2] = FaultyReplica(replicas[2], mode="flap", crash_step=3,
                                heal_step=8)
    router = ReplicaRouter(replicas, cooldown_steps=2)
    for u, qi in enumerate(q_ids):
        router.submit(_req(g, qi, uid=u))
    done = {r.uid: r for r in router.drain()}

    assert set(done) == set(range(n))  # exactly-once, fleet-wide
    s = router.stats()
    assert s["duplicate_deliveries"] == 0
    n_done = sum(r.done and not r.failed for r in done.values())
    n_failed = sum(bool(r.failed) for r in done.values())
    n_shed = sum(bool(r.shed) for r in done.values())
    assert n_done + n_failed + n_shed == n  # accounting closes
    assert n_done > 0
    for u, qi in enumerate(q_ids):
        r = done[u]
        if qi not in bad_q and r.done and not r.degraded and not r.stale:
            assert r.out_tokens == ref[u].out_tokens
            np.testing.assert_array_equal(r.retrieved_nodes,
                                          ref[u].retrieved_nodes)
    _assert_fleet_clean(router, cache)
