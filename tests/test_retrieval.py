"""Batched graph retrieval vs the pure-Python oracle (+ properties)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import graph_retrieval as gr
from repro.core import naive
from repro.core.filters import dynamic_filter, similarity_scores
from repro.core.indexing import BruteIndex, IVFIndex
from repro.graph import csr_to_ell, generators


@pytest.fixture(scope="module")
def graph():
    g = generators.citation_graph(300, avg_deg=6, seed=7)
    return g, csr_to_ell(g), g.to_adj_dict()


def _seeds(n, q=6, s=4, seed=0):
    return np.random.default_rng(seed).integers(0, n, size=(q, s)).astype(np.int32)


def test_bfs_matches_naive(graph):
    g, ell, adj = graph
    seeds = _seeds(g.num_nodes)
    sub = gr.retrieve_subgraph(ell, jnp.asarray(seeds), "bfs", max_hops=3, max_nodes=40)
    for qi in range(len(seeds)):
        ref = naive.bfs_subgraph(adj, sorted(set(seeds[qi].tolist())), 3, 40)
        got = [int(v) for v, m in zip(np.asarray(sub.nodes[qi]), np.asarray(sub.mask[qi])) if m]
        assert got == ref


def test_bfs_distances_match_naive(graph):
    g, ell, adj = graph
    seeds = _seeds(g.num_nodes, q=4)
    sm = gr.seeds_to_mask(jnp.asarray(seeds), g.num_nodes)
    dist = np.asarray(gr.bfs_distances(ell.nbr, ell.nbr_mask, sm, 4))
    for qi in range(4):
        ref = naive.bfs_distances(adj, sorted(set(seeds[qi].tolist())), 4)
        for v in range(g.num_nodes):
            want = ref.get(v, int(gr.INF))
            assert dist[qi, v] == want, (qi, v)


def test_steiner_contains_terminals_and_is_connected(graph):
    g, ell, adj = graph
    seeds = _seeds(g.num_nodes, q=5, s=5, seed=3)
    sub = gr.retrieve_subgraph(
        ell, jnp.asarray(seeds), "steiner", max_hops=4, max_nodes=64
    )
    for qi in range(5):
        got = {int(v) for v, m in zip(np.asarray(sub.nodes[qi]), np.asarray(sub.mask[qi])) if m}
        assert set(seeds[qi].tolist()) <= got
        # connectivity within induced subgraph (BFS over got through adj)
        start = next(iter(got))
        seen = {start}
        frontier = [start]
        while frontier:
            nxt = []
            for u in frontier:
                for w in adj[u]:
                    if w in got and w not in seen:
                        seen.add(w)
                        nxt.append(w)
            frontier = nxt
        # terminals must be reachable if the naive Steiner connected them
        ref = naive.steiner_subgraph(adj, sorted(set(seeds[qi].tolist())), 4, 64)
        if set(ref) >= set(seeds[qi].tolist()):
            assert set(seeds[qi].tolist()) <= seen


def test_steiner_size_close_to_naive(graph):
    g, ell, adj = graph
    seeds = _seeds(g.num_nodes, q=8, s=4, seed=11)
    sub = gr.retrieve_subgraph(
        ell, jnp.asarray(seeds), "steiner", max_hops=4, max_nodes=64
    )
    for qi in range(8):
        got = int(np.asarray(sub.mask[qi]).sum())
        ref = len(naive.steiner_subgraph(adj, sorted(set(seeds[qi].tolist())), 4, 64))
        # both are 2-approximations with different tie-breaks; sizes comparable
        assert got <= 2 * ref + 4


def test_dense_subgraph_keeps_seeds_and_density(graph):
    g, ell, adj = graph
    seeds = _seeds(g.num_nodes, q=4, s=3, seed=5)
    sub = gr.retrieve_subgraph(ell, jnp.asarray(seeds), "dense", max_hops=2, max_nodes=24)
    bfs = gr.retrieve_subgraph(ell, jnp.asarray(seeds), "bfs", max_hops=2, max_nodes=24)

    def internal_edges(nodes):
        s = set(nodes)
        return sum(1 for u in s for w in adj[u] if w in s)

    for qi in range(4):
        got = [int(v) for v, m in zip(np.asarray(sub.nodes[qi]), np.asarray(sub.mask[qi])) if m]
        assert set(seeds[qi].tolist()) <= set(got)
        ref = [int(v) for v, m in zip(np.asarray(bfs.nodes[qi]), np.asarray(bfs.mask[qi])) if m]
        # dense strategy should not be (much) sparser than closest-first BFS
        assert internal_edges(got) >= internal_edges(ref) - 2


def test_induced_adjacency(graph):
    g, ell, adj = graph
    seeds = _seeds(g.num_nodes, q=3)
    sub = gr.retrieve_subgraph(ell, jnp.asarray(seeds), "bfs", max_hops=2, max_nodes=20)
    snbr, smask = gr.induced_adjacency(ell.nbr, ell.nbr_mask, sub)
    nodes = np.asarray(sub.nodes)
    for qi in range(3):
        for i in range(20):
            if not np.asarray(sub.mask)[qi, i]:
                continue
            u = int(nodes[qi, i])
            in_sub = set(nodes[qi][np.asarray(sub.mask)[qi]].tolist())
            expect = {w for w in adj[u] if w in in_sub}
            got = {
                int(nodes[qi, p]) for p, ok in zip(
                    np.asarray(snbr)[qi, i], np.asarray(smask)[qi, i]
                ) if ok
            }
            assert got == expect


def test_dynamic_filter_budget_and_seeds(graph):
    g, ell, _ = graph
    seeds = _seeds(g.num_nodes, q=4, s=2, seed=9)
    sub = gr.retrieve_subgraph(ell, jnp.asarray(seeds), "bfs", max_hops=3, max_nodes=48)
    emb = jnp.asarray(g.node_feat)
    scores = similarity_scores(emb, emb[seeds[:, 0]])
    out = dynamic_filter(sub, scores, jnp.asarray(seeds), budget=10)
    assert out.nodes.shape == (4, 10)
    for qi in range(4):
        kept = set(np.asarray(out.nodes[qi])[np.asarray(out.mask[qi])].tolist())
        orig = set(np.asarray(sub.nodes[qi])[np.asarray(sub.mask[qi])].tolist())
        assert kept <= orig and len(kept) <= 10
        assert set(seeds[qi].tolist()) & orig <= kept  # seeds survive


def test_ivf_recall_vs_brute():
    rng = np.random.default_rng(0)
    emb = jnp.asarray(rng.standard_normal((2000, 64)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    brute = BruteIndex.build(emb)
    ivf = IVFIndex.build(emb, n_clusters=32, nprobe=16)
    sb, ib = brute.search(q, 10)
    si, ii = ivf.search(q, 10)
    rec = np.mean([
        len(set(np.asarray(ii[r]).tolist()) & set(np.asarray(ib[r]).tolist())) / 10
        for r in range(16)
    ])
    assert rec > 0.8, rec


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(20, 120),
    deg=st.integers(1, 5),
    hops=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
def test_bfs_property_vs_naive(n, deg, hops, seed):
    rng = np.random.default_rng(seed)
    from repro.graph import CSRGraph

    src = rng.integers(0, n, size=n * deg)
    dst = rng.integers(0, n, size=n * deg)
    g = CSRGraph.from_edges(src, dst, n, symmetrize=True)
    ell = csr_to_ell(g)
    adj = g.to_adj_dict()
    seeds = rng.integers(0, n, size=(2, 2)).astype(np.int32)
    m = min(16, n)
    sub = gr.retrieve_subgraph(ell, jnp.asarray(seeds), "bfs", max_hops=hops, max_nodes=m)
    for qi in range(2):
        ref = naive.bfs_subgraph(adj, sorted(set(seeds[qi].tolist())), hops, m)
        got = [int(v) for v, mk in zip(np.asarray(sub.nodes[qi]), np.asarray(sub.mask[qi])) if mk]
        assert got == ref
