"""ShardedIndex: exact parity with the unsharded brute scan, the
hierarchical merge oracle, and a forced multi-device CPU mesh subprocess."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BruteIndex, ShardedIndex, hierarchical_topk_merge
from repro.core.indexing import build_index


def _bitwise_equal(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and np.array_equal(
        a.view(np.uint32), b.view(np.uint32)
    )


# ---------------------------------------------------------------- merge ----
def test_hierarchical_merge_matches_flat_topk(rng):
    for s, q, w, k in [(2, 3, 5, 4), (5, 2, 7, 9), (8, 4, 3, 6), (1, 2, 6, 3)]:
        scores = jnp.asarray(rng.standard_normal((s, q, w)), jnp.float32)
        ids = jnp.asarray(rng.permutation(s * q * w)[: s * q * w]
                          .reshape(s, q, w), jnp.int32)
        ms, mi = hierarchical_topk_merge(scores, ids, k)
        flat_s = np.asarray(scores.transpose(1, 0, 2).reshape(q, -1))
        flat_i = np.asarray(ids.transpose(1, 0, 2).reshape(q, -1))
        kk = min(k, s * w)
        for qi in range(q):
            order = np.lexsort((flat_i[qi], -flat_s[qi]))[:kk]
            np.testing.assert_array_equal(np.asarray(mi)[qi],
                                          flat_i[qi][order])
            np.testing.assert_array_equal(np.asarray(ms)[qi],
                                          flat_s[qi][order])


def test_hierarchical_merge_breaks_ties_by_id(rng):
    # identical scores everywhere -> merge must return the lowest ids
    s, q, w, k = 4, 2, 3, 5
    scores = jnp.ones((s, q, w), jnp.float32)
    perm = np.tile(rng.permutation(s * w), (q, 1))  # same ids for each query
    ids = jnp.asarray(perm.reshape(q, s, w).transpose(1, 0, 2), jnp.int32)
    _, mi = hierarchical_topk_merge(scores, ids, k)
    np.testing.assert_array_equal(np.asarray(mi),
                                  np.tile(np.arange(k), (q, 1)))


# ------------------------------------------------- logical-shard parity ----
@pytest.mark.parametrize("n,n_shards,k", [
    (101, 3, 7),     # N not divisible by shard count
    (96, 4, 5),      # divisible
    (60, 7, 60),     # k == N, shards uneven
    (2500, 2, 11),   # big enough for the kernel path on the unsharded side
])
def test_sharded_matches_brute_bitwise(rng, n, n_shards, k):
    emb = rng.standard_normal((n, 32)).astype(np.float32)
    q = rng.standard_normal((5, 32)).astype(np.float32)
    bs, bi = BruteIndex.build(emb).search(q, k)
    ss, si = ShardedIndex.build(emb, n_shards=n_shards).search(q, k)
    assert _bitwise_equal(bs, ss)
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(si))


def test_sharded_tie_breaking_with_duplicate_rows(rng):
    # duplicated rows across shard boundaries -> exact score ties; the
    # merge must reproduce lax.top_k's lowest-global-id-first order
    base = rng.standard_normal((40, 16)).astype(np.float32)
    emb = np.concatenate([base, base, base])  # ids i, i+40, i+80 tie
    q = base[:4] + 0.0
    bs, bi = BruteIndex.build(emb).search(q, 9)
    ss, si = ShardedIndex.build(emb, n_shards=5).search(q, 9)
    assert _bitwise_equal(bs, ss)
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(si))


def test_sharded_single_shard_and_build_index_kinds(rng):
    emb = rng.standard_normal((50, 8)).astype(np.float32)
    q = rng.standard_normal((3, 8)).astype(np.float32)
    bs, bi = BruteIndex.build(emb).search(q, 4)
    one = build_index(emb, kind="sharded", n_shards=1)
    ss, si = one.search(q, 4)
    assert _bitwise_equal(bs, ss)
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(si))
    sivf = build_index(emb, kind="sharded_ivf", n_shards=2, n_clusters=4,
                       nprobe=4)
    s2, i2 = sivf.search(q, 4)
    assert s2.shape == (3, 4) and int(np.asarray(i2).max()) < 50


def test_sharded_empty_trailing_shard(rng):
    """ceil-partitioning can leave a shard with zero real rows (n=5, s=4 ->
    rows_per_shard=2 and shard 3 is all padding); both inners must cope."""
    emb = rng.standard_normal((5, 8)).astype(np.float32)
    q = rng.standard_normal((2, 8)).astype(np.float32)
    bs, bi = BruteIndex.build(emb).search(q, 5)
    ss, si = ShardedIndex.build(emb, n_shards=4).search(q, 5)
    assert _bitwise_equal(bs, ss)
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(si))
    sv = ShardedIndex.build(emb, n_shards=4, inner="ivf", n_clusters=4,
                            nprobe=4)
    s2, i2 = sv.search(q, 3)
    assert int(np.asarray(i2).max()) < 5
    assert np.isfinite(np.asarray(s2)).all()


def test_sharded_ivf_recall_vs_brute(rng):
    emb = rng.standard_normal((1200, 32)).astype(np.float32)
    q = rng.standard_normal((12, 32)).astype(np.float32)
    _, bi = BruteIndex.build(emb).search(q, 10)
    sivf = ShardedIndex.build(emb, n_shards=3, inner="ivf", n_clusters=8,
                              nprobe=8)  # nprobe == C: exhaustive per shard
    _, si = sivf.search(q, 10)
    rec = np.mean([
        len(set(np.asarray(si[r]).tolist())
            & set(np.asarray(bi[r]).tolist())) / 10
        for r in range(12)
    ])
    assert rec >= 0.99, rec  # all lists probed in every shard -> exact


# ----------------------------------------------- forced multi-device mesh ----
_PARITY_SCRIPT = """
import numpy as np, jax
from repro.core import BruteIndex, ShardedIndex

assert jax.device_count() == 4, jax.device_count()
rng = np.random.default_rng(7)
for n, s, k in [(2898, 4, 9), (3001, 4, 17), (4096, 8, 5)]:
    emb = rng.standard_normal((n, 48)).astype(np.float32)
    q = rng.standard_normal((6, 48)).astype(np.float32)
    bs, bi = BruteIndex.build(emb).search(q, k)
    idx = ShardedIndex.build(emb, n_shards=s)
    assert idx.mesh.size == 4, idx.mesh.size  # a real 4-way mesh
    ss, si = idx.search(q, k)
    assert np.array_equal(np.asarray(bs).view(np.uint32),
                          np.asarray(ss).view(np.uint32)), (n, s, "scores")
    assert np.array_equal(np.asarray(bi), np.asarray(si)), (n, s, "ids")
print("MESH_PARITY_OK")
"""


def test_sharded_parity_on_forced_multidevice_mesh():
    """Bit-identical (scores, ids) on a real >= 4-way CPU mesh, including
    N not divisible by the shard count.  Runs in a subprocess because
    --xla_force_host_platform_device_count must be set before jax init."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "").replace(
            "--xla_force_host_platform_device_count=4", ""
        )
        + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    out = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MESH_PARITY_OK" in out.stdout
