"""Self-speculative decode: bitwise parity vs one-token decode, EOS-inside-
window, max_new/cache_len truncation clamps, drafter lookup semantics, and
parity through the fused RAG engine under both admission schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import TransformerConfig, model as tm
from repro.serving import Request, ServeEngine
from repro.serving.drafter import draft_tokens

CFG = TransformerConfig(
    name="spec-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
    d_head=16, d_ff=64, vocab=64, dtype="float32",
)
PARAMS = tm.init_params(jax.random.PRNGKey(0), CFG)


def _mixed_requests(seed=3):
    """Random + repetitive prompts, mixed generation lengths (staggered slot
    turnover), incl. a max_new=1 request (admission-time finish)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for u, mn in enumerate([5, 12, 1, 30, 8, 12, 25]):
        if u % 2:
            pat = rng.integers(1, 64, size=int(rng.integers(2, 4)))
            p = np.tile(pat, 6)[: int(rng.integers(4, 10))]
        else:
            p = rng.integers(1, 64, size=int(rng.integers(3, 10)))
        reqs.append(Request(uid=u, prompt_ids=p.astype(np.int32),
                            max_new_tokens=mn))
    return reqs


def _run(reqs, **kw):
    eng = ServeEngine(PARAMS, CFG, slots=3, cache_len=48, **kw)
    for r in reqs:
        eng.submit(r)
    done = {r.uid: r for r in eng.run_to_completion()}
    return eng, done


# ------------------------------------------------------------------ parity ----
@pytest.mark.parametrize("window", [2, 4, 8])
def test_bitwise_parity_across_windows(window):
    """spec_decode=on emits bitwise-identical out_tokens to one-token decode
    for every request, at every draft window."""
    base_eng, base = _run(_mixed_requests(), spec_decode=False)
    spec_eng, spec = _run(_mixed_requests(), spec_decode=True,
                          draft_window=window)
    assert set(base) == set(spec) == set(range(7))
    for u in base:
        assert spec[u].out_tokens == base[u].out_tokens, f"uid {u}"
    # same tokens, strictly fewer-or-equal decode dispatches
    assert spec_eng.decode_steps <= base_eng.decode_steps
    assert spec_eng.decode_tokens == base_eng.decode_tokens
    ds = spec_eng.decode_stats()
    assert ds["spec_decode"] and ds["draft_window"] == window
    assert ds["tokens_per_step"] >= 1.0


def test_parity_with_sliding_window_attention():
    cfg = TransformerConfig(
        name="spec-sw", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=64, dtype="float32", sliding_window=16,
    )
    params = tm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    outs = {}
    for spec in (False, True):
        eng = ServeEngine(params, cfg, slots=2, cache_len=48,
                          spec_decode=spec, draft_window=4)
        r2 = np.random.default_rng(0)
        for u in range(4):
            eng.submit(Request(uid=u,
                               prompt_ids=r2.integers(1, 64, 8).astype(np.int32),
                               max_new_tokens=30))
        outs[spec] = {r.uid: r.out_tokens for r in eng.run_to_completion()}
    assert outs[True] == outs[False]


def test_parity_with_quantized_kv_cache():
    cfg = TransformerConfig(
        name="spec-q", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=64, dtype="float32", kv_quant=True,
    )
    params = tm.init_params(jax.random.PRNGKey(2), cfg)
    outs = {}
    for spec in (False, True):
        eng = ServeEngine(params, cfg, slots=2, cache_len=48,
                          spec_decode=spec, draft_window=4)
        r2 = np.random.default_rng(5)
        for u in range(3):
            eng.submit(Request(uid=u,
                               prompt_ids=r2.integers(1, 64, 6).astype(np.int32),
                               max_new_tokens=20))
        outs[spec] = {r.uid: r.out_tokens for r in eng.run_to_completion()}
    assert outs[True] == outs[False]


def test_parity_matches_offline_greedy():
    """Spec decode == slot engine == offline greedy generation."""
    from repro.models.transformer.generate import generate_tokens

    prompt = np.asarray([5, 9, 3, 22, 41], np.int32)
    eng = ServeEngine(PARAMS, CFG, slots=2, cache_len=32, spec_decode=True,
                      draft_window=4)
    eng.submit(Request(uid=0, prompt_ids=prompt, max_new_tokens=8))
    done = eng.run_to_completion()
    offline = generate_tokens(
        PARAMS, jnp.asarray(prompt)[None], jnp.asarray([len(prompt)]),
        jax.random.PRNGKey(0), CFG, max_new=8, cache_len=32, temperature=0.0,
    )
    assert done[0].out_tokens[:8] == np.asarray(offline[0]).tolist()


# ----------------------------------------------------------- EOS in window ----
def test_eos_inside_window_truncates_exactly():
    """EOS accepted mid-window ends the request at the first EOS, matching
    the one-token schedule bit for bit."""
    # find a token the model actually emits mid-stream, use it as EOS
    _, probe = _run(_mixed_requests(), spec_decode=False)
    eos = None
    for u in probe:
        toks = probe[u].out_tokens
        for t in toks[2:-1]:
            eos = int(t)
            break
        if eos is not None:
            break
    assert eos is not None, "probe stream emitted too few tokens"

    _, base = _run(_mixed_requests(), spec_decode=False, eos_id=eos)
    spec_eng, spec = _run(_mixed_requests(), spec_decode=True,
                          draft_window=8, eos_id=eos)
    for u in base:
        assert spec[u].out_tokens == base[u].out_tokens
        toks = spec[u].out_tokens
        # nothing may be emitted past the first EOS
        if eos in toks:
            assert toks.index(eos) == len(toks) - 1
    # at least one request must actually have stopped on EOS for this test
    # to exercise the path
    assert any(r.out_tokens and r.out_tokens[-1] == eos
               for r in spec.values())


# -------------------------------------------------------- truncation clamps ----
@pytest.mark.parametrize("max_new", [1, 3, 7])
def test_window_never_overshoots_max_new(max_new):
    """Regression: multi-token acceptance must clamp at max_new_tokens even
    when the draft window is larger than the remaining budget (and the old
    append-then-check accounting would have overshot)."""
    pat = np.asarray([11, 27], np.int32)
    for spec in (False, True):
        eng = ServeEngine(PARAMS, CFG, slots=2, cache_len=64,
                          spec_decode=spec, draft_window=8)
        for u in range(4):
            eng.submit(Request(uid=u, prompt_ids=np.tile(pat, 8),
                               max_new_tokens=max_new))
        done = eng.run_to_completion()
        assert len(done) == 4
        for r in done:
            assert len(r.out_tokens) == max_new, \
                f"spec={spec}: emitted {len(r.out_tokens)} != {max_new}"


def test_window_never_overshoots_cache_len():
    """Acceptance must also clamp at the KV arena edge: a window that would
    run past cache_len commits only the tokens that fit."""
    cache_len = 24
    prompt = np.asarray([3, 7, 3, 7, 3, 7, 3, 7], np.int32)  # L=8
    lens = {}
    for spec in (False, True):
        eng = ServeEngine(PARAMS, CFG, slots=1, cache_len=cache_len,
                          spec_decode=spec, draft_window=8)
        eng.submit(Request(uid=0, prompt_ids=prompt, max_new_tokens=1000))
        done = eng.run_to_completion()
        lens[spec] = len(done[0].out_tokens)
        # 1 prefill token + decode up to cursor == cache_len
        assert lens[spec] == cache_len - len(prompt) + 1
    assert lens[True] == lens[False]


def test_max_new_one_finishes_at_admission():
    """max_new_tokens=1 emits exactly the prefill token in both modes (the
    old engine emitted a second token before checking the budget)."""
    for spec in (False, True):
        eng = ServeEngine(PARAMS, CFG, slots=2, cache_len=32,
                          spec_decode=spec)
        eng.submit(Request(uid=0, prompt_ids=np.asarray([4, 9], np.int32),
                           max_new_tokens=1))
        done = eng.run_to_completion()
        assert len(done) == 1 and len(done[0].out_tokens) == 1


# ------------------------------------------------------------------ drafter ----
def test_drafter_bigram_cycle_extrapolation():
    """A locked period-3 loop is drafted exactly, wrapping past the end of
    history: hist [1,2,3,1,2] -> continuation [3,1,2,3]."""
    hist = np.zeros((1, 16), np.int32)
    hist[0, :5] = [1, 2, 3, 1, 2]
    out = np.asarray(draft_tokens(jnp.asarray(hist),
                                  jnp.asarray([5], np.int32), 4))
    assert out[0].tolist() == [3, 1, 2, 3]


def test_drafter_unigram_fallback_and_repeat_last():
    hist = np.zeros((2, 16), np.int32)
    hist[0, :3] = [7, 9, 9]   # unigram match at j=1, period 1 -> all 9s
    hist[1, :3] = [4, 5, 6]   # no match at all -> repeat last token
    out = np.asarray(draft_tokens(jnp.asarray(hist),
                                  jnp.asarray([3, 3], np.int32), 3))
    assert out[0].tolist() == [9, 9, 9]
    assert out[1].tolist() == [6, 6, 6]


def test_drafter_prefers_bigram_over_unigram():
    """The bigram occurrence wins even when a more recent unigram match
    exists: hist [2,5, 9,5, 2,5] trailing bigram (2,5) -> continuation from
    j=1, not from the later unigram 5 at j=3."""
    hist = np.zeros((1, 16), np.int32)
    hist[0, :6] = [2, 5, 9, 5, 2, 5]
    out = np.asarray(draft_tokens(jnp.asarray(hist),
                                  jnp.asarray([6], np.int32), 2))
    assert out[0].tolist() == [9, 5]


def test_drafter_dead_slot_is_harmless():
    hist = np.zeros((1, 8), np.int32)
    out = np.asarray(draft_tokens(jnp.asarray(hist),
                                  jnp.asarray([0], np.int32), 3))
    assert out.shape == (1, 3)  # content irrelevant: verification rejects


# ------------------------------------------------- fused RAG engine parity ----
@pytest.fixture(scope="module")
def rag_stack():
    from repro.core import BruteIndex, GraphTokenizer, PipelineConfig, \
        RGLPipeline, Vocab
    from repro.graph import csr_to_ell, generators

    g = generators.citation_graph(100, avg_deg=6, seed=11)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=48, node_budget=6)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb, tokenizer=tok,
        node_text=g.node_text,
        config=PipelineConfig(strategy="bfs", k_seeds=3, max_hops=2,
                              max_nodes=12, filter_budget=6),
    )
    cfg = TransformerConfig(
        name="spec-rag-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    return g, pipe, cfg, params


def test_rag_engine_parity_across_all_schedules(rag_stack):
    """The full decode/admission schedule matrix — {one-token, speculative}
    x {sync, prefetched admission} — produces bitwise-identical per-request
    outputs, retrievals, and cache accounting (the CI matrix flips the same
    two switches via RGL_SPEC_DECODE / RGL_PREFETCH)."""
    from repro.serving import RAGRequest, RAGServeEngine

    g, pipe, cfg, params = rag_stack

    def run(spec, prefetch):
        eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=96,
                             prefetch=prefetch, spec_decode=spec,
                             draft_window=4)
        q_ids = [0, 1, 2, 0, 3, 1]
        for u, qi in enumerate(q_ids):
            eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[qi]),
                                  query_text=g.node_text[qi],
                                  max_new_tokens=4 + 2 * (u % 3)))
        done = {r.uid: r for r in eng.run_to_completion()}
        assert len(done) == 6
        return eng, done

    ref_eng, ref = run(spec=False, prefetch=False)
    for spec, prefetch in [(False, True), (True, False), (True, True)]:
        eng, done = run(spec, prefetch)
        for u in ref:
            assert done[u].out_tokens == ref[u].out_tokens, (spec, prefetch)
            np.testing.assert_array_equal(done[u].retrieved_nodes,
                                          ref[u].retrieved_nodes)
            np.testing.assert_array_equal(done[u].prompt_ids,
                                          ref[u].prompt_ids)
        assert eng.cache_hits == ref_eng.cache_hits
        assert eng.cache_misses == ref_eng.cache_misses
        s = eng.stats()
        assert s["spec_decode"] == spec and s["prefetch"] == prefetch
        assert s["emitted_tokens"] == ref_eng.stats()["emitted_tokens"]


def test_rag_overlap_telemetry_counts_tokens(rag_stack):
    """Prefetch overlap telemetry reports accepted tokens (schedule-
    invariant work), not just steps: under speculation one step commits
    several tokens, so overlap_tokens >= overlap_steps."""
    import time

    from repro.serving import DelayedRetrieval, RAGRequest, RAGServeEngine

    g, pipe, cfg, params = rag_stack
    eng = RAGServeEngine(DelayedRetrieval(pipe, cost_s=0.02), params, cfg,
                         slots=2, cache_len=96, prefetch=True,
                         spec_decode=True, draft_window=4)
    for u in range(6):
        eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[u]),
                              query_text=g.node_text[u], max_new_tokens=10))
    done = eng.run_to_completion()
    assert len(done) == 6
    s = eng.stats()
    assert s["prefetch_waves"] >= 1
    assert s["overlap_tokens"] >= s["overlap_steps"] >= 1
    # sync schedule accrues no overlap at all
    sync = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=96,
                          prefetch=False, spec_decode=True)
    for u in range(4):
        sync.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[u]),
                               query_text=g.node_text[u], max_new_tokens=6))
    sync.run_to_completion()
    assert sync.stats()["overlap_tokens"] == 0


# ---------------------------------------------------------- configuration ----
def test_spec_env_default_and_override(monkeypatch):
    def make(**kw):
        return ServeEngine(PARAMS, CFG, slots=1, cache_len=32, **kw)

    monkeypatch.delenv("RGL_SPEC_DECODE", raising=False)
    assert not make().spec_decode
    monkeypatch.setenv("RGL_SPEC_DECODE", "1")
    assert make().spec_decode
    assert not make(spec_decode=False).spec_decode  # explicit beats env
    monkeypatch.setenv("RGL_SPEC_DECODE", "0")
    assert not make().spec_decode
    assert make(spec_decode=True).spec_decode
    monkeypatch.setenv("RGL_DRAFT_WINDOW", "6")
    assert make(spec_decode=True).draft_window == 6
    with pytest.raises(ValueError, match="draft_window"):
        make(spec_decode=True, draft_window=1)


def test_draft_window_env_raises_like_constructor(monkeypatch):
    """RGL_DRAFT_WINDOW=1 must fail exactly like draft_window=1 — it used
    to be silently clamped to 2, so the same invalid input had two
    behaviors depending on which path set it."""
    def make(**kw):
        return ServeEngine(PARAMS, CFG, slots=1, cache_len=32, **kw)

    monkeypatch.setenv("RGL_DRAFT_WINDOW", "1")
    with pytest.raises(ValueError, match="draft_window"):
        make(spec_decode=True)
    # non-speculative engines never validate the window (parity with the
    # constructor path, where draft_window=1 is fine if spec is off)
    assert make(spec_decode=False).draft_window == 1
    monkeypatch.setenv("RGL_DRAFT_WINDOW", "banana")
    with pytest.raises(ValueError, match="RGL_DRAFT_WINDOW"):
        make(spec_decode=True)


def test_acceptance_telemetry_on_repetitive_stream():
    """A strongly cyclic stream must commit >1 token per slot-step and
    account drafts consistently."""
    pat = np.asarray([13, 29, 44], np.int32)
    eng = ServeEngine(PARAMS, CFG, slots=2, cache_len=96, spec_decode=True,
                      draft_window=4)
    for u in range(4):
        eng.submit(Request(uid=u, prompt_ids=np.tile(pat, 8),
                           max_new_tokens=60))
    done = eng.run_to_completion()
    assert len(done) == 4
    ds = eng.decode_stats()
    assert ds["tokens_per_step"] > 1.2  # speculation actually accepted
    assert ds["draft_accepted"] == ds["decode_tokens"] - eng.slot_steps
    assert 0.0 < ds["draft_accept_rate"] <= 1.0
