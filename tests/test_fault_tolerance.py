"""Fault-tolerant serving: seeded fault injection, retry isolation, the
graceful-degradation ladder, deadlines + load shedding, abort/drain
reconciliation, and the chaos soak across the admission/decode matrix."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import BruteIndex, GraphTokenizer, PipelineConfig, \
    RGLPipeline, Vocab
from repro.graph import csr_to_ell, generators
from repro.models.transformer import TransformerConfig, model as tm
from repro.serving import (
    CachedRetrieval, DelayedRetrieval, FaultyRetrieval, RAGRequest,
    RAGServeEngine, RetrievalCache, RetrievalFault,
)

N_NODES = 120
CACHE_LEN = 96
SLOTS = 3


@pytest.fixture(scope="module")
def stack():
    g = generators.citation_graph(N_NODES, avg_deg=6, seed=7)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=64, node_budget=6)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb, tokenizer=tok,
        node_text=g.node_text,
        config=PipelineConfig(strategy="bfs", k_seeds=3, max_hops=2,
                              max_nodes=16, filter_budget=8),
    )
    cfg = TransformerConfig(
        name="fault-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    return g, pipe, cfg, params


def _req(g, qi, uid=0, max_new=4, **kw):
    return RAGRequest(uid=uid, query_emb=np.asarray(g.node_feat[qi]),
                      query_text=g.node_text[qi], max_new_tokens=max_new,
                      **kw)


def _assert_clean(eng):
    """No leaked state in any layer once the engine settles."""
    assert eng.cache.inflight_count == 0
    assert eng.prefetcher.in_flight == 0
    assert not eng._inflight and not eng._terminal
    assert not eng.engine.queue and not eng.engine.live.any()
    inner = eng.engine
    if inner.paged_kv:
        # all blocks returned except those deliberately held by retrieval-
        # cache prefill pins (prefix sharing keeps hot prompts resident)
        assert inner._free_host == inner.pool_blocks - inner.kv_pinned_blocks
        assert int(inner._ntab.sum()) == 0


# -------------------------------------------------------- fault scheduling ----
def test_fault_schedule_is_seeded_and_deterministic(stack):
    g, pipe, *_ = stack
    a = FaultyRetrieval(pipe, seed=3, fault_rate=0.5)
    b = FaultyRetrieval(pipe, seed=3, fault_rate=0.5)
    c = FaultyRetrieval(pipe, seed=4, fault_rate=0.5)
    rows = [np.asarray(g.node_feat[i]) for i in range(40)]
    sched_a = [a.fault_of(r) for r in rows]
    assert sched_a == [b.fault_of(r) for r in rows]  # same seed, same fate
    assert sched_a != [c.fault_of(r) for r in rows]  # seed changes the draw
    hit = [s for s in sched_a if s is not None]
    assert hit and len(hit) < len(rows)  # some faulty, some clean
    assert set(hit) <= set(FaultyRetrieval.FAULT_TYPES)
    none = FaultyRetrieval(pipe, seed=3, fault_rate=0.0)
    assert all(none.fault_of(r) is None for r in rows)
    with pytest.raises(ValueError, match="fault_rate"):
        FaultyRetrieval(pipe, fault_rate=1.5)
    with pytest.raises(ValueError, match="unknown fault types"):
        FaultyRetrieval(pipe, fault_types=("gremlin",))


# ------------------------------------------------------------ retry layer ----
def test_transient_fault_recovers_via_retry_bitwise(stack):
    """A row that faults once and then heals (fails_per_row=1) recovers
    through the retry path: every request completes un-degraded with
    outputs bitwise identical to a no-fault run."""
    g, pipe, cfg, params = stack

    def run(src, retries):
        eng = RAGServeEngine(src, params, cfg, slots=SLOTS,
                             cache_len=CACHE_LEN, prefetch=True,
                             max_retries=retries, retrieval_timeout_s=0.05)
        for u in range(6):
            eng.submit(_req(g, u, uid=u))
        done = {r.uid: r for r in eng.run_to_completion()}
        _assert_clean(eng)
        return eng, done

    _, clean = run(pipe, 0)
    faulty = FaultyRetrieval(pipe, seed=11, fault_rate=0.5, fails_per_row=1)
    assert any(faulty.fault_of(np.asarray(g.node_feat[u])) for u in range(6))
    eng, done = run(faulty, 2)
    assert len(done) == 6
    for u in range(6):
        assert done[u].done and not done[u].failed and not done[u].degraded
        assert done[u].out_tokens == clean[u].out_tokens
        np.testing.assert_array_equal(done[u].retrieved_nodes,
                                      clean[u].retrieved_nodes)
    s = eng.stats()
    assert s["retries"] > 0 and s["retrieval_failures"] == 0
    assert s["failed"] == s["degraded"] == 0


def test_permanent_fault_isolated_to_its_own_request(stack):
    """One permanently-poisoned row degrades only its own request; its
    wave-mates complete with outputs bitwise identical to a no-fault run
    (the retry layer re-dispatches failed miss-groups one by one)."""
    g, pipe, cfg, params = stack
    faulty = FaultyRetrieval(pipe, seed=11, fault_rate=0.5,
                             fault_types=("corrupt",))
    sched = {u: faulty.fault_of(np.asarray(g.node_feat[u])) for u in range(6)}
    bad = {u for u, s in sched.items() if s is not None}
    assert bad and len(bad) < 6  # mixed wave compositions

    def run(src):
        eng = RAGServeEngine(src, params, cfg, slots=SLOTS,
                             cache_len=CACHE_LEN, prefetch=True,
                             max_retries=1, retrieval_timeout_s=0.05)
        for u in range(6):
            eng.submit(_req(g, u, uid=u))
        done = {r.uid: r for r in eng.run_to_completion()}
        _assert_clean(eng)
        return eng, done

    _, clean = run(pipe)
    eng, done = run(faulty)
    for u in range(6):
        if u in bad:
            assert done[u].degraded and done[u].done
            assert done[u].retrieved_nodes.size == 0
        else:
            assert not done[u].degraded
            assert done[u].out_tokens == clean[u].out_tokens
            np.testing.assert_array_equal(done[u].retrieved_nodes,
                                          clean[u].retrieved_nodes)
    assert eng.stats()["degraded"] == len(bad)


# ----------------------------------------------------- degradation ladder ----
def test_ladder_rung_stale_cache_entry(stack):
    """Retry exhaustion falls back to a TTL-expired but still-resident cache
    entry before considering degraded mode."""
    g, pipe, cfg, params = stack
    now = [0.0]
    cache = RetrievalCache(capacity=8, ttl=10.0, now_fn=lambda: now[0])
    ok = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                        retrieval_cache=cache)
    ok.submit(_req(g, 0, uid=0))
    clean = ok.run_to_completion()[0]
    assert cache.peek_stale(np.asarray(g.node_feat[0])) is not None

    now[0] = 100.0  # entry is now TTL-expired (but resident)
    boom = FaultyRetrieval(pipe, seed=0, fault_rate=1.0,
                           fault_types=("dispatch",))
    eng = RAGServeEngine(boom, params, cfg, slots=2, cache_len=CACHE_LEN,
                         retrieval_cache=cache)
    eng.submit(_req(g, 0, uid=1))
    r = eng.run_to_completion()[0]
    assert r.done and r.stale and not r.degraded and not r.failed
    assert r.out_tokens == clean.out_tokens  # same entry -> same decode
    np.testing.assert_array_equal(r.retrieved_nodes, clean.retrieved_nodes)
    assert eng.stats()["stale_served"] == 1 and eng.stats()["degraded"] == 0
    _assert_clean(eng)


@pytest.mark.parametrize("ftype", ["dispatch", "force", "stuck", "corrupt"])
def test_ladder_rung_degraded_per_fault_type(stack, ftype):
    """With no cache fallback, every fault type exhausts into retrieval-free
    decode: the request completes on a query-only prompt."""
    g, pipe, cfg, params = stack
    boom = FaultyRetrieval(pipe, seed=0, fault_rate=1.0, fault_types=(ftype,))
    eng = RAGServeEngine(boom, params, cfg, slots=2, cache_len=CACHE_LEN,
                         prefetch=True, max_retries=1,
                         retrieval_timeout_s=0.05)
    eng.submit(_req(g, 0, uid=0, max_new=3))
    r = eng.run_to_completion()[0]
    assert r.done and r.degraded and not r.failed
    assert len(r.out_tokens) == 3 and r.retrieved_nodes.size == 0
    s = eng.stats()
    assert s["degraded"] == 1 and s["retrieval_failures"] >= 1
    assert boom.injected[ftype] > 0
    _assert_clean(eng)


def test_ladder_rung_failed_when_degraded_disabled(stack):
    g, pipe, cfg, params = stack
    boom = FaultyRetrieval(pipe, seed=0, fault_rate=1.0,
                           fault_types=("corrupt",))
    eng = RAGServeEngine(boom, params, cfg, slots=2, cache_len=CACHE_LEN,
                         degraded_mode=False)
    eng.submit(_req(g, 0, uid=7))
    r = eng.run_to_completion()[0]
    assert r.failed and not r.done and not r.degraded
    assert "corrupt" in r.error and "node id out of range" in r.error
    assert eng.stats()["failed"] == 1
    _assert_clean(eng)


def test_stuck_row_without_timeout_fails_loud_not_hung(stack, monkeypatch):
    """An unconfigured timeout over a never-ready row must not deadlock the
    engine: forcing the stuck array raises (contained by the ladder)."""
    g, pipe, cfg, params = stack
    # pin the no-timeout configuration even when the CI fault-injection
    # cell arms RGL_RETRIEVAL_TIMEOUT engine-wide
    monkeypatch.delenv("RGL_RETRIEVAL_TIMEOUT", raising=False)
    monkeypatch.delenv("RGL_RETRIES", raising=False)
    boom = FaultyRetrieval(pipe, seed=0, fault_rate=1.0,
                           fault_types=("stuck",))
    eng = RAGServeEngine(boom, params, cfg, slots=2, cache_len=CACHE_LEN)
    eng.submit(_req(g, 0, uid=0))
    r = eng.run_to_completion()[0]
    assert r.done and r.degraded
    with pytest.raises(RetrievalFault, match="stuck"):
        np.asarray(boom.retrieve_many(np.asarray(g.node_feat[0])).seeds)


# ------------------------------------------------- deadlines & overload ----
def test_deadline_expired_requests_shed_never_dispatched(stack):
    g, pipe, cfg, params = stack
    now = [0.0]
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                         now_fn=lambda: now[0])
    eng.submit(_req(g, 0, uid=0, deadline_s=5.0))
    eng.submit(_req(g, 1, uid=1))  # no deadline: must still complete
    now[0] = 6.0  # past uid=0's deadline before any step ran
    done = {r.uid: r for r in eng.run_to_completion()}
    assert done[0].shed and not done[0].done and "deadline" in done[0].error
    assert done[1].done and not done[1].shed
    assert eng.prefetcher.queries == 1  # the shed request never dispatched
    assert eng.stats()["shed"] == 1
    _assert_clean(eng)


def test_default_deadline_env_and_kwarg(stack, monkeypatch):
    g, pipe, cfg, params = stack
    now = [0.0]
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                         default_deadline_s=2.0, now_fn=lambda: now[0])
    eng.submit(_req(g, 0, uid=0))
    assert eng.pending[0].deadline_at == 2.0
    monkeypatch.setenv("RGL_DEADLINE", "7.5")
    eng2 = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                          now_fn=lambda: now[0])
    assert eng2.default_deadline_s == 7.5
    monkeypatch.setenv("RGL_DEADLINE", "junk")
    with pytest.raises(ValueError, match="RGL_DEADLINE"):
        RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN)


def test_bounded_pending_queue_shed_policies(stack):
    g, pipe, cfg, params = stack
    # reject: the newcomer is refused
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                         max_pending=2, shed_policy="reject")
    assert eng.submit(_req(g, 0, uid=0))
    assert eng.submit(_req(g, 1, uid=1))
    assert not eng.submit(_req(g, 2, uid=2))  # queue full -> shed on arrival
    done = {r.uid: r for r in eng.run_to_completion()}
    assert done[0].done and done[1].done
    assert done[2].shed and "reject" in done[2].error
    assert eng.stats()["shed"] == 1

    # evict-oldest: the oldest pending request makes room
    eng2 = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                          max_pending=2, shed_policy="evict-oldest")
    for u in range(3):
        eng2.submit(_req(g, u, uid=u))
    done2 = {r.uid: r for r in eng2.run_to_completion()}
    assert done2[0].shed and "evict-oldest" in done2[0].error
    assert done2[1].done and done2[2].done
    with pytest.raises(ValueError, match="shed_policy"):
        RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                       shed_policy="drop-newest")


def test_submit_validation_rejects_poison_requests(stack):
    g, pipe, cfg, params = stack
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN)
    good = np.asarray(g.node_feat[0])
    nan = good.copy()
    nan[0] = np.nan
    with pytest.raises(ValueError, match="request 3.*NaN"):
        eng.submit(RAGRequest(uid=3, query_emb=nan, query_text="q"))
    with pytest.raises(ValueError, match="request 4.*1-D"):
        eng.submit(RAGRequest(uid=4, query_emb=np.stack([good, good]),
                              query_text="q"))
    with pytest.raises(ValueError, match="request 5.*dim"):
        eng.submit(RAGRequest(uid=5, query_emb=good[:3], query_text="q"))
    with pytest.raises(ValueError, match="request 6.*query_text"):
        eng.submit(RAGRequest(uid=6, query_emb=good, query_text="   "))
    with pytest.raises(ValueError, match="request 7.*max_new_tokens"):
        eng.submit(RAGRequest(uid=7, query_emb=good, query_text="q",
                              max_new_tokens=0))
    with pytest.raises(ValueError, match="request 8.*deadline_s"):
        eng.submit(RAGRequest(uid=8, query_emb=good, query_text="q",
                              deadline_s=-1.0))
    assert not eng.pending and eng.run_to_completion() == []


# ------------------------------------------------------- abort & recovery ----
@pytest.mark.parametrize("paged", [False, True])
def test_abort_reconciles_all_layers_and_engine_reusable(stack, paged):
    """abort() mid-flight retires live slots (returning paged KV blocks),
    drops in-flight waves (releasing their cache keys), sheds the queue, and
    leaves the engine able to serve a fresh workload correctly."""
    g, pipe, cfg, params = stack
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                         prefetch=True, prefetch_depth=2, paged_kv=paged)
    for u in range(6):
        eng.submit(_req(g, u, uid=u, max_new=8))
    eng.step()  # some admitted + decoding, some in flight, some pending
    out = eng.abort(reason="test teardown")
    done = {r.uid: r for r in out}
    assert set(done) == set(range(6))
    for r in done.values():
        assert (r.failed or r.shed) and r.error is not None
    _assert_clean(eng)

    # fresh workload on the same engine matches a clean engine's outputs
    for u in range(3):
        eng.submit(_req(g, u, uid=100 + u))
    redo = {r.uid: r for r in eng.run_to_completion()}
    ref_eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                             paged_kv=paged)
    for u in range(3):
        ref_eng.submit(_req(g, u, uid=100 + u))
    ref = {r.uid: r for r in ref_eng.run_to_completion()}
    for uid in ref:
        assert redo[uid].done and redo[uid].out_tokens == ref[uid].out_tokens
    _assert_clean(eng)


def test_recovery_after_run_to_completion_exhaustion(stack):
    """The PR-motivating bug: a run_to_completion RuntimeError used to leave
    the engine unrecoverable.  abort() reconciles; drain() never raises."""
    g, pipe, cfg, params = stack
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                         prefetch=True)
    for u in range(4):
        eng.submit(_req(g, u, uid=u, max_new=20))
    with pytest.raises(RuntimeError, match="still pending"):
        eng.run_to_completion(max_steps=2)
    leftovers = eng.abort(reason="exhausted")
    assert leftovers and all(r.failed or r.shed for r in leftovers)
    _assert_clean(eng)
    eng.submit(_req(g, 0, uid=50))
    done = eng.run_to_completion()
    assert len(done) == 1 and done[0].done
    _assert_clean(eng)

    # drain() folds the same recovery into one call
    eng2 = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN)
    for u in range(4):
        eng2.submit(_req(g, u, uid=u, max_new=20))
    out = eng2.drain(max_steps=2)
    assert len(out) == 4 and any(r.failed or r.shed for r in out)
    _assert_clean(eng2)


def test_mid_flight_fault_then_fresh_workload(stack):
    """Regression (satellite): after a contained mid-flight fault the SAME
    engine must complete a fresh workload with clean outputs."""
    g, pipe, cfg, params = stack
    faulty = FaultyRetrieval(pipe, seed=5, fault_rate=1.0,
                             fault_types=("force",), fails_per_row=1)
    eng = RAGServeEngine(faulty, params, cfg, slots=2, cache_len=CACHE_LEN,
                         prefetch=True,
                         max_retries=0)  # first fault goes straight to ladder
    eng.submit(_req(g, 0, uid=0))
    first = eng.run_to_completion()[0]
    assert first.done and first.degraded
    _assert_clean(eng)
    # the row healed (fails_per_row=1); the same engine now serves it fully
    eng.submit(_req(g, 0, uid=1))
    second = eng.run_to_completion()[0]
    assert second.done and not second.degraded
    assert second.retrieved_nodes.size > 0
    _assert_clean(eng)


def test_abort_mid_launch_continuous_wave(stack):
    """abort() invoked while continuous-admission waves sit between launch
    and collect: every layer reconciles (no leaked slots, waves, or
    in-flight cache keys) and the engine serves a fresh workload after."""
    g, pipe, cfg, params = stack
    clock = [0.0]
    sleep = lambda s: clock.__setitem__(0, clock[0] + s)  # noqa: E731
    feat0 = np.asarray(g.node_feat[0], np.float32)

    def cost(row):  # row 0 lands instantly; every other row never does
        return 0.0 if np.allclose(row, feat0) else np.inf

    delayed = DelayedRetrieval(pipe, cost_s=0.0, cost_fn=cost,
                               now_fn=lambda: clock[0], sleep_fn=sleep)
    eng = RAGServeEngine(delayed, params, cfg, slots=SLOTS,
                         cache_len=CACHE_LEN, prefetch=True,
                         admission="continuous",
                         now_fn=lambda: clock[0], sleep_fn=sleep)
    for u in range(3):
        eng.submit(_req(g, u, uid=u))
    eng.step()
    # uid 0's wave collected + admitted (arena non-idle); uids 1-2 are
    # launched-but-uncollected, their keys registered in flight
    assert int(eng.engine.live.sum()) == 1
    assert eng.prefetcher.in_flight == 2
    assert eng.cache.inflight_count == 2
    out = {r.uid: r for r in eng.abort(reason="mid-launch abort")}
    assert set(out) == {0, 1, 2}  # exactly one terminal per request
    assert all(r.failed or r.shed for r in out.values())
    _assert_clean(eng)
    # the same engine serves a fresh (instant-retrieval) workload cleanly
    eng.submit(_req(g, 0, uid=9))
    done = eng.run_to_completion()
    assert len(done) == 1 and done[0].done
    _assert_clean(eng)


def test_cache_stale_counters(stack):
    """peek_stale is observable at the cache tier: stale_hits counts
    resident (even TTL-expired) fallbacks, stale_misses counts empty-handed
    lookups — neither touches the hit/miss counters or recency."""
    g, *_ = stack
    now = [0.0]
    cache = RetrievalCache(capacity=4, ttl=10.0, now_fn=lambda: now[0])
    emb = np.asarray(g.node_feat[0])
    assert cache.peek_stale(emb) is None
    s = cache.stats()
    assert s["stale_misses"] == 1 and s["stale_hits"] == 0
    entry = CachedRetrieval(
        nodes=np.arange(4, dtype=np.int32), mask=np.ones(4, bool),
        dist=np.zeros(4, np.int32), seeds=np.arange(2, dtype=np.int32),
    )
    cache.put(emb, entry)
    assert cache.peek_stale(emb) is entry
    now[0] = 100.0  # TTL-expired: invisible to get, served by peek_stale
    assert cache.get(emb) is None
    assert cache.peek_stale(emb) is entry
    s = cache.stats()
    assert s["stale_hits"] == 2 and s["stale_misses"] == 1
    assert s["hits"] == 0 and s["misses"] == 1  # peeks counted separately


# -------------------------------------------------------------- chaos soak ----
@pytest.mark.slow
@pytest.mark.parametrize("prefetch", [False, True])
@pytest.mark.parametrize("admission", ["wave", "continuous"])
@pytest.mark.parametrize("paged", [False, True])
def test_chaos_soak_matrix(stack, prefetch, admission, paged):
    """Seeded chaos across the admission/decode matrix: all fault types at a
    25% rate over a repeat-heavy stream.  Invariants: step() never raises,
    every request reaches exactly one terminal state, nothing leaks in any
    layer, counters account for every submitted request, and the fault-free
    subset is bitwise identical to a no-fault run."""
    g, pipe, cfg, params = stack
    n = 14
    q_ids = [u % 7 for u in range(n)]  # repeats: cache hits + dedup + stale

    def run(src, **kw):
        eng = RAGServeEngine(src, params, cfg, slots=SLOTS,
                             cache_len=CACHE_LEN, prefetch=prefetch,
                             admission=admission, paged_kv=paged,
                             max_retries=1, retrieval_timeout_s=0.05,
                             **kw)
        for u, qi in enumerate(q_ids):
            eng.submit(_req(g, qi, uid=u, max_new=4))
        done = {r.uid: r for r in eng.drain()}
        _assert_clean(eng)
        return eng, done

    _, clean = run(pipe)
    faulty = FaultyRetrieval(pipe, seed=23, fault_rate=0.25)
    bad_q = {qi for qi in set(q_ids)
             if faulty.fault_of(np.asarray(g.node_feat[qi])) is not None}
    assert bad_q and len(bad_q) < 7
    eng, done = run(faulty)

    assert set(done) == set(range(n))  # every request terminal, exactly once
    s = eng.stats()
    n_done = sum(r.done and not r.failed for r in done.values())
    assert n_done + s["failed"] + s["shed"] == n  # accounting closes
    assert n_done > 0
    for u, qi in enumerate(q_ids):
        r = done[u]
        assert r.done or r.failed or r.shed
        if qi not in bad_q and r.done and not r.degraded and not r.stale:
            assert r.out_tokens == clean[u].out_tokens
            np.testing.assert_array_equal(r.retrieved_nodes,
                                          clean[u].retrieved_nodes)
    # fault-free requests are never collateral damage of faulty wave-mates
    for u, qi in enumerate(q_ids):
        if qi not in bad_q:
            assert done[u].done and not done[u].failed
            assert not done[u].degraded and not done[u].stale


@pytest.mark.slow
@pytest.mark.parametrize("admission", ["wave", "continuous"])
def test_chaos_soak_with_mutations(stack, admission):
    """Mutation cell of the chaos soak: seeded retrieval faults AND seeded
    graph mutations interleave with serving steps.  Invariants: step() and
    apply_mutations() never raise, every request reaches exactly one
    terminal state, no layer leaks, the cache epoch tracks the store, and
    the post-soak compacted store is bitwise identical to a from-scratch
    rebuild of its merged corpus."""
    from repro.core import MutableGraphStore, MutationBatch
    from repro.graph import CSRGraph

    g, _, cfg, params = stack
    store = MutableGraphStore.build(g, index_kind="brute")
    pipe = store.make_pipeline(
        tokenizer=GraphTokenizer(Vocab.build(g.node_text), max_len=64,
                                 node_budget=6),
        config=PipelineConfig(strategy="bfs", k_seeds=3, max_hops=2,
                              max_nodes=16, filter_budget=8),
    )
    faulty = FaultyRetrieval(pipe, seed=23, fault_rate=0.25)
    eng = RAGServeEngine(faulty, params, cfg, slots=SLOTS,
                         cache_len=CACHE_LEN, prefetch=True,
                         admission=admission, max_retries=1,
                         retrieval_timeout_s=0.05, compact_every=6)
    n = 14
    for u in range(n):
        eng.submit(_req(g, u % 7, uid=u, max_new=4))

    rng = np.random.default_rng(29)
    done, steps = {}, 0
    while not eng._drained() and steps < 400:
        for r in eng.step():
            assert r.uid not in done  # exactly one terminal per request
            done[r.uid] = r
        steps += 1
        if rng.random() < 0.5:  # ~10% write mix relative to decode steps
            n_nodes = store.n_nodes
            alive = np.flatnonzero(np.asarray(store.alive)[:n_nodes])
            u, v = int(rng.choice(alive)), int(rng.choice(alive))
            roll = rng.random()
            if roll < 0.45:
                batch = MutationBatch(add_edges=np.array([[u, v]]))
            elif roll < 0.9:
                batch = MutationBatch(del_edges=np.array([[u, v]]))
            else:
                batch = MutationBatch(
                    add_node_feat=rng.normal(
                        size=(1, g.node_feat.shape[1])).astype(np.float32),
                    add_node_text=[f"chaos {n_nodes}"],
                    add_edges=np.array([[n_nodes, u]]),
                )
            eng.apply_mutations(batch)

    assert set(done) == set(range(n))
    s = eng.stats()
    n_done = sum(r.done and not r.failed for r in done.values())
    assert n_done + s["failed"] + s["shed"] == n
    assert n_done > 0
    assert store.epoch >= 1 and s["mutation_batches"] == store.batches_applied
    assert eng.cache.graph_epoch == store.epoch
    _assert_clean(eng)

    # the soaked store still compacts to rebuild-equivalent state
    store.compact()
    src, dst = store.delta.live_edge_list()
    g2 = CSRGraph.from_edges(
        src, dst, store.n_nodes,
        node_feat=store.h_feat[:store.n_nodes].copy(),
        node_text=list(store.node_text[:store.n_nodes]))
    ref = MutableGraphStore.build(g2, index_kind="brute", alive=store.alive,
                                  active=True)
    np.testing.assert_array_equal(np.asarray(store.graph.nbr),
                                  np.asarray(ref.graph.nbr))
    np.testing.assert_array_equal(np.asarray(store.node_emb),
                                  np.asarray(ref.node_emb))
