"""`hypothesis` compatibility shim for property-based tests.

On environments with hypothesis installed, re-exports the real
``given``/``settings``/``st``.  On bare environments it provides a tiny
deterministic fallback: ``@given`` draws ``max_examples`` samples from the
declared strategies with a fixed-seed PRNG and runs the test body once per
sample.  No shrinking, no database — just enough to keep the property tests
executing (and the modules collecting) everywhere.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def draw(self, rnd):
            return self._sample(rnd)

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda r: r.choice(opts))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.randint(0, 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = getattr(run, "_max_examples", 10)
                rnd = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rnd) for k, s in strats.items()}
                    fn(*args, **kwargs, **drawn)

            # hide the drawn params from pytest's fixture resolution
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items()
                    if name not in strats]
            run.__signature__ = sig.replace(parameters=keep)
            del run.__wrapped__  # or pytest re-reads fn's signature
            return run

        return deco
