"""Fused RAG serving: golden pipeline behaviour + engine/reference parity +
retrieval-cache semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BruteIndex, GraphTokenizer, PipelineConfig, RGLPipeline, Vocab,
    index_from_config,
)
from repro.core import naive
from repro.core.tokenization import subgraph_texts
from repro.graph import csr_to_ell, generators
from repro.models.transformer import TransformerConfig, model as tm
from repro.models.transformer.generate import generate_tokens
from repro.serving import RAGRequest, RAGServeEngine, RetrievalCache

N_NODES = 200
MAX_LEN = 96
MAX_NEW = 6
CACHE_LEN = 128


@pytest.fixture(scope="module")
def stack():
    g = generators.citation_graph(N_NODES, avg_deg=6, seed=11)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=MAX_LEN, node_budget=8)
    pcfg = PipelineConfig(strategy="bfs", k_seeds=3, max_hops=2,
                          max_nodes=16, filter_budget=8)
    from repro.serving.config import env_flag
    if env_flag("RGL_MUTATION"):
        # RGL_MUTATION CI cell: the whole serving matrix runs on a pipeline
        # built through a pristine MutableGraphStore — zero-mutation serving
        # must be bitwise identical to the frozen setup below
        from repro.core import MutableGraphStore
        store = MutableGraphStore.build(g, index_kind="brute")
        pipe = store.make_pipeline(tokenizer=tok, config=pcfg)
    else:
        pipe = RGLPipeline(
            graph=ell, index=BruteIndex.build(emb), node_emb=emb,
            tokenizer=tok, node_text=g.node_text, config=pcfg,
        )
    cfg = TransformerConfig(
        name="rag-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    return g, pipe, cfg, params


# ---------------------------------------------------------------- golden ----
def test_pipeline_run_golden(stack):
    """RGLPipeline.run on a small deterministic graph: seed ids, filtered
    subgraph membership, and prompt shapes all match the reference path."""
    g, pipe, _, _ = stack
    qe = jnp.asarray(g.node_feat[:3])
    texts = [g.node_text[i] for i in range(3)]
    out = pipe.run(qe, texts)

    # seeds == the exact top-k of the brute index (ref oracle)
    from repro.kernels.topk_sim import ref as tref
    from repro.core.indexing import l2_normalize

    emb_n = l2_normalize(jnp.asarray(g.node_feat))
    _, exp_seeds = tref.topk_similarity(l2_normalize(qe), emb_n, 3)
    np.testing.assert_array_equal(out["seeds"], np.asarray(exp_seeds))
    for qi in range(3):  # a node's own embedding retrieves itself
        assert qi in out["seeds"][qi]

    # filtered membership: subset of the naive BFS ball, seeds preserved,
    # budget respected
    adj = g.to_adj_dict()
    sub = out["subgraph"]
    nodes = np.asarray(sub.nodes)
    mask = np.asarray(sub.mask)
    for qi in range(3):
        got = {int(v) for v, m in zip(nodes[qi], mask[qi]) if m}
        ball = set(naive.bfs_subgraph(adj, sorted(set(out["seeds"][qi].tolist())),
                                      2, N_NODES))
        assert got <= ball
        assert set(out["seeds"][qi].tolist()) <= got
        assert len(got) <= pipe.config.filter_budget + pipe.config.k_seeds

    # fixed prompt shapes + determinism
    assert out["prompt_ids"].shape == (3, MAX_LEN)
    assert out["prompt_mask"].shape == (3, MAX_LEN)
    out2 = pipe.run(qe, texts)
    np.testing.assert_array_equal(out["prompt_ids"], out2["prompt_ids"])


def test_retrieve_many_padding_is_inert(stack):
    """Padded rows in the fixed-shape serving batch never perturb real rows."""
    g, pipe, _, _ = stack
    qe = np.asarray(g.node_feat[:2], np.float32)
    res1 = pipe.retrieve(jnp.asarray(qe))
    sub1, seeds1 = res1.sub, res1.seeds
    res8 = pipe.retrieve_many(qe, batch_size=8)
    sub8, seeds8, n_valid = res8.sub, res8.seeds, res8.n_valid
    assert n_valid == 2 and seeds8.shape[0] == 8
    assert res8.epoch == pipe.epoch
    np.testing.assert_array_equal(np.asarray(seeds8)[:2], np.asarray(seeds1))
    np.testing.assert_array_equal(np.asarray(sub8.nodes)[:2],
                                  np.asarray(sub1.nodes))
    np.testing.assert_array_equal(np.asarray(sub8.mask)[:2],
                                  np.asarray(sub1.mask))


# ---------------------------------------------------- engine vs reference ----
def _reference_tokens(g, pipe, cfg, params, qi):
    """Unbatched pipeline + offline greedy decode — the fused engine oracle."""
    sub = pipe.retrieve(jnp.asarray(g.node_feat[qi])[None]).sub
    texts = subgraph_texts(sub, g.node_text)[0]
    ids, mask = pipe.tokenizer.linearize(g.node_text[qi], texts)
    prompt = ids[mask]
    out = generate_tokens(
        params, jnp.asarray(prompt)[None], jnp.asarray([len(prompt)]),
        jax.random.PRNGKey(0), cfg, max_new=MAX_NEW, cache_len=CACHE_LEN,
        temperature=0.0,
    )
    return np.asarray(out[0]).tolist()


def test_fused_engine_matches_unbatched_pipeline(stack):
    """A single fused-engine request is token-identical to the unbatched
    RGLPipeline + greedy-decode reference path."""
    g, pipe, cfg, params = stack
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN)
    eng.submit(RAGRequest(uid=0, query_emb=np.asarray(g.node_feat[0]),
                          query_text=g.node_text[0], max_new_tokens=MAX_NEW))
    done = eng.run_to_completion()
    assert len(done) == 1 and done[0].done
    assert done[0].out_tokens[:MAX_NEW] == _reference_tokens(
        g, pipe, cfg, params, 0
    )


def test_fused_engine_batch_matches_reference(stack):
    """Batched admission (shared prefill + shared retrieval batch) stays
    token-identical per request."""
    g, pipe, cfg, params = stack
    eng = RAGServeEngine(pipe, params, cfg, slots=4, cache_len=CACHE_LEN)
    for qi in range(4):
        eng.submit(RAGRequest(uid=qi, query_emb=np.asarray(g.node_feat[qi]),
                              query_text=g.node_text[qi],
                              max_new_tokens=MAX_NEW))
    done = {r.uid: r for r in eng.run_to_completion()}
    assert set(done) == {0, 1, 2, 3}
    assert eng.retrieval_batches == 1  # one jitted call for the whole wave
    for qi in range(4):
        assert done[qi].out_tokens[:MAX_NEW] == _reference_tokens(
            g, pipe, cfg, params, qi
        )


def test_fused_engine_on_sharded_index_matches_brute_reference(stack):
    """RAGServeEngine admission works unchanged on a sharded index: with
    ``index_kind="sharded"`` the fused engine emits tokens identical to the
    brute-index reference path (sharded brute search is bit-identical)."""
    g, pipe, cfg, params = stack
    pcfg = PipelineConfig(strategy="bfs", k_seeds=3, max_hops=2,
                          max_nodes=16, filter_budget=8,
                          index_kind="sharded", index_shards=3)
    sharded_pipe = RGLPipeline(
        graph=pipe.graph,
        index=index_from_config(jnp.asarray(g.node_feat), pcfg),
        node_emb=pipe.node_emb, tokenizer=pipe.tokenizer,
        node_text=g.node_text, config=pcfg,
    )
    eng = RAGServeEngine(sharded_pipe, params, cfg, slots=4,
                         cache_len=CACHE_LEN)
    for qi in range(4):
        eng.submit(RAGRequest(uid=qi, query_emb=np.asarray(g.node_feat[qi]),
                              query_text=g.node_text[qi],
                              max_new_tokens=MAX_NEW))
    done = {r.uid: r for r in eng.run_to_completion()}
    assert set(done) == {0, 1, 2, 3}
    for qi in range(4):  # reference runs on the brute-index pipeline
        assert done[qi].out_tokens[:MAX_NEW] == _reference_tokens(
            g, pipe, cfg, params, qi
        )


# ------------------------------------------------------------------ cache ----
def test_retrieval_cache_hit_and_counters(stack):
    g, pipe, cfg, params = stack
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN)

    def ask(uid):
        eng.submit(RAGRequest(uid=uid, query_emb=np.asarray(g.node_feat[5]),
                              query_text=g.node_text[5],
                              max_new_tokens=MAX_NEW))
        return eng.run_to_completion()[0]

    first = ask(0)
    assert (eng.cache_hits, eng.cache_misses) == (0, 1)
    assert not first.cache_hit

    second = ask(1)  # identical query -> served from the retrieval cache
    assert (eng.cache_hits, eng.cache_misses) == (1, 1)
    assert second.cache_hit
    assert eng.retrieved_queries == 1  # no second retrieval ran
    assert second.out_tokens == first.out_tokens
    np.testing.assert_array_equal(second.prompt_ids, first.prompt_ids)

    # near-duplicate within quantization eps also hits
    jitter = np.asarray(g.node_feat[5]) + 1e-5
    eng.submit(RAGRequest(uid=2, query_emb=jitter, query_text=g.node_text[5],
                          max_new_tokens=MAX_NEW))
    third = eng.run_to_completion()[0]
    assert third.cache_hit and eng.cache_hits == 2


def test_retrieval_cache_lru_eviction():
    cache = RetrievalCache(capacity=2)
    from repro.serving import CachedRetrieval

    def entry(i):
        return CachedRetrieval(
            nodes=np.asarray([i], np.int32), mask=np.asarray([True]),
            dist=np.asarray([0], np.int32), seeds=np.asarray([i], np.int32),
        )

    e0, e1, e2 = (np.full(4, i, np.float32) for i in range(3))
    cache.put(e0, entry(0))
    cache.put(e1, entry(1))
    assert cache.get(e0) is not None  # refresh e0 -> e1 becomes LRU
    cache.put(e2, entry(2))  # evicts e1
    assert cache.get(e1) is None
    assert cache.get(e0) is not None and cache.get(e2) is not None
    assert cache.evictions == 1
    assert cache.stats()["size"] == 2


def test_oversized_prompt_rejected_loudly(stack):
    """Prompts that cannot fit the KV arena fail at submit, not silently."""
    from repro.serving import Request, ServeEngine

    g, pipe, cfg, params = stack
    eng = ServeEngine(params, cfg, slots=2, cache_len=16)
    with pytest.raises(ValueError, match="cannot fit"):
        eng.submit(Request(uid=0, prompt_ids=np.arange(1, 40, dtype=np.int32)))
    # and the fused engine refuses a tokenizer/arena mismatch at construction
    with pytest.raises(ValueError, match="max_len"):
        RAGServeEngine(pipe, params, cfg, slots=2, cache_len=MAX_LEN)


def test_cache_disabled():
    cache = RetrievalCache(capacity=0)
    emb = np.ones(4, np.float32)
    assert cache.get(emb) is None
    cache.put(emb, None)  # no-op
    assert len(cache) == 0 and cache.misses == 1


# ----------------------------------------------------- eviction policies ----
def _entry(i):
    from repro.serving import CachedRetrieval

    return CachedRetrieval(
        nodes=np.asarray([i], np.int32), mask=np.asarray([True]),
        dist=np.asarray([0], np.int32), seeds=np.asarray([i], np.int32),
    )


def _emb(i):
    return np.full(4, i, np.float32)


def test_cache_lfu_eviction():
    """lfu keeps warm regulars; the coldest (fewest hits) entry goes."""
    cache = RetrievalCache(capacity=2, policy="lfu")
    cache.put(_emb(0), _entry(0))
    cache.put(_emb(1), _entry(1))
    for _ in range(3):
        assert cache.get(_emb(0)) is not None  # e0: 3 hits
    assert cache.get(_emb(1)) is not None  # e1: 1 hit, more recent
    cache.put(_emb(2), _entry(2))  # evicts e1 (fewest hits), not e0
    assert cache.get(_emb(1)) is None
    assert cache.get(_emb(0)) is not None
    assert cache.evictions == 1
    assert cache.hit_count(_emb(0)) == 4
    # a 0-hit newcomer is protected at insertion: e2 (1 hit) goes, not e3
    assert cache.get(_emb(2)) is not None
    cache.put(_emb(3), _entry(3))
    assert cache.get(_emb(2)) is None
    assert cache.get(_emb(0)) is not None and cache.get(_emb(3)) is not None
    assert cache.evictions == 2


def test_cache_reinsert_preserves_hits_under_lfu():
    """Re-putting a live key must keep its accumulated hit count: a warm
    lfu entry re-inserted (e.g. prefetch.py re-publishing an owner wave's
    result after the owner's copy was evicted) used to restart at 0 hits
    and become the next eviction victim."""
    cache = RetrievalCache(capacity=2, policy="lfu")
    cache.put(_emb(0), _entry(0))
    cache.put(_emb(1), _entry(1))
    for _ in range(3):
        assert cache.get(_emb(0)) is not None  # e0: warm, 3 hits
    assert cache.get(_emb(1)) is not None  # e1: 1 hit
    cache.put(_emb(0), _entry(0))  # re-insert the warm key
    assert cache.hit_count(_emb(0)) == 3  # hits survive the re-insert
    cache.put(_emb(2), _entry(2))  # must evict e1 (1 hit), NOT warm e0
    assert cache.get(_emb(0)) is not None
    assert cache.get(_emb(1)) is None
    assert cache.evictions == 1


def test_cache_reinsert_refreshes_ttl_window():
    """inserted_at DOES refresh on re-insert (documented): a re-put carries
    fresh data, so its TTL expiry window restarts."""
    clock = {"t": 0.0}
    cache = RetrievalCache(capacity=4, policy="ttl", ttl=10.0,
                           now_fn=lambda: clock["t"])
    cache.put(_emb(0), _entry(0))
    clock["t"] = 8.0
    cache.put(_emb(0), _entry(0))  # re-insert at t=8 restarts the window
    clock["t"] = 15.0  # 15 > 0+10 but < 8+10: alive only if refreshed
    assert cache.get(_emb(0)) is not None
    clock["t"] = 18.1
    assert cache.get(_emb(0)) is None  # 18.1 > 8+10: expires on schedule


def test_cache_ttl_expiry_and_fifo_eviction():
    clock = {"t": 0.0}
    cache = RetrievalCache(capacity=2, policy="ttl", ttl=10.0,
                           now_fn=lambda: clock["t"])
    cache.put(_emb(0), _entry(0))
    clock["t"] = 5.0
    cache.put(_emb(1), _entry(1))
    assert cache.get(_emb(0)) is not None  # 5s old, alive
    clock["t"] = 11.0  # e0 expired (11 > 10), e1 alive (6s old)
    assert cache.get(_emb(0)) is None
    assert cache.expired == 1 and cache.misses == 1
    # repeated lookups of the same expired resident entry count misses,
    # but the EXPIRY is counted once per entry, not once per lookup
    assert cache.get(_emb(0)) is None
    assert cache.get(_emb(0)) is None
    assert cache.expired == 1 and cache.misses == 3
    # resident/live split: the expired entry still occupies capacity
    # (peek_stale can serve it) but is not live for get()
    s = cache.stats()
    assert s["resident"] == 2 and s["live"] == 1
    assert s["size"] == s["resident"]  # historical meaning preserved
    assert cache.get(_emb(1)) is not None
    # capacity pressure evicts oldest-inserted, not least-recent
    cache.put(_emb(2), _entry(2))
    cache.put(_emb(3), _entry(3))  # purge finds nothing fresh-expired -> FIFO
    assert cache.get(_emb(1)) is None  # oldest inserted went first
    assert cache.stats()["expired"] >= 1
    assert cache.stats()["policy"] == "ttl"


def test_cache_ttl_purge_before_policy_eviction():
    clock = {"t": 0.0}
    cache = RetrievalCache(capacity=2, policy="lru", ttl=1.0,
                           now_fn=lambda: clock["t"])
    cache.put(_emb(0), _entry(0))
    cache.put(_emb(1), _entry(1))
    clock["t"] = 2.0  # both expired
    cache.put(_emb(2), _entry(2))  # expiry purge, no policy eviction needed
    assert cache.expired == 2 and cache.evictions == 0
    assert cache.stats()["size"] == 1


def test_cache_policy_validation_and_engine_kwargs(stack):
    with pytest.raises(ValueError, match="policy"):
        RetrievalCache(policy="mru")
    g, pipe, cfg, params = stack
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=CACHE_LEN,
                         cache_policy="lfu", cache_ttl=60.0)
    assert eng.cache.policy == "lfu" and eng.cache.ttl == 60.0
