"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bfs_frontier import ops as bops, ref as bref
from repro.kernels.ell_spmm import ops as eops, ref as eref
from repro.kernels.flash_attn import ops as fops, ref as fref
from repro.kernels.topk_sim import ops as tops, ref as tref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------- topk_sim --
@pytest.mark.parametrize("q,n,d,k", [
    (1, 2048, 64, 5),
    (7, 3000, 96, 10),
    (16, 2500, 128, 32),
    (130, 4096, 32, 8),   # q > q_blk
    (4, 2048, 200, 64),   # d not 128-multiple
])
def test_topk_sim_sweep(q, n, d, k):
    qv = jnp.asarray(RNG.standard_normal((q, d)), jnp.float32)
    ev = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    s1, i1 = tops.topk_similarity(qv, ev, k, use_kernel=True)
    s2, i2 = tref.topk_similarity(qv, ev, k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_sim_dtypes(dtype):
    qv = jnp.asarray(RNG.standard_normal((4, 64)), dtype)
    ev = jnp.asarray(RNG.standard_normal((2048, 64)), dtype)
    s1, i1 = tops.topk_similarity(qv, ev, 5, use_kernel=True)
    s2, i2 = tref.topk_similarity(
        qv.astype(jnp.float32), ev.astype(jnp.float32), 5
    )
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-2, atol=2e-2)


# -------------------------------------------------------------- flash_attn --
@pytest.mark.parametrize("s,h,kv,dh,w,blk", [
    (128, 4, 4, 32, None, 64),
    (256, 4, 2, 64, None, 128),
    (256, 8, 1, 32, 64, 64),   # MQA + window
    (192, 4, 2, 32, 100, 64),  # s not blk-multiple-friendly window
])
def test_flash_attention_sweep(s, h, kv, dh, w, blk):
    b = 2
    q = jnp.asarray(RNG.standard_normal((b, s, h, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, dh)), jnp.float32)
    o1 = fops.flash_attention(q, k, v, window=w, q_blk=blk, kv_blk=blk)
    o2 = fref.flash_attention(q, k, v, window=w)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    b, s, h, dh = 1, 128, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, s, h, dh)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((b, s, h, dh)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((b, s, h, dh)), jnp.bfloat16)
    o1 = fops.flash_attention(q, k, v, q_blk=64, kv_blk=64)
    o2 = fref.flash_attention(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2), rtol=3e-2, atol=3e-2
    )


# ---------------------------------------------------------------- ell_spmm --
@pytest.mark.parametrize("q,m,k,d", [
    (1, 64, 8, 32),
    (3, 100, 12, 48),
    (8, 256, 16, 128),
    (2, 50, 4, 200),
])
def test_ell_spmm_sweep(q, m, k, d):
    feat = jnp.asarray(RNG.standard_normal((q, m, d)), jnp.float32)
    nbr = jnp.asarray(RNG.integers(0, m + 1, (q, m, k)), jnp.int32)
    msk = jnp.asarray(RNG.random((q, m, k)) < 0.7)
    o1 = eops.ell_aggregate(feat, nbr, msk, use_kernel=True)
    o2 = eref.ell_aggregate(feat, nbr, msk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)


def test_ell_spmm_matches_segment_sum():
    """Cross-check against an edge-list segment_sum formulation."""
    q, m, k, d = 2, 40, 6, 16
    feat = jnp.asarray(RNG.standard_normal((q, m, d)), jnp.float32)
    nbr = jnp.asarray(RNG.integers(0, m, (q, m, k)), jnp.int32)
    msk = jnp.asarray(RNG.random((q, m, k)) < 0.8)
    out = np.asarray(eops.ell_aggregate(feat, nbr, msk, use_kernel=True))
    for qi in range(q):
        expect = np.zeros((m, d), np.float32)
        for i in range(m):
            for kk in range(k):
                if msk[qi, i, kk]:
                    expect[i] += np.asarray(feat[qi, int(nbr[qi, i, kk])])
        np.testing.assert_allclose(out[qi], expect, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ bfs_frontier --
@pytest.mark.parametrize("n,k,q,blk", [
    (512, 8, 2, 128),
    (700, 9, 4, 128),
    (1024, 16, 1, 512),
])
def test_bfs_frontier_sweep(n, k, q, blk):
    nbr = jnp.asarray(RNG.integers(0, n + 1, (n, k)), jnp.int32)
    msk = jnp.asarray(RNG.random((n, k)) < 0.8)
    fr = jnp.asarray(RNG.random((q, n)) < 0.05)
    r1 = bops.frontier_hop(fr, nbr, msk, use_kernel=True, blk_n=blk)
    r2 = bref.frontier_hop(fr, nbr, msk)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_bfs_frontier_in_full_bfs():
    """Kernel-driven BFS == jnp BFS on a real graph."""
    from repro.graph import csr_to_ell, generators
    from repro.core import graph_retrieval as gr

    g = generators.citation_graph(600, avg_deg=5, seed=4)
    ell = csr_to_ell(g)
    seeds = np.asarray([[3, 17], [99, 4]], np.int32)
    sm = gr.seeds_to_mask(jnp.asarray(seeds), g.num_nodes)
    # one hop via kernel vs ref
    h1 = bops.frontier_hop(sm, ell.nbr, ell.nbr_mask, use_kernel=True)
    h2 = bref.frontier_hop(sm, ell.nbr, ell.nbr_mask)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
