"""Paged KV arena: bitwise parity vs the contiguous arena across the whole
decode/admission schedule matrix, block-allocator oracles (alloc/free/reuse,
exhaustion), KV-exhaustion truncation flags, and per-request (continuous)
admission semantics."""
import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import TransformerConfig, model as tm
from repro.serving import Request, ServeEngine
from repro.serving.engine import _auto_block_size

CFG = TransformerConfig(
    name="paged-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
    d_head=16, d_ff=64, vocab=64, dtype="float32",
)
PARAMS = tm.init_params(jax.random.PRNGKey(0), CFG)


def _mixed_requests(seed=3):
    """Random + repetitive prompts, mixed generation lengths — staggered
    slot turnover so retirement (block free) interleaves with admission
    (block alloc), incl. a max_new=1 admission-time finish."""
    rng = np.random.default_rng(seed)
    reqs = []
    for u, mn in enumerate([5, 12, 1, 30, 8, 12, 25]):
        if u % 2:
            pat = rng.integers(1, 64, size=int(rng.integers(2, 4)))
            p = np.tile(pat, 6)[: int(rng.integers(4, 10))]
        else:
            p = rng.integers(1, 64, size=int(rng.integers(3, 10)))
        reqs.append(Request(uid=u, prompt_ids=p.astype(np.int32),
                            max_new_tokens=mn))
    return reqs


def _run(reqs, **kw):
    eng = ServeEngine(PARAMS, CFG, slots=3, cache_len=48, **kw)
    for r in reqs:
        eng.submit(r)
    done = {r.uid: r for r in eng.run_to_completion()}
    return eng, done


# ------------------------------------------------------------------ parity ----
@pytest.mark.parametrize("spec", [False, True])
def test_paged_parity_both_decode_modes(spec):
    """paged_kv=on emits bitwise-identical out_tokens (and truncation flags)
    to the contiguous arena, in one-token and speculative decode."""
    ref_eng, ref = _run(_mixed_requests(), paged_kv=False,
                        spec_decode=spec, draft_window=4)
    pag_eng, pag = _run(_mixed_requests(), paged_kv=True,
                        spec_decode=spec, draft_window=4)
    assert set(ref) == set(pag) == set(range(7))
    for u in ref:
        assert pag[u].out_tokens == ref[u].out_tokens, f"uid {u}"
        assert pag[u].truncated == ref[u].truncated, f"uid {u}"
    # identical schedule: same dispatch count, same committed tokens
    assert pag_eng.decode_steps == ref_eng.decode_steps
    assert pag_eng.decode_tokens == ref_eng.decode_tokens
    assert pag_eng.truncations == ref_eng.truncations
    ds = pag_eng.decode_stats()
    assert ds["paged_kv"] and ds["block_size"] == 16
    # full-size pool (3 slots x 3 blocks): never gates, fully drains
    assert ds["pool_blocks"] == 9
    assert ds["pool_free_blocks"] == 9


def test_paged_parity_with_sliding_window_attention():
    cfg = TransformerConfig(
        name="paged-sw", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=64, dtype="float32", sliding_window=16,
    )
    params = tm.init_params(jax.random.PRNGKey(1), cfg)
    outs = {}
    for paged in (False, True):
        eng = ServeEngine(params, cfg, slots=2, cache_len=48,
                          paged_kv=paged, spec_decode=True, draft_window=4)
        r2 = np.random.default_rng(0)
        for u in range(4):
            eng.submit(Request(uid=u,
                               prompt_ids=r2.integers(1, 64, 8).astype(np.int32),
                               max_new_tokens=30))
        outs[paged] = {r.uid: r.out_tokens for r in eng.run_to_completion()}
    assert outs[True] == outs[False]


def test_paged_parity_with_quantized_kv_cache():
    cfg = TransformerConfig(
        name="paged-q", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=64, dtype="float32", kv_quant=True,
    )
    params = tm.init_params(jax.random.PRNGKey(2), cfg)
    outs = {}
    for paged in (False, True):
        eng = ServeEngine(params, cfg, slots=2, cache_len=48,
                          paged_kv=paged)
        r2 = np.random.default_rng(5)
        for u in range(3):
            eng.submit(Request(uid=u,
                               prompt_ids=r2.integers(1, 64, 6).astype(np.int32),
                               max_new_tokens=20))
        outs[paged] = {r.uid: r.out_tokens for r in eng.run_to_completion()}
    assert outs[True] == outs[False]


def test_paged_matches_offline_greedy():
    """Paged decode == offline greedy generation (the reference oracle that
    does not go through any serving-engine code path)."""
    from repro.models.transformer.generate import generate_tokens

    prompt = np.asarray([5, 9, 3, 22, 41], np.int32)
    eng = ServeEngine(PARAMS, CFG, slots=2, cache_len=32, paged_kv=True,
                      block_size=8)
    eng.submit(Request(uid=0, prompt_ids=prompt, max_new_tokens=8))
    done = eng.run_to_completion()
    offline = generate_tokens(
        PARAMS, jnp.asarray(prompt)[None], jnp.asarray([len(prompt)]),
        jax.random.PRNGKey(0), CFG, max_new=8, cache_len=32, temperature=0.0,
    )
    assert done[0].out_tokens[:8] == np.asarray(offline[0]).tolist()


# ------------------------------------------------------- allocator oracles ----
def test_alloc_blocks_pops_distinct_and_masks_dead_slots():
    pool = 6
    table = jnp.full((3, 3), -1, jnp.int32)
    free = jnp.arange(pool, dtype=jnp.int32)
    n_free = jnp.asarray(pool, jnp.int32)
    target = jnp.asarray([2, 3, 1], jnp.int32)
    live = jnp.asarray([True, True, False])
    ref = jnp.zeros((pool,), jnp.int32)
    t2, nf2, ref2 = tm.alloc_blocks(table, free, n_free, ref, target, live, 3)
    t2 = np.asarray(t2)
    assert int(nf2) == pool - 5  # 2 + 3, dead slot allocates nothing
    # every popped block carries exactly one hold (its slot's table entry)
    assert int(np.asarray(ref2).sum()) == 5
    assert set(np.where(np.asarray(ref2) == 1)[0]) == \
        {b for row in t2[:2] for b in row if b >= 0}
    assert (t2[2] == -1).all()
    got = [b for row in t2[:2] for b in row if b >= 0]
    assert len(got) == 5 and len(set(got)) == 5  # distinct blocks
    assert set(got) <= set(range(pool))
    # table prefix is filled left-to-right, no holes
    assert (t2[0][:2] >= 0).all() and t2[0][2] == -1
    assert (t2[1] >= 0).all()


def test_alloc_is_incremental_against_existing_table():
    """target counts TOTAL blocks: a slot already holding n gets target-n
    new ones appended after its existing entries."""
    pool = 4
    table = jnp.asarray([[7, -1, -1]], jnp.int32)  # one block held already
    free = jnp.arange(pool, dtype=jnp.int32)
    n_free = jnp.asarray(pool, jnp.int32)
    t2, nf2, _ = tm.alloc_blocks(table, free, n_free,
                                 jnp.zeros((pool,), jnp.int32),
                                 jnp.asarray([3], jnp.int32),
                                 jnp.asarray([True]), 3)
    t2 = np.asarray(t2)
    assert int(nf2) == pool - 2
    assert t2[0][0] == 7  # existing entry untouched
    assert (t2[0][1:] >= 0).all()


def test_free_then_realloc_reuses_blocks():
    """free_slot_blocks pushes a slot's blocks back; the next alloc pops
    exactly those (LIFO stack → zero fragmentation growth on churn)."""
    cache = tm.init_paged_cache(CFG, 2, 32, 16, 4)
    t2, nf2, ref2 = tm.alloc_blocks(cache.table, cache.free, cache.n_free,
                                    cache.ref,
                                    jnp.asarray([2, 0], jnp.int32),
                                    jnp.asarray([True, False]), 2)
    import dataclasses
    held = set(np.asarray(t2)[0].tolist())
    cache = dataclasses.replace(cache, table=t2, n_free=nf2, ref=ref2)
    cache = tm.free_slot_blocks(cache, jnp.asarray([True, False]))
    assert int(cache.n_free) == 4
    assert (np.asarray(cache.ref) == 0).all()  # zero holders everywhere
    assert (np.asarray(cache.table)[0] == -1).all()
    assert (np.asarray(cache.pos)[0] == -1).all()
    assert int(np.asarray(cache.cursor)[0]) == 0
    t3, nf3, _ = tm.alloc_blocks(cache.table, cache.free, cache.n_free,
                                 cache.ref,
                                 jnp.asarray([0, 2], jnp.int32),
                                 jnp.asarray([False, True]), 2)
    assert set(np.asarray(t3)[1].tolist()) == held  # same blocks, new slot


def test_block_reuse_through_engine_churn():
    """Back-to-back request batches through an engine with a minimal pool:
    every batch drains, the free count returns to full, and the high-water
    mark never exceeds the pool (host mirror == device allocator)."""
    eng = ServeEngine(PARAMS, CFG, slots=2, cache_len=32, paged_kv=True,
                      block_size=8, pool_blocks=8)
    rng = np.random.default_rng(9)
    for batch in range(3):
        for u in range(4):
            eng.submit(Request(
                uid=batch * 10 + u,
                prompt_ids=rng.integers(1, 64, size=7).astype(np.int32),
                max_new_tokens=10))
        done = eng.run_to_completion()
        assert len(done) == 4
        assert eng._free_host == 8
        assert (eng._ntab == 0).all()
        assert int(np.asarray(eng.cache.n_free)) == 8
    assert eng.pool_high_water <= 8
    assert eng.truncations == 0


# --------------------------------------------------- exhaustion/truncation ----
@pytest.mark.parametrize("spec", [False, True])
def test_pool_exhaustion_truncates_and_recovers(spec):
    """An undersized pool retires requests early with truncated=True instead
    of wedging or corrupting: everything completes, flags and counters
    agree, and the pool is whole again afterwards."""
    eng = ServeEngine(PARAMS, CFG, slots=4, cache_len=32, paged_kv=True,
                      block_size=16, pool_blocks=5, spec_decode=spec,
                      draft_window=4)
    rng = np.random.default_rng(1)
    for u in range(8):
        eng.submit(Request(uid=u,
                           prompt_ids=rng.integers(1, 64, 12).astype(np.int32),
                           max_new_tokens=25))
    done = eng.run_to_completion()
    assert len(done) == 8
    truncated = [r for r in done if r.truncated]
    assert truncated  # 4 live slots x 2 blocks > 5: pressure is guaranteed
    for r in truncated:
        assert len(r.out_tokens) < r.max_new_tokens
    assert eng.truncations == len(truncated)
    assert eng.decode_stats()["truncations"] == len(truncated)
    assert eng.decode_stats()["pool_high_water_blocks"] <= 5
    assert eng._free_host == 5 and (eng._ntab == 0).all()


def test_contiguous_arena_exhaustion_sets_truncated():
    """The pre-existing silent-truncation path (cursor >= cache_len on the
    contiguous arena) now reports itself."""
    eng = ServeEngine(PARAMS, CFG, slots=1, cache_len=16, paged_kv=False)
    prompt = np.arange(1, 11, dtype=np.int32)  # 10 + 1 + room for 5 more
    eng.submit(Request(uid=0, prompt_ids=prompt, max_new_tokens=50))
    done = eng.run_to_completion()
    assert done[0].truncated
    # 1 admission token + one per decode step until cursor hits cache_len
    assert len(done[0].out_tokens) == 16 - 10 + 1
    assert eng.truncations == 1
    assert eng.decode_stats()["truncations"] == 1
    # a request that ends by its own budget is NOT truncated
    eng.submit(Request(uid=1, prompt_ids=prompt, max_new_tokens=3))
    done = eng.run_to_completion()
    assert not done[0].truncated and eng.truncations == 1


# ----------------------------------------------------------- configuration ----
def test_env_toggle_and_validation(monkeypatch):
    def make(**kw):
        return ServeEngine(PARAMS, CFG, slots=1, cache_len=32, **kw)

    monkeypatch.delenv("RGL_PAGED_KV", raising=False)
    monkeypatch.delenv("RGL_KV_BLOCK", raising=False)
    assert not make().paged_kv
    monkeypatch.setenv("RGL_PAGED_KV", "1")
    eng = make()
    assert eng.paged_kv and eng.block_size == 16
    assert not make(paged_kv=False).paged_kv  # explicit beats env
    monkeypatch.setenv("RGL_KV_BLOCK", "8")
    assert make().block_size == 8
    with pytest.raises(ValueError, match="divide"):
        make(block_size=7)  # 32 % 7 != 0
    with pytest.raises(ValueError, match="pool_blocks"):
        make(block_size=8, pool_blocks=3)  # < one full-length request


def test_auto_block_size_divides_any_cache_len():
    assert _auto_block_size(512) == 16
    assert _auto_block_size(48) == 16
    assert _auto_block_size(24) == 12
    assert _auto_block_size(13) == 13  # <= preferred, divides itself
    assert _auto_block_size(34) == 2   # 2 x 17: largest divisor <= 16
    for n in (13, 24, 34, 48, 100, 512):
        bs = _auto_block_size(n)
        assert 1 <= bs <= 16 and n % bs == 0


# ------------------------------------------------- fused RAG engine matrix ----
@pytest.fixture(scope="module")
def rag_stack():
    from repro.core import BruteIndex, GraphTokenizer, PipelineConfig, \
        RGLPipeline, Vocab
    from repro.graph import csr_to_ell, generators

    g = generators.citation_graph(100, avg_deg=6, seed=11)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=48, node_budget=6)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb, tokenizer=tok,
        node_text=g.node_text,
        config=PipelineConfig(strategy="bfs", k_seeds=3, max_hops=2,
                              max_nodes=12, filter_budget=6),
    )
    cfg = TransformerConfig(
        name="paged-rag-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    return g, pipe, cfg, params


def _rag_run(rag_stack, **kw):
    from repro.serving import RAGRequest, RAGServeEngine

    g, pipe, cfg, params = rag_stack
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=96, **kw)
    q_ids = [0, 1, 2, 0, 3, 1]
    for u, qi in enumerate(q_ids):
        eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[qi]),
                              query_text=g.node_text[qi],
                              max_new_tokens=4 + 2 * (u % 3)))
    done = {r.uid: r for r in eng.run_to_completion()}
    assert len(done) == 6
    return eng, done


def test_rag_schedule_matrix_bitwise_identical(rag_stack):
    """paged x prefetch x spec_decode (the tier-1 CI axes): per-request
    out_tokens, retrievals, prompts, and cache accounting all match the
    contiguous sync one-token reference."""
    ref_eng, ref = _rag_run(rag_stack, paged_kv=False, prefetch=False,
                            spec_decode=False)
    cells = [c for c in itertools.product((False, True), repeat=3)
             if c != (False, False, False)]
    for paged, prefetch, spec in cells:
        eng, done = _rag_run(rag_stack, paged_kv=paged, prefetch=prefetch,
                             spec_decode=spec, draft_window=4)
        for u in ref:
            assert done[u].out_tokens == ref[u].out_tokens, \
                (paged, prefetch, spec, u)
            assert done[u].truncated == ref[u].truncated
            np.testing.assert_array_equal(done[u].retrieved_nodes,
                                          ref[u].retrieved_nodes)
            np.testing.assert_array_equal(done[u].prompt_ids,
                                          ref[u].prompt_ids)
        assert eng.cache_hits == ref_eng.cache_hits, (paged, prefetch, spec)
        assert eng.cache_misses == ref_eng.cache_misses
        s = eng.stats()
        assert s["paged_kv"] == paged
        assert s["emitted_tokens"] == ref_eng.stats()["emitted_tokens"]


def test_continuous_admission_bitwise_identical(rag_stack):
    """Per-request (continuous) admission — sync and prefetched, contiguous
    and paged — produces the same per-request outputs as wave admission
    (greedy decode is schedule-invariant per request)."""
    _, ref = _rag_run(rag_stack, paged_kv=False, prefetch=False,
                      spec_decode=False, admission="wave")
    for paged, prefetch in itertools.product((False, True), repeat=2):
        eng, done = _rag_run(rag_stack, paged_kv=paged, prefetch=prefetch,
                             admission="continuous")
        for u in ref:
            assert done[u].out_tokens == ref[u].out_tokens, (paged, prefetch)
            np.testing.assert_array_equal(done[u].retrieved_nodes,
                                          ref[u].retrieved_nodes)
        assert eng.stats()["admission"] == "continuous"


def test_continuous_admission_dodges_slow_retrieval_row(rag_stack):
    """One expensive retrieval row: wave admission holds its wave-mates
    behind it; continuous admission admits the fast requests immediately
    and the slow request finishes last."""
    from repro.serving import RAGRequest, RAGServeEngine
    from repro.serving.simulate import DelayedRetrieval

    g, pipe, cfg, params = rag_stack
    slow_emb = np.asarray(g.node_feat[0])

    def cost_fn(row):
        return 0.2 if np.array_equal(row, slow_emb) else 0.0

    def run(admission):
        delayed = DelayedRetrieval(pipe, cost_s=0.0, cost_fn=cost_fn)
        eng = RAGServeEngine(delayed, params, cfg, slots=2, cache_len=96,
                             prefetch=True, admission=admission,
                             cache_capacity=0)
        for u in range(5):
            eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[u]),
                                  query_text=g.node_text[u],
                                  max_new_tokens=6))
        t0 = time.perf_counter()
        order = [r.uid for r in eng.run_to_completion()]
        return order, time.perf_counter() - t0

    run("wave")  # absorb any remaining jit compiles before timing
    o_wave, t_wave = run("wave")
    o_cont, t_cont = run("continuous")
    assert sorted(o_cont) == sorted(o_wave) == list(range(5))
    # continuous: the slow request (uid 0, launched first) finishes after
    # every fast wave-mate instead of gating them at admission
    assert o_cont.index(0) > max(o_cont.index(u) for u in (1, 2, 3, 4))
    # and the whole batch clears sooner than the wave schedule
    assert t_cont < t_wave


def test_rag_pool_exhaustion_propagates_truncated(rag_stack):
    """RAGRequest.truncated mirrors the inner engine's flag under an
    undersized paged pool, and the count lands in stats()."""
    from repro.serving import RAGRequest, RAGServeEngine

    g, pipe, cfg, params = rag_stack
    eng = RAGServeEngine(pipe, params, cfg, slots=2, cache_len=96,
                         paged_kv=True, kv_block_size=16, kv_pool_blocks=6,
                         cache_capacity=0)
    for u in range(4):
        eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[u]),
                              query_text=g.node_text[u],
                              max_new_tokens=64))
    done = eng.run_to_completion()
    assert len(done) == 4
    assert any(r.truncated for r in done)
    for r in done:
        if r.truncated:
            assert len(r.out_tokens) < r.max_new_tokens
    assert eng.stats()["truncations"] == sum(r.truncated for r in done)
