"""Prefix-shared paged KV: refcounted block allocator oracle vs a Python
reference model, copy-on-write adoption semantics, double-free/leak
tripwires, cached-prefill reuse parity (engine and RAG tiers, both decode
modes, both admission schedules, contiguous fallback), pool exhaustion
under sharing, and the retrieval-cache pin lifecycle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BruteIndex, GraphTokenizer, PipelineConfig, \
    RGLPipeline, Vocab
from repro.graph import csr_to_ell, generators
from repro.models.transformer import TransformerConfig, model as tm
from repro.serving import (
    CachedRetrieval, FaultyRetrieval, RAGRequest, RAGServeEngine, Request,
    RetrievalCache, ServeEngine,
)

CFG = TransformerConfig(
    name="share-t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
    d_head=16, d_ff=64, vocab=64, dtype="float32",
)
PARAMS = tm.init_params(jax.random.PRNGKey(0), CFG)


def _assert_mirrors(eng: ServeEngine) -> None:
    """The engine's host allocator mirrors are content-exact replicas of the
    device state: stack contents, per-block refcounts, per-slot tables."""
    depth = len(eng._free_stack)
    assert int(np.asarray(eng.cache.n_free)) == depth
    assert np.asarray(eng.cache.free)[:depth].tolist() == eng._free_stack
    assert np.asarray(eng.cache.ref).tolist() == eng._ref_host.tolist()
    table = np.asarray(eng.cache.table)
    for i, blks in enumerate(eng._slot_blocks):
        assert table[i, :len(blks)].tolist() == blks
        assert (table[i, len(blks):] == -1).all()


def _blank_entry() -> CachedRetrieval:
    z = np.empty(0, np.int32)
    return CachedRetrieval(nodes=z, mask=np.empty(0, bool), dist=z, seeds=z)


# ------------------------------------------------- allocator churn oracle ----
def test_refcount_allocator_churn_oracle():
    """Random alloc/free/acquire/release churn against a plain-Python
    reference allocator: the device free stack (contents, not just depth),
    refcount array, and block tables stay bitwise identical throughout."""
    pool, slots, m, bs = 10, 3, 4, 4
    cache = tm.init_paged_cache(CFG, slots, m * bs, bs, pool)
    ref_free = list(range(pool))
    ref_ref = [0] * pool
    ref_tab = [[] for _ in range(slots)]
    pins = []  # extra holds taken by "cache pins"
    rng = np.random.default_rng(0)

    def check():
        depth = len(ref_free)
        assert int(cache.n_free) == depth
        assert np.asarray(cache.free)[:depth].tolist() == ref_free
        assert np.asarray(cache.ref).tolist() == ref_ref
        tab = np.asarray(cache.table)
        for i in range(slots):
            assert tab[i, :len(ref_tab[i])].tolist() == ref_tab[i]
            assert (tab[i, len(ref_tab[i]):] == -1).all()

    for _ in range(60):
        op = int(rng.integers(0, 4))
        if op == 0:  # grow one slot's table toward a random target
            i = int(rng.integers(slots))
            tgt = int(min(m, len(ref_tab[i]) + rng.integers(0, 3)))
            need = tgt - len(ref_tab[i])
            if need <= 0 or need > len(ref_free):
                continue
            live = np.zeros(slots, bool)
            live[i] = True
            target = np.zeros(slots, np.int32)
            target[i] = tgt
            t, nf, r = tm.alloc_blocks(
                cache.table, cache.free, cache.n_free, cache.ref,
                jnp.asarray(target), jnp.asarray(live), m,
            )
            cache = dataclasses.replace(cache, table=t, n_free=nf, ref=r)
            for _ in range(need):
                b = ref_free.pop()
                ref_ref[b] = 1
                ref_tab[i].append(b)
        elif op == 1:  # retire one slot (drops its holds)
            i = int(rng.integers(slots))
            mask = np.zeros(slots, bool)
            mask[i] = True
            cache = tm.free_slot_blocks(cache, jnp.asarray(mask))
            drops = {}
            for b in ref_tab[i]:
                drops[b] = drops.get(b, 0) + 1
            ref_tab[i] = []
            for b in sorted(drops):  # pushes are ascending-id on device
                ref_ref[b] -= drops[b]
                if ref_ref[b] <= 0:
                    ref_free.append(b)
        elif op == 2:  # pin a random prefix of a held slot's blocks
            i = int(rng.integers(slots))
            if not ref_tab[i]:
                continue
            ids = ref_tab[i][:int(rng.integers(1, len(ref_tab[i]) + 1))]
            cache = tm.acquire_blocks(cache, jnp.asarray(ids, jnp.int32))
            for b in ids:
                ref_ref[b] += 1
            pins.append(list(ids))
        elif pins:  # release a pin
            ids = pins.pop(int(rng.integers(len(pins))))
            cache = tm.release_blocks(cache, jnp.asarray(ids, jnp.int32))
            drops = {}
            for b in ids:
                drops[b] = drops.get(b, 0) + 1
            for b in sorted(drops):
                ref_ref[b] -= drops[b]
                if ref_ref[b] <= 0:
                    ref_free.append(b)
        check()
    # drain everything: the pool must come back whole with zero refs
    for ids in pins:
        cache = tm.release_blocks(cache, jnp.asarray(ids, jnp.int32))
    cache = tm.free_slot_blocks(cache, jnp.asarray(np.ones(slots, bool)))
    assert int(cache.n_free) == pool
    assert (np.asarray(cache.ref) == 0).all()


# --------------------------------------------------------- adoption + COW ----
def test_adopt_prefix_blocks_aliases_full_blocks_and_cows_tail():
    bs, m, pool, L = 4, 4, 8, 10  # nfull=2, partial tail of 2 rows
    cache = tm.init_paged_cache(CFG, 2, m * bs, bs, pool)
    t, nf, r = tm.alloc_blocks(
        cache.table, cache.free, cache.n_free, cache.ref,
        jnp.asarray([3, 0], jnp.int32), jnp.asarray([True, False]), 3,
    )
    cache = dataclasses.replace(cache, table=t, n_free=nf, ref=r)
    donor = np.asarray(t)[0][:3].tolist()
    # write recognizable K/V into the donor's prompt rows
    rows = [b * bs + o for b in donor for o in range(bs)][:L]
    k = np.array(cache.k)  # writable copy
    for pos, row in enumerate(rows):
        k[:, row] = float(pos + 1)
    cache = dataclasses.replace(
        cache,
        k=jnp.asarray(k),
        pos=cache.pos.at[0, :L].set(jnp.arange(L, dtype=jnp.int32)),
        cursor=cache.cursor.at[0].set(L),
    )
    # engine protocol: pin hold (+1) then plan hold (+1) before adoption
    cache = tm.acquire_blocks(cache, jnp.asarray(donor, jnp.int32))
    cache = tm.acquire_blocks(cache, jnp.asarray(donor, jnp.int32))
    src_table = np.full((2, m), -1, np.int32)
    src_table[1, :2] = donor[:2]
    new, cur = tm.adopt_prefix_blocks(
        cache, jnp.zeros(2, jnp.int32), jnp.asarray([False, True]),
        jnp.asarray(src_table), jnp.asarray([0, L], jnp.int32),
        jnp.asarray([-1, donor[2]], jnp.int32),
        jnp.asarray([0, 7], jnp.int32), bs,
    )
    tab1 = np.asarray(new.table)[1]
    assert tab1[:2].tolist() == donor[:2]  # full blocks aliased
    fresh = int(tab1[2])
    assert fresh >= 0 and fresh not in donor  # tail copied, not aliased
    assert tab1[3] == -1
    ref = np.asarray(new.ref)
    # full blocks: donor slot + pin + consumer slot = 3 holds; tail source:
    # the plan's one-dispatch hold was dropped inside adopt -> back to 2
    assert ref[donor[0]] == 3 and ref[donor[1]] == 3
    assert ref[donor[2]] == 2 and ref[fresh] == 1
    # COW copy carried the tail rows bitwise
    np.testing.assert_array_equal(
        np.asarray(new.k)[:, fresh * bs:(fresh + 1) * bs],
        np.asarray(new.k)[:, donor[2] * bs:(donor[2] + 1) * bs],
    )
    pos1 = np.asarray(new.pos)[1]
    assert pos1[:L].tolist() == list(range(L)) and (pos1[L:] == -1).all()
    assert int(np.asarray(new.cursor)[1]) == L
    assert int(np.asarray(cur)[1]) == 7  # donor's recorded first token
    assert int(np.asarray(cur)[0]) == 0  # unmasked slot untouched


# -------------------------------------------- engine-tier sharing + parity ----
def _share_engine(**kw):
    kw.setdefault("slots", 3)
    kw.setdefault("cache_len", 48)
    kw.setdefault("paged_kv", True)
    kw.setdefault("block_size", 8)
    return ServeEngine(PARAMS, CFG, **kw)


@pytest.mark.parametrize("spec", [False, True])
def test_shared_admission_bitwise_matches_fresh(spec):
    """Donor pins its prefilled prompt blocks to an entry; an identical
    later prompt adopts them and skips prefill — outputs bitwise identical
    to an engine that prefills everything, in both decode modes."""
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, 64, n).astype(np.int32) for n in (13, 16, 9)]

    def run(share):
        eng = _share_engine(prefix_share=share, spec_decode=spec,
                            draft_window=4)
        entries = {}
        outs = {}
        uid = 0
        for wave in range(3):  # each wave re-serves every prompt
            for pi, p in enumerate(prompts):
                e = entries.setdefault(pi, _blank_entry())
                r = Request(uid=uid, prompt_ids=p, max_new_tokens=8)
                if share:
                    r.pin_to = e
                    if e.kv_blocks is not None:
                        r.shared_prefix = e
                eng.submit(r)
                uid += 1
            for r in eng.run_to_completion():
                outs[r.uid] = list(r.out_tokens)
            _assert_mirrors(eng)
        return eng, outs, entries

    ref_eng, ref, _ = run(False)
    sh_eng, got, entries = run(True)
    assert got == ref
    ds = sh_eng.decode_stats()
    assert ds["kv_shared_admits"] >= 6  # waves 2..3 alias all 3 prompts
    assert ds["kv_reused_tokens"] >= 6 * 9
    assert ds["kv_cow_copies"] >= 1  # the 13- and 9-token prompts mid-block
    assert ds["prefill_rows"] < ref_eng.decode_stats()["prefill_rows"]
    assert sh_eng.kv_pins == 3 and sh_eng.kv_pinned_blocks > 0
    # releasing every pin returns the pool to whole, zero refs anywhere
    for e in entries.values():
        e.kv_release(e)
    assert sh_eng._free_host == sh_eng.pool_blocks
    assert (sh_eng._ref_host == 0).all()
    assert (np.asarray(sh_eng.cache.ref) == 0).all()
    _assert_mirrors(sh_eng)


def test_share_plan_falls_back_on_prompt_mismatch():
    """A shared_prefix entry whose pinned prompt differs from the request's
    prompt is re-validated at admission and ignored — fresh prefill, same
    outputs, no shared admits."""
    rng = np.random.default_rng(7)
    pa = rng.integers(1, 64, 12).astype(np.int32)
    pb = rng.integers(1, 64, 12).astype(np.int32)
    assert not np.array_equal(pa, pb)

    eng = _share_engine(prefix_share=True)
    entry = _blank_entry()
    eng.submit(Request(uid=0, prompt_ids=pa, max_new_tokens=6, pin_to=entry))
    eng.run_to_completion()
    assert entry.kv_blocks is not None and entry.kv_len == 12
    # wrong prompt riding the entry: must not alias
    eng.submit(Request(uid=1, prompt_ids=pb, max_new_tokens=6,
                       shared_prefix=entry))
    done = {r.uid: r for r in eng.run_to_completion()}
    ref = _share_engine(prefix_share=False)
    ref.submit(Request(uid=1, prompt_ids=pb, max_new_tokens=6))
    ref_done = {r.uid: r for r in ref.run_to_completion()}
    assert done[1].out_tokens == ref_done[1].out_tokens
    assert eng.kv_shared_admits == 0
    _assert_mirrors(eng)
    entry.kv_release(entry)
    assert eng._free_host == eng.pool_blocks


# ----------------------------------------------------------------- tripwires ----
def test_alloc_guard_raises_with_pool_counters():
    eng = _share_engine()
    assert eng._kv_debug  # conftest arms RGL_KV_DEBUG for the whole suite
    with pytest.raises(RuntimeError, match="alloc invariant"):
        eng._guard_alloc(eng.pool_blocks + 1, "unit test")


def test_double_free_tripwire_raises():
    eng = _share_engine()
    blk = eng._pop_host(0, 1)[0]
    with pytest.raises(RuntimeError, match="double-free"):
        eng._host_release({blk: 2})  # two drops against a single hold


# ------------------------------------------------------- RAG-tier sharing ----
N_NODES = 120
CACHE_LEN = 96
SLOTS = 3


@pytest.fixture(scope="module")
def stack():
    g = generators.citation_graph(N_NODES, avg_deg=6, seed=7)
    ell = csr_to_ell(g)
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    tok = GraphTokenizer(vocab, max_len=64, node_budget=6)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb, tokenizer=tok,
        node_text=g.node_text,
        config=PipelineConfig(strategy="bfs", k_seeds=3, max_hops=2,
                              max_nodes=16, filter_budget=8),
    )
    cfg = TransformerConfig(
        name="share-rag", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=vocab.size, dtype="float32",
    )
    params = tm.init_params(jax.random.PRNGKey(0), cfg)
    return g, pipe, cfg, params


def _rag_run(stack, share, src=None, n=12, uniq=4, **kw):
    g, pipe, cfg, params = stack
    eng = RAGServeEngine(src or pipe, params, cfg, slots=SLOTS,
                         cache_len=CACHE_LEN, prefix_share=share, **kw)
    q_ids = [u % uniq for u in range(n)]  # repeat-heavy: sharing regime
    for u, qi in enumerate(q_ids):
        eng.submit(RAGRequest(uid=u, query_emb=np.asarray(g.node_feat[qi]),
                              query_text=g.node_text[qi], max_new_tokens=4))
    done = {r.uid: r for r in eng.drain()}
    outs = {
        u: (list(r.out_tokens),
            np.asarray(r.retrieved_nodes).tolist(),
            np.asarray(r.prompt_ids).tolist())
        for u, r in done.items() if r.done and not r.failed
    }
    return eng, outs


def _assert_share_clean(eng):
    inner = eng.engine
    assert not inner.queue and not inner.live.any()
    if inner.paged_kv:
        _assert_mirrors(inner)
        assert inner._free_host == inner.pool_blocks - inner.kv_pinned_blocks
        # no holder is unaccounted: every remaining ref belongs to a pin
        assert int(inner._ref_host.sum()) == sum(
            np.asarray(s.entry.kv_blocks).size
            for s in eng.cache._data.values()
            if s.entry.kv_blocks is not None
        )
        freed = eng.cache.reclaim_kv(10 ** 9)
        assert freed == 0 or inner._free_host == inner.pool_blocks
        assert inner._free_host == inner.pool_blocks  # zero leaked blocks
        assert (inner._ref_host == 0).all()


@pytest.mark.parametrize("spec,admission", [(False, "wave"),
                                            (True, "continuous")])
def test_rag_prefix_share_parity(stack, spec, admission):
    """The end-to-end acceptance bar: share-on output (out_tokens,
    retrieved_nodes, prompt_ids per uid) is bitwise identical to share-off
    on a repeat-heavy stream, sharing actually fires, and the pool has zero
    leaked blocks after the drain."""
    kw = dict(paged_kv=True, spec_decode=spec, admission=admission)
    _, ref = _rag_run(stack, share=False, **kw)
    eng, got = _rag_run(stack, share=True, **kw)
    assert got == ref
    ds = eng.engine.decode_stats()
    assert ds["kv_shared_admits"] > 0
    assert ds["prefill_rows"] < len(ref)
    assert eng.cache.kv_pinned_entries() > 0
    _assert_share_clean(eng)


def test_rag_prefix_share_contiguous_fallback(stack):
    """prefix_share=True on a contiguous arena is inert: identical outputs,
    no sharing machinery engaged."""
    _, ref = _rag_run(stack, share=False, paged_kv=False)
    eng, got = _rag_run(stack, share=True, paged_kv=False)
    assert got == ref
    assert not eng.engine.prefix_share  # forced off without the paged arena
    assert eng.engine.decode_stats()["prefill_rows"] == len(ref)


def test_pool_exhaustion_under_sharing_truncates_and_recovers(stack):
    """Undersized pool + sharing: cache pins are reclaimed before any live
    request is truncated, every request terminates, outputs match the
    unshared run bitwise, and nothing leaks."""
    kw = dict(paged_kv=True, kv_pool_blocks=8, n=10, uniq=3)
    ref_eng, ref = _rag_run(stack, share=False, **kw)
    eng, got = _rag_run(stack, share=True, **kw)
    assert got == ref
    assert set(got) == set(range(10))  # everything terminated
    assert eng.engine.truncations == ref_eng.engine.truncations
    assert eng.engine.kv_pins > 0  # pinning happened...
    assert eng.engine.kv_releases > 0  # ...and pressure reclaimed pins
    _assert_share_clean(eng)


@pytest.mark.slow
@pytest.mark.parametrize("prefetch", [False, True])
@pytest.mark.parametrize("spec", [False, True])
@pytest.mark.parametrize("admission", ["wave", "continuous"])
def test_rag_prefix_share_parity_matrix(stack, prefetch, spec, admission):
    kw = dict(paged_kv=True, prefetch=prefetch, spec_decode=spec,
              admission=admission)
    _, ref = _rag_run(stack, share=False, **kw)
    eng, got = _rag_run(stack, share=True, **kw)
    assert got == ref
    assert eng.engine.decode_stats()["kv_shared_admits"] > 0
    _assert_share_clean(eng)


@pytest.mark.slow
def test_chaos_soak_with_prefix_sharing(stack):
    """Seeded retrieval chaos with sharing on: the fault-free subset is
    bitwise identical to a clean unshared run, every request reaches a
    terminal state, and the pool shows zero leaked or double-freed blocks
    (RGL_KV_DEBUG is armed suite-wide, so a double-free would raise)."""
    g, pipe, cfg, params = stack
    _, clean = _rag_run(stack, share=False, paged_kv=True, n=14, uniq=7)
    faulty = FaultyRetrieval(pipe, seed=23, fault_rate=0.25)
    bad_q = {qi for qi in range(7)
             if faulty.fault_of(np.asarray(g.node_feat[qi])) is not None}
    eng, got = _rag_run(stack, share=True, src=faulty, n=14, uniq=7,
                        paged_kv=True, max_retries=1,
                        retrieval_timeout_s=0.05)
    assert got  # the fault-free subset completed
    for u, out in got.items():
        if (u % 7) not in bad_q:
            assert out == clean[u]
    _assert_share_clean(eng)


# --------------------------------------------------- cache pin lifecycle ----
def _emb(i):
    return np.full(4, float(i), np.float32)


def _pinned_entry(owner, blocks, released):
    e = _blank_entry()
    e.kv_blocks = np.asarray(blocks, np.int32)
    e.kv_owner = owner

    def rel(entry):
        n = int(np.asarray(entry.kv_blocks).size)
        entry.kv_blocks = None
        entry.kv_release = None
        released.append(entry)
        return n

    e.kv_release = rel
    return e


def test_cache_releases_kv_pin_on_eviction_and_overwrite():
    released = []
    cache = RetrievalCache(capacity=2, policy="lru")
    e0 = _pinned_entry("eng", [1, 2], released)
    e1 = _pinned_entry("eng", [3], released)
    cache.put(_emb(0), e0)
    cache.put(_emb(1), e1)
    assert cache.is_resident(e0) and cache.is_resident(e1)
    cache.put(_emb(2), _blank_entry())  # capacity: evicts e0 (LRU)
    assert released == [e0] and not cache.is_resident(e0)
    # overwrite of a live key releases the displaced entry's pin
    cache.put(_emb(1), _blank_entry())
    assert released == [e0, e1] and not cache.is_resident(e1)
    assert cache.kv_pinned_entries() == 0


def test_cache_ttl_purge_releases_kv_pin_and_counts_expiry_once():
    released = []
    clock = {"t": 0.0}
    cache = RetrievalCache(capacity=2, policy="lru", ttl=1.0,
                           now_fn=lambda: clock["t"])
    e0 = _pinned_entry("eng", [0, 1, 2], released)
    cache.put(_emb(0), e0)
    clock["t"] = 2.0
    assert cache.get(_emb(0)) is None  # expired (counted once)
    assert cache.get(_emb(0)) is None
    assert cache.expired == 1
    assert cache.stats()["resident"] == 1 and cache.stats()["live"] == 0
    cache.put(_emb(1), _blank_entry())
    cache.put(_emb(2), _blank_entry())  # purge reclaims the expired entry
    assert released == [e0]
    assert cache.expired == 1  # purge does not double-count the expiry


def test_reclaim_kv_orders_victims_and_filters_owner():
    released = []
    clock = {"t": 0.0}
    cache = RetrievalCache(capacity=8, policy="lru", ttl=10.0,
                           now_fn=lambda: clock["t"])
    stale = _pinned_entry("eng", [0], released)
    cold = _pinned_entry("eng", [1, 2], released)
    warm = _pinned_entry("eng", [3, 4], released)
    other = _pinned_entry("other-eng", [5], released)
    cache.put(_emb(0), stale)
    clock["t"] = 11.0  # only `stale` is TTL-expired now
    cache.put(_emb(1), cold)
    cache.put(_emb(2), warm)
    cache.put(_emb(3), other)
    assert cache.get(_emb(2)) is warm  # refresh: cold is now least-recent
    # expired pins go first, then LRU order among the rest; other-owner
    # pins are untouched by an owner-filtered reclaim
    freed = cache.reclaim_kv(2, owner="eng")
    assert freed >= 2 and released[0] is stale and released[1] is cold
    assert warm.kv_blocks is not None and other.kv_blocks is not None
    freed = cache.reclaim_kv(100, owner="eng")
    assert released[-1] is warm and other.kv_blocks is not None
    # unfiltered reclaim takes the remaining foreign pin too
    assert cache.reclaim_kv(100) == 1 and other.kv_blocks is None
    # entries keep their retrieval results: only the pins were dropped
    assert len(cache) == 4 and cache.kv_pinned_entries() == 0


def test_pin_gate_rejects_non_resident_entry():
    """The engine consults kv_pin_gate before pinning: an entry that was
    evicted between submit and admission must not be pinned (the pin would
    hold pool blocks no eviction could ever release)."""
    eng = _share_engine(prefix_share=True)
    cache = RetrievalCache(capacity=1, policy="lru")
    eng.kv_pin_gate = cache.is_resident
    evicted = _blank_entry()
    cache.put(_emb(0), evicted)
    cache.put(_emb(1), _blank_entry())  # capacity 1: evicts `evicted`
    assert not cache.is_resident(evicted)
    p = np.arange(1, 13, dtype=np.int32)
    eng.submit(Request(uid=0, prompt_ids=p, max_new_tokens=4,
                       pin_to=evicted))
    eng.run_to_completion()
    assert evicted.kv_blocks is None and eng.kv_pins == 0
    assert eng._free_host == eng.pool_blocks  # nothing held back
