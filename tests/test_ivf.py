"""IVF index: recall vs brute on clustered data, tiled-scan parity with the
dense-gather oracle, vectorized list build, and degenerate edge cases."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.indexing import (
    BruteIndex, IVFIndex, build_inverted_lists, kmeans,
)
from repro.kernels.ivf_scan import ops as iops


def _clustered(rng, n_centers=10, per=120, d=24, spread=0.15):
    centers = rng.standard_normal((n_centers, d)).astype(np.float32) * 3
    pts = (centers[None].repeat(per, 0)
           + spread * rng.standard_normal((per, n_centers, d))).reshape(-1, d)
    return pts.astype(np.float32), centers


# ----------------------------------------------------------------- recall ----
def test_ivf_recall_on_clustered_data(rng):
    emb, centers = _clustered(rng)
    q = (centers[:8] + 0.1 * rng.standard_normal((8, centers.shape[1])))
    q = q.astype(np.float32)
    brute = BruteIndex.build(emb)
    ivf = IVFIndex.build(emb, n_clusters=16, nprobe=16)  # nprobe == C
    _, bi = brute.search(q, 10)
    _, ii = ivf.search(q, 10)
    rec = np.mean([
        len(set(np.asarray(ii[r]).tolist())
            & set(np.asarray(bi[r]).tolist())) / 10
        for r in range(8)
    ])
    assert rec >= 0.9, rec  # all lists probed -> should be (near-)exact


def test_ivf_recall_degrades_gracefully_with_fewer_probes(rng):
    emb, centers = _clustered(rng)
    q = centers[:8].astype(np.float32)
    ivf = IVFIndex.build(emb, n_clusters=16, nprobe=2)
    brute = BruteIndex.build(emb)
    _, bi = brute.search(q, 10)
    _, ii = ivf.search(q, 10)
    rec = np.mean([
        len(set(np.asarray(ii[r]).tolist())
            & set(np.asarray(bi[r]).tolist())) / 10
        for r in range(8)
    ])
    assert rec >= 0.5, rec  # queries sit on centroids: 2 probes find most


# ----------------------------------------------------- tiled scan parity ----
@pytest.mark.parametrize("trial", range(4))
def test_tiled_scan_bitwise_matches_dense_exact_arithmetic(trial):
    """Integer-valued embeddings: every dot product is exactly representable
    in fp32 regardless of summation order, so bitwise equality isolates the
    merge/tie logic from XLA's position-dependent vectorization rounding.
    Duplicate candidate ids force abundant exact score ties."""
    rng = np.random.default_rng(100 + trial)
    n, d, qn = 400, 16, 6
    w = int(rng.integers(12, 900))
    k = int(rng.integers(1, 24))
    emb = jnp.asarray(rng.integers(-3, 4, (n, d)), jnp.float32)
    q = jnp.asarray(rng.integers(-3, 4, (qn, d)), jnp.float32)
    cand_np = rng.integers(0, n + 1, (qn, w)).astype(np.int32)
    m = w // 3
    cand_np[:, :m] = cand_np[:, m:2 * m]  # duplicate ids -> score ties
    cand = jnp.asarray(cand_np)
    cmask = jnp.asarray(rng.random((qn, w)) < 0.7) & (cand < n)
    sd, idd = iops.ivf_candidate_scan(q, emb, cand, cmask, k, tiled=False)
    st, idt = iops.ivf_candidate_scan(q, emb, cand, cmask, k, tiled=True,
                                      c_blk=128)
    np.testing.assert_array_equal(
        np.asarray(sd).view(np.uint32), np.asarray(st).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(idd), np.asarray(idt))


@pytest.mark.parametrize("trial", range(3))
def test_tiled_scan_float_matches_dense_within_ulp(trial):
    """Float data: XLA CPU's einsum rounds position-dependently (the same id
    at two positions can differ by 1 ULP even within the dense path), so the
    contract is allclose scores + identical ids away from near-ties."""
    rng = np.random.default_rng(200 + trial)
    n, d, qn = 400, 16, 6
    w = int(rng.integers(12, 900))
    k = int(rng.integers(1, 24))
    emb = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((qn, d)), jnp.float32)
    cand = jnp.asarray(rng.integers(0, n + 1, (qn, w)), jnp.int32)
    cmask = jnp.asarray(rng.random((qn, w)) < 0.7) & (cand < n)
    sd, idd = iops.ivf_candidate_scan(q, emb, cand, cmask, k, tiled=False)
    st, idt = iops.ivf_candidate_scan(q, emb, cand, cmask, k, tiled=True,
                                      c_blk=128)
    sd, st, idd, idt = map(np.asarray, (sd, st, idd, idt))
    np.testing.assert_allclose(st, sd, rtol=1e-6, atol=1e-6)
    # ids must agree wherever the rank is not decided by a near-tie
    gap_prev = np.abs(np.diff(sd, axis=1, prepend=np.inf))
    gap_next = np.abs(np.diff(sd, axis=1, append=-np.inf))
    clear = np.minimum(gap_prev, gap_next) > 1e-4
    np.testing.assert_array_equal(idd[clear], idt[clear])


def test_tiled_scan_all_masked_rows():
    """Fewer valid candidates than k: -inf tail, same ids as the oracle.
    Integer-valued data keeps the comparison exact (see above)."""
    rng = np.random.default_rng(7)
    n, d, qn, w, k = 200, 8, 3, 300, 6
    emb = jnp.asarray(rng.integers(-3, 4, (n, d)), jnp.float32)
    q = jnp.asarray(rng.integers(-3, 4, (qn, d)), jnp.float32)
    cand = jnp.asarray(rng.integers(0, n, (qn, w)), jnp.int32)
    cmask = jnp.zeros((qn, w), bool).at[:, :2].set(True)  # 2 valid < k
    sd, idd = iops.ivf_candidate_scan(q, emb, cand, cmask, k, tiled=False)
    st, idt = iops.ivf_candidate_scan(q, emb, cand, cmask, k, tiled=True,
                                      c_blk=64)
    assert np.all(np.isneginf(np.asarray(sd)[:, 2:]))
    np.testing.assert_array_equal(
        np.asarray(sd).view(np.uint32), np.asarray(st).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(idd), np.asarray(idt))


def test_ivf_search_tiled_matches_dense_end_to_end():
    from repro.core.indexing import _ivf_search, l2_normalize

    rng = np.random.default_rng(11)
    emb, _ = _clustered(rng, n_centers=6, per=80)
    ivf = IVFIndex.build(emb, n_clusters=8, nprobe=3)
    q = l2_normalize(jnp.asarray(rng.standard_normal((5, emb.shape[1])),
                                 jnp.float32))
    args = (ivf.emb, ivf.centroids, ivf.lists, ivf.list_mask, q,
            ivf.nprobe, 7)
    sd, idd = _ivf_search(*args, tiled=False)
    st, idt = _ivf_search(*args, tiled=True)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sd),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(idd), np.asarray(idt))


# ------------------------------------------------------------ list build ----
def test_build_inverted_lists_matches_loop(rng):
    for n, c in [(0, 4), (1, 1), (37, 5), (400, 7)]:
        assign = rng.integers(0, c, n).astype(np.int64)
        lists, mask = build_inverted_lists(assign, n, c)
        counts = np.bincount(assign, minlength=c)
        pad = max(8, int(counts.max()) if n else 8)
        ref = np.full((c, pad), n, np.int32)
        fill = np.zeros(c, np.int64)
        for i in np.argsort(assign, kind="stable"):
            cl = assign[i]
            ref[cl, fill[cl]] = i
            fill[cl] += 1
        np.testing.assert_array_equal(lists, ref)
        np.testing.assert_array_equal(mask, ref < n)


def test_build_inverted_lists_empty_cluster(rng):
    assign = np.zeros(10, np.int64)  # every point in cluster 0
    lists, mask = build_inverted_lists(assign, 10, 4)
    assert mask[0].sum() == 10 and mask[1:].sum() == 0
    np.testing.assert_array_equal(np.sort(lists[0][mask[0]]), np.arange(10))


# ------------------------------------------------------------ degenerate ----
def test_kmeans_more_clusters_than_points(rng):
    x = jnp.asarray(rng.standard_normal((5, 8)), jnp.float32)
    cent, assign = kmeans(x, 12)  # used to crash choice(replace=False)
    assert cent.shape == (12, 8) and assign.shape == (5,)
    assert int(assign.max()) < 12


def test_ivf_build_clamps_clusters_and_nprobe(rng):
    emb = rng.standard_normal((6, 8)).astype(np.float32)
    ivf = IVFIndex.build(emb, n_clusters=32, nprobe=64)
    assert ivf.centroids.shape[0] <= 6
    assert ivf.nprobe <= ivf.centroids.shape[0]
    s, i = ivf.search(rng.standard_normal((2, 8)).astype(np.float32), 6)
    # every real node reachable: all 6 ids found across the probed lists
    assert set(np.asarray(i).flatten().tolist()) <= set(range(6))
    bs, bi = BruteIndex.build(emb).search(
        rng.standard_normal((2, 8)).astype(np.float32), 6)
    assert s.shape == bs.shape


def test_ivf_keeps_requested_k_when_candidates_are_narrow(rng):
    """k larger than the probed candidate width still yields (Q, k):
    the tail is (-inf, sentinel) padding, not a silently narrower array."""
    emb = rng.standard_normal((30, 8)).astype(np.float32)
    ivf = IVFIndex.build(emb, n_clusters=8, nprobe=1)
    w = ivf.nprobe * ivf.lists.shape[1]
    k = w + 5
    s, i = ivf.search(rng.standard_normal((3, 8)).astype(np.float32), k)
    assert s.shape == (3, k) and i.shape == (3, k)
    assert np.all(np.isneginf(np.asarray(s)[:, w:]))
    assert np.all(np.asarray(i)[:, w:] == 30)


def test_ivf_empty_cluster_probe_is_safe(rng):
    # duplicate points force empty clusters; probing them must not crash
    # or emit sentinel ids as results when real candidates exist
    emb = np.tile(rng.standard_normal((3, 8)).astype(np.float32), (20, 1))
    ivf = IVFIndex.build(emb, n_clusters=8, nprobe=8)
    s, i = ivf.search(emb[:4], 5)
    assert int(np.asarray(i).max()) < 60
    assert np.isfinite(np.asarray(s)).all()
