"""GNN zoo: forward shapes, gradients, equivariance, sampler-to-block path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import generators
from repro.models.gnn import GNNConfig, apply_gnn, gnn_loss, init_gnn
from repro.models.gnn.wigner import (
    build_wigner_lut, direction_bins, m_index_sets, real_sph_harm,
)


@pytest.fixture(scope="module")
def small_graph():
    g = generators.citation_graph(120, avg_deg=5, d_feat=24, seed=2)
    src, dst = g.edge_list()
    return {
        "node_feat": jnp.asarray(g.node_feat),
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
        "edge_mask": jnp.ones(len(src), bool),
        "targets": jnp.zeros((120, 6)),
    }


@pytest.mark.parametrize("arch", ["gin", "meshgraphnet", "graphcast"])
def test_gnn_forward_and_grad(arch, small_graph):
    d_out = 24 if arch == "graphcast" else 6
    cfg = GNNConfig(
        name=arch, arch=arch, n_layers=3, d_hidden=32, d_in=24, d_out=d_out,
        n_vars=24,
    )
    p = init_gnn(jax.random.PRNGKey(0), cfg)
    inputs = dict(small_graph)
    if arch == "graphcast":
        inputs["targets"] = jnp.zeros((120, 24))
    out = apply_gnn(p, cfg, inputs)
    assert out.shape == (120, d_out)
    assert not bool(jnp.isnan(out).any())
    g = jax.grad(lambda pp: gnn_loss(pp, cfg, inputs))(p)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_padded_edges_are_no_ops(small_graph):
    cfg = GNNConfig(name="gin", arch="gin", n_layers=2, d_hidden=16, d_in=24, d_out=6)
    p = init_gnn(jax.random.PRNGKey(0), cfg)
    out1 = apply_gnn(p, cfg, small_graph)
    n = small_graph["node_feat"].shape[0]
    e = small_graph["edge_src"].shape[0]
    padded = dict(
        small_graph,
        edge_src=jnp.concatenate([small_graph["edge_src"], jnp.full(13, n, jnp.int32)]),
        edge_dst=jnp.concatenate([small_graph["edge_dst"], jnp.full(13, n, jnp.int32)]),
        edge_mask=jnp.concatenate([small_graph["edge_mask"], jnp.zeros(13, bool)]),
    )
    out2 = apply_gnn(p, cfg, padded)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


# ------------------------------------------------------------- equiformer --
def test_sph_harm_orthonormal():
    rng = np.random.default_rng(0)
    s = rng.standard_normal((100_000, 3))
    s /= np.linalg.norm(s, axis=1, keepdims=True)
    Y = real_sph_harm(3, s)
    G = (Y.T @ Y) / len(s) * 4 * np.pi
    assert np.abs(G - np.eye(16)).max() < 0.02


def test_wigner_blocks_orthogonal_and_rotate_to_z():
    lut = build_wigner_lut(2, n_theta=8, n_phi=16, n_samples=256)
    yz = real_sph_harm(2, np.array([[0, 0, 1.0]]))[0]
    for b in (0, 37, 100):
        D = lut[b]
        assert np.abs(D @ D.T - np.eye(9)).max() < 1e-5
        th = (b // 16 + 0.5) / 8 * np.pi
        ph = ((b % 16) + 0.5) / 16 * 2 * np.pi - np.pi
        d = np.array([[np.sin(th) * np.cos(ph), np.sin(th) * np.sin(ph), np.cos(th)]])
        yd = real_sph_harm(2, d)[0]
        assert np.abs(D @ yd - yz).max() < 1e-6


def test_m_index_sets():
    ms = m_index_sets(3, 2)
    assert ms[0][0].tolist() == [0, 2, 6, 12]  # (l, m=0) at l^2+l
    assert ms[1][0].tolist() == [3, 7, 13]
    assert ms[1][1].tolist() == [1, 5, 11]
    assert len(ms[2][0]) == 2


@pytest.fixture(scope="module")
def equi_setup():
    g = generators.citation_graph(80, avg_deg=4, d_feat=16, seed=3)
    src, dst = g.edge_list()
    rng = np.random.default_rng(0)
    pos = rng.standard_normal((80, 3)).astype(np.float32)
    cfg = GNNConfig(
        name="eq", arch="equiformer_v2", n_layers=2, d_hidden=16, d_in=16,
        d_out=4, l_max=2, m_max=1, n_heads=4,
    )
    lut = jnp.asarray(build_wigner_lut(2, n_theta=32, n_phi=64, n_samples=256))
    inputs = {
        "node_feat": jnp.asarray(g.node_feat),
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
        "edge_mask": jnp.ones(len(src), bool),
        "pos": jnp.asarray(pos),
        "wigner_lut": lut,
        "targets": jnp.zeros((80, 4)),
    }
    params = init_gnn(jax.random.PRNGKey(1), cfg)
    return cfg, params, inputs, pos


@pytest.mark.slow
def test_equiformer_forward_and_grad(equi_setup):
    cfg, params, inputs, _ = equi_setup
    out = apply_gnn(params, cfg, inputs)
    assert out.shape == (80, 4) and not bool(jnp.isnan(out).any())
    g = jax.grad(lambda p: gnn_loss(p, cfg, inputs))(params)
    assert np.isfinite(sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g)))


def test_equiformer_rotation_invariance_improves_with_bins(equi_setup):
    cfg, params, inputs, pos = equi_setup
    th = 0.9
    R = np.array(
        [[np.cos(th), -np.sin(th), 0], [np.sin(th), np.cos(th), 0], [0, 0, 1]],
        dtype=np.float32,
    )
    o1 = apply_gnn(params, cfg, inputs)
    o2 = apply_gnn(params, cfg, dict(inputs, pos=jnp.asarray(pos @ R.T)))
    rel_fine = float(jnp.max(jnp.abs(o1 - o2))) / float(jnp.max(jnp.abs(o1)))
    lut_coarse = jnp.asarray(build_wigner_lut(2, n_theta=8, n_phi=16, n_samples=256))
    o1c = apply_gnn(params, cfg, dict(inputs, wigner_lut=lut_coarse))
    o2c = apply_gnn(
        params, cfg, dict(inputs, wigner_lut=lut_coarse, pos=jnp.asarray(pos @ R.T))
    )
    rel_coarse = float(jnp.max(jnp.abs(o1c - o2c))) / float(jnp.max(jnp.abs(o1c)))
    assert rel_fine < 0.15
    assert rel_fine < rel_coarse  # quantization error falls with bin count


def test_equiformer_edge_chunking_invariance(equi_setup):
    cfg, params, inputs, _ = equi_setup
    from repro.models.gnn.equiformer import apply_equiformer

    e = inputs["edge_src"].shape[0]
    # pad edges to a multiple of 4 chunks
    import math

    pe = math.ceil(e / 4) * 4
    pad = pe - e
    n = inputs["node_feat"].shape[0]
    inp = dict(
        inputs,
        edge_src=jnp.concatenate([inputs["edge_src"], jnp.full(pad, n, jnp.int32)]),
        edge_dst=jnp.concatenate([inputs["edge_dst"], jnp.full(pad, n, jnp.int32)]),
        edge_mask=jnp.concatenate([inputs["edge_mask"], jnp.zeros(pad, bool)]),
    )
    o1 = apply_equiformer(params, cfg, inp, edge_chunk=pe)
    o2 = apply_equiformer(params, cfg, inp, edge_chunk=pe // 4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)


# --------------------------------------------------- sampler-to-block path --
def test_sampled_block_trains_gnn():
    from repro.graph import NeighborSampler

    g = generators.citation_graph(400, avg_deg=6, d_feat=16, seed=5)
    s = NeighborSampler(g, (4, 3), seed=0)
    blk = s.sample(np.arange(16))
    # convert hops to edge list over union positions
    srcs, dsts = [], []
    # hop arrays give neighbor positions; frontier positions for hop h:
    frontier_pos = blk.seeds_pos
    for h, m in zip(blk.hops, blk.hop_masks):
        fp = np.repeat(frontier_pos, h.shape[1]).reshape(h.shape)
        srcs.append(h[m])
        dsts.append(fp[m])
        frontier_pos = h.reshape(-1)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    cap = len(blk.nodes)
    feat = np.zeros((cap, 16), np.float32)
    feat[: blk.n_valid] = g.node_feat[blk.nodes[: blk.n_valid]]
    cfg = GNNConfig(name="gin", arch="gin", n_layers=2, d_hidden=16, d_in=16, d_out=4)
    p = init_gnn(jax.random.PRNGKey(0), cfg)
    mask = np.zeros(cap, np.float32)
    mask[blk.seeds_pos] = 1.0
    inputs = {
        "node_feat": jnp.asarray(feat),
        "edge_src": jnp.asarray(src.astype(np.int32)),
        "edge_dst": jnp.asarray(dst.astype(np.int32)),
        "edge_mask": jnp.ones(len(src), bool),
        "targets": jnp.zeros((cap, 4)),
        "node_mask": jnp.asarray(mask),
    }
    loss = gnn_loss(p, cfg, inputs)
    assert np.isfinite(float(loss))
