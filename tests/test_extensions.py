"""PPR retrieval, converters, Functional API, Steiner approximation bound."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph_retrieval as gr
from repro.core import naive
from repro.graph import CSRGraph, csr_to_ell, generators
from repro.graph.convert import from_dgl, from_pyg, to_dgl, to_pyg


@pytest.fixture(scope="module")
def graph():
    g = generators.citation_graph(250, avg_deg=6, seed=11)
    return g, csr_to_ell(g), g.to_adj_dict()


# ------------------------------------------------------------------- PPR ---
def test_ppr_matches_naive_scores(graph):
    g, ell, adj = graph
    seeds = np.asarray([[3, 40], [99, 7]], np.int32)
    sub = gr.retrieve_subgraph(ell, jnp.asarray(seeds), "ppr", max_nodes=24,
                               n_iter=8)
    for qi in range(2):
        ref = naive.ppr_subgraph(adj, sorted(set(seeds[qi].tolist())), 24,
                                 n_iter=8)
        got = [int(v) for v, m in zip(np.asarray(sub.nodes[qi]),
                                      np.asarray(sub.mask[qi])) if m]
        # same top set (ordering may differ at float ties): compare top-12 sets
        assert set(got[:12]) == set(ref[:12])


def test_ppr_in_pipeline(graph):
    import dataclasses

    from repro.core import (
        BruteIndex, GraphTokenizer, PipelineConfig, RGLPipeline, Vocab,
        ExtractiveGenerator,
    )

    g, ell, _ = graph
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    pipe = RGLPipeline(
        graph=ell, index=BruteIndex.build(emb), node_emb=emb,
        tokenizer=GraphTokenizer(vocab, max_len=128, node_budget=8),
        generator=ExtractiveGenerator(vocab), node_text=g.node_text,
        config=PipelineConfig(strategy="ppr", k_seeds=3, max_nodes=24,
                              filter_budget=12),
    )
    out = pipe.run(emb[:3], [g.node_text[i] for i in range(3)])
    assert len(out["outputs"]) == 3


# ------------------------------------------------------------ converters ---
def test_pyg_roundtrip(graph):
    g, _, _ = graph
    g2 = from_pyg(to_pyg(g))
    assert g2.num_nodes == g.num_nodes and g2.num_edges == g.num_edges
    np.testing.assert_allclose(g2.node_feat, g.node_feat)
    for u in (0, 17, 123):
        assert sorted(g2.neighbors(u)) == sorted(g.neighbors(u))


def test_dgl_roundtrip(graph):
    g, _, _ = graph
    g2 = from_dgl(to_dgl(g))
    assert g2.num_nodes == g.num_nodes and g2.num_edges == g.num_edges
    for u in (0, 17, 123):
        assert sorted(g2.neighbors(u)) == sorted(g.neighbors(u))


# --------------------------------------------------------- functional API ---
def test_functional_api_composes_with_custom_stage(graph):
    from repro.core import BruteIndex, GraphTokenizer, Vocab
    from repro.core.functional import (
        compose, stage_embed, stage_filter, stage_seeds, stage_subgraph,
        stage_tokenize,
    )

    g, ell, _ = graph
    emb = jnp.asarray(g.node_feat)
    vocab = Vocab.build(g.node_text)
    calls = []

    def custom_stage(ctx):  # injected logic between retrieval and filtering
        calls.append(int(ctx["subgraph"].mask.sum()))
        return ctx

    run = compose(
        stage_embed(BruteIndex.build(emb)),
        stage_seeds(k=3),
        stage_subgraph(ell, "bfs", max_hops=2, max_nodes=32),
        custom_stage,
        stage_filter(emb, budget=10),
        stage_tokenize(GraphTokenizer(vocab, max_len=128, node_budget=8),
                       g.node_text),
    )
    ctx = run({"query_emb": emb[:4],
               "query_texts": [g.node_text[i] for i in range(4)]})
    assert ctx["prompt_ids"].shape == (4, 128)
    assert ctx["subgraph"].nodes.shape == (4, 10)
    assert calls and calls[0] > 0


# ------------------------------------------- Steiner approximation bound ---
def _exact_steiner_size(adj, terminals, n):
    """Brute force: smallest connected node set containing all terminals."""
    best = None
    nodes = list(range(n))
    for r in range(len(set(terminals)), n + 1):
        for cand in itertools.combinations(nodes, r):
            cs = set(cand)
            if not set(terminals) <= cs:
                continue
            start = next(iter(cs))
            seen, frontier = {start}, [start]
            while frontier:
                nxt = [w for u in frontier for w in adj[u]
                       if w in cs and w not in seen]
                seen.update(nxt)
                frontier = nxt
            if seen == cs:
                return r
        if best:
            break
    return n


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_steiner_within_2x_of_optimal_on_small_graphs(seed):
    rng = np.random.default_rng(seed)
    n = 9
    src = rng.integers(0, n, size=2 * n)
    dst = rng.integers(0, n, size=2 * n)
    # ensure connectivity with a path backbone
    back = np.arange(n - 1)
    g = CSRGraph.from_edges(
        np.concatenate([src, back]), np.concatenate([dst, back + 1]), n,
        symmetrize=True,
    )
    adj = g.to_adj_dict()
    ell = csr_to_ell(g)
    terms = sorted(set(rng.choice(n, size=3, replace=False).tolist()))
    opt = _exact_steiner_size(adj, terms, n)
    seeds = np.asarray([terms], np.int32)
    sub = gr.retrieve_subgraph(ell, jnp.asarray(seeds), "steiner",
                               max_hops=n, max_nodes=n)
    got = int(np.asarray(sub.mask[0]).sum())
    # KMB guarantee is 2x on EDGES; node-count slack +1 covers tie-breaks
    assert got <= 2 * opt + 1, (got, opt, terms)
