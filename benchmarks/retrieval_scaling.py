"""Paper Fig. 2/4: retrieval time vs query count, naive vs RGL-batched —
plus the corpus-size sweep for the workset-compacted backend.

``run`` reproduces the figure: the naive side is the NetworkX-class
pure-Python implementation (repro.core.naive) run per query; the RGL side
is the batched jit'd frontier algebra.  We report per-strategy wall time
at each query count, the speedup ratio, and the learning-time context (one
GIN training step on the same graph).  CPU-only container: RATIOS are the
reproduction target, not absolute times.

``run_corpus_sweep`` measures the claim behind the compact backend: dense
stage-3 cost grows with N (full-graph gathers per hop) while compact cost
is bounded by the workset capacity, so speedup grows with corpus size.
Results persist to ``BENCH_retrieval_scaling.json`` via ``write_json``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph_retrieval as gr
from repro.core import naive
from repro.graph import CSRGraph, csr_to_ell, generators
from repro.models.gnn import GNNConfig, gnn_loss, init_gnn


def run(n_nodes: int = 20_000, query_counts=(10, 100, 1000), seed: int = 0,
        max_hops: int = 3, max_nodes: int = 32, n_seeds: int = 4,
        strategies=("bfs", "steiner", "dense")) -> list:
    g = generators.citation_graph(n_nodes, avg_deg=12, d_feat=64, seed=seed,
                                  with_text=False)
    # cap ELL degree at 64 (hub truncation — standard for PA graphs; the
    # naive baseline keeps full adjacency, which only helps it)
    ell = csr_to_ell(g, max_deg=64)
    adj = g.to_adj_dict()
    q_chunk = 32  # process queries in fixed-shape batches (steiner builds
    # (Q, N*K) bridge tables — chunking bounds peak memory)
    rng = np.random.default_rng(seed)
    rows = []

    # learning-time context: one full-batch GIN step
    src, dst = g.edge_list()
    cfg = GNNConfig(name="gin", arch="gin", n_layers=3, d_hidden=64, d_in=64,
                    d_out=16)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    inputs = {
        "node_feat": jnp.asarray(g.node_feat),
        "edge_src": jnp.asarray(src), "edge_dst": jnp.asarray(dst),
        "edge_mask": jnp.ones(len(src), bool),
        "targets": jnp.zeros((n_nodes, 16)),
    }
    # pass inputs as jit args (closure capture would constant-fold the graph)
    grad_fn = jax.jit(lambda p, b: jax.grad(lambda pp: gnn_loss(pp, cfg, b))(p))
    jax.block_until_ready(grad_fn(params, inputs))  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(grad_fn(params, inputs))
    learn_s = time.perf_counter() - t0
    rows.append({"name": "gin_train_step", "queries": 0, "seconds": learn_s,
                 "speedup": 1.0})

    naive_fn = {
        "bfs": lambda s: naive.bfs_subgraph(adj, s, max_hops, max_nodes),
        "steiner": lambda s: naive.steiner_subgraph(adj, s, max_hops + 1, max_nodes),
        "dense": lambda s: naive.dense_subgraph(adj, s, 2, max_nodes),
    }
    batched_kw = {
        "bfs": dict(max_hops=max_hops, max_nodes=max_nodes),
        "steiner": dict(max_hops=max_hops + 1, max_nodes=max_nodes),
        "dense": dict(max_hops=2, max_nodes=max_nodes),
    }

    for strat in strategies:
        for q in query_counts:
            if strat == "steiner" and q > 200:
                continue  # measured at <=100, linear extrapolation in report
            seeds = rng.integers(0, n_nodes, size=(q, n_seeds)).astype(np.int32)
            # --- naive, per query (cap the measured subset & extrapolate) ---
            q_meas = min(q, 100)
            t0 = time.perf_counter()
            for i in range(q_meas):
                naive_fn[strat](sorted(set(seeds[i].tolist())))
            t_naive = (time.perf_counter() - t0) * (q / q_meas)
            # --- RGL batched (jit; exclude compile like the paper excludes
            # library setup): warm-up on the same shapes, then chunked ------
            pad = (-len(seeds)) % q_chunk
            sp = np.concatenate([seeds, seeds[:pad]]) if pad else seeds
            chunks = [jnp.asarray(sp[i:i + q_chunk])
                      for i in range(0, len(sp), q_chunk)]
            out = gr.retrieve_subgraph(ell, chunks[0], strat, **batched_kw[strat])
            jax.block_until_ready(out.nodes)
            t0 = time.perf_counter()
            for ch in chunks:
                out = gr.retrieve_subgraph(ell, ch, strat, **batched_kw[strat])
                jax.block_until_ready(out.nodes)
            t_rgl = time.perf_counter() - t0
            rows.append({
                "name": f"naive_{strat}", "queries": q, "seconds": t_naive,
                "speedup": 1.0,
            })
            rows.append({
                "name": f"rgl_{strat}", "queries": q, "seconds": t_rgl,
                "speedup": t_naive / max(t_rgl, 1e-9),
            })
    return rows


# ---------------------------------------------------------------------------
# Corpus-size sweep: dense (O(N) per hop) vs compact (O(workset_cap) per hop)
# ---------------------------------------------------------------------------

# dense-path measurement ceilings: beyond these N the dense leg is skipped
# (steiner's dense bridge tables are (Q, N*K); dense peeling re-gathers
# (Q, N, K) per round) — the compact leg always runs.
_DENSE_N_CEILING = {"bfs": None, "ppr": None, "dense": 200_000,
                    "steiner": 50_000}


def _random_ell(n: int, out_deg: int, max_deg: int, seed: int):
    """Vectorized uniform random graph (the PA generator is a Python loop —
    unusable at 500k nodes).  Symmetrized, ELL degree capped at max_deg."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, n, size=(n, out_deg), dtype=np.int64)
    src = np.repeat(np.arange(n, dtype=np.int64), out_deg)
    g = CSRGraph.from_edges(src, dst.ravel(), n, symmetrize=True)
    return csr_to_ell(g, max_deg=max_deg)


def _time_call(fn, repeats: int) -> float:
    out = fn()
    jax.block_until_ready(out.nodes)  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out.nodes)
        best = min(best, time.perf_counter() - t0)
    return best


def run_corpus_sweep(
    corpus_sizes=(50_000, 200_000, 500_000),
    strategies=("bfs", "dense", "steiner", "ppr"),
    n_queries: int = 16,
    n_seeds: int = 4,
    max_nodes: int = 32,
    workset_cap: int = 4096,
    out_deg: int = 4,
    max_deg: int = 32,
    repeats: int = 2,
    seed: int = 0,
) -> dict:
    kw = {
        "bfs": dict(max_hops=3, max_nodes=max_nodes),
        "dense": dict(max_hops=2, max_nodes=max_nodes),
        "steiner": dict(max_hops=3, max_nodes=max_nodes),
        "ppr": dict(max_nodes=max_nodes),
    }
    rng = np.random.default_rng(seed)
    results = []
    for n in corpus_sizes:
        ell = _random_ell(n, out_deg, max_deg, seed)
        seeds = jnp.asarray(
            rng.integers(0, n, size=(n_queries, n_seeds)).astype(np.int32)
        )
        for strat in strategies:
            compact = lambda: gr.retrieve_subgraph(  # noqa: E731
                ell, seeds, strat, mode="compact", workset_cap=workset_cap,
                **kw[strat],
            )
            t_compact = _time_call(compact, repeats)
            sub = compact()
            ovf = float(np.asarray(sub.overflow).mean())
            ceiling = _DENSE_N_CEILING[strat]
            if ceiling is not None and n > ceiling:
                results.append({
                    "n": n, "strategy": strat, "compact_s": t_compact,
                    "compact_overflow_frac": ovf, "dense_s": None,
                    "speedup": None,
                    "dense_skipped": f"dense {strat} capped at N<={ceiling}",
                })
                continue
            dense = lambda: gr.retrieve_subgraph(  # noqa: E731
                ell, seeds, strat, mode="dense", **kw[strat]
            )
            t_dense = _time_call(dense, repeats)
            results.append({
                "n": n, "strategy": strat, "compact_s": t_compact,
                "compact_overflow_frac": ovf, "dense_s": t_dense,
                "speedup": t_dense / max(t_compact, 1e-9),
                "dense_skipped": None,
            })
    return {
        "config": {
            "corpus_sizes": list(corpus_sizes), "strategies": list(strategies),
            "n_queries": n_queries, "n_seeds": n_seeds,
            "max_nodes": max_nodes, "workset_cap": workset_cap,
            "out_deg": out_deg, "max_deg": max_deg, "repeats": repeats,
            "backend": jax.default_backend(),
        },
        "results": results,
    }


def write_json(report: dict, path: str = "BENCH_retrieval_scaling.json"):
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")


def main():
    rows = run()
    print("name,queries,seconds,speedup_vs_naive")
    for r in rows:
        print(f"{r['name']},{r['queries']},{r['seconds']:.4f},{r['speedup']:.1f}")
    rep = run_corpus_sweep()
    write_json(rep)
    print("strategy,n,dense_s,compact_s,speedup,overflow_frac")
    for r in rep["results"]:
        d = "skip" if r["dense_s"] is None else f"{r['dense_s']:.4f}"
        s = "-" if r["speedup"] is None else f"{r['speedup']:.2f}x"
        print(f"{r['strategy']},{r['n']},{d},{r['compact_s']:.4f},{s},"
              f"{r['compact_overflow_frac']:.2f}")
    return rows, rep


if __name__ == "__main__":
    main()
