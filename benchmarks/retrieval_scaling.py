"""Paper Fig. 2/4: retrieval time vs query count, naive vs RGL-batched.

The naive side is the NetworkX-class pure-Python implementation
(repro.core.naive) run per query; the RGL side is the batched jit'd frontier
algebra.  We report per-strategy wall time at each query count, the speedup
ratio, and the learning-time context (one GIN training step on the same
graph), reproducing the figure's stacked structure.  CPU-only container:
RATIOS are the reproduction target, not absolute times.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph_retrieval as gr
from repro.core import naive
from repro.graph import csr_to_ell, generators
from repro.models.gnn import GNNConfig, gnn_loss, init_gnn


def run(n_nodes: int = 20_000, query_counts=(10, 100, 1000), seed: int = 0,
        max_hops: int = 3, max_nodes: int = 32, n_seeds: int = 4,
        strategies=("bfs", "steiner", "dense")) -> list:
    g = generators.citation_graph(n_nodes, avg_deg=12, d_feat=64, seed=seed,
                                  with_text=False)
    # cap ELL degree at 64 (hub truncation — standard for PA graphs; the
    # naive baseline keeps full adjacency, which only helps it)
    ell = csr_to_ell(g, max_deg=64)
    adj = g.to_adj_dict()
    q_chunk = 32  # process queries in fixed-shape batches (steiner builds
    # (Q, N*K) bridge tables — chunking bounds peak memory)
    rng = np.random.default_rng(seed)
    rows = []

    # learning-time context: one full-batch GIN step
    src, dst = g.edge_list()
    cfg = GNNConfig(name="gin", arch="gin", n_layers=3, d_hidden=64, d_in=64,
                    d_out=16)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    inputs = {
        "node_feat": jnp.asarray(g.node_feat),
        "edge_src": jnp.asarray(src), "edge_dst": jnp.asarray(dst),
        "edge_mask": jnp.ones(len(src), bool),
        "targets": jnp.zeros((n_nodes, 16)),
    }
    # pass inputs as jit args (closure capture would constant-fold the graph)
    grad_fn = jax.jit(lambda p, b: jax.grad(lambda pp: gnn_loss(pp, cfg, b))(p))
    jax.block_until_ready(grad_fn(params, inputs))  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(grad_fn(params, inputs))
    learn_s = time.perf_counter() - t0
    rows.append({"name": "gin_train_step", "queries": 0, "seconds": learn_s,
                 "speedup": 1.0})

    naive_fn = {
        "bfs": lambda s: naive.bfs_subgraph(adj, s, max_hops, max_nodes),
        "steiner": lambda s: naive.steiner_subgraph(adj, s, max_hops + 1, max_nodes),
        "dense": lambda s: naive.dense_subgraph(adj, s, 2, max_nodes),
    }
    batched_kw = {
        "bfs": dict(max_hops=max_hops, max_nodes=max_nodes),
        "steiner": dict(max_hops=max_hops + 1, max_nodes=max_nodes),
        "dense": dict(max_hops=2, max_nodes=max_nodes),
    }

    for strat in strategies:
        for q in query_counts:
            if strat == "steiner" and q > 200:
                continue  # measured at <=100, linear extrapolation in report
            seeds = rng.integers(0, n_nodes, size=(q, n_seeds)).astype(np.int32)
            # --- naive, per query (cap the measured subset & extrapolate) ---
            q_meas = min(q, 100)
            t0 = time.perf_counter()
            for i in range(q_meas):
                naive_fn[strat](sorted(set(seeds[i].tolist())))
            t_naive = (time.perf_counter() - t0) * (q / q_meas)
            # --- RGL batched (jit; exclude compile like the paper excludes
            # library setup): warm-up on the same shapes, then chunked ------
            pad = (-len(seeds)) % q_chunk
            sp = np.concatenate([seeds, seeds[:pad]]) if pad else seeds
            chunks = [jnp.asarray(sp[i:i + q_chunk])
                      for i in range(0, len(sp), q_chunk)]
            out = gr.retrieve_subgraph(ell, chunks[0], strat, **batched_kw[strat])
            jax.block_until_ready(out.nodes)
            t0 = time.perf_counter()
            for ch in chunks:
                out = gr.retrieve_subgraph(ell, ch, strat, **batched_kw[strat])
                jax.block_until_ready(out.nodes)
            t_rgl = time.perf_counter() - t0
            rows.append({
                "name": f"naive_{strat}", "queries": q, "seconds": t_naive,
                "speedup": 1.0,
            })
            rows.append({
                "name": f"rgl_{strat}", "queries": q, "seconds": t_rgl,
                "speedup": t_naive / max(t_rgl, 1e-9),
            })
    return rows


def main():
    rows = run()
    print("name,queries,seconds,speedup_vs_naive")
    for r in rows:
        print(f"{r['name']},{r['queries']},{r['seconds']:.4f},{r['speedup']:.1f}")
    return rows


if __name__ == "__main__":
    main()
